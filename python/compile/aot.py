"""AOT lowering: JAX/Pallas PDHG chunks -> artifacts/pdhg_<bucket>.hlo.txt.

Run once at build time (`make artifacts`); the Rust runtime loads the HLO
text through `HloModuleProto::from_text_file` and executes it on the PJRT
CPU client.  HLO **text** (not `.serialize()`) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids.

Also writes artifacts/manifest.json describing every bucket (shapes,
iteration count, argument order) so the Rust side never hard-codes them.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(bucket: model.Bucket) -> str:
    specs = model.chunk_arg_specs(bucket)
    lowered = jax.jit(model.chunk_fn(bucket)).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default="",
                    help="comma-separated bucket names (default: all)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    wanted = set(filter(None, args.buckets.split(",")))
    manifest = {"format": "hlo-text", "pad_b": model.PAD_B, "buckets": []}
    for bucket in model.BUCKETS:
        if wanted and bucket.name not in wanted:
            continue
        text = lower_bucket(bucket)
        fname = f"pdhg_{bucket.name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["buckets"].append(
            {
                "name": bucket.name,
                "file": fname,
                "n": bucket.n,
                "r": bucket.r,
                "nz": bucket.nz,
                "iters": bucket.iters,
                "block": bucket.block,
                "args": [
                    "nz_val:f32[nz]", "nz_row:i32[nz]", "nz_col:i32[nz]",
                    "b:f32[r]", "c:f32[n]", "lo:f32[n]", "hi:f32[n]",
                    "z0:f32[n]", "y0:f32[r]", "tau:f32[1]", "sigma:f32[1]",
                ],
                "outputs": [
                    "z:f32[n]", "y:f32[r]",
                    "z_avg:f32[n]", "y_avg:f32[r]", "diag:f32[8]",
                ],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
