"""Layer-1 Pallas reduction kernels: blocked dot products.

Used by the Layer-2 PDHG model for objectives and residual norms:
`dot(c, z)`, `dot(b, y)`, and squared norms (as `dot(x, x)`).

Each grid step reduces one VMEM-resident block to a single partial sum;
the (tiny) final reduction over partials happens in plain jnp.  On a real
TPU the per-block reduction maps to VPU lane reductions over an 8x128
retile; on this image the kernel runs under interpret=True (see
pdhg_update.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pdhg_update import DEFAULT_BLOCK, _grid_1d


def _dot_kernel(x_ref, y_ref, out_ref):
    out_ref[0] = jnp.sum(x_ref[...] * y_ref[...])


@functools.partial(jax.named_call, name="pallas_block_dot")
def block_dot(x, y, *, block: int = DEFAULT_BLOCK):
    """dot(x, y) with a blocked Pallas partial-sum pass.

    Args:
      x, y: f32[n] (n a multiple of `block`).
    Returns:
      f32[] scalar.
    """
    n = x.shape[0]
    grid = _grid_1d(n, block)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    partial = pl.pallas_call(
        _dot_kernel,
        grid=(grid,),
        in_specs=[vec, vec],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), x.dtype),
        interpret=True,
    )(x, y)
    return jnp.sum(partial)


def sumsq(x, *, block: int = DEFAULT_BLOCK):
    """||x||_2^2 via block_dot(x, x)."""
    return block_dot(x, x, block=block)
