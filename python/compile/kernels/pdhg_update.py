"""Layer-1 Pallas kernels: the fused PDHG update steps.

These are the per-iteration elementwise hot spots of the restarted PDHG
(PDLP-style) LP solver used for the HLP / QHLP relaxations of the paper
(Amaris et al., 2017).  A PDHG iteration is

    z+  = clip(z - tau * (c + A^T y), lo, hi)        (primal prox)
    zb  = 2 z+ - z                                   (extrapolation)
    y+  = max(0, y + sigma * (A zb - b))             (dual prox)

The sparse matvecs (A zb, A^T y) stay in Layer 2 (gather + segment_sum);
the two fused prox/extrapolation updates below are the Pallas kernels.

TPU mapping (see DESIGN.md #Hardware-Adaptation): 1-D grid, each block a
`block`-element f32 slab resident in VMEM; the scalar step size rides along
as a (1,)-shaped operand mapped to every block.  `interpret=True` is
mandatory on this image: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO that the
Rust runtime's `PjRtClient::cpu()` runs directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _primal_kernel(tau_ref, z_ref, g_ref, lo_ref, hi_ref, znew_ref, zbar_ref):
    """znew = clip(z - tau*g, lo, hi); zbar = 2*znew - z, one VMEM block."""
    tau = tau_ref[0]
    z = z_ref[...]
    step = z - tau * g_ref[...]
    znew = jnp.minimum(jnp.maximum(step, lo_ref[...]), hi_ref[...])
    znew_ref[...] = znew
    zbar_ref[...] = 2.0 * znew - z


def _dual_kernel(sigma_ref, y_ref, r_ref, ynew_ref):
    """ynew = max(0, y + sigma*r), one VMEM block."""
    sigma = sigma_ref[0]
    ynew_ref[...] = jnp.maximum(y_ref[...] + sigma * r_ref[...], 0.0)


def _grid_1d(n: int, block: int) -> int:
    if n % block != 0:
        raise ValueError(f"size {n} not a multiple of block {block}")
    return n // block


@functools.partial(jax.named_call, name="pallas_primal_update")
def primal_update(z, g, lo, hi, tau, *, block: int = DEFAULT_BLOCK):
    """Fused primal prox + extrapolation.

    Args:
      z, g, lo, hi: f32[n] (n a multiple of `block`).
      tau: f32[1] step size.
    Returns:
      (z_new, z_bar): f32[n] each.
    """
    n = z.shape[0]
    grid = _grid_1d(n, block)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    scl = pl.BlockSpec((1,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((n,), z.dtype)
    return pl.pallas_call(
        _primal_kernel,
        grid=(grid,),
        in_specs=[scl, vec, vec, vec, vec],
        out_specs=[vec, vec],
        out_shape=[out, out],
        interpret=True,
    )(tau, z, g, lo, hi)


@functools.partial(jax.named_call, name="pallas_dual_update")
def dual_update(y, r, sigma, *, block: int = DEFAULT_BLOCK):
    """Fused dual prox: max(0, y + sigma * r).

    Args:
      y, r: f32[m] (m a multiple of `block`).
      sigma: f32[1] step size.
    Returns:
      y_new: f32[m].
    """
    m = y.shape[0]
    grid = _grid_1d(m, block)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    scl = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _dual_kernel,
        grid=(grid,),
        in_specs=[scl, vec, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((m,), y.dtype),
        interpret=True,
    )(sigma, y, r)
