"""Pure-jnp oracles for every Pallas kernel (the correctness reference).

pytest (python/tests/test_kernels.py) asserts allclose between each kernel
in pdhg_update.py / reduce.py and its oracle here, across shapes and seeds
(hypothesis).  Keep these boring and obviously correct.
"""

from __future__ import annotations

import jax.numpy as jnp


def primal_update(z, g, lo, hi, tau):
    """Oracle for kernels.pdhg_update.primal_update."""
    tau = jnp.asarray(tau).reshape(())
    znew = jnp.clip(z - tau * g, lo, hi)
    return znew, 2.0 * znew - z


def dual_update(y, r, sigma):
    """Oracle for kernels.pdhg_update.dual_update."""
    sigma = jnp.asarray(sigma).reshape(())
    return jnp.maximum(y + sigma * r, 0.0)


def block_dot(x, y):
    """Oracle for kernels.reduce.block_dot."""
    return jnp.sum(x * y)


def sumsq(x):
    return jnp.sum(x * x)
