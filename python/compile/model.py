"""Layer-2 JAX model: one AOT-compilable chunk of the restarted PDHG LP
solver for the paper's HLP / QHLP relaxations.

The Rust coordinator (Layer 3) builds the LP

    minimize    c^T z
    subject to  A z <= b          (A sparse, COO)
                lo <= z <= hi

from the precedence DAG (constraints (1)-(6) of HLP, (9)-(14) of QHLP,
equalities split into two inequalities), Ruiz-preconditions it, pads it
into a static (N, R, NZ) *bucket*, and then repeatedly executes the
`pdhg_chunk` computation below — each call advances `ITERS` PDHG
iterations and reports a duality-gap certificate, so Rust decides when to
stop.  Python never runs after `make artifacts`.

Padding contract (what Rust must send):
  * padded columns:  c = 0, lo = hi = 0            -> z stays 0
  * padded rows:     b = +PAD_B (huge)             -> slack, y stays 0
  * padded nnz:      val = 0, row = 0, col = 0     -> contributes nothing

The fused elementwise updates are Layer-1 Pallas kernels
(kernels/pdhg_update.py); the sparse matvecs are gather + segment_sum,
which XLA fuses into the surrounding loop body.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import pdhg_update as pk
from .kernels import reduce as rk

PAD_B = 1.0e9  # b value for padded rows (see contract above)


class Bucket(NamedTuple):
    """Static shape class for one compiled artifact."""

    name: str
    n: int  # padded number of primal variables (multiple of block)
    r: int  # padded number of rows (multiple of block)
    nz: int  # padded number of nonzeros
    iters: int  # PDHG iterations per executable call
    block: int  # Pallas block length


# The artifact ladder.  Sized for the paper's campaign (Section 6):
#   HLP  has N = 2n+1 variables, R = |E| + n_src + #sinks + 2 rows;
#   QHLP has N = (Q+1)n + 1, R = |E| + n_src + #sinks + 2n + Q rows
# with n up to 4620 tasks (potri, nb_blocks=20) and |E| up to ~13k arcs.
# Small buckets keep padding waste low for the nb_blocks=5/10 instances
# (a tiny LP in a huge bucket pays the full padded matvec every
# iteration — see EXPERIMENTS.md §Perf).
BUCKETS = [
    Bucket("t0", n=512, r=1024, nz=4096, iters=250, block=512),
    Bucket("t1", n=1024, r=2048, nz=8192, iters=250, block=1024),
    Bucket("t2", n=2048, r=4096, nz=16384, iters=250, block=2048),
    Bucket("b0", n=4096, r=8192, nz=32768, iters=250, block=4096),
    Bucket("b1", n=8192, r=16384, nz=65536, iters=250, block=4096),
    Bucket("b2", n=16384, r=32768, nz=131072, iters=250, block=4096),
    Bucket("b3", n=32768, r=65536, nz=262144, iters=250, block=4096),
]


def matvec(nz_val, nz_row, nz_col, z, num_rows):
    """A @ z for COO A (padded entries are (0,0,0) and contribute 0)."""
    return jax.ops.segment_sum(
        nz_val * jnp.take(z, nz_col, mode="clip"), nz_row, num_segments=num_rows
    )


def rmatvec(nz_val, nz_row, nz_col, y, num_cols):
    """A^T @ y."""
    return jax.ops.segment_sum(
        nz_val * jnp.take(y, nz_row, mode="clip"), nz_col, num_segments=num_cols
    )


def _diagnostics(nz_val, nz_row, nz_col, b, c, lo, hi, z, y, *, n, r, block):
    """KKT residuals + primal/dual objectives (the stopping certificate).

    dual objective of (min c'z : Az<=b, lo<=z<=hi) at y>=0 with reduced
    cost rc = c + A'y:  g(y) = -b'y + sum_j min(rc_j*lo_j, rc_j*hi_j).
    Padded rows carry b = PAD_B with y = 0; mask them out of b'y anyway to
    stay exact under nonzero dual noise.
    """
    az = matvec(nz_val, nz_row, nz_col, z, r)
    rc = c + rmatvec(nz_val, nz_row, nz_col, y, n)
    live_row = (b < PAD_B / 2).astype(z.dtype)
    pviol = jnp.maximum(az - b, 0.0) * live_row
    pres = jnp.sqrt(rk.sumsq(pviol, block=block))
    # dual residual: distance from z to the box-projected gradient step
    dres = jnp.sqrt(rk.sumsq(z - jnp.clip(z - rc, lo, hi), block=block))
    pobj = rk.block_dot(c, z, block=block)
    dobj = -rk.block_dot(b * live_row, y, block=block) + jnp.sum(
        jnp.minimum(rc * lo, rc * hi)
    )
    return pobj, dobj, pres, dres


def pdhg_chunk(nz_val, nz_row, nz_col, b, c, lo, hi, z0, y0, tau, sigma, *, bucket: Bucket):
    """Run `bucket.iters` PDHG iterations from (z0, y0).

    Returns (z, y, z_avg, y_avg, diag) where (z_avg, y_avg) is the
    in-chunk ergodic average (the restart-to-average candidate, as in
    PDLP) and diag = f32[8] = [pobj, dobj, pres, dres] for the last
    iterate followed by the same four values for the average.
    """
    n, r, block = bucket.n, bucket.r, bucket.block

    def body(_, state):
        z, y, sz, sy = state
        g = c + rmatvec(nz_val, nz_row, nz_col, y, n)
        z_new, z_bar = pk.primal_update(z, g, lo, hi, tau, block=block)
        resid = matvec(nz_val, nz_row, nz_col, z_bar, r) - b
        y_new = pk.dual_update(y, resid, sigma, block=block)
        return (z_new, y_new, sz + z_new, sy + y_new)

    init = (z0, y0, jnp.zeros_like(z0), jnp.zeros_like(y0))
    z, y, sz, sy = lax.fori_loop(0, bucket.iters, body, init)
    z_avg = sz / bucket.iters
    y_avg = sy / bucket.iters
    d_last = _diagnostics(
        nz_val, nz_row, nz_col, b, c, lo, hi, z, y, n=n, r=r, block=block
    )
    d_avg = _diagnostics(
        nz_val, nz_row, nz_col, b, c, lo, hi, z_avg, y_avg, n=n, r=r, block=block
    )
    diag = jnp.stack(list(d_last) + list(d_avg))
    return z, y, z_avg, y_avg, diag


def chunk_fn(bucket: Bucket):
    """The jittable entry point for one bucket (fixed shapes)."""

    def fn(nz_val, nz_row, nz_col, b, c, lo, hi, z0, y0, tau, sigma):
        return pdhg_chunk(
            nz_val, nz_row, nz_col, b, c, lo, hi, z0, y0, tau, sigma, bucket=bucket
        )

    return fn


def chunk_arg_specs(bucket: Bucket):
    """ShapeDtypeStructs in the exact positional order of chunk_fn."""
    f32 = jnp.float32
    i32 = jnp.int32
    s = jax.ShapeDtypeStruct
    return (
        s((bucket.nz,), f32),  # nz_val
        s((bucket.nz,), i32),  # nz_row
        s((bucket.nz,), i32),  # nz_col
        s((bucket.r,), f32),  # b
        s((bucket.n,), f32),  # c
        s((bucket.n,), f32),  # lo
        s((bucket.n,), f32),  # hi
        s((bucket.n,), f32),  # z0
        s((bucket.r,), f32),  # y0
        s((1,), f32),  # tau
        s((1,), f32),  # sigma
    )


# ---------------------------------------------------------------------------
# Reference drive loop (build/test-time only): mirrors what the Rust
# runtime does across chunks.  Used by pytest to check that chunked PDHG
# actually solves LPs to optimality.
# ---------------------------------------------------------------------------


def estimate_opnorm(nz_val, nz_row, nz_col, n, r):
    """sqrt(||A||_1 * ||A||_inf) >= ||A||_2 (cheap, matches the Rust side)."""
    av = jnp.abs(nz_val)
    col_sums = jax.ops.segment_sum(av, nz_col, num_segments=n)
    row_sums = jax.ops.segment_sum(av, nz_row, num_segments=r)
    return jnp.sqrt(jnp.max(col_sums) * jnp.max(row_sums))


def solve(nz_val, nz_row, nz_col, b, c, lo, hi, *, bucket: Bucket,
          max_chunks: int = 200, tol: float = 1e-4):
    """Drive pdhg_chunk until the relative gap + residuals close."""
    norm_a = float(estimate_opnorm(nz_val, nz_row, nz_col, bucket.n, bucket.r))
    eta = 1.0 / max(norm_a, 1e-12)
    tau = jnp.array([0.9 * eta], jnp.float32)
    sigma = jnp.array([0.9 * eta], jnp.float32)
    z = jnp.zeros((bucket.n,), jnp.float32)
    y = jnp.zeros((bucket.r,), jnp.float32)
    fn = jax.jit(chunk_fn(bucket))
    info = {}
    for chunk in range(max_chunks):
        z, y, z_avg, y_avg, diag = fn(
            nz_val, nz_row, nz_col, b, c, lo, hi, z, y, tau, sigma)
        vals = [float(v) for v in diag]
        score = lambda d: d[2] + d[3] + abs(d[0] - d[1])
        # restart-to-average when the ergodic point is better (PDLP)
        if score(vals[4:]) < score(vals[:4]):
            z, y = z_avg, y_avg
            pobj, dobj, pres, dres = vals[4:]
        else:
            pobj, dobj, pres, dres = vals[:4]
        scale = 1.0 + abs(pobj) + abs(dobj)
        gap = abs(pobj - dobj) / scale
        info = dict(pobj=pobj, dobj=dobj, pres=pres, dres=dres, gap=gap,
                    chunks=chunk + 1, iters=(chunk + 1) * bucket.iters)
        if gap < tol and pres / scale < tol and dres / scale < tol:
            break
    return z, y, info


def pad_coo(rows, cols, vals, b, c, lo, hi, bucket: Bucket):
    """Pad a concrete LP into `bucket` shapes per the padding contract."""
    import numpy as np

    nz = len(vals)
    if nz > bucket.nz or len(b) > bucket.r or len(c) > bucket.n:
        raise ValueError("LP does not fit bucket")
    nz_val = np.zeros(bucket.nz, np.float32)
    nz_row = np.zeros(bucket.nz, np.int32)
    nz_col = np.zeros(bucket.nz, np.int32)
    nz_val[:nz] = vals
    nz_row[:nz] = rows
    nz_col[:nz] = cols
    bb = np.full(bucket.r, PAD_B, np.float32)
    bb[: len(b)] = b
    cc = np.zeros(bucket.n, np.float32)
    cc[: len(c)] = c
    ll = np.zeros(bucket.n, np.float32)
    ll[: len(lo)] = lo
    hh = np.zeros(bucket.n, np.float32)
    hh[: len(hi)] = hi
    return (jnp.asarray(nz_val), jnp.asarray(nz_row), jnp.asarray(nz_col),
            jnp.asarray(bb), jnp.asarray(cc), jnp.asarray(ll), jnp.asarray(hh))
