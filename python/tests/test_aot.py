"""AOT lowering: HLO-text artifacts + manifest have the right structure."""

import json
import os
import re
import subprocess
import sys

import pytest

from compile import aot, model

TINY = model.Bucket("t", n=256, r=512, nz=1024, iters=10, block=256)


def test_lower_tiny_bucket_has_entry_layout():
    text = aot.lower_bucket(TINY)
    assert text.startswith("HloModule")
    # entry layout carries the exact bucket shapes in positional order
    assert "f32[1024]" in text  # nz_val
    assert "s32[1024]" in text  # nz_row / nz_col
    assert "f32[512]" in text  # b / y0
    assert "f32[256]" in text  # c / lo / hi / z0
    assert "ENTRY" in text
    # 3 outputs: z, y, diag
    m = re.search(r"->\((.*?)\)\}", text)
    assert m and m.group(1).count("f32") == 5
    assert "f32[8]" in m.group(1)


def test_lower_is_deterministic():
    assert aot.lower_bucket(TINY) == aot.lower_bucket(TINY)


def test_manifest_written(tmp_path):
    # run the module CLI for the smallest real bucket only
    env = dict(os.environ)
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--buckets", "b0"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
    )
    man = json.loads((out / "manifest.json").read_text())
    assert man["format"] == "hlo-text"
    names = [b["name"] for b in man["buckets"]]
    assert names == ["b0"]
    b0 = man["buckets"][0]
    assert (out / b0["file"]).exists()
    assert b0["n"] == 4096 and b0["r"] == 8192 and b0["nz"] == 32768
    assert b0["args"][0] == "nz_val:f32[nz]"
    assert b0["outputs"][-1] == "diag:f32[8]"


def test_bucket_ladder_covers_campaign():
    """Largest campaign LP (QHLP potri nb=20: n=4620 tasks, Q=3) fits b3."""
    n_tasks, q, arcs = 4620, 3, 13000
    n_vars = (q + 1) * n_tasks + 1
    rows = arcs + n_tasks + n_tasks + 2 * n_tasks + q
    big = model.BUCKETS[-1]
    assert n_vars <= big.n and rows <= big.r
