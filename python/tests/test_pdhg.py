"""L2 PDHG solver end-to-end: solves LPs to certified optimality.

Cross-checked against scipy.linprog (HiGHS) on random box LPs and on a
hand-built HLP instance (the paper's allocation LP for a small DAG) —
this mirrors exactly what the Rust `lp::model` builder emits.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy.optimize import linprog

import jax.numpy as jnp

from compile import model

TINY = model.Bucket("t", n=256, r=256, nz=1024, iters=300, block=256)
SMALL = model.Bucket("s", n=512, r=512, nz=2048, iters=300, block=512)


def solve_pdhg(rows, cols, vals, b, c, lo, hi, bucket=TINY, tol=1e-5):
    args = model.pad_coo(rows, cols, vals, b, c, lo, hi, bucket)
    z, y, info = model.solve(*args, bucket=bucket, tol=tol)
    return np.asarray(z[: len(c)]), info


def solve_scipy(rows, cols, vals, b, c, lo, hi):
    nr, nc = len(b), len(c)
    a = np.zeros((nr, nc))
    for r_, c_, v in zip(rows, cols, vals):
        a[r_, c_] += v
    res = linprog(c, A_ub=a, b_ub=b, bounds=list(zip(lo, hi)),
                  method="highs")
    assert res.status == 0, res.message
    return res.fun


def test_knapsack_like_lp():
    # min -x1-x2 : x1+x2 <= 1.5, x in [0,1]^2  -> -1.5
    z, info = solve_pdhg([0, 0], [0, 1], [1.0, 1.0], [1.5], [-1, -1],
                         [0, 0], [1, 1])
    assert abs(info["pobj"] + 1.5) < 1e-4
    assert info["gap"] < 1e-4


def test_degenerate_single_var():
    # min x : x >= 3  (i.e. -x <= -3), x in [0, 10] -> 3
    z, info = solve_pdhg([0], [0], [-1.0], [-3.0], [1.0], [0.0], [10.0])
    assert abs(info["pobj"] - 3.0) < 1e-3


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_box_lp_matches_scipy(seed):
    r = np.random.default_rng(seed)
    nc = int(r.integers(3, 12))
    nr = int(r.integers(2, 10))
    dens = 0.5
    rows, cols, vals = [], [], []
    for i in range(nr):
        for j in range(nc):
            if r.random() < dens:
                rows.append(i)
                cols.append(j)
                vals.append(float(r.uniform(-2, 2)))
    if not rows:  # ensure at least one entry
        rows, cols, vals = [0], [0], [1.0]
    b = [float(r.uniform(0.5, 5)) for _ in range(nr)]  # b>0 => z=0 feasible
    c = [float(r.uniform(-1, 1)) for _ in range(nc)]
    lo = [0.0] * nc
    hi = [float(r.uniform(0.5, 3)) for _ in range(nc)]
    want = solve_scipy(rows, cols, vals, b, c, lo, hi)
    z, info = solve_pdhg(rows, cols, vals, b, c, lo, hi, tol=1e-6)
    scale = 1 + abs(want)
    assert abs(info["pobj"] - want) / scale < 2e-3, (info, want)


def build_hlp(n_tasks, arcs, p_cpu, p_gpu, m, k):
    """The paper's HLP relaxation (constraints (1)-(5)) in COO form.

    Variables: z = [x_0..x_{n-1}, C_0..C_{n-1}, lambda];
    x_j in [0,1]; C_j, lambda in [0, U].
    Mirrors rust/src/lp/model.rs exactly.
    """
    n = n_tasks
    xs = lambda j: j
    cs = lambda j: n + j
    lam = 2 * n
    rows, cols, vals, b = [], [], [], []
    row = 0
    has_pred = set(j for (_, j) in arcs)
    # (1) C_i + p̄_j x_j + p̠_j (1-x_j) <= C_j
    for (i, j) in arcs:
        rows += [row, row, row]
        cols += [cs(i), xs(j), cs(j)]
        vals += [1.0, p_cpu[j] - p_gpu[j], -1.0]
        b.append(-p_gpu[j])
        row += 1
    # (2) sources
    for j in range(n):
        if j in has_pred:
            continue
        rows += [row, row]
        cols += [xs(j), cs(j)]
        vals += [p_cpu[j] - p_gpu[j], -1.0]
        b.append(-p_gpu[j])
        row += 1
    # (3) C_j <= lambda
    for j in range(n):
        rows += [row, row]
        cols += [cs(j), lam]
        vals += [1.0, -1.0]
        b.append(0.0)
        row += 1
    # (4) CPU load
    for j in range(n):
        rows.append(row)
        cols.append(xs(j))
        vals.append(p_cpu[j] / m)
    rows.append(row)
    cols.append(lam)
    vals.append(-1.0)
    b.append(0.0)
    row += 1
    # (5) GPU load: (1/k) sum p̠_j (1 - x_j) <= lambda
    for j in range(n):
        rows.append(row)
        cols.append(xs(j))
        vals.append(-p_gpu[j] / k)
    rows.append(row)
    cols.append(lam)
    vals.append(-1.0)
    b.append(-sum(p_gpu) / k)
    row += 1

    u = sum(p_cpu)  # serial-CPU upper bound
    c = [0.0] * (2 * n) + [1.0]
    lo = [0.0] * (2 * n + 1)
    hi = [1.0] * n + [u] * (n + 1)
    return rows, cols, vals, b, c, lo, hi


def test_hlp_diamond_dag_matches_scipy():
    # Diamond: 0 -> {1, 2} -> 3 on m=2 CPUs, k=1 GPU.
    arcs = [(0, 1), (0, 2), (1, 3), (2, 3)]
    p_cpu = [4.0, 2.0, 6.0, 4.0]
    p_gpu = [1.0, 5.0, 1.0, 1.0]
    lp = build_hlp(4, arcs, p_cpu, p_gpu, 2, 1)
    want = solve_scipy(*lp)
    z, info = solve_pdhg(*lp, bucket=TINY, tol=1e-6)
    assert abs(info["pobj"] - want) / (1 + abs(want)) < 2e-3, (info, want)
    # lambda >= critical path on fastest device ((0,1,3) all GPU = 3)
    assert info["pobj"] >= 3.0 - 1e-3


def test_hlp_chain_all_faster_on_gpu():
    # Chain of 3, GPU always 1, CPU always 10, m=k=1: LP* = 3 (all GPU).
    arcs = [(0, 1), (1, 2)]
    lp = build_hlp(3, arcs, [10.0] * 3, [1.0] * 3, 1, 1)
    want = solve_scipy(*lp)
    z, info = solve_pdhg(*lp, tol=1e-6)
    assert abs(want - 3.0) < 1e-9
    assert abs(info["pobj"] - 3.0) < 5e-3


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hlp_random_dag_matches_scipy(seed):
    r = np.random.default_rng(seed)
    n = int(r.integers(4, 14))
    arcs = [(i, j) for i in range(n) for j in range(i + 1, n)
            if r.random() < 0.25]
    p_cpu = r.uniform(1, 10, n).tolist()
    p_gpu = r.uniform(0.2, 12, n).tolist()
    m = int(r.integers(1, 5))
    k = int(r.integers(1, m + 1))
    lp = build_hlp(n, arcs, p_cpu, p_gpu, m, k)
    want = solve_scipy(*lp)
    z, info = solve_pdhg(*lp, bucket=SMALL, tol=1e-6)
    assert abs(info["pobj"] - want) / (1 + abs(want)) < 5e-3, (info, want)
