"""Pallas kernels vs pure-jnp oracles (ref.py) — the core L1 correctness
signal.  Hypothesis sweeps shapes (several block sizes, multi-block grids)
and value regimes (including extreme step sizes and infinite-ish bounds).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pdhg_update as pk
from compile.kernels import reduce as rk
from compile.kernels import ref


def rng_arrays(seed, n, k, scale=10.0):
    r = np.random.default_rng(seed)
    return [r.uniform(-scale, scale, n).astype(np.float32) for _ in range(k)]


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    block=st.sampled_from([128, 256, 512]),
    nblocks=st.integers(1, 5),
    tau=st.floats(1e-6, 10.0),
)
def test_primal_update_matches_ref(seed, block, nblocks, tau):
    n = block * nblocks
    z, g, a, b_ = rng_arrays(seed, n, 4)
    lo, hi = np.minimum(a, b_), np.maximum(a, b_)
    tau_arr = jnp.array([tau], jnp.float32)
    got_z, got_zb = pk.primal_update(
        jnp.asarray(z), jnp.asarray(g), jnp.asarray(lo), jnp.asarray(hi),
        tau_arr, block=block)
    want_z, want_zb = ref.primal_update(z, g, lo, hi, np.float32(tau))
    np.testing.assert_allclose(got_z, want_z, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(got_zb, want_zb, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    block=st.sampled_from([128, 256, 512]),
    nblocks=st.integers(1, 5),
    sigma=st.floats(1e-6, 10.0),
)
def test_dual_update_matches_ref(seed, block, nblocks, sigma):
    m = block * nblocks
    y, r_ = rng_arrays(seed, m, 2)
    sig = jnp.array([sigma], jnp.float32)
    got = pk.dual_update(jnp.asarray(y), jnp.asarray(r_), sig, block=block)
    want = ref.dual_update(y, r_, np.float32(sigma))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    assert float(jnp.min(got)) >= 0.0


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    block=st.sampled_from([128, 256, 512]),
    nblocks=st.integers(1, 6),
)
def test_block_dot_matches_ref(seed, block, nblocks):
    n = block * nblocks
    x, y = rng_arrays(seed, n, 2, scale=2.0)
    got = float(rk.block_dot(jnp.asarray(x), jnp.asarray(y), block=block))
    want = float(ref.block_dot(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sumsq_nonnegative_and_exact_on_zeros():
    z = jnp.zeros((256,), jnp.float32)
    assert float(rk.sumsq(z, block=256)) == 0.0
    x = jnp.ones((512,), jnp.float32)
    assert float(rk.sumsq(x, block=256)) == 512.0


def test_primal_update_clips_to_box():
    n = 256
    z = jnp.full((n,), 100.0, jnp.float32)
    g = jnp.zeros((n,), jnp.float32)
    lo = jnp.zeros((n,), jnp.float32)
    hi = jnp.ones((n,), jnp.float32)
    znew, zbar = pk.primal_update(z, g, lo, hi, jnp.array([1.0], jnp.float32),
                                  block=n)
    np.testing.assert_allclose(znew, np.ones(n, np.float32))
    np.testing.assert_allclose(zbar, 2.0 * np.ones(n) - 100.0)


def test_block_size_must_divide():
    z = jnp.zeros((300,), jnp.float32)
    with pytest.raises(ValueError):
        pk.dual_update(z, z, jnp.array([1.0], jnp.float32), block=256)
