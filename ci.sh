#!/usr/bin/env bash
# CI gate: formatting, lints, tier-1 build + tests, and (optionally) the
# scheduler perf gate that refreshes BENCH_sched.json.
#
#   ./ci.sh          # fmt-check + clippy + tier-1
#   ./ci.sh --perf   # also run the perf_hot_paths acceptance bench
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "(rustfmt not installed; skipping format check)"
fi

echo "== cargo clippy -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "(clippy not installed; skipping lints)"
fi

echo "== hetlint =="
if cargo --version >/dev/null 2>&1; then
    # always-on static analysis: writes ANALYSIS.json, exits 1 on any
    # unsuppressed finding (see tools/hetlint/src/main.rs for the rules)
    cargo run -p hetlint --release
    cargo test -q -p hetlint
else
    echo "(cargo not installed; skipping hetlint)"
fi

echo "== reference-coupling check =="
# The golden-parity protocol, made mechanical: a diff that touches the
# engine decision files must also touch the parity pin or the reference
# oracle.  Base ref overridable for CI ranges (HETSCHED_COUPLE_BASE).
couple_base="${HETSCHED_COUPLE_BASE:-HEAD~1}"
if git rev-parse --verify -q "$couple_base" >/dev/null 2>&1; then
    changed="$(git diff --name-only "$couple_base" HEAD --)"
    engine_touched="$(printf '%s\n' "$changed" \
        | grep -E '^rust/src/sched/(engine|est|heft|online)\.rs$' || true)"
    if [[ -n "$engine_touched" ]] && ! printf '%s\n' "$changed" \
        | grep -qE '^(rust/tests/golden_parity\.rs|rust/src/sched/reference\.rs)$'; then
        echo "reference-coupling violation: $couple_base..HEAD touches" >&2
        printf '%s\n' "$engine_touched" >&2
        echo "without touching rust/tests/golden_parity.rs or rust/src/sched/reference.rs." >&2
        echo "Engine behavior changes must update the parity pin or the reference oracle (ROADMAP protocol)." >&2
        exit 1
    fi
    echo "coupling OK ($couple_base..HEAD)"
else
    echo "(base $couple_base not resolvable; skipping coupling check)"
fi

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== daemon smoke: serve-service kill -9 + WAL restart =="
if cargo --version >/dev/null 2>&1; then
    # end-to-end replay == rerun over real TCP: start the daemon on an
    # ephemeral port, submit two DAGs, snapshot the drained report,
    # kill -9 the daemon, restart it from the same WAL, and require the
    # restarted report byte-for-byte identical
    smoke_dir="$(mktemp -d)"
    hs=target/release/hetsched
    "$hs" serve-service --addr 127.0.0.1:0 --m 4 --k 2 \
        --wal "$smoke_dir/service.wal" --port-file "$smoke_dir/port" \
        >"$smoke_dir/daemon1.log" 2>&1 &
    daemon=$!
    for _ in $(seq 1 100); do [[ -s "$smoke_dir/port" ]] && break; sleep 0.1; done
    [[ -s "$smoke_dir/port" ]] || { cat "$smoke_dir/daemon1.log" >&2; exit 1; }
    addr="$(cat "$smoke_dir/port")"
    "$hs" submit --addr "$addr" --app potrf --nb 4 --bs 64 --arrival 0
    "$hs" submit --addr "$addr" --app getrf --nb 3 --bs 64 --arrival 5 --policy eft
    "$hs" report --addr "$addr" > "$smoke_dir/report_before"
    kill -9 "$daemon"
    wait "$daemon" 2>/dev/null || true
    "$hs" serve-service --addr 127.0.0.1:0 --m 4 --k 2 \
        --wal "$smoke_dir/service.wal" --port-file "$smoke_dir/port2" \
        >"$smoke_dir/daemon2.log" 2>&1 &
    daemon=$!
    for _ in $(seq 1 100); do [[ -s "$smoke_dir/port2" ]] && break; sleep 0.1; done
    [[ -s "$smoke_dir/port2" ]] || { cat "$smoke_dir/daemon2.log" >&2; exit 1; }
    addr="$(cat "$smoke_dir/port2")"
    "$hs" status --addr "$addr" --tenant 1 | grep -q '"n_placed"'
    "$hs" report --addr "$addr" > "$smoke_dir/report_after"
    "$hs" shutdown --addr "$addr"
    wait "$daemon" 2>/dev/null || true
    if ! diff -u "$smoke_dir/report_before" "$smoke_dir/report_after"; then
        echo "daemon smoke FAILED: report diverged across kill -9 + WAL restart" >&2
        exit 1
    fi
    echo "daemon smoke OK: report byte-identical across kill -9 + WAL restart"
    rm -rf "$smoke_dir"
else
    echo "(cargo not installed; skipping daemon smoke)"
fi

echo "== sharded daemon smoke: --shards 4 kill -9 + WAL restart =="
if cargo --version >/dev/null 2>&1; then
    # same replay == rerun contract, but through the two-level sharded
    # scheduler: 4 disjoint slices of an 8x4 pool, shard ids recorded in
    # the WAL decision stream and bitwise-verified on restart; also pins
    # the refusal path (a 4-shard WAL must not reopen at another count)
    shard_dir="$(mktemp -d)"
    hs=target/release/hetsched
    "$hs" serve-service --addr 127.0.0.1:0 --m 8 --k 4 --shards 4 \
        --wal "$shard_dir/service.wal" --port-file "$shard_dir/port" \
        >"$shard_dir/daemon1.log" 2>&1 &
    daemon=$!
    for _ in $(seq 1 100); do [[ -s "$shard_dir/port" ]] && break; sleep 0.1; done
    [[ -s "$shard_dir/port" ]] || { cat "$shard_dir/daemon1.log" >&2; exit 1; }
    addr="$(cat "$shard_dir/port")"
    "$hs" submit --addr "$addr" --app potrf --nb 4 --bs 64 --arrival 0
    "$hs" submit --addr "$addr" --app getrf --nb 3 --bs 64 --arrival 2 --policy eft
    "$hs" submit --addr "$addr" --app potrf --nb 3 --bs 64 --arrival 4 --policy greedy
    "$hs" submit --addr "$addr" --app getrf --nb 4 --bs 64 --arrival 6
    "$hs" report --addr "$addr" > "$shard_dir/report_before"
    kill -9 "$daemon"
    wait "$daemon" 2>/dev/null || true
    # the refusal path: reopening the 4-shard WAL at --shards 2 must fail
    if "$hs" serve-service --addr 127.0.0.1:0 --m 8 --k 4 --shards 2 \
        --wal "$shard_dir/service.wal" --port-file "$shard_dir/portX" \
        >"$shard_dir/daemonX.log" 2>&1; then
        echo "sharded smoke FAILED: 4-shard WAL reopened at --shards 2" >&2
        exit 1
    fi
    grep -q "shard" "$shard_dir/daemonX.log" \
        || { echo "shard-count refusal did not name the shard mismatch" >&2; cat "$shard_dir/daemonX.log" >&2; exit 1; }
    "$hs" serve-service --addr 127.0.0.1:0 --m 8 --k 4 --shards 4 \
        --wal "$shard_dir/service.wal" --port-file "$shard_dir/port2" \
        >"$shard_dir/daemon2.log" 2>&1 &
    daemon=$!
    for _ in $(seq 1 100); do [[ -s "$shard_dir/port2" ]] && break; sleep 0.1; done
    [[ -s "$shard_dir/port2" ]] || { cat "$shard_dir/daemon2.log" >&2; exit 1; }
    addr="$(cat "$shard_dir/port2")"
    "$hs" report --addr "$addr" > "$shard_dir/report_after"
    "$hs" shutdown --addr "$addr"
    wait "$daemon" 2>/dev/null || true
    if ! diff -u "$shard_dir/report_before" "$shard_dir/report_after"; then
        echo "sharded smoke FAILED: report diverged across kill -9 + WAL restart" >&2
        exit 1
    fi
    echo "sharded smoke OK: 4-shard report byte-identical across kill -9 + WAL restart; shard-count mismatch refused"
    rm -rf "$shard_dir"
else
    echo "(cargo not installed; skipping sharded daemon smoke)"
fi

echo "== trace determinism: two fresh daemon runs write byte-identical JSONL =="
if cargo --version >/dev/null 2>&1; then
    # the obs contract, end to end over real TCP: the --trace-out stream
    # carries virtual time only, so the same workload against two fresh
    # daemons must produce byte-identical trace files; while we're here,
    # the metrics and explain surfaces must serve
    tdir="$(mktemp -d)"
    hs=target/release/hetsched
    for i in 1 2; do
        "$hs" serve-service --addr 127.0.0.1:0 --m 4 --k 2 \
            --wal "$tdir/run$i.wal" --port-file "$tdir/port$i" \
            --trace-out "$tdir/trace$i.jsonl" >"$tdir/daemon$i.log" 2>&1 &
        tdaemon=$!
        for _ in $(seq 1 100); do [[ -s "$tdir/port$i" ]] && break; sleep 0.1; done
        [[ -s "$tdir/port$i" ]] || { cat "$tdir/daemon$i.log" >&2; exit 1; }
        taddr="$(cat "$tdir/port$i")"
        "$hs" submit --addr "$taddr" --app potrf --nb 4 --bs 64 --arrival 0 >/dev/null
        "$hs" submit --addr "$taddr" --app getrf --nb 3 --bs 64 --arrival 5 --policy eft >/dev/null
        "$hs" report --addr "$taddr" >/dev/null
        "$hs" metrics --addr "$taddr" | grep -q 'svc_decisions' \
            || { echo "metrics surface missing svc_decisions" >&2; exit 1; }
        "$hs" shutdown --addr "$taddr" >/dev/null
        wait "$tdaemon" 2>/dev/null || true
    done
    [[ -s "$tdir/trace1.jsonl" ]] || { echo "trace file missing or empty" >&2; exit 1; }
    if ! diff -u "$tdir/trace1.jsonl" "$tdir/trace2.jsonl"; then
        echo "trace determinism FAILED: two fresh runs wrote different traces" >&2
        exit 1
    fi
    "$hs" explain --wal "$tdir/run1.wal" --task 0:0 | grep -q 'rule:' \
        || { echo "explain output missing its rule line" >&2; exit 1; }
    echo "trace determinism OK: byte-identical JSONL across two runs; metrics + explain serve"
    rm -rf "$tdir"
else
    echo "(cargo not installed; skipping trace determinism)"
fi

if [[ "${1:-}" == "--perf" ]]; then
    echo "== perf gate: hetlint ANALYSIS.json clean =="
    if [[ ! -s ANALYSIS.json ]]; then
        echo "ANALYSIS.json missing or empty (the hetlint stage must have run)" >&2
        exit 1
    fi
    if command -v python3 >/dev/null 2>&1; then
        python3 - <<'PY' || exit 1
import json, sys
with open("ANALYSIS.json") as f:
    r = json.load(f)
findings = r.get("findings", [])
if findings:
    first = findings[0]
    sys.exit(
        f"ANALYSIS.json has {len(findings)} unsuppressed finding(s), e.g. "
        f"{first['file']}:{first['line']} [{first['rule']}]"
    )
bare = [s for s in r.get("suppressed", []) if not s.get("justification", "").strip()]
if bare:
    sys.exit(f"{len(bare)} suppression(s) without justification")
print(
    f"hetlint gate OK: 0 findings, {len(r.get('suppressed', []))} justified "
    f"suppressions over {r.get('files_scanned')} files"
)
PY
    fi

    echo "== perf gate: engine >= 5x seed EST, gap-index HEFT >= 1x scan, Tick clock >= banded f64 (writes BENCH_sched.json) =="
    HETSCHED_BENCH_QUICK=1 cargo bench --bench perf_hot_paths
    if command -v python3 >/dev/null 2>&1; then
        python3 - <<'PY' || exit 1
import json, sys
with open("BENCH_sched.json") as f:
    r = json.load(f)
est = r["est"]["speedup"]
if est < 5.0:
    sys.exit(f"EST engine speedup {est:.1f}x below the 5x acceptance gate")
heft = r["heft"]["speedup"]
if heft < 1.0:
    sys.exit(f"gap-index HEFT ({heft:.2f}x) must beat the 256-unit linear scan")
# integer-clock gate: the Tick comparator must not lose to the banded
# f64 compare it replaced (5% noise slack, same as the kernel gate)
clk = r["clock"]
if clk["tick_ms"] > clk["f64_ms"] * 1.05:
    sys.exit(
        f"Tick decision comparator ({clk['tick_ms']:.3f} ms) lost to the "
        f"banded f64 baseline ({clk['f64_ms']:.3f} ms)"
    )
print(f"sched gate OK: EST {est:.1f}x, gap-index HEFT {heft:.2f}x on {r['heft_instance']['platform']}, "
      f"Tick clock {clk['speedup']:.2f}x the banded-f64 comparator")
PY
    fi
    cat BENCH_sched.json

    echo "== perf gate: service-mode throughput + fairness policies (writes BENCH_service.json) =="
    cargo bench --bench service_throughput
    if [[ ! -s BENCH_service.json ]]; then
        echo "BENCH_service.json missing or empty" >&2
        exit 1
    fi
    if command -v python3 >/dev/null 2>&1; then
        python3 - <<'PY' || exit 1
import json, sys
with open("BENCH_service.json") as f:
    r = json.load(f)
# every admission policy must have produced its row, plus the sharded one
for key in ("fifo", "quota", "stretch", "sharded"):
    if key not in r:
        sys.exit(f"BENCH_service.json is missing the {key} row")
fifo, ws = r["fifo"], r["stretch"]
# sharded gate: on the same contended 50x1000 instance, the two-level
# scheduler (4 disjoint slices, quarter-size heaps and unit trees) must
# not be slower than the single-loop fifo row it shards
sh = r["sharded"]
if sh["tasks_per_sec"] < fifo["tasks_per_sec"]:
    sys.exit(
        f"sharded throughput {sh['tasks_per_sec']:.0f} tasks/s below the "
        f"single-loop fifo row's {fifo['tasks_per_sec']:.0f}"
    )
# fairness gate: on the contended 50x1000 bench, weighted-stretch
# admission must strictly beat FIFO on the stretch tail (the sim-
# measured margin is ~24%, so strictness costs no flakiness)
if ws["max_stretch"] >= fifo["max_stretch"]:
    sys.exit(
        f"WeightedStretch max stretch {ws['max_stretch']:.3f} must be strictly "
        f"below FIFO's {fifo['max_stretch']:.3f} on the contended bench"
    )
print(
    f"service gate OK: max stretch FIFO {fifo['max_stretch']:.2f} >= "
    f"WStretch {ws['max_stretch']:.2f} "
    f"(p99 {fifo['p99_stretch']:.2f} -> {ws['p99_stretch']:.2f}, "
    f"Jain {fifo['jain_index']:.3f} -> {ws['jain_index']:.3f}; "
    f"quota row max {r['quota']['max_stretch']:.2f}; "
    f"sharded {sh['tasks_per_sec']:.0f} >= fifo {fifo['tasks_per_sec']:.0f} tasks/s)"
)
PY
    fi
    cat BENCH_service.json

    echo "== perf gate: batched warm-start LP driver (writes BENCH_lp.json) =="
    cargo bench --bench lp_batch
    if [[ ! -s BENCH_lp.json ]]; then
        echo "BENCH_lp.json missing or empty" >&2
        exit 1
    fi
    if command -v python3 >/dev/null 2>&1; then
        python3 - <<'PY' || exit 1
import json, sys
with open("BENCH_lp.json") as f:
    r = json.load(f)
cold = r["cold"]["wall_s"]
warm = r["warm"]["wall_s"]
if warm > cold:
    sys.exit(f"warm-started grid ({warm:.3f} s) slower than cold per-solve baseline ({cold:.3f} s)")
# thread-count-independent work gate: total PDHG iterations (5% slack —
# an individual warm seed is not guaranteed to help, the gate is for
# systematic regressions)
wi, ci = r["warm"]["iters"], r["cold_contracted"]["iters"]
if wi > ci * 1.05:
    sys.exit(f"warm-started grid needed >5% more iterations ({wi:.0f}) than per-item contracted solves ({ci:.0f})")
# SIMD-kernel gate: the fused, laned, autotuned RustChunk must not lose
# to the scalar oracle (5% noise slack)
kb, ks = r["kernel"]["blocked_s"], r["kernel"]["scalar_s"]
if kb > ks * 1.05:
    sys.exit(f"SIMD PDHG kernel ({kb:.4f} s) lost to the scalar oracle ({ks:.4f} s)")
print(f"lp gate OK: warm {warm:.3f} s <= cold {cold:.3f} s ({r['speedup_warm_vs_cold']:.2f}x; "
      f"fair parallel baseline {r['speedup_warm_vs_cold_parallel']:.2f}x; iters {wi:.0f} <= {ci:.0f}; "
      f"kernel simd/scalar {r['kernel']['speedup']:.2f}x at block widths "
      f"{r['kernel']['block']:.0f}/{r['kernel']['block_t']:.0f})")
PY
    fi
    cat BENCH_lp.json

    echo "== perf gate: obs no-op overhead on the contended service bench (writes BENCH_obs.json) =="
    cargo bench --bench obs_overhead
    if [[ ! -s BENCH_obs.json ]]; then
        echo "BENCH_obs.json missing or empty" >&2
        exit 1
    fi
    if command -v python3 >/dev/null 2>&1; then
        python3 - <<'PY' || exit 1
import json, sys
with open("BENCH_obs.json") as f:
    r = json.load(f)
noop = r["noop"]["tasks_per_sec"]
if noop < 10_000.0:
    sys.exit(f"no-op-sink service throughput {noop:.0f} tasks/s below the 10k floor")
pct = r["recording_overhead_pct"]
if not (-50.0 <= pct <= 100.0):
    sys.exit(f"recording-sink overhead {pct:.1f}% outside the sane [-50, 100]% band")
print(
    f"obs gate OK: noop {noop:.0f} tasks/s, recording {r['recording']['tasks_per_sec']:.0f} "
    f"({pct:+.1f}%, {r['recording']['events_per_decision']:.2f} events/decision)"
)
PY
    fi
    cat BENCH_obs.json
fi

echo "CI OK"
