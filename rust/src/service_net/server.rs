//! The daemon: a WAL-coupled deterministic scheduler core plus a
//! std-only TCP front end.
//!
//! [`Core`] is the part the crash-recovery proofs run against — no
//! sockets, no threads: every mutating op follows the write-ahead
//! discipline *log, fsync, apply, log decisions, fsync* so that after a
//! crash the WAL prefix always covers every acknowledged op.
//! [`Core::open`] replays the log through the same [`ShardedService`]
//! code path that produced it, verifying every recomputed decision
//! (shard assignment included) against the logged one bit for bit (see
//! the [module docs](super)).
//!
//! [`serve`] wraps a `Core` in the network: the accept loop hands each
//! connection to a reader thread, and every parsed [`Request`] is
//! funneled through one mpsc channel into the single scheduler thread
//! that owns the `Core`.  That channel is the serialization point: the
//! op order the scheduler applies (and the WAL records) is the only
//! order there is — concurrent clients race to enqueue, never to
//! mutate.

use std::collections::VecDeque;
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::obs::event::to_jsonl;
use crate::obs::{Event, EventKind, Metrics, MetricsReport};
use crate::platform::Platform;
use crate::sched::service::{
    validate_submission, CancelOutcome, DecisionRecord, ServiceReport, ShardedService, Submission,
};
use crate::sim::Placement;
use crate::substrate::json::Json;

use super::wal::{self, Wal, WalRecord};
use super::wire::{self, Request};

/// Configuration for [`serve`].
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Bind address, e.g. `127.0.0.1:7477`; port 0 picks an ephemeral
    /// port (printed, and written to `port_file` if set).
    pub addr: String,
    pub wal: PathBuf,
    pub plat: Platform,
    /// If set, the actual listening address is written here — how the
    /// ci.sh smoke stage finds an ephemerally-bound daemon.
    pub port_file: Option<PathBuf>,
    /// If set, structured events (decision spans, queue depths, WAL
    /// byte counts) are appended here as JSONL after every op.  The
    /// stream carries virtual time only, so two runs of the same
    /// workload write byte-identical files (ci.sh pins this).
    pub trace_out: Option<PathBuf>,
    /// Scheduler shards (`--shards N`); 1 reproduces the single-loop
    /// daemon bit for bit.  Recorded in the WAL's platform record, so a
    /// log can only be reopened at the shard count that wrote it.
    pub shards: usize,
}

/// What replaying the WAL found (reported once at startup).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplaySummary {
    pub ops: usize,
    pub decisions_logged: usize,
    /// Decisions the dead daemon took but never logged (lost tail),
    /// regenerated deterministically and re-appended on open.
    pub decisions_regenerated: usize,
    pub torn_tail: bool,
}

/// Bucket bounds (seconds) for the edge decision-latency histogram.
const EDGE_LATENCY_BOUNDS: [f64; 7] = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0];
const EDGE_LATENCY_HIST: &str = "edge_decision_latency_s";

/// The deterministic daemon state: a [`ShardedService`] whose every
/// mutation is mirrored in (and recoverable from) a [`Wal`], plus the
/// daemon-edge metrics registry.  Edge metrics (op counts, WAL bytes,
/// wall-clock latency) live here — outside the replay-stable core —
/// so they can read the clock without touching a placement.
pub struct Core {
    plat: Platform,
    svc: ShardedService,
    wal: Wal,
    edge: Metrics,
    /// Bytes appended since the last fsync (feeds the fsync trace event).
    unsynced: u64,
}

impl Core {
    /// [`Core::open_sharded`] with one shard — the single-loop daemon,
    /// bit-identical to the pre-shard core (kept as the call shape the
    /// recovery suite and `explain` drive).
    pub fn open(path: &Path, plat: &Platform) -> Result<(Core, ReplaySummary), String> {
        Core::open_sharded(path, plat, 1)
    }

    /// Open (or create) the WAL at `path` and reconstruct the sharded
    /// service state by replaying it.  A fresh log records the platform
    /// *and* the shard count; an existing log must have been written
    /// for the same platform at the same shard count — shard layout is
    /// part of the decision stream's identity, so a mismatched restart
    /// is refused rather than silently re-sliced.
    pub fn open_sharded(
        path: &Path,
        plat: &Platform,
        shards: usize,
    ) -> Result<(Core, ReplaySummary), String> {
        let scan = wal::recover(path)?;
        let mut wal = Wal::open_append(path, scan.good_len)?;
        let mut svc = ShardedService::new(plat, shards)?;
        let mut summary = ReplaySummary {
            ops: 0,
            decisions_logged: 0,
            decisions_regenerated: 0,
            torn_tail: scan.torn,
        };

        if scan.records.is_empty() {
            let mut core = Core::with_edge(plat.clone(), svc, wal);
            core.wal_append(&WalRecord::Platform {
                counts: plat.counts.clone(),
                shards,
            })?;
            core.wal_sync()?;
            return Ok((core, summary));
        }

        let WalRecord::Platform { counts, shards: logged_shards } = &scan.records[0] else {
            return Err("WAL does not start with a platform record".into());
        };
        if counts != &plat.counts {
            return Err(format!(
                "WAL platform {:?} does not match requested {:?}",
                counts, plat.counts
            ));
        }
        if *logged_shards != shards {
            return Err(format!(
                "WAL was written with {logged_shards} shard(s) but --shards {shards} \
                 was requested: shard layout determines the decision stream, reopen \
                 with --shards {logged_shards}"
            ));
        }

        // Re-execute the ops; every logged decision must match the
        // recomputed stream bit for bit — shard assignment included
        // (replay == rerun, checked).
        let mut pending: VecDeque<(DecisionRecord, Placement, usize)> = VecDeque::new();
        for (n, rec) in scan.records.iter().enumerate().skip(1) {
            match rec {
                WalRecord::Platform { .. } => {
                    return Err(format!("duplicate platform record at index {n}"))
                }
                WalRecord::Submit { sub } => {
                    summary.ops += 1;
                    let before = svc.decisions().len();
                    svc.admit(sub.clone())
                        .map_err(|e| format!("replay: submit at index {n} rejected: {e}"))?;
                    queue_new_decisions(&svc, before, &mut pending);
                }
                WalRecord::Cancel { tenant } => {
                    summary.ops += 1;
                    check_cancel(&svc, *tenant)
                        .map_err(|e| format!("replay: cancel at index {n} rejected: {e}"))?;
                    svc.cancel(*tenant);
                }
                WalRecord::Drain => {
                    summary.ops += 1;
                    let before = svc.decisions().len();
                    svc.run();
                    queue_new_decisions(&svc, before, &mut pending);
                }
                WalRecord::Decision { rec, place, shard } => {
                    summary.decisions_logged += 1;
                    let (exp_rec, exp_place, exp_shard) =
                        pending.pop_front().ok_or_else(|| {
                            format!("replay: decision record at index {n} has no recomputed match")
                        })?;
                    if !decision_eq(rec, place, *shard, &exp_rec, &exp_place, exp_shard) {
                        return Err(format!(
                            "replay: decision mismatch at index {n}: logged \
                             (tenant {}, task {}, time {}, shard {}) vs recomputed \
                             (tenant {}, task {}, time {}, shard {}) — WAL corrupt or \
                             non-deterministic build",
                            rec.tenant, rec.task, rec.time, shard,
                            exp_rec.tenant, exp_rec.task, exp_rec.time, exp_shard
                        ));
                    }
                }
            }
        }
        // Decisions taken before the crash but lost with the tail:
        // regenerate their records (determinism makes them identical to
        // what the dead daemon computed).
        let mut core = Core::with_edge(plat.clone(), svc, wal);
        for (rec, place, shard) in pending {
            summary.decisions_regenerated += 1;
            core.wal_append(&WalRecord::Decision { rec, place, shard })?;
        }
        if summary.decisions_regenerated > 0 {
            core.wal_sync()?;
        }
        Ok((core, summary))
    }

    fn with_edge(plat: Platform, svc: ShardedService, wal: Wal) -> Core {
        let mut edge = Metrics::new();
        edge.register_hist(EDGE_LATENCY_HIST, &EDGE_LATENCY_BOUNDS);
        Core { plat, svc, wal, edge, unsynced: 0 }
    }

    /// Append a record, keeping the edge counters and (when tracing)
    /// the event stream in step with the WAL.
    fn wal_append(&mut self, rec: &WalRecord) -> Result<(), String> {
        let bytes = self.wal.append(rec)? as u64;
        self.edge.inc("wal_appends");
        self.edge.add("wal_bytes", bytes);
        self.unsynced += bytes;
        self.svc.trace_edge(EventKind::Wal { op: "append", bytes });
        Ok(())
    }

    fn wal_sync(&mut self) -> Result<(), String> {
        self.wal.sync()?;
        self.edge.inc("wal_syncs");
        let bytes = std::mem::take(&mut self.unsynced);
        self.svc.trace_edge(EventKind::Wal { op: "fsync", bytes });
        Ok(())
    }

    /// Admit a submission: log + fsync the op, apply it, log + fsync
    /// the decisions it triggered.  Returns the tenant id.
    pub fn submit(&mut self, sub: Submission) -> Result<usize, String> {
        // validate before logging — a rejected submission must leave no
        // trace in the WAL (replay would reject it too and refuse to
        // start)
        let t0 = Instant::now();
        validate_submission(&self.plat, &sub)?;
        self.wal_append(&WalRecord::Submit { sub: sub.clone() })?;
        self.wal_sync()?;
        let before = self.svc.decisions().len();
        let id = self.svc.admit(sub).map_err(|e| format!("admit after validate: {e}"))?;
        self.log_new_decisions(before)?;
        self.note_edge_latency(before, t0);
        Ok(id)
    }

    /// Cancel a tenant at the current virtual time.
    pub fn cancel(&mut self, tenant: usize) -> Result<CancelOutcome, String> {
        check_cancel(&self.svc, tenant)?;
        self.wal_append(&WalRecord::Cancel { tenant })?;
        self.wal_sync()?;
        Ok(self.svc.cancel(tenant))
    }

    /// Drain the stream (deciding every pending head) and build the
    /// report.  The drain is an op like any other: logged before its
    /// decisions so a crash mid-drain replays to the same stream.
    pub fn report(&mut self) -> Result<ServiceReport, String> {
        if self.svc.n_tenants() == 0 {
            return Err("no tenants submitted".into());
        }
        if !self.svc.is_drained() {
            let t0 = Instant::now();
            self.wal_append(&WalRecord::Drain)?;
            self.wal_sync()?;
            let before = self.svc.decisions().len();
            self.svc.run();
            self.log_new_decisions(before)?;
            self.note_edge_latency(before, t0);
        }
        Ok(self.svc.report(None))
    }

    /// Read-only view of one tenant (no state advance, nothing logged).
    pub fn status(&self, tenant: usize) -> Result<Json, String> {
        if tenant >= self.svc.n_tenants() {
            return Err(format!("no tenant {tenant}"));
        }
        let sub = &self.svc.submissions()[tenant];
        Ok(Json::obj(vec![
            ("tenant", Json::Num(tenant as f64)),
            ("app", Json::Str(sub.graph.app.clone())),
            ("n_tasks", Json::Num(sub.graph.n_tasks() as f64)),
            ("n_placed", Json::Num(self.svc.n_placed(tenant) as f64)),
            ("arrival", Json::Num(sub.arrival)),
            (
                "cancelled_at",
                self.svc.cancelled_at(tenant).map_or(Json::Null, Json::Num),
            ),
        ]))
    }

    pub fn decisions(&self) -> &[DecisionRecord] {
        self.svc.decisions()
    }

    pub fn n_tenants(&self) -> usize {
        self.svc.n_tenants()
    }

    fn log_new_decisions(&mut self, before: usize) -> Result<(), String> {
        let mut queue = VecDeque::new();
        queue_new_decisions(&self.svc, before, &mut queue);
        let appended = !queue.is_empty();
        for (rec, place, shard) in queue {
            self.wal_append(&WalRecord::Decision { rec, place, shard })?;
        }
        if appended {
            self.wal_sync()?;
        }
        Ok(())
    }

    /// Split this op's edge wall-time evenly across the decisions it
    /// produced and attribute each share to the decision's tenant.
    /// This is the *only* place daemon timing enters a report, and it
    /// flows into [`crate::sched::service::TenantReport::decision_latency`]
    /// alone — never a placement (pinned by
    /// `service_fairness::latency_metric_never_feeds_placement`).
    fn note_edge_latency(&mut self, before: usize, t0: Instant) {
        let owners: Vec<usize> =
            self.svc.decisions()[before..].iter().map(|d| d.tenant).collect();
        if owners.is_empty() {
            return;
        }
        let per = (t0.elapsed().as_secs_f64() / owners.len() as f64).max(f64::MIN_POSITIVE);
        for tenant in owners {
            self.edge.observe(EDGE_LATENCY_HIST, per);
            self.svc.note_decision_latency(tenant, per);
        }
    }

    /// Count one front-end op in the edge registry (`ops_submit`,
    /// `ops_status`, …).
    pub fn note_op(&mut self, op: &str) {
        self.edge.add(&format!("ops_{op}"), 1);
    }

    /// Merged metrics snapshot: the replay-stable core registry
    /// ([`ShardedService::metrics`]) plus the daemon-edge registry (op counts,
    /// WAL bytes/syncs, edge decision-latency histogram).
    pub fn metrics(&self) -> MetricsReport {
        let mut m = self.svc.metrics();
        m.merge(&self.edge);
        m.report()
    }

    /// Switch on event recording (the `--trace-out` path).
    pub fn enable_trace(&mut self) {
        self.svc.enable_trace();
    }

    /// Drain recorded events (empty when tracing is off); sequence
    /// numbers stay monotone across drains.
    pub fn take_trace(&mut self) -> Vec<Event> {
        self.svc.take_trace()
    }
}

fn queue_new_decisions(
    svc: &ShardedService,
    before: usize,
    out: &mut VecDeque<(DecisionRecord, Placement, usize)>,
) {
    for (i, d) in svc.decisions().iter().enumerate().skip(before) {
        let place = svc
            .placement_of(d.tenant, d.task)
            .expect("fresh decision has a placement");
        out.push_back((*d, place, svc.decision_shard(i)));
    }
}

fn check_cancel(svc: &ShardedService, tenant: usize) -> Result<(), String> {
    if tenant >= svc.n_tenants() {
        return Err(format!("no tenant {tenant}"));
    }
    if svc.cancelled_at(tenant).is_some() {
        return Err(format!("tenant {tenant} already cancelled"));
    }
    Ok(())
}

/// Bitwise decision/placement equality — the replay==rerun invariant
/// is about bits, not epsilons (and `-0.0 == 0.0` must not paper over
/// a sign flip).  The shard id is part of the identity: a decision
/// recomputed on a different shard is a divergence even if the
/// translated placement coincides.
fn decision_eq(
    a: &DecisionRecord,
    ap: &Placement,
    ashard: usize,
    b: &DecisionRecord,
    bp: &Placement,
    bshard: usize,
) -> bool {
    a.tenant == b.tenant
        && a.task == b.task
        && a.time.to_bits() == b.time.to_bits()
        && ashard == bshard
        && ap.ptype == bp.ptype
        && ap.unit == bp.unit
        && ap.start.to_bits() == bp.start.to_bits()
        && ap.finish.to_bits() == bp.finish.to_bits()
}

/// Replay a WAL through a tracing [`ShardedService`] and render why
/// `tenant:task` landed where it did (`hetsched explain`).  The shard
/// count comes from the platform record, so the reconstruction slices
/// the machine exactly as the daemon that wrote the log did.  Replay ==
/// rerun, so the recorded event stream is exactly what a traced
/// original run would have emitted; logged decision records are
/// verification-only and skipped here.
pub fn explain_from_wal(path: &Path, tenant: usize, task: usize) -> Result<String, String> {
    let scan = wal::recover(path)?;
    if scan.records.is_empty() {
        return Err(format!("{}: empty WAL", path.display()));
    }
    let WalRecord::Platform { counts, shards } = &scan.records[0] else {
        return Err("WAL does not start with a platform record".into());
    };
    let plat = Platform::new(counts.clone());
    let mut svc = ShardedService::new(&plat, *shards)?;
    svc.enable_trace();
    for (n, rec) in scan.records.iter().enumerate().skip(1) {
        match rec {
            WalRecord::Platform { .. } => {
                return Err(format!("duplicate platform record at index {n}"))
            }
            WalRecord::Submit { sub } => {
                svc.admit(sub.clone())
                    .map_err(|e| format!("replay: submit at index {n} rejected: {e}"))?;
            }
            WalRecord::Cancel { tenant } => {
                check_cancel(&svc, *tenant)
                    .map_err(|e| format!("replay: cancel at index {n} rejected: {e}"))?;
                svc.cancel(*tenant);
            }
            WalRecord::Drain => svc.run(),
            WalRecord::Decision { .. } => {}
        }
    }
    if tenant >= svc.n_tenants() {
        return Err(format!("no tenant {tenant} in this WAL ({} tenants)", svc.n_tenants()));
    }
    let events = svc.take_trace();
    crate::obs::explain::render(&events, tenant, task)
}

// ---------------------------------------------------------------------------
// TCP front end
// ---------------------------------------------------------------------------

type Reply = mpsc::Sender<Json>;

/// Write `contents` to `path` atomically: write + fsync a `<path>.tmp`
/// sibling, then rename over the target.  A reader (the ci.sh smoke
/// stage polling the port file) sees either the old file or the
/// complete new one — never a torn prefix — and the fsync means the
/// advertised address survives a machine crash as well as a daemon
/// crash.
pub fn write_file_atomic(path: &Path, contents: &str) -> Result<(), String> {
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));
    let mut f = std::fs::File::create(&tmp)
        .map_err(|e| format!("create {}: {e}", tmp.display()))?;
    f.write_all(contents.as_bytes())
        .map_err(|e| format!("write {}: {e}", tmp.display()))?;
    f.sync_all().map_err(|e| format!("fsync {}: {e}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
}

/// Run the daemon until a client sends `shutdown`.  Blocks the calling
/// thread.
pub fn serve(cfg: &DaemonConfig) -> Result<(), String> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| format!("bind {}: {e}", cfg.addr))?;
    let local = listener.local_addr().map_err(|e| e.to_string())?;
    let (mut core, replay) = Core::open_sharded(&cfg.wal, &cfg.plat, cfg.shards)?;
    let trace_file = match &cfg.trace_out {
        None => None,
        Some(p) => {
            // enable *after* replay: the trace covers this process's
            // ops, so two fresh-WAL runs of one workload match bytewise
            core.enable_trace();
            Some(
                std::fs::File::create(p)
                    .map_err(|e| format!("trace out {}: {e}", p.display()))?,
            )
        }
    };
    println!(
        "hetsched serve-service: listening on {local}, {} shard(s), wal {} \
         ({} ops replayed, {} decisions verified{}{})",
        cfg.shards,
        cfg.wal.display(),
        replay.ops,
        replay.decisions_logged,
        if replay.decisions_regenerated > 0 {
            format!(", {} regenerated", replay.decisions_regenerated)
        } else {
            String::new()
        },
        if replay.torn_tail { ", torn tail truncated" } else { "" },
    );
    if let Some(pf) = &cfg.port_file {
        write_file_atomic(pf, &local.to_string())
            .map_err(|e| format!("port file {}: {e}", pf.display()))?;
    }

    let (tx, rx) = mpsc::channel::<(Request, Reply)>();
    let shutdown = Arc::new(AtomicBool::new(false));
    // wall clock at the daemon's edge only: uptime/ops accounting —
    // nothing here flows into a scheduling decision
    let started = Instant::now();
    let sched = std::thread::spawn(move || scheduler_loop(core, rx, trace_file));

    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        let tx = tx.clone();
        let shutdown = Arc::clone(&shutdown);
        std::thread::spawn(move || handle_conn(stream, tx, shutdown, local));
    }
    drop(tx);
    let ops = sched.join().map_err(|_| "scheduler thread panicked".to_string())?;
    println!(
        "hetsched serve-service: shut down after {ops} ops in {:.3}s",
        started.elapsed().as_secs_f64()
    );
    Ok(())
}

/// The single mutation point: owns the [`Core`], applies requests in
/// channel order, answers each through its reply channel.  When a
/// trace file is attached, recorded events are drained to it after
/// every op so a crash loses at most one op's worth of events.
fn scheduler_loop(
    mut core: Core,
    rx: mpsc::Receiver<(Request, Reply)>,
    mut trace_out: Option<std::fs::File>,
) -> usize {
    let mut ops = 0usize;
    while let Ok((req, reply)) = rx.recv() {
        ops += 1;
        core.note_op(match &req {
            Request::Submit(_) => "submit",
            Request::Status { .. } => "status",
            Request::Cancel { .. } => "cancel",
            Request::Report => "report",
            Request::Metrics => "metrics",
            Request::Shutdown => "shutdown",
        });
        let shutting_down = matches!(req, Request::Shutdown);
        let resp = match req {
            Request::Submit(sub) => match core.submit(sub) {
                Ok(tenant) => wire::ok_response(vec![("tenant", Json::Num(tenant as f64))]),
                Err(e) => wire::err_response(&e),
            },
            Request::Status { tenant } => match core.status(tenant) {
                Ok(v) => wire::ok_response(vec![("status", v)]),
                Err(e) => wire::err_response(&e),
            },
            Request::Cancel { tenant } => match core.cancel(tenant) {
                Ok(out) => wire::ok_response(vec![
                    ("at", Json::Num(out.at)),
                    ("dropped_tasks", Json::Num(out.dropped_tasks as f64)),
                    ("released_units", Json::Num(out.released_units as f64)),
                ]),
                Err(e) => wire::err_response(&e),
            },
            Request::Report => match core.report() {
                Ok(r) => wire::ok_response(vec![("report", wire::report_to_json(&r))]),
                Err(e) => wire::err_response(&e),
            },
            Request::Metrics => {
                wire::ok_response(vec![("metrics", core.metrics().to_json())])
            }
            Request::Shutdown => wire::ok_response(vec![]),
        };
        // Trace-write failures must not be silent: a truncated trace
        // would fail the byte-identity pin downstream with no hint why.
        // Report once, then stop tracing — the scheduler itself keeps
        // running (the trace is an observability surface, not state).
        if let Some(f) = &mut trace_out {
            let events = core.take_trace();
            let failed = if events.is_empty() {
                false
            } else {
                f.write_all(to_jsonl(&events).as_bytes())
                    .and_then(|()| f.flush())
                    .map_err(|e| eprintln!("hetsched serve-service: trace write failed: {e}"))
                    .is_err()
            };
            if failed {
                trace_out = None;
            }
        }
        let _ = reply.send(resp);
        if shutting_down {
            break;
        }
    }
    ops
}

/// Per-connection reader: parse frames, forward to the scheduler, relay
/// responses.  A protocol error answers with `ok:false` and closes the
/// connection; the daemon stays up.
fn handle_conn(
    stream: TcpStream,
    tx: mpsc::Sender<(Request, Reply)>,
    shutdown: Arc<AtomicBool>,
    local: std::net::SocketAddr,
) {
    let Ok(mut writer) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    loop {
        let frame = match wire::read_frame(&mut reader) {
            Ok(Some(v)) => v,
            Ok(None) => return, // clean EOF
            Err(e) => {
                let _ = wire::write_frame(&mut writer, &wire::err_response(&e));
                return;
            }
        };
        let req = match wire::request_from_json(&frame) {
            Ok(r) => r,
            Err(e) => {
                let _ = wire::write_frame(&mut writer, &wire::err_response(&e));
                continue;
            }
        };
        let is_shutdown = matches!(req, Request::Shutdown);
        let (rtx, rrx) = mpsc::channel();
        if tx.send((req, rtx)).is_err() {
            let _ = wire::write_frame(&mut writer, &wire::err_response("daemon shutting down"));
            return;
        }
        let resp = rrx
            .recv()
            .unwrap_or_else(|_| wire::err_response("daemon shutting down"));
        let _ = wire::write_frame(&mut writer, &resp);
        if is_shutdown {
            shutdown.store(true, Ordering::SeqCst);
            // poke the accept loop so it observes the flag and exits
            let _ = TcpStream::connect(local);
            return;
        }
    }
}
