//! Wire format: one frame = `<len> <json>\n` where `<len>` is the
//! decimal byte length of the JSON payload.  The writer never emits a
//! raw newline inside a payload (strings are escaped), so a frame is
//! always exactly one line; the length prefix makes truncation
//! detectable (a torn tail fails the length or parse check), which is
//! why the WAL reuses this framing for its records.
//!
//! Also home to the JSON (de)serializers for the protocol's domain
//! values — [`OnlinePolicy`], [`TenantPolicy`], [`Submission`], requests
//! and the canonical [`ServiceReport`] projection — so the TCP layer
//! and the WAL speak one dialect.

use std::io::{BufRead, Write};

use crate::graph::io as gio;
use crate::sched::online::OnlinePolicy;
use crate::sched::service::{ServiceReport, Submission, TenantPolicy};
use crate::substrate::json::{self, Json};

/// Encode one frame, trailing newline included.
pub fn encode_frame(v: &Json) -> String {
    let body = v.to_string();
    format!("{} {body}\n", body.len())
}

/// Decode one frame line (without its trailing newline): check the
/// length prefix against the payload, then parse the payload.
pub fn decode_frame(line: &str) -> Result<Json, String> {
    let (len, body) = line
        .split_once(' ')
        .ok_or_else(|| "frame missing length prefix".to_string())?;
    let len: usize = len
        .parse()
        .map_err(|_| format!("bad frame length prefix '{len}'"))?;
    if body.len() != len {
        return Err(format!(
            "frame length mismatch: prefix {len}, payload {}",
            body.len()
        ));
    }
    json::parse(body)
}

/// Write one frame to a stream.
pub fn write_frame(w: &mut impl Write, v: &Json) -> std::io::Result<()> {
    w.write_all(encode_frame(v).as_bytes())?;
    w.flush()
}

/// Read one frame; `Ok(None)` on clean EOF, `Err` on a torn or
/// malformed frame.
pub fn read_frame(r: &mut impl BufRead) -> Result<Option<Json>, String> {
    let mut line = String::new();
    let n = r.read_line(&mut line).map_err(|e| e.to_string())?;
    if n == 0 {
        return Ok(None);
    }
    let Some(stripped) = line.strip_suffix('\n') else {
        return Err("torn frame (EOF before newline)".into());
    };
    decode_frame(stripped).map(Some)
}

// ---------------------------------------------------------------------------
// Domain value codecs
// ---------------------------------------------------------------------------

pub fn policy_to_json(p: &OnlinePolicy) -> Json {
    match p {
        // the Random seed is a u64; it travels as a string because a
        // JSON number is an f64 (lossy past 2^53)
        OnlinePolicy::Random(seed) => Json::obj(vec![
            ("kind", Json::Str("random".into())),
            ("seed", Json::Str(seed.to_string())),
        ]),
        other => Json::obj(vec![("kind", Json::Str(policy_kind(other).into()))]),
    }
}

fn policy_kind(p: &OnlinePolicy) -> &'static str {
    match p {
        OnlinePolicy::ErLs => "er-ls",
        OnlinePolicy::Eft => "eft",
        OnlinePolicy::Greedy => "greedy",
        OnlinePolicy::Random(_) => "random",
        OnlinePolicy::R1 => "r1",
        OnlinePolicy::R2 => "r2",
        OnlinePolicy::R3 => "r3",
    }
}

pub fn policy_from_json(v: &Json) -> Result<OnlinePolicy, String> {
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("policy: missing kind")?;
    Ok(match kind {
        "er-ls" => OnlinePolicy::ErLs,
        "eft" => OnlinePolicy::Eft,
        "greedy" => OnlinePolicy::Greedy,
        "random" => {
            let seed = v
                .get("seed")
                .and_then(Json::as_str)
                .and_then(|s| s.parse::<u64>().ok())
                .ok_or("policy: random needs a u64 seed")?;
            OnlinePolicy::Random(seed)
        }
        "r1" => OnlinePolicy::R1,
        "r2" => OnlinePolicy::R2,
        "r3" => OnlinePolicy::R3,
        other => return Err(format!("unknown policy '{other}'")),
    })
}

pub fn admission_to_json(a: &TenantPolicy) -> Json {
    match a {
        TenantPolicy::Fifo => Json::obj(vec![("kind", Json::Str("fifo".into()))]),
        TenantPolicy::Quota { cpu_share, gpu_share } => Json::obj(vec![
            ("kind", Json::Str("quota".into())),
            ("cpu_share", Json::Num(*cpu_share)),
            ("gpu_share", Json::Num(*gpu_share)),
        ]),
        TenantPolicy::WeightedStretch { weight } => Json::obj(vec![
            ("kind", Json::Str("stretch".into())),
            ("weight", Json::Num(*weight)),
        ]),
    }
}

pub fn admission_from_json(v: &Json) -> Result<TenantPolicy, String> {
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("admission: missing kind")?;
    let num = |k: &str| -> Result<f64, String> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("admission: missing {k}"))
    };
    Ok(match kind {
        "fifo" => TenantPolicy::Fifo,
        "quota" => TenantPolicy::Quota {
            cpu_share: num("cpu_share")?,
            gpu_share: num("gpu_share")?,
        },
        "stretch" => TenantPolicy::WeightedStretch { weight: num("weight")? },
        other => return Err(format!("unknown admission '{other}'")),
    })
}

/// Serialize a submission losslessly (the graph codec round-trips
/// names/times/arcs exactly; floats use the shortest-round-trip
/// writer).  The arrival order is written only when it differs from the
/// default task-id order.
pub fn submission_to_json(s: &Submission) -> Json {
    let mut pairs = vec![
        ("graph", gio::to_json(&s.graph)),
        ("arrival", Json::Num(s.arrival)),
        ("policy", policy_to_json(&s.policy)),
        ("admission", admission_to_json(&s.admission)),
    ];
    let order = s.order_vec();
    if order.iter().enumerate().any(|(i, &t)| i != t) {
        pairs.push((
            "order",
            Json::Arr(order.iter().map(|&t| Json::Num(t as f64)).collect()),
        ));
    }
    Json::obj(pairs)
}

pub fn submission_from_json(v: &Json) -> Result<Submission, String> {
    let graph = gio::from_json(v.get("graph").ok_or("submission: missing graph")?)?;
    let arrival = v
        .get("arrival")
        .and_then(Json::as_f64)
        .ok_or("submission: missing arrival")?;
    if !(arrival.is_finite() && arrival >= 0.0) {
        return Err(format!("submission: bad arrival {arrival}"));
    }
    let policy = policy_from_json(v.get("policy").ok_or("submission: missing policy")?)?;
    let admission =
        admission_from_json(v.get("admission").ok_or("submission: missing admission")?)?;
    let mut sub = Submission::new(graph, arrival, policy).with_admission(admission);
    if let Some(ord) = v.get("order") {
        let order: Option<Vec<usize>> = ord
            .as_arr()
            .ok_or("submission: order must be an array")?
            .iter()
            .map(Json::as_usize)
            .collect();
        let order = order.ok_or("submission: bad order entry")?;
        if order.len() != sub.graph.n_tasks() {
            return Err("submission: order must cover all tasks".into());
        }
        sub = sub.with_order(order);
    }
    Ok(sub)
}

// ---------------------------------------------------------------------------
// Requests and responses
// ---------------------------------------------------------------------------

/// A client request, one frame each; the server answers each with one
/// response frame (`{"ok":true,...}` or `{"ok":false,"error":...}`).
#[derive(Clone, Debug)]
pub enum Request {
    Submit(Submission),
    Status { tenant: usize },
    Cancel { tenant: usize },
    Report,
    /// Snapshot of the daemon's metrics registry (scheduler counters
    /// merged with the daemon-edge counters/histograms).
    Metrics,
    Shutdown,
}

pub fn request_to_json(r: &Request) -> Json {
    match r {
        Request::Submit(sub) => Json::obj(vec![
            ("op", Json::Str("submit".into())),
            ("sub", submission_to_json(sub)),
        ]),
        Request::Status { tenant } => Json::obj(vec![
            ("op", Json::Str("status".into())),
            ("tenant", Json::Num(*tenant as f64)),
        ]),
        Request::Cancel { tenant } => Json::obj(vec![
            ("op", Json::Str("cancel".into())),
            ("tenant", Json::Num(*tenant as f64)),
        ]),
        Request::Report => Json::obj(vec![("op", Json::Str("report".into()))]),
        Request::Metrics => Json::obj(vec![("op", Json::Str("metrics".into()))]),
        Request::Shutdown => Json::obj(vec![("op", Json::Str("shutdown".into()))]),
    }
}

pub fn request_from_json(v: &Json) -> Result<Request, String> {
    let op = v.get("op").and_then(Json::as_str).ok_or("missing op")?;
    let tenant = || -> Result<usize, String> {
        v.get("tenant")
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("{op}: missing tenant"))
    };
    Ok(match op {
        "submit" => Request::Submit(submission_from_json(
            v.get("sub").ok_or("submit: missing sub")?,
        )?),
        "status" => Request::Status { tenant: tenant()? },
        "cancel" => Request::Cancel { tenant: tenant()? },
        "report" => Request::Report,
        "metrics" => Request::Metrics,
        "shutdown" => Request::Shutdown,
        other => return Err(format!("unknown op '{other}'")),
    })
}

pub fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("ok", Json::Bool(true)));
    Json::obj(fields)
}

pub fn err_response(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.into())),
    ])
}

/// Canonical (deterministic) JSON projection of a [`ServiceReport`]:
/// every virtual-time metric, placement and decision, plus the
/// replay-stable observability summary (`rule_counts`,
/// `restricted_decisions` — pure functions of the op stream), but *not*
/// the wall-clock decision-latency summaries — those are measurement
/// noise and would break the byte-for-byte replay==rerun comparison the
/// WAL recovery guarantee is pinned on.
pub fn report_to_json(r: &ServiceReport) -> Json {
    let tenants: Vec<Json> = r
        .tenants
        .iter()
        .map(|t| {
            Json::obj(vec![
                ("tenant", Json::Num(t.tenant as f64)),
                ("app", Json::Str(t.app.clone())),
                ("n_tasks", Json::Num(t.n_tasks as f64)),
                ("n_placed", Json::Num(t.n_placed as f64)),
                ("arrival", Json::Num(t.arrival)),
                ("completion", Json::Num(t.completion)),
                ("flow_time", Json::Num(t.flow_time)),
                ("ideal_makespan", Json::Num(t.ideal_makespan)),
                ("stretch", Json::Num(t.stretch)),
                (
                    "cancelled_at",
                    t.cancelled_at.map_or(Json::Null, Json::Num),
                ),
                (
                    "kept_tasks",
                    Json::Arr(t.kept_tasks.iter().map(|&j| Json::Num(j as f64)).collect()),
                ),
                (
                    "placements",
                    Json::Arr(
                        t.schedule
                            .placements
                            .iter()
                            .map(|p| {
                                Json::Arr(vec![
                                    Json::Num(p.ptype as f64),
                                    Json::Num(p.unit as f64),
                                    Json::Num(p.start),
                                    Json::Num(p.finish),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    let decisions: Vec<Json> = r
        .decisions
        .iter()
        .map(|d| {
            Json::Arr(vec![
                Json::Num(d.tenant as f64),
                Json::Num(d.task as f64),
                Json::Num(d.time),
            ])
        })
        .collect();
    Json::obj(vec![
        ("tenants", Json::Arr(tenants)),
        ("decisions", Json::Arr(decisions)),
        ("horizon", Json::Num(r.horizon)),
        ("total_tasks", Json::Num(r.total_tasks as f64)),
        ("mean_stretch", Json::Num(r.mean_stretch)),
        ("max_stretch", Json::Num(r.max_stretch)),
        ("stretch_p99", Json::Num(r.stretch_p99)),
        ("jain_index", Json::Num(r.jain_index)),
        (
            "utilization",
            Json::Arr(r.utilization.iter().map(|&u| Json::Num(u)).collect()),
        ),
        (
            "rule_counts",
            Json::Arr(
                r.rule_counts
                    .iter()
                    .map(|(rule, n)| {
                        Json::Arr(vec![Json::Str(rule.clone()), Json::Num(*n as f64)])
                    })
                    .collect(),
            ),
        ),
        ("restricted_decisions", Json::Num(r.restricted_decisions as f64)),
    ])
}

/// Exact inverse of [`report_to_json`]'s observability summary: the
/// `(rule, count)` pairs in serialized (tag-sorted) order plus the
/// restricted-decision count.  Used by clients and the round-trip pins.
pub fn report_obs_from_json(v: &Json) -> Result<(Vec<(String, u64)>, u64), String> {
    let rules = v
        .get("rule_counts")
        .and_then(Json::as_arr)
        .ok_or("report: missing rule_counts")?
        .iter()
        .map(|pair| {
            let arr = pair.as_arr().ok_or("report: rule_counts entry not a pair")?;
            match arr {
                [Json::Str(rule), n] => Ok((
                    rule.clone(),
                    n.as_usize().ok_or("report: bad rule count")? as u64,
                )),
                _ => Err("report: rule_counts entry not [tag, count]".to_string()),
            }
        })
        .collect::<Result<Vec<_>, String>>()?;
    let restricted = v
        .get("restricted_decisions")
        .and_then(Json::as_usize)
        .ok_or("report: missing restricted_decisions")? as u64;
    Ok((rules, restricted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;

    fn sample_sub() -> Submission {
        let mut b = Builder::new("wire");
        let a = b.add_task("A", vec![1.5, 0.5]);
        let c = b.add_task("B", vec![2.0, 4.0]);
        b.add_arc(a, c);
        Submission::new(b.build(), 3.25, OnlinePolicy::Random(u64::MAX))
            .with_admission(TenantPolicy::Quota { cpu_share: 0.25, gpu_share: 1.0 })
    }

    #[test]
    fn frame_roundtrip() {
        let v = Json::obj(vec![("x", Json::Str("a\nb".into()))]);
        let f = encode_frame(&v);
        assert!(f.ends_with('\n'));
        // escaped newline: the frame is still a single line
        assert_eq!(f.matches('\n').count(), 1);
        assert_eq!(decode_frame(f.strip_suffix('\n').unwrap()).unwrap(), v);
    }

    #[test]
    fn frame_rejects_torn_and_tampered() {
        let f = encode_frame(&Json::obj(vec![("k", Json::Num(1.0))]));
        let line = f.strip_suffix('\n').unwrap();
        // cut anywhere inside the payload: length check must fail
        for cut in 0..line.len() {
            assert!(decode_frame(&line[..cut]).is_err(), "cut at {cut}");
        }
        assert!(decode_frame("notalen {}").is_err());
    }

    #[test]
    fn read_frame_reports_missing_newline_as_torn() {
        let f = encode_frame(&Json::Null);
        let torn = &f[..f.len() - 1];
        let mut r = std::io::BufReader::new(torn.as_bytes());
        assert!(read_frame(&mut r).is_err());
        let mut r = std::io::BufReader::new(f.as_bytes());
        assert_eq!(read_frame(&mut r).unwrap(), Some(Json::Null));
        assert_eq!(read_frame(&mut r).unwrap(), None);
    }

    #[test]
    fn submission_roundtrip_is_lossless() {
        let sub = sample_sub();
        let v = json::parse(&submission_to_json(&sub).to_string()).unwrap();
        let back = submission_from_json(&v).unwrap();
        assert_eq!(back.graph.proc_times, sub.graph.proc_times);
        assert_eq!(back.graph.succs, sub.graph.succs);
        assert_eq!(back.arrival.to_bits(), sub.arrival.to_bits());
        assert_eq!(back.policy, OnlinePolicy::Random(u64::MAX));
        assert_eq!(back.admission, sub.admission);
        // a non-default order travels too (two independent tasks,
        // reversed arrival order)
        let mut b = Builder::new("pair");
        b.add_task("A", vec![1.0, 1.0]);
        b.add_task("B", vec![2.0, 2.0]);
        let sub = Submission::new(b.build(), 0.0, OnlinePolicy::Eft).with_order(vec![1, 0]);
        let back = submission_from_json(
            &json::parse(&submission_to_json(&sub).to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.order_vec(), vec![1, 0]);
    }

    #[test]
    fn request_roundtrip() {
        for req in [
            Request::Submit(sample_sub()),
            Request::Status { tenant: 3 },
            Request::Cancel { tenant: 0 },
            Request::Report,
            Request::Metrics,
            Request::Shutdown,
        ] {
            let v = json::parse(&request_to_json(&req).to_string()).unwrap();
            let back = request_from_json(&v).unwrap();
            // compare through the codec (Request has no PartialEq: the
            // Submission graph does not derive it)
            assert_eq!(
                request_to_json(&back).to_string(),
                request_to_json(&req).to_string()
            );
        }
        assert!(request_from_json(&Json::obj(vec![("op", Json::Str("x".into()))])).is_err());
        // a negative tenant index must not saturate into tenant 0
        let v = Json::obj(vec![
            ("op", Json::Str("cancel".into())),
            ("tenant", Json::Num(-1.0)),
        ]);
        assert!(request_from_json(&v).is_err());
    }

    #[test]
    fn error_envelope_roundtrips_through_frames() {
        // the structured error envelope must survive the wire exactly:
        // ok flag false, message byte-identical (including escapes)
        for msg in ["no tenant 7", "weird \"quoted\" message\nwith newline"] {
            let env = err_response(msg);
            let line = encode_frame(&env);
            assert_eq!(line.matches('\n').count(), 1, "envelope stays one frame");
            let back = decode_frame(line.strip_suffix('\n').unwrap()).unwrap();
            assert_eq!(back.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(back.get("error").and_then(Json::as_str), Some(msg));
            assert_eq!(back, env);
        }
        // and the ok envelope keeps its leading flag plus payload fields
        let okv = ok_response(vec![("tenant", Json::Num(2.0))]);
        let back = decode_frame(encode_frame(&okv).strip_suffix('\n').unwrap()).unwrap();
        assert_eq!(back.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(back.get("tenant").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn report_obs_fields_roundtrip_exactly() {
        use crate::platform::Platform;
        use crate::sched::service::run_service;
        let mut b = Builder::new("obs");
        let a = b.add_task("A", vec![1.0, 2.0]);
        let c = b.add_task("B", vec![2.0, 1.0]);
        b.add_arc(a, c);
        let g = b.build();
        let plat = Platform::hybrid(2, 1);
        let subs = vec![
            Submission::new(g.clone(), 0.0, OnlinePolicy::Eft),
            Submission::new(g, 0.5, OnlinePolicy::Greedy)
                .with_admission(TenantPolicy::Quota { cpu_share: 0.5, gpu_share: 1.0 }),
        ];
        let report = run_service(&plat, &subs);
        assert!(!report.rule_counts.is_empty(), "every decision is attributed");
        let total: u64 = report.rule_counts.iter().map(|(_, n)| n).sum();
        assert_eq!(total as usize, report.decisions.len());

        let v = report_to_json(&report);
        // serialize -> parse -> re-serialize must be byte-identical
        let text = v.to_string();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(parsed.to_string(), text);
        // and the obs summary decodes back exactly
        let (rules, restricted) = report_obs_from_json(&parsed).unwrap();
        assert_eq!(rules, report.rule_counts);
        assert_eq!(restricted, report.restricted_decisions);
        // latency summaries never enter the wire projection
        assert!(v.get("decision_latency").is_none());
    }
}
