//! Thin blocking client for the daemon protocol — one request frame
//! out, one response frame back, over a persistent TCP connection.
//! Used by the `hetsched submit|status|cancel|report|shutdown`
//! subcommands and by the integration tests.
//!
//! Every socket operation carries a deadline: a wedged daemon (accepted
//! the connection, never answers) surfaces as a structured timeout
//! error after [`DEFAULT_TIMEOUT_S`] seconds instead of hanging the CLI
//! forever.  `--timeout-s 0` disables the deadline for debugging.

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Duration;

use crate::sched::service::Submission;
use crate::substrate::json::Json;

use super::wire::{self, Request};

/// Default per-operation socket deadline (connect/read/write), seconds.
pub const DEFAULT_TIMEOUT_S: u64 = 10;

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// `None` = blocking forever (explicitly requested via timeout 0).
    timeout: Option<Duration>,
}

impl Client {
    /// Connect with the default deadline.
    pub fn connect(addr: &str) -> Result<Client, String> {
        Client::connect_with_timeout(addr, DEFAULT_TIMEOUT_S)
    }

    /// Connect with a per-operation deadline of `timeout_s` seconds
    /// (0 = no deadline).  The same deadline covers the connect itself
    /// and every subsequent read/write on the stream.
    pub fn connect_with_timeout(addr: &str, timeout_s: u64) -> Result<Client, String> {
        let timeout = (timeout_s > 0).then(|| Duration::from_secs(timeout_s));
        let stream = match timeout {
            Some(d) => {
                use std::net::ToSocketAddrs;
                let sock = addr
                    .to_socket_addrs()
                    .map_err(|e| format!("resolve {addr}: {e}"))?
                    .next()
                    .ok_or_else(|| format!("resolve {addr}: no addresses"))?;
                TcpStream::connect_timeout(&sock, d)
                    .map_err(|e| format!("connect {addr}: {e}"))?
            }
            None => TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?,
        };
        stream
            .set_read_timeout(timeout)
            .map_err(|e| format!("set read timeout: {e}"))?;
        stream
            .set_write_timeout(timeout)
            .map_err(|e| format!("set write timeout: {e}"))?;
        let writer = stream.try_clone().map_err(|e| e.to_string())?;
        Ok(Client { reader: BufReader::new(stream), writer, timeout })
    }

    /// Mark would-block/timed-out socket errors so they read as a
    /// deadline expiry, not a protocol failure.
    fn deadline_context(&self, msg: String) -> String {
        let timed_out = msg.contains("TimedOut")
            || msg.contains("WouldBlock")
            || msg.contains("timed out")
            || msg.contains("temporarily unavailable");
        match (timed_out, self.timeout) {
            (true, Some(d)) => format!(
                "timeout: no response from the daemon within {}s (--timeout-s to adjust): {msg}",
                d.as_secs()
            ),
            _ => msg,
        }
    }

    /// Send one request, await its response.  `ok:false` responses
    /// become `Err` with the daemon's error text; the `Ok` value is the
    /// full response object (fields beyond `ok` depend on the op).
    pub fn call(&mut self, req: &Request) -> Result<Json, String> {
        wire::write_frame(&mut self.writer, &wire::request_to_json(req))
            .map_err(|e| self.deadline_context(format!("send: {e}")))?;
        let resp = wire::read_frame(&mut self.reader)
            .map_err(|e| self.deadline_context(e))?
            .ok_or_else(|| "daemon closed the connection".to_string())?;
        match resp.get("ok") {
            Some(Json::Bool(true)) => Ok(resp),
            Some(Json::Bool(false)) => Err(match resp.get("error") {
                Some(Json::Str(m)) => m.clone(),
                _ => "daemon error (no message)".to_string(),
            }),
            _ => Err("malformed response (missing ok field)".to_string()),
        }
    }

    /// Submit a DAG; returns the tenant id the daemon assigned.
    pub fn submit(&mut self, sub: &Submission) -> Result<usize, String> {
        let resp = self.call(&Request::Submit(sub.clone()))?;
        resp.get("tenant")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| "response missing tenant id".to_string())
    }

    /// Read-only snapshot of one tenant.
    pub fn status(&mut self, tenant: usize) -> Result<Json, String> {
        let resp = self.call(&Request::Status { tenant })?;
        resp.get("status")
            .cloned()
            .ok_or_else(|| "response missing status".to_string())
    }

    /// Cancel a tenant; returns the daemon's cancel-outcome object
    /// (`at`, `dropped_tasks`, `released_units`).
    pub fn cancel(&mut self, tenant: usize) -> Result<Json, String> {
        self.call(&Request::Cancel { tenant })
    }

    /// Drain the stream and fetch the canonical report JSON.
    pub fn report(&mut self) -> Result<Json, String> {
        let resp = self.call(&Request::Report)?;
        resp.get("report")
            .cloned()
            .ok_or_else(|| "response missing report".to_string())
    }

    /// Fetch the merged metrics snapshot (core + daemon-edge registry)
    /// as the serialized [`MetricsReport`](crate::obs::MetricsReport)
    /// object.  Read-only: nothing is logged, no state advances.
    pub fn metrics(&mut self) -> Result<Json, String> {
        let resp = self.call(&Request::Metrics)?;
        resp.get("metrics")
            .cloned()
            .ok_or_else(|| "response missing metrics".to_string())
    }

    /// Ask the daemon to exit (acknowledged before it goes down).
    pub fn shutdown(&mut self) -> Result<(), String> {
        self.call(&Request::Shutdown).map(|_| ())
    }
}
