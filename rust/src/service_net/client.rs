//! Thin blocking client for the daemon protocol — one request frame
//! out, one response frame back, over a persistent TCP connection.
//! Used by the `hetsched submit|status|cancel|report|shutdown`
//! subcommands and by the integration tests.

use std::io::BufReader;
use std::net::TcpStream;

use crate::sched::service::Submission;
use crate::substrate::json::Json;

use super::wire::{self, Request};

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, String> {
        let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let writer = stream.try_clone().map_err(|e| e.to_string())?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request, await its response.  `ok:false` responses
    /// become `Err` with the daemon's error text; the `Ok` value is the
    /// full response object (fields beyond `ok` depend on the op).
    pub fn call(&mut self, req: &Request) -> Result<Json, String> {
        wire::write_frame(&mut self.writer, &wire::request_to_json(req))
            .map_err(|e| format!("send: {e}"))?;
        let resp = wire::read_frame(&mut self.reader)?
            .ok_or_else(|| "daemon closed the connection".to_string())?;
        match resp.get("ok") {
            Some(Json::Bool(true)) => Ok(resp),
            Some(Json::Bool(false)) => Err(match resp.get("error") {
                Some(Json::Str(m)) => m.clone(),
                _ => "daemon error (no message)".to_string(),
            }),
            _ => Err("malformed response (missing ok field)".to_string()),
        }
    }

    /// Submit a DAG; returns the tenant id the daemon assigned.
    pub fn submit(&mut self, sub: &Submission) -> Result<usize, String> {
        let resp = self.call(&Request::Submit(sub.clone()))?;
        resp.get("tenant")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| "response missing tenant id".to_string())
    }

    /// Read-only snapshot of one tenant.
    pub fn status(&mut self, tenant: usize) -> Result<Json, String> {
        let resp = self.call(&Request::Status { tenant })?;
        resp.get("status")
            .cloned()
            .ok_or_else(|| "response missing status".to_string())
    }

    /// Cancel a tenant; returns the daemon's cancel-outcome object
    /// (`at`, `dropped_tasks`, `released_units`).
    pub fn cancel(&mut self, tenant: usize) -> Result<Json, String> {
        self.call(&Request::Cancel { tenant })
    }

    /// Drain the stream and fetch the canonical report JSON.
    pub fn report(&mut self) -> Result<Json, String> {
        let resp = self.call(&Request::Report)?;
        resp.get("report")
            .cloned()
            .ok_or_else(|| "response missing report".to_string())
    }

    /// Fetch the merged metrics snapshot (core + daemon-edge registry)
    /// as the serialized [`MetricsReport`](crate::obs::MetricsReport)
    /// object.  Read-only: nothing is logged, no state advances.
    pub fn metrics(&mut self) -> Result<Json, String> {
        let resp = self.call(&Request::Metrics)?;
        resp.get("metrics")
            .cloned()
            .ok_or_else(|| "response missing metrics".to_string())
    }

    /// Ask the daemon to exit (acknowledged before it goes down).
    pub fn shutdown(&mut self) -> Result<(), String> {
        self.call(&Request::Shutdown).map(|_| ())
    }
}
