//! Append-only write-ahead log for the service daemon.
//!
//! One [`wire`] frame per record, five record kinds:
//!
//! * `platform` — first record of every log: the unit pool the stream
//!   was scheduled on (replay must rebuild the identical pool).
//! * `submit` / `cancel` / `drain` — the ops, in the authoritative
//!   order the scheduler thread applied them.  Each op is appended and
//!   fsync'd *before* its effects are acknowledged.
//! * `decision` — every [`DecisionRecord`] (plus its placement) the op
//!   generated, appended after the op record that caused it.
//!
//! Because decisions are deterministic functions of the op sequence,
//! the `decision` records are redundant — and that redundancy is the
//! point: replay re-executes the ops and *checks* each recomputed
//! decision against the log ([`super::server::Core::open`]), turning
//! "replay == rerun" from an assumption into a startup invariant.
//!
//! Crash anatomy: appends are sequential, so a crash leaves the file as
//! (complete records)* + (at most one torn tail).  [`recover`]
//! truncates the torn tail — a half-written record belongs to an op
//! that was never acknowledged — while a malformed record *before* the
//! tail is real corruption and refuses to load.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::sched::service::{DecisionRecord, Submission};
use crate::sim::Placement;
use crate::substrate::json::Json;

use super::wire;

/// One WAL record (see module docs).
///
/// Sharding rides the same record grammar (one log, no second format):
/// the `platform` record carries the shard count the log was written
/// under (absent in pre-shard logs, which replay as 1), and every
/// `decision` record carries the id of the shard that took it (absent
/// → 0), so replay can recompute and bitwise-verify the per-shard
/// decision streams exactly as it does for the single loop.
#[derive(Clone, Debug)]
pub enum WalRecord {
    Platform { counts: Vec<usize>, shards: usize },
    Submit { sub: Submission },
    Cancel { tenant: usize },
    Drain,
    Decision { rec: DecisionRecord, place: Placement, shard: usize },
}

pub fn record_to_json(r: &WalRecord) -> Json {
    match r {
        WalRecord::Platform { counts, shards } => Json::obj(vec![
            ("k", Json::Str("platform".into())),
            (
                "counts",
                Json::Arr(counts.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            ("shards", Json::Num(*shards as f64)),
        ]),
        WalRecord::Submit { sub } => Json::obj(vec![
            ("k", Json::Str("submit".into())),
            ("sub", wire::submission_to_json(sub)),
        ]),
        WalRecord::Cancel { tenant } => Json::obj(vec![
            ("k", Json::Str("cancel".into())),
            ("tenant", Json::Num(*tenant as f64)),
        ]),
        WalRecord::Drain => Json::obj(vec![("k", Json::Str("drain".into()))]),
        WalRecord::Decision { rec, place, shard } => Json::obj(vec![
            ("k", Json::Str("decision".into())),
            ("tenant", Json::Num(rec.tenant as f64)),
            ("task", Json::Num(rec.task as f64)),
            ("time", Json::Num(rec.time)),
            ("ptype", Json::Num(place.ptype as f64)),
            ("unit", Json::Num(place.unit as f64)),
            ("start", Json::Num(place.start)),
            ("finish", Json::Num(place.finish)),
            ("shard", Json::Num(*shard as f64)),
        ]),
    }
}

pub fn record_from_json(v: &Json) -> Result<WalRecord, String> {
    let kind = v.get("k").and_then(Json::as_str).ok_or("record: missing k")?;
    let idx = |k: &str| -> Result<usize, String> {
        v.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("{kind} record: bad {k}"))
    };
    let num = |k: &str| -> Result<f64, String> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("{kind} record: bad {k}"))
    };
    // optional shard fields: pre-shard logs carry neither key and
    // replay as a single-shard (shard-0) stream
    let opt_idx = |k: &str, default: usize| -> Result<usize, String> {
        match v.get(k) {
            None => Ok(default),
            Some(j) => j.as_usize().ok_or_else(|| format!("{kind} record: bad {k}")),
        }
    };
    Ok(match kind {
        "platform" => {
            let counts: Option<Vec<usize>> = v
                .get("counts")
                .and_then(Json::as_arr)
                .ok_or("platform record: missing counts")?
                .iter()
                .map(Json::as_usize)
                .collect();
            WalRecord::Platform {
                counts: counts.ok_or("platform record: bad count")?,
                shards: opt_idx("shards", 1)?,
            }
        }
        "submit" => WalRecord::Submit {
            sub: wire::submission_from_json(v.get("sub").ok_or("submit record: missing sub")?)?,
        },
        "cancel" => WalRecord::Cancel { tenant: idx("tenant")? },
        "drain" => WalRecord::Drain,
        "decision" => WalRecord::Decision {
            rec: DecisionRecord {
                tenant: idx("tenant")?,
                task: idx("task")?,
                time: num("time")?,
            },
            place: Placement {
                ptype: idx("ptype")?,
                unit: idx("unit")?,
                start: num("start")?,
                finish: num("finish")?,
            },
            shard: opt_idx("shard", 0)?,
        },
        other => return Err(format!("unknown record kind '{other}'")),
    })
}

/// Outcome of scanning a WAL file.
#[derive(Debug)]
pub struct Recovery {
    pub records: Vec<WalRecord>,
    /// Byte length of the longest complete-record prefix; anything
    /// beyond it is a torn tail to truncate.
    pub good_len: u64,
    /// Whether a torn tail was present (and dropped).
    pub torn: bool,
}

/// Scan a WAL file, decoding every complete record and locating the
/// truncation point.  A missing file recovers to the empty log.  A
/// malformed record that is *not* the final one is corruption (`Err`);
/// a malformed or newline-less final line is a torn tail.
pub fn recover(path: &Path) -> Result<Recovery, String> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(Recovery { records: Vec::new(), good_len: 0, torn: false })
        }
        Err(e) => return Err(format!("{}: {e}", path.display())),
    };
    // scan raw bytes: offsets must index the file itself, and a crash
    // can tear a multibyte character (lossy str conversion would shift
    // every offset after it)
    let mut records = Vec::new();
    let mut good_len = 0u64;
    let mut pos = 0usize;
    while pos < bytes.len() {
        let Some(rel) = bytes[pos..].iter().position(|&b| b == b'\n') else {
            // trailing bytes with no newline: torn tail
            return Ok(Recovery { records, good_len, torn: true });
        };
        let decoded = std::str::from_utf8(&bytes[pos..pos + rel])
            .map_err(|e| e.to_string())
            .and_then(wire::decode_frame)
            .and_then(|v| record_from_json(&v));
        match decoded {
            Ok(r) => {
                records.push(r);
                pos += rel + 1;
                good_len = pos as u64;
            }
            // a malformed final line is a torn tail; earlier ones are
            // corruption (sequential appends cannot produce them)
            Err(_) if pos + rel + 1 >= bytes.len() => {
                return Ok(Recovery { records, good_len, torn: true });
            }
            Err(e) => {
                return Err(format!(
                    "corrupt WAL record at byte {pos} (not the final record): {e}"
                ))
            }
        }
    }
    Ok(Recovery { records, good_len, torn: false })
}

/// Append handle over a WAL file.  [`Self::append`] buffers through the
/// OS write; [`Self::sync`] is the durability point (`fdatasync`) —
/// the server syncs once per op, after the op record and all its
/// decision records.
pub struct Wal {
    file: File,
    path: PathBuf,
}

impl Wal {
    /// Open for appending, truncating any torn tail found by a prior
    /// [`recover`] scan.
    pub fn open_append(path: &Path, good_len: u64) -> Result<Wal, String> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .append(false)
            .truncate(false)
            .open(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        file.set_len(good_len)
            .map_err(|e| format!("{}: truncate: {e}", path.display()))?;
        let mut file = file;
        use std::io::Seek;
        file.seek(std::io::SeekFrom::End(0))
            .map_err(|e| format!("{}: seek: {e}", path.display()))?;
        Ok(Wal { file, path: path.to_path_buf() })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record; returns the number of bytes written (frame
    /// length), which feeds the daemon's `wal_bytes` counter and `wal`
    /// trace events.
    pub fn append(&mut self, rec: &WalRecord) -> Result<usize, String> {
        let frame = wire::encode_frame(&record_to_json(rec));
        self.file
            .write_all(frame.as_bytes())
            .map_err(|e| format!("{}: append: {e}", self.path.display()))?;
        Ok(frame.len())
    }

    /// Make everything appended so far durable before acknowledging.
    pub fn sync(&mut self) -> Result<(), String> {
        self.file
            .sync_data()
            .map_err(|e| format!("{}: fsync: {e}", self.path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;
    use crate::sched::online::OnlinePolicy;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("hetsched_wal_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_records() -> Vec<WalRecord> {
        let mut b = Builder::new("w");
        b.add_task("t", vec![1.0, 2.0]);
        vec![
            WalRecord::Platform { counts: vec![2, 1], shards: 1 },
            WalRecord::Submit {
                sub: Submission::new(b.build(), 0.5, OnlinePolicy::Eft),
            },
            WalRecord::Decision {
                rec: DecisionRecord { tenant: 0, task: 0, time: 0.5 },
                place: Placement { ptype: 0, unit: 1, start: 0.5, finish: 1.5 },
                shard: 0,
            },
            WalRecord::Cancel { tenant: 0 },
            WalRecord::Drain,
        ]
    }

    fn encode_all(recs: &[WalRecord]) -> String {
        recs.iter()
            .map(|r| wire::encode_frame(&record_to_json(r)))
            .collect()
    }

    #[test]
    fn records_roundtrip_through_frames() {
        for r in sample_records() {
            let line = wire::encode_frame(&record_to_json(&r));
            let v = wire::decode_frame(line.strip_suffix('\n').unwrap()).unwrap();
            let back = record_from_json(&v).unwrap();
            assert_eq!(
                record_to_json(&back).to_string(),
                record_to_json(&r).to_string()
            );
        }
    }

    #[test]
    fn preshard_records_parse_with_default_shard_fields() {
        // logs written before sharding carry no `shards`/`shard` keys:
        // they must replay as a single-shard, shard-0 stream
        let plat = Json::obj(vec![
            ("k", Json::Str("platform".into())),
            ("counts", Json::Arr(vec![Json::Num(2.0), Json::Num(1.0)])),
        ]);
        match record_from_json(&plat).unwrap() {
            WalRecord::Platform { counts, shards } => {
                assert_eq!(counts, vec![2, 1]);
                assert_eq!(shards, 1);
            }
            other => panic!("not a platform record: {other:?}"),
        }
        let dec = Json::obj(vec![
            ("k", Json::Str("decision".into())),
            ("tenant", Json::Num(0.0)),
            ("task", Json::Num(0.0)),
            ("time", Json::Num(0.5)),
            ("ptype", Json::Num(0.0)),
            ("unit", Json::Num(1.0)),
            ("start", Json::Num(0.5)),
            ("finish", Json::Num(1.5)),
        ]);
        match record_from_json(&dec).unwrap() {
            WalRecord::Decision { shard, rec, .. } => {
                assert_eq!(shard, 0);
                assert_eq!((rec.tenant, rec.task), (0, 0));
            }
            other => panic!("not a decision record: {other:?}"),
        }
        // a bad shard value is still a parse error, not a silent default
        let bad = Json::obj(vec![
            ("k", Json::Str("platform".into())),
            ("counts", Json::Arr(vec![Json::Num(2.0), Json::Num(1.0)])),
            ("shards", Json::Num(-3.0)),
        ]);
        assert!(record_from_json(&bad).is_err());
    }

    #[test]
    fn recover_scans_complete_logs() {
        let path = tmp("complete.wal");
        let text = encode_all(&sample_records());
        std::fs::write(&path, &text).unwrap();
        let rec = recover(&path).unwrap();
        assert_eq!(rec.records.len(), 5);
        assert!(!rec.torn);
        assert_eq!(rec.good_len, text.len() as u64);
    }

    #[test]
    fn recover_truncates_torn_tail_at_every_cut() {
        let recs = sample_records();
        let text = encode_all(&recs);
        // boundaries of complete records, as byte offsets
        let mut bounds = vec![0usize];
        for (i, b) in text.bytes().enumerate() {
            if b == b'\n' {
                bounds.push(i + 1);
            }
        }
        let path = tmp("torn.wal");
        for cut in 0..=text.len() {
            std::fs::write(&path, &text.as_bytes()[..cut]).unwrap();
            let rec = recover(&path).unwrap_or_else(|e| panic!("cut {cut}: {e}"));
            // the recovered prefix is the last record boundary <= cut
            let n_complete = bounds.iter().filter(|&&b| b <= cut && b > 0).count();
            assert_eq!(rec.records.len(), n_complete, "cut {cut}");
            assert_eq!(rec.good_len, bounds[n_complete] as u64, "cut {cut}");
            assert_eq!(rec.torn, cut != bounds[n_complete], "cut {cut}");
        }
    }

    #[test]
    fn recover_rejects_mid_log_corruption() {
        let path = tmp("corrupt.wal");
        let recs = sample_records();
        let mut text = encode_all(&recs[..2]);
        text.push_str("garbage line\n");
        text.push_str(&encode_all(&recs[2..3]));
        std::fs::write(&path, &text).unwrap();
        assert!(recover(&path).unwrap_err().contains("corrupt WAL record"));
    }

    #[test]
    fn recover_missing_file_is_empty_log() {
        let rec = recover(&tmp("never_written.wal")).unwrap();
        assert!(rec.records.is_empty());
        assert_eq!(rec.good_len, 0);
    }

    #[test]
    fn open_append_truncates_and_extends() {
        let path = tmp("append.wal");
        let recs = sample_records();
        let mut text = encode_all(&recs[..2]);
        text.push_str("12 {\"k\":\"drai"); // torn tail
        std::fs::write(&path, &text).unwrap();
        let scan = recover(&path).unwrap();
        assert!(scan.torn);
        let mut wal = Wal::open_append(&path, scan.good_len).unwrap();
        wal.append(&recs[3]).unwrap();
        wal.sync().unwrap();
        let again = recover(&path).unwrap();
        assert_eq!(again.records.len(), 3);
        assert!(!again.torn);
        assert!(matches!(again.records[2], WalRecord::Cancel { tenant: 0 }));
    }
}
