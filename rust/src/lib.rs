//! # hetsched
//!
//! Reproduction of *“Generic algorithms for scheduling applications on
//! heterogeneous multi-core platforms”* (Amaris, Lucarelli, Mommessin,
//! Trystram — CS.DC 2017) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the scheduling framework: task graphs,
//!   workload generators, the HLP/QHLP allocation phase, the offline
//!   schedulers (HLP-EST, HLP-OLS, HEFT, QHLP-\*), the online engine
//!   (ER-LS, EFT, Greedy, Random), a discrete-event simulator, a live
//!   coordinator runtime, and the full experiment campaign of §6.
//! * **Layer 2/1 (python/compile, build-time only)** — the HLP/QHLP LP
//!   relaxation solved by a restarted PDHG whose fused updates are Pallas
//!   kernels; AOT-lowered to HLO text and executed from
//!   [`runtime`] via PJRT.  Python never runs on the scheduling path.
//!
//! See DESIGN.md for the module inventory and EXPERIMENTS.md for the
//! reproduced tables/figures.

// The determinism story (golden parity, replay == rerun) is only as
// strong as memory safety and visibility hygiene; tools/hetlint adds
// the repo-specific rules on top of these crate-wide lints.
#![forbid(unsafe_code)]
#![deny(unreachable_pub)]

pub mod analysis;
pub mod experiments;
pub mod graph;
pub mod algos;
pub mod alloc;
pub mod lp;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod service_net;
pub mod sim;
pub mod coordinator;
pub mod platform;
pub mod substrate;
pub mod workloads;
