//! Minimal JSON: value model, writer, and a recursive-descent parser.
//!
//! In-tree because the build is offline (no `serde`).  Used for the
//! artifacts manifest (read), experiment result files (write) and the
//! LP* cache (read+write).  Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not needed by any producer here).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as an index: `Some` only for a non-negative integer
    /// representable in `usize`.  A saturating `as usize` cast here
    /// would turn a corrupt field (`-3`, `1e300`, `NaN`) into a
    /// plausible index like `0` — every caller (manifest, LP* cache,
    /// graph wire decode, WAL replay) wants a hard `None` instead.
    pub fn as_usize(&self) -> Option<usize> {
        match self.as_f64() {
            // fract() == 0.0 is false for NaN and ±inf (their fract is
            // NaN); 2^64 = usize::MAX as f64 exactly, and every float
            // strictly below it casts losslessly into range
            Some(x) if x.fract() == 0.0 && x >= 0.0 && x < usize::MAX as f64 => {
                Some(x as usize)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // JSON has no NaN/Infinity tokens; writing Rust's
                // Display forms would poison the file for any parser
                // (including ours).  Policy: non-finite numbers
                // serialize as `null` — lossy by design, and the only
                // choice that keeps every written document valid JSON.
                let neg_zero = *x == 0.0 && x.is_sign_negative();
                if !x.is_finite() {
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 && !neg_zero {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    // covers fractional values and -0.0 (whose `as i64`
                    // cast would drop the sign: it prints as "-0");
                    // Rust's shortest-round-trip Display re-parses to
                    // the same bits
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns Err(position, message) on failure.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    let mut end = start + 1;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end]).map_err(|e| e.to_string())?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("c", Json::Str("hi \"quoted\"\n".into())),
        ]);
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "format": "hlo-text",
          "pad_b": 1e9,
          "buckets": [
            {"name": "b0", "n": 4096, "r": 8192, "nz": 32768, "iters": 250}
          ]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text");
        assert_eq!(v.get("pad_b").unwrap().as_f64().unwrap(), 1e9);
        let b = &v.get("buckets").unwrap().as_arr().unwrap()[0];
        assert_eq!(b.get("n").unwrap().as_usize().unwrap(), 4096);
    }

    #[test]
    fn numbers_ints_and_floats() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(parse("42").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn non_finite_numbers_write_as_null() {
        // invalid-JSON regression: the old writer emitted Display forms
        // ("NaN", "inf", "-inf") that no parser accepts
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(x).to_string();
            assert_eq!(s, "null", "{x} must serialize as null");
            assert_eq!(parse(&s).unwrap(), Json::Null);
        }
        // and embedded in a document the whole write stays parseable
        let doc = Json::obj(vec![
            ("stretch", Json::Num(f64::NAN)),
            ("ideal", Json::Num(f64::INFINITY)),
            ("ok", Json::Num(2.5)),
        ]);
        let back = parse(&doc.to_string()).unwrap();
        assert_eq!(back.get("stretch").unwrap(), &Json::Null);
        assert_eq!(back.get("ideal").unwrap(), &Json::Null);
        assert_eq!(back.get("ok").unwrap(), &Json::Num(2.5));
    }

    #[test]
    fn finite_numbers_roundtrip_bitwise() {
        // -0.0 used to take the `as i64` branch and come back as +0.0
        for x in [
            -0.0,
            0.0,
            1.0,
            -17.0,
            0.1,
            -1e-300,
            3.141592653589793,
            1e15,
            -1e15,
            9.007199254740991e15,
            f64::MIN_POSITIVE,
            f64::MAX,
        ] {
            let s = Json::Num(x).to_string();
            let back = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(
                back.to_bits(),
                x.to_bits(),
                "{x} wrote as {s} but re-parsed as {back}"
            );
        }
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
    }

    #[test]
    fn as_usize_rejects_non_indices() {
        // saturating-cast regression: -3.0 used to come back as Some(0)
        for bad in [-3.0, -0.5, 0.5, 1e300, f64::NAN, f64::INFINITY, -1e300] {
            assert_eq!(Json::Num(bad).as_usize(), None, "{bad} is not an index");
        }
        assert_eq!(Json::Str("7".into()).as_usize(), None);
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(4096.0).as_usize(), Some(4096));
        assert_eq!(
            Json::Num(9.007199254740991e15).as_usize(),
            Some(9007199254740991)
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Ab""#).unwrap().as_str().unwrap(), "Ab");
    }

    #[test]
    fn nested_structures() {
        let s = r#"[[1,[2,[3]]],{"x":{"y":[null]}}]"#;
        let v = parse(s).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }
}
