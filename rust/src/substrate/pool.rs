//! Scoped thread-pool helpers (std::thread; no tokio available offline).
//!
//! Two tools:
//!  * [`parallel_map`] — run a job per item on `workers` threads, preserving
//!    input order; drives the experiment campaign.
//!  * [`WorkQueue`] — an MPMC channel built from Mutex+Condvar, used by the
//!    live coordinator's worker pool.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Run `f` over `items` on up to `workers` OS threads; results keep input
/// order. Panics in workers propagate.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let items: Vec<Mutex<Option<T>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = items[i].lock().unwrap().take().unwrap();
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect()
}

/// A simple MPMC FIFO queue with blocking pop and close semantics.
pub struct WorkQueue<T> {
    inner: Mutex<QueueInner<T>>,
    cond: Condvar,
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> WorkQueue<T> {
    pub fn new() -> Arc<Self> {
        Arc::new(WorkQueue {
            inner: Mutex::new(QueueInner {
                items: VecDeque::new(),
                closed: false,
            }),
            cond: Condvar::new(),
        })
    }

    /// Push an item; returns false if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        g.items.push_back(item);
        self.cond.notify_one();
        true
    }

    /// Blocking pop; None once closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.items.pop_front() {
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.cond.wait(g).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().items.pop_front()
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(items, 8, |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_worker() {
        assert_eq!(parallel_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn work_queue_fifo_and_close() {
        let q = WorkQueue::new();
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert!(!q.push(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn work_queue_cross_thread() {
        let q = WorkQueue::new();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(x) = q2.pop() {
                got.push(x);
            }
            got
        });
        for i in 0..50 {
            q.push(i);
        }
        q.close();
        let got = h.join().unwrap();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }
}
