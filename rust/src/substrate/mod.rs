//! In-tree substrates for the offline build (no crates.io beyond the
//! `xla` tree): deterministic RNG, JSON, CLI parsing, thread pool,
//! micro-bench harness, property-testing, summary statistics.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
