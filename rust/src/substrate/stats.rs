//! Summary statistics + tiny table/CSV rendering for the experiment
//! campaign (the paper reports means, standard errors and outlier
//! structure across instances — Figs. 3–7).

/// One-pass summary of a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub stderr: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub geo_mean: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let std = var.sqrt();
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let geo = if xs.iter().all(|&x| x > 0.0) {
            (xs.iter().map(|x| x.ln()).sum::<f64>() / n as f64).exp()
        } else {
            f64::NAN
        };
        Summary {
            n,
            mean,
            std,
            stderr: std / (n as f64).sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            geo_mean: geo,
        }
    }
}

/// Percentile by linear interpolation on a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Render rows as a fixed-width text table (markdown-pipe style).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
        out.push('|');
        for (i, c) in cells.iter().enumerate() {
            out.push(' ');
            out.push_str(c);
            for _ in c.len()..widths[i] {
                out.push(' ');
            }
            out.push_str(" |");
        }
        out.push('\n');
    };
    fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
        &mut out,
    );
    out.push('|');
    for w in &widths {
        out.push_str(&"-".repeat(w + 2));
        out.push('|');
    }
    out.push('\n');
    for row in rows {
        fmt_row(row, &widths, &mut out);
    }
    out
}

/// Render rows as CSV (quotes cells containing separators).
pub fn render_csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let esc = |s: &str| {
        if s.contains(',') || s.contains('"') || s.contains('\n') {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    };
    let mut out = String::new();
    out.push_str(&headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 4.0).abs() < 1e-12);
        assert!((s.p50 - 2.5).abs() < 1e-12);
        let expected_std = (((1.5f64 * 1.5 + 0.5 * 0.5) * 2.0) / 3.0).sqrt();
        assert!((s.std - expected_std).abs() < 1e-12);
    }

    #[test]
    fn summary_geo_mean() {
        let s = Summary::of(&[1.0, 4.0]);
        assert!((s.geo_mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[7.0]);
        assert_eq!(s.n, 1);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn table_render_aligns() {
        let t = render_table(
            &["app", "ratio"],
            &[vec!["potrf".into(), "1.23".into()], vec!["fj".into(), "2".into()]],
        );
        assert!(t.contains("| app   | ratio |"));
        assert!(t.lines().count() == 4);
    }

    #[test]
    fn csv_escapes() {
        let c = render_csv(&["a"], &[vec!["x,y".into()]]);
        assert!(c.contains("\"x,y\""));
    }
}
