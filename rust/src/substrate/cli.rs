//! Tiny CLI argument parser (no `clap` offline): subcommand + `--flag
//! value` / `--switch` conventions, with typed getters and a usage dump.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first bare word = subcommand, `--k v` = flag,
    /// `--k` followed by another `--` or end = switch.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.switches.push(name.to_string());
                    i += 1;
                }
            } else {
                if out.command.is_none() {
                    out.command = Some(a.clone());
                } else {
                    out.positional.push(a.clone());
                }
                i += 1;
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())
    }

    pub fn str_flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn string(&self, name: &str, default: &str) -> String {
        self.str_flag(name).unwrap_or(default).to_string()
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.str_flag(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.str_flag(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.str_flag(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Strict form of [`Self::usize`]: a present-but-unparseable value
    /// (`--m abc`) or a value-less occurrence (`--m --full`, which the
    /// parser demotes to a switch) is an `Err` naming the flag, instead
    /// of silently running with the default.  Absent flag = default.
    pub fn try_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.try_parse(name, default)
    }

    /// Strict form of [`Self::f64`]; see [`Self::try_usize`].
    pub fn try_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        self.try_parse(name, default)
    }

    /// Strict form of [`Self::u64`]; see [`Self::try_usize`].
    pub fn try_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        self.try_parse(name, default)
    }

    fn try_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        if let Some(s) = self.str_flag(name) {
            s.parse()
                .map_err(|_| format!("--{name}: cannot parse '{s}'"))
        } else if self.has(name) {
            // `--name` with no value was parsed as a switch; a typed
            // getter asking for it means the value went missing (e.g.
            // `--weight --full` ate the weight)
            Err(format!("--{name} requires a value"))
        } else {
            Ok(default)
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Comma-separated list flag, e.g. `--apps potrf,getrf`.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.str_flag(name)
            .map(|s| {
                s.split(',')
                    .map(|x| x.trim().to_string())
                    .filter(|x| !x.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(&words.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_flags_switches() {
        let a = parse(&["experiment", "--fig", "3", "--full", "--out", "results"]);
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.string("fig", ""), "3");
        assert!(a.has("full"));
        assert_eq!(a.string("out", ""), "results");
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["x", "--m", "64", "--tol", "0.5"]);
        assert_eq!(a.usize("m", 1), 64);
        assert_eq!(a.f64("tol", 1.0), 0.5);
        assert_eq!(a.usize("missing", 7), 7);
    }

    #[test]
    fn try_getters_reject_unparseable_values() {
        // regression: `--weight abc` used to silently run with the
        // default weight
        let a = parse(&["service", "--weight", "abc", "--m", "16"]);
        let err = a.try_f64("weight", 1.0).unwrap_err();
        assert!(err.contains("--weight") && err.contains("abc"), "{err}");
        assert_eq!(a.try_usize("m", 1), Ok(16));
        assert!(a.try_usize("m-bad", 1).is_ok(), "absent flag keeps default");
        assert!(parse(&["x", "--seed", "-1"]).try_u64("seed", 0).is_err());
    }

    #[test]
    fn try_getters_reject_switch_demoted_flags() {
        // regression: `--weight --full` used to demote --weight to a
        // switch and silently drop the admission weight
        let a = parse(&["service", "--weight", "--full"]);
        let err = a.try_f64("weight", 1.0).unwrap_err();
        assert!(err.contains("--weight requires a value"), "{err}");
        assert!(a.has("full"));
        // a genuine switch queried as a switch is untouched
        assert!(a.has("weight"));
    }

    #[test]
    fn list_flag() {
        let a = parse(&["x", "--apps", "potrf, getrf,,posv"]);
        assert_eq!(a.list("apps"), vec!["potrf", "getrf", "posv"]);
        assert!(a.list("none").is_empty());
    }

    #[test]
    fn positional_args() {
        let a = parse(&["gen", "file1", "file2"]);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }
}
