// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Micro-benchmark harness (criterion stand-in for the offline build).
//!
//! Every `rust/benches/*.rs` target is `harness = false` and drives this:
//! warmup, timed iterations until a minimum measuring window, then a
//! report line with mean / p50 / p95 and optional throughput.

use std::time::{Duration, Instant};

use crate::substrate::stats;

pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1500),
            min_iters: 5,
            max_iters: 100_000,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
        )
    }

    /// items/second at the mean time, for `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

/// Time `f` under `opts`; the closure must do one full unit of work.
pub fn bench_with<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < opts.warmup {
        f();
    }
    // Measure.
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < opts.measure && samples.len() < opts.max_iters)
        || samples.len() < opts.min_iters
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: Duration::from_secs_f64(mean),
        p50: Duration::from_secs_f64(stats::percentile(&samples, 0.50)),
        p95: Duration::from_secs_f64(stats::percentile(&samples, 0.95)),
    }
}

/// Convenience wrapper with default options; prints the report line.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench_with(name, &BenchOpts::default(), f);
    println!("{}", r.report());
    r
}

/// Keep a value from being optimized away (ptr read volatile fence).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 10_000,
        };
        let mut acc = 0u64;
        let r = bench_with("spin", &opts, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.iters >= 3);
        assert!(r.mean > Duration::ZERO);
        assert!(r.p95 >= r.p50);
        black_box(acc);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with(" us"));
    }
}
