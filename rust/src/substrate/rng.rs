//! Deterministic RNG: SplitMix64 seeding + xoshiro256++ core, with the
//! uniform / Gaussian / log-normal draws the workload generators need.
//!
//! In-tree because the build is offline (no `rand` crate); determinism is
//! load-bearing for the experiment campaign — every instance is seeded by
//! a stable hash of its parameters, so reruns are bit-identical.

/// xoshiro256++ PRNG (public-domain reference algorithm by Blackman/Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a labelled sub-purpose.
    pub fn fork(&mut self, label: &str) -> Rng {
        let mut h = fnv1a(label.as_bytes());
        h ^= self.next_u64();
        Rng::new(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine here: the
        // modulo bias on 64 bits is far below anything observable.
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300); // avoid log(0)
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Positive Gaussian draw, truncated below at `floor`.
    pub fn gaussian_pos(&mut self, mean: f64, std: f64, floor: f64) -> f64 {
        for _ in 0..64 {
            let v = self.gaussian(mean, std);
            if v > floor {
                return v;
            }
        }
        floor.max(mean)
    }

    /// Log-normal multiplicative jitter with median 1 and sigma (of log).
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        self.gaussian(0.0, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// FNV-1a 64-bit hash; used to derive stable per-instance seeds from
/// parameter strings (app name, nb_blocks, machine config...).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Stable seed for a named experiment instance.
pub fn seed_for(parts: &[&str]) -> u64 {
    let joined = parts.join("\u{1f}");
    fnv1a(joined.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.uniform(2.0, 5.0);
            assert!((2.0..5.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
        assert!((var - 4.0).abs() < 0.3, "var {var}");
    }

    #[test]
    fn gaussian_pos_respects_floor() {
        let mut r = Rng::new(13);
        for _ in 0..200 {
            assert!(r.gaussian_pos(0.1, 5.0, 0.01) >= 0.01);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn seed_for_is_stable_and_sensitive() {
        assert_eq!(seed_for(&["a", "b"]), seed_for(&["a", "b"]));
        assert_ne!(seed_for(&["a", "b"]), seed_for(&["ab"]));
        assert_ne!(seed_for(&["a", "b"]), seed_for(&["b", "a"]));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut f1 = base.fork("one");
        let mut f2 = base.fork("two");
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert!(same < 2);
    }
}
