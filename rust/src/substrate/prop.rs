//! Mini property-testing framework (proptest stand-in for the offline
//! build): run a property over `cases` seeded inputs; on failure, report
//! the failing seed so the case can be replayed deterministically.
//!
//! Generators are plain closures over [`Rng`]; composite generators for
//! DAGs / platforms live next to their types (e.g. `graph::gen`).

use crate::substrate::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Override case count via HETSCHED_PROP_CASES for deeper soak runs.
        let cases = std::env::var("HETSCHED_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        PropConfig {
            cases,
            base_seed: 0x5EED_0001,
        }
    }
}

/// Run `prop(rng, case_index)`; panic with the seed on the first failure.
/// The property signals failure by returning `Err(message)`.
pub fn for_all<F>(name: &str, cfg: &PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay: Rng::new({seed:#x})"
            );
        }
    }
}

/// Shorthand with the default config.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for_all(name, &PropConfig::default(), prop)
}

/// assert_le with a readable error for property bodies.
pub fn ensure_le(lhs: f64, rhs: f64, what: &str) -> Result<(), String> {
    if lhs <= rhs + 1e-9 {
        Ok(())
    } else {
        Err(format!("{what}: {lhs} > {rhs}"))
    }
}

pub fn ensure(cond: bool, what: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        for_all(
            "trivial",
            &PropConfig {
                cases: 10,
                base_seed: 1,
            },
            |rng, _| {
                count += 1;
                ensure(rng.f64() < 1.0, "uniform below 1")
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn failing_property_panics_with_seed() {
        for_all(
            "failing",
            &PropConfig {
                cases: 5,
                base_seed: 2,
            },
            |_, case| ensure(case < 3, "case too big"),
        );
    }

    #[test]
    fn helpers() {
        assert!(ensure_le(1.0, 2.0, "le").is_ok());
        assert!(ensure_le(2.0, 1.0, "le").is_err());
        assert!(ensure_close(1.0, 1.0 + 1e-12, 1e-9, "close").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-9, "close").is_err());
    }
}
