//! Path metrics on task graphs: bottom-level ranks (the OLS priority of
//! §4.1 and the HEFT priority of §3), critical paths, and the standard
//! combinatorial lower bounds (Graham).

use super::{TaskGraph, TaskId};

/// Bottom-level rank for arbitrary per-task lengths:
/// `rank(j) = len(j) + max_{s in succ(j)} rank(s)`
/// i.e. the longest path from j to its last descendant, inclusive.
pub fn bottom_level(g: &TaskGraph, len: &dyn Fn(TaskId) -> f64) -> Vec<f64> {
    let order = g.topo_order().expect("acyclic");
    let mut rank = vec![0.0f64; g.n_tasks()];
    for &j in order.iter().rev() {
        let tail = g.succs[j]
            .iter()
            .map(|&s| rank[s])
            .fold(0.0f64, f64::max);
        rank[j] = len(j) + tail;
    }
    rank
}

/// Top-level: longest path strictly *before* j (earliest possible start
/// if infinitely many units).
pub fn top_level(g: &TaskGraph, len: &dyn Fn(TaskId) -> f64) -> Vec<f64> {
    let order = g.topo_order().expect("acyclic");
    let mut tl = vec![0.0f64; g.n_tasks()];
    for &j in order.iter() {
        let t = tl[j] + len(j);
        for &s in &g.succs[j] {
            if t > tl[s] {
                tl[s] = t;
            }
        }
    }
    tl
}

/// Length of the critical path under `len`.
pub fn critical_path(g: &TaskGraph, len: &dyn Fn(TaskId) -> f64) -> f64 {
    bottom_level(g, len).iter().copied().fold(0.0, f64::max)
}

/// OLS rank (§4.1): lengths follow the HLP *allocation* (`alloc[j]` is the
/// processor type of task j).
pub fn ols_rank(g: &TaskGraph, alloc: &[usize]) -> Vec<f64> {
    bottom_level(g, &|j| g.time_on(j, alloc[j]))
}

/// HEFT rank (§3): lengths are unit-count-weighted average times,
/// `(Σ_q m_q · p_{j,q}) / Σ_q m_q` — which reduces to the paper's
/// `(m·p̄_j + k·p̠_j)/(m+k)` for 2 types.
pub fn heft_rank(g: &TaskGraph, type_counts: &[usize]) -> Vec<f64> {
    let total: usize = type_counts.iter().sum();
    bottom_level(g, &|j| {
        type_counts
            .iter()
            .enumerate()
            .map(|(q, &mq)| mq as f64 * g.time_on(j, q))
            .sum::<f64>()
            / total as f64
    })
}

/// Valid combinatorial lower bound on OPT: max of the best-case critical
/// path (every task at its fastest type) and the best-case total work
/// spread over all units.
pub fn lower_bound(g: &TaskGraph, type_counts: &[usize]) -> f64 {
    let min_len = |j: TaskId| {
        g.proc_times[j]
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
    };
    let cp = critical_path(g, &min_len);
    let units: usize = type_counts.iter().sum();
    let work: f64 = (0..g.n_tasks()).map(min_len).sum();
    cp.max(work / units as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;

    fn chain3() -> TaskGraph {
        let mut b = Builder::new("chain");
        let t0 = b.add_task("a", vec![2.0, 1.0]);
        let t1 = b.add_task("b", vec![3.0, 1.0]);
        let t2 = b.add_task("c", vec![4.0, 1.0]);
        b.add_arc(t0, t1);
        b.add_arc(t1, t2);
        b.build()
    }

    #[test]
    fn bottom_level_on_chain() {
        let g = chain3();
        let r = bottom_level(&g, &|j| g.p_cpu(j));
        assert_eq!(r, vec![9.0, 7.0, 4.0]);
        assert_eq!(critical_path(&g, &|j| g.p_cpu(j)), 9.0);
    }

    #[test]
    fn top_level_on_chain() {
        let g = chain3();
        let t = top_level(&g, &|j| g.p_cpu(j));
        assert_eq!(t, vec![0.0, 2.0, 5.0]);
    }

    #[test]
    fn ranks_decrease_along_arcs() {
        let g = chain3();
        let r = ols_rank(&g, &[0, 1, 0]);
        for j in 0..g.n_tasks() {
            for &s in &g.succs[j] {
                assert!(r[j] > r[s]);
            }
        }
    }

    #[test]
    fn heft_rank_weighted_average() {
        let g = chain3();
        // m=3 CPUs, k=1 GPU: len(a) = (3*2+1*1)/4 = 1.75
        let r = heft_rank(&g, &[3, 1]);
        let len_c = (3.0 * 4.0 + 1.0) / 4.0;
        assert!((r[2] - len_c).abs() < 1e-12);
    }

    #[test]
    fn lower_bound_sane() {
        let g = chain3();
        // fastest chain = 3 (all GPU); work/units = 3/3 = 1
        let lb = lower_bound(&g, &[2, 1]);
        assert!((lb - 3.0).abs() < 1e-12);
    }
}
