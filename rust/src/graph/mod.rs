//! Precedence task graphs: the application model of the paper.
//!
//! A [`TaskGraph`] is a DAG whose nodes are sequential tasks and whose
//! arcs are precedence relations; every task carries one processing time
//! per *processor type* (`p̄_j` on CPU, `p̠_j` on GPU for the hybrid
//! 2-type case; a vector of `Q` times in the general case of Section 5).

pub mod gen;
pub mod io;
pub mod paths;

pub type TaskId = usize;

/// Processor-type indices for the hybrid case.
pub const CPU: usize = 0;
pub const GPU: usize = 1;

#[derive(Clone, Debug)]
pub struct TaskGraph {
    /// Human-readable application name ("potrf", "fork-join", ...).
    pub app: String,
    /// Kernel name per task ("GEMM", "TRSM", ...).
    pub names: Vec<String>,
    /// `proc_times[j][q]` = processing time of task j on a type-q unit.
    pub proc_times: Vec<Vec<f64>>,
    pub preds: Vec<Vec<TaskId>>,
    pub succs: Vec<Vec<TaskId>>,
}

impl TaskGraph {
    pub fn n_tasks(&self) -> usize {
        self.names.len()
    }

    pub fn n_types(&self) -> usize {
        self.proc_times.first().map(|t| t.len()).unwrap_or(0)
    }

    pub fn n_arcs(&self) -> usize {
        self.succs.iter().map(|s| s.len()).sum()
    }

    /// `p̄_j` (CPU time) in the hybrid case.
    pub fn p_cpu(&self, j: TaskId) -> f64 {
        self.proc_times[j][CPU]
    }

    /// `p̠_j` (GPU time) in the hybrid case.
    pub fn p_gpu(&self, j: TaskId) -> f64 {
        self.proc_times[j][GPU]
    }

    pub fn time_on(&self, j: TaskId, q: usize) -> f64 {
        self.proc_times[j][q]
    }

    pub fn sources(&self) -> Vec<TaskId> {
        (0..self.n_tasks()).filter(|&j| self.preds[j].is_empty()).collect()
    }

    pub fn sinks(&self) -> Vec<TaskId> {
        (0..self.n_tasks()).filter(|&j| self.succs[j].is_empty()).collect()
    }

    /// Kahn topological order; `None` if the graph has a cycle.
    pub fn topo_order(&self) -> Option<Vec<TaskId>> {
        let n = self.n_tasks();
        let mut indeg: Vec<usize> = self.preds.iter().map(|p| p.len()).collect();
        let mut queue: std::collections::VecDeque<TaskId> =
            (0..n).filter(|&j| indeg[j] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(j) = queue.pop_front() {
            order.push(j);
            for &s in &self.succs[j] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// Structural sanity: consistent arrays, mirrored arcs, acyclic,
    /// strictly positive processing times, uniform type count.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_tasks();
        if self.proc_times.len() != n || self.preds.len() != n || self.succs.len() != n {
            return Err("inconsistent array lengths".into());
        }
        let q = self.n_types();
        if q == 0 {
            return Err("no processor types".into());
        }
        for j in 0..n {
            if self.proc_times[j].len() != q {
                return Err(format!("task {j}: wrong number of type times"));
            }
            for (t, &p) in self.proc_times[j].iter().enumerate() {
                if !(p > 0.0) || !p.is_finite() {
                    return Err(format!("task {j}: nonpositive time on type {t}"));
                }
                if p >= crate::sched::engine::MAX_TIME_UNITS {
                    return Err(format!(
                        "task {j}: time {p} on type {t} exceeds the 2^31 time-unit tick headroom"
                    ));
                }
            }
            for &s in &self.succs[j] {
                if s >= n {
                    return Err(format!("task {j}: successor {s} out of range"));
                }
                if !self.preds[s].contains(&j) {
                    return Err(format!("arc ({j},{s}) not mirrored in preds"));
                }
            }
            for &p in &self.preds[j] {
                if !self.succs[p].contains(&j) {
                    return Err(format!("arc ({p},{j}) not mirrored in succs"));
                }
            }
        }
        if self.topo_order().is_none() {
            return Err("graph has a cycle".into());
        }
        Ok(())
    }

    /// Count tasks per kernel name (Table 4 checks).
    pub fn kernel_histogram(&self) -> std::collections::BTreeMap<String, usize> {
        let mut h = std::collections::BTreeMap::new();
        for name in &self.names {
            *h.entry(name.clone()).or_insert(0) += 1;
        }
        h
    }
}

/// Incremental builder; arcs are deduplicated.
#[derive(Clone, Debug, Default)]
pub struct Builder {
    app: String,
    names: Vec<String>,
    proc_times: Vec<Vec<f64>>,
    preds: Vec<Vec<TaskId>>,
    succs: Vec<Vec<TaskId>>,
}

impl Builder {
    pub fn new(app: &str) -> Builder {
        Builder {
            app: app.to_string(),
            ..Default::default()
        }
    }

    pub fn add_task(&mut self, name: &str, times: Vec<f64>) -> TaskId {
        let id = self.names.len();
        self.names.push(name.to_string());
        self.proc_times.push(times);
        self.preds.push(Vec::new());
        self.succs.push(Vec::new());
        id
    }

    /// Add arc `i -> j` (i must precede j). Self-loops rejected.
    pub fn add_arc(&mut self, i: TaskId, j: TaskId) {
        assert_ne!(i, j, "self-loop {i}");
        if !self.succs[i].contains(&j) {
            self.succs[i].push(j);
            self.preds[j].push(i);
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.names.len()
    }

    pub fn build(self) -> TaskGraph {
        match self.try_build() {
            Ok(g) => g,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible build: the checked entry point for untrusted graphs
    /// (daemon decode, CLI input).  Rejects NaN / non-positive /
    /// infinite costs unconditionally (not just in debug) — a single
    /// NaN time would otherwise poison every downstream float
    /// comparison silently — and rejects any finite cost at or beyond
    /// the 2^31 time-unit tick headroom
    /// ([`crate::sched::engine::MAX_TIME_UNITS`]): a huge finite cost
    /// would saturate `Tick::quantize` and collapse every comparison
    /// against it, so it is an input error, not a clamp.
    pub fn try_build(self) -> Result<TaskGraph, String> {
        for (j, times) in self.proc_times.iter().enumerate() {
            if times.is_empty() {
                return Err(format!("task {j} ({}): no processing times", self.names[j]));
            }
            for (q, &p) in times.iter().enumerate() {
                if !(p.is_finite() && p > 0.0) {
                    return Err(format!(
                        "task {j} ({}): processing time {p} on type {q} must be finite and > 0",
                        self.names[j]
                    ));
                }
                if p >= crate::sched::engine::MAX_TIME_UNITS {
                    return Err(format!(
                        "task {j} ({}): processing time {p} on type {q} exceeds the \
                         2^31 time-unit tick headroom",
                        self.names[j]
                    ));
                }
            }
        }
        let g = TaskGraph {
            app: self.app,
            names: self.names,
            proc_times: self.proc_times,
            preds: self.preds,
            succs: self.succs,
        };
        debug_assert!(g.validate().is_ok(), "{:?}", g.validate());
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> TaskGraph {
        let mut b = Builder::new("diamond");
        let t0 = b.add_task("a", vec![4.0, 1.0]);
        let t1 = b.add_task("b", vec![2.0, 5.0]);
        let t2 = b.add_task("c", vec![6.0, 1.0]);
        let t3 = b.add_task("d", vec![4.0, 1.0]);
        b.add_arc(t0, t1);
        b.add_arc(t0, t2);
        b.add_arc(t1, t3);
        b.add_arc(t2, t3);
        b.build()
    }

    #[test]
    fn builder_roundtrip() {
        let g = diamond();
        assert_eq!(g.n_tasks(), 4);
        assert_eq!(g.n_arcs(), 4);
        assert_eq!(g.n_types(), 2);
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn topo_order_respects_arcs() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (i, &t) in order.iter().enumerate() {
                p[t] = i;
            }
            p
        };
        for j in 0..g.n_tasks() {
            for &s in &g.succs[j] {
                assert!(pos[j] < pos[s]);
            }
        }
    }

    #[test]
    fn duplicate_arcs_are_deduped() {
        let mut b = Builder::new("x");
        let a = b.add_task("a", vec![1.0, 1.0]);
        let c = b.add_task("b", vec![1.0, 1.0]);
        b.add_arc(a, c);
        b.add_arc(a, c);
        let g = b.build();
        assert_eq!(g.n_arcs(), 1);
    }

    #[test]
    fn cycle_detected() {
        // bypass builder's debug assert by constructing directly
        let g = TaskGraph {
            app: "cyc".into(),
            names: vec!["a".into(), "b".into()],
            proc_times: vec![vec![1.0], vec![1.0]],
            preds: vec![vec![1], vec![0]],
            succs: vec![vec![1], vec![0]],
        };
        assert!(g.topo_order().is_none());
        assert!(g.validate().is_err());
    }

    #[test]
    fn bad_times_rejected() {
        let g = TaskGraph {
            app: "bad".into(),
            names: vec!["a".into()],
            proc_times: vec![vec![0.0]],
            preds: vec![vec![]],
            succs: vec![vec![]],
        };
        assert!(g.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "finite and > 0")]
    fn builder_rejects_nan_cost() {
        let mut b = Builder::new("nan");
        b.add_task("a", vec![1.0, f64::NAN]);
        let _ = b.build();
    }

    #[test]
    #[should_panic(expected = "finite and > 0")]
    fn builder_rejects_negative_cost() {
        let mut b = Builder::new("neg");
        b.add_task("a", vec![-1.0, 2.0]);
        let _ = b.build();
    }

    #[test]
    #[should_panic(expected = "finite and > 0")]
    fn builder_rejects_infinite_cost() {
        let mut b = Builder::new("inf");
        b.add_task("a", vec![f64::INFINITY, 2.0]);
        let _ = b.build();
    }

    #[test]
    fn builder_rejects_beyond_headroom_cost() {
        // regression: 1e308 is finite, so the finite-and-positive check
        // passes, but it saturates Tick::quantize to u64::MAX and every
        // comparison against it collapses — must be an Err, not a clamp
        let mut b = Builder::new("huge");
        b.add_task("a", vec![1e308, 2.0]);
        let err = b.try_build().unwrap_err();
        assert!(err.contains("2^31 time-unit tick headroom"), "{err}");
    }

    #[test]
    #[should_panic(expected = "tick headroom")]
    fn build_panics_beyond_headroom() {
        let mut b = Builder::new("huge");
        b.add_task("a", vec![crate::sched::engine::MAX_TIME_UNITS, 2.0]);
        let _ = b.build();
    }

    #[test]
    fn headroom_boundary_is_exclusive() {
        // the largest admissible cost is one ulp under 2^31 time units
        let just_under = crate::sched::engine::MAX_TIME_UNITS - 1.0;
        let mut b = Builder::new("edge");
        b.add_task("a", vec![just_under, 1.0]);
        let g = b.try_build().expect("just-under-headroom cost admissible");
        assert!(g.validate().is_ok());
        // and validate() rejects the same out-of-headroom graph built
        // by hand (the daemon-decode path goes through validate too)
        let bad = TaskGraph {
            app: "huge".into(),
            names: vec!["a".into()],
            proc_times: vec![vec![1e308]],
            preds: vec![vec![]],
            succs: vec![vec![]],
        };
        assert!(bad.validate().unwrap_err().contains("headroom"));
    }

    #[test]
    fn kernel_histogram_counts() {
        let g = diamond();
        let h = g.kernel_histogram();
        assert_eq!(h.len(), 4);
        assert_eq!(h["a"], 1);
    }
}
