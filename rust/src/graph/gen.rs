//! Random task-graph generators for property tests (not the paper's
//! benchmark — those live in `workloads/`): layered DAGs and
//! Erdős–Rényi-style DAGs with random 2-type or Q-type times.

use crate::substrate::rng::Rng;

use super::{Builder, TaskGraph};

/// Random DAG: arc (i, j), i < j, with probability `density`; times
/// uniform in [0.5, 10] per type.
pub fn random_dag(rng: &mut Rng, n: usize, density: f64, n_types: usize) -> TaskGraph {
    let mut b = Builder::new("random");
    for j in 0..n {
        let times: Vec<f64> = (0..n_types).map(|_| rng.uniform(0.5, 10.0)).collect();
        b.add_task(&format!("t{j}"), times);
    }
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.chance(density) {
                b.add_arc(i, j);
            }
        }
    }
    b.build()
}

/// Layered DAG: `layers` layers of ~`width` tasks; arcs only between
/// consecutive layers with probability `density` (plus a fallback arc so
/// no task in layer l > 0 is orphaned).
pub fn layered_dag(
    rng: &mut Rng,
    layers: usize,
    width: usize,
    density: f64,
    n_types: usize,
) -> TaskGraph {
    let mut b = Builder::new("layered");
    let mut prev: Vec<usize> = Vec::new();
    for l in 0..layers {
        let w = 1 + rng.below(width.max(1));
        let cur: Vec<usize> = (0..w)
            .map(|i| {
                let times: Vec<f64> = (0..n_types).map(|_| rng.uniform(0.5, 10.0)).collect();
                b.add_task(&format!("l{l}_{i}"), times)
            })
            .collect();
        if l > 0 {
            for &j in &cur {
                let mut any = false;
                for &i in &prev {
                    if rng.chance(density) {
                        b.add_arc(i, j);
                        any = true;
                    }
                }
                if !any {
                    let i = prev[rng.below(prev.len())];
                    b.add_arc(i, j);
                }
            }
        }
        prev = cur;
    }
    b.build()
}

/// Random "accelerator-flavoured" hybrid DAG: GPU times are CPU times
/// scaled by an acceleration factor in [0.1, 50] (mimicking the paper's
/// fork-join recipe), so allocation actually matters.
pub fn hybrid_dag(rng: &mut Rng, n: usize, density: f64) -> TaskGraph {
    let mut b = Builder::new("hybrid");
    for j in 0..n {
        let cpu = rng.uniform(1.0, 20.0);
        let accel = if rng.chance(0.1) {
            rng.uniform(0.1, 0.5)
        } else {
            rng.uniform(0.5, 50.0)
        };
        b.add_task(&format!("t{j}"), vec![cpu, cpu / accel]);
    }
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.chance(density) {
                b.add_arc(i, j);
            }
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_dag_valid() {
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let g = random_dag(&mut rng, 30, 0.2, 2);
            assert!(g.validate().is_ok());
            assert_eq!(g.n_tasks(), 30);
        }
    }

    #[test]
    fn layered_dag_valid_and_layered() {
        let mut rng = Rng::new(2);
        let g = layered_dag(&mut rng, 5, 6, 0.4, 3);
        assert!(g.validate().is_ok());
        assert_eq!(g.n_types(), 3);
        // every non-first-layer task has a predecessor
        let sources = g.sources();
        for s in &sources {
            assert!(g.names[*s].starts_with("l0_"), "{}", g.names[*s]);
        }
    }

    #[test]
    fn hybrid_dag_has_heterogeneous_times() {
        let mut rng = Rng::new(3);
        let g = hybrid_dag(&mut rng, 50, 0.1);
        assert!(g.validate().is_ok());
        let faster_gpu = (0..50).filter(|&j| g.p_gpu(j) < g.p_cpu(j)).count();
        let faster_cpu = (0..50).filter(|&j| g.p_gpu(j) > g.p_cpu(j)).count();
        assert!(faster_gpu > 0 && faster_cpu > 0);
    }
}
