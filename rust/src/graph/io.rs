//! Task-graph serialization: JSON (lossless round-trip) and Graphviz DOT
//! (inspection).  The JSON schema is the library's on-disk instance
//! format (`hetsched gen --out file.json`).

use crate::substrate::json::{self, Json};

use super::{Builder, TaskGraph};

pub fn to_json(g: &TaskGraph) -> Json {
    let tasks: Vec<Json> = (0..g.n_tasks())
        .map(|j| {
            Json::obj(vec![
                ("name", Json::Str(g.names[j].clone())),
                (
                    "times",
                    Json::Arr(g.proc_times[j].iter().map(|&t| Json::Num(t)).collect()),
                ),
            ])
        })
        .collect();
    let arcs: Vec<Json> = (0..g.n_tasks())
        .flat_map(|j| {
            g.succs[j]
                .iter()
                .map(move |&s| Json::Arr(vec![Json::Num(j as f64), Json::Num(s as f64)]))
        })
        .collect();
    Json::obj(vec![
        ("app", Json::Str(g.app.clone())),
        ("tasks", Json::Arr(tasks)),
        ("arcs", Json::Arr(arcs)),
    ])
}

pub fn from_json(v: &Json) -> Result<TaskGraph, String> {
    let app = v
        .get("app")
        .and_then(|x| x.as_str())
        .ok_or("missing app")?;
    let mut b = Builder::new(app);
    for t in v.get("tasks").and_then(|x| x.as_arr()).ok_or("missing tasks")? {
        let name = t.get("name").and_then(|x| x.as_str()).ok_or("task name")?;
        let times = t
            .get("times")
            .and_then(|x| x.as_arr())
            .ok_or("task times")?
            .iter()
            .map(|x| x.as_f64().ok_or("bad time"))
            .collect::<Result<Vec<_>, _>>()?;
        b.add_task(name, times);
    }
    for a in v.get("arcs").and_then(|x| x.as_arr()).ok_or("missing arcs")? {
        let pair = a.as_arr().ok_or("bad arc")?;
        if pair.len() != 2 {
            return Err("bad arc arity".into());
        }
        let i = pair[0].as_usize().ok_or("bad arc src")?;
        let j = pair[1].as_usize().ok_or("bad arc dst")?;
        if i >= b.n_tasks() || j >= b.n_tasks() {
            return Err("arc endpoint out of range".into());
        }
        b.add_arc(i, j);
    }
    // try_build surfaces invalid documents (NaN / non-positive /
    // beyond-tick-headroom costs) as Err rather than a builder panic
    let g = b.try_build()?;
    g.validate()?;
    Ok(g)
}

pub fn parse_graph(text: &str) -> Result<TaskGraph, String> {
    from_json(&json::parse(text)?)
}

/// Graphviz DOT with kernel names and CPU/GPU times in the labels.
pub fn to_dot(g: &TaskGraph) -> String {
    let mut s = String::new();
    s.push_str(&format!("digraph \"{}\" {{\n  rankdir=TB;\n", g.app));
    for j in 0..g.n_tasks() {
        let times = g.proc_times[j]
            .iter()
            .map(|t| format!("{t:.2}"))
            .collect::<Vec<_>>()
            .join("/");
        s.push_str(&format!(
            "  t{j} [label=\"{}#{j}\\n{}\"];\n",
            g.names[j], times
        ));
    }
    for j in 0..g.n_tasks() {
        for &k in &g.succs[j] {
            s.push_str(&format!("  t{j} -> t{k};\n"));
        }
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;

    fn sample() -> TaskGraph {
        let mut b = Builder::new("sample");
        let a = b.add_task("A", vec![1.5, 0.5]);
        let c = b.add_task("B", vec![2.0, 4.0]);
        let d = b.add_task("C", vec![3.0, 1.0]);
        b.add_arc(a, c);
        b.add_arc(a, d);
        b.build()
    }

    #[test]
    fn json_roundtrip() {
        let g = sample();
        let text = to_json(&g).to_string();
        let back = parse_graph(&text).unwrap();
        assert_eq!(back.app, g.app);
        assert_eq!(back.names, g.names);
        assert_eq!(back.proc_times, g.proc_times);
        assert_eq!(back.succs, g.succs);
    }

    #[test]
    fn from_json_rejects_bad_docs() {
        assert!(parse_graph("{}").is_err());
        assert!(parse_graph(r#"{"app":"x","tasks":[],"arcs":[[0,1]]}"#).is_err());
        // untrusted documents must surface bad costs as Err, not panic:
        // non-positive, and finite-but-beyond-tick-headroom
        let zero = r#"{"app":"x","tasks":[{"name":"a","times":[0.0]}],"arcs":[]}"#;
        assert!(parse_graph(zero).is_err());
        let huge = r#"{"app":"x","tasks":[{"name":"a","times":[1e308]}],"arcs":[]}"#;
        let err = parse_graph(huge).unwrap_err();
        assert!(err.contains("headroom"), "{err}");
    }

    #[test]
    fn dot_contains_nodes_and_edges() {
        let d = to_dot(&sample());
        assert!(d.contains("digraph"));
        assert!(d.contains("t0 -> t1"));
        assert!(d.contains("A#0"));
    }
}
