//! Machine model: `Q` sets of identical processors (Section 1 of the
//! paper; `Q = 2` is the hybrid CPU/GPU case with `m >= k`), plus the
//! exact machine-configuration grids of the experimental campaign (§6).

/// A heterogeneous platform: `counts[q]` identical units of type `q`.
/// Type 0 is "CPU" and type 1 "GPU" in the hybrid case.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Platform {
    pub counts: Vec<usize>,
    pub names: Vec<String>,
}

impl Platform {
    pub fn new(counts: Vec<usize>) -> Platform {
        assert!(!counts.is_empty() && counts.iter().all(|&c| c > 0));
        let names = (0..counts.len())
            .map(|q| match q {
                0 => "CPU".to_string(),
                1 => "GPU".to_string(),
                q => format!("GPU{q}"),
            })
            .collect();
        Platform { counts, names }
    }

    /// Hybrid platform with `m` CPUs and `k` GPUs.
    pub fn hybrid(m: usize, k: usize) -> Platform {
        Platform::new(vec![m, k])
    }

    pub fn n_types(&self) -> usize {
        self.counts.len()
    }

    pub fn n_units(&self) -> usize {
        self.counts.iter().sum()
    }

    /// m (number of CPUs) in the hybrid case.
    pub fn m(&self) -> usize {
        self.counts[0]
    }

    /// k (number of GPUs) in the hybrid case.
    pub fn k(&self) -> usize {
        self.counts[1]
    }

    pub fn label(&self) -> String {
        self.counts
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join("x")
    }
}

/// The paper's 16 hybrid configurations (§6.2): 16..128 CPUs x 2..16 GPUs.
pub fn paper_two_type_configs() -> Vec<Platform> {
    let ms = [16usize, 32, 64, 128];
    let ks = [2usize, 4, 8, 16];
    let mut out = Vec::new();
    for &m in &ms {
        for &k in &ks {
            out.push(Platform::hybrid(m, k));
        }
    }
    out
}

/// Cluster-scale hybrid configs beyond the paper's grid (ROADMAP "scale
/// the campaign grids"): the paper's 16 configurations plus 256-unit
/// (192 CPUs + 64 GPUs) and 320-unit (256 + 64) platforms — the sizes
/// the gap-indexed HEFT and blocked PDHG kernels are gated on.
pub fn extended_two_type_configs() -> Vec<Platform> {
    let mut out = paper_two_type_configs();
    out.push(Platform::hybrid(192, 64));
    out.push(Platform::hybrid(256, 64));
    out
}

/// The paper's 3-type grid (§6.2): triplets (CPUs, GPU1s, GPU2s) over the
/// same value sets, 64 configurations in total.
pub fn paper_three_type_configs() -> Vec<Platform> {
    let ms = [16usize, 32, 64, 128];
    let ks = [2usize, 4, 8, 16];
    let mut out = Vec::new();
    for &m in &ms {
        for &k1 in &ks {
            for &k2 in &ks {
                out.push(Platform::new(vec![m, k1, k2]));
            }
        }
    }
    out
}

/// Reduced grids for quick campaigns (`--scale` smoke/default; the full
/// paper grid stays available behind `--scale full`).
pub fn reduced_two_type_configs() -> Vec<Platform> {
    vec![
        Platform::hybrid(16, 2),
        Platform::hybrid(16, 8),
        Platform::hybrid(64, 4),
        Platform::hybrid(128, 16),
    ]
}

pub fn reduced_three_type_configs() -> Vec<Platform> {
    vec![
        Platform::new(vec![16, 2, 2]),
        Platform::new(vec![16, 8, 2]),
        Platform::new(vec![64, 4, 8]),
        Platform::new(vec![128, 16, 4]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_accessors() {
        let p = Platform::hybrid(16, 4);
        assert_eq!(p.m(), 16);
        assert_eq!(p.k(), 4);
        assert_eq!(p.n_units(), 20);
        assert_eq!(p.n_types(), 2);
        assert_eq!(p.label(), "16x4");
        assert_eq!(p.names[0], "CPU");
        assert_eq!(p.names[1], "GPU");
    }

    #[test]
    fn paper_grids_have_paper_sizes() {
        assert_eq!(paper_two_type_configs().len(), 16);
        assert_eq!(paper_three_type_configs().len(), 64);
        // m >= k holds for every paper hybrid config
        for p in paper_two_type_configs() {
            assert!(p.m() >= p.k());
        }
    }

    #[test]
    #[should_panic]
    fn zero_count_rejected() {
        Platform::new(vec![4, 0]);
    }

    #[test]
    fn extended_grid_appends_cluster_scale_configs() {
        let ext = extended_two_type_configs();
        assert_eq!(ext.len(), 18);
        assert_eq!(&ext[..16], &paper_two_type_configs()[..]);
        assert_eq!(ext[16].n_units(), 256);
        assert_eq!((ext[16].m(), ext[16].k()), (192, 64));
        assert_eq!(ext[17].n_units(), 320);
    }
}
