// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! `hetsched` — CLI for the heterogeneous-scheduling framework.
//!
//! Subcommands:
//!   counts        Table 4/5 task counts (sanity vs the paper)
//!   gen           generate an instance (JSON to --out, DOT with --dot)
//!   lp            solve the (Q)HLP relaxation of an instance
//!   schedule      run an offline algorithm, print makespan (+ --gantt)
//!   online        run an online policy
//!   experiment    regenerate a figure: --fig 3|4|5|6|7
//!   lower-bounds  run the Theorem 1/2/4 adversarial instances
//!   serve         live coordinator run (worker threads)
//!   service       multi-tenant streaming service simulation
//!   metrics       fetch a running daemon's metrics snapshot
//!   explain       replay a WAL and explain one task's placement
//!   artifacts     show the AOT artifact manifest

use hetsched::algos::{run_offline, solve_hlp, solve_qhlp, Offline};
use hetsched::analysis::{
    mean_improvement_pct, pairwise_by_app, ratio_by_app, ratio_by_sqrt_mk, records_csv,
    render_summary_table,
};
use hetsched::coordinator::{run_live, LiveConfig};
use hetsched::experiments::{offline, online, thm, CampaignOpts};
use hetsched::graph::{io as gio, TaskGraph};
use hetsched::platform::Platform;
use hetsched::runtime::LpBackendKind;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sched::service::{run_service, Submission, TenantPolicy};
use hetsched::obs::MetricsReport;
use hetsched::service_net::{explain_from_wal, serve, Client, DaemonConfig};
use hetsched::sim::{validate, validate_realized, validate_service};
use hetsched::substrate::cli::Args;
use hetsched::workloads::{chameleon, forkjoin, Instance, Scale};

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("counts") => cmd_counts(),
        Some("gen") => cmd_gen(&args),
        Some("lp") => cmd_lp(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("online") => cmd_online(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("lower-bounds") => cmd_lower_bounds(&args),
        Some("serve") => cmd_serve(&args),
        Some("service") => cmd_service(&args),
        Some("serve-service") => cmd_serve_service(&args),
        Some("submit") => cmd_submit(&args),
        Some("status") => cmd_status(&args),
        Some("cancel") => cmd_cancel(&args),
        Some("report") => cmd_report(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("explain") => cmd_explain(&args),
        Some("shutdown") => cmd_shutdown(&args),
        Some("artifacts") => cmd_artifacts(),
        _ => usage(),
    }
}

fn usage() {
    eprintln!(
        "usage: hetsched <command> [flags]\n\
         commands:\n  \
         counts\n  \
         gen        --app potrf|getrf|posv|potri|potrs|forkjoin --nb N --bs B \
         [--width W --phases P] [--types 2|3] [--out FILE] [--dot]\n  \
         lp         (gen flags) --m M --k K [--backend auto|rust|pjrt|simplex] [--tol T]\n  \
         schedule   (lp flags) --algo hlp-est|hlp-ols|heft [--gantt]\n  \
         online     (gen flags) --m M --k K --policy er-ls|eft|greedy|random|r1|r2|r3\n  \
         experiment --fig 3|4|5|6|7 [--scale smoke|default|full] [--backend B] \
         [--workers N] [--out DIR]\n  \
         lower-bounds [--thm 1|2|4]\n  \
         serve      (gen flags) --m M --k K --policy P [--time-scale S]\n  \
         service    --tenants N --tasks T --m M --k K [--gap G] [--seed S] \
         [--admission fifo|quota|stretch] [--cpu-share F --gpu-share F] [--weight W]\n  \
         serve-service --addr HOST:PORT --wal FILE --m M --k K [--shards N] \
         [--port-file FILE] [--trace-out FILE]\n  \
         submit     --addr HOST:PORT (gen flags) [--arrival T] [--policy P] \
         [--admission A ...] [--timeout-s S]\n  \
         status     --addr HOST:PORT --tenant I [--timeout-s S]\n  \
         cancel     --addr HOST:PORT --tenant I [--timeout-s S]\n  \
         report     --addr HOST:PORT [--timeout-s S]\n  \
         metrics    --addr HOST:PORT [--json] [--timeout-s S]\n  \
         explain    --wal FILE --task TENANT:TASK\n  \
         shutdown   --addr HOST:PORT [--timeout-s S]\n  \
         artifacts"
    );
    std::process::exit(2);
}

fn cmd_counts() {
    println!("Table 4 (Chameleon task counts):");
    println!("{:>8} {:>8} {:>8} {:>8}", "app", "nb=5", "nb=10", "nb=20");
    for app in chameleon::APPS {
        let row: Vec<usize> = [5, 10, 20]
            .iter()
            .map(|&nb| chameleon::table4_count(app, nb).unwrap())
            .collect();
        println!("{:>8} {:>8} {:>8} {:>8}", app, row[0], row[1], row[2]);
    }
    println!("\nTable 5 (fork-join task counts):");
    print!("{:>6}", "p\\w");
    for w in forkjoin::PAPER_WIDTHS {
        print!(" {w:>6}");
    }
    println!();
    for p in forkjoin::PAPER_PHASES {
        print!("{p:>6}");
        for w in forkjoin::PAPER_WIDTHS {
            print!(" {:>6}", forkjoin::table5_count(w, p));
        }
        println!();
    }
}

fn instance_from_args(args: &Args) -> Instance {
    let app = args.string("app", "potrf");
    if app == "forkjoin" || app == "fork-join" {
        Instance::ForkJoin {
            width: args.usize("width", 100),
            phases: args.usize("phases", 2),
        }
    } else {
        Instance::Chameleon {
            app,
            nb_blocks: args.usize("nb", 10),
            block_size: args.usize("bs", 320),
        }
    }
}

fn graph_from_args(args: &Args) -> TaskGraph {
    let n_types = args.usize("types", 2);
    instance_from_args(args).generate(n_types)
}

fn platform_from_args(args: &Args, g: &TaskGraph) -> Platform {
    if g.n_types() == 2 {
        Platform::hybrid(args.usize("m", 16), args.usize("k", 4))
    } else {
        Platform::new(vec![
            args.usize("m", 16),
            args.usize("k", 4),
            args.usize("k2", 4),
        ])
    }
}

fn backend_from_args(args: &Args) -> LpBackendKind {
    LpBackendKind::parse(&args.string("backend", "auto")).unwrap_or_else(|| {
        eprintln!("unknown backend");
        std::process::exit(2)
    })
}

fn cmd_gen(args: &Args) {
    let g = graph_from_args(args);
    eprintln!(
        "{}: {} tasks, {} arcs, {} types",
        g.app,
        g.n_tasks(),
        g.n_arcs(),
        g.n_types()
    );
    let text = if args.has("dot") {
        gio::to_dot(&g)
    } else {
        gio::to_json(&g).to_string()
    };
    match args.str_flag("out") {
        Some(path) => std::fs::write(path, text).expect("write output"),
        None => println!("{text}"),
    }
}

fn cmd_lp(args: &Args) {
    let g = graph_from_args(args);
    let plat = platform_from_args(args, &g);
    let backend = backend_from_args(args);
    let tol = args.f64("tol", 1e-4);
    let t = std::time::Instant::now();
    let sol = if g.n_types() == 2 {
        solve_hlp(&g, &plat, backend, tol)
    } else {
        solve_qhlp(&g, &plat, backend, tol)
    };
    println!(
        "LP* = {:.6}  (backend {}, gap {:.2e}, {} iters, {:?})",
        sol.sol.obj,
        sol.sol.backend,
        sol.sol.gap,
        sol.sol.iters,
        t.elapsed()
    );
    let cpu = sol.alloc.iter().filter(|&&a| a == 0).count();
    println!(
        "allocation: {} tasks on CPU, {} on accelerators",
        cpu,
        g.n_tasks() - cpu
    );
}

fn cmd_schedule(args: &Args) {
    let g = graph_from_args(args);
    let plat = platform_from_args(args, &g);
    let backend = backend_from_args(args);
    let algo = match args.string("algo", "hlp-ols").as_str() {
        "hlp-est" => Offline::HlpEst,
        "hlp-ols" => Offline::HlpOls,
        "heft" => Offline::Heft,
        other => {
            eprintln!("unknown algo {other}");
            std::process::exit(2)
        }
    };
    let tol = args.f64("tol", 1e-4);
    let t = std::time::Instant::now();
    let (s, lp) = run_offline(algo, &g, &plat, None, backend, tol);
    validate(&g, &plat, &s).expect("invalid schedule");
    println!(
        "{} on {} ({}): makespan {:.6} in {:?}",
        algo.name(),
        g.app,
        plat.label(),
        s.makespan,
        t.elapsed()
    );
    if let Some(lp) = lp {
        println!("LP* = {:.6}, ratio = {:.4}", lp.sol.obj, s.makespan / lp.sol.obj);
    }
    let util = s.utilization(&plat);
    println!(
        "utilization: {}",
        util.iter()
            .enumerate()
            .map(|(q, u)| format!("{} {:.1}%", plat.names[q], u * 100.0))
            .collect::<Vec<_>>()
            .join(", ")
    );
    if args.has("gantt") {
        println!("{}", s.gantt(&g, &plat));
    }
}

/// Exit with the flag-naming parse error (used by the strict `try_*`
/// getters: a mistyped `--weight abc` or a value-eating `--weight
/// --full` aborts instead of silently running with the default).
fn or_die<T>(r: Result<T, String>) -> T {
    r.unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2)
    })
}

fn policy_from_args(args: &Args) -> OnlinePolicy {
    match args.string("policy", "er-ls").as_str() {
        "er-ls" | "erls" => OnlinePolicy::ErLs,
        "eft" => OnlinePolicy::Eft,
        "greedy" => OnlinePolicy::Greedy,
        "random" => OnlinePolicy::Random(or_die(args.try_u64("seed", 42))),
        "r1" => OnlinePolicy::R1,
        "r2" => OnlinePolicy::R2,
        "r3" => OnlinePolicy::R3,
        other => {
            eprintln!("unknown policy {other}");
            std::process::exit(2)
        }
    }
}

fn cmd_online(args: &Args) {
    let g = graph_from_args(args);
    let plat = platform_from_args(args, &g);
    let policy = policy_from_args(args);
    let t = std::time::Instant::now();
    let s = online_by_id(&g, &plat, &policy);
    validate(&g, &plat, &s).expect("invalid schedule");
    println!(
        "{} on {} ({}): makespan {:.6} in {:?}",
        policy.name(),
        g.app,
        plat.label(),
        s.makespan,
        t.elapsed()
    );
}

fn campaign_opts(args: &Args) -> CampaignOpts {
    let mut opts = CampaignOpts {
        scale: Scale::parse(&args.string("scale", "default")).unwrap_or(Scale::Default),
        backend: backend_from_args(args),
        tol: args.f64("tol", 1e-4),
        ..Default::default()
    };
    if let Some(w) = args.str_flag("workers") {
        opts.workers = w.parse().unwrap_or(opts.workers);
    }
    if args.has("no-cache") {
        opts.cache_path = None;
    } else if let Some(dir) = args.str_flag("out") {
        opts.cache_path = Some(std::path::Path::new(dir).join("lp_cache.json"));
    }
    opts
}

fn write_out(args: &Args, name: &str, content: &str) {
    if let Some(dir) = args.str_flag("out") {
        std::fs::create_dir_all(dir).ok();
        let path = std::path::Path::new(dir).join(name);
        std::fs::write(&path, content).expect("write results");
        eprintln!("wrote {}", path.display());
    }
}

fn cmd_experiment(args: &Args) {
    let fig = args.usize("fig", 3);
    let opts = campaign_opts(args);
    match fig {
        3 | 4 => {
            let records = offline::run(2, &opts);
            write_out(args, &format!("fig{fig}_records.csv"), &records_csv(&records));
            if fig == 3 {
                for algo in ["HLP-EST", "HLP-OLS", "HEFT"] {
                    println!(
                        "{}",
                        render_summary_table(
                            &format!("Fig.3 makespan/LP* — {algo}"),
                            &ratio_by_app(&records, algo)
                        )
                    );
                }
            } else {
                println!(
                    "{}",
                    render_summary_table(
                        "Fig.4-left HLP-EST / HLP-OLS",
                        &pairwise_by_app(&records, "HLP-EST", "HLP-OLS")
                    )
                );
                println!(
                    "{}",
                    render_summary_table(
                        "Fig.4-right HEFT / HLP-OLS",
                        &pairwise_by_app(&records, "HEFT", "HLP-OLS")
                    )
                );
                println!(
                    "mean improvement of HLP-OLS over HLP-EST: {:.1}%",
                    mean_improvement_pct(&records, "HLP-OLS", "HLP-EST")
                );
                println!(
                    "mean improvement of HLP-OLS over HEFT: {:.1}%",
                    mean_improvement_pct(&records, "HLP-OLS", "HEFT")
                );
            }
        }
        5 => {
            let records = offline::run(3, &opts);
            write_out(args, "fig5_records.csv", &records_csv(&records));
            for algo in ["QHLP-EST", "QHLP-OLS", "QHEFT"] {
                println!(
                    "{}",
                    render_summary_table(
                        &format!("Fig.5-left makespan/LP* — {algo}"),
                        &ratio_by_app(&records, algo)
                    )
                );
            }
            println!(
                "{}",
                render_summary_table(
                    "Fig.5-right QHEFT / QHLP-OLS",
                    &pairwise_by_app(&records, "QHEFT", "QHLP-OLS")
                )
            );
            println!(
                "mean improvement of QHEFT over QHLP-OLS: {:.1}%",
                mean_improvement_pct(&records, "QHEFT", "QHLP-OLS")
            );
        }
        6 | 7 => {
            let records = online::run(&opts);
            write_out(args, &format!("fig{fig}_records.csv"), &records_csv(&records));
            if fig == 6 {
                for algo in ["ER-LS", "EFT", "Greedy", "Random"] {
                    println!(
                        "{}",
                        render_summary_table(
                            &format!("Fig.6-left makespan/LP* — {algo}"),
                            &ratio_by_app(&records, algo)
                        )
                    );
                }
                println!("Fig.6-right mean competitive ratio vs sqrt(m/k):");
                for algo in ["ER-LS", "EFT", "Greedy"] {
                    let series = ratio_by_sqrt_mk(&records, algo);
                    let pts: Vec<String> = series
                        .iter()
                        .map(|(x, s)| format!("({x:.2}, {:.3}±{:.3})", s.mean, s.stderr))
                        .collect();
                    println!("  {algo:>7}: {}", pts.join(" "));
                }
            } else {
                println!(
                    "{}",
                    render_summary_table(
                        "Fig.7-left Greedy / ER-LS",
                        &pairwise_by_app(&records, "Greedy", "ER-LS")
                    )
                );
                println!(
                    "{}",
                    render_summary_table(
                        "Fig.7-right EFT / ER-LS",
                        &pairwise_by_app(&records, "EFT", "ER-LS")
                    )
                );
                println!(
                    "mean improvement of ER-LS over Greedy: {:.1}%",
                    mean_improvement_pct(&records, "ER-LS", "Greedy")
                );
                println!(
                    "mean improvement of ER-LS over EFT: {:.1}%",
                    mean_improvement_pct(&records, "ER-LS", "EFT")
                );
            }
        }
        other => {
            eprintln!("unknown figure {other}");
            std::process::exit(2);
        }
    }
}

fn cmd_lower_bounds(args: &Args) {
    let which = args.usize("thm", 0);
    if which == 0 || which == 1 {
        println!("Theorem 1 (HEFT lower bound, instance of Table 1 / Fig. 1):");
        println!(
            "{:>5} {:>3} {:>12} {:>12} {:>9} {:>9} {:>9}",
            "m", "k", "HEFT", "GOOD", "ratio", "exact", "asympt"
        );
        for (m, k) in [(9usize, 2usize), (16, 2), (16, 4), (36, 4), (64, 8), (128, 8)] {
            if k * k > m {
                continue;
            }
            let (heft_ms, good_ms, ratio) = thm::thm1_run(m, k);
            println!(
                "{m:>5} {k:>3} {heft_ms:>12.4} {good_ms:>12.4} {ratio:>9.4} {:>9.4} {:>9.4}",
                thm::thm1_exact_ratio(m, k),
                thm::thm1_predicted_ratio(m, k)
            );
        }
    }
    if which == 0 || which == 2 {
        println!("\nTheorem 2 (HLP-EST tightness, instance of Table 2 / Fig. 2):");
        println!(
            "{:>5} {:>12} {:>10} {:>10} {:>10}",
            "m", "LP*", "EST", "OLS", "6-O(1/m)"
        );
        for m in [5usize, 10, 20, 40, 80] {
            let (lp_star, est_ratio, ols_ratio) = thm::thm2_run(m);
            println!(
                "{m:>5} {lp_star:>12.4} {est_ratio:>10.4} {ols_ratio:>10.4} {:>10.4}",
                thm::thm2_worst_makespan(m) / lp_star
            );
        }
    }
    if which == 0 || which == 4 {
        println!("\nTheorem 4 (ER-LS lower bound, instance of Table 3):");
        println!(
            "{:>5} {:>3} {:>12} {:>12} {:>9} {:>9}",
            "m", "k", "ER-LS", "OPT", "ratio", "sqrt(m/k)"
        );
        for (m, k) in [(16usize, 4usize), (36, 4), (64, 4), (64, 16), (128, 8)] {
            let (erls_ms, opt_ms, ratio) = thm::thm4_run(m, k);
            println!(
                "{m:>5} {k:>3} {erls_ms:>12.4} {opt_ms:>12.4} {ratio:>9.4} {:>9.4}",
                (m as f64 / k as f64).sqrt()
            );
        }
    }
}

fn cmd_serve(args: &Args) {
    let g = graph_from_args(args);
    let plat = Platform::hybrid(args.usize("m", 4), args.usize("k", 2));
    let policy = policy_from_args(args);
    let cfg = LiveConfig {
        time_scale: args.f64("time-scale", 0.001),
        policy,
    };
    let order: Vec<usize> = (0..g.n_tasks()).collect();
    println!(
        "serving {} ({} tasks) on {} workers ({}), policy {} ...",
        g.app,
        g.n_tasks(),
        plat.n_units(),
        plat.label(),
        cfg.policy.name()
    );
    let (report, realized) = run_live(&g, &plat, &order, &cfg);
    // wall-measured durations include dispatch/wakeup overhead, so the
    // realized-schedule validator (duration >= allocated) applies
    validate_realized(&g, &plat, &realized).expect("realized schedule invalid");
    println!(
        "realized makespan {:.3} (predicted {:.3}, +{:.1}%), wall {:?}",
        report.realized_makespan,
        report.predicted_makespan,
        (report.realized_makespan / report.predicted_makespan - 1.0) * 100.0,
        report.wall
    );
    println!(
        "dispatch latency (edge-measured): p50 {:.1} us, p95 {:.1} us",
        report.decision_latency.p50 * 1e6,
        report.decision_latency.p95 * 1e6
    );
}

fn admission_from_args(args: &Args) -> TenantPolicy {
    match args.string("admission", "fifo").as_str() {
        "fifo" => TenantPolicy::Fifo,
        "quota" => TenantPolicy::Quota {
            cpu_share: or_die(args.try_f64("cpu-share", 0.5)),
            gpu_share: or_die(args.try_f64("gpu-share", 0.5)),
        },
        "stretch" | "weighted-stretch" => TenantPolicy::WeightedStretch {
            weight: or_die(args.try_f64("weight", 1.0)),
        },
        other => {
            eprintln!("unknown admission policy {other} (fifo|quota|stretch)");
            std::process::exit(2)
        }
    }
}

fn cmd_service(args: &Args) {
    let n_tenants = or_die(args.try_usize("tenants", 8));
    let n_tasks = or_die(args.try_usize("tasks", 200));
    let plat = Platform::hybrid(
        or_die(args.try_usize("m", 16)),
        or_die(args.try_usize("k", 4)),
    );
    let gap = or_die(args.try_f64("gap", 20.0));
    let admission = admission_from_args(args);
    let mut rng = hetsched::substrate::rng::Rng::new(or_die(args.try_u64("seed", 7)));
    let policies = [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy];
    let subs: Vec<Submission> = (0..n_tenants)
        .map(|t| {
            let density = (4.0 / n_tasks as f64).min(0.2);
            let g = hetsched::graph::gen::hybrid_dag(&mut rng, n_tasks, density);
            Submission::new(g, t as f64 * gap, policies[t % policies.len()].clone())
                .with_admission(admission.clone())
        })
        .collect();
    println!(
        "service: {n_tenants} tenants x {n_tasks} tasks on {} (arrival gap {gap}, admission {})",
        plat.label(),
        admission.name()
    );
    let t0 = std::time::Instant::now();
    let report = run_service(&plat, &subs);
    let wall = t0.elapsed();
    validate_service(&plat, &report.tenant_runs(&subs)).expect("service schedule feasible");
    println!(
        "{:>6} {:>8} {:>9} {:>10} {:>9} {:>8}",
        "tenant", "policy", "arrival", "flow", "ideal", "stretch"
    );
    for (t, s) in report.tenants.iter().zip(&subs) {
        println!(
            "{:>6} {:>8} {:>9.1} {:>10.1} {:>9.1} {:>8.2}",
            t.tenant,
            s.policy.name(),
            t.arrival,
            t.flow_time,
            t.ideal_makespan,
            t.stretch
        );
    }
    println!(
        "horizon {:.1} | mean stretch {:.2} | max {:.2} | p99 {:.2} | Jain {:.3} | {} decisions in {:?}",
        report.horizon,
        report.mean_stretch,
        report.max_stretch,
        report.stretch_p99,
        report.jain_index,
        report.decisions.len(),
        wall
    );
}

fn cmd_serve_service(args: &Args) {
    let cfg = DaemonConfig {
        addr: args.string("addr", "127.0.0.1:0"),
        wal: std::path::PathBuf::from(args.string("wal", "service.wal")),
        plat: Platform::hybrid(
            or_die(args.try_usize("m", 16)),
            or_die(args.try_usize("k", 4)),
        ),
        port_file: args.str_flag("port-file").map(std::path::PathBuf::from),
        trace_out: args.str_flag("trace-out").map(std::path::PathBuf::from),
        shards: or_die(args.try_usize("shards", 1)),
    };
    or_die(serve(&cfg));
}

fn client_from_args(args: &Args) -> Client {
    let timeout_s = or_die(args.try_u64(
        "timeout-s",
        hetsched::service_net::DEFAULT_TIMEOUT_S,
    ));
    or_die(Client::connect_with_timeout(
        &args.string("addr", "127.0.0.1:7477"),
        timeout_s,
    ))
}

fn tenant_from_args(args: &Args) -> usize {
    or_die(args.try_usize("tenant", 0))
}

fn cmd_submit(args: &Args) {
    let g = graph_from_args(args);
    let arrival = or_die(args.try_f64("arrival", 0.0));
    if !(arrival.is_finite() && arrival >= 0.0) {
        or_die::<()>(Err(format!("--arrival must be finite and >= 0, got {arrival}")));
    }
    let sub = Submission::new(g, arrival, policy_from_args(args))
        .with_admission(admission_from_args(args));
    let tenant = or_die(client_from_args(args).submit(&sub));
    println!("tenant {tenant}");
}

fn cmd_status(args: &Args) {
    let status = or_die(client_from_args(args).status(tenant_from_args(args)));
    println!("{status}");
}

fn cmd_cancel(args: &Args) {
    let out = or_die(client_from_args(args).cancel(tenant_from_args(args)));
    println!("{out}");
}

fn cmd_report(args: &Args) {
    // canonical deterministic projection (no wall-clock fields): two
    // drained daemons with the same WAL print byte-identical reports
    let report = or_die(client_from_args(args).report());
    println!("{report}");
}

fn cmd_metrics(args: &Args) {
    // merged snapshot: replay-stable core counters + daemon-edge
    // registry (op counts, WAL bytes, edge latency histogram)
    let json = or_die(client_from_args(args).metrics());
    if args.has("json") {
        println!("{json}");
    } else {
        print!("{}", or_die(MetricsReport::from_json(&json)).render());
    }
}

fn parse_task_spec(spec: &str) -> Result<(usize, usize), String> {
    let (t, j) = spec
        .split_once(':')
        .ok_or_else(|| format!("--task must be TENANT:TASK, got {spec:?}"))?;
    let tenant = t.parse().map_err(|_| format!("bad tenant in --task {spec:?}"))?;
    let task = j.parse().map_err(|_| format!("bad task in --task {spec:?}"))?;
    Ok((tenant, task))
}

fn cmd_explain(args: &Args) {
    // offline: replays the WAL through a tracing Service (replay ==
    // rerun, so the explanation describes the original run exactly)
    let wal = std::path::PathBuf::from(args.string("wal", "service.wal"));
    let (tenant, task) = or_die(parse_task_spec(&args.string("task", "0:0")));
    println!("{}", or_die(explain_from_wal(&wal, tenant, task)));
}

fn cmd_shutdown(args: &Args) {
    or_die(client_from_args(args).shutdown());
    println!("daemon stopped");
}

fn cmd_artifacts() {
    match hetsched::runtime::load_manifest() {
        Ok(man) => {
            println!("artifacts dir: {}", man.dir.display());
            println!(
                "{:>6} {:>8} {:>8} {:>8} {:>6} {:>6}",
                "name", "n", "r", "nz", "iters", "block"
            );
            for b in &man.buckets {
                println!(
                    "{:>6} {:>8} {:>8} {:>8} {:>6} {:>6}",
                    b.name, b.n, b.r, b.nz, b.iters, b.block
                );
            }
        }
        Err(e) => {
            eprintln!("no artifacts: {e} (run `make artifacts`)");
            std::process::exit(1);
        }
    }
}
