//! Allocation-phase policies (which processor *type* runs each task).
//!
//! The HLP/QHLP rounding allocations live in [`crate::lp::rounding`];
//! here are the greedy low-complexity rules of §4.2 plus the baselines:
//!
//! * **R1**: `p̄_j/m ≤ p̠_j/k` → CPU (load-normalized comparison)
//! * **R2**: `p̄_j/√m ≤ p̠_j/√k` → CPU (the rule inside ER-LS's Step 2)
//! * **R3**: `p̄_j ≤ p̠_j` → CPU (pure speed comparison)
//! * **Greedy**: fastest type (Q-generic; equals R3 for 2 types)
//! * **Random**: uniform type choice (Q-generic)

use crate::graph::TaskGraph;
use crate::platform::Platform;
use crate::substrate::rng::Rng;

pub type Allocation = Vec<usize>;

/// Rule R1 for one task: CPU iff `p̄/m ≤ p̠/k`.
pub fn r1_side(p_cpu: f64, p_gpu: f64, m: usize, k: usize) -> usize {
    usize::from(p_cpu / m as f64 > p_gpu / k as f64)
}

/// Rule R2 for one task: CPU iff `p̄/√m ≤ p̠/√k`.
pub fn r2_side(p_cpu: f64, p_gpu: f64, m: usize, k: usize) -> usize {
    usize::from(p_cpu / (m as f64).sqrt() > p_gpu / (k as f64).sqrt())
}

/// Rule R3 for one task: CPU iff `p̄ ≤ p̠`.
pub fn r3_side(p_cpu: f64, p_gpu: f64) -> usize {
    usize::from(p_cpu > p_gpu)
}

pub fn rule_r1(g: &TaskGraph, plat: &Platform) -> Allocation {
    assert_eq!(g.n_types(), 2);
    (0..g.n_tasks())
        .map(|j| r1_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k()))
        .collect()
}

pub fn rule_r2(g: &TaskGraph, plat: &Platform) -> Allocation {
    assert_eq!(g.n_types(), 2);
    (0..g.n_tasks())
        .map(|j| r2_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k()))
        .collect()
}

pub fn rule_r3(g: &TaskGraph, _plat: &Platform) -> Allocation {
    assert_eq!(g.n_types(), 2);
    (0..g.n_tasks())
        .map(|j| r3_side(g.p_cpu(j), g.p_gpu(j)))
        .collect()
}

/// Fastest-type allocation (the "Greedy" baseline of §6.3, Q-generic).
pub fn greedy_min_time(g: &TaskGraph) -> Allocation {
    (0..g.n_tasks())
        .map(|j| {
            (0..g.n_types())
                // total_cmp: same order as partial_cmp on the finite
                // times the builder enforces, but panic-free by design
                .min_by(|&a, &b| g.time_on(j, a).total_cmp(&g.time_on(j, b)))
                .unwrap()
        })
        .collect()
}

/// Uniform random type per task (the "Random" baseline of §6.3).
pub fn random_alloc(g: &TaskGraph, n_types: usize, rng: &mut Rng) -> Allocation {
    (0..g.n_tasks()).map(|_| rng.below(n_types)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;

    fn g2() -> TaskGraph {
        let mut b = Builder::new("g");
        b.add_task("fast-gpu", vec![10.0, 1.0]);
        b.add_task("fast-cpu", vec![1.0, 10.0]);
        b.add_task("mild-gpu", vec![3.0, 2.0]);
        b.build()
    }

    #[test]
    fn r3_pure_speed() {
        let g = g2();
        assert_eq!(rule_r3(&g, &Platform::hybrid(4, 1)), vec![1, 0, 1]);
    }

    #[test]
    fn r1_load_normalized() {
        let g = g2();
        // m=16, k=2: CPU iff p̄/16 <= p̠/2 i.e. p̄ <= 8 p̠
        // task0: 10 <= 8 -> false -> GPU; task2: 3 <= 16 -> CPU
        assert_eq!(rule_r1(&g, &Platform::hybrid(16, 2)), vec![1, 0, 0]);
    }

    #[test]
    fn r2_between_r1_and_r3() {
        let g = g2();
        // m=16,k=2: CPU iff p̄/4 <= p̠/1.414 i.e. p̄ <= 2.83 p̠
        // task2: 3 <= 5.66 -> CPU
        assert_eq!(rule_r2(&g, &Platform::hybrid(16, 2)), vec![1, 0, 0]);
        // m=16,k=16: R2 == R3
        assert_eq!(
            rule_r2(&g, &Platform::hybrid(16, 16)),
            rule_r3(&g, &Platform::hybrid(16, 16))
        );
    }

    #[test]
    fn greedy_is_argmin() {
        let mut b = Builder::new("q3");
        b.add_task("t", vec![3.0, 2.0, 1.0]);
        b.add_task("u", vec![1.0, 2.0, 3.0]);
        let g = b.build();
        assert_eq!(greedy_min_time(&g), vec![2, 0]);
    }

    #[test]
    fn random_alloc_in_range_and_deterministic() {
        let g = g2();
        let a = random_alloc(&g, 2, &mut Rng::new(4));
        let b = random_alloc(&g, 2, &mut Rng::new(4));
        assert_eq!(a, b);
        assert!(a.iter().all(|&q| q < 2));
    }
}
