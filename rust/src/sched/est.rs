//! The Earliest Starting Time policy (§3): given the allocation, at each
//! step schedule the ready task with the earliest possible starting time
//! (ties towards the smaller task id).  This is the scheduling phase of
//! HLP-EST (Kedad-Sidhoum et al.) and of its Q-type extension QHLP-EST.
//!
//! "Ready" here means all predecessors are already *scheduled* (their
//! completion times are known), matching the static EST construction.
//!
//! Engine-backed since the event-driven refactor: per-type unit trees
//! ([`engine::UnitTree`]) give the idle horizon and the unit pick in
//! O(log units), and the split arrived/pending ready queues
//! ([`engine::EstReady`]) make the global earliest-start selection
//! O(Q log n) per step — O((n + |E|) log n) per instance overall, versus
//! the O(n · (|ready| + units)) rescan of the retained reference
//! implementation ([`super::reference::est_schedule`]).  All event times
//! are [`engine::Tick`] counts, so starting-time comparisons are exact
//! integer compares — ties (equal ticks) resolve towards the smaller
//! task id, with no float band anywhere in the loop.  Both
//! implementations produce identical schedules (golden-parity suite,
//! including the repeated-cost-constant tie farms).

use crate::graph::{TaskGraph, TaskId};
use crate::obs::{DecisionEvent, EventKind, NoopSink, Sink};
use crate::platform::Platform;
use crate::sim::{Placement, Schedule};

use super::engine::{EstReady, Tick, UnitPool};

/// Schedule with a fixed allocation under the EST policy.
pub fn est_schedule(g: &TaskGraph, plat: &Platform, alloc: &[usize]) -> Schedule {
    est_schedule_traced(g, plat, alloc, &mut NoopSink)
}

/// [`est_schedule`] with an event sink: per decision, a ready-queue
/// depth sample plus the decision span (rule tag `est`, candidate
/// count, exact-tie cluster size).  With a [`NoopSink`] this *is*
/// `est_schedule` — the attribution bookkeeping never feeds the
/// comparator, and the parity suites pin the placements bitwise.
pub fn est_schedule_traced(
    g: &TaskGraph,
    plat: &Platform,
    alloc: &[usize],
    sink: &mut dyn Sink,
) -> Schedule {
    let n = g.n_tasks();
    assert_eq!(alloc.len(), n);
    let n_types = plat.n_types();
    debug_assert!(alloc.iter().all(|&q| q < n_types));

    let mut units = UnitPool::new(&plat.counts);
    let mut ready = EstReady::new(n_types);
    let mut remaining: Vec<usize> = g.preds.iter().map(|p| p.len()).collect();
    let mut ready_time = vec![Tick::ZERO; n];
    let mut placements: Vec<Option<Placement>> = vec![None; n];

    for j in 0..n {
        if remaining[j] == 0 {
            ready.push(alloc[j], Tick::ZERO, units.earliest_idle(alloc[j]), j);
        }
    }

    for _ in 0..n {
        // earliest (starting tick, id) over the per-type candidates:
        // exact integer comparison — a candidate wins outright when it
        // is strictly earlier, and equal ticks tie towards the smaller
        // task id, exactly `reference::est_schedule`'s comparator on
        // canonical times.
        let mut best: Option<(Tick, TaskId, usize)> = None; // (est, task, type)
        let mut candidates = 0usize;
        let mut tie_cluster = 1usize;
        for q in 0..n_types {
            if let Some((est, j)) = ready.peek(q, units.earliest_idle(q)) {
                // arrived tasks report the horizon; a task whose own
                // ready tick equals the horizon starts there too, so the
                // max is a no-op kept for clarity
                let est = est.max(ready_time[j]);
                candidates += 1;
                let better = match best {
                    None => true,
                    Some((b_est, b_j, _)) => {
                        // attribution bookkeeping only; the comparator
                        // below is the reference's, unchanged
                        if est < b_est {
                            tie_cluster = 1;
                        } else if est == b_est {
                            tie_cluster += 1;
                        }
                        est < b_est || (est == b_est && j < b_j)
                    }
                };
                if better {
                    best = Some((est, j, q));
                }
            }
        }
        // hetlint: allow(no-panic-in-hot-path) -- DAG acyclicity (Builder-checked) keeps the ready set non-empty until all tasks place
        let (est, j, q) = best.expect("ready set empty with tasks remaining");
        let popped = ready.pop(q);
        debug_assert_eq!(popped, Some(j));
        debug_assert_eq!(q, alloc[j]);

        // unit achieving the earliest start (min free tick, `min_by`
        // first-index tie-break)
        let unit = units.types[q].argmin_first();
        let start = est;
        let finish = start + Tick::quantize_cost(g.time_on(j, q));
        units.types[q].set(unit, finish);
        placements[j] = Some(Placement {
            ptype: q,
            unit,
            start: start.to_f64(),
            finish: finish.to_f64(),
        });
        if sink.enabled() {
            sink.emit(
                start.to_f64(),
                EventKind::Queue { scope: "est-ready", depth: ready.depth_total() },
            );
            sink.emit(
                start.to_f64(),
                EventKind::Decision(DecisionEvent {
                    tenant: 0,
                    task: j,
                    policy: "EST",
                    rule: "est",
                    candidates,
                    tie_cluster,
                    alternatives: Vec::new(),
                    restricted: Vec::new(),
                    ptype: q,
                    unit,
                    start: start.to_f64(),
                    finish: finish.to_f64(),
                }),
            );
        }
        // the horizon of type q may have advanced: promote pending tasks
        ready.promote(q, units.earliest_idle(q));

        for &s in &g.succs[j] {
            ready_time[s] = ready_time[s].max(finish);
            remaining[s] -= 1;
            if remaining[s] == 0 {
                let qs = alloc[s];
                ready.push(qs, ready_time[s], units.earliest_idle(qs), s);
            }
        }
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sched::reference;
    use crate::sim::validate;
    use crate::substrate::rng::Rng;

    #[test]
    fn est_picks_earliest_start() {
        // Two independent tasks, 1 CPU + 1 GPU; t0 CPU-allocated (busy
        // CPU), t1 GPU-allocated: both start at 0 on their own types.
        let mut b = Builder::new("x");
        b.add_task("a", vec![4.0, 1.0]);
        b.add_task("b", vec![1.0, 4.0]);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s = est_schedule(&g, &plat, &[0, 1]);
        validate(&g, &plat, &s).unwrap();
        assert_eq!(s.placements[0].start, 0.0);
        assert_eq!(s.placements[1].start, 0.0);
        assert!((s.makespan - 4.0).abs() < 1e-9);
    }

    #[test]
    fn est_orders_by_start_not_priority() {
        // chain a->b plus independent c, all CPU, 1 CPU:
        // EST schedules a (est 0), then c (est p_a vs ready b at p_a: tie
        // -> smaller id wins: b), order is a, b, c.
        let mut b = Builder::new("y");
        let a = b.add_task("a", vec![2.0, 9.0]);
        let t_b = b.add_task("b", vec![1.0, 9.0]);
        b.add_task("c", vec![1.0, 9.0]);
        b.add_arc(a, t_b);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s = est_schedule(&g, &plat, &[0, 0, 0]);
        validate(&g, &plat, &s).unwrap();
        assert!(s.placements[1].start < s.placements[2].start);
    }

    #[test]
    fn est_valid_on_random_hybrid_dags() {
        let mut rng = Rng::new(21);
        for _ in 0..15 {
            let g = gen::hybrid_dag(&mut rng, 50, 0.1);
            let plat = Platform::hybrid(4, 2);
            let alloc: Vec<usize> =
                (0..50).map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j))).collect();
            let s = est_schedule(&g, &plat, &alloc);
            validate(&g, &plat, &s).unwrap();
            assert_eq!(s.allocation(), alloc);
        }
    }

    #[test]
    fn est_three_types() {
        let mut b = Builder::new("q3");
        for j in 0..6 {
            b.add_task("t", vec![3.0, 2.0, 1.0 + j as f64]);
        }
        let g = b.build();
        let plat = Platform::new(vec![2, 2, 2]);
        let alloc = vec![0, 0, 1, 1, 2, 2];
        let s = est_schedule(&g, &plat, &alloc);
        validate(&g, &plat, &s).unwrap();
    }

    #[test]
    fn traced_est_matches_untraced() {
        use crate::obs::{EventKind, RecordingSink};
        let mut rng = Rng::new(17);
        let g = gen::hybrid_dag(&mut rng, 50, 0.1);
        let plat = Platform::hybrid(4, 2);
        let alloc: Vec<usize> = (0..50).map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j))).collect();
        let plain = est_schedule(&g, &plat, &alloc);
        let mut sink = RecordingSink::new();
        let traced = est_schedule_traced(&g, &plat, &alloc, &mut sink);
        assert_eq!(plain.placements, traced.placements);
        let events = sink.take();
        let decisions = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Decision(_)))
            .count();
        let depths = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Queue { .. }))
            .count();
        assert_eq!((decisions, depths), (50, 50), "one span + one sample per task");
    }

    #[test]
    fn est_engine_matches_reference_inline() {
        // quick in-module parity check; the full 50+-instance sweep
        // lives in rust/tests/golden_parity.rs
        let mut rng = Rng::new(99);
        for _ in 0..8 {
            let g = gen::hybrid_dag(&mut rng, 60, 0.08);
            let plat = Platform::hybrid(5, 3);
            let alloc: Vec<usize> =
                (0..60).map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j))).collect();
            let a = est_schedule(&g, &plat, &alloc);
            let b = reference::est_schedule(&g, &plat, &alloc);
            assert_eq!(a.placements, b.placements);
        }
    }
}
