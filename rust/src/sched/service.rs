//! Multi-tenant streaming service mode: many task graphs arriving over
//! (virtual) time into one shared unit pool.
//!
//! # Mapping to the paper's on-line model (§4.2, §6.3, §7)
//!
//! The paper's on-line setting assumes tasks arrive in a
//! precedence-respecting stream and the scheduler takes an *irrevocable*
//! (processor, start-time) decision at each arrival.  That regime is
//! exactly a shared-cluster service: applications (tenants) submit DAGs
//! over time, and a StarPU-like runtime multiplexes them over one
//! CPU/GPU pool.  This module grows the single-DAG engine of
//! [`super::online`] into that service:
//!
//! * A [`Submission`] is one tenant's application: a [`TaskGraph`], an
//!   arrival time, and the online policy (ER-LS / EFT / Greedy / …)
//!   taking its decisions.  Each tenant keeps its own
//!   precedence-respecting arrival order (task-id order by default, as
//!   our generators emit ids topologically).
//! * Tasks of tenant *i* arrive as a stream: task at stream position
//!   `p` arrives at `a_p = max(arrival_i, a_{p-1}, r_p)` where
//!   `r_p = max(arrival_i, max_pred C)` — a task is submitted once its
//!   predecessors complete, and never before the tenant's earlier
//!   submissions (the stream is sequential, as in the paper's model
//!   where the arrival order extends the precedence order).
//! * A global completion-driven event loop merges the tenant streams by
//!   arrival time (ties: tenant id, then stream position) and feeds each
//!   arrival to the shared [`PolicyEngine`] over one
//!   [`engine::UnitPool`](super::engine::UnitPool).  Decisions are
//!   irrevocable: the chosen unit is reserved until the task's finish.
//!
//! Because each tenant's decisions happen in its own stream order with
//! the pool state observed at arrival, a *single*-tenant service run
//! takes exactly the decisions of [`online_schedule`] — golden parity,
//! pinned by tests.  Under contention the same policies now see a pool
//! warmed by other tenants, which is the irrevocable-multiplexing regime
//! the survey literature (Beaumont et al. 2019) describes for hybrid
//! runtimes.
//!
//! Per-tenant metrics follow the service-scheduling literature: *flow
//! time* (completion − arrival), *stretch* (flow time over the tenant's
//! ideal single-tenant makespan under the same policy on an empty pool),
//! and decision latency.  The aggregate [`ServiceReport`] adds the
//! horizon, utilization, and stretch summaries that
//! `examples/service_mode.rs` and `benches/service_throughput.rs`
//! report.
//!
//! The loop is reified as [`Service`] (new/step/run/cancel/report):
//! [`Service::cancel`] removes a tenant mid-stream, releasing its
//! not-yet-started unit reservations back to the pool via
//! [`UnitPool::release`](super::engine::UnitPool::release) and reporting
//! the tenant's partial metrics, while every survivor's schedule stays
//! feasible (invariant tests).  [`run_service`] is the drained
//! one-call form.
//!
//! Admission control and fairness live one layer above the per-task
//! decision rules, in [`policy`]: each [`Submission`] carries a
//! [`TenantPolicy`] ([`Submission::with_admission`]) — FIFO (the golden
//! baseline, bit-identical to the pre-policy path pinned against
//! [`reference::run_service`](super::reference::run_service)), hard
//! per-type held-units quotas enforced at the
//! [`PolicyEngine`]/[`UnitPool`](super::engine::UnitPool) reservation
//! boundary, or weighted-stretch reordering of admissions inside
//! fully-busy pool windows.  The [`ServiceReport`] carries the fairness
//! aggregates (max/p99 stretch, Jain's index over
//! [`ServiceReport::completed_stretches`]) the policy comparison tables
//! report.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, BTreeMap};

use crate::graph::{TaskGraph, TaskId};
use crate::obs::{Event, EventKind, Metrics, RecordingSink, Sink};
use crate::platform::Platform;
use crate::sim::{Placement, Schedule, TenantRun};
use crate::substrate::rng::Rng;
use crate::substrate::stats::{percentile, Summary};

use super::online::{online_schedule, requires_two_types, OnlinePolicy, PolicyEngine, UnitSet};
use super::OrdF64;

/// Tie band for weighted-stretch leapfrog *keys* — raw float ratios
/// (`weight × elapsed / ideal`), not event times, so they live outside
/// the tick clock and keep a small band: key ties within ±1e-12 keep
/// the FIFO (time, tenant, position) order.
const WS_KEY_BAND: f64 = 1e-12;

pub mod policy;
pub mod shard;

pub use policy::TenantPolicy;
pub use shard::ShardedService;

/// One tenant's application entering the service.
#[derive(Clone, Debug)]
pub struct Submission {
    pub graph: TaskGraph,
    /// Virtual time at which the tenant submits the application; no task
    /// of the tenant may start before it.
    pub arrival: f64,
    /// The online policy taking this tenant's irrevocable decisions.
    pub policy: OnlinePolicy,
    /// The admission-control policy governing this tenant's share of the
    /// pool (defaults to [`TenantPolicy::Fifo`], today's behavior).
    pub admission: TenantPolicy,
    /// Precedence-respecting arrival order of the tenant's tasks
    /// (defaults to task-id order, which our generators emit
    /// topologically).
    order: Option<Vec<TaskId>>,
}

impl Submission {
    pub fn new(graph: TaskGraph, arrival: f64, policy: OnlinePolicy) -> Submission {
        assert!(arrival.is_finite() && arrival >= 0.0, "bad arrival {arrival}");
        Submission {
            graph,
            arrival,
            policy,
            admission: TenantPolicy::Fifo,
            order: None,
        }
    }

    /// Use a custom (topological) arrival order for this tenant.
    pub fn with_order(mut self, order: Vec<TaskId>) -> Submission {
        assert_eq!(order.len(), self.graph.n_tasks(), "order must cover all tasks");
        self.order = Some(order);
        self
    }

    /// Set this tenant's admission-control policy (see [`policy`]).
    pub fn with_admission(mut self, admission: TenantPolicy) -> Submission {
        self.admission = admission;
        self
    }

    pub(crate) fn order_vec(&self) -> Vec<TaskId> {
        self.order
            .clone()
            .unwrap_or_else(|| (0..self.graph.n_tasks()).collect())
    }
}

/// One irrevocable decision, in global decision order: tenant `tenant`'s
/// task `task` arrived (and was placed) at virtual time `time`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecisionRecord {
    pub tenant: usize,
    pub task: TaskId,
    pub time: f64,
}

/// Per-tenant outcome.
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub tenant: usize,
    pub app: String,
    pub n_tasks: usize,
    /// Tasks that actually ran (equals `n_tasks` unless cancelled).
    pub n_placed: usize,
    pub arrival: f64,
    /// Virtual time the tenant's last (kept) task finishes.
    pub completion: f64,
    /// completion − arrival.
    pub flow_time: f64,
    /// Makespan of the same (graph, order, policy) on an empty pool.
    pub ideal_makespan: f64,
    /// flow_time / ideal_makespan (1.0 = no slowdown from contention).
    /// Partial (an underestimate) for cancelled tenants.
    pub stretch: f64,
    /// Wall-clock seconds per irrevocable decision, measured *only* at
    /// a runtime edge ([`Service::note_decision_latency`] — the daemon
    /// or live coordinator).  Batch/replay runs leave it empty; the
    /// core never reads the clock.
    pub decision_latency: Summary,
    /// The tenant's placements (absolute virtual times on the shared
    /// pool).  For a cancelled tenant this holds only the kept tasks in
    /// task-id order, so it is *not* graph-aligned — consumers must
    /// check `cancelled_at` (see [`ServiceReport::tenant_runs`]) and can
    /// map entries back to task ids through `kept_tasks`.
    pub schedule: Schedule,
    /// Task ids of `schedule.placements`, in order (simply `0..n_tasks`
    /// for a tenant that was not cancelled).
    pub kept_tasks: Vec<TaskId>,
    /// Virtual time at which [`Service::cancel`] hit this tenant.
    pub cancelled_at: Option<f64>,
}

/// Aggregate outcome of one service run.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub tenants: Vec<TenantReport>,
    /// Every decision in global order (drives the live coordinator).
    pub decisions: Vec<DecisionRecord>,
    /// Virtual time the last task of any tenant finishes.
    pub horizon: f64,
    pub total_tasks: usize,
    pub mean_stretch: f64,
    pub max_stretch: f64,
    /// 99th-percentile stretch over completed tenants (the fairness
    /// tail the admission policies are compared on).
    pub stretch_p99: f64,
    /// Jain's fairness index over completed tenants' stretches —
    /// (Σs)²/(n·Σs²) ∈ (0, 1], 1 when every tenant is slowed equally.
    pub jain_index: f64,
    /// Busy fraction per type over [0, horizon).
    pub utilization: Vec<f64>,
    /// Decision-rule attribution (tag → count), sorted by tag.  A pure
    /// function of the op stream — replay-stable, safe for the wire
    /// report's byte-for-byte replay==rerun comparison.
    pub rule_counts: Vec<(String, u64)>,
    /// Decisions taken under a non-trivial quota restriction
    /// (replay-stable, like `rule_counts`).
    pub restricted_decisions: u64,
}

impl ServiceReport {
    /// Stretches of the tenants that ran to completion, the *single*
    /// source for every stretch aggregate (mean/max/p99/Jain) in and
    /// around this report.  A cancelled tenant's partial stretch is an
    /// underestimate of its contention (its tail never ran), so mixing
    /// it into percentiles would understate unfairness — consumers that
    /// previously folded `tenants` directly into their own aggregates
    /// should use this helper instead.
    pub fn completed_stretches(&self) -> Vec<f64> {
        self.tenants
            .iter()
            .filter(|t| t.cancelled_at.is_none())
            .map(|t| t.stretch)
            .collect()
    }

    /// Pair each tenant's schedule with its submission for the
    /// tenant-aware merge validator
    /// ([`validate_service`](crate::sim::validate_service)).  Cancelled
    /// tenants are skipped — their kept-task schedules are not
    /// graph-aligned (validate those with a manual overlap check, as the
    /// cancellation tests do).
    pub fn tenant_runs<'a>(&'a self, subs: &'a [Submission]) -> Vec<TenantRun<'a>> {
        assert_eq!(subs.len(), self.tenants.len());
        subs.iter()
            .zip(&self.tenants)
            .filter(|(_, t)| t.cancelled_at.is_none())
            .map(|(s, t)| TenantRun {
                graph: &s.graph,
                schedule: &t.schedule,
                arrival: s.arrival,
            })
            .collect()
    }
}

/// ready = max(tenant arrival, predecessors' completions); a task's
/// predecessors are all decided by the time this runs because the order
/// is topological and each tenant's stream is processed strictly in
/// order (non-topological orders panic here).
fn ready_time(
    g: &TaskGraph,
    arrival: f64,
    placed: &[Option<Placement>],
    tenant: usize,
    j: TaskId,
) -> f64 {
    g.preds[j]
        .iter()
        .map(|&p| {
            placed[p]
                .unwrap_or_else(|| panic!("tenant {tenant}: order not topological at task {j}"))
                .finish
        })
        .fold(arrival, f64::max)
}

/// One unit reservation in decision order (the cancellation ledger):
/// enough to rewind trailing reservations of a cancelled tenant.
#[derive(Clone, Copy, Debug)]
struct Reservation {
    tenant: usize,
    task: TaskId,
    /// the unit's free time before this reservation (rewind target)
    prev_free: f64,
    start: f64,
}

/// Outcome of a [`Service::cancel`] call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CancelOutcome {
    pub tenant: usize,
    /// Virtual time the cancellation took effect.
    pub at: f64,
    /// Placed-but-not-yet-started tasks whose reservations were rewound.
    pub dropped_tasks: usize,
    /// Units whose free time was rewound via `UnitPool::release`.
    pub released_units: usize,
}

/// The reified multi-tenant streaming scheduler: [`run_service`] drained
/// in one call is the common case, but the struct form lets a caller
/// step the stream ([`Self::step`]) and cancel tenants mid-stream
/// ([`Self::cancel`]).
///
/// Cancellation semantics: at the current virtual time `t` (the last
/// processed arrival), the tenant's pending stream entry is dropped, and
/// its placed tasks that have not started by `t` are rewound — each
/// unit's *trailing* reservations belonging to the tenant are popped and
/// the unit's free time released to what it was before them
/// ([`super::engine::UnitPool::release`]).  Tasks already running at `t`
/// finish (decisions are irrevocable and the work is half done), and a
/// cancelled reservation buried under another tenant's later reservation
/// stays blocked — the later decision was taken on top of it and is
/// itself irrevocable.  Dropping then *cascades* within the tenant:
/// every kept task depending on a dropped one is dropped too (buried
/// ones leave an unused gap on their unit), so the reported partial
/// schedule never contains a task whose predecessor did not run.
/// Survivors' schedules remain feasible either way (pinned by the
/// invariant tests).
///
/// The struct owns clones of the platform and submissions so a daemon
/// ([`crate::service_net`]) can keep one `Service` alive across client
/// connections and admit tenants incrementally ([`Self::admit`]): the
/// batch constructors clone their slices, which keeps every existing
/// `Service::new(&plat, &subs)` call site source-compatible.
pub struct Service {
    plat: Platform,
    subs: Vec<Submission>,
    orders: Vec<Vec<TaskId>>,
    engine: PolicyEngine,
    rngs: Vec<Option<Rng>>,
    placements: Vec<Vec<Option<Placement>>>,
    latencies: Vec<Vec<f64>>,
    decisions: Vec<DecisionRecord>,
    // Stream heap: (arrival time, tenant, stream position, ready time).
    // One outstanding arrival per tenant keeps the heap at O(tenants),
    // and carrying the ready time computes each task's fold exactly once.
    heap: BinaryHeap<Reverse<(OrdF64, usize, usize, OrdF64)>>,
    /// per (type, unit): reservation stack in decision order
    ledger: Vec<Vec<Vec<Reservation>>>,
    cancelled: Vec<Option<f64>>,
    /// virtual time of the last processed arrival
    now: f64,
    /// per tenant: per-type held-unit caps (quota tenants only)
    caps: Vec<Option<Vec<usize>>>,
    /// per tenant per type: unit → latest outstanding finish — the
    /// held-units ledger the quota caps are enforced on (empty vec for
    /// tenants without a quota)
    held: Vec<Vec<BTreeMap<usize, f64>>>,
    /// per tenant: weighted-stretch reordering weight
    weights: Vec<Option<f64>>,
    /// per tenant: ideal single-tenant makespan (NaN unless the tenant
    /// is weighted-stretch; the reordering key needs it up front)
    ws_ideals: Vec<f64>,
    any_ws: bool,
    /// event sink — `None` (tracing off, the default) behaves as a
    /// [`NoopSink`](crate::obs::NoopSink); the daemon's `--trace-out`
    /// switches it on via [`Self::enable_trace`].  Never read by any
    /// decision (pinned bitwise by the `obs_parity` suite).
    trace: Option<RecordingSink>,
    /// always-on decision attribution: rule tag → count.  Replay-stable
    /// (a pure function of the op stream — no clock anywhere near it),
    /// so it may surface in the wire report.
    rule_counts: BTreeMap<&'static str, u64>,
    /// decisions taken under a quota-restricted (`Only`/`Banned`) set
    restricted_decisions: u64,
    /// weighted-stretch leapfrogs: busy-window admissions that bypassed
    /// the FIFO head
    leapfrogs: u64,
}

/// Non-panicking form of the submission checks [`Service::new`]
/// enforces; the daemon surface turns these into error responses
/// instead of crashing the accept loop.
pub fn validate_submission(plat: &Platform, s: &Submission) -> Result<(), String> {
    if s.graph.n_tasks() == 0 {
        return Err("empty submission".into());
    }
    // re-checked here because the fields are public (Submission::new
    // validates, but nothing stops callers mutating afterwards)
    if !(s.arrival.is_finite() && s.arrival >= 0.0) {
        return Err(format!("bad arrival {}", s.arrival));
    }
    if requires_two_types(&s.policy) && plat.n_types() != 2 {
        return Err(format!("{} is defined for hybrid platforms", s.policy.name()));
    }
    if s.graph.n_types() != plat.n_types() {
        return Err(format!(
            "graph/platform type count mismatch ({} vs {})",
            s.graph.n_types(),
            plat.n_types()
        ));
    }
    if let Some(ord) = &s.order {
        if ord.len() != s.graph.n_tasks() {
            return Err("order must cover all tasks".into());
        }
    }
    s.admission.try_validate(plat)
}

impl Service {
    pub fn new(plat: &Platform, subs: &[Submission]) -> Service {
        Service::new_with_ideals(plat, subs, None)
    }

    /// [`Service::new`] with precomputed per-tenant ideal makespans (one
    /// per submission, as in [`run_service_with_ideals`]) so
    /// weighted-stretch tenants do not trigger a single-tenant rerun
    /// here.  `None` computes them for the tenants that need one.
    pub fn new_with_ideals(
        plat: &Platform,
        subs: &[Submission],
        ideals: Option<&[f64]>,
    ) -> Service {
        if let Some(v) = ideals {
            assert_eq!(v.len(), subs.len(), "one ideal makespan per submission");
        }
        let mut svc = Service::empty(plat);
        for (i, s) in subs.iter().enumerate() {
            validate_submission(plat, s).unwrap_or_else(|e| panic!("{e}"));
            svc.push_tenant(s.clone(), ideals.map(|v| v[i]));
        }
        svc
    }

    /// A service with no tenants yet: the daemon form.  Tenants then
    /// enter through [`Self::admit`]; batch construction
    /// ([`Self::new`]) is exactly `empty` + one `push_tenant` per
    /// submission with no stream advancement in between, so the two
    /// paths share every invariant.
    pub fn empty(plat: &Platform) -> Service {
        Service {
            plat: plat.clone(),
            subs: Vec::new(),
            orders: Vec::new(),
            engine: PolicyEngine::new(plat),
            rngs: Vec::new(),
            placements: Vec::new(),
            latencies: Vec::new(),
            decisions: Vec::new(),
            heap: BinaryHeap::new(),
            ledger: plat
                .counts
                .iter()
                .map(|&c| (0..c).map(|_| Vec::new()).collect())
                .collect(),
            cancelled: Vec::new(),
            now: 0.0,
            caps: Vec::new(),
            held: Vec::new(),
            weights: Vec::new(),
            ws_ideals: Vec::new(),
            any_ws: false,
            trace: None,
            rule_counts: BTreeMap::new(),
            restricted_decisions: 0,
            leapfrogs: 0,
        }
    }

    /// Append one (already-validated) tenant and push its first stream
    /// head; no existing head is disturbed.  `ideal` as in
    /// [`Self::new_with_ideals`] (only read for weighted-stretch
    /// tenants).
    fn push_tenant(&mut self, sub: Submission, ideal: Option<f64>) -> usize {
        let i = self.subs.len();
        let order = sub.order_vec();
        let placed: Vec<Option<Placement>> = vec![None; sub.graph.n_tasks()];
        let r0 = ready_time(&sub.graph, sub.arrival, &placed, i, order[0]);
        self.heap
            .push(Reverse((OrdF64(sub.arrival.max(r0)), i, 0, OrdF64(r0))));
        let weight = sub.admission.weight();
        self.any_ws |= weight.is_some();
        self.ws_ideals.push(if weight.is_none() {
            f64::NAN
        } else if let Some(v) = ideal {
            v
        } else {
            online_schedule(&sub.graph, &self.plat, &order, &sub.policy).makespan
        });
        let caps = sub.admission.caps(&self.plat);
        self.held.push(match caps {
            Some(_) => self.plat.counts.iter().map(|_| BTreeMap::new()).collect(),
            None => Vec::new(),
        });
        self.caps.push(caps);
        self.weights.push(weight);
        self.rngs.push(match sub.policy {
            OnlinePolicy::Random(seed) => Some(Rng::new(seed)),
            _ => None,
        });
        self.latencies.push(Vec::with_capacity(sub.graph.n_tasks()));
        self.placements.push(placed);
        self.cancelled.push(None);
        self.orders.push(order);
        self.subs.push(sub);
        i
    }

    /// Admit one tenant into a live stream (the daemon path) and return
    /// its tenant id.  The effective arrival is
    /// `max(sub.arrival, now)` — decisions already taken are
    /// irrevocable, so an arrival cannot land in the scheduler's past —
    /// and every pending head strictly earlier than it is decided first
    /// ([`Self::advance_before`]): those arrivals precede the new one in
    /// the merged stream and their decisions must not see the new
    /// tenant.  For FIFO/quota submissions with non-decreasing arrivals
    /// this makes the incremental stream bit-identical to the batch
    /// [`run_service`] over the same submissions (pinned by tests).
    /// Weighted-stretch tenants are the documented exception: the batch
    /// path can let a *future* arrival leapfrog inside a busy window
    /// ([`Self::next_head`]), while a live service cannot see arrivals
    /// that have not been submitted yet — incremental admission is the
    /// online-correct behavior, and replay == rerun (re-applying the
    /// same admit sequence) holds for every policy mix either way.
    ///
    /// Returns `Err` (with the service untouched) on an invalid
    /// submission.
    pub fn admit(&mut self, sub: Submission) -> Result<usize, String> {
        validate_submission(&self.plat, &sub)?;
        let mut sub = sub;
        sub.arrival = sub.arrival.max(self.now);
        self.advance_before(sub.arrival);
        Ok(self.push_tenant(sub, None))
    }

    /// Admit a batch of tenants, advancing the stream once per distinct
    /// arrival window instead of once per submission: consecutive
    /// submissions sharing an arrival time are grouped, and the heap is
    /// only drained up to each window's start.  Bit-identical to calling
    /// [`Self::admit`] per submission in the same order (within one
    /// window the repeated `advance_before` calls are no-ops and the
    /// clamp `max(arrival, now)` is unchanged by earlier same-window
    /// pushes — `now` never advances past the window while admitting
    /// into it); pinned by the `service_shard` batching-parity test.
    ///
    /// All submissions are validated up front: on `Err` the service is
    /// untouched (no partial batch).
    pub fn admit_batch(&mut self, subs: Vec<Submission>) -> Result<Vec<usize>, String> {
        for s in &subs {
            validate_submission(&self.plat, s)?;
        }
        let mut ids = Vec::with_capacity(subs.len());
        let mut window: Option<f64> = None;
        for mut sub in subs {
            let raw = sub.arrival;
            if window != Some(raw) {
                window = Some(raw);
                self.advance_before(raw.max(self.now));
            }
            sub.arrival = raw.max(self.now);
            ids.push(self.push_tenant(sub, None));
        }
        Ok(ids)
    }

    /// Decide every pending stream head with arrival time strictly
    /// before `t` (the merged-stream prefix that is already in the past
    /// once an event at `t` is known).
    pub fn advance_before(&mut self, t: f64) {
        while let Some(&Reverse((OrdF64(head), _, _, _))) = self.heap.peek() {
            if head >= t {
                break;
            }
            self.step();
        }
    }

    /// True once every admitted task has been decided (the stream is
    /// drained and [`Self::report`] may be called).
    pub fn is_drained(&self) -> bool {
        self.heap.is_empty()
    }

    /// Number of tenants admitted so far.
    pub fn n_tenants(&self) -> usize {
        self.subs.len()
    }

    /// Every decision so far, in global decision order.
    pub fn decisions(&self) -> &[DecisionRecord] {
        &self.decisions
    }

    /// The placement of tenant `i`'s task `j`, if decided (and not
    /// rewound by a cancellation).
    pub fn placement_of(&self, i: usize, j: TaskId) -> Option<Placement> {
        self.placements[i][j]
    }

    /// Tasks of tenant `i` placed so far (post-cancellation rewinds).
    pub fn n_placed(&self, i: usize) -> usize {
        self.placements[i].iter().flatten().count()
    }

    /// Virtual time at which tenant `i` was cancelled, if it was.
    pub fn cancelled_at(&self, i: usize) -> Option<f64> {
        self.cancelled[i]
    }

    /// The admitted submissions (arrivals are the effective,
    /// possibly-clamped ones for tenants that entered via
    /// [`Self::admit`]).
    pub fn submissions(&self) -> &[Submission] {
        &self.subs
    }

    /// Pop the next head to admit.  Pure-FIFO/quota services take the
    /// merged stream strictly in arrival order (the pre-policy path).
    /// With weighted-stretch tenants present, a head entering a *fully
    /// busy* pool window may be leapfrogged: every unit's free time lies
    /// beyond the head's arrival, so any competing head inside the
    /// window would start no earlier than the window's end anyway, and
    /// the service is free to admit the most-behind tenant first — the
    /// one maximizing `weight · (t − arrival) / ideal makespan`.  Heads
    /// of FIFO/quota tenants are barriers: they are never bypassed, so
    /// mixing policies keeps their arrival-order guarantee intact.  With
    /// an idle unit anywhere (in particular for a single tenant on an
    /// empty pool, or with no contention) the window is empty and the
    /// order is exactly FIFO.
    fn next_head(&mut self) -> Option<Reverse<(OrdF64, usize, usize, OrdF64)>> {
        let first = self.heap.pop()?;
        if !self.any_ws {
            return Some(first);
        }
        let Reverse((OrdF64(t0), i0, _, _)) = first;
        if self.weights[i0].is_none() {
            return Some(first);
        }
        // the pool's global idle horizon: an idle unit by t0 means the
        // pool is not saturated, and FIFO order stands
        let tau = (0..self.plat.n_types())
            .map(|q| self.engine.pool().earliest_idle(q).to_f64())
            .fold(f64::INFINITY, f64::min);
        if tau <= t0 {
            return Some(first);
        }
        // collect the weighted-stretch heads inside the busy window
        // [t0, tau]; stop at the first FIFO/quota head (a barrier)
        let mut cands = vec![first];
        while let Some(&Reverse((OrdF64(t), i, _, _))) = self.heap.peek() {
            if t > tau || self.weights[i].is_none() {
                break;
            }
            cands.push(self.heap.pop().unwrap());
        }
        if cands.len() == 1 {
            return cands.pop();
        }
        // admit the most-behind tenant first; everyone's stretch is
        // evaluated at the window head so the comparison is common-time,
        // and band ties keep the FIFO (time, tenant, position) order
        let t_eval = t0.max(self.now);
        let mut best = 0usize;
        let mut best_key = f64::NEG_INFINITY;
        for (idx, &Reverse((_, i, _, _))) in cands.iter().enumerate() {
            // elapsed flow clamps at 0 (a head can sit in the window
            // before its tenant's arrival-relative clock started)
            let key = self.weights[i].expect("only weighted-stretch heads compete")
                * (t_eval - self.subs[i].arrival).max(0.0)
                / self.ws_ideals[i];
            if idx == 0 || key > best_key + WS_KEY_BAND {
                best = idx;
                best_key = key;
            }
        }
        if best != 0 {
            self.leapfrogs += 1;
        }
        let chosen = cands.swap_remove(best);
        for c in cands {
            self.heap.push(c);
        }
        Some(chosen)
    }

    /// Process the next arrival in the merged stream; `None` once the
    /// stream is drained.
    pub fn step(&mut self) -> Option<DecisionRecord> {
        let Reverse((OrdF64(at), i, pos, OrdF64(ready))) = self.next_head()?;
        debug_assert!(self.cancelled[i].is_none(), "cancelled tenant left in stream");
        let g = &self.subs[i].graph;
        let j = self.orders[i][pos];
        debug_assert!(
            self.placements[i][j].is_none(),
            "tenant {i}: task {j} decided twice"
        );
        debug_assert!(at >= ready, "stream time regressed");
        // a leapfrogged head's admission happens at the preemptor's
        // (later) time; for FIFO/quota heads `at >= self.now` always, so
        // this is exactly the old `self.now = at`
        let at = at.max(self.now);
        self.now = at;

        if self.trace.enabled() {
            // depth of the merged stream heap at this decision (the
            // popped head counts itself back in)
            let depth = self.heap.len() + 1;
            self.trace.emit(at, EventKind::Queue { scope: "stream-heap", depth });
        }
        let (p, dtrace) = match &self.caps[i] {
            None => self.engine.decide_in_traced(
                g,
                &self.plat,
                j,
                ready,
                &self.subs[i].policy,
                self.rngs[i].as_mut(),
                &[],
                i,
                &mut self.trace,
            ),
            Some(caps) => {
                // quota path: expire finished reservations from the
                // held-units ledger at the admission time, then restrict
                // the decision to what the caps leave open
                for m in self.held[i].iter_mut() {
                    m.retain(|_, f| *f > at);
                }
                // the held-units key list is only materialized for
                // types actually AT their cap (the off-cap common case
                // stays allocation-light on this hot path)
                let held_units: Vec<Vec<usize>> = self.held[i]
                    .iter()
                    .zip(caps)
                    .map(|(m, &cap)| {
                        if cap != 0 && m.len() >= cap {
                            m.keys().copied().collect()
                        } else {
                            Vec::new()
                        }
                    })
                    .collect();
                let sets: Vec<UnitSet> = caps
                    .iter()
                    .enumerate()
                    .map(|(q, &cap)| {
                        if cap == 0 {
                            UnitSet::Banned
                        } else if self.held[i][q].len() < cap {
                            UnitSet::All
                        } else {
                            UnitSet::Only(&held_units[q])
                        }
                    })
                    .collect();
                if sets.iter().any(|s| !matches!(s, UnitSet::All)) {
                    self.restricted_decisions += 1;
                }
                let (p, dtrace) = self.engine.decide_in_traced(
                    g,
                    &self.plat,
                    j,
                    ready,
                    &self.subs[i].policy,
                    self.rngs[i].as_mut(),
                    &sets,
                    i,
                    &mut self.trace,
                );
                let entry = self.held[i][p.ptype].entry(p.unit).or_insert(p.finish);
                if p.finish > *entry {
                    *entry = p.finish;
                }
                debug_assert!(
                    self.held[i][p.ptype].len() <= caps[p.ptype],
                    "tenant {i}: quota exceeded on type {}",
                    p.ptype
                );
                (p, dtrace)
            }
        };
        *self.rule_counts.entry(dtrace.rule).or_insert(0) += 1;
        // the unit's free time before this reservation: the ledger
        // mirrors every reserve/release on the pool, so it is the last
        // entry's finish (or 0) — recorded for exact rewinds on cancel
        let prev_free = self.ledger[p.ptype][p.unit]
            .last()
            .map(|r| {
                self.placements[r.tenant][r.task]
                    .expect("ledger entries are placed")
                    .finish
            })
            .unwrap_or(0.0);
        self.ledger[p.ptype][p.unit].push(Reservation {
            tenant: i,
            task: j,
            prev_free,
            start: p.start,
        });
        self.placements[i][j] = Some(p);
        let record = DecisionRecord {
            tenant: i,
            task: j,
            time: at,
        };
        self.decisions.push(record);

        if pos + 1 < self.orders[i].len() {
            let r_next = ready_time(
                g,
                self.subs[i].arrival,
                &self.placements[i],
                i,
                self.orders[i][pos + 1],
            );
            self.heap
                .push(Reverse((OrdF64(at.max(r_next)), i, pos + 1, OrdF64(r_next))));
        }
        Some(record)
    }

    /// Drain the stream.
    pub fn run(&mut self) {
        while self.step().is_some() {}
    }

    /// Virtual time of the last processed arrival.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Switch on event recording (the daemon's `--trace-out` path).
    /// Idempotent; recording never influences a decision (pinned
    /// bitwise by the `obs_parity` suite).
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            self.trace = Some(RecordingSink::new());
        }
    }

    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some()
    }

    /// Drain the recorded events (empty when tracing is off).  Sequence
    /// numbers stay globally monotone across drains, so a streaming
    /// JSONL writer can call this after every op.
    pub fn take_trace(&mut self) -> Vec<Event> {
        self.trace.as_mut().map(RecordingSink::take).unwrap_or_default()
    }

    /// Emit a daemon-edge event (e.g. WAL append/fsync byte counts)
    /// into the trace stream at the current virtual time.  A no-op when
    /// tracing is off.  Edge events share the core's globally monotone
    /// sequence, so one JSONL stream interleaves both deterministically
    /// — and because byte counts are a pure function of the op stream,
    /// the interleaved trace is still byte-identical across runs.
    pub fn trace_edge(&mut self, kind: EventKind) {
        let now = self.now;
        self.trace.emit(now, kind);
    }

    /// Record one decision's wall-clock latency, measured at the daemon
    /// edge (`service_net`, where the clock is allowlisted) and
    /// attributed to `tenant`.  The core itself never reads the clock —
    /// hetlint R4 holds with zero suppressions in this file — and the
    /// recorded values feed only [`TenantReport::decision_latency`],
    /// never a placement (pinned by
    /// `service_fairness::latency_metric_never_feeds_placement`).
    /// Out-of-range tenants are ignored (the edge may race a
    /// cancellation).
    pub fn note_decision_latency(&mut self, tenant: usize, secs: f64) {
        if let Some(v) = self.latencies.get_mut(tenant) {
            v.push(secs);
        }
    }

    /// Always-on observability counters as a [`Metrics`] snapshot.
    /// Every value is a pure function of the op stream (no clock), so
    /// the registry is identical after a WAL replay.
    pub fn metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        m.add("svc_decisions", self.decisions.len() as u64);
        m.add("svc_tenants", self.subs.len() as u64);
        m.add(
            "svc_cancelled_tenants",
            self.cancelled.iter().filter(|c| c.is_some()).count() as u64,
        );
        m.add("svc_restricted_decisions", self.restricted_decisions);
        m.add("svc_leapfrogs", self.leapfrogs);
        for (rule, n) in &self.rule_counts {
            m.add(&format!("svc_rule_{rule}"), *n);
        }
        if let Some(t) = &self.trace {
            m.add("svc_trace_events", t.emitted());
        }
        m
    }

    /// Always-on rule attribution (tag → decisions taken through that
    /// rule path) — the replay-stable summary the wire report carries.
    pub fn rule_counts(&self) -> &BTreeMap<&'static str, u64> {
        &self.rule_counts
    }

    /// Decisions taken under a quota-restricted set (replay-stable).
    pub fn restricted_decisions(&self) -> u64 {
        self.restricted_decisions
    }

    /// Cancel `tenant` at the current virtual time (see the struct docs
    /// for the exact semantics).
    pub fn cancel(&mut self, tenant: usize) -> CancelOutcome {
        assert!(tenant < self.subs.len(), "no tenant {tenant}");
        assert!(
            self.cancelled[tenant].is_none(),
            "tenant {tenant} cancelled twice"
        );
        let at = self.now;
        self.cancelled[tenant] = Some(at);
        // the tenant takes no further decisions, so its quota ledger is
        // moot; clearing keeps the held-units invariant trivially true
        for m in self.held[tenant].iter_mut() {
            m.clear();
        }

        // drop the tenant's pending stream entry
        let kept: Vec<_> = std::mem::take(&mut self.heap)
            .into_vec()
            .into_iter()
            .filter(|&Reverse((_, i, _, _))| i != tenant)
            .collect();
        self.heap = kept.into();

        // rewind the tenant's trailing not-yet-started reservations
        let mut dropped_tasks = 0usize;
        let mut released_units = 0usize;
        for q in 0..self.plat.n_types() {
            for u in 0..self.plat.counts[q] {
                let mut popped_any = false;
                while let Some(&res) = self.ledger[q][u].last() {
                    if res.tenant != tenant || res.start < at {
                        break;
                    }
                    self.ledger[q][u].pop();
                    self.engine.release_unit(q, u, res.prev_free);
                    self.placements[tenant][res.task] = None;
                    dropped_tasks += 1;
                    popped_any = true;
                }
                if popped_any {
                    released_units += 1;
                }
            }
        }
        // cascade: a kept task whose predecessor was just dropped cannot
        // run either.  A placed task's predecessors were all placed when
        // it streamed, so a `None` pred here can only mean "dropped"; one
        // pass in the tenant's (topological) stream order reaches the
        // fixpoint.  Such tasks are usually buried under a survivor's
        // later reservation, which is irrevocable — the unit then simply
        // keeps a gap where the cascaded task would have run.
        let order = self.orders[tenant].clone();
        for &j in &order {
            let Some(p) = self.placements[tenant][j] else {
                continue;
            };
            let orphaned = self.subs[tenant].graph.preds[j]
                .iter()
                .any(|&pr| self.placements[tenant][pr].is_none());
            if !orphaned {
                continue;
            }
            // only not-yet-started tasks can be orphaned: a dropped pred
            // has start >= at, and j starts after that pred finishes
            debug_assert!(p.start >= at, "running task with dropped pred");
            self.placements[tenant][j] = None;
            dropped_tasks += 1;
            let stack = &mut self.ledger[p.ptype][p.unit];
            let pos = stack
                .iter()
                .position(|r| r.tenant == tenant && r.task == j)
                .expect("placed task has a ledger entry");
            if pos == stack.len() - 1 {
                let res = stack.pop().unwrap();
                self.engine.release_unit(p.ptype, p.unit, res.prev_free);
                released_units += 1;
            } else {
                stack.remove(pos);
            }
        }
        CancelOutcome {
            tenant,
            at,
            dropped_tasks,
            released_units,
        }
    }

    /// Build the final report.  Call after the stream drained
    /// ([`Self::run`]); `ideals` as in [`run_service_with_ideals`].
    pub fn report(&self, ideals: Option<&[f64]>) -> ServiceReport {
        assert!(self.heap.is_empty(), "report before the stream drained");
        let n_tenants = self.subs.len();
        if let Some(v) = ideals {
            assert_eq!(v.len(), n_tenants, "one ideal makespan per submission");
        }

        let mut tenants = Vec::with_capacity(n_tenants);
        let mut horizon = 0.0f64;
        for (i, s) in self.subs.iter().enumerate() {
            let kept: Vec<Placement> = self.placements[i].iter().flatten().copied().collect();
            let kept_tasks: Vec<TaskId> = self.placements[i]
                .iter()
                .enumerate()
                .filter_map(|(j, p)| p.map(|_| j))
                .collect();
            if self.cancelled[i].is_none() {
                assert_eq!(kept.len(), s.graph.n_tasks(), "undecided task in report");
            }
            let n_placed = kept.len();
            let schedule = Schedule::from_placements(kept);
            // a cancelled tenant that never ran anything contributes
            // nothing to the horizon (completion = arrival is only a
            // flow-time anchor, not an event on the pool)
            let completion = if n_placed == 0 {
                s.arrival
            } else {
                schedule.makespan
            };
            if n_placed > 0 {
                horizon = horizon.max(completion);
            }
            let ideal = match ideals {
                Some(v) => v[i],
                // a weighted-stretch tenant's ideal was already computed
                // for the reordering key (same expression, same value)
                None if self.ws_ideals[i].is_finite() => self.ws_ideals[i],
                None => online_schedule(&s.graph, &self.plat, &self.orders[i], &s.policy)
                    .makespan,
            };
            let flow = completion - s.arrival;
            tenants.push(TenantReport {
                tenant: i,
                app: s.graph.app.clone(),
                n_tasks: s.graph.n_tasks(),
                n_placed,
                arrival: s.arrival,
                completion,
                flow_time: flow,
                ideal_makespan: ideal,
                stretch: flow / ideal,
                decision_latency: Summary::of(&self.latencies[i]),
                schedule,
                kept_tasks,
                cancelled_at: self.cancelled[i],
            });
        }

        let mut report = ServiceReport {
            tenants,
            decisions: self.decisions.clone(),
            horizon,
            total_tasks: self.subs.iter().map(|s| s.graph.n_tasks()).sum(),
            mean_stretch: 0.0,
            max_stretch: 0.0,
            stretch_p99: 0.0,
            jain_index: 1.0,
            utilization: Vec::new(),
            rule_counts: self
                .rule_counts
                .iter()
                .map(|(&rule, &n)| (rule.to_string(), n))
                .collect(),
            restricted_decisions: self.restricted_decisions,
        };
        finalize_report(&mut report, &self.plat.counts);
        report
    }
}

/// Fill the derived aggregates of a report whose `tenants`,
/// `decisions`, `horizon`, `total_tasks`, `rule_counts` and
/// `restricted_decisions` are already in place: per-type utilization
/// from the tenant loads, then the completed-tenant stretch aggregates
/// (mean/max/p99/Jain).  One code path shared by [`Service::report`]
/// and the sharded merger ([`ShardedService`]) so an N-shard merge
/// reproduces the single-loop aggregation bit for bit.
pub(crate) fn finalize_report(report: &mut ServiceReport, counts: &[usize]) {
    let mut utilization = vec![0.0; counts.len()];
    if report.horizon > 0.0 {
        for t in &report.tenants {
            for (q, w) in t.schedule.loads(counts.len()).iter().enumerate() {
                utilization[q] += w / (report.horizon * counts[q] as f64);
            }
        }
    }
    report.utilization = utilization;
    // every stretch aggregate flows through the one
    // completed-tenants helper: a cancelled tenant's partial stretch
    // is an underestimate and must not leak into any of them
    let mut stretches = report.completed_stretches();
    if !stretches.is_empty() {
        stretches.sort_by(|a, b| a.total_cmp(b));
        let n = stretches.len() as f64;
        let sum: f64 = stretches.iter().sum();
        let sum_sq: f64 = stretches.iter().map(|s| s * s).sum();
        report.mean_stretch = sum / n;
        report.max_stretch = stretches[stretches.len() - 1];
        report.stretch_p99 = percentile(&stretches, 0.99);
        report.jain_index = if sum_sq > 0.0 { sum * sum / (n * sum_sq) } else { 1.0 };
    }
}

/// Run the multi-tenant streaming service: merge the tenants' arrival
/// streams over virtual time and take every decision through one shared
/// [`PolicyEngine`].  O(total_tasks · (log tenants + Q log units)), plus
/// one single-tenant rerun per submission for the ideal/stretch metrics
/// (precompute those and use [`run_service_with_ideals`] when
/// benchmarking the streaming engine itself).  The per-decision
/// `Q log units` term covers every policy including EFT: service
/// decisions are irrevocable (no backfilling), so the pool's unit trees
/// never hold idle gaps and `PolicyEngine::eft_candidate`'s tail-clamp
/// rule — the tail half of the engine's gap-indexed selection
/// ([`super::engine::GapIndex`]) — is the whole query, which is what
/// keeps 256-unit service pools cheap per arrival.
pub fn run_service(plat: &Platform, subs: &[Submission]) -> ServiceReport {
    run_service_with_ideals(plat, subs, None)
}

/// [`run_service`] with precomputed per-tenant ideal makespans (one per
/// submission: the makespan of `online_schedule` for that tenant's
/// (graph, order, policy) on an empty pool).  `None` computes them here.
pub fn run_service_with_ideals(
    plat: &Platform,
    subs: &[Submission],
    ideals: Option<&[f64]>,
) -> ServiceReport {
    let mut service = Service::new_with_ideals(plat, subs, ideals);
    service.run();
    service.report(ideals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sched::online::{online_by_id, random_topo_order};
    use crate::sim::validate_service;

    fn plat() -> Platform {
        Platform::hybrid(4, 2)
    }

    #[test]
    fn single_tenant_matches_online_exactly() {
        let mut rng = Rng::new(41);
        for case in 0..6u64 {
            let g = gen::hybrid_dag(&mut rng, 50, 0.1);
            for policy in [
                OnlinePolicy::ErLs,
                OnlinePolicy::Eft,
                OnlinePolicy::Greedy,
                OnlinePolicy::Random(case),
                OnlinePolicy::R1,
                OnlinePolicy::R2,
                OnlinePolicy::R3,
            ] {
                let expect = online_by_id(&g, &plat(), &policy);
                let subs = vec![Submission::new(g.clone(), 0.0, policy)];
                let report = run_service(&plat(), &subs);
                assert_eq!(report.tenants[0].schedule.placements, expect.placements);
                assert_eq!(report.tenants[0].stretch, 1.0);
            }
        }
    }

    #[test]
    fn single_tenant_custom_order_matches_online() {
        let mut rng = Rng::new(43);
        let g = gen::hybrid_dag(&mut rng, 40, 0.12);
        let order = random_topo_order(&g, &mut rng);
        let expect = online_schedule(&g, &plat(), &order, &OnlinePolicy::ErLs);
        let subs =
            vec![Submission::new(g.clone(), 0.0, OnlinePolicy::ErLs).with_order(order)];
        let report = run_service(&plat(), &subs);
        assert_eq!(report.tenants[0].schedule.placements, expect.placements);
    }

    #[test]
    fn arrival_delays_all_tenant_starts() {
        let mut b = Builder::new("late");
        b.add_task("t", vec![2.0, 1.0]);
        let g = b.build();
        let subs = vec![Submission::new(g, 10.0, OnlinePolicy::Eft)];
        let report = run_service(&plat(), &subs);
        let p = report.tenants[0].schedule.placements[0];
        assert!(p.start >= 10.0);
        assert_eq!(report.tenants[0].flow_time, p.finish - 10.0);
    }

    #[test]
    fn contention_serializes_on_one_unit() {
        // two single-task tenants, CPU-faster task, 1 CPU + 1 GPU,
        // Greedy: both pick the CPU, tenant 1 queues behind tenant 0
        let mk = || {
            let mut b = Builder::new("one");
            b.add_task("t", vec![2.0, 50.0]);
            b.build()
        };
        let plat = Platform::hybrid(1, 1);
        let subs = vec![
            Submission::new(mk(), 0.0, OnlinePolicy::Greedy),
            Submission::new(mk(), 0.0, OnlinePolicy::Greedy),
        ];
        let report = run_service(&plat, &subs);
        assert_eq!(report.tenants[0].schedule.placements[0].start, 0.0);
        assert_eq!(report.tenants[1].schedule.placements[0].start, 2.0);
        assert_eq!(report.tenants[0].stretch, 1.0);
        assert_eq!(report.tenants[1].stretch, 2.0);
        assert!((report.horizon - 4.0).abs() < 1e-12);
        assert_eq!(report.max_stretch, 2.0);
        validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
    }

    #[test]
    fn streams_interleave_by_arrival_time() {
        // tenant 1 arrives while tenant 0's chain is still streaming:
        // decisions must interleave by virtual time, not tenant order
        let chain = |len: usize| {
            let mut b = Builder::new("chain");
            let mut prev = None;
            for _ in 0..len {
                let t = b.add_task("t", vec![1.0, 1.0]);
                if let Some(p) = prev {
                    b.add_arc(p, t);
                }
                prev = Some(t);
            }
            b.build()
        };
        let plat = Platform::hybrid(2, 1);
        let subs = vec![
            Submission::new(chain(6), 0.0, OnlinePolicy::Greedy),
            Submission::new(chain(2), 2.5, OnlinePolicy::Greedy),
        ];
        let report = run_service(&plat, &subs);
        // tenant 1's first decision lands between tenant 0's 3rd and 4th
        let times: Vec<(usize, f64)> = report
            .decisions
            .iter()
            .map(|d| (d.tenant, d.time))
            .collect();
        let t1_first = times.iter().position(|&(t, _)| t == 1).unwrap();
        assert!(t1_first > 2 && t1_first < 6, "interleave position {t1_first}");
        for w in report.decisions.windows(2) {
            assert!(w[0].time <= w[1].time, "decision times must be sorted");
        }
        validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
    }

    #[test]
    fn cancel_before_start_releases_the_unit() {
        // 1 CPU + 1 GPU; tenant 0's CPU task is placed at t=0, then the
        // tenant is cancelled before the task starts "running" past any
        // later arrival: the reservation is rewound, so tenant 1 (arrival
        // 5) starts at 5 instead of queueing behind the ghost until 10.
        let mk = |cpu: f64| {
            let mut b = Builder::new("one");
            b.add_task("t", vec![cpu, 100.0]);
            b.build()
        };
        let plat = Platform::hybrid(1, 1);
        let subs = vec![
            Submission::new(mk(10.0), 0.0, OnlinePolicy::Greedy),
            Submission::new(mk(1.0), 5.0, OnlinePolicy::Greedy),
        ];
        let mut svc = Service::new(&plat, &subs);
        assert!(svc.step().is_some()); // tenant 0 placed on the CPU [0, 10)
        let out = svc.cancel(0);
        assert_eq!(out, CancelOutcome { tenant: 0, at: 0.0, dropped_tasks: 1, released_units: 1 });
        svc.run();
        let report = svc.report(None);
        assert_eq!(report.tenants[0].cancelled_at, Some(0.0));
        assert_eq!(report.tenants[0].n_placed, 0);
        assert!(report.tenants[0].schedule.placements.is_empty());
        // the survivor got the freed unit at its own arrival
        assert_eq!(report.tenants[1].schedule.placements[0].start, 5.0);
        assert_eq!(report.tenants[1].schedule.placements[0].finish, 6.0);
        validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
    }

    #[test]
    fn cancel_keeps_running_tasks_and_drops_the_stream() {
        // tenant 0: 2-task CPU chain; cancelled after its first task
        // started (now = 5 when tenant 1's arrival is processed): the
        // running task finishes, the second task never arrives, and the
        // survivor's already-irrevocable decision stands.
        let chain2 = || {
            let mut b = Builder::new("chain");
            let a = b.add_task("a", vec![10.0, 100.0]);
            let c = b.add_task("b", vec![10.0, 100.0]);
            b.add_arc(a, c);
            b.build()
        };
        let one = || {
            let mut b = Builder::new("one");
            b.add_task("t", vec![1.0, 100.0]);
            b.build()
        };
        let plat = Platform::hybrid(1, 1);
        let subs = vec![
            Submission::new(chain2(), 0.0, OnlinePolicy::Greedy),
            Submission::new(one(), 5.0, OnlinePolicy::Greedy),
        ];
        let mut svc = Service::new(&plat, &subs);
        assert!(svc.step().is_some()); // t0/a on CPU [0, 10)
        assert!(svc.step().is_some()); // t1 arrives at 5, queues [10, 11)
        assert_eq!(svc.now(), 5.0);
        let out = svc.cancel(0);
        assert_eq!(out.dropped_tasks, 0, "running task is kept");
        assert_eq!(out.released_units, 0);
        svc.run();
        let report = svc.report(None);
        assert_eq!(report.tenants[0].n_placed, 1, "second chain task never ran");
        assert_eq!(report.tenants[0].completion, 10.0);
        assert_eq!(report.tenants[1].schedule.placements[0].start, 10.0);
        assert!((report.horizon - 11.0).abs() < 1e-12);
        validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
    }

    #[test]
    fn cancel_mid_stream_keeps_survivors_valid() {
        let mut rng = Rng::new(91);
        for case in 0..6usize {
            let subs: Vec<Submission> = (0..6)
                .map(|t| {
                    let g = gen::hybrid_dag(&mut rng, 25, 0.12);
                    let policy = if t % 2 == 0 {
                        OnlinePolicy::Greedy
                    } else {
                        OnlinePolicy::Eft
                    };
                    Submission::new(g, t as f64 * 2.0, policy)
                })
                .collect();
            let mut svc = Service::new(&plat(), &subs);
            for _ in 0..(6 * 25) / 3 {
                let _ = svc.step();
            }
            let victim = case % 6;
            let out = svc.cancel(victim);
            assert_eq!(out.tenant, victim);
            svc.run();
            let report = svc.report(None);
            // survivors are complete and jointly feasible on the pool
            validate_service(&plat(), &report.tenant_runs(&subs))
                .unwrap_or_else(|e| panic!("case {case}: {e}"));
            for t in &report.tenants {
                if t.cancelled_at.is_none() {
                    assert_eq!(t.n_placed, t.n_tasks);
                } else {
                    assert!(t.n_placed <= t.n_tasks);
                }
            }
            // and nothing overlaps anywhere — including the cancelled
            // tenant's kept (already-running) tasks
            crate::sim::validate_placements_no_overlap(
                report.tenants.iter().flat_map(|t| &t.schedule.placements),
            )
            .unwrap_or_else(|e| panic!("case {case}: overlap after cancel: {e}"));
            // cascade invariant: no kept task of a cancelled tenant may
            // depend on a dropped one, and kept precedences hold
            for (i, t) in report.tenants.iter().enumerate() {
                if t.cancelled_at.is_none() {
                    continue;
                }
                let g = &subs[i].graph;
                let mut placed: Vec<Option<Placement>> = vec![None; g.n_tasks()];
                for (&j, p) in t.kept_tasks.iter().zip(&t.schedule.placements) {
                    placed[j] = Some(*p);
                }
                for &j in &t.kept_tasks {
                    for &pr in &g.preds[j] {
                        let pp = placed[pr].unwrap_or_else(|| {
                            panic!("case {case}: kept task {j} depends on dropped {pr}")
                        });
                        assert!(
                            placed[j].unwrap().start >= pp.finish - 1e-9,
                            "case {case}: kept precedence violated {pr}->{j}"
                        );
                    }
                }
            }
        }
    }

    fn cpu_chain(app: &str, len: usize, dur: f64) -> TaskGraph {
        let mut b = Builder::new(app);
        let mut prev = None;
        for _ in 0..len {
            let t = b.add_task("t", vec![dur, dur * 100.0]);
            if let Some(p) = prev {
                b.add_arc(p, t);
            }
            prev = Some(t);
        }
        b.build()
    }

    #[test]
    fn quota_cap_one_stacks_on_a_single_cpu() {
        // 4 CPUs + 2 GPUs, but the tenant's cpu_share grants one unit:
        // its independent CPU-fast tasks must serialize on one CPU while
        // 3 CPUs sit idle (hard caps are enforced even on an idle pool)
        let mut b = Builder::new("wide");
        for _ in 0..4 {
            b.add_task("t", vec![2.0, 200.0]);
        }
        let g = b.build();
        let subs = vec![Submission::new(g, 0.0, OnlinePolicy::Greedy)
            .with_admission(TenantPolicy::Quota { cpu_share: 0.25, gpu_share: 1.0 })];
        let report = run_service(&plat(), &subs);
        let t = &report.tenants[0];
        for (k, p) in t.schedule.placements.iter().enumerate() {
            assert_eq!((p.ptype, p.unit), (0, 0), "task {k} must stay on CPU 0");
            assert_eq!(p.start, k as f64 * 2.0, "task {k} queues behind the cap");
        }
        validate_service(&plat(), &report.tenant_runs(&subs)).unwrap();
    }

    #[test]
    fn quota_frees_units_as_reservations_expire() {
        // cap 1 on CPUs; two independent tasks — the second stacks on the
        // held unit; a third task arriving after both finished may pick a
        // fresh unit again (the ledger expired)
        let mut b = Builder::new("w3");
        b.add_task("a", vec![2.0, 200.0]);
        b.add_task("b", vec![2.0, 200.0]);
        let c = b.add_task("c", vec![2.0, 200.0]);
        let a = 0;
        b.add_arc(a, c);
        let g = b.build();
        let subs = vec![Submission::new(g, 0.0, OnlinePolicy::Greedy)
            .with_admission(TenantPolicy::Quota { cpu_share: 0.25, gpu_share: 1.0 })];
        let report = run_service(&plat(), &subs);
        let p = &report.tenants[0].schedule.placements;
        assert_eq!((p[0].start, p[0].unit), (0.0, 0));
        assert_eq!((p[1].start, p[1].unit), (2.0, 0), "at cap: stacks behind itself");
        // c streams after a finishes (ready 2.0) but decides at time 2.0
        // when b's reservation (finish 4.0) still holds unit 0
        assert_eq!((p[2].start, p[2].unit), (4.0, 0));
    }

    #[test]
    fn quota_zero_share_bans_the_type() {
        let mut b = Builder::new("cpuonly");
        b.add_task("t", vec![1.0, 50.0]);
        let g = b.build();
        // CPU-fast task, but cpu_share 0: Greedy must fall through to GPU
        let subs = vec![Submission::new(g, 0.0, OnlinePolicy::Greedy)
            .with_admission(TenantPolicy::Quota { cpu_share: 0.0, gpu_share: 1.0 })];
        let report = run_service(&plat(), &subs);
        assert_eq!(report.tenants[0].schedule.placements[0].ptype, 1);
    }

    #[test]
    fn single_tenant_parity_under_every_admission_policy() {
        // full-share quota and any weighted-stretch weight leave a lone
        // tenant's placements exactly the online engine's
        let mut rng = Rng::new(47);
        let g = gen::hybrid_dag(&mut rng, 40, 0.1);
        for policy in [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let expect = online_by_id(&g, &plat(), &policy);
            for admission in [
                TenantPolicy::Fifo,
                TenantPolicy::Quota { cpu_share: 1.0, gpu_share: 1.0 },
                TenantPolicy::WeightedStretch { weight: 0.25 },
                TenantPolicy::WeightedStretch { weight: 4.0 },
            ] {
                let subs = vec![
                    Submission::new(g.clone(), 0.0, policy.clone()).with_admission(admission)
                ];
                let report = run_service(&plat(), &subs);
                assert_eq!(
                    report.tenants[0].schedule.placements, expect.placements,
                    "{} under {}",
                    policy.name(),
                    subs[0].admission.name()
                );
            }
        }
    }

    #[test]
    fn weighted_stretch_admits_the_most_behind_tenant_first() {
        // 1 CPU + 1 GPU; tenant 0 hogs the GPU [0, 100); tenants 1 and 2
        // run CPU chains.  At the t=4 window the pool is busy until 10,
        // and both remaining heads (t1's second task at 4, t2's second
        // task at 10) compete:
        //   equal weights  -> t1 (stretch 4/8 = 0.5 beats 4/12 = 0.33)
        //   t1 weight 0.1  -> t2 jumps the queue (0.05 vs 0.33)
        let plat = Platform::hybrid(1, 1);
        let hog = || {
            let mut b = Builder::new("hog");
            b.add_task("t", vec![10000.0, 100.0]);
            b.build()
        };
        let mk = |subs_w: [f64; 2]| -> Vec<Submission> {
            vec![
                Submission::new(hog(), 0.0, OnlinePolicy::Greedy)
                    .with_admission(TenantPolicy::WeightedStretch { weight: 1.0 }),
                Submission::new(cpu_chain("t1", 2, 4.0), 0.0, OnlinePolicy::Greedy)
                    .with_admission(TenantPolicy::WeightedStretch { weight: subs_w[0] }),
                Submission::new(cpu_chain("t2", 2, 6.0), 0.0, OnlinePolicy::Greedy)
                    .with_admission(TenantPolicy::WeightedStretch { weight: subs_w[1] }),
            ]
        };

        // equal weights: t1 keeps its FIFO slot at the window
        let subs = mk([1.0, 1.0]);
        let report = run_service(&plat, &subs);
        assert_eq!(report.tenants[1].schedule.placements[1].start, 10.0);
        assert_eq!(report.tenants[2].schedule.placements[1].start, 14.0);
        for w in report.decisions.windows(2) {
            assert!(w[0].time <= w[1].time, "decision times must be sorted");
        }
        validate_service(&plat, &report.tenant_runs(&subs)).unwrap();

        // deprioritize t1: t2's second task takes the [10, 16) slot
        let subs = mk([0.1, 1.0]);
        let report = run_service(&plat, &subs);
        assert_eq!(report.tenants[2].schedule.placements[1].start, 10.0);
        assert_eq!(report.tenants[1].schedule.placements[1].start, 16.0);
        for w in report.decisions.windows(2) {
            assert!(w[0].time <= w[1].time, "decision times must be sorted");
        }
        validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
    }

    #[test]
    fn fairness_aggregates_exclude_cancelled_partials() {
        // tenant 0 is cancelled after one running task: its partial
        // stretch must not leak into mean/max/p99/Jain (regression for
        // the tenant_runs-consumer mixup)
        let plat = Platform::hybrid(1, 1);
        let subs = vec![
            Submission::new(cpu_chain("victim", 3, 10.0), 0.0, OnlinePolicy::Greedy),
            Submission::new(cpu_chain("survivor", 1, 2.0), 5.0, OnlinePolicy::Greedy),
        ];
        let mut svc = Service::new(&plat, &subs);
        assert!(svc.step().is_some()); // victim task 0 on CPU [0, 10)
        assert!(svc.step().is_some()); // survivor arrives at 5, queues
        let _ = svc.cancel(0);
        svc.run();
        let report = svc.report(None);
        // the cancelled tenant reports its (partial, underestimating)
        // stretch, but the aggregates only see the survivor
        let survivor_stretch = report.tenants[1].stretch;
        assert_eq!(report.completed_stretches(), vec![survivor_stretch]);
        assert_eq!(report.mean_stretch, survivor_stretch);
        assert_eq!(report.max_stretch, survivor_stretch);
        assert_eq!(report.stretch_p99, survivor_stretch);
        assert_eq!(report.jain_index, 1.0);
    }

    #[test]
    fn jain_index_measures_stretch_dispersion() {
        // two identical single-task tenants colliding on one CPU:
        // stretches (1, 2) -> Jain (1+2)^2 / (2 * (1+4)) = 0.9
        let mk = || {
            let mut b = Builder::new("one");
            b.add_task("t", vec![2.0, 50.0]);
            b.build()
        };
        let plat = Platform::hybrid(1, 1);
        let subs = vec![
            Submission::new(mk(), 0.0, OnlinePolicy::Greedy),
            Submission::new(mk(), 0.0, OnlinePolicy::Greedy),
        ];
        let report = run_service(&plat, &subs);
        assert_eq!(report.max_stretch, 2.0);
        assert!((report.stretch_p99 - 1.99).abs() < 1e-9);
        assert!((report.jain_index - 0.9).abs() < 1e-12);
    }

    #[test]
    fn mixed_policies_share_one_pool_feasibly() {
        let mut rng = Rng::new(57);
        let policies = [
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(3),
        ];
        let subs: Vec<Submission> = (0..8)
            .map(|t| {
                let g = gen::hybrid_dag(&mut rng, 30, 0.1);
                Submission::new(g, t as f64 * 3.0, policies[t % policies.len()].clone())
            })
            .collect();
        let report = run_service(&plat(), &subs);
        assert_eq!(report.total_tasks, 8 * 30);
        assert_eq!(report.decisions.len(), 8 * 30);
        // list-scheduling anomalies mean contention is not *pointwise*
        // worse, but stretches must be positive, finite and bounded by
        // the reported max
        assert!(report.mean_stretch > 0.0 && report.mean_stretch.is_finite());
        assert!(report.max_stretch >= report.mean_stretch - 1e-12);
        for u in &report.utilization {
            assert!(*u >= 0.0 && *u <= 1.0 + 1e-9);
        }
        validate_service(&plat(), &report.tenant_runs(&subs)).unwrap();
        // batch runs never read the wall clock: decision latency is
        // daemon-edge-only (`note_decision_latency`), so it is empty here
        for t in &report.tenants {
            assert_eq!(t.decision_latency.n, 0);
            assert!(t.completion >= t.arrival);
        }
    }

    #[test]
    fn incremental_admit_matches_batch_bitwise() {
        // the daemon invariant's foundation: admitting submissions one
        // at a time (monotone arrivals, advancing the stream between
        // admissions) produces the same decision stream and report as
        // the batch constructor — bit for bit, not approximately
        let mut rng = Rng::new(91);
        let policies = [
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(11),
        ];
        for round in 0..4u64 {
            let subs: Vec<Submission> = (0..6)
                .map(|t| {
                    let g = gen::hybrid_dag(&mut rng, 25, 0.12);
                    Submission::new(
                        g,
                        t as f64 * (2.0 + round as f64),
                        policies[(t + round as usize) % policies.len()].clone(),
                    )
                })
                .collect();
            let mut batch = Service::new(&plat(), &subs);
            batch.run();
            let mut inc = Service::empty(&plat());
            for s in &subs {
                assert_eq!(inc.admit(s.clone()).unwrap(), inc.n_tenants() - 1);
            }
            inc.run();
            assert_eq!(batch.decisions().len(), inc.decisions().len());
            for (a, b) in batch.decisions().iter().zip(inc.decisions()) {
                assert_eq!((a.tenant, a.task), (b.tenant, b.task));
                assert_eq!(a.time.to_bits(), b.time.to_bits());
            }
            let (ra, rb) = (batch.report(None), inc.report(None));
            assert_eq!(ra.horizon.to_bits(), rb.horizon.to_bits());
            for (ta, tb) in ra.tenants.iter().zip(&rb.tenants) {
                assert_eq!(ta.schedule.placements, tb.schedule.placements);
                assert_eq!(ta.stretch.to_bits(), tb.stretch.to_bits());
            }
        }
    }

    #[test]
    fn admit_clamps_late_arrivals_to_now() {
        // once the stream has advanced past t, a submission "arriving"
        // earlier is admitted at now (no time travel, decisions stay
        // monotone)
        let chain = |len: usize| {
            let mut b = Builder::new("chain");
            let mut prev = None;
            for _ in 0..len {
                let t = b.add_task("t", vec![1.0, 1.0]);
                if let Some(p) = prev {
                    b.add_arc(p, t);
                }
                prev = Some(t);
            }
            b.build()
        };
        let mut svc = Service::empty(&plat());
        svc.admit(Submission::new(chain(4), 0.0, OnlinePolicy::Greedy))
            .unwrap();
        svc.advance_before(3.0);
        assert!(svc.now() >= 2.0);
        let id = svc
            .admit(Submission::new(chain(1), 0.5, OnlinePolicy::Greedy))
            .unwrap();
        svc.run();
        assert!(svc.submissions()[id].arrival >= 2.0, "arrival clamped on admit");
        let first_t1 = svc
            .decisions()
            .iter()
            .find(|d| d.tenant == id)
            .unwrap()
            .time;
        assert!(first_t1 >= 2.0, "late arrival clamped to now, got {first_t1}");
        for w in svc.decisions().windows(2) {
            assert!(w[0].time <= w[1].time, "decision times must stay sorted");
        }
    }

    #[test]
    fn admit_rejects_invalid_submissions() {
        let mut svc = Service::empty(&plat());
        let mut b = Builder::new("ok");
        b.add_task("t", vec![1.0, 1.0]);
        let g = b.build();
        // arrival poisoned after construction (fields are public; the
        // daemon cannot trust Submission::new ran its asserts)
        let mut bad = Submission::new(g.clone(), 0.0, OnlinePolicy::Eft);
        bad.arrival = f64::NAN;
        assert!(svc.admit(bad).is_err());
        // graph/platform type-count mismatch
        let mut b3 = Builder::new("threetype");
        b3.add_task("t", vec![1.0, 1.0, 1.0]);
        assert!(svc
            .admit(Submission::new(b3.build(), 0.0, OnlinePolicy::Eft))
            .is_err());
        assert_eq!(svc.n_tenants(), 0, "rejected submissions leave no trace");
        assert!(svc.admit(Submission::new(g, 0.0, OnlinePolicy::Eft)).is_ok());
        assert_eq!(svc.n_tenants(), 1);
    }
}
