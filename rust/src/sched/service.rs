//! Multi-tenant streaming service mode: many task graphs arriving over
//! (virtual) time into one shared unit pool.
//!
//! # Mapping to the paper's on-line model (§4.2, §6.3, §7)
//!
//! The paper's on-line setting assumes tasks arrive in a
//! precedence-respecting stream and the scheduler takes an *irrevocable*
//! (processor, start-time) decision at each arrival.  That regime is
//! exactly a shared-cluster service: applications (tenants) submit DAGs
//! over time, and a StarPU-like runtime multiplexes them over one
//! CPU/GPU pool.  This module grows the single-DAG engine of
//! [`super::online`] into that service:
//!
//! * A [`Submission`] is one tenant's application: a [`TaskGraph`], an
//!   arrival time, and the online policy (ER-LS / EFT / Greedy / …)
//!   taking its decisions.  Each tenant keeps its own
//!   precedence-respecting arrival order (task-id order by default, as
//!   our generators emit ids topologically).
//! * Tasks of tenant *i* arrive as a stream: task at stream position
//!   `p` arrives at `a_p = max(arrival_i, a_{p-1}, r_p)` where
//!   `r_p = max(arrival_i, max_pred C)` — a task is submitted once its
//!   predecessors complete, and never before the tenant's earlier
//!   submissions (the stream is sequential, as in the paper's model
//!   where the arrival order extends the precedence order).
//! * A global completion-driven event loop merges the tenant streams by
//!   arrival time (ties: tenant id, then stream position) and feeds each
//!   arrival to the shared [`PolicyEngine`] over one
//!   [`engine::UnitPool`](super::engine::UnitPool).  Decisions are
//!   irrevocable: the chosen unit is reserved until the task's finish.
//!
//! Because each tenant's decisions happen in its own stream order with
//! the pool state observed at arrival, a *single*-tenant service run
//! takes exactly the decisions of [`online_schedule`] — golden parity,
//! pinned by tests.  Under contention the same policies now see a pool
//! warmed by other tenants, which is the irrevocable-multiplexing regime
//! the survey literature (Beaumont et al. 2019) describes for hybrid
//! runtimes.
//!
//! Per-tenant metrics follow the service-scheduling literature: *flow
//! time* (completion − arrival), *stretch* (flow time over the tenant's
//! ideal single-tenant makespan under the same policy on an empty pool),
//! and decision latency.  The aggregate [`ServiceReport`] adds the
//! horizon, utilization, and stretch summaries that
//! `examples/service_mode.rs` and `benches/service_throughput.rs`
//! report.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::graph::{TaskGraph, TaskId};
use crate::platform::Platform;
use crate::sim::{Placement, Schedule, TenantRun};
use crate::substrate::rng::Rng;
use crate::substrate::stats::Summary;

use super::online::{online_schedule, requires_two_types, OnlinePolicy, PolicyEngine};
use super::OrdF64;

/// One tenant's application entering the service.
#[derive(Clone, Debug)]
pub struct Submission {
    pub graph: TaskGraph,
    /// Virtual time at which the tenant submits the application; no task
    /// of the tenant may start before it.
    pub arrival: f64,
    /// The online policy taking this tenant's irrevocable decisions.
    pub policy: OnlinePolicy,
    /// Precedence-respecting arrival order of the tenant's tasks
    /// (defaults to task-id order, which our generators emit
    /// topologically).
    order: Option<Vec<TaskId>>,
}

impl Submission {
    pub fn new(graph: TaskGraph, arrival: f64, policy: OnlinePolicy) -> Submission {
        assert!(arrival.is_finite() && arrival >= 0.0, "bad arrival {arrival}");
        Submission {
            graph,
            arrival,
            policy,
            order: None,
        }
    }

    /// Use a custom (topological) arrival order for this tenant.
    pub fn with_order(mut self, order: Vec<TaskId>) -> Submission {
        assert_eq!(order.len(), self.graph.n_tasks(), "order must cover all tasks");
        self.order = Some(order);
        self
    }

    fn order_vec(&self) -> Vec<TaskId> {
        self.order
            .clone()
            .unwrap_or_else(|| (0..self.graph.n_tasks()).collect())
    }
}

/// One irrevocable decision, in global decision order: tenant `tenant`'s
/// task `task` arrived (and was placed) at virtual time `time`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecisionRecord {
    pub tenant: usize,
    pub task: TaskId,
    pub time: f64,
}

/// Per-tenant outcome.
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub tenant: usize,
    pub app: String,
    pub n_tasks: usize,
    pub arrival: f64,
    /// Virtual time the tenant's last task finishes.
    pub completion: f64,
    /// completion − arrival.
    pub flow_time: f64,
    /// Makespan of the same (graph, order, policy) on an empty pool.
    pub ideal_makespan: f64,
    /// flow_time / ideal_makespan (1.0 = no slowdown from contention).
    pub stretch: f64,
    /// Wall-clock seconds per irrevocable decision.
    pub decision_latency: Summary,
    /// The tenant's placements (absolute virtual times on the shared pool).
    pub schedule: Schedule,
}

/// Aggregate outcome of one service run.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub tenants: Vec<TenantReport>,
    /// Every decision in global order (drives the live coordinator).
    pub decisions: Vec<DecisionRecord>,
    /// Virtual time the last task of any tenant finishes.
    pub horizon: f64,
    pub total_tasks: usize,
    pub mean_stretch: f64,
    pub max_stretch: f64,
    /// Busy fraction per type over [0, horizon).
    pub utilization: Vec<f64>,
}

impl ServiceReport {
    /// Pair each tenant's schedule with its submission for the
    /// tenant-aware merge validator
    /// ([`validate_service`](crate::sim::validate_service)).
    pub fn tenant_runs<'a>(&'a self, subs: &'a [Submission]) -> Vec<TenantRun<'a>> {
        assert_eq!(subs.len(), self.tenants.len());
        subs.iter()
            .zip(&self.tenants)
            .map(|(s, t)| TenantRun {
                graph: &s.graph,
                schedule: &t.schedule,
                arrival: s.arrival,
            })
            .collect()
    }
}

/// ready = max(tenant arrival, predecessors' completions); a task's
/// predecessors are all decided by the time this runs because the order
/// is topological and each tenant's stream is processed strictly in
/// order (non-topological orders panic here).
fn ready_time(
    g: &TaskGraph,
    arrival: f64,
    placed: &[Option<Placement>],
    tenant: usize,
    j: TaskId,
) -> f64 {
    g.preds[j]
        .iter()
        .map(|&p| {
            placed[p]
                .unwrap_or_else(|| panic!("tenant {tenant}: order not topological at task {j}"))
                .finish
        })
        .fold(arrival, f64::max)
}

/// Run the multi-tenant streaming service: merge the tenants' arrival
/// streams over virtual time and take every decision through one shared
/// [`PolicyEngine`].  O(total_tasks · (log tenants + Q log units)), plus
/// one single-tenant rerun per submission for the ideal/stretch metrics
/// (precompute those and use [`run_service_with_ideals`] when
/// benchmarking the streaming engine itself).
pub fn run_service(plat: &Platform, subs: &[Submission]) -> ServiceReport {
    run_service_with_ideals(plat, subs, None)
}

/// [`run_service`] with precomputed per-tenant ideal makespans (one per
/// submission: the makespan of `online_schedule` for that tenant's
/// (graph, order, policy) on an empty pool).  `None` computes them here.
pub fn run_service_with_ideals(
    plat: &Platform,
    subs: &[Submission],
    ideals: Option<&[f64]>,
) -> ServiceReport {
    let n_tenants = subs.len();
    if let Some(v) = ideals {
        assert_eq!(v.len(), n_tenants, "one ideal makespan per submission");
    }
    for s in subs {
        assert!(s.graph.n_tasks() > 0, "empty submission");
        // re-checked here because the fields are public (Submission::new
        // validates, but nothing stops callers mutating afterwards)
        assert!(
            s.arrival.is_finite() && s.arrival >= 0.0,
            "bad arrival {}",
            s.arrival
        );
        if requires_two_types(&s.policy) {
            assert!(
                plat.n_types() == 2,
                "{} is defined for hybrid platforms",
                s.policy.name()
            );
        }
        assert_eq!(
            s.graph.n_types(),
            plat.n_types(),
            "graph/platform type count mismatch"
        );
    }

    let orders: Vec<Vec<TaskId>> = subs.iter().map(|s| s.order_vec()).collect();
    let mut engine = PolicyEngine::new(plat);
    let mut rngs: Vec<Option<Rng>> = subs
        .iter()
        .map(|s| match s.policy {
            OnlinePolicy::Random(seed) => Some(Rng::new(seed)),
            _ => None,
        })
        .collect();
    let mut placements: Vec<Vec<Option<Placement>>> = subs
        .iter()
        .map(|s| vec![None; s.graph.n_tasks()])
        .collect();
    let mut latencies: Vec<Vec<f64>> = subs
        .iter()
        .map(|s| Vec::with_capacity(s.graph.n_tasks()))
        .collect();
    let total_tasks: usize = subs.iter().map(|s| s.graph.n_tasks()).sum();
    let mut decisions = Vec::with_capacity(total_tasks);

    // Stream heap: (arrival time, tenant, stream position, ready time).
    // One outstanding arrival per tenant keeps the heap at O(tenants),
    // and carrying the ready time computes each task's fold exactly once.
    let mut heap: BinaryHeap<Reverse<(OrdF64, usize, usize, OrdF64)>> = BinaryHeap::new();
    for (i, s) in subs.iter().enumerate() {
        let r0 = ready_time(&s.graph, s.arrival, &placements[i], i, orders[i][0]);
        heap.push(Reverse((OrdF64(s.arrival.max(r0)), i, 0, OrdF64(r0))));
    }

    while let Some(Reverse((OrdF64(at), i, pos, OrdF64(ready)))) = heap.pop() {
        let g = &subs[i].graph;
        let j = orders[i][pos];
        debug_assert!(placements[i][j].is_none(), "tenant {i}: task {j} decided twice");
        debug_assert!(at >= ready, "stream time regressed");

        let td = Instant::now();
        let p = engine.decide(g, plat, j, ready, &subs[i].policy, rngs[i].as_mut());
        latencies[i].push(td.elapsed().as_secs_f64() + 1e-9);
        placements[i][j] = Some(p);
        decisions.push(DecisionRecord {
            tenant: i,
            task: j,
            time: at,
        });

        if pos + 1 < orders[i].len() {
            let r_next = ready_time(g, subs[i].arrival, &placements[i], i, orders[i][pos + 1]);
            heap.push(Reverse((OrdF64(at.max(r_next)), i, pos + 1, OrdF64(r_next))));
        }
    }

    // per-tenant reports
    let mut tenants = Vec::with_capacity(n_tenants);
    let mut horizon = 0.0f64;
    for (i, s) in subs.iter().enumerate() {
        let schedule = Schedule::from_placements(
            placements[i]
                .iter()
                .map(|p| p.expect("every task decided"))
                .collect(),
        );
        let completion = schedule.makespan;
        horizon = horizon.max(completion);
        let ideal = match ideals {
            Some(v) => v[i],
            None => online_schedule(&s.graph, plat, &orders[i], &s.policy).makespan,
        };
        let flow = completion - s.arrival;
        tenants.push(TenantReport {
            tenant: i,
            app: s.graph.app.clone(),
            n_tasks: s.graph.n_tasks(),
            arrival: s.arrival,
            completion,
            flow_time: flow,
            ideal_makespan: ideal,
            stretch: flow / ideal,
            decision_latency: Summary::of(&latencies[i]),
            schedule,
        });
    }

    let stretches: Vec<f64> = tenants.iter().map(|t| t.stretch).collect();
    let mut utilization = vec![0.0; plat.n_types()];
    if horizon > 0.0 {
        for t in &tenants {
            for (q, w) in t.schedule.loads(plat.n_types()).iter().enumerate() {
                utilization[q] += w / (horizon * plat.counts[q] as f64);
            }
        }
    }
    ServiceReport {
        tenants,
        decisions,
        horizon,
        total_tasks,
        mean_stretch: if stretches.is_empty() {
            0.0
        } else {
            stretches.iter().sum::<f64>() / stretches.len() as f64
        },
        max_stretch: stretches.iter().fold(0.0f64, |a, &b| a.max(b)),
        utilization,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sched::online::{online_by_id, random_topo_order};
    use crate::sim::validate_service;

    fn plat() -> Platform {
        Platform::hybrid(4, 2)
    }

    #[test]
    fn single_tenant_matches_online_exactly() {
        let mut rng = Rng::new(41);
        for case in 0..6u64 {
            let g = gen::hybrid_dag(&mut rng, 50, 0.1);
            for policy in [
                OnlinePolicy::ErLs,
                OnlinePolicy::Eft,
                OnlinePolicy::Greedy,
                OnlinePolicy::Random(case),
                OnlinePolicy::R1,
                OnlinePolicy::R2,
                OnlinePolicy::R3,
            ] {
                let expect = online_by_id(&g, &plat(), &policy);
                let subs = vec![Submission::new(g.clone(), 0.0, policy)];
                let report = run_service(&plat(), &subs);
                assert_eq!(report.tenants[0].schedule.placements, expect.placements);
                assert_eq!(report.tenants[0].stretch, 1.0);
            }
        }
    }

    #[test]
    fn single_tenant_custom_order_matches_online() {
        let mut rng = Rng::new(43);
        let g = gen::hybrid_dag(&mut rng, 40, 0.12);
        let order = random_topo_order(&g, &mut rng);
        let expect = online_schedule(&g, &plat(), &order, &OnlinePolicy::ErLs);
        let subs =
            vec![Submission::new(g.clone(), 0.0, OnlinePolicy::ErLs).with_order(order)];
        let report = run_service(&plat(), &subs);
        assert_eq!(report.tenants[0].schedule.placements, expect.placements);
    }

    #[test]
    fn arrival_delays_all_tenant_starts() {
        let mut b = Builder::new("late");
        b.add_task("t", vec![2.0, 1.0]);
        let g = b.build();
        let subs = vec![Submission::new(g, 10.0, OnlinePolicy::Eft)];
        let report = run_service(&plat(), &subs);
        let p = report.tenants[0].schedule.placements[0];
        assert!(p.start >= 10.0);
        assert_eq!(report.tenants[0].flow_time, p.finish - 10.0);
    }

    #[test]
    fn contention_serializes_on_one_unit() {
        // two single-task tenants, CPU-faster task, 1 CPU + 1 GPU,
        // Greedy: both pick the CPU, tenant 1 queues behind tenant 0
        let mk = || {
            let mut b = Builder::new("one");
            b.add_task("t", vec![2.0, 50.0]);
            b.build()
        };
        let plat = Platform::hybrid(1, 1);
        let subs = vec![
            Submission::new(mk(), 0.0, OnlinePolicy::Greedy),
            Submission::new(mk(), 0.0, OnlinePolicy::Greedy),
        ];
        let report = run_service(&plat, &subs);
        assert_eq!(report.tenants[0].schedule.placements[0].start, 0.0);
        assert_eq!(report.tenants[1].schedule.placements[0].start, 2.0);
        assert_eq!(report.tenants[0].stretch, 1.0);
        assert_eq!(report.tenants[1].stretch, 2.0);
        assert!((report.horizon - 4.0).abs() < 1e-12);
        assert_eq!(report.max_stretch, 2.0);
        validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
    }

    #[test]
    fn streams_interleave_by_arrival_time() {
        // tenant 1 arrives while tenant 0's chain is still streaming:
        // decisions must interleave by virtual time, not tenant order
        let chain = |len: usize| {
            let mut b = Builder::new("chain");
            let mut prev = None;
            for _ in 0..len {
                let t = b.add_task("t", vec![1.0, 1.0]);
                if let Some(p) = prev {
                    b.add_arc(p, t);
                }
                prev = Some(t);
            }
            b.build()
        };
        let plat = Platform::hybrid(2, 1);
        let subs = vec![
            Submission::new(chain(6), 0.0, OnlinePolicy::Greedy),
            Submission::new(chain(2), 2.5, OnlinePolicy::Greedy),
        ];
        let report = run_service(&plat, &subs);
        // tenant 1's first decision lands between tenant 0's 3rd and 4th
        let times: Vec<(usize, f64)> = report
            .decisions
            .iter()
            .map(|d| (d.tenant, d.time))
            .collect();
        let t1_first = times.iter().position(|&(t, _)| t == 1).unwrap();
        assert!(t1_first > 2 && t1_first < 6, "interleave position {t1_first}");
        for w in report.decisions.windows(2) {
            assert!(w[0].time <= w[1].time, "decision times must be sorted");
        }
        validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
    }

    #[test]
    fn mixed_policies_share_one_pool_feasibly() {
        let mut rng = Rng::new(57);
        let policies = [
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(3),
        ];
        let subs: Vec<Submission> = (0..8)
            .map(|t| {
                let g = gen::hybrid_dag(&mut rng, 30, 0.1);
                Submission::new(g, t as f64 * 3.0, policies[t % policies.len()].clone())
            })
            .collect();
        let report = run_service(&plat(), &subs);
        assert_eq!(report.total_tasks, 8 * 30);
        assert_eq!(report.decisions.len(), 8 * 30);
        // list-scheduling anomalies mean contention is not *pointwise*
        // worse, but stretches must be positive, finite and bounded by
        // the reported max
        assert!(report.mean_stretch > 0.0 && report.mean_stretch.is_finite());
        assert!(report.max_stretch >= report.mean_stretch - 1e-12);
        for u in &report.utilization {
            assert!(*u >= 0.0 && *u <= 1.0 + 1e-9);
        }
        validate_service(&plat(), &report.tenant_runs(&subs)).unwrap();
        // per-tenant decision latency was measured for every task
        for t in &report.tenants {
            assert_eq!(t.decision_latency.n, 30);
            assert!(t.completion >= t.arrival);
        }
    }
}
