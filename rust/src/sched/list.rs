//! Allocation-respecting List Scheduling (Graham's algorithm adapted to
//! two or more types of resources, §4.1): whenever a unit of type q is
//! idle and a ready task allocated to q exists, start the ready task of
//! highest priority immediately.
//!
//! OLS = this scheduler with `priority = ols_rank` (the allocation-aware
//! bottom-level rank of §4.1).  The engine is event-driven —
//! O((n + |E|) log n) per instance — built on the shared
//! [`engine::EventQueue`] completion heap, per-type ready max-heaps and
//! LIFO idle-unit pools.  The virtual clock cursor is an
//! [`engine::Tick`], so "completions at time t" is an exact integer
//! equality batch, not a float comparison.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::{TaskGraph, TaskId};
use crate::obs::{DecisionEvent, EventKind, NoopSink, Sink};
use crate::platform::Platform;
use crate::sim::{Placement, Schedule};

use super::engine::{EventQueue, Tick};
use super::OrdF64;

/// Schedule with a fixed allocation and per-task priority (higher first).
pub fn list_schedule(
    g: &TaskGraph,
    plat: &Platform,
    alloc: &[usize],
    priority: &[f64],
) -> Schedule {
    list_schedule_traced(g, plat, alloc, priority, &mut NoopSink)
}

/// [`list_schedule`] with an event sink: per task start, a ready-queue
/// depth sample (total queued across the per-type heaps) plus the
/// decision span (rule tag `list`).  With a [`NoopSink`] this *is*
/// `list_schedule`; the parity suites pin the placements bitwise.
pub fn list_schedule_traced(
    g: &TaskGraph,
    plat: &Platform,
    alloc: &[usize],
    priority: &[f64],
    sink: &mut dyn Sink,
) -> Schedule {
    let n = g.n_tasks();
    assert_eq!(alloc.len(), n);
    assert_eq!(priority.len(), n);
    let q_types = plat.n_types();
    debug_assert!(alloc.iter().all(|&q| q < q_types));

    // ready queues per type: (priority, Reverse(id)) max-heap.  The
    // priority is a *rank*, not an event time — it stays f64 (total
    // order via OrdF64) while the clock below runs in ticks.
    let mut ready: Vec<BinaryHeap<(OrdF64, Reverse<TaskId>)>> =
        (0..q_types).map(|_| BinaryHeap::new()).collect();
    // idle unit pools per type (LIFO)
    let mut idle: Vec<Vec<usize>> = plat.counts.iter().map(|&c| (0..c).collect()).collect();
    // completion events, earliest first
    let mut events = EventQueue::new();

    let mut remaining: Vec<usize> = g.preds.iter().map(|p| p.len()).collect();
    let mut placements: Vec<Option<Placement>> = vec![None; n];
    let mut finish_tick = vec![Tick::ZERO; n];
    for j in 0..n {
        if remaining[j] == 0 {
            ready[alloc[j]].push((OrdF64(priority[j]), Reverse(j)));
        }
    }

    let mut t = Tick::ZERO;
    let mut scheduled = 0usize;
    loop {
        // start everything startable at tick t
        for q in 0..q_types {
            while !idle[q].is_empty() && !ready[q].is_empty() {
                // hetlint: allow(no-panic-in-hot-path) -- loop guard checked both heaps non-empty
                let (_, Reverse(j)) = ready[q].pop().unwrap();
                // hetlint: allow(no-panic-in-hot-path) -- loop guard checked both heaps non-empty
                let unit = idle[q].pop().unwrap();
                let finish = t + Tick::quantize_cost(g.time_on(j, q));
                finish_tick[j] = finish;
                placements[j] = Some(Placement {
                    ptype: q,
                    unit,
                    start: t.to_f64(),
                    finish: finish.to_f64(),
                });
                if sink.enabled() {
                    let depth: usize = ready.iter().map(BinaryHeap::len).sum();
                    sink.emit(t.to_f64(), EventKind::Queue { scope: "list-ready", depth });
                    sink.emit(
                        t.to_f64(),
                        EventKind::Decision(DecisionEvent {
                            tenant: 0,
                            task: j,
                            policy: "List",
                            rule: "list",
                            candidates: 1,
                            tie_cluster: 1,
                            alternatives: Vec::new(),
                            restricted: Vec::new(),
                            ptype: q,
                            unit,
                            start: t.to_f64(),
                            finish: finish.to_f64(),
                        }),
                    );
                }
                events.push(finish, j);
                scheduled += 1;
            }
        }
        if scheduled == n && events.is_empty() {
            break;
        }
        // advance to the next completion(s)
        let Some((t_next, _)) = events.peek() else {
            // no events but unscheduled tasks left => deadlock (cycle)
            assert_eq!(scheduled, n, "list scheduler stalled");
            break;
        };
        t = t_next;
        while let Some((tf, j)) = events.peek() {
            if tf > t {
                break;
            }
            events.pop();
            // hetlint: allow(no-panic-in-hot-path) -- a completion event exists only for a task already placed
            let p = placements[j].as_ref().unwrap();
            idle[p.ptype].push(p.unit);
            for &s in &g.succs[j] {
                remaining[s] -= 1;
                if remaining[s] == 0 {
                    ready[alloc[s]].push((OrdF64(priority[s]), Reverse(s)));
                }
            }
        }
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

/// OLS (§4.1): List Scheduling prioritized by the allocation-aware rank.
pub fn ols_schedule(g: &TaskGraph, plat: &Platform, alloc: &[usize]) -> Schedule {
    let rank = crate::graph::paths::ols_rank(g, alloc);
    list_schedule(g, plat, alloc, &rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sim::validate;
    use crate::substrate::rng::Rng;

    #[test]
    fn independent_tasks_fill_units() {
        let mut b = Builder::new("ind");
        for _ in 0..4 {
            b.add_task("t", vec![2.0, 1.0]);
        }
        let g = b.build();
        let plat = Platform::hybrid(2, 1);
        // all on CPU: 4 tasks, 2 CPUs, 2 units of work each -> makespan 4
        let s = list_schedule(&g, &plat, &[0; 4], &[0.0; 4]);
        validate(&g, &plat, &s).unwrap();
        assert!((s.makespan - 4.0).abs() < 1e-9);
    }

    #[test]
    fn priorities_control_order() {
        let mut b = Builder::new("prio");
        for _ in 0..2 {
            b.add_task("t", vec![1.0, 1.0]);
        }
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        // both on single CPU; task 1 has higher priority -> starts first
        let s = list_schedule(&g, &plat, &[0, 0], &[1.0, 2.0]);
        assert!(s.placements[1].start < s.placements[0].start);
    }

    #[test]
    fn graham_no_unforced_idle() {
        // property: at any task start > 0, the unit was busy or no task
        // allocated to that type was ready earlier.  We spot-check via a
        // chain + parallel mix: CPU never idles while ready CPU work exists.
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let g = gen::hybrid_dag(&mut rng, 40, 0.15);
            let plat = Platform::hybrid(3, 2);
            let alloc: Vec<usize> = (0..40).map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j))).collect();
            let prio = crate::graph::paths::ols_rank(&g, &alloc);
            let s = list_schedule(&g, &plat, &alloc, &prio);
            validate(&g, &plat, &s).unwrap();
            // work-conserving bound: C_max <= W_q/m_q + CP ... (coarse)
            let loads = s.loads(2);
            let cp = crate::graph::paths::critical_path(&g, &|j| g.time_on(j, alloc[j]));
            let bound = loads[0] / 3.0 + loads[1] / 2.0 + cp;
            assert!(s.makespan <= bound + 1e-6, "{} > {}", s.makespan, bound);
        }
    }

    #[test]
    fn ols_respects_allocation() {
        let mut rng = Rng::new(9);
        let g = gen::hybrid_dag(&mut rng, 30, 0.2);
        let plat = Platform::hybrid(4, 2);
        let alloc: Vec<usize> = (0..30).map(|j| j % 2).collect();
        let s = ols_schedule(&g, &plat, &alloc);
        validate(&g, &plat, &s).unwrap();
        assert_eq!(s.allocation(), alloc);
    }

    #[test]
    fn traced_list_matches_untraced() {
        use crate::obs::{EventKind, RecordingSink};
        let mut rng = Rng::new(61);
        let g = gen::hybrid_dag(&mut rng, 40, 0.15);
        let plat = Platform::hybrid(3, 2);
        let alloc: Vec<usize> = (0..40).map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j))).collect();
        let prio = crate::graph::paths::ols_rank(&g, &alloc);
        let plain = list_schedule(&g, &plat, &alloc, &prio);
        let mut sink = RecordingSink::new();
        let traced = list_schedule_traced(&g, &plat, &alloc, &prio, &mut sink);
        assert_eq!(plain.placements, traced.placements);
        let decisions = sink
            .events()
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Decision(_)))
            .count();
        assert_eq!(decisions, 40);
    }

    #[test]
    fn chain_executes_serially() {
        let mut b = Builder::new("chain");
        let a = b.add_task("a", vec![1.0, 9.0]);
        let c = b.add_task("b", vec![2.0, 9.0]);
        let d = b.add_task("c", vec![3.0, 9.0]);
        b.add_arc(a, c);
        b.add_arc(c, d);
        let g = b.build();
        let plat = Platform::hybrid(2, 1);
        let s = ols_schedule(&g, &plat, &[0, 0, 0]);
        assert!((s.makespan - 6.0).abs() < 1e-9);
    }
}
