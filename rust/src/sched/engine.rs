//! Shared event-driven scheduling engine: the data structures every
//! scheduler in this crate selects over, with logarithmic updates where
//! the seed implementations re-scanned linearly.
//!
//! * [`UnitTree`] — an indexed min segment tree over the units of one
//!   processor type, keyed by free time.  Supports the exact queries the
//!   schedulers need in O(log c): earliest idle time, the `min_by`
//!   tie-break ("first index achieving the minimum"), and threshold
//!   queries ("first/last unit idle by time t") that reproduce the EFT
//!   ready-clamp tie-break bit-for-bit.
//! * [`UnitPool`] — one `UnitTree` per processor type.
//! * [`EstReady`] — per-type ready queues for the EST policy: tasks whose
//!   ready time is at or below the type's idle horizon collapse into one
//!   id-ordered bucket (their starting times are all the horizon), while
//!   later-ready tasks wait in a (ready_time, id) heap and are promoted
//!   as the horizon advances.  Selection over the whole ready set is
//!   O(Q log n) per step instead of O(|ready| · units).
//! * [`EventQueue`] — completion-event min-heap for list scheduling.
//! * [`GapIndex`] — per-type gap index for insertion-based EFT (HEFT
//!   backfilling): a tail min-tree over unit finish times plus per-unit
//!   sorted gap lists, near-O(log c) per decision on mostly-gapless
//!   workloads.
//! * [`Timeline`] — one unit's busy intervals with a linear first-fit
//!   scan; retained as the reference oracle structure the gap index is
//!   pinned against.
//!
//! Tie-break contract: the engine reproduces the seed semantics — both
//! exact floating-point ties (`Iterator::min_by` resolves equal keys
//! towards the *first* index, EST ties towards the smaller task id, the
//! EFT ready-clamp towards the smallest unit index) *and* the
//! reference's ±[`TIE_BAND`] float comparison band: candidates whose
//! keys differ by at most 1e-12 count as tied, exactly as the seed
//! scans' `< b - 1e-12 || (<= b + 1e-12 && id <)` comparators treat
//! them.  Values that land strictly inside the open band (distinct but
//! within 1e-12) only arise from repeated non-representable cost
//! constants summed along different paths; those ulp clusters are many
//! orders of magnitude narrower than the band, so band membership is
//! unambiguous in practice and the heap-based selection below matches
//! the seed scans candidate-for-candidate.  The golden-parity suite
//! (`rust/tests/golden_parity.rs`, including the repeated-constant
//! tie farms) pins this against the retained reference implementations
//! in [`super::reference`].

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::TaskId;

use super::OrdF64;

/// The reference schedulers' float-comparison tie band: keys within
/// ±1e-12 of each other are ties (broken by task/unit/type index rules).
pub const TIE_BAND: f64 = 1e-12;

/// Banded float equality: `a` ties `b` iff they lie within ±[`TIE_BAND`]
/// of each other.  The `no-raw-float-eq` hetlint rule requires float
/// `==`/`!=` in `sched/` and `lp/` to go through these helpers (or to
/// carry a justified suppression when a comparison is intentionally
/// exact, e.g. structural zero filters in the LP kernels).
pub fn band_eq(a: f64, b: f64) -> bool {
    (a - b).abs() <= TIE_BAND
}

/// Banded float inequality; see [`band_eq`].
pub fn band_ne(a: f64, b: f64) -> bool {
    !band_eq(a, b)
}

/// Indexed min segment tree over one processor type's units, keyed by
/// the time each unit becomes free.  All queries take finite thresholds.
#[derive(Clone, Debug)]
pub struct UnitTree {
    len: usize,
    size: usize,
    /// 1-based heap layout; leaves at `size..size + len`, padding +inf.
    tree: Vec<f64>,
}

impl UnitTree {
    pub fn new(len: usize) -> UnitTree {
        assert!(len > 0, "a processor type needs at least one unit");
        let size = len.next_power_of_two();
        let mut tree = vec![f64::INFINITY; 2 * size];
        for leaf in tree.iter_mut().skip(size).take(len) {
            *leaf = 0.0;
        }
        for i in (1..size).rev() {
            tree[i] = tree[2 * i].min(tree[2 * i + 1]);
        }
        UnitTree { len, size, tree }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Earliest time any unit is free (the type's idle horizon τ_q).
    pub fn min(&self) -> f64 {
        self.tree[1]
    }

    /// Free time of one unit.
    pub fn get(&self, unit: usize) -> f64 {
        debug_assert!(unit < self.len);
        self.tree[self.size + unit]
    }

    /// Update one unit's free time.
    pub fn set(&mut self, unit: usize, free: f64) {
        debug_assert!(unit < self.len);
        let mut i = self.size + unit;
        self.tree[i] = free;
        while i > 1 {
            i /= 2;
            self.tree[i] = self.tree[2 * i].min(self.tree[2 * i + 1]);
        }
    }

    /// Lowest unit index free by time `t`, if any.
    pub fn first_at_most(&self, t: f64) -> Option<usize> {
        if self.tree[1] > t {
            return None;
        }
        let mut i = 1;
        while i < self.size {
            i = if self.tree[2 * i] <= t { 2 * i } else { 2 * i + 1 };
        }
        Some(i - self.size)
    }

    /// Highest unit index free by time `t`, if any.
    pub fn last_at_most(&self, t: f64) -> Option<usize> {
        if self.tree[1] > t {
            return None;
        }
        let mut i = 1;
        while i < self.size {
            i = if self.tree[2 * i + 1] <= t { 2 * i + 1 } else { 2 * i };
        }
        Some(i - self.size)
    }

    /// First (lowest) unit index achieving the minimum free time — the
    /// element `Iterator::min_by` returns on ties, which is what the
    /// seed schedulers' linear scans picked.
    pub fn argmin_first(&self) -> usize {
        // hetlint: allow(no-panic-in-hot-path) -- UnitTree is built with len >= 1, so the min is always achieved
        self.first_at_most(self.min()).expect("tree is non-empty")
    }

    /// Last (highest) unit index achieving the minimum free time (the
    /// `max_by`-style tie-break; kept for policies that want to spread
    /// load away from low-index units).
    pub fn argmin_last(&self) -> usize {
        // hetlint: allow(no-panic-in-hot-path) -- UnitTree is built with len >= 1, so the min is always achieved
        self.last_at_most(self.min()).expect("tree is non-empty")
    }

    /// Earliest free time among `units` (+∞ for an empty slice) — the
    /// restricted-set form of [`Self::min`], used by the service's
    /// quota admission layer when a tenant at its held-units cap may
    /// only select among the units it already holds.  Exact min over
    /// the same leaf values the tree holds, so on the full unit set it
    /// equals [`Self::min`] bit-for-bit.
    pub fn min_over(&self, units: &[usize]) -> f64 {
        units
            .iter()
            .map(|&u| self.get(u))
            .fold(f64::INFINITY, f64::min)
    }

    /// Lowest unit in `units` (which must be ascending) free by time
    /// `t` — the restricted-set form of [`Self::first_at_most`]; on the
    /// full ascending unit set the two agree by construction.
    pub fn first_at_most_over(&self, units: &[usize], t: f64) -> Option<usize> {
        debug_assert!(units.windows(2).all(|w| w[0] < w[1]), "units must ascend");
        units.iter().copied().find(|&u| self.get(u) <= t)
    }
}

/// One [`UnitTree`] per processor type.
#[derive(Clone, Debug)]
pub struct UnitPool {
    pub types: Vec<UnitTree>,
}

impl UnitPool {
    pub fn new(counts: &[usize]) -> UnitPool {
        UnitPool {
            types: counts.iter().map(|&c| UnitTree::new(c)).collect(),
        }
    }

    pub fn n_types(&self) -> usize {
        self.types.len()
    }

    /// τ_q: earliest time a unit of type `q` is idle.
    pub fn earliest_idle(&self, q: usize) -> f64 {
        self.types[q].min()
    }

    /// Time `unit` of type `q` becomes free.
    pub fn free_at(&self, q: usize, unit: usize) -> f64 {
        self.types[q].get(unit)
    }

    /// Reserve `unit` of type `q` until `finish`: the unit is busy (its
    /// free time advances) until then.  This is the single mutation the
    /// shared-pool service mode and every online policy go through, so a
    /// pool can be threaded across many tenants' decisions.
    pub fn reserve(&mut self, q: usize, unit: usize, finish: f64) {
        debug_assert!(finish >= self.types[q].get(unit), "reservations never rewind");
        self.types[q].set(unit, finish);
    }

    /// Release `unit` of type `q` back to `free`: used when a tenant is
    /// cancelled after a reservation (rewinds the free time).
    pub fn release(&mut self, q: usize, unit: usize, free: f64) {
        self.types[q].set(unit, free);
    }
}

/// Per-type ready queues for the EST policy (see module docs).
pub struct EstReady {
    /// tasks whose ready time is at (or within [`TIE_BAND`] of) the
    /// type's idle horizon: their starting times all tie with the
    /// horizon under the reference's band comparison, so only the id
    /// orders them
    arrived: Vec<BinaryHeap<Reverse<TaskId>>>,
    /// tasks still waiting on a predecessor finish beyond the horizon's
    /// band, ordered by (ready_time, id); a BTreeSet (not a heap) so the
    /// head's ±[`TIE_BAND`] cluster can be range-scanned for the
    /// smallest id, matching the reference's banded comparator when two
    /// pending ready times differ only by summation ulps
    pending: Vec<std::collections::BTreeSet<(OrdF64, TaskId)>>,
}

impl EstReady {
    pub fn new(n_types: usize) -> EstReady {
        EstReady {
            arrived: (0..n_types).map(|_| BinaryHeap::new()).collect(),
            pending: (0..n_types).map(|_| Default::default()).collect(),
        }
    }

    /// Insert a task that just became ready; `tau` is the current idle
    /// horizon of its allocated type `q`.  A ready time within
    /// [`TIE_BAND`] of the horizon already *ties* with it in the
    /// reference comparator, so such tasks go straight to the id-ordered
    /// bucket (their true EST — `max(ready, tau)` — is restored by the
    /// caller when it starts them).
    pub fn push(&mut self, q: usize, ready: f64, tau: f64, j: TaskId) {
        if ready <= tau + TIE_BAND {
            self.arrived[q].push(Reverse(j));
        } else {
            self.pending[q].insert((OrdF64(ready), j));
        }
    }

    /// Move tasks whose ready time the advancing horizon has passed (to
    /// within the band) into the id-ordered bucket.  Call after every
    /// assignment on type `q`.
    pub fn promote(&mut self, q: usize, tau: f64) {
        while let Some(&(OrdF64(r), j)) = self.pending[q].first() {
            if r > tau + TIE_BAND {
                break;
            }
            self.pending[q].remove(&(OrdF64(r), j));
            self.arrived[q].push(Reverse(j));
        }
    }

    /// The reference comparator's winner within the pending queue of
    /// type `q`: the smallest id among the head's ±[`TIE_BAND`] cluster
    /// (ready times within the band tie, smaller id wins; everything
    /// past the band loses outright to the head).
    fn pending_best(&self, q: usize) -> Option<(OrdF64, TaskId)> {
        let &(OrdF64(r0), j0) = self.pending[q].first()?;
        let mut best = (OrdF64(r0), j0);
        for &(r, j) in self.pending[q].range(..=(OrdF64(r0 + TIE_BAND), TaskId::MAX)) {
            if j < best.1 {
                best = (r, j);
            }
        }
        Some(best)
    }

    /// Best (starting time, id) candidate on type `q` under horizon
    /// `tau`, without removing it.  Arrived tasks all start at (within
    /// the band of) `tau`; pending tasks start at their own ready time
    /// (> `tau` + band), so an arrived task always dominates when
    /// present.
    pub fn peek(&self, q: usize, tau: f64) -> Option<(f64, TaskId)> {
        if let Some(Reverse(j)) = self.arrived[q].peek().copied() {
            return Some((tau, j));
        }
        self.pending_best(q).map(|(OrdF64(r), j)| (r, j))
    }

    /// Total queued tasks across every type — the ready-queue depth
    /// sample the traced EST emits per decision.  Observability read
    /// only: selection never consults it.  (Iterator form rather than
    /// indexing: this file's no-panic indexing budget stays flat.)
    pub fn depth_total(&self) -> usize {
        self.arrived.iter().map(BinaryHeap::len).sum::<usize>()
            + self.pending.iter().map(std::collections::BTreeSet::len).sum::<usize>()
    }

    /// Remove the candidate [`Self::peek`] reported for type `q`.
    pub fn pop(&mut self, q: usize) -> Option<TaskId> {
        if let Some(Reverse(j)) = self.arrived[q].pop() {
            return Some(j);
        }
        let best = self.pending_best(q)?;
        self.pending[q].remove(&best);
        Some(best.1)
    }
}

/// Per-type gap index for insertion-based (backfilling) EFT selection —
/// the structure that takes HEFT's unit pick from O(units · intervals)
/// per task to near-O(log units) on mostly-gapless workloads.
///
/// State per unit: the *tail* (the time the unit is free after its last
/// busy interval, kept in a [`UnitTree`] over all units of the type) and
/// a sorted list of idle *gaps* `(start, end)` between busy intervals,
/// where `start` is the running max of earlier finishes (exactly the `t`
/// value [`Timeline::earliest_start`]'s scan carries into the gap) and
/// `end` is the next busy interval's start.  Units owning at least one
/// gap sit in an id-ordered set; on mostly-gapless workloads that set is
/// tiny, so a selection is one tail-tree query plus a first-fit probe
/// per *gapped* unit instead of a scan over every unit's timeline.
///
/// Tie-break contract: [`Self::best_eft`] reproduces the reference
/// timeline scan ([`super::reference::heft_schedule`]) under the same
/// ±[`TIE_BAND`] comparator and ulp-cluster assumption the other engine
/// selections rely on (see module docs): the tail-side candidate is the
/// lowest-index unit within the band of the tail clamp, gap candidates
/// are folded in with the scan's own comparator, and a unit's gap
/// candidate always beats its own tail (a gap ends strictly before the
/// tail begins).  Gap *fits* use the same `1e-12` insertion slack as
/// [`Timeline::earliest_start`]; exactness requires task durations
/// larger than that slack (every workload generator draws strictly
/// positive, far larger costs).  The golden-parity suite pins gap-index
/// HEFT against the reference scan placement-for-placement.
#[derive(Clone, Debug)]
pub struct GapIndex {
    /// per-unit free time after the last busy interval
    tails: UnitTree,
    /// per-unit idle gaps (start, end), time-ordered, positive length
    gaps: Vec<Vec<(f64, f64)>>,
    /// units currently owning at least one gap, ascending
    gapped: std::collections::BTreeSet<usize>,
}

impl GapIndex {
    pub fn new(len: usize) -> GapIndex {
        GapIndex {
            tails: UnitTree::new(len),
            gaps: vec![Vec::new(); len],
            gapped: Default::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.tails.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tails.is_empty()
    }

    /// Total gaps currently indexed (test/bench introspection).
    pub fn n_gaps(&self) -> usize {
        self.gaps.iter().map(Vec::len).sum()
    }

    /// First gap of `unit` that can host a task ready at `ready` of
    /// length `dur`; returns the start time.  Gap ends are increasing,
    /// so gaps that end before the task could finish are skipped by
    /// binary search and only genuinely plausible gaps are probed.
    fn first_fit(&self, unit: usize, ready: f64, dur: f64) -> Option<f64> {
        let gaps = &self.gaps[unit];
        let lo = gaps.partition_point(|&(_, e)| e + TIE_BAND < ready + dur);
        for &(g, e) in &gaps[lo..] {
            let start = ready.max(g);
            if start + dur <= e + TIE_BAND {
                return Some(start);
            }
        }
        None
    }

    /// Best `(eft, unit, start)` for a task ready at `ready` with
    /// duration `dur` — the candidate the reference timeline scan picks,
    /// without visiting gapless units (see type docs for the contract).
    pub fn best_eft(&self, ready: f64, dur: f64) -> (f64, usize, f64) {
        // tail candidate: the unit the scan would pick if no gap fit
        // anywhere — lowest index whose tail ties (within the band) the
        // ready/horizon clamp, exactly the online EFT clamp rule
        let tau = self.tails.min();
        let clamp = if tau <= ready + TIE_BAND { ready } else { tau };
        let ut = self
            .tails
            .first_at_most(clamp + TIE_BAND)
            // hetlint: allow(no-panic-in-hot-path) -- clamp >= tails.min() by construction, so some unit is always at most clamp + band
            .expect("idle horizon lies within its own band");
        let start_t = ready.max(self.tails.get(ut));
        let mut best = (start_t + dur, ut, start_t);
        // gap candidates, folded in with the reference scan's own
        // comparator (a fitting gap always beats the same unit's tail,
        // so per-unit semantics are preserved; the gapped set iterates
        // in ascending unit order like the scan)
        for &u in &self.gapped {
            if let Some(start) = self.first_fit(u, ready, dur) {
                let eft = start + dur;
                if eft < best.0 - TIE_BAND || (eft <= best.0 + TIE_BAND && u < best.1) {
                    best = (eft, u, start);
                }
            }
        }
        best
    }

    /// Record a placement `[start, finish)` on `unit`.  `start` must be
    /// a value [`Self::best_eft`] (or the reference scan) produced for
    /// the current state: either inside an indexed gap or at/after the
    /// unit's tail.
    pub fn insert(&mut self, unit: usize, start: f64, finish: f64) {
        let tail = self.tails.get(unit);
        if start >= tail {
            // tail placement; a late ready time opens a new gap, which
            // lands after every existing gap (gap ends are busy starts,
            // all below the old tail)
            if start > tail {
                self.gaps[unit].push((tail, start));
                self.gapped.insert(unit);
            }
            self.tails.set(unit, finish);
        } else {
            // gap placement: shrink/split the hosting gap.  A start
            // below the tail that sits in no indexed gap violates the
            // contract above — fail loudly instead of wrapping the
            // index (this is the cold path; one compare is free).
            let gaps = &mut self.gaps[unit];
            let at = gaps.partition_point(|&(g, _)| g <= start);
            assert!(
                at > 0,
                "start {start} is below unit {unit}'s tail {tail} but inside no indexed gap"
            );
            let i = at - 1;
            let (g, e) = gaps[i];
            debug_assert!(
                start >= g && finish <= e + TIE_BAND,
                "placement [{start}, {finish}) outside gap [{g}, {e})"
            );
            match (start > g, e > finish) {
                (true, true) => {
                    gaps[i] = (g, start);
                    gaps.insert(i + 1, (finish, e));
                }
                (true, false) => gaps[i] = (g, start),
                (false, true) => gaps[i] = (finish, e),
                (false, false) => {
                    gaps.remove(i);
                    if gaps.is_empty() {
                        self.gapped.remove(&unit);
                    }
                }
            }
        }
    }
}

/// Completion-event min-heap: (finish time, task), earliest first, ties
/// towards the smaller task id.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(OrdF64, TaskId)>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, finish: f64, j: TaskId) {
        self.heap.push(Reverse((OrdF64(finish), j)));
    }

    pub fn peek(&self) -> Option<(f64, TaskId)> {
        self.heap.peek().copied().map(|Reverse((OrdF64(t), j))| (t, j))
    }

    pub fn pop(&mut self) -> Option<(f64, TaskId)> {
        self.heap.pop().map(|Reverse((OrdF64(t), j))| (t, j))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// One unit's busy intervals, kept sorted by start time, with a linear
/// first-fit scan — the seed structure behind insertion-based
/// (backfilling) policies.  The engine HEFT now selects through the
/// [`GapIndex`] instead; `Timeline` is retained as the reference
/// oracle's structure ([`super::reference::heft_schedule`]) and for
/// tests, and its `earliest_start` defines the gap-fit semantics
/// (including the 1e-12 insertion slack) the gap index reproduces.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    busy: Vec<(f64, f64)>,
}

impl Timeline {
    /// Earliest start ≥ `ready` for a task of length `dur` (insertion).
    pub fn earliest_start(&self, ready: f64, dur: f64) -> f64 {
        let mut t = ready;
        for &(s, f) in &self.busy {
            if t + dur <= s + 1e-12 {
                return t;
            }
            if f > t {
                t = f;
            }
        }
        t
    }

    pub fn insert(&mut self, start: f64, finish: f64) {
        let pos = self.busy.partition_point(|&(s, _)| s < start);
        self.busy.insert(pos, (start, finish));
    }

    pub fn n_intervals(&self) -> usize {
        self.busy.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_tree_min_and_updates() {
        let mut t = UnitTree::new(5);
        assert_eq!(t.len(), 5);
        assert_eq!(t.min(), 0.0);
        for u in 0..5 {
            t.set(u, (u + 1) as f64);
        }
        assert_eq!(t.min(), 1.0);
        assert_eq!(t.get(3), 4.0);
        t.set(3, 0.5);
        assert_eq!(t.min(), 0.5);
        assert_eq!(t.argmin_first(), 3);
        assert_eq!(t.argmin_last(), 3);
    }

    #[test]
    fn unit_tree_tie_breaks_match_min_by() {
        // free times [2, 1, 1, 7]: Iterator::min_by returns the FIRST
        // minimum (index 1) on ties
        let mut t = UnitTree::new(4);
        for (u, f) in [2.0, 1.0, 1.0, 7.0].iter().enumerate() {
            t.set(u, *f);
        }
        assert_eq!(t.argmin_first(), 1);
        assert_eq!(t.argmin_last(), 2);
        let avail = [2.0, 1.0, 1.0, 7.0];
        let by_scan = avail
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(u, _)| u)
            .unwrap();
        assert_eq!(t.argmin_first(), by_scan);
    }

    #[test]
    fn unit_tree_threshold_queries() {
        let mut t = UnitTree::new(3);
        for (u, f) in [5.0, 3.0, 9.0].iter().enumerate() {
            t.set(u, *f);
        }
        assert_eq!(t.first_at_most(4.0), Some(1));
        assert_eq!(t.first_at_most(6.0), Some(0));
        assert_eq!(t.last_at_most(6.0), Some(1));
        assert_eq!(t.first_at_most(2.0), None);
        assert_eq!(t.last_at_most(9.0), Some(2));
    }

    #[test]
    fn unit_tree_restricted_set_queries_match_full_scans() {
        let mut t = UnitTree::new(5);
        for (u, f) in [4.0, 2.0, 2.0, 9.0, 1.0].iter().enumerate() {
            t.set(u, *f);
        }
        // restricted min + first-at-most over a subset
        assert_eq!(t.min_over(&[0, 3]), 4.0);
        assert_eq!(t.min_over(&[1, 2, 3]), 2.0);
        assert_eq!(t.min_over(&[]), f64::INFINITY);
        assert_eq!(t.first_at_most_over(&[1, 2, 3], 2.0), Some(1));
        assert_eq!(t.first_at_most_over(&[0, 3], 3.0), None);
        // full ascending set degenerates to the tree queries
        let all = [0, 1, 2, 3, 4];
        assert_eq!(t.min_over(&all), t.min());
        assert_eq!(t.first_at_most_over(&all, 2.0), t.first_at_most(2.0));
        assert_eq!(t.first_at_most_over(&all, 0.5), t.first_at_most(0.5));
    }

    #[test]
    fn unit_tree_non_power_of_two_padding_ignored() {
        let mut t = UnitTree::new(3);
        t.set(0, 10.0);
        t.set(1, 10.0);
        t.set(2, 10.0);
        // padding leaves are +inf and must never win a threshold query
        assert_eq!(t.min(), 10.0);
        assert_eq!(t.last_at_most(10.0), Some(2));
        assert_eq!(t.argmin_first(), 0);
    }

    #[test]
    fn unit_pool_reserve_and_release() {
        let mut pool = UnitPool::new(&[2, 1]);
        assert_eq!(pool.earliest_idle(0), 0.0);
        pool.reserve(0, 0, 5.0);
        assert_eq!(pool.free_at(0, 0), 5.0);
        assert_eq!(pool.earliest_idle(0), 0.0); // unit 1 still idle
        pool.reserve(0, 1, 3.0);
        assert_eq!(pool.earliest_idle(0), 3.0);
        pool.release(0, 0, 1.0);
        assert_eq!(pool.earliest_idle(0), 1.0);
        assert_eq!(pool.earliest_idle(1), 0.0);
    }

    #[test]
    fn est_ready_promotes_on_horizon_advance() {
        let mut r = EstReady::new(1);
        r.push(0, 0.0, 0.0, 5); // arrived
        r.push(0, 4.0, 0.0, 2); // pending (ready 4 > tau 0)
        r.push(0, 9.0, 0.0, 1); // pending
        assert_eq!(r.peek(0, 0.0), Some((0.0, 5)));
        assert_eq!(r.pop(0), Some(5));
        // horizon still 0: earliest candidate is the pending (4, 2)
        assert_eq!(r.peek(0, 0.0), Some((4.0, 2)));
        // horizon advances past 4: task 2 arrives, starts at the horizon
        r.promote(0, 6.0);
        assert_eq!(r.peek(0, 6.0), Some((6.0, 2)));
        assert_eq!(r.pop(0), Some(2));
        assert_eq!(r.peek(0, 6.0), Some((9.0, 1)));
        assert_eq!(r.pop(0), Some(1));
        assert_eq!(r.peek(0, 6.0), None);
        assert_eq!(r.pop(0), None);
    }

    #[test]
    fn est_ready_band_ties_resolve_by_id() {
        // two pending tasks mathematically tied but ulps apart: the
        // reference's ±1e-12 band makes the smaller id win even though
        // its ready time is the (negligibly) later one
        let mut r = EstReady::new(1);
        r.push(0, 10.0 + 5e-13, 0.0, 7);
        r.push(0, 10.0, 0.0, 9);
        assert_eq!(r.peek(0, 0.0), Some((10.0 + 5e-13, 7)));
        assert_eq!(r.pop(0), Some(7));
        assert_eq!(r.pop(0), Some(9));
        assert_eq!(r.pop(0), None);

        // a ready time within the band of the horizon counts as arrived
        // (id-ordered bucket), not pending
        let mut r = EstReady::new(1);
        r.push(0, 5.0 + 5e-13, 5.0, 3);
        r.push(0, 5.0, 5.0, 8);
        assert_eq!(r.pop(0), Some(3));
        assert_eq!(r.pop(0), Some(8));

        // past the band: strictly earlier ready time wins regardless of id
        let mut r = EstReady::new(1);
        r.push(0, 10.0, 0.0, 9);
        r.push(0, 10.1, 0.0, 1);
        assert_eq!(r.pop(0), Some(9));
        assert_eq!(r.pop(0), Some(1));
    }

    #[test]
    fn est_ready_arrived_orders_by_id() {
        let mut r = EstReady::new(1);
        r.push(0, 0.0, 0.0, 9);
        r.push(0, 0.0, 0.0, 3);
        r.push(0, 0.0, 0.0, 7);
        assert_eq!(r.pop(0), Some(3));
        assert_eq!(r.pop(0), Some(7));
        assert_eq!(r.pop(0), Some(9));
    }

    #[test]
    fn event_queue_orders_by_finish_then_id() {
        let mut e = EventQueue::new();
        e.push(3.0, 1);
        e.push(1.0, 2);
        e.push(1.0, 0);
        assert_eq!(e.len(), 3);
        assert_eq!(e.pop(), Some((1.0, 0)));
        assert_eq!(e.pop(), Some((1.0, 2)));
        assert_eq!(e.peek(), Some((3.0, 1)));
        assert_eq!(e.pop(), Some((3.0, 1)));
        assert!(e.is_empty());
    }

    #[test]
    fn gap_index_tail_placements_and_new_gaps() {
        let mut gi = GapIndex::new(2);
        // empty units: best EFT is ready + dur on unit 0
        assert_eq!(gi.best_eft(0.0, 3.0), (3.0, 0, 0.0));
        gi.insert(0, 0.0, 3.0);
        // unit 1 still idle at 0
        assert_eq!(gi.best_eft(0.0, 2.0), (2.0, 1, 0.0));
        gi.insert(1, 0.0, 2.0);
        // a late-ready task ties both units at the ready clamp: the
        // lowest unit index wins, and placing it opens a gap [3, 5)
        assert_eq!(gi.best_eft(5.0, 1.0), (6.0, 0, 5.0));
        gi.insert(0, 5.0, 6.0);
        assert_eq!(gi.n_gaps(), 1);
        // a 2-long task ready at 0: unit 1's tail (finish 4) beats the
        // gap candidate on unit 0 (start 3, finish 5)
        assert_eq!(gi.best_eft(0.0, 2.0), (4.0, 1, 2.0));
        gi.insert(1, 2.0, 4.0);
        assert_eq!(gi.n_gaps(), 1);
        // a 1-long task backfills into unit 0's gap [3, 5)
        assert_eq!(gi.best_eft(0.0, 1.0), (4.0, 0, 3.0));
    }

    #[test]
    fn gap_index_matches_timeline_semantics() {
        // the gap index must agree with Timeline::earliest_start on a
        // busy/gappy unit, including exact-fit gaps
        let mut tl = Timeline::default();
        let mut gi = GapIndex::new(1);
        for &(s, f) in &[(0.0, 2.0), (5.0, 7.0), (9.0, 12.0)] {
            // replay via tail/gap inserts: place at exactly (s, f)
            gi.insert(0, s, f);
            tl.insert(s, f);
        }
        assert_eq!(gi.n_gaps(), 2); // [2,5) and [7,9)
        for (ready, dur) in [
            (0.0, 3.0),  // fits [2,5) exactly
            (0.0, 4.0),  // too long for both gaps -> tail
            (2.5, 2.0),  // fits [2,5) from 2.5 exactly
            (6.0, 1.5),  // fits [7,9) from 7
            (3.0, 2.0),  // exact fit in [2,5) starting at 3
            (11.0, 1.0), // past all gaps -> tail at 12
            (0.0, 0.5),  // first gap, at its start
        ] {
            let want = tl.earliest_start(ready, dur);
            let (eft, unit, start) = gi.best_eft(ready, dur);
            assert_eq!(unit, 0);
            assert_eq!(start, want, "ready {ready} dur {dur}");
            assert_eq!(eft, want + dur);
        }
    }

    #[test]
    fn gap_index_consumed_gap_is_removed() {
        let mut gi = GapIndex::new(1);
        gi.insert(0, 0.0, 1.0);
        gi.insert(0, 4.0, 5.0); // opens [1, 4)
        assert_eq!(gi.n_gaps(), 1);
        // exact-fit consumption
        let (eft, _, start) = gi.best_eft(1.0, 3.0);
        assert_eq!((start, eft), (1.0, 4.0));
        gi.insert(0, 1.0, 4.0);
        assert_eq!(gi.n_gaps(), 0);
        // unit is gapless again: tail placement
        assert_eq!(gi.best_eft(0.0, 1.0), (6.0, 0, 5.0));
    }

    #[test]
    fn gap_index_gap_split_keeps_both_pieces() {
        let mut gi = GapIndex::new(1);
        gi.insert(0, 0.0, 1.0);
        gi.insert(0, 9.0, 10.0); // gap [1, 9)
        // placing [3, 5) splits it into [1, 3) and [5, 9)
        gi.insert(0, 3.0, 5.0);
        assert_eq!(gi.n_gaps(), 2);
        assert_eq!(gi.best_eft(0.0, 2.0), (3.0, 0, 1.0));
        assert_eq!(gi.best_eft(0.0, 3.0), (8.0, 0, 5.0));
    }

    #[test]
    fn gap_index_band_ties_go_to_lowest_unit() {
        // both units idle by the clamp: lowest index wins, like the
        // reference scan's first-minimum rule
        let mut gi = GapIndex::new(3);
        gi.insert(0, 0.0, 2.0);
        gi.insert(1, 0.0, 1.0);
        gi.insert(2, 0.0, 1.0);
        // ready 3.0 > all tails: every unit starts at 3, unit 0 wins
        assert_eq!(gi.best_eft(3.0, 1.0), (4.0, 0, 3.0));
        // ready 0: unit 1 is the first earliest-tail unit
        assert_eq!(gi.best_eft(0.0, 1.0), (2.0, 1, 1.0));
        // a gap candidate tying a tail candidate resolves by unit index
        let mut gi = GapIndex::new(2);
        gi.insert(0, 0.0, 1.0);
        gi.insert(0, 2.0, 3.0); // gap [1, 2) on unit 0
        gi.insert(1, 0.0, 1.0);
        // dur 1 ready 1: unit 0's gap start 1 ties unit 1's tail start 1
        // -> unit 0 (lower index), inside the gap
        assert_eq!(gi.best_eft(1.0, 1.0), (2.0, 0, 1.0));
    }

    #[test]
    fn timeline_insertion_finds_gaps() {
        let mut tl = Timeline::default();
        tl.insert(0.0, 2.0);
        tl.insert(5.0, 7.0);
        // a 3-long task fits in [2,5)
        assert_eq!(tl.earliest_start(0.0, 3.0), 2.0);
        // a 4-long task must go after 7
        assert_eq!(tl.earliest_start(0.0, 4.0), 7.0);
        // respects ready time
        assert_eq!(tl.earliest_start(2.5, 2.0), 2.5);
        assert_eq!(tl.n_intervals(), 2);
    }
}
