//! Shared event-driven scheduling engine: the data structures every
//! scheduler in this crate selects over, with logarithmic updates where
//! the seed implementations re-scanned linearly.
//!
//! * [`Tick`] — the engine's scaled-integer event clock.  All event and
//!   finish times in the decision core are `u64` tick counts at a fixed
//!   2⁻³³ resolution; see the type docs for the scale choice and the
//!   overflow headroom.
//! * [`UnitTree`] — an indexed min segment tree over the units of one
//!   processor type, keyed by free time.  Supports the exact queries the
//!   schedulers need in O(log c): earliest idle time, the `min_by`
//!   tie-break ("first index achieving the minimum"), threshold queries
//!   ("first/last unit idle by time t"), and restricted-set variants of
//!   both that descend the tree instead of scanning the subset.
//! * [`UnitPool`] — one `UnitTree` per processor type.
//! * [`EstReady`] — per-type ready queues for the EST policy: tasks whose
//!   ready tick is at or below the type's idle horizon collapse into one
//!   id-ordered bucket (their starting times are all the horizon), while
//!   later-ready tasks wait in a (ready_tick, id) heap and are promoted
//!   as the horizon advances.  Selection over the whole ready set is
//!   O(Q log n) per step instead of O(|ready| · units).
//! * [`EventQueue`] — completion-event min-heap for list scheduling.
//! * [`GapIndex`] — per-type gap index for insertion-based EFT (HEFT
//!   backfilling): a tail min-tree over unit finish ticks plus per-unit
//!   sorted gap lists, near-O(log c) per decision on mostly-gapless
//!   workloads.
//! * [`Timeline`] — one unit's busy intervals with a linear first-fit
//!   scan; retained as the reference oracle structure the gap index is
//!   pinned against.
//!
//! Tie-break contract: the engine reproduces the seed semantics — ties
//! resolve towards the *first* index (`Iterator::min_by` on equal keys,
//! EST ties towards the smaller task id, the EFT ready-clamp towards the
//! smallest unit index) — but equality itself is now **exact**: two
//! event times tie iff they quantize to the same tick.  The pre-Tick
//! engine carried a ±1e-12 float comparison band (`band_eq`/`TIE_BAND`)
//! through every comparator to absorb ulp drift between differently
//! associated path sums; quantization subsumes it.  Costs are quantized
//! once at decision entry, integer addition is associative, so any two
//! decision keys built from the same cost multiset are *bitwise equal* —
//! the ulp clusters the band existed for collapse to exact ties, and
//! cross-shard determinism holds by construction (no two platforms can
//! round the same sum differently).  The golden-parity suite
//! (`rust/tests/golden_parity.rs`, including the repeated-constant tie
//! farms) pins this against the retained reference implementations in
//! [`super::reference`], which apply the same quantization through
//! [`canon`]/[`canon_cost`] and compare exactly.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::TaskId;

/// Fixed-point event time: an unsigned count of 2⁻³³-second ticks.
///
/// Scale choice: `2⁻³³ ≈ 1.16e-10` sits three decades *below* the old
/// ±1e-12 tie band's practical discrimination window (distinct costs in
/// every workload generator differ by ≥ 1e-6) and three decades *above*
/// the ulp clusters the band used to absorb (repeated 0.1-style
/// constants summed along different paths drift by ≤ ~1e-13 at
/// campaign magnitudes), so quantization preserves every decision the
/// banded comparators made: genuinely distinct keys stay distinct,
/// ulp-smeared ties become exactly equal.
///
/// Overflow headroom: `2⁶⁴ / 2³³ = 2³¹ ≈ 2.1e9` time units
/// ([`MAX_TIME_UNITS`]).  The largest virtual horizon in the repo (the
/// 100k-task `Scale::Full` campaign) stays below 1e6, five decades
/// clear.  Tick addition saturates at `Tick::MAX` — associative (a
/// saturating sum is the min of the true sum and the ceiling, and min
/// commutes with addition order), so path sums are independent of
/// evaluation order, and a sum that does hit the ceiling stays an
/// absorbing "never finishes" sentinel instead of wrapping to a tiny
/// finish time.  `graph::Builder` rejects any single cost beyond the
/// headroom outright, so saturation can only arise from pathological
/// chain *sums*, where the monotone ceiling is the correct semantics.
///
/// Conversion is exact both ways for any horizon this repo can reach:
/// every tick count below 2⁵² is exactly representable as f64, so
/// `Tick::quantize(t.to_f64()) == t` round-trips bitwise and the f64
/// placements handed to sim/service/obs are exact dequantizations.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Tick(pub u64);

/// log2 of the tick rate: 33 fractional bits.
pub const TICK_SHIFT: u32 = 33;
const TICK_SCALE: f64 = (1u64 << TICK_SHIFT) as f64;

/// Largest event time (in time units) the tick clock can represent:
/// `2⁶⁴ / 2³³ = 2³¹`.  Costs at or beyond this are rejected at graph
/// construction ([`crate::graph::Builder::try_build`]); event-time
/// *sums* that exceed it saturate to [`Tick::MAX`] instead of wrapping.
pub const MAX_TIME_UNITS: f64 = (1u64 << (64 - TICK_SHIFT)) as f64;

impl Tick {
    pub const ZERO: Tick = Tick(0);
    pub const MAX: Tick = Tick(u64::MAX);

    /// Quantize a non-negative event time to the nearest tick.  The
    /// `as u64` cast saturates (Rust guarantee), so `inf` and beyond-
    /// headroom finite times land on `Tick::MAX` rather than wrapping.
    #[inline]
    pub fn quantize(t: f64) -> Tick {
        debug_assert!(!t.is_sign_negative(), "event times are non-negative");
        Tick((t * TICK_SCALE).round() as u64)
    }

    /// Quantize a task cost, clamped to at least one tick so a busy
    /// interval never degenerates to zero width (costs in every
    /// workload generator are ≥ 1e-3, so the clamp is purely
    /// defensive and never fires in practice).
    #[inline]
    pub fn quantize_cost(t: f64) -> Tick {
        Tick(Tick::quantize(t).0.max(1))
    }

    /// Exact f64 value of this tick count (see type docs).
    #[inline]
    pub fn to_f64(self) -> f64 {
        self.0 as f64 / TICK_SCALE
    }
}

impl std::ops::Add for Tick {
    type Output = Tick;
    /// Saturating: a path sum that exceeds the clock's range clamps to
    /// `Tick::MAX` (an absorbing "never finishes" sentinel) instead of
    /// debug-panicking / release-wrapping to a tiny finish time.
    #[inline]
    fn add(self, rhs: Tick) -> Tick {
        Tick(self.0.saturating_add(rhs.0))
    }
}

/// The canonical (quantized) form of an event time: what any f64 time
/// becomes after a round trip through the tick clock.  The reference
/// schedulers run on canonical values with *exact* comparators and
/// match the tick engine bit-for-bit, because sums of canonical values
/// below the 2⁵²-tick horizon are themselves exact in f64.
pub fn canon(t: f64) -> f64 {
    Tick::quantize(t).to_f64()
}

/// Canonical form of a task cost (the ≥-one-tick clamp of
/// [`Tick::quantize_cost`], dequantized).
pub fn canon_cost(t: f64) -> f64 {
    Tick::quantize_cost(t).to_f64()
}

/// Indexed min segment tree over one processor type's units, keyed by
/// the tick each unit becomes free.  All queries take finite thresholds.
#[derive(Clone, Debug)]
pub struct UnitTree {
    len: usize,
    size: usize,
    /// 1-based heap layout; leaves at `size..size + len`, padding MAX.
    tree: Vec<Tick>,
}

impl UnitTree {
    pub fn new(len: usize) -> UnitTree {
        assert!(len > 0, "a processor type needs at least one unit");
        let size = len.next_power_of_two();
        let mut tree = vec![Tick::MAX; 2 * size];
        for leaf in tree.iter_mut().skip(size).take(len) {
            *leaf = Tick::ZERO;
        }
        for i in (1..size).rev() {
            tree[i] = tree[2 * i].min(tree[2 * i + 1]);
        }
        UnitTree { len, size, tree }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Earliest tick any unit is free (the type's idle horizon τ_q).
    pub fn min(&self) -> Tick {
        self.tree[1]
    }

    /// Free tick of one unit.
    pub fn get(&self, unit: usize) -> Tick {
        debug_assert!(unit < self.len);
        self.tree[self.size + unit]
    }

    /// Update one unit's free tick.
    pub fn set(&mut self, unit: usize, free: Tick) {
        debug_assert!(unit < self.len);
        let mut i = self.size + unit;
        self.tree[i] = free;
        while i > 1 {
            i /= 2;
            self.tree[i] = self.tree[2 * i].min(self.tree[2 * i + 1]);
        }
    }

    /// Lowest unit index free by tick `t`, if any.
    pub fn first_at_most(&self, t: Tick) -> Option<usize> {
        if self.tree[1] > t {
            return None;
        }
        let mut i = 1;
        while i < self.size {
            i = if self.tree[2 * i] <= t { 2 * i } else { 2 * i + 1 };
        }
        Some(i - self.size)
    }

    /// Highest unit index free by tick `t`, if any.
    pub fn last_at_most(&self, t: Tick) -> Option<usize> {
        if self.tree[1] > t {
            return None;
        }
        let mut i = 1;
        while i < self.size {
            i = if self.tree[2 * i + 1] <= t { 2 * i + 1 } else { 2 * i };
        }
        Some(i - self.size)
    }

    /// First (lowest) unit index achieving the minimum free tick — the
    /// element `Iterator::min_by` returns on ties, which is what the
    /// seed schedulers' linear scans picked.
    pub fn argmin_first(&self) -> usize {
        // hetlint: allow(no-panic-in-hot-path) -- UnitTree is built with len >= 1, so the min is always achieved
        self.first_at_most(self.min()).expect("tree is non-empty")
    }

    /// Last (highest) unit index achieving the minimum free tick (the
    /// `max_by`-style tie-break; kept for policies that want to spread
    /// load away from low-index units).
    pub fn argmin_last(&self) -> usize {
        // hetlint: allow(no-panic-in-hot-path) -- UnitTree is built with len >= 1, so the min is always achieved
        self.last_at_most(self.min()).expect("tree is non-empty")
    }

    /// Earliest free tick among `units` (ascending; [`Tick::MAX`] for an
    /// empty slice) — the restricted-set form of [`Self::min`], used by
    /// the service's quota admission layer when a tenant at its
    /// held-units cap may only select among the units it already holds.
    ///
    /// Descends the segment tree, pruning subtrees whose minimum cannot
    /// improve the incumbent and collapsing subtrees fully covered by
    /// the restricted set to one node read, instead of the seed's
    /// O(|units|) leaf fold — quota tenants holding a dense block of
    /// units pay O(log c) per probe.  Exact min over the same leaf
    /// values the tree holds, so on the full unit set it equals
    /// [`Self::min`] bit-for-bit.
    pub fn min_over(&self, units: &[usize]) -> Tick {
        debug_assert!(units.windows(2).all(|w| w[0] < w[1]), "units must ascend");
        self.min_over_node(1, 0, self.size, units, Tick::MAX)
    }

    fn min_over_node(&self, node: usize, lo: usize, hi: usize, units: &[usize], best: Tick) -> Tick {
        if units.is_empty() || self.tree[node] >= best {
            return best;
        }
        // fully covered range: the node's min IS the restricted min here
        if units.len() == hi - lo {
            return best.min(self.tree[node]);
        }
        if hi - lo == 1 {
            // units is non-empty and within [lo, hi), so lo is a member
            return best.min(self.tree[node]);
        }
        let mid = lo + (hi - lo) / 2;
        let split = units.partition_point(|&u| u < mid);
        let best = self.min_over_node(2 * node, lo, mid, &units[..split], best);
        self.min_over_node(2 * node + 1, mid, hi, &units[split..], best)
    }

    /// Lowest unit in `units` (ascending) free by tick `t` — the
    /// restricted-set form of [`Self::first_at_most`]; on the full
    /// ascending unit set the two agree by construction.
    ///
    /// Left-to-right tree descent with subtree-minimum pruning: the
    /// first member leaf at most `t` is found without touching members
    /// in pruned subtrees, so a hit early in the unit order is O(log c)
    /// regardless of how many units the tenant holds.
    pub fn first_at_most_over(&self, units: &[usize], t: Tick) -> Option<usize> {
        debug_assert!(units.windows(2).all(|w| w[0] < w[1]), "units must ascend");
        self.first_at_most_over_node(1, 0, self.size, units, t)
    }

    fn first_at_most_over_node(
        &self,
        node: usize,
        lo: usize,
        hi: usize,
        units: &[usize],
        t: Tick,
    ) -> Option<usize> {
        if units.is_empty() || self.tree[node] > t {
            return None;
        }
        if hi - lo == 1 {
            // units non-empty within a width-1 range: lo is a member,
            // and tree[node] <= t just passed
            return Some(lo);
        }
        let mid = lo + (hi - lo) / 2;
        let split = units.partition_point(|&u| u < mid);
        self.first_at_most_over_node(2 * node, lo, mid, &units[..split], t)
            .or_else(|| self.first_at_most_over_node(2 * node + 1, mid, hi, &units[split..], t))
    }
}

/// One [`UnitTree`] per processor type.
#[derive(Clone, Debug)]
pub struct UnitPool {
    pub types: Vec<UnitTree>,
}

impl UnitPool {
    pub fn new(counts: &[usize]) -> UnitPool {
        UnitPool {
            types: counts.iter().map(|&c| UnitTree::new(c)).collect(),
        }
    }

    pub fn n_types(&self) -> usize {
        self.types.len()
    }

    /// τ_q: earliest tick a unit of type `q` is idle.
    pub fn earliest_idle(&self, q: usize) -> Tick {
        self.types[q].min()
    }

    /// Tick `unit` of type `q` becomes free.
    pub fn free_at(&self, q: usize, unit: usize) -> Tick {
        self.types[q].get(unit)
    }

    /// Reserve `unit` of type `q` until `finish`: the unit is busy (its
    /// free tick advances) until then.  This is the single mutation the
    /// shared-pool service mode and every online policy go through, so a
    /// pool can be threaded across many tenants' decisions.
    pub fn reserve(&mut self, q: usize, unit: usize, finish: Tick) {
        debug_assert!(finish >= self.types[q].get(unit), "reservations never rewind");
        self.types[q].set(unit, finish);
    }

    /// Release `unit` of type `q` back to `free`: used when a tenant is
    /// cancelled after a reservation (rewinds the free tick).
    pub fn release(&mut self, q: usize, unit: usize, free: Tick) {
        self.types[q].set(unit, free);
    }
}

/// Per-type ready queues for the EST policy (see module docs).
pub struct EstReady {
    /// tasks whose ready tick is at or below the type's idle horizon:
    /// their starting times all equal the horizon, so only the id
    /// orders them
    arrived: Vec<BinaryHeap<Reverse<TaskId>>>,
    /// tasks still waiting on a predecessor finish beyond the horizon,
    /// ordered by (ready_tick, id); equal ticks are *exact* ties (see
    /// module docs), so the heap head is already the seed comparator's
    /// winner — no band-cluster scan
    pending: Vec<BinaryHeap<Reverse<(Tick, TaskId)>>>,
}

impl EstReady {
    pub fn new(n_types: usize) -> EstReady {
        EstReady {
            arrived: (0..n_types).map(|_| BinaryHeap::new()).collect(),
            pending: (0..n_types).map(|_| BinaryHeap::new()).collect(),
        }
    }

    /// Insert a task that just became ready; `tau` is the current idle
    /// horizon of its allocated type `q`.  A ready tick at or below the
    /// horizon already starts *at* the horizon, so such tasks go
    /// straight to the id-ordered bucket.
    pub fn push(&mut self, q: usize, ready: Tick, tau: Tick, j: TaskId) {
        if ready <= tau {
            self.arrived[q].push(Reverse(j));
        } else {
            self.pending[q].push(Reverse((ready, j)));
        }
    }

    /// Move tasks whose ready tick the advancing horizon has passed into
    /// the id-ordered bucket.  Call after every assignment on type `q`.
    pub fn promote(&mut self, q: usize, tau: Tick) {
        while let Some(&Reverse((r, j))) = self.pending[q].peek() {
            if r > tau {
                break;
            }
            self.pending[q].pop();
            self.arrived[q].push(Reverse(j));
        }
    }

    /// Best (starting tick, id) candidate on type `q` under horizon
    /// `tau`, without removing it.  Arrived tasks all start at `tau`;
    /// pending tasks start at their own ready tick (> `tau`), so an
    /// arrived task always dominates when present.
    pub fn peek(&self, q: usize, tau: Tick) -> Option<(Tick, TaskId)> {
        if let Some(Reverse(j)) = self.arrived[q].peek().copied() {
            return Some((tau, j));
        }
        self.pending[q].peek().map(|&Reverse((r, j))| (r, j))
    }

    /// Total queued tasks across every type — the ready-queue depth
    /// sample the traced EST emits per decision.  Observability read
    /// only: selection never consults it.  (Iterator form rather than
    /// indexing: this file's no-panic indexing budget stays flat.)
    pub fn depth_total(&self) -> usize {
        self.arrived.iter().map(BinaryHeap::len).sum::<usize>()
            + self.pending.iter().map(BinaryHeap::len).sum::<usize>()
    }

    /// Remove the candidate [`Self::peek`] reported for type `q`.
    pub fn pop(&mut self, q: usize) -> Option<TaskId> {
        if let Some(Reverse(j)) = self.arrived[q].pop() {
            return Some(j);
        }
        self.pending[q].pop().map(|Reverse((_, j))| j)
    }
}

/// Per-type gap index for insertion-based (backfilling) EFT selection —
/// the structure that takes HEFT's unit pick from O(units · intervals)
/// per task to near-O(log units) on mostly-gapless workloads.
///
/// State per unit: the *tail* (the tick the unit is free after its last
/// busy interval, kept in a [`UnitTree`] over all units of the type) and
/// a sorted list of idle *gaps* `(start, end)` between busy intervals,
/// where `start` is the running max of earlier finishes (exactly the `t`
/// value [`Timeline::earliest_start`]'s scan carries into the gap) and
/// `end` is the next busy interval's start.  Units owning at least one
/// gap sit in an id-ordered set; on mostly-gapless workloads that set is
/// tiny, so a selection is one tail-tree query plus a first-fit probe
/// per *gapped* unit instead of a scan over every unit's timeline.
///
/// Tie-break contract: [`Self::best_eft`] reproduces the reference
/// timeline scan ([`super::reference::heft_schedule`]) under exact tick
/// equality (see module docs): the tail-side candidate is the
/// lowest-index unit whose tail equals the tail clamp, gap candidates
/// are folded in with the scan's own comparator, and a unit's gap
/// candidate always beats its own tail (a gap ends strictly before the
/// tail begins).  Gap *fits* are exact interval containment — the float
/// engine's 1e-12 insertion slack is gone with the rest of the band
/// machinery.  The golden-parity suite pins gap-index HEFT against the
/// reference scan placement-for-placement.
#[derive(Clone, Debug)]
pub struct GapIndex {
    /// per-unit free tick after the last busy interval
    tails: UnitTree,
    /// per-unit idle gaps (start, end), time-ordered, positive length
    gaps: Vec<Vec<(Tick, Tick)>>,
    /// units currently owning at least one gap, ascending
    gapped: std::collections::BTreeSet<usize>,
}

impl GapIndex {
    pub fn new(len: usize) -> GapIndex {
        GapIndex {
            tails: UnitTree::new(len),
            gaps: vec![Vec::new(); len],
            gapped: Default::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.tails.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tails.is_empty()
    }

    /// Total gaps currently indexed (test/bench introspection).
    pub fn n_gaps(&self) -> usize {
        self.gaps.iter().map(Vec::len).sum()
    }

    /// First gap of `unit` that can host a task ready at `ready` of
    /// length `dur`; returns the start tick.  Gap ends are increasing,
    /// so gaps that end before the task could finish are skipped by
    /// binary search and only genuinely plausible gaps are probed.
    fn first_fit(&self, unit: usize, ready: Tick, dur: Tick) -> Option<Tick> {
        let gaps = &self.gaps[unit];
        let lo = gaps.partition_point(|&(_, e)| e < ready + dur);
        for &(g, e) in &gaps[lo..] {
            let start = ready.max(g);
            if start + dur <= e {
                return Some(start);
            }
        }
        None
    }

    /// Best `(eft, unit, start)` for a task ready at `ready` with
    /// duration `dur` — the candidate the reference timeline scan picks,
    /// without visiting gapless units (see type docs for the contract).
    pub fn best_eft(&self, ready: Tick, dur: Tick) -> (Tick, usize, Tick) {
        // tail candidate: the unit the scan would pick if no gap fit
        // anywhere — lowest index whose tail is at most the
        // ready/horizon clamp, exactly the online EFT clamp rule
        let tau = self.tails.min();
        let clamp = if tau <= ready { ready } else { tau };
        let ut = self
            .tails
            .first_at_most(clamp)
            // hetlint: allow(no-panic-in-hot-path) -- clamp >= tails.min() by construction, so some unit is always at most clamp
            .expect("idle horizon is itself at most the clamp");
        let start_t = ready.max(self.tails.get(ut));
        let mut best = (start_t + dur, ut, start_t);
        // gap candidates, folded in with the reference scan's own
        // comparator (a fitting gap always beats the same unit's tail,
        // so per-unit semantics are preserved; the gapped set iterates
        // in ascending unit order like the scan)
        for &u in &self.gapped {
            if let Some(start) = self.first_fit(u, ready, dur) {
                let eft = start + dur;
                if eft < best.0 || (eft == best.0 && u < best.1) {
                    best = (eft, u, start);
                }
            }
        }
        best
    }

    /// Record a placement `[start, finish)` on `unit`.  `start` must be
    /// a tick [`Self::best_eft`] (or the reference scan) produced for
    /// the current state: either inside an indexed gap or at/after the
    /// unit's tail.
    pub fn insert(&mut self, unit: usize, start: Tick, finish: Tick) {
        let tail = self.tails.get(unit);
        if start >= tail {
            // tail placement; a late ready tick opens a new gap, which
            // lands after every existing gap (gap ends are busy starts,
            // all below the old tail)
            if start > tail {
                self.gaps[unit].push((tail, start));
                self.gapped.insert(unit);
            }
            self.tails.set(unit, finish);
        } else {
            // gap placement: shrink/split the hosting gap.  A start
            // below the tail that sits in no indexed gap violates the
            // contract above — fail loudly instead of wrapping the
            // index (this is the cold path; one compare is free).
            let gaps = &mut self.gaps[unit];
            let at = gaps.partition_point(|&(g, _)| g <= start);
            assert!(
                at > 0,
                "start {start:?} is below unit {unit}'s tail {tail:?} but inside no indexed gap"
            );
            let i = at - 1;
            let (g, e) = gaps[i];
            debug_assert!(
                start >= g && finish <= e,
                "placement [{start:?}, {finish:?}) outside gap [{g:?}, {e:?})"
            );
            match (start > g, e > finish) {
                (true, true) => {
                    gaps[i] = (g, start);
                    gaps.insert(i + 1, (finish, e));
                }
                (true, false) => gaps[i] = (g, start),
                (false, true) => gaps[i] = (finish, e),
                (false, false) => {
                    gaps.remove(i);
                    if gaps.is_empty() {
                        self.gapped.remove(&unit);
                    }
                }
            }
        }
    }
}

/// Completion-event min-heap: (finish tick, task), earliest first, ties
/// towards the smaller task id.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(Tick, TaskId)>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, finish: Tick, j: TaskId) {
        self.heap.push(Reverse((finish, j)));
    }

    pub fn peek(&self) -> Option<(Tick, TaskId)> {
        self.heap.peek().copied().map(|Reverse((t, j))| (t, j))
    }

    pub fn pop(&mut self) -> Option<(Tick, TaskId)> {
        self.heap.pop().map(|Reverse((t, j))| (t, j))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// One unit's busy intervals, kept sorted by start tick, with a linear
/// first-fit scan — the seed structure behind insertion-based
/// (backfilling) policies.  The engine HEFT now selects through the
/// [`GapIndex`] instead; `Timeline` is retained as the reference
/// oracle's structure ([`super::reference::heft_schedule`]) and for
/// tests, and its `earliest_start` defines the gap-fit semantics the
/// gap index reproduces (exact containment — the float version's 1e-12
/// insertion slack died with the tie band).
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    busy: Vec<(Tick, Tick)>,
}

impl Timeline {
    /// Earliest start ≥ `ready` for a task of length `dur` (insertion).
    pub fn earliest_start(&self, ready: Tick, dur: Tick) -> Tick {
        let mut t = ready;
        for &(s, f) in &self.busy {
            if t + dur <= s {
                return t;
            }
            if f > t {
                t = f;
            }
        }
        t
    }

    pub fn insert(&mut self, start: Tick, finish: Tick) {
        let pos = self.busy.partition_point(|&(s, _)| s < start);
        self.busy.insert(pos, (start, finish));
    }

    pub fn n_intervals(&self) -> usize {
        self.busy.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tk(t: f64) -> Tick {
        Tick::quantize(t)
    }

    #[test]
    fn tick_quantize_roundtrip_and_order() {
        for t in [0.0, 1.0, 0.1, 2.5, 1e4, 123.456] {
            let q = Tick::quantize(t);
            assert_eq!(Tick::quantize(q.to_f64()), q, "roundtrip at {t}");
            assert!((q.to_f64() - t).abs() <= 0.5 / (1u64 << TICK_SHIFT) as f64);
        }
        assert!(tk(1.0) < tk(1.0 + 1e-9));
        assert_eq!(tk(1.0), tk(1.0 + 1e-13), "ulp noise collapses to a tie");
        assert_eq!(tk(0.0), Tick::ZERO);
        assert_eq!(tk(1.5) + tk(2.5), tk(4.0), "integer addition is exact");
        assert!(Tick::quantize_cost(0.0) >= Tick(1), "cost clamp");
        assert_eq!(canon(2.0), 2.0);
        assert_eq!(canon_cost(3.5), 3.5);
    }

    #[test]
    fn tick_saturates_at_headroom() {
        // quantize round-trip holds right up to the headroom boundary...
        let under = MAX_TIME_UNITS - 1.0;
        let q = Tick::quantize(under);
        assert!(q < Tick::MAX);
        assert_eq!(Tick::quantize(q.to_f64()), q, "round-trip just under headroom");
        // ...and at/over the boundary the cast saturates instead of wrapping
        assert_eq!(Tick::quantize(MAX_TIME_UNITS), Tick::MAX);
        assert_eq!(Tick::quantize(1e308), Tick::MAX);
        assert_eq!(Tick::quantize(f64::INFINITY), Tick::MAX);
        // regression: Add saturates — `Tick::MAX + anything` must stay
        // MAX (the absorbing never-finishes sentinel), not wrap small
        assert_eq!(Tick::MAX + tk(1.0), Tick::MAX);
        assert_eq!(q + q, Tick::MAX, "near-boundary sum clamps, not wraps");
    }

    #[test]
    fn tick_saturating_add_preserves_finished_before() {
        // if a finishes before b (a <= b), then for any shared suffix
        // cost c the relation survives the (saturating) addition — a
        // wrapping add would invert it once b + c overflowed
        let probes = [
            tk(0.0),
            tk(1.0),
            tk(123.456),
            Tick::quantize(MAX_TIME_UNITS / 2.0),
            Tick::quantize(MAX_TIME_UNITS - 1.0),
            Tick::MAX,
        ];
        for &a in &probes {
            for &b in &probes {
                if a > b {
                    continue;
                }
                for &c in &probes {
                    assert!(a + c <= b + c, "monotone: {a:?}+{c:?} vs {b:?}+{c:?}");
                }
            }
        }
        // saturating addition stays associative: both orders reach the
        // same min(true sum, ceiling)
        for &a in &probes {
            for &b in &probes {
                for &c in &probes {
                    assert_eq!((a + b) + c, a + (b + c));
                }
            }
        }
    }

    #[test]
    fn unit_tree_min_and_updates() {
        let mut t = UnitTree::new(5);
        assert_eq!(t.len(), 5);
        assert_eq!(t.min(), Tick::ZERO);
        for u in 0..5 {
            t.set(u, tk((u + 1) as f64));
        }
        assert_eq!(t.min(), tk(1.0));
        assert_eq!(t.get(3), tk(4.0));
        t.set(3, tk(0.5));
        assert_eq!(t.min(), tk(0.5));
        assert_eq!(t.argmin_first(), 3);
        assert_eq!(t.argmin_last(), 3);
    }

    #[test]
    fn unit_tree_tie_breaks_match_min_by() {
        // free times [2, 1, 1, 7]: Iterator::min_by returns the FIRST
        // minimum (index 1) on ties
        let mut t = UnitTree::new(4);
        for (u, f) in [2.0, 1.0, 1.0, 7.0].iter().enumerate() {
            t.set(u, tk(*f));
        }
        assert_eq!(t.argmin_first(), 1);
        assert_eq!(t.argmin_last(), 2);
        let avail = [tk(2.0), tk(1.0), tk(1.0), tk(7.0)];
        let by_scan = avail
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.cmp(b.1))
            .map(|(u, _)| u)
            .unwrap();
        assert_eq!(t.argmin_first(), by_scan);
    }

    #[test]
    fn unit_tree_threshold_queries() {
        let mut t = UnitTree::new(3);
        for (u, f) in [5.0, 3.0, 9.0].iter().enumerate() {
            t.set(u, tk(*f));
        }
        assert_eq!(t.first_at_most(tk(4.0)), Some(1));
        assert_eq!(t.first_at_most(tk(6.0)), Some(0));
        assert_eq!(t.last_at_most(tk(6.0)), Some(1));
        assert_eq!(t.first_at_most(tk(2.0)), None);
        assert_eq!(t.last_at_most(tk(9.0)), Some(2));
    }

    #[test]
    fn unit_tree_restricted_set_queries_match_full_scans() {
        let mut t = UnitTree::new(5);
        for (u, f) in [4.0, 2.0, 2.0, 9.0, 1.0].iter().enumerate() {
            t.set(u, tk(*f));
        }
        // restricted min + first-at-most over a subset
        assert_eq!(t.min_over(&[0, 3]), tk(4.0));
        assert_eq!(t.min_over(&[1, 2, 3]), tk(2.0));
        assert_eq!(t.min_over(&[]), Tick::MAX);
        assert_eq!(t.first_at_most_over(&[1, 2, 3], tk(2.0)), Some(1));
        assert_eq!(t.first_at_most_over(&[0, 3], tk(3.0)), None);
        // full ascending set degenerates to the tree queries
        let all = [0, 1, 2, 3, 4];
        assert_eq!(t.min_over(&all), t.min());
        assert_eq!(t.first_at_most_over(&all, tk(2.0)), t.first_at_most(tk(2.0)));
        assert_eq!(t.first_at_most_over(&all, tk(0.5)), t.first_at_most(tk(0.5)));
    }

    #[test]
    fn unit_tree_restricted_descent_matches_linear_fold() {
        // the tree-descent restricted queries against the seed linear
        // fold, over every subset of a 6-unit tree (pinning the
        // quota-path rewrite result-for-result)
        let mut t = UnitTree::new(6);
        let frees = [5.0, 2.0, 8.0, 2.0, 1.0, 7.0];
        for (u, f) in frees.iter().enumerate() {
            t.set(u, tk(*f));
        }
        for mask in 0u32..64 {
            let units: Vec<usize> = (0..6).filter(|u| mask & (1 << u) != 0).collect();
            let fold = units
                .iter()
                .map(|&u| t.get(u))
                .fold(Tick::MAX, Tick::min);
            assert_eq!(t.min_over(&units), fold, "min mask {mask:b}");
            for thr in [0.5, 1.0, 2.0, 5.0, 9.0] {
                let scan = units.iter().copied().find(|&u| t.get(u) <= tk(thr));
                assert_eq!(
                    t.first_at_most_over(&units, tk(thr)),
                    scan,
                    "first-at-most mask {mask:b} thr {thr}"
                );
            }
        }
    }

    #[test]
    fn unit_tree_non_power_of_two_padding_ignored() {
        let mut t = UnitTree::new(3);
        t.set(0, tk(10.0));
        t.set(1, tk(10.0));
        t.set(2, tk(10.0));
        // padding leaves are MAX and must never win a threshold query
        assert_eq!(t.min(), tk(10.0));
        assert_eq!(t.last_at_most(tk(10.0)), Some(2));
        assert_eq!(t.argmin_first(), 0);
    }

    #[test]
    fn unit_pool_reserve_and_release() {
        let mut pool = UnitPool::new(&[2, 1]);
        assert_eq!(pool.earliest_idle(0), Tick::ZERO);
        pool.reserve(0, 0, tk(5.0));
        assert_eq!(pool.free_at(0, 0), tk(5.0));
        assert_eq!(pool.earliest_idle(0), Tick::ZERO); // unit 1 still idle
        pool.reserve(0, 1, tk(3.0));
        assert_eq!(pool.earliest_idle(0), tk(3.0));
        pool.release(0, 0, tk(1.0));
        assert_eq!(pool.earliest_idle(0), tk(1.0));
        assert_eq!(pool.earliest_idle(1), Tick::ZERO);
    }

    #[test]
    fn est_ready_promotes_on_horizon_advance() {
        let mut r = EstReady::new(1);
        r.push(0, tk(0.0), tk(0.0), 5); // arrived
        r.push(0, tk(4.0), tk(0.0), 2); // pending (ready 4 > tau 0)
        r.push(0, tk(9.0), tk(0.0), 1); // pending
        assert_eq!(r.peek(0, tk(0.0)), Some((tk(0.0), 5)));
        assert_eq!(r.pop(0), Some(5));
        // horizon still 0: earliest candidate is the pending (4, 2)
        assert_eq!(r.peek(0, tk(0.0)), Some((tk(4.0), 2)));
        // horizon advances past 4: task 2 arrives, starts at the horizon
        r.promote(0, tk(6.0));
        assert_eq!(r.peek(0, tk(6.0)), Some((tk(6.0), 2)));
        assert_eq!(r.pop(0), Some(2));
        assert_eq!(r.peek(0, tk(6.0)), Some((tk(9.0), 1)));
        assert_eq!(r.pop(0), Some(1));
        assert_eq!(r.peek(0, tk(6.0)), None);
        assert_eq!(r.pop(0), None);
    }

    #[test]
    fn est_ready_sub_resolution_ties_resolve_by_id() {
        // two pending tasks mathematically tied but ulps apart: both
        // quantize to the same tick, so they tie *exactly* and the
        // smaller id wins — the outcome the old ±1e-12 band produced
        // with a cluster scan now falls out of the heap order
        let mut r = EstReady::new(1);
        r.push(0, tk(10.0 + 5e-13), tk(0.0), 7);
        r.push(0, tk(10.0), tk(0.0), 9);
        assert_eq!(r.peek(0, tk(0.0)), Some((tk(10.0), 7)));
        assert_eq!(r.pop(0), Some(7));
        assert_eq!(r.pop(0), Some(9));
        assert_eq!(r.pop(0), None);

        // a ready time quantizing onto the horizon counts as arrived
        // (id-ordered bucket), not pending
        let mut r = EstReady::new(1);
        r.push(0, tk(5.0 + 5e-13), tk(5.0), 3);
        r.push(0, tk(5.0), tk(5.0), 8);
        assert_eq!(r.pop(0), Some(3));
        assert_eq!(r.pop(0), Some(8));

        // beyond tick resolution: strictly earlier ready wins regardless
        // of id
        let mut r = EstReady::new(1);
        r.push(0, tk(10.0), tk(0.0), 9);
        r.push(0, tk(10.1), tk(0.0), 1);
        assert_eq!(r.pop(0), Some(9));
        assert_eq!(r.pop(0), Some(1));
    }

    #[test]
    fn est_ready_arrived_orders_by_id() {
        let mut r = EstReady::new(1);
        r.push(0, tk(0.0), tk(0.0), 9);
        r.push(0, tk(0.0), tk(0.0), 3);
        r.push(0, tk(0.0), tk(0.0), 7);
        assert_eq!(r.pop(0), Some(3));
        assert_eq!(r.pop(0), Some(7));
        assert_eq!(r.pop(0), Some(9));
    }

    #[test]
    fn event_queue_orders_by_finish_then_id() {
        let mut e = EventQueue::new();
        e.push(tk(3.0), 1);
        e.push(tk(1.0), 2);
        e.push(tk(1.0), 0);
        assert_eq!(e.len(), 3);
        assert_eq!(e.pop(), Some((tk(1.0), 0)));
        assert_eq!(e.pop(), Some((tk(1.0), 2)));
        assert_eq!(e.peek(), Some((tk(3.0), 1)));
        assert_eq!(e.pop(), Some((tk(3.0), 1)));
        assert!(e.is_empty());
    }

    #[test]
    fn gap_index_tail_placements_and_new_gaps() {
        let mut gi = GapIndex::new(2);
        // empty units: best EFT is ready + dur on unit 0
        assert_eq!(gi.best_eft(tk(0.0), tk(3.0)), (tk(3.0), 0, tk(0.0)));
        gi.insert(0, tk(0.0), tk(3.0));
        // unit 1 still idle at 0
        assert_eq!(gi.best_eft(tk(0.0), tk(2.0)), (tk(2.0), 1, tk(0.0)));
        gi.insert(1, tk(0.0), tk(2.0));
        // a late-ready task ties both units at the ready clamp: the
        // lowest unit index wins, and placing it opens a gap [3, 5)
        assert_eq!(gi.best_eft(tk(5.0), tk(1.0)), (tk(6.0), 0, tk(5.0)));
        gi.insert(0, tk(5.0), tk(6.0));
        assert_eq!(gi.n_gaps(), 1);
        // a 2-long task ready at 0: unit 1's tail (finish 4) beats the
        // gap candidate on unit 0 (start 3, finish 5)
        assert_eq!(gi.best_eft(tk(0.0), tk(2.0)), (tk(4.0), 1, tk(2.0)));
        gi.insert(1, tk(2.0), tk(4.0));
        assert_eq!(gi.n_gaps(), 1);
        // a 1-long task backfills into unit 0's gap [3, 5)
        assert_eq!(gi.best_eft(tk(0.0), tk(1.0)), (tk(4.0), 0, tk(3.0)));
    }

    #[test]
    fn gap_index_matches_timeline_semantics() {
        // the gap index must agree with Timeline::earliest_start on a
        // busy/gappy unit, including exact-fit gaps
        let mut tl = Timeline::default();
        let mut gi = GapIndex::new(1);
        for &(s, f) in &[(0.0, 2.0), (5.0, 7.0), (9.0, 12.0)] {
            // replay via tail/gap inserts: place at exactly (s, f)
            gi.insert(0, tk(s), tk(f));
            tl.insert(tk(s), tk(f));
        }
        assert_eq!(gi.n_gaps(), 2); // [2,5) and [7,9)
        for (ready, dur) in [
            (0.0, 3.0),  // fits [2,5) exactly
            (0.0, 4.0),  // too long for both gaps -> tail
            (2.5, 2.0),  // fits [2,5) from 2.5 exactly
            (6.0, 1.5),  // fits [7,9) from 7
            (3.0, 2.0),  // exact fit in [2,5) starting at 3
            (11.0, 1.0), // past all gaps -> tail at 12
            (0.0, 0.5),  // first gap, at its start
        ] {
            let want = tl.earliest_start(tk(ready), tk(dur));
            let (eft, unit, start) = gi.best_eft(tk(ready), tk(dur));
            assert_eq!(unit, 0);
            assert_eq!(start, want, "ready {ready} dur {dur}");
            assert_eq!(eft, want + tk(dur));
        }
    }

    #[test]
    fn gap_index_consumed_gap_is_removed() {
        let mut gi = GapIndex::new(1);
        gi.insert(0, tk(0.0), tk(1.0));
        gi.insert(0, tk(4.0), tk(5.0)); // opens [1, 4)
        assert_eq!(gi.n_gaps(), 1);
        // exact-fit consumption
        let (eft, _, start) = gi.best_eft(tk(1.0), tk(3.0));
        assert_eq!((start, eft), (tk(1.0), tk(4.0)));
        gi.insert(0, tk(1.0), tk(4.0));
        assert_eq!(gi.n_gaps(), 0);
        // unit is gapless again: tail placement
        assert_eq!(gi.best_eft(tk(0.0), tk(1.0)), (tk(6.0), 0, tk(5.0)));
    }

    #[test]
    fn gap_index_gap_split_keeps_both_pieces() {
        let mut gi = GapIndex::new(1);
        gi.insert(0, tk(0.0), tk(1.0));
        gi.insert(0, tk(9.0), tk(10.0)); // gap [1, 9)
        // placing [3, 5) splits it into [1, 3) and [5, 9)
        gi.insert(0, tk(3.0), tk(5.0));
        assert_eq!(gi.n_gaps(), 2);
        assert_eq!(gi.best_eft(tk(0.0), tk(2.0)), (tk(3.0), 0, tk(1.0)));
        assert_eq!(gi.best_eft(tk(0.0), tk(3.0)), (tk(8.0), 0, tk(5.0)));
    }

    #[test]
    fn gap_index_exact_ties_go_to_lowest_unit() {
        // both units idle by the clamp: lowest index wins, like the
        // reference scan's first-minimum rule
        let mut gi = GapIndex::new(3);
        gi.insert(0, tk(0.0), tk(2.0));
        gi.insert(1, tk(0.0), tk(1.0));
        gi.insert(2, tk(0.0), tk(1.0));
        // ready 3.0 > all tails: every unit starts at 3, unit 0 wins
        assert_eq!(gi.best_eft(tk(3.0), tk(1.0)), (tk(4.0), 0, tk(3.0)));
        // ready 0: unit 1 is the first earliest-tail unit
        assert_eq!(gi.best_eft(tk(0.0), tk(1.0)), (tk(2.0), 1, tk(1.0)));
        // a gap candidate tying a tail candidate resolves by unit index
        let mut gi = GapIndex::new(2);
        gi.insert(0, tk(0.0), tk(1.0));
        gi.insert(0, tk(2.0), tk(3.0)); // gap [1, 2) on unit 0
        gi.insert(1, tk(0.0), tk(1.0));
        // dur 1 ready 1: unit 0's gap start 1 ties unit 1's tail start 1
        // -> unit 0 (lower index), inside the gap
        assert_eq!(gi.best_eft(tk(1.0), tk(1.0)), (tk(2.0), 0, tk(1.0)));
    }

    #[test]
    fn timeline_insertion_finds_gaps() {
        let mut tl = Timeline::default();
        tl.insert(tk(0.0), tk(2.0));
        tl.insert(tk(5.0), tk(7.0));
        // a 3-long task fits in [2,5)
        assert_eq!(tl.earliest_start(tk(0.0), tk(3.0)), tk(2.0));
        // a 4-long task must go after 7
        assert_eq!(tl.earliest_start(tk(0.0), tk(4.0)), tk(7.0));
        // respects ready time
        assert_eq!(tl.earliest_start(tk(2.5), tk(2.0)), tk(2.5));
        assert_eq!(tl.n_intervals(), 2);
    }
}
