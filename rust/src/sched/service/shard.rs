//! Sharded two-level service scheduler: a global admission layer
//! fanning out to `N` per-shard [`Service`] loops, each owning a
//! disjoint slice of the platform.
//!
//! # Model
//!
//! The single-loop [`Service`] serializes every irrevocable decision
//! through one [`PolicyEngine`](crate::sched::online::PolicyEngine)
//! over one [`UnitPool`](crate::sched::engine::UnitPool).  That is the
//! right semantics for the paper's on-line model, but at cluster scale
//! (1024 units, hundreds of tenants) every arrival pays a heap and
//! unit-tree whose size grows with the *whole* machine.  The sharded
//! form splits the platform into `N` disjoint slices — shard `s` owns
//! `counts[q]/N (+1 for the first `counts[q] % N` shards)` units of
//! every type `q`, so each slice is itself a valid heterogeneous
//! platform — and runs one unmodified `Service` per slice.  The global
//! layer only does admission (tenant → shard assignment), periodic
//! whole-tenant rebalancing, and stream merging:
//!
//! * **Whole tenants only.**  A tenant's DAG is admitted to exactly one
//!   shard and every one of its irrevocable decisions is taken there.
//!   Decisions never split across shards, so each shard's decision
//!   stream is exactly a single-loop service over its own submissions —
//!   all per-shard invariants (overlap-freedom, precedence, quota
//!   ledgers, cancellation rewinds) are inherited unchanged, and the
//!   per-task decision rules are the PR 5 policy engine, untouched.
//! * **Assignment** is a deterministic argmin over live normalized
//!   backlog (undecided tasks per owned unit; ties prefer the lowest
//!   shard id), a pure function of the op stream — replay == rerun
//!   holds exactly as for the single loop.
//! * **Rebalancing** runs every [`REBALANCE_EPOCH`] admissions and
//!   migrates only tenants with *zero* decisions taken: migration is a
//!   clean cancel-tombstone on the source shard (nothing to rewind)
//!   plus a fresh admit on the destination.  A tenant with even one
//!   irrevocable decision is pinned to its shard forever.
//! * **Merging**: the global decision stream is the concatenation of
//!   per-shard streams in *operational order* (each op touches one
//!   shard; drains visit shards `0..N` in order), with local tenant
//!   ids relabelled to global ones and unit indices translated by the
//!   shard's per-type base offset.  Per-shard streams stay
//!   time-monotone; the global stream is ordered by operation, which is
//!   the order the WAL makes durable — crash replay recomputes and
//!   bitwise-verifies each per-shard stream exactly as it does for the
//!   single loop (`service_net::server::Core`).
//!
//! `--shards 1` is the degenerate case: one shard owning the whole
//! platform, zero-offset translation, identity relabelling — the
//! report, metrics and trace surfaces delegate to the inner `Service`
//! directly, so single-shard output is bit-identical (report JSON
//! bytes included) to the pre-shard service loop (pinned by the
//! `service_shard` parity suite).
//!
//! Quota admission policies are interpreted against the tenant's own
//! shard slice (`share × slice_counts`, the same ceil rule as before).
//! Because slices are no larger than the machine, a tenant's concurrent
//! held units never exceed its single-loop global cap — the cross-shard
//! invariant tests pin this.

use std::collections::BTreeMap;

use crate::graph::TaskId;
use crate::obs::{Event, EventKind, Metrics, Restrict};
use crate::platform::Platform;
use crate::sim::Placement;

use super::{
    finalize_report, validate_submission, CancelOutcome, DecisionRecord, Service,
    ServiceReport, Submission,
};

/// Admissions between two rebalance passes.  Small enough that a burst
/// of lopsided arrivals is corrected within the burst, large enough
/// that assignment stays O(1) amortized.
pub const REBALANCE_EPOCH: usize = 64;

/// Most tenants moved per rebalance pass (each migration is a cancel +
/// re-admit; bounding the batch keeps epochs cheap and deterministic).
const MAX_MIGRATIONS_PER_EPOCH: usize = 4;

/// Where a global tenant currently lives.
#[derive(Clone, Copy, Debug)]
struct TenantSlot {
    shard: usize,
    local: usize,
}

/// The sharded two-level service: global admission + `N` single-loop
/// [`Service`] shards on disjoint platform slices.  Mirrors the
/// `Service` surface the daemon core drives (`admit`, `cancel`, `run`,
/// `report`, `decisions`, `placement_of`, trace/metrics), with every
/// tenant id global and every unit index translated back to the full
/// platform's numbering.
pub struct ShardedService {
    /// The full platform (shard slices partition its unit ranges).
    plat: Platform,
    shards: Vec<Service>,
    /// `base[s][q]`: global unit index of shard `s`'s first type-`q`
    /// unit (slices are contiguous per type).
    base: Vec<Vec<usize>>,
    /// Total units owned by each shard (the backlog normalizer).
    units: Vec<usize>,
    /// Global tenant table: where each global id currently lives.
    tenants: Vec<TenantSlot>,
    /// Reverse map: `local_to_global[s][local]` = global id (stale
    /// slots of migrated-away tenants keep their old id; they are
    /// tombstoned on the shard and never produce decisions).
    local_to_global: Vec<Vec<usize>>,
    /// Global copies of the admitted submissions (arrivals are the
    /// effective clamped ones, re-clamped on migration).
    subs: Vec<Submission>,
    /// True cancellations (tombstones from migration are *not* marked).
    cancelled: Vec<bool>,
    /// Undecided-task count per global tenant (0 once drained or
    /// cancelled) — the incremental load accounting.
    undecided: Vec<usize>,
    /// Undecided tasks currently assigned to each shard.
    backlog: Vec<usize>,
    /// Merged global decision stream (operational order).
    decisions: Vec<DecisionRecord>,
    /// Shard that took each merged decision (parallel to `decisions`).
    decision_shards: Vec<usize>,
    /// Per-shard count of decisions already merged.
    watermarks: Vec<usize>,
    admissions: usize,
    migrations: u64,
    /// Global trace sequence counter for the N>1 merged stream.
    seq: u64,
}

impl ShardedService {
    /// Split `plat` into `n_shards` disjoint slices and run one
    /// [`Service`] per slice.  Every shard needs at least one unit of
    /// every type, so `1 <= n_shards <= min_q counts[q]`.
    pub fn new(plat: &Platform, n_shards: usize) -> Result<ShardedService, String> {
        if n_shards == 0 {
            return Err("shards must be >= 1".to_string());
        }
        let min_count = plat.counts.iter().copied().min().unwrap_or(0);
        if n_shards > min_count {
            return Err(format!(
                "shards ({n_shards}) exceed the smallest type count ({min_count}): \
                 every shard needs at least one unit of every type"
            ));
        }
        let n_types = plat.n_types();
        let mut base = vec![vec![0usize; n_types]; n_shards];
        let mut slice_counts = vec![vec![0usize; n_types]; n_shards];
        for (q, &c) in plat.counts.iter().enumerate() {
            let (div, rem) = (c / n_shards, c % n_shards);
            let mut offset = 0;
            for s in 0..n_shards {
                let units = div + usize::from(s < rem);
                base[s][q] = offset;
                slice_counts[s][q] = units;
                offset += units;
            }
        }
        let shards: Vec<Service> = slice_counts
            .iter()
            .map(|c| Service::empty(&Platform::new(c.clone())))
            .collect();
        let units: Vec<usize> = slice_counts.iter().map(|c| c.iter().sum()).collect();
        Ok(ShardedService {
            plat: plat.clone(),
            shards,
            base,
            units,
            tenants: Vec::new(),
            local_to_global: vec![Vec::new(); n_shards],
            subs: Vec::new(),
            cancelled: Vec::new(),
            undecided: Vec::new(),
            backlog: vec![0; n_shards],
            decisions: Vec::new(),
            decision_shards: vec![],
            watermarks: vec![0; n_shards],
            admissions: 0,
            migrations: 0,
            seq: 0,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard currently owning global tenant `i`.
    pub fn shard_of(&self, i: usize) -> usize {
        self.tenants[i].shard
    }

    /// Normalized live backlog of shard `s` (undecided tasks per unit).
    fn load(&self, s: usize) -> f64 {
        self.backlog[s] as f64 / self.units[s] as f64
    }

    /// Deterministic argmin over normalized backlog; ties prefer the
    /// lowest shard id (strict `<` while scanning upward).
    fn pick_shard(&self) -> usize {
        let mut best = 0;
        for s in 1..self.shards.len() {
            if self.load(s) < self.load(best) {
                best = s;
            }
        }
        best
    }

    /// Merge shard `s`'s not-yet-merged decisions into the global
    /// stream: relabel the tenant to its global id and keep the shard
    /// id alongside (unit translation happens at the placement
    /// surfaces, which carry the unit).
    fn pull_decisions(&mut self, s: usize) {
        let all = self.shards[s].decisions();
        let fresh: Vec<DecisionRecord> = all[self.watermarks[s]..].to_vec();
        self.watermarks[s] = all.len();
        for d in fresh {
            let gid = self.local_to_global[s][d.tenant];
            self.decisions.push(DecisionRecord { tenant: gid, task: d.task, time: d.time });
            self.decision_shards.push(s);
            self.undecided[gid] -= 1;
            self.backlog[s] -= 1;
        }
    }

    /// Admit one tenant: validate against the *global* platform, assign
    /// the least-loaded shard, admit there (the shard clamps the
    /// arrival to its own virtual clock) and merge any decisions the
    /// shard took while advancing to the arrival.  Every
    /// [`REBALANCE_EPOCH`] admissions a rebalance pass runs after the
    /// admit.  Returns the global tenant id; `Err` leaves the service
    /// untouched.
    pub fn admit(&mut self, sub: Submission) -> Result<usize, String> {
        validate_submission(&self.plat, &sub)?;
        let s = self.pick_shard();
        self.admit_to(s, sub)
    }

    /// Admit a batch, grouping consecutive submissions that share an
    /// arrival window *and* an assigned shard into one
    /// [`Service::admit_batch`] call — the global layer's same-window
    /// batching, amortizing the shard's stream advance over the group.
    /// Bit-identical to admitting one at a time (pinned by the
    /// batching-parity test): when a group opens, its shard is advanced
    /// to the window immediately and fresh decisions are merged, so
    /// every later argmin sees exactly the backlog the sequential path
    /// would; groups also close at rebalance-epoch boundaries, so
    /// migrations fire between the same two admissions in either mode.
    /// All submissions are validated up front; on `Err` nothing is
    /// admitted.
    pub fn admit_batch(&mut self, subs: Vec<Submission>) -> Result<Vec<usize>, String> {
        for s in &subs {
            validate_submission(&self.plat, s)?;
        }
        let mut ids = Vec::with_capacity(subs.len());
        let mut group: Vec<Submission> = Vec::new();
        let (mut group_shard, mut group_window) = (0usize, f64::NAN);
        for sub in subs {
            let s = self.pick_shard();
            let extends = !group.is_empty()
                && s == group_shard
                && sub.arrival == group_window
                // never extend past an epoch boundary: the sequential
                // path would rebalance there, changing later argmins
                && (self.admissions + group.len()) % REBALANCE_EPOCH != 0;
            if !extends {
                let done = std::mem::take(&mut group);
                self.flush_group(group_shard, done, &mut ids);
                group_shard = s;
                group_window = sub.arrival;
                // advance the shard to the window now (exactly what the
                // sequential admit would do first) so the backlog every
                // later argmin reads is current
                let at = sub.arrival.max(self.shards[s].now());
                self.shards[s].advance_before(at);
                self.pull_decisions(s);
            }
            // provisional load so the next argmin counts this tenant;
            // flush_group reconciles before the shared tail re-adds it
            self.backlog[s] += sub.graph.n_tasks();
            group.push(sub);
        }
        let last = group_shard;
        let done = std::mem::take(&mut group);
        self.flush_group(last, done, &mut ids);
        Ok(ids)
    }

    /// Admit one buffered same-window group into shard `s` and run the
    /// per-tenant bookkeeping [`Self::admit_to`] would have done.
    fn flush_group(&mut self, s: usize, group: Vec<Submission>, ids: &mut Vec<usize>) {
        if group.is_empty() {
            return;
        }
        let sizes: Vec<usize> = group.iter().map(|g| g.graph.n_tasks()).collect();
        // drop the provisional backlog; the loop below re-adds it as
        // each tenant is recorded
        self.backlog[s] -= sizes.iter().sum::<usize>();
        let locals = self.shards[s]
            .admit_batch(group)
            .expect("validated up front");
        for (local, n_tasks) in locals.into_iter().zip(sizes) {
            let gid = self.tenants.len();
            self.tenants.push(TenantSlot { shard: s, local });
            self.local_to_global[s].push(gid);
            debug_assert_eq!(self.local_to_global[s].len() - 1, local);
            self.subs.push(self.shards[s].submissions()[local].clone());
            self.cancelled.push(false);
            self.undecided.push(n_tasks);
            self.backlog[s] += n_tasks;
            self.admissions += 1;
            if self.admissions % REBALANCE_EPOCH == 0 {
                // by the grouping rule this can only be the last member
                self.pull_decisions(s);
                self.rebalance();
            }
        }
        self.pull_decisions(s);
    }

    /// The shared tail of [`Self::admit`]/[`Self::admit_batch`]:
    /// admit into shard `s`, record the slot, account the load, pull
    /// fresh decisions and maybe rebalance.
    fn admit_to(&mut self, s: usize, sub: Submission) -> Result<usize, String> {
        let n_tasks = sub.graph.n_tasks();
        let local = self.shards[s].admit(sub)?;
        let gid = self.tenants.len();
        self.tenants.push(TenantSlot { shard: s, local });
        self.local_to_global[s].push(gid);
        debug_assert_eq!(self.local_to_global[s].len() - 1, local);
        // store the effective (clamped) submission the shard holds
        self.subs.push(self.shards[s].submissions()[local].clone());
        self.cancelled.push(false);
        self.undecided.push(n_tasks);
        self.backlog[s] += n_tasks;
        self.pull_decisions(s);
        self.admissions += 1;
        if self.admissions % REBALANCE_EPOCH == 0 {
            self.rebalance();
        }
        Ok(gid)
    }

    /// Periodic load rebalancing: migrate up to
    /// [`MAX_MIGRATIONS_PER_EPOCH`] whole tenants from the most- to the
    /// least-loaded shard, newest first, *only* tenants with zero
    /// decisions taken (an irrevocable decision pins a DAG to its
    /// shard).  Migration = clean cancel-tombstone on the source (no
    /// reservations exist to rewind) + fresh admit on the destination,
    /// and only happens when it strictly narrows the normalized load
    /// gap — a pure function of the op stream, so replay reproduces
    /// every migration exactly.
    fn rebalance(&mut self) {
        if self.shards.len() < 2 {
            return;
        }
        for _ in 0..MAX_MIGRATIONS_PER_EPOCH {
            let (mut src, mut dst) = (0, 0);
            for s in 1..self.shards.len() {
                if self.load(s) > self.load(src) {
                    src = s;
                }
                if self.load(s) < self.load(dst) {
                    dst = s;
                }
            }
            if src == dst {
                return;
            }
            let mut moved = false;
            for gid in (0..self.tenants.len()).rev() {
                let slot = self.tenants[gid];
                if slot.shard != src
                    || self.cancelled[gid]
                    || self.undecided[gid] == 0
                    || self.undecided[gid] != self.subs[gid].graph.n_tasks()
                {
                    continue;
                }
                let w = self.undecided[gid];
                let src_after = (self.backlog[src] - w) as f64 / self.units[src] as f64;
                let dst_after = (self.backlog[dst] + w) as f64 / self.units[dst] as f64;
                if src_after.max(dst_after) >= self.load(src) {
                    continue; // moving this tenant would not narrow the gap
                }
                // tombstone the source slot (zero decisions -> nothing
                // to rewind; the slot stays cancelled and is skipped at
                // every merge surface)
                let _ = self.shards[src].cancel(slot.local);
                let sub = self.subs[gid].clone();
                let local = self.shards[dst]
                    .admit(sub)
                    .expect("migrated submission was admitted before");
                self.tenants[gid] = TenantSlot { shard: dst, local };
                self.local_to_global[dst].push(gid);
                debug_assert_eq!(self.local_to_global[dst].len() - 1, local);
                // the destination re-clamps the arrival to its clock
                self.subs[gid].arrival = self.shards[dst].submissions()[local].arrival;
                self.backlog[src] -= w;
                self.backlog[dst] += w;
                self.migrations += 1;
                self.pull_decisions(dst);
                moved = true;
                break;
            }
            if !moved {
                return;
            }
        }
    }

    /// Cancel global tenant `i` on its shard (single-loop semantics,
    /// scoped to the shard's slice).  Panics on unknown or
    /// already-cancelled tenants, exactly like [`Service::cancel`].
    pub fn cancel(&mut self, i: usize) -> CancelOutcome {
        assert!(i < self.tenants.len(), "no tenant {i}");
        assert!(!self.cancelled[i], "tenant {i} cancelled twice");
        let slot = self.tenants[i];
        let out = self.shards[slot.shard].cancel(slot.local);
        self.cancelled[i] = true;
        self.backlog[slot.shard] -= self.undecided[i];
        self.undecided[i] = 0;
        CancelOutcome { tenant: i, ..out }
    }

    /// Drain every shard (ascending shard id — the deterministic
    /// operational order the merged stream and the WAL record).
    pub fn run(&mut self) {
        for s in 0..self.shards.len() {
            self.shards[s].run();
            self.pull_decisions(s);
        }
    }

    pub fn is_drained(&self) -> bool {
        self.shards.iter().all(Service::is_drained)
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// The merged global decision stream (operational order; per-shard
    /// subsequences are time-monotone).
    pub fn decisions(&self) -> &[DecisionRecord] {
        &self.decisions
    }

    /// Shard that took merged decision `i` (parallel to
    /// [`Self::decisions`]) — the WAL's per-decision shard id.
    pub fn decision_shard(&self, i: usize) -> usize {
        self.decision_shards[i]
    }

    /// Global-platform placement of tenant `i`'s task `j`: the shard's
    /// slice-local unit index translated by the shard's base offset.
    pub fn placement_of(&self, i: usize, j: TaskId) -> Option<Placement> {
        let slot = self.tenants[i];
        self.shards[slot.shard].placement_of(slot.local, j).map(|mut p| {
            p.unit += self.base[slot.shard][p.ptype];
            p
        })
    }

    pub fn n_placed(&self, i: usize) -> usize {
        let slot = self.tenants[i];
        self.shards[slot.shard].n_placed(slot.local)
    }

    /// Virtual cancel time of a *true* cancellation (migration
    /// tombstones are invisible here).
    pub fn cancelled_at(&self, i: usize) -> Option<f64> {
        if !self.cancelled[i] {
            return None;
        }
        let slot = self.tenants[i];
        self.shards[slot.shard].cancelled_at(slot.local)
    }

    /// The admitted submissions by global id (arrivals are the
    /// effective clamped ones).
    pub fn submissions(&self) -> &[Submission] {
        &self.subs
    }

    /// Build the merged report.  Single-shard services delegate to the
    /// inner [`Service::report`] (bit-identical bytes to the pre-shard
    /// loop); multi-shard services merge per-shard tenant reports —
    /// global ids, translated units, tombstones dropped — and recompute
    /// the aggregates through the same [`finalize_report`] path the
    /// single loop uses.
    pub fn report(&self, ideals: Option<&[f64]>) -> ServiceReport {
        if let Some(v) = ideals {
            assert_eq!(v.len(), self.tenants.len(), "one ideal makespan per tenant");
        }
        if self.shards.len() == 1 {
            return self.shards[0].report(ideals);
        }
        // scatter the global ideals onto shard-local slots (tombstoned
        // slots keep NaN: their stretch is discarded with the slot)
        let shard_reports: Vec<ServiceReport> = match ideals {
            None => self.shards.iter().map(|s| s.report(None)).collect(),
            Some(v) => {
                let mut per_shard: Vec<Vec<f64>> = self
                    .shards
                    .iter()
                    .map(|s| vec![f64::NAN; s.n_tenants()])
                    .collect();
                for (gid, slot) in self.tenants.iter().enumerate() {
                    per_shard[slot.shard][slot.local] = v[gid];
                }
                self.shards
                    .iter()
                    .zip(&per_shard)
                    .map(|(s, iv)| s.report(Some(iv)))
                    .collect()
            }
        };
        let mut tenants = Vec::with_capacity(self.tenants.len());
        let mut horizon = 0.0f64;
        let mut rule_counts: BTreeMap<String, u64> = BTreeMap::new();
        let mut restricted = 0u64;
        for (gid, slot) in self.tenants.iter().enumerate() {
            let mut t = shard_reports[slot.shard].tenants[slot.local].clone();
            t.tenant = gid;
            for p in &mut t.schedule.placements {
                p.unit += self.base[slot.shard][p.ptype];
            }
            if t.n_placed > 0 {
                horizon = horizon.max(t.completion);
            }
            tenants.push(t);
        }
        for r in &shard_reports {
            for (rule, n) in &r.rule_counts {
                *rule_counts.entry(rule.clone()).or_insert(0) += n;
            }
            restricted += r.restricted_decisions;
        }
        let mut report = ServiceReport {
            tenants,
            decisions: self.decisions.clone(),
            horizon,
            total_tasks: self.subs.iter().map(|s| s.graph.n_tasks()).sum(),
            mean_stretch: 0.0,
            max_stretch: 0.0,
            stretch_p99: 0.0,
            jain_index: 1.0,
            utilization: Vec::new(),
            rule_counts: rule_counts.into_iter().collect(),
            restricted_decisions: restricted,
        };
        finalize_report(&mut report, &self.plat.counts);
        report
    }

    /// Always-on counters.  Single shard: the inner service's registry,
    /// byte-identical to the pre-shard loop.  Multi-shard: global sums
    /// computed at this layer (tombstones excluded from tenant counts)
    /// plus a `shard{i}_`-prefixed copy of every shard's registry.
    pub fn metrics(&self) -> Metrics {
        if self.shards.len() == 1 {
            return self.shards[0].metrics();
        }
        let mut m = Metrics::new();
        m.add("svc_decisions", self.decisions.len() as u64);
        m.add("svc_tenants", self.tenants.len() as u64);
        m.add(
            "svc_cancelled_tenants",
            self.cancelled.iter().filter(|&&c| c).count() as u64,
        );
        m.add("svc_shards", self.shards.len() as u64);
        m.add("svc_migrations", self.migrations);
        let mut leapfrogs = 0;
        let mut restricted = 0;
        let mut rules: BTreeMap<String, u64> = BTreeMap::new();
        for (i, sh) in self.shards.iter().enumerate() {
            let sm = sh.metrics();
            leapfrogs += sm.counter("svc_leapfrogs");
            restricted += sm.counter("svc_restricted_decisions");
            for (rule, n) in sh.rule_counts() {
                *rules.entry(rule.to_string()).or_insert(0) += n;
            }
            m.merge_prefixed(&sm, &format!("shard{i}_"));
        }
        m.add("svc_leapfrogs", leapfrogs);
        m.add("svc_restricted_decisions", restricted);
        for (rule, n) in rules {
            m.add(&format!("svc_rule_{rule}"), n);
        }
        m
    }

    /// Switch on event recording in every shard (idempotent).
    pub fn enable_trace(&mut self) {
        for s in &mut self.shards {
            s.enable_trace();
        }
    }

    pub fn trace_enabled(&self) -> bool {
        self.shards.iter().any(Service::trace_enabled)
    }

    /// Emit a daemon-edge event.  Edge events describe the whole
    /// daemon, not a slice, so they ride shard 0's stream (and the
    /// single-shard path is byte-identical to the pre-shard loop).
    pub fn trace_edge(&mut self, kind: EventKind) {
        self.shards[0].trace_edge(kind);
    }

    pub fn note_decision_latency(&mut self, tenant: usize, secs: f64) {
        if let Some(slot) = self.tenants.get(tenant).copied() {
            self.shards[slot.shard].note_decision_latency(slot.local, secs);
        }
    }

    /// Drain the recorded events.  Single shard: the inner sink's
    /// stream, untouched.  Multi-shard: a stable merge of the per-shard
    /// streams by (virtual time, shard id), with tenant ids, unit
    /// indices and quota-restriction unit lists remapped to global
    /// numbering and sequence numbers reassigned by one global counter
    /// (monotone across drains, like the single sink's).
    pub fn take_trace(&mut self) -> Vec<Event> {
        if self.shards.len() == 1 {
            return self.shards[0].take_trace();
        }
        let batches: Vec<Vec<Event>> = self.shards.iter_mut().map(Service::take_trace).collect();
        let mut cursor = vec![0usize; batches.len()];
        let mut merged = Vec::with_capacity(batches.iter().map(Vec::len).sum());
        loop {
            let mut best: Option<usize> = None;
            for (s, batch) in batches.iter().enumerate() {
                let Some(ev) = batch.get(cursor[s]) else { continue };
                match best {
                    None => best = Some(s),
                    // strict < keeps the lowest shard id on vtime ties
                    Some(b) => {
                        if ev.vtime < batches[b][cursor[b]].vtime {
                            best = Some(s);
                        }
                    }
                }
            }
            let Some(s) = best else { break };
            let mut ev = batches[s][cursor[s]].clone();
            cursor[s] += 1;
            self.remap_event(s, &mut ev);
            ev.seq = self.seq;
            self.seq += 1;
            merged.push(ev);
        }
        merged
    }

    /// Rewrite a shard-local event into global numbering.
    fn remap_event(&self, s: usize, ev: &mut Event) {
        if let EventKind::Decision(d) = &mut ev.kind {
            d.tenant = self.local_to_global[s][d.tenant];
            d.unit += self.base[s][d.ptype];
            for alt in &mut d.alternatives {
                alt.unit += self.base[s][alt.ptype];
            }
            for (q, r) in d.restricted.iter_mut().enumerate() {
                if let Restrict::Only(units) = r {
                    for u in units.iter_mut() {
                        *u += self.base[s][q];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sched::online::OnlinePolicy;
    use crate::substrate::rng::Rng;

    fn one_task(cpu: f64, gpu: f64, arrival: f64) -> Submission {
        let mut b = Builder::new("one");
        b.add_task("t", vec![cpu, gpu]);
        Submission::new(b.build(), arrival, OnlinePolicy::Greedy)
    }

    #[test]
    fn slices_partition_every_type() {
        let plat = Platform::hybrid(10, 3);
        let svc = ShardedService::new(&plat, 3).unwrap();
        // type 0: 10 = 4 + 3 + 3 at bases 0, 4, 7
        assert_eq!(svc.base.iter().map(|b| b[0]).collect::<Vec<_>>(), vec![0, 4, 7]);
        // type 1: 3 = 1 + 1 + 1 at bases 0, 1, 2
        assert_eq!(svc.base.iter().map(|b| b[1]).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(svc.units, vec![5, 4, 4]);
    }

    #[test]
    fn shard_count_bounds_are_enforced() {
        let plat = Platform::hybrid(8, 2);
        assert!(ShardedService::new(&plat, 0).is_err());
        assert!(ShardedService::new(&plat, 3).is_err(), "only 2 GPUs");
        assert!(ShardedService::new(&plat, 2).is_ok());
    }

    #[test]
    fn assignment_is_argmin_over_normalized_backlog() {
        let plat = Platform::hybrid(4, 2);
        let mut svc = ShardedService::new(&plat, 2).unwrap();
        // empty loads tie -> shard 0; then shard 1 is strictly lighter
        let a = svc.admit(one_task(5.0, 50.0, 0.0)).unwrap();
        let b = svc.admit(one_task(5.0, 50.0, 0.0)).unwrap();
        assert_eq!(svc.shard_of(a), 0);
        assert_eq!(svc.shard_of(b), 1);
    }

    #[test]
    fn unit_indices_translate_to_global_numbering() {
        // two single-CPU-task tenants land on different shards; both
        // decide local CPU 0, so the second must surface as global
        // CPU 1 (shard 1's base offset)
        let plat = Platform::hybrid(2, 2);
        let mut svc = ShardedService::new(&plat, 2).unwrap();
        let a = svc.admit(one_task(1.0, 10.0, 0.0)).unwrap();
        let b = svc.admit(one_task(1.0, 10.0, 0.0)).unwrap();
        svc.run();
        let pa = svc.placement_of(a, 0).unwrap();
        let pb = svc.placement_of(b, 0).unwrap();
        assert_eq!((pa.ptype, pa.unit), (0, 0));
        assert_eq!((pb.ptype, pb.unit), (0, 1));
        // both started at 0 on *different* global units
        assert_eq!(pa.start, 0.0);
        assert_eq!(pb.start, 0.0);
    }

    #[test]
    fn rebalance_migrates_zero_decision_tenants_across_a_real_gap() {
        // 63 single-task tenants at t=0 spread evenly; the 64th
        // admission arrives at t=100 and lands on the lighter shard,
        // draining that shard's whole backlog (advance_before decides
        // its pending singles).  The epoch boundary then sees a genuine
        // gap — one shard still holds ~32 undecided singles, the other
        // ~10 — and migrates MAX_MIGRATIONS_PER_EPOCH zero-decision
        // tenants across it, without a single cancel surfacing.
        let plat = Platform::hybrid(2, 2);
        let mut svc = ShardedService::new(&plat, 2).unwrap();
        for _ in 0..(REBALANCE_EPOCH - 1) {
            svc.admit(one_task(1.0, 1.0, 0.0)).unwrap();
        }
        let mut b = Builder::new("late");
        let mut prev = None;
        for _ in 0..10 {
            let t = b.add_task("t", vec![1.0, 1.0]);
            if let Some(p) = prev {
                b.add_arc(p, t);
            }
            prev = Some(t);
        }
        svc.admit(Submission::new(b.build(), 100.0, OnlinePolicy::Greedy)).unwrap();
        let m = svc.metrics();
        assert!(
            m.counter("svc_migrations") > 0,
            "epoch boundary over a drained shard must migrate"
        );
        svc.run();
        let report = svc.report(None);
        // every task decided exactly once, despite the migrations
        assert_eq!(report.decisions.len(), (REBALANCE_EPOCH - 1) + 10);
        for t in &report.tenants {
            assert_eq!(t.n_placed, t.n_tasks, "tenant {} incomplete", t.tenant);
            assert!(t.cancelled_at.is_none(), "migration must not surface as a cancel");
        }
        let m = svc.metrics();
        assert_eq!(m.counter("svc_tenants"), REBALANCE_EPOCH as u64);
        assert_eq!(m.counter("svc_cancelled_tenants"), 0, "tombstones are not cancels");
    }

    #[test]
    fn migration_rewrites_nothing_observable() {
        // deterministic rerun: two identical runs produce identical
        // decision streams, shard assignments and reports
        let plat = Platform::hybrid(4, 2);
        let mk = || {
            let mut rng = Rng::new(0x5AAD);
            let mut svc = ShardedService::new(&plat, 2).unwrap();
            for t in 0..(REBALANCE_EPOCH + 10) {
                let g = gen::hybrid_dag(&mut rng, 1 + t % 7, 0.2);
                svc.admit(Submission::new(g, t as f64 * 0.25, OnlinePolicy::Eft)).unwrap();
            }
            svc.run();
            svc
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.decisions().len(), b.decisions().len());
        for (x, y) in a.decisions().iter().zip(b.decisions()) {
            assert_eq!((x.tenant, x.task), (y.tenant, y.task));
            assert_eq!(x.time.to_bits(), y.time.to_bits());
        }
        assert_eq!(a.decision_shards, b.decision_shards);
        for i in 0..a.n_tenants() {
            assert_eq!(a.shard_of(i), b.shard_of(i));
        }
    }

    #[test]
    fn cancel_is_scoped_to_the_owning_shard() {
        let plat = Platform::hybrid(2, 2);
        let mut svc = ShardedService::new(&plat, 2).unwrap();
        let a = svc.admit(one_task(10.0, 100.0, 0.0)).unwrap();
        let b = svc.admit(one_task(10.0, 100.0, 0.0)).unwrap();
        svc.run();
        let out = svc.cancel(a);
        assert_eq!(out.tenant, a);
        assert!(svc.cancelled_at(a).is_some());
        assert!(svc.cancelled_at(b).is_none());
        let report = svc.report(None);
        assert_eq!(report.tenants[b].n_placed, 1, "other shard untouched");
    }
}
