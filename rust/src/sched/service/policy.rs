//! Admission-control and fairness policies for the multi-tenant service.
//!
//! The service's base regime is first-come-first-served: every arrival is
//! committed the moment it enters the merged stream, and whichever tenant
//! arrived first grabs the earliest unit slots.  That is exactly the
//! paper's on-line model per tenant, but across tenants it lets one heavy
//! application starve everyone behind it — the fairness gap the
//! two-resource survey literature (Beaumont et al. 2019) flags for
//! CPU/GPU clusters.  A [`TenantPolicy`] closes it at the *admission*
//! layer, i.e. strictly above the per-task decision rules: each tenant's
//! own stream still flows in precedence order through the same
//! irrevocable [`PolicyEngine`](crate::sched::online::PolicyEngine)
//! rules, so the paper's per-tenant guarantees are untouched.
//!
//! * [`TenantPolicy::Fifo`] — the golden baseline: commit at arrival, no
//!   caps.  Bit-identical to the pre-policy service path (pinned against
//!   [`reference::run_service`](crate::sched::reference::run_service)).
//! * [`TenantPolicy::Quota`] — hard per-tenant caps on *concurrently
//!   held units* of each type.  A unit counts as held from the moment a
//!   task is (irrevocably) placed on it until the tenant's last
//!   reservation on it finishes.  An at-cap tenant may still stack work
//!   on units it already holds (queueing behind itself — "waiting"), and
//!   its decision rules fall through to the other type exactly like the
//!   paper's two-sided rules: the restricted GPU idle time feeds ER-LS
//!   Step 1, EFT compares the restricted candidates of both sides, and a
//!   zero share forbids the side outright.  Caps are enforced even when
//!   the pool is idle (predictable isolation beats work conservation
//!   here), so the quota-never-exceeded ledger invariant is
//!   unconditional.
//! * [`TenantPolicy::WeightedStretch`] — contended-window reordering:
//!   when the pool is fully busy at the head of the stream (every unit's
//!   free time lies beyond the next arrival), every competing
//!   weighted-stretch head inside that busy window would start no
//!   earlier anyway, so the service is free to admit them in fairness
//!   order instead of arrival order.  It picks the head maximizing
//!   `weight · (t − arrival) / ideal_makespan` (the tenant currently
//!   most behind, scaled by its weight), so heavy tenants can be
//!   deprioritized by assigning them a small weight.  With an idle pool
//!   — in particular for a single tenant — the window is empty and the
//!   order degrades to FIFO, which is what keeps single-tenant runs
//!   placement-identical to `sched::online`.
//!
//! Policies are per-tenant
//! ([`Submission::with_admission`](super::Submission::with_admission))
//! and mix freely: FIFO/Quota heads are never bypassed by
//! weighted-stretch reordering.

use crate::platform::Platform;

/// Per-tenant admission policy (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum TenantPolicy {
    /// Commit every arrival immediately, no caps — today's service
    /// behavior, retained as the golden baseline.
    Fifo,
    /// Hard caps on concurrently-held units per type, as fractions of
    /// the pool: the tenant may hold at most `ceil(share · count_q)`
    /// distinct units of type `q` at any instant (a zero share forbids
    /// the type).  Hybrid (CPU+GPU) platforms only.
    Quota { cpu_share: f64, gpu_share: f64 },
    /// Reorder admissions inside fully-busy windows by descending
    /// `weight · current stretch`; `weight > 1` prioritizes the tenant,
    /// `weight < 1` deprioritizes it.
    WeightedStretch { weight: f64 },
}

impl TenantPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            TenantPolicy::Fifo => "FIFO",
            TenantPolicy::Quota { .. } => "Quota",
            TenantPolicy::WeightedStretch { .. } => "WStretch",
        }
    }

    /// Validate the policy against the platform it will run on (shares
    /// in [0, 1] with at least one unit reachable, positive finite
    /// weight, quota restricted to hybrid platforms).  Panics on a bad
    /// policy; [`Self::try_validate`] is the daemon-facing form.
    pub fn validate(&self, plat: &Platform) {
        self.try_validate(plat).unwrap_or_else(|e| panic!("{e}"));
    }

    /// [`Self::validate`] returning the violation instead of panicking,
    /// so a service daemon can refuse the submission and stay up.
    pub fn try_validate(&self, plat: &Platform) -> Result<(), String> {
        match self {
            TenantPolicy::Fifo => Ok(()),
            TenantPolicy::Quota { cpu_share, gpu_share } => {
                if plat.n_types() != 2 {
                    return Err(
                        "Quota shares are defined for hybrid (CPU+GPU) platforms".into()
                    );
                }
                for share in [cpu_share, gpu_share] {
                    if !(share.is_finite() && (0.0..=1.0).contains(share)) {
                        return Err(format!("quota share {share} outside [0, 1]"));
                    }
                }
                if !(*cpu_share > 0.0 || *gpu_share > 0.0) {
                    return Err("a quota must leave at least one type usable".into());
                }
                Ok(())
            }
            TenantPolicy::WeightedStretch { weight } => {
                if !(weight.is_finite() && *weight > 0.0) {
                    return Err(format!(
                        "weighted-stretch weight {weight} must be positive"
                    ));
                }
                Ok(())
            }
        }
    }

    /// Per-type held-unit caps on `plat`, or `None` when the policy
    /// imposes none.  `cap_q = ceil(share_q · count_q)` clamped to the
    /// type's unit count; a zero share gives cap 0 (type forbidden).
    pub fn caps(&self, plat: &Platform) -> Option<Vec<usize>> {
        match self {
            TenantPolicy::Quota { cpu_share, gpu_share } => Some(
                [*cpu_share, *gpu_share]
                    .iter()
                    .zip(&plat.counts)
                    .map(|(&share, &count)| share_cap(share, count))
                    .collect(),
            ),
            _ => None,
        }
    }

    /// The reordering weight, or `None` for admission-at-arrival
    /// policies.
    pub fn weight(&self) -> Option<f64> {
        match self {
            TenantPolicy::WeightedStretch { weight } => Some(*weight),
            _ => None,
        }
    }
}

/// cap = ceil(share · count), clamped to [1, count] for positive shares;
/// 0 for a zero share (type forbidden).
fn share_cap(share: f64, count: usize) -> usize {
    if share <= 0.0 {
        0
    } else {
        ((share * count as f64).ceil() as usize).clamp(1, count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caps_round_up_and_clamp() {
        let plat = Platform::hybrid(8, 3);
        let p = TenantPolicy::Quota { cpu_share: 0.25, gpu_share: 1.0 };
        assert_eq!(p.caps(&plat), Some(vec![2, 3]));
        let p = TenantPolicy::Quota { cpu_share: 0.01, gpu_share: 0.0 };
        // tiny positive share still grants one unit; zero share forbids
        assert_eq!(p.caps(&plat), Some(vec![1, 0]));
        assert_eq!(TenantPolicy::Fifo.caps(&plat), None);
        assert_eq!(
            TenantPolicy::WeightedStretch { weight: 2.0 }.caps(&plat),
            None
        );
    }

    #[test]
    fn weight_accessor() {
        assert_eq!(TenantPolicy::Fifo.weight(), None);
        assert_eq!(
            TenantPolicy::WeightedStretch { weight: 0.5 }.weight(),
            Some(0.5)
        );
    }

    #[test]
    fn validate_accepts_sane_policies() {
        let plat = Platform::hybrid(4, 2);
        TenantPolicy::Fifo.validate(&plat);
        TenantPolicy::Quota { cpu_share: 0.5, gpu_share: 0.0 }.validate(&plat);
        TenantPolicy::WeightedStretch { weight: 3.0 }.validate(&plat);
    }

    #[test]
    #[should_panic(expected = "outside [0, 1]")]
    fn validate_rejects_oversized_share() {
        TenantPolicy::Quota { cpu_share: 1.5, gpu_share: 0.5 }.validate(&Platform::hybrid(4, 2));
    }

    #[test]
    #[should_panic(expected = "at least one type")]
    fn validate_rejects_all_zero_shares() {
        TenantPolicy::Quota { cpu_share: 0.0, gpu_share: 0.0 }.validate(&Platform::hybrid(4, 2));
    }

    #[test]
    #[should_panic(expected = "must be positive")]
    fn validate_rejects_zero_weight() {
        TenantPolicy::WeightedStretch { weight: 0.0 }.validate(&Platform::hybrid(4, 2));
    }

    #[test]
    #[should_panic(expected = "hybrid")]
    fn validate_rejects_quota_on_three_types() {
        TenantPolicy::Quota { cpu_share: 0.5, gpu_share: 0.5 }
            .validate(&Platform::new(vec![2, 2, 2]));
    }
}
