//! The scheduling phase: given (or while deciding) an allocation, place
//! tasks on units over time.
//!
//! * [`list`] — allocation-respecting List Scheduling (Graham) with an
//!   arbitrary priority; OLS (§4.1) is this with the HLP-rank priority.
//! * [`est`] — the Earliest Starting Time policy of HLP-EST (§3).
//! * [`heft`] — HEFT with insertion-based backfilling (§3), Q-type ready.
//! * [`online`] — the online engine (§4.2): ER-LS, EFT, Greedy, Random
//!   and the R1/R2/R3 rules, with irrevocable decisions.

pub mod est;
pub mod heft;
pub mod list;
pub mod online;

/// Total order wrapper for f64 priorities (NaN-free by construction).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN priority")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordf64_orders() {
        let mut v = vec![OrdF64(3.0), OrdF64(1.0), OrdF64(2.0)];
        v.sort();
        assert_eq!(v, vec![OrdF64(1.0), OrdF64(2.0), OrdF64(3.0)]);
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn ordf64_rejects_nan() {
        let _ = OrdF64(f64::NAN).cmp(&OrdF64(1.0));
    }
}
