//! The scheduling phase: given (or while deciding) an allocation, place
//! tasks on units over time.
//!
//! * [`engine`] — the shared event-driven core: per-type unit trees,
//!   split ready queues, completion-event heaps, and insertion
//!   timelines.  Every scheduler below selects through it.
//! * [`list`] — allocation-respecting List Scheduling (Graham) with an
//!   arbitrary priority; OLS (§4.1) is this with the HLP-rank priority.
//! * [`est`] — the Earliest Starting Time policy of HLP-EST (§3).
//! * [`heft`] — HEFT with insertion-based backfilling (§3), Q-type ready.
//! * [`online`] — the online engine (§4.2): ER-LS, EFT, Greedy, Random
//!   and the R1/R2/R3 rules, with irrevocable decisions taken through
//!   the shared [`online::PolicyEngine`].
//! * [`service`] — the multi-tenant streaming service mode: many task
//!   graphs arriving over virtual time into one shared unit pool, each
//!   tenant's stream flowing through the same irrevocable policies.
//! * [`reference`] — the pre-engine (seed) implementations, kept as the
//!   golden-parity oracle and the perf baseline.
//!
//! # Complexity
//!
//! With n tasks, E precedence arcs, Q processor types and c units per
//! type (c = max_q m_q):
//!
//! | scheduler         | engine-backed              | reference (seed)      |
//! |-------------------|----------------------------|-----------------------|
//! | `est_schedule`    | O((n + E) log n)           | O(n · (ready + c))    |
//! | `list_schedule`   | O((n + E) log n)           | O((n + E) log n)      |
//! | `online_schedule` | O((n + E) + n·Q·log c)     | O((n + E) + n·Q·c)    |
//! | `heft_schedule`   | O(n·Q·(log c + G log n/c)) | O(n · Q · c · gaps)   |
//!
//! HEFT's insertion-based EFT rides the per-type [`engine::GapIndex`]:
//! a tail min-tree answers the no-gap case in O(log c), and only the G
//! units currently owning idle gaps are probed (first-fit over their
//! sorted gap lists).  Mostly-gapless workloads keep G near zero, so
//! selection is near-O(log c) per task instead of the reference's scan
//! over every unit's timeline; gap-heavy adversarial workloads degrade
//! gracefully back to the reference cost, never worse.
//!
//! # The tick clock
//!
//! Event time in the engine (and therefore in every scheduler above) is
//! the [`engine::Tick`] fixed-point counter: `Tick(u64)` at 2⁻³³ time
//! units per tick ([`engine::TICK_SHIFT`] = 33).  Costs and ready times
//! quantize once at decision/admission entry (`round`-to-nearest;
//! nonzero costs clamp to ≥ 1 tick) and every comparator in the hot
//! path is an exact integer compare — the former ±1e-12 float tie band
//! and its `band_eq` clustering are gone entirely.  Two event times tie
//! iff they quantize to the same tick: sub-resolution differences
//! (≲ 5.8e-11) collapse onto one tick, anything larger separates.
//! Headroom: `u64::MAX` ticks ≈ 2.1e9 time units before overflow, and
//! round-tripping `Tick -> f64 -> Tick` is exact below 2⁵² ticks, so
//! the f64 values crossing the public API boundary (placements, sinks,
//! [`online::PolicyEngine`]) are lossless tick-canonical multiples of
//! 2⁻³³ — f64 adds and maxes of such values are themselves exact below
//! 2⁵³ ticks, which is what lets the f64 [`reference`] bodies match the
//! integer engine bit-for-bit.
//!
//! Tie-breaks are preserved exactly for exact tick ties (see `engine`
//! docs); `rust/tests/golden_parity.rs` pins engine-vs-reference
//! schedule equality across random instances.

pub mod engine;
pub mod est;
pub mod heft;
pub mod list;
pub mod online;
pub mod reference;
pub mod service;

/// Total order wrapper for f64 priorities.
///
/// Backed by `f64::total_cmp`, so even a NaN priority (which
/// `graph::Builder` already rejects at the cost level, but rank
/// arithmetic could in principle produce) orders deterministically
/// instead of panicking mid-schedule.  All priorities in this repo are
/// non-negative finite values, for which total_cmp agrees exactly with
/// the old `partial_cmp` ordering — golden parity is unaffected.
#[derive(Clone, Copy, Debug)]
pub struct OrdF64(pub f64);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == std::cmp::Ordering::Equal
    }
}

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    // hetlint: allow(float-total-order) -- required trait method; delegates to the total_cmp-backed Ord below
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordf64_orders() {
        let mut v = vec![OrdF64(3.0), OrdF64(1.0), OrdF64(2.0)];
        v.sort();
        assert_eq!(v, vec![OrdF64(1.0), OrdF64(2.0), OrdF64(3.0)]);
    }

    #[test]
    fn ordf64_totally_orders_nan() {
        // A NaN priority must order deterministically, never panic:
        // total_cmp puts positive NaN above +inf.
        let mut v = vec![OrdF64(f64::NAN), OrdF64(1.0), OrdF64(f64::INFINITY)];
        v.sort();
        assert_eq!(v[0], OrdF64(1.0));
        assert_eq!(v[1], OrdF64(f64::INFINITY));
        assert!(v[2].0.is_nan());
        assert_eq!(OrdF64(f64::NAN).cmp(&OrdF64(f64::NAN)), std::cmp::Ordering::Equal);
    }
}
