//! HEFT — Heterogeneous Earliest Finish Time (Topcuoglu et al.), the
//! single-phase baseline of §3, without communication costs and
//! generalized to Q resource types (QHEFT in §6.2).
//!
//! Tasks are prioritized by the average-processing-time upward rank
//! `rank(j) = (Σ_q m_q p_{j,q})/(Σ_q m_q) + max_succ rank`, then placed
//! one by one on the unit minimizing the *earliest finish time*, with
//! insertion-based backfilling (a task may slot into an idle gap).
//! Ties between a CPU and a GPU go to the GPU (the paper's Theorem 1
//! convention); ties within a type go to the lowest unit index.  Finish
//! times are [`engine::Tick`] counts, so a tie is *exact* tick equality
//! — the seed's ad-hoc 1e-9 band and the interim engine-wide ±1e-12
//! band are both gone; two EFTs tie iff their quantized values are
//! equal (sub-resolution differences of ≲ 5.8e-11 collapse onto one
//! tick, anything larger separates).
//!
//! Selection rides the [`engine::GapIndex`]: a tail min-tree over unit
//! finish ticks plus per-unit sorted gap lists, so each decision costs
//! O(Q (log c + |gapped units|)) instead of scanning every unit's
//! timeline — near-O(log c) on mostly-gapless workloads, and what makes
//! 100k-task / 256-unit `Scale::Full` campaigns tractable.  Placements
//! are pinned bit-identical to the retained reference scan
//! ([`super::reference::heft_schedule`]) by the golden-parity suite.

use crate::graph::{paths, TaskGraph};
use crate::obs::{DecisionEvent, EventKind, NoopSink, Sink};
use crate::platform::Platform;
use crate::sim::{Placement, Schedule};

use super::engine::{GapIndex, Tick};

/// HEFT / QHEFT schedule.
pub fn heft_schedule(g: &TaskGraph, plat: &Platform) -> Schedule {
    heft_schedule_traced(g, plat, &mut NoopSink)
}

/// [`heft_schedule`] with an event sink: per decision, a gap-index
/// probe sample (how many idle gaps the chosen type's index holds) plus
/// the decision span (rule tag `heft`, per-type candidate count,
/// exact-tie cluster size).  With a [`NoopSink`] this *is*
/// `heft_schedule`; the parity suites pin the placements bitwise.
pub fn heft_schedule_traced(g: &TaskGraph, plat: &Platform, sink: &mut dyn Sink) -> Schedule {
    let n = g.n_tasks();
    let rank = paths::heft_rank(g, &plat.counts);
    let mut order: Vec<usize> = (0..n).collect();
    // non-increasing rank; ties by id for determinism
    order.sort_by(|&a, &b| rank[b].total_cmp(&rank[a]).then(a.cmp(&b)));

    let mut index: Vec<GapIndex> = plat.counts.iter().map(|&c| GapIndex::new(c)).collect();
    let mut finish_tick = vec![Tick::ZERO; n];
    let mut placements: Vec<Option<Placement>> = vec![None; n];

    for &j in &order {
        let ready = g.preds[j]
            .iter()
            .map(|&p| finish_tick[p])
            .fold(Tick::ZERO, Tick::max);
        // choose (type, unit) minimizing EFT; exact tick tie -> larger
        // type index (GPU over CPU), then lower unit index.  Types
        // ascend, so the reference comparator's `q > b_q` arm is always
        // true for a later type: an equal EFT means replace.
        let mut best: Option<(Tick, usize, usize, Tick)> = None; // (eft, q, unit, start)
        let mut tie_cluster = 1usize;
        for q in 0..plat.n_types() {
            let dur = Tick::quantize_cost(g.time_on(j, q));
            let (eft, unit, start) = index[q].best_eft(ready, dur);
            let better = match best {
                None => true,
                Some((b_eft, _, _, _)) => {
                    // attribution bookkeeping only; the comparator is
                    // the reference's, unchanged
                    if eft == b_eft {
                        tie_cluster += 1;
                    } else if eft < b_eft {
                        tie_cluster = 1;
                    }
                    eft <= b_eft
                }
            };
            if better {
                best = Some((eft, q, unit, start));
            }
        }
        // hetlint: allow(no-panic-in-hot-path) -- n_types >= 1, so the loop above always sets best
        let (eft, q, unit, start) = best.unwrap();
        if sink.enabled() {
            // .get() rather than indexing: this file's no-panic
            // indexing budget stays flat
            let gaps = index.get(q).map_or(0, GapIndex::n_gaps);
            sink.emit(start.to_f64(), EventKind::GapProbe { task: j, ptype: q, gaps });
        }
        index[q].insert(unit, start, eft);
        if sink.enabled() {
            sink.emit(
                start.to_f64(),
                EventKind::Decision(DecisionEvent {
                    tenant: 0,
                    task: j,
                    policy: "HEFT",
                    rule: "heft",
                    candidates: plat.n_types(),
                    tie_cluster,
                    alternatives: Vec::new(),
                    restricted: Vec::new(),
                    ptype: q,
                    unit,
                    start: start.to_f64(),
                    finish: eft.to_f64(),
                }),
            );
        }
        finish_tick[j] = eft;
        placements[j] = Some(Placement {
            ptype: q,
            unit,
            start: start.to_f64(),
            finish: eft.to_f64(),
        });
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sched::reference;
    use crate::sim::validate;
    use crate::substrate::rng::Rng;

    #[test]
    fn heft_prefers_faster_unit() {
        let mut b = Builder::new("x");
        b.add_task("a", vec![10.0, 1.0]);
        let g = b.build();
        let plat = Platform::hybrid(2, 1);
        let s = heft_schedule(&g, &plat);
        assert_eq!(s.placements[0].ptype, 1);
        assert!((s.makespan - 1.0).abs() < 1e-12);
    }

    #[test]
    fn heft_tie_goes_to_gpu() {
        let mut b = Builder::new("tie");
        b.add_task("a", vec![2.0, 2.0]);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s = heft_schedule(&g, &plat);
        assert_eq!(s.placements[0].ptype, 1);
    }

    #[test]
    fn ties_are_exact_at_tick_resolution() {
        // under the interim float engine a ±1e-12 band decided what
        // "tied" meant; under the tick clock the quantizer does.  A
        // 1e-10 EFT difference is ≈ 0.86 ticks and rounds the two costs
        // to *different* ticks: the earlier finish (the CPU) wins.  A
        // 1e-13 difference quantizes onto the same tick: exact tie ->
        // GPU, the Theorem-1 convention.  Same outcomes the band
        // produced, now by construction (reference updated together,
        // per the ROADMAP golden-parity protocol).
        let mut b = Builder::new("band");
        b.add_task("a", vec![1.0, 1.0 + 1e-10]);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s = heft_schedule(&g, &plat);
        assert_eq!(s.placements[0].ptype, 0, "1e-10 is beyond tick resolution");
        let r = reference::heft_schedule(&g, &plat);
        assert_eq!(s.placements, r.placements);
        // a 1e-13 difference is inside one tick: still a tie -> GPU
        let mut b = Builder::new("band2");
        b.add_task("a", vec![1.0, 1.0 + 1e-13]);
        let g = b.build();
        let s = heft_schedule(&g, &plat);
        assert_eq!(s.placements[0].ptype, 1, "1e-13 stays a tie");
        assert_eq!(s.placements, reference::heft_schedule(&g, &plat).placements);
    }

    #[test]
    fn heft_backfills_into_gaps() {
        // big runs on GPU [0,1); its successor `late` runs on CPU [1,2);
        // the low-rank `tiny` must backfill into the CPU idle gap [0,1)
        // instead of queueing at t=2.
        let mut b = Builder::new("gap");
        let big = b.add_task("big", vec![10.0, 1.0]);
        let late = b.add_task("late", vec![1.0, 10.0]);
        b.add_task("tiny", vec![1.0, 2.0]);
        b.add_arc(big, late);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s = heft_schedule(&g, &plat);
        validate(&g, &plat, &s).unwrap();
        assert_eq!(s.placements[2].ptype, 0);
        assert_eq!(s.placements[2].start, 0.0, "tiny should backfill");
        assert!((s.makespan - 2.0).abs() < 1e-9, "makespan {}", s.makespan);
    }

    #[test]
    fn heft_valid_on_random_dags_2_and_3_types() {
        let mut rng = Rng::new(31);
        for _ in 0..10 {
            let g = gen::hybrid_dag(&mut rng, 60, 0.08);
            let plat = Platform::hybrid(4, 2);
            let s = heft_schedule(&g, &plat);
            validate(&g, &plat, &s).unwrap();
            assert_eq!(s.placements, reference::heft_schedule(&g, &plat).placements);
        }
        for _ in 0..5 {
            let g = gen::random_dag(&mut rng, 40, 0.1, 3);
            let plat = Platform::new(vec![4, 2, 2]);
            let s = heft_schedule(&g, &plat);
            validate(&g, &plat, &s).unwrap();
            assert_eq!(s.placements, reference::heft_schedule(&g, &plat).placements);
        }
    }

    #[test]
    fn traced_heft_matches_untraced() {
        use crate::obs::{EventKind, RecordingSink};
        let mut rng = Rng::new(43);
        let g = gen::hybrid_dag(&mut rng, 60, 0.08);
        let plat = Platform::hybrid(4, 2);
        let plain = heft_schedule(&g, &plat);
        let mut sink = RecordingSink::new();
        let traced = heft_schedule_traced(&g, &plat, &mut sink);
        assert_eq!(plain.placements, traced.placements);
        let events = sink.take();
        let decisions = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Decision(_)))
            .count();
        let probes = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::GapProbe { .. }))
            .count();
        assert_eq!((decisions, probes), (60, 60), "one span + one probe per task");
    }

    #[test]
    fn heft_beats_serial_on_parallel_work() {
        let mut b = Builder::new("par");
        for _ in 0..8 {
            b.add_task("t", vec![1.0, 1.0]);
        }
        let g = b.build();
        let plat = Platform::hybrid(4, 4);
        let s = heft_schedule(&g, &plat);
        assert!((s.makespan - 1.0).abs() < 1e-9);
    }
}
