//! HEFT — Heterogeneous Earliest Finish Time (Topcuoglu et al.), the
//! single-phase baseline of §3, without communication costs and
//! generalized to Q resource types (QHEFT in §6.2).
//!
//! Tasks are prioritized by the average-processing-time upward rank
//! `rank(j) = (Σ_q m_q p_{j,q})/(Σ_q m_q) + max_succ rank`, then placed
//! one by one on the unit minimizing the *earliest finish time*, with
//! insertion-based backfilling (a task may slot into an idle gap).
//! Ties between a CPU and a GPU go to the GPU (the paper's Theorem 1
//! convention); ties within a type go to the lowest unit index.
//!
//! Built on the shared [`engine::Timeline`].  Unlike the EST/OLS/online
//! schedulers, insertion-based EFT must inspect every unit's gap
//! structure per task (a min-heap over tail times cannot see gaps), so
//! HEFT's selection remains O(n · units); the engine refactor shares the
//! timeline plumbing rather than changing the asymptotics.

use crate::graph::{paths, TaskGraph};
use crate::platform::Platform;
use crate::sim::{Placement, Schedule};

use super::engine::Timeline;

/// HEFT / QHEFT schedule.
pub fn heft_schedule(g: &TaskGraph, plat: &Platform) -> Schedule {
    let n = g.n_tasks();
    let rank = paths::heft_rank(g, &plat.counts);
    let mut order: Vec<usize> = (0..n).collect();
    // non-increasing rank; ties by id for determinism
    order.sort_by(|&a, &b| rank[b].total_cmp(&rank[a]).then(a.cmp(&b)));

    let mut timelines: Vec<Vec<Timeline>> = plat
        .counts
        .iter()
        .map(|&c| vec![Timeline::default(); c])
        .collect();
    let mut placements: Vec<Option<Placement>> = vec![None; n];

    for &j in &order {
        let ready = g.preds[j]
            .iter()
            .map(|&p| placements[p].expect("rank order is topological").finish)
            .fold(0.0f64, f64::max);
        // choose (type, unit) minimizing EFT; tie -> larger type index
        // (GPU over CPU), then lower unit index
        let mut best: Option<(f64, usize, usize, f64)> = None; // (eft, q, unit, start)
        for q in 0..plat.n_types() {
            let dur = g.time_on(j, q);
            for (u, tl) in timelines[q].iter().enumerate() {
                let start = tl.earliest_start(ready, dur);
                let eft = start + dur;
                let better = match best {
                    None => true,
                    Some((b_eft, b_q, _, _)) => {
                        eft < b_eft - 1e-9 || (eft <= b_eft + 1e-9 && q > b_q)
                    }
                };
                if better {
                    best = Some((eft, q, u, start));
                }
            }
        }
        let (eft, q, unit, start) = best.unwrap();
        timelines[q][unit].insert(start, eft);
        placements[j] = Some(Placement {
            ptype: q,
            unit,
            start,
            finish: eft,
        });
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sim::validate;
    use crate::substrate::rng::Rng;

    #[test]
    fn heft_prefers_faster_unit() {
        let mut b = Builder::new("x");
        b.add_task("a", vec![10.0, 1.0]);
        let g = b.build();
        let plat = Platform::hybrid(2, 1);
        let s = heft_schedule(&g, &plat);
        assert_eq!(s.placements[0].ptype, 1);
        assert!((s.makespan - 1.0).abs() < 1e-12);
    }

    #[test]
    fn heft_tie_goes_to_gpu() {
        let mut b = Builder::new("tie");
        b.add_task("a", vec![2.0, 2.0]);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s = heft_schedule(&g, &plat);
        assert_eq!(s.placements[0].ptype, 1);
    }

    #[test]
    fn heft_backfills_into_gaps() {
        // big runs on GPU [0,1); its successor `late` runs on CPU [1,2);
        // the low-rank `tiny` must backfill into the CPU idle gap [0,1)
        // instead of queueing at t=2.
        let mut b = Builder::new("gap");
        let big = b.add_task("big", vec![10.0, 1.0]);
        let late = b.add_task("late", vec![1.0, 10.0]);
        b.add_task("tiny", vec![1.0, 2.0]);
        b.add_arc(big, late);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s = heft_schedule(&g, &plat);
        validate(&g, &plat, &s).unwrap();
        assert_eq!(s.placements[2].ptype, 0);
        assert_eq!(s.placements[2].start, 0.0, "tiny should backfill");
        assert!((s.makespan - 2.0).abs() < 1e-9, "makespan {}", s.makespan);
    }

    #[test]
    fn heft_valid_on_random_dags_2_and_3_types() {
        let mut rng = Rng::new(31);
        for _ in 0..10 {
            let g = gen::hybrid_dag(&mut rng, 60, 0.08);
            let plat = Platform::hybrid(4, 2);
            let s = heft_schedule(&g, &plat);
            validate(&g, &plat, &s).unwrap();
        }
        for _ in 0..5 {
            let g = gen::random_dag(&mut rng, 40, 0.1, 3);
            let plat = Platform::new(vec![4, 2, 2]);
            let s = heft_schedule(&g, &plat);
            validate(&g, &plat, &s).unwrap();
        }
    }

    #[test]
    fn heft_beats_serial_on_parallel_work() {
        let mut b = Builder::new("par");
        for _ in 0..8 {
            b.add_task("t", vec![1.0, 1.0]);
        }
        let g = b.build();
        let plat = Platform::hybrid(4, 4);
        let s = heft_schedule(&g, &plat);
        assert!((s.makespan - 1.0).abs() < 1e-9);
    }
}
