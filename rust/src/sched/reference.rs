//! Reference schedulers: the pre-engine implementations, retained
//! verbatim (quadratic selection loops and all) as the oracle for the
//! golden-parity suite (`rust/tests/golden_parity.rs`) and as the
//! baseline the perf bench (`benches/perf_hot_paths.rs`) measures the
//! engine speedup against.  [`run_service`] freezes the *pre-policy*
//! multi-tenant service path the same way: the FIFO admission baseline
//! the service's policy layer is pinned against.
//!
//! Do NOT "optimize" these: their value is being the old behavior.  The
//! only changes from the seed code are `f64::total_cmp` in place of the
//! panic-prone `partial_cmp(..).unwrap()` chains (identical ordering on
//! the finite, NaN-free values the graph builder now enforces) and the
//! *canonical-time protocol* that replaced the tie bands when the engine
//! moved to the [`Tick`](super::engine::Tick) fixed-point clock (a
//! deliberate, CHANGES.md-flagged update made together with that engine
//! change, per the ROADMAP golden-parity protocol):
//!
//! * every event-time quantity (task durations, ready times) passes
//!   through [`canon`]/[`canon_cost`] — quantize to the 2⁻³³ tick grid,
//!   dequantize — once at decision entry;
//! * comparators are *exact* (`<` / `==`), with no ±ε band anywhere.
//!
//! Canonical values are integer multiples of 2⁻³³ well below 2⁵³ ticks,
//! so the f64 adds and maxes in these naive bodies are exact and the
//! selection loops order candidates identically to the engine's integer
//! compares — same ties, same winners, bit-equal placements.  Rule-side
//! selection (R1/R2/R3, Greedy, ER-LS Step 2) still reads the raw float
//! costs: those are allocation rules over processing-time ratios, not
//! event-time comparisons, and the engine applies the same split.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::alloc;
use crate::graph::{TaskGraph, TaskId};
use crate::platform::Platform;
use crate::sim::{Placement, Schedule};
use crate::substrate::rng::Rng;

use super::engine::{canon, canon_cost, Tick, Timeline};
use super::online::OnlinePolicy;
use super::OrdF64;

/// Reference HEFT: insertion-based EFT with the per-task scan over every
/// unit's [`Timeline`] — the oracle the gap-indexed engine HEFT
/// ([`super::heft::heft_schedule`]) is pinned against.
///
/// The shared [`Timeline`] container is tick-typed, so this body runs
/// its scan directly in tick space (quantize once per decision, exactly
/// where the engine does); the *selection structure* — a full
/// (type × unit) timeline scan per task — is still the seed's.  The EFT
/// comparator is the exact `eft < best || (eft == best && q > b_q)`:
/// ties are exact tick equality, GPU-most type wins, first (lowest)
/// unit within a type wins.
pub fn heft_schedule(g: &TaskGraph, plat: &Platform) -> Schedule {
    let n = g.n_tasks();
    let rank = crate::graph::paths::heft_rank(g, &plat.counts);
    let mut order: Vec<usize> = (0..n).collect();
    // non-increasing rank; ties by id for determinism
    order.sort_by(|&a, &b| rank[b].total_cmp(&rank[a]).then(a.cmp(&b)));

    let mut timelines: Vec<Vec<Timeline>> = plat
        .counts
        .iter()
        .map(|&c| vec![Timeline::default(); c])
        .collect();
    let mut finish_tick = vec![Tick::ZERO; n];
    let mut placements: Vec<Option<Placement>> = vec![None; n];

    for &j in &order {
        let ready = g.preds[j]
            .iter()
            .map(|&p| finish_tick[p])
            .fold(Tick::ZERO, Tick::max);
        // choose (type, unit) minimizing EFT; tie -> larger type index
        // (GPU over CPU), then lower unit index
        let mut best: Option<(Tick, usize, usize, Tick)> = None; // (eft, q, unit, start)
        for q in 0..plat.n_types() {
            let dur = Tick::quantize_cost(g.time_on(j, q));
            for (u, tl) in timelines[q].iter().enumerate() {
                let start = tl.earliest_start(ready, dur);
                let eft = start + dur;
                let better = match best {
                    None => true,
                    Some((b_eft, b_q, _, _)) => eft < b_eft || (eft == b_eft && q > b_q),
                };
                if better {
                    best = Some((eft, q, u, start));
                }
            }
        }
        let (eft, q, unit, start) = best.unwrap();
        timelines[q][unit].insert(start, eft);
        finish_tick[j] = eft;
        placements[j] = Some(Placement {
            ptype: q,
            unit,
            start: start.to_f64(),
            finish: eft.to_f64(),
        });
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

/// Seed EST: O(n · (|ready| + units)) selection per instance, on
/// canonical times with exact comparators.
pub fn est_schedule(g: &TaskGraph, plat: &Platform, alloc: &[usize]) -> Schedule {
    let n = g.n_tasks();
    assert_eq!(alloc.len(), n);

    // per-type unit free times (linear scan: unit counts are small)
    let mut unit_free: Vec<Vec<f64>> =
        plat.counts.iter().map(|&c| vec![0.0f64; c]).collect();
    let mut remaining: Vec<usize> = g.preds.iter().map(|p| p.len()).collect();
    let mut ready_time = vec![0.0f64; n];
    let mut ready: Vec<TaskId> = (0..n).filter(|&j| remaining[j] == 0).collect();
    let mut placements: Vec<Option<Placement>> = vec![None; n];

    for _ in 0..n {
        // pick the ready task with the earliest possible start; all
        // times are canonical, so the comparison is exact
        let mut best: Option<(f64, TaskId, usize)> = None; // (est, task, ready-slot)
        for (slot, &j) in ready.iter().enumerate() {
            let q = alloc[j];
            let avail = unit_free[q].iter().copied().fold(f64::INFINITY, f64::min);
            let est = ready_time[j].max(avail);
            let better = match best {
                None => true,
                Some((b_est, b_j, _)) => est < b_est || (est == b_est && j < b_j),
            };
            if better {
                best = Some((est, j, slot));
            }
        }
        let (est, j, slot) = best.expect("ready set empty with tasks remaining");
        ready.swap_remove(slot);
        let q = alloc[j];
        // unit achieving the earliest start
        let (unit, _) = unit_free[q]
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let start = est;
        let finish = start + canon_cost(g.time_on(j, q));
        unit_free[q][unit] = finish;
        placements[j] = Some(Placement {
            ptype: q,
            unit,
            start,
            finish,
        });
        for &s in &g.succs[j] {
            ready_time[s] = ready_time[s].max(finish);
            remaining[s] -= 1;
            if remaining[s] == 0 {
                ready.push(s);
            }
        }
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

/// Seed list scheduler (identical algorithm to `sched::list`, retained
/// so the parity suite compares two independently-maintained bodies).
pub fn list_schedule(
    g: &TaskGraph,
    plat: &Platform,
    alloc: &[usize],
    priority: &[f64],
) -> Schedule {
    let n = g.n_tasks();
    assert_eq!(alloc.len(), n);
    assert_eq!(priority.len(), n);
    let q_types = plat.n_types();
    debug_assert!(alloc.iter().all(|&q| q < q_types));

    // ready queues per type: (priority, Reverse(id)) max-heap
    let mut ready: Vec<BinaryHeap<(OrdF64, Reverse<TaskId>)>> =
        (0..q_types).map(|_| BinaryHeap::new()).collect();
    // idle unit pools per type
    let mut idle: Vec<Vec<usize>> = plat.counts.iter().map(|&c| (0..c).collect()).collect();
    // completion events: Reverse((finish, task))
    let mut events: BinaryHeap<Reverse<(OrdF64, TaskId)>> = BinaryHeap::new();

    let mut remaining: Vec<usize> = g.preds.iter().map(|p| p.len()).collect();
    let mut placements: Vec<Option<Placement>> = vec![None; n];
    for j in 0..n {
        if remaining[j] == 0 {
            ready[alloc[j]].push((OrdF64(priority[j]), Reverse(j)));
        }
    }

    let mut t = 0.0f64;
    let mut scheduled = 0usize;
    loop {
        // start everything startable at time t
        for q in 0..q_types {
            while !idle[q].is_empty() && !ready[q].is_empty() {
                let (_, Reverse(j)) = ready[q].pop().unwrap();
                let unit = idle[q].pop().unwrap();
                let finish = t + canon_cost(g.time_on(j, q));
                placements[j] = Some(Placement {
                    ptype: q,
                    unit,
                    start: t,
                    finish,
                });
                events.push(Reverse((OrdF64(finish), j)));
                scheduled += 1;
            }
        }
        if scheduled == n && events.is_empty() {
            break;
        }
        // advance to the next completion(s); canonical times, so the
        // same-batch test below is an exact equality
        let Some(Reverse((OrdF64(t_next), _))) = events.peek().copied() else {
            // no events but unscheduled tasks left => deadlock (cycle)
            assert_eq!(scheduled, n, "list scheduler stalled");
            break;
        };
        t = t_next;
        while let Some(Reverse((OrdF64(tf), j))) = events.peek().copied() {
            if tf > t {
                break;
            }
            events.pop();
            let p = placements[j].unwrap();
            idle[p.ptype].push(p.unit);
            for &s in &g.succs[j] {
                remaining[s] -= 1;
                if remaining[s] == 0 {
                    ready[alloc[s]].push((OrdF64(priority[s]), Reverse(s)));
                }
            }
        }
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

/// Seed OLS: seed list scheduling with the HLP-rank priority.
pub fn ols_schedule(g: &TaskGraph, plat: &Platform, alloc: &[usize]) -> Schedule {
    let rank = crate::graph::paths::ols_rank(g, alloc);
    list_schedule(g, plat, alloc, &rank)
}

/// Machine state of the seed online engine: flat per-unit availability
/// vectors with O(units) scans per decision.
struct State {
    avail: Vec<Vec<f64>>,
}

impl State {
    fn earliest_idle(&self, q: usize) -> f64 {
        self.avail[q].iter().copied().fold(f64::INFINITY, f64::min)
    }

    fn best_unit(&self, q: usize) -> usize {
        self.avail[q]
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(u, _)| u)
            .unwrap()
    }
}

/// Seed online engine: O(units) linear scans per arrival.
pub fn online_schedule(
    g: &TaskGraph,
    plat: &Platform,
    order: &[TaskId],
    policy: &OnlinePolicy,
) -> Schedule {
    let n = g.n_tasks();
    assert_eq!(order.len(), n, "arrival order must cover all tasks");
    let two_types = plat.n_types() == 2;
    if matches!(
        policy,
        OnlinePolicy::ErLs | OnlinePolicy::R1 | OnlinePolicy::R2 | OnlinePolicy::R3
    ) {
        assert!(two_types, "{} is defined for hybrid platforms", policy.name());
    }

    let mut st = State {
        avail: plat.counts.iter().map(|&c| vec![0.0f64; c]).collect(),
    };
    let mut rng = match policy {
        OnlinePolicy::Random(seed) => Some(Rng::new(*seed)),
        _ => None,
    };
    let mut placements: Vec<Option<Placement>> = vec![None; n];
    let mut seen = vec![false; n];

    for &j in order {
        // arrival must respect precedences; the fold is over canonical
        // finishes, and canon() is the decision-entry quantization —
        // the same boundary the engine's decide() applies
        let ready = canon(
            g.preds[j]
                .iter()
                .map(|&p| {
                    placements[p]
                        .unwrap_or_else(|| panic!("order not topological: {p} after {j}"))
                        .finish
                })
                .fold(0.0f64, f64::max),
        );
        debug_assert!(!seen[j]);
        seen[j] = true;

        // choose (type, unit)
        let (q, unit) = match policy {
            OnlinePolicy::ErLs => {
                let tau_gpu = st.earliest_idle(1);
                let r_gpu = tau_gpu.max(ready);
                // Step 1 is an event-time comparison: canonical costs,
                // exact arithmetic
                let q = if canon_cost(g.p_cpu(j)) >= r_gpu + canon_cost(g.p_gpu(j)) {
                    1 // Step 1: GPU side
                } else {
                    alloc::r2_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k())
                };
                (q, st.best_unit(q))
            }
            OnlinePolicy::R1 => {
                let q = alloc::r1_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k());
                (q, st.best_unit(q))
            }
            OnlinePolicy::R2 => {
                let q = alloc::r2_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k());
                (q, st.best_unit(q))
            }
            OnlinePolicy::R3 => {
                let q = alloc::r3_side(g.p_cpu(j), g.p_gpu(j));
                (q, st.best_unit(q))
            }
            OnlinePolicy::Greedy => {
                let q = (0..plat.n_types())
                    .min_by(|&a, &b| g.time_on(j, a).total_cmp(&g.time_on(j, b)))
                    .unwrap();
                (q, st.best_unit(q))
            }
            OnlinePolicy::Random(_) => {
                let q = rng.as_mut().unwrap().below(plat.n_types());
                (q, st.best_unit(q))
            }
            OnlinePolicy::Eft => {
                // minimize finish across every unit; exact tie -> the
                // GPU-most type
                let mut best: Option<(f64, usize, usize)> = None;
                for q in 0..plat.n_types() {
                    let dur = canon_cost(g.time_on(j, q));
                    for (u, &a) in st.avail[q].iter().enumerate() {
                        let finish = ready.max(a) + dur;
                        let better = match best {
                            None => true,
                            Some((bf, bq, _)) => finish < bf || (finish == bf && q > bq),
                        };
                        if better {
                            best = Some((finish, q, u));
                        }
                    }
                }
                let (_, q, u) = best.unwrap();
                (q, u)
            }
        };

        let start = ready.max(st.avail[q][unit]);
        let finish = start + canon_cost(g.time_on(j, q));
        st.avail[q][unit] = finish;
        placements[j] = Some(Placement {
            ptype: q,
            unit,
            start,
            finish,
        });
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

/// Seed convenience wrapper: arrival order = task-id order.
pub fn online_by_id(g: &TaskGraph, plat: &Platform, policy: &OnlinePolicy) -> Schedule {
    let order: Vec<TaskId> = (0..g.n_tasks()).collect();
    online_schedule(g, plat, &order, policy)
}

/// The pre-policy multi-tenant service path, frozen as the golden
/// baseline for the admission-control layer: merge the tenants' arrival
/// streams by (time, tenant, stream position) and commit every arrival
/// immediately through the seed linear-scan decision rules above —
/// first-come-first-served over one shared pool, no quotas, no
/// reordering.  `sched::service` under all-FIFO admission must stay
/// placement-identical to this (the cross-policy differential suite in
/// `rust/tests/schedule_invariants.rs` pins it); per the ROADMAP
/// golden-parity protocol, any deliberate change to the FIFO service
/// semantics must update this body in the same PR and say so in
/// CHANGES.md.
///
/// The merge heap keys stay *raw* f64 (arrival times as submitted; the
/// service merges with the same raw keys, so the orders agree); ready
/// times pass through [`canon`] after the pop — the decision-entry
/// quantization boundary, matching the engine's decide().
///
/// Returns one [`Schedule`] per submission (absolute virtual times on
/// the shared pool).  Independently-maintained body: the decision match
/// below deliberately duplicates [`online_schedule`]'s, like the other
/// reference oracles in this module.
pub fn run_service(plat: &Platform, subs: &[super::service::Submission]) -> Vec<Schedule> {
    let mut st = State {
        avail: plat.counts.iter().map(|&c| vec![0.0f64; c]).collect(),
    };
    let orders: Vec<Vec<TaskId>> = subs.iter().map(|s| s.order_vec()).collect();
    let mut rngs: Vec<Option<Rng>> = subs
        .iter()
        .map(|s| match s.policy {
            OnlinePolicy::Random(seed) => Some(Rng::new(seed)),
            _ => None,
        })
        .collect();
    let mut placements: Vec<Vec<Option<Placement>>> = subs
        .iter()
        .map(|s| vec![None; s.graph.n_tasks()])
        .collect();

    let ready_of = |g: &TaskGraph, arrival: f64, placed: &[Option<Placement>], j: TaskId| {
        g.preds[j]
            .iter()
            .map(|&p| placed[p].expect("stream order not topological").finish)
            .fold(arrival, f64::max)
    };
    let mut heap: BinaryHeap<Reverse<(OrdF64, usize, usize, OrdF64)>> = BinaryHeap::new();
    for (i, s) in subs.iter().enumerate() {
        let r0 = ready_of(&s.graph, s.arrival, &placements[i], orders[i][0]);
        heap.push(Reverse((OrdF64(s.arrival.max(r0)), i, 0, OrdF64(r0))));
    }

    while let Some(Reverse((OrdF64(at), i, pos, OrdF64(ready)))) = heap.pop() {
        let g = &subs[i].graph;
        let j = orders[i][pos];
        // decision-entry quantization (the engine's decide() boundary)
        let ready = canon(ready);
        let (q, unit) = match &subs[i].policy {
            OnlinePolicy::ErLs => {
                let tau_gpu = st.earliest_idle(1);
                let r_gpu = tau_gpu.max(ready);
                let q = if canon_cost(g.p_cpu(j)) >= r_gpu + canon_cost(g.p_gpu(j)) {
                    1
                } else {
                    alloc::r2_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k())
                };
                (q, st.best_unit(q))
            }
            OnlinePolicy::R1 => {
                let q = alloc::r1_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k());
                (q, st.best_unit(q))
            }
            OnlinePolicy::R2 => {
                let q = alloc::r2_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k());
                (q, st.best_unit(q))
            }
            OnlinePolicy::R3 => {
                let q = alloc::r3_side(g.p_cpu(j), g.p_gpu(j));
                (q, st.best_unit(q))
            }
            OnlinePolicy::Greedy => {
                let q = (0..plat.n_types())
                    .min_by(|&a, &b| g.time_on(j, a).total_cmp(&g.time_on(j, b)))
                    .unwrap();
                (q, st.best_unit(q))
            }
            OnlinePolicy::Random(_) => {
                let q = rngs[i].as_mut().unwrap().below(plat.n_types());
                (q, st.best_unit(q))
            }
            OnlinePolicy::Eft => {
                let mut best: Option<(f64, usize, usize)> = None;
                for q in 0..plat.n_types() {
                    let dur = canon_cost(g.time_on(j, q));
                    for (u, &a) in st.avail[q].iter().enumerate() {
                        let finish = ready.max(a) + dur;
                        let better = match best {
                            None => true,
                            Some((bf, bq, _)) => finish < bf || (finish == bf && q > bq),
                        };
                        if better {
                            best = Some((finish, q, u));
                        }
                    }
                }
                let (_, q, u) = best.unwrap();
                (q, u)
            }
        };
        let start = ready.max(st.avail[q][unit]);
        let finish = start + canon_cost(g.time_on(j, q));
        st.avail[q][unit] = finish;
        placements[i][j] = Some(Placement {
            ptype: q,
            unit,
            start,
            finish,
        });
        if pos + 1 < orders[i].len() {
            let jn = orders[i][pos + 1];
            let rn = ready_of(g, subs[i].arrival, &placements[i], jn);
            heap.push(Reverse((OrdF64(at.max(rn)), i, pos + 1, OrdF64(rn))));
        }
    }

    placements
        .into_iter()
        .map(|ps| Schedule::from_placements(ps.into_iter().map(Option::unwrap).collect()))
        .collect()
}
