//! The online setting (§4.2): tasks arrive in an order that respects the
//! precedences; at arrival the scheduler takes an *irrevocable* decision
//! — a processor and a start time.  No backfilling, no revisiting.
//!
//! Policies:
//! * **ER-LS** — Step 1: if `p̄_j ≥ R_{j,gpu} + p̠_j` assign to GPU
//!   (`R_{j,gpu} = max(τ_gpu, max_pred C_i)`, τ_gpu = earliest time a GPU
//!   is idle); Step 2: otherwise rule R2.  Θ(√(m/k))-competitive.
//! * **EFT** — earliest finish time across all units (baseline).
//! * **Greedy** — fastest type, then earliest start on it (baseline).
//! * **Random** — uniform type, earliest start (baseline).
//! * **R1/R2/R3** — the simple rules, then earliest start on the side.
//!
//! Engine-backed since the event-driven refactor: machine state lives in
//! per-type unit trees ([`engine::UnitTree`]), so every decision —
//! earliest idle time, best unit, and the full EFT scan — is
//! O(Q log units) instead of the O(units) linear rescans of the retained
//! reference implementation ([`super::reference::online_schedule`]).
//! The engine clock is the [`engine::Tick`] fixed-point counter: ready
//! times quantize once at decision entry, durations once per candidate,
//! and every comparison in the rules below is an exact integer compare.
//! The public [`PolicyEngine`] API stays `f64` — callers hand in float
//! times and get float placements back — and because emitted times are
//! tick-canonical (exact multiples of 2⁻³³ well inside `u64` range) the
//! quantize→dequantize round-trip at this boundary is lossless.
//! Decisions (and therefore schedules) are identical to the reference;
//! the golden-parity suite pins this.

use crate::alloc;
use crate::graph::{TaskGraph, TaskId};
use crate::obs::{Alt, DecisionEvent, EventKind, NoopSink, Restrict, Sink};
use crate::platform::Platform;
use crate::sim::{Placement, Schedule};
use crate::substrate::rng::Rng;

use super::engine::{Tick, UnitPool};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OnlinePolicy {
    ErLs,
    Eft,
    Greedy,
    Random(u64),
    R1,
    R2,
    R3,
}

impl OnlinePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            OnlinePolicy::ErLs => "ER-LS",
            OnlinePolicy::Eft => "EFT",
            OnlinePolicy::Greedy => "Greedy",
            OnlinePolicy::Random(_) => "Random",
            OnlinePolicy::R1 => "R1-LS",
            OnlinePolicy::R2 => "R2-LS",
            OnlinePolicy::R3 => "R3-LS",
        }
    }
}

/// Admission-layer constraint on which units of one processor type a
/// decision may use.  Built per-decision by the service's quota policy
/// ([`TenantPolicy::Quota`](super::service::policy::TenantPolicy)):
/// a tenant below its held-units cap sees [`UnitSet::All`]; at the cap
/// it sees [`UnitSet::Only`] its currently-held units (it may stack
/// work behind itself but not spread further); a zero share makes the
/// whole type [`UnitSet::Banned`].  The unconstrained decision path
/// passes no sets at all, and every restricted query degenerates to the
/// exact tree query on the full unit set, so constrained and
/// unconstrained selection share one rule structure.
#[derive(Clone, Copy, Debug)]
pub enum UnitSet<'a> {
    /// No cap binding: every unit of the type is allowed.
    All,
    /// Only these units (ascending ids — the tenant's held set).
    Only(&'a [usize]),
    /// The type is forbidden (zero quota share).
    Banned,
}

impl UnitSet<'_> {
    fn banned(&self) -> bool {
        matches!(self, UnitSet::Banned)
    }
}

/// The constraint for type `q` out of a per-type slice; an empty (or
/// short) slice means unconstrained — the common no-admission path.
fn set_for<'a>(allowed: &[UnitSet<'a>], q: usize) -> UnitSet<'a> {
    allowed.get(q).copied().unwrap_or(UnitSet::All)
}

/// Always-cheap attribution of one decision — which rule path fired and
/// how contested the selection scan was.  Returned by
/// [`PolicyEngine::decide_in_traced`] alongside the placement so the
/// service can keep always-on rule counters (replay-stable: the fields
/// are pure functions of the decision inputs) without paying for an
/// event sink.
#[derive(Clone, Copy, Debug)]
pub struct DecisionTrace {
    /// Rule path tag — e.g. `erls-step1`, `r2-flip`, `eft` (the prose
    /// for each tag lives in [`crate::obs::explain`]).
    pub rule: &'static str,
    /// Candidates examined by the selection scan.
    pub candidates: usize,
    /// Candidates whose finish tick exactly equalled the incumbent's
    /// during the scan (1 = the winner was never challenged).
    pub tie_cluster: usize,
}

/// Shared decision engine for the online policies: one [`UnitPool`] of
/// per-type unit trees, keyed by the tick each unit becomes idle, plus
/// the irrevocable `(type, unit, start, finish)` decision rule of every
/// policy.  `online_schedule` drives it for a single task stream; the
/// multi-tenant service mode ([`super::service`]) threads one engine
/// across the interleaved streams of many tenants, so single-tenant
/// service runs are placement-identical to `online_schedule` *by
/// construction* (and the parity suite pins it anyway).
pub struct PolicyEngine {
    avail: UnitPool,
}

impl PolicyEngine {
    pub fn new(plat: &Platform) -> PolicyEngine {
        PolicyEngine {
            avail: UnitPool::new(&plat.counts),
        }
    }

    /// The shared pool state (read-only view).
    pub fn pool(&self) -> &UnitPool {
        &self.avail
    }

    /// Rewind one unit's free time (tenant-cancellation path: the
    /// service releases a cancelled tenant's not-yet-started
    /// reservations through here, via [`UnitPool::release`]).  `free` is
    /// a tick-canonical time the caller previously read out of a
    /// placement or the pool, so quantizing it back is exact.
    pub fn release_unit(&mut self, q: usize, unit: usize, free: f64) {
        self.avail.release(q, unit, Tick::quantize(free));
    }

    /// Earliest idle tick among the allowed units of type `q`
    /// ([`Tick::MAX`] when the type is banned).  [`UnitSet::All`] is the
    /// exact tree query.
    fn earliest_idle_in(&self, q: usize, s: UnitSet) -> Tick {
        match s {
            UnitSet::All => self.avail.types[q].min(),
            UnitSet::Only(units) => self.avail.types[q].min_over(units),
            UnitSet::Banned => Tick::MAX,
        }
    }

    /// The unit the seed's `min_by` scan picks among the allowed units:
    /// lowest index among the earliest-idle ones.  On [`UnitSet::All`]
    /// this is the tree's `argmin_first`; on a restricted set it is the
    /// same first-strict-minimum scan over the set.
    fn best_unit_in(&self, q: usize, s: UnitSet) -> usize {
        let tree = &self.avail.types[q];
        match s {
            UnitSet::All => tree.argmin_first(),
            UnitSet::Only(units) => {
                assert!(!units.is_empty(), "at-cap tenant must hold a unit");
                let mut best = units[0];
                for &u in &units[1..] {
                    if tree.get(u) < tree.get(best) {
                        best = u;
                    }
                }
                best
            }
            UnitSet::Banned => unreachable!("banned type selected"),
        }
    }

    /// EFT candidate on type `q` for a task ready at tick `ready` with
    /// duration `dur` ticks: (finish, unit).  The optimal finish is
    /// `max(ready, τ_q) + dur`; every unit idle at or before that clamp
    /// ties *exactly* (equal ticks), and the scan keeps the *first* such
    /// unit — a lower-indexed unit idle at the same tick beats a
    /// higher-indexed one.  The returned finish uses the chosen unit's
    /// true idle tick.
    ///
    /// This is the tail-candidate half of the gap-indexed selection
    /// ([`engine::GapIndex::best_eft`](super::engine::GapIndex)): online
    /// decisions are irrevocable (no backfilling), so units never own
    /// idle gaps and the tail tree alone answers the query in
    /// O(log units) — the same clamp rule HEFT's gap index applies
    /// before folding in its gap candidates.
    fn eft_candidate(&self, q: usize, ready: Tick, dur: Tick) -> (Tick, usize) {
        let tree = &self.avail.types[q];
        let tau = tree.min();
        let clamp = if tau <= ready { ready } else { tau };
        let u = tree
            .first_at_most(clamp)
            // hetlint: allow(no-panic-in-hot-path) -- clamp >= tree.min() by construction, so some unit is always at or below it
            .expect("idle horizon admits its own minimizer");
        let start = ready.max(tree.get(u));
        (start + dur, u)
    }

    /// [`Self::eft_candidate`] restricted to the allowed units of type
    /// `q`: same clamp rule over the restricted idle horizon, first
    /// allowed unit idle at or before the clamp.  `None` for a banned
    /// type.
    fn eft_candidate_in(
        &self,
        q: usize,
        ready: Tick,
        dur: Tick,
        s: UnitSet,
    ) -> Option<(Tick, usize)> {
        match s {
            UnitSet::All => Some(self.eft_candidate(q, ready, dur)),
            UnitSet::Only(units) => {
                assert!(!units.is_empty(), "at-cap tenant must hold a unit");
                let tree = &self.avail.types[q];
                let tau = tree.min_over(units);
                let clamp = if tau <= ready { ready } else { tau };
                let u = tree
                    .first_at_most_over(units, clamp)
                    // hetlint: allow(no-panic-in-hot-path) -- clamp >= min over the (asserted non-empty) unit set, so a unit is always at or below it
                    .expect("restricted idle horizon admits its own minimizer");
                let start = ready.max(tree.get(u));
                Some((start + dur, u))
            }
            UnitSet::Banned => None,
        }
    }

    /// Take the irrevocable decision for task `j` of graph `g`, ready at
    /// `ready` (max of its predecessors' completions and its tenant's
    /// arrival time), and reserve the chosen unit until the task's
    /// finish.  `rng` must be `Some` exactly for the Random policy.
    pub fn decide(
        &mut self,
        g: &TaskGraph,
        plat: &Platform,
        j: TaskId,
        ready: f64,
        policy: &OnlinePolicy,
        rng: Option<&mut Rng>,
    ) -> Placement {
        self.decide_in(g, plat, j, ready, policy, rng, &[])
    }

    /// [`Self::decide`] under per-type admission constraints (`allowed`;
    /// an empty slice is unconstrained).  The rule structure is the
    /// paper's own, applied to the restricted availability: the
    /// two-sided rules keep their side unless the quota bans it (then
    /// they fall through to the other side), ER-LS Step 1 reads the GPU
    /// idle horizon *of the allowed GPU units* (a capped tenant sees its
    /// own earliest-free held GPU as `τ_gpu`), and EFT minimizes finish
    /// over the allowed units of every non-banned type.  With `allowed`
    /// empty, every branch reduces to the unconstrained expressions
    /// operation for operation — the golden-parity guarantees are
    /// untouched by construction.
    #[allow(clippy::too_many_arguments)]
    pub fn decide_in(
        &mut self,
        g: &TaskGraph,
        plat: &Platform,
        j: TaskId,
        ready: f64,
        policy: &OnlinePolicy,
        rng: Option<&mut Rng>,
        allowed: &[UnitSet],
    ) -> Placement {
        self.decide_in_traced(g, plat, j, ready, policy, rng, allowed, 0, &mut NoopSink)
            .0
    }

    /// [`Self::decide_in`] with decision attribution: returns the
    /// placement plus a [`DecisionTrace`] (always computed — cheap tags
    /// and counts), and emits a full [`EventKind::Decision`] span when
    /// `sink` records.  The sink never influences the decision: event
    /// payloads (exact-tie alternatives, restricted-set snapshots) are
    /// built only behind [`Sink::enabled`], and the selection
    /// arithmetic is identical expression for expression to the
    /// untraced path — `obs_parity` pins recording vs. no-op bitwise.
    /// `tenant` only labels the emitted event (0 for single streams).
    #[allow(clippy::too_many_arguments)]
    pub fn decide_in_traced(
        &mut self,
        g: &TaskGraph,
        plat: &Platform,
        j: TaskId,
        ready: f64,
        policy: &OnlinePolicy,
        rng: Option<&mut Rng>,
        allowed: &[UnitSet],
        tenant: usize,
        sink: &mut dyn Sink,
    ) -> (Placement, DecisionTrace) {
        // the clock boundary: quantize once, here; everything below is
        // exact integer arithmetic.  Rule *sides* (R1/R2/R3, Greedy,
        // ER-LS Step 2) still read the raw float costs — they are
        // allocation rules over processing-time ratios, not event-time
        // comparisons, and the reference applies the same split.
        let ready = Tick::quantize(ready);
        // a two-sided rule's side, quota-adjusted: banned sides fall
        // through to the other side (validation guarantees one is open)
        let flip = |q: usize| -> usize {
            if set_for(allowed, q).banned() {
                1 - q
            } else {
                q
            }
        };
        let record = sink.enabled();
        let mut candidates = 1usize;
        let mut tie_cluster = 1usize;
        let mut alts: Vec<Alt> = Vec::new();
        // choose (type, unit) and name the rule path taken
        let (q, unit, rule) = match policy {
            OnlinePolicy::ErLs => {
                let (q, rule) = if set_for(allowed, 1).banned() {
                    (0, "erls-cpu-forced")
                } else if set_for(allowed, 0).banned() {
                    (1, "erls-gpu-forced")
                } else {
                    candidates = 2; // both sides weighed
                    let tau_gpu = self.earliest_idle_in(1, set_for(allowed, 1));
                    let r_gpu = tau_gpu.max(ready);
                    // Step 1 compares a CPU duration against an absolute
                    // GPU finish — event-time arithmetic, so it runs on
                    // quantized ticks like every other time comparison
                    if Tick::quantize_cost(g.p_cpu(j)) >= r_gpu + Tick::quantize_cost(g.p_gpu(j)) {
                        (1, "erls-step1") // Step 1: GPU side
                    } else {
                        let side = alloc::r2_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k());
                        (side, if side == 1 { "erls-step2-gpu" } else { "erls-step2-cpu" })
                    }
                };
                (q, self.best_unit_in(q, set_for(allowed, q)), rule)
            }
            OnlinePolicy::R1 => {
                let side = alloc::r1_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k());
                let q = flip(side);
                (
                    q,
                    self.best_unit_in(q, set_for(allowed, q)),
                    if q == side { "r1" } else { "r1-flip" },
                )
            }
            OnlinePolicy::R2 => {
                let side = alloc::r2_side(g.p_cpu(j), g.p_gpu(j), plat.m(), plat.k());
                let q = flip(side);
                (
                    q,
                    self.best_unit_in(q, set_for(allowed, q)),
                    if q == side { "r2" } else { "r2-flip" },
                )
            }
            OnlinePolicy::R3 => {
                let side = alloc::r3_side(g.p_cpu(j), g.p_gpu(j));
                let q = flip(side);
                (
                    q,
                    self.best_unit_in(q, set_for(allowed, q)),
                    if q == side { "r3" } else { "r3-flip" },
                )
            }
            OnlinePolicy::Greedy => {
                let open = (0..plat.n_types()).filter(|&q| !set_for(allowed, q).banned());
                candidates = open.clone().count();
                let q = open
                    .min_by(|&a, &b| g.time_on(j, a).total_cmp(&g.time_on(j, b)))
                    // hetlint: allow(no-panic-in-hot-path) -- admission control guarantees every admitted task at least one open type
                    .expect("quota leaves no usable type");
                (q, self.best_unit_in(q, set_for(allowed, q)), "greedy")
            }
            OnlinePolicy::Random(_) => {
                // draw first (identical rng consumption with or without
                // a quota), then walk to the next open type if banned
                // hetlint: allow(no-panic-in-hot-path) -- Random is only constructed with an rng (policy ctor invariant)
                let drawn = rng.expect("Random policy needs an rng").below(plat.n_types());
                let q = (0..plat.n_types())
                    .map(|step| (drawn + step) % plat.n_types())
                    .find(|&q| !set_for(allowed, q).banned())
                    // hetlint: allow(no-panic-in-hot-path) -- admission control guarantees every admitted task at least one open type
                    .expect("quota leaves no usable type");
                (
                    q,
                    self.best_unit_in(q, set_for(allowed, q)),
                    if q == drawn { "random" } else { "random-walk" },
                )
            }
            OnlinePolicy::Eft => {
                // minimize finish across every allowed unit; exact tick
                // tie -> the later (higher) type wins, matching the
                // reference scan's `q > bq` rule
                let mut best: Option<(Tick, usize, usize)> = None;
                let mut cands = 0usize;
                for q in 0..plat.n_types() {
                    let dur = Tick::quantize_cost(g.time_on(j, q));
                    let Some((finish, u)) = self.eft_candidate_in(q, ready, dur, set_for(allowed, q))
                    else {
                        continue;
                    };
                    cands += 1;
                    let better = match best {
                        None => true,
                        Some((bf, bq, bu)) => {
                            // the comparator is the exact `finish <= bf`;
                            // the tie/strict split below only books
                            // attribution
                            if finish == bf {
                                tie_cluster += 1;
                                if record {
                                    alts.push(Alt { ptype: bq, unit: bu, finish: bf.to_f64() });
                                }
                            } else if finish < bf {
                                tie_cluster = 1;
                                if record {
                                    alts.clear();
                                }
                            }
                            finish <= bf
                        }
                    };
                    if better {
                        best = Some((finish, q, u));
                    }
                }
                candidates = cands;
                // hetlint: allow(no-panic-in-hot-path) -- admission control guarantees every admitted task at least one open type
                let (_, q, u) = best.expect("quota leaves no usable type");
                (q, u, "eft")
            }
        };

        let start = ready.max(self.avail.free_at(q, unit));
        let finish = start + Tick::quantize_cost(g.time_on(j, q));
        self.avail.reserve(q, unit, finish);
        let placement = Placement {
            ptype: q,
            unit,
            start: start.to_f64(),
            finish: finish.to_f64(),
        };
        if record {
            let restricted: Vec<Restrict> = allowed
                .iter()
                .map(|s| match s {
                    UnitSet::All => Restrict::All,
                    UnitSet::Only(units) => Restrict::Only(units.to_vec()),
                    UnitSet::Banned => Restrict::Banned,
                })
                .collect();
            sink.emit(
                ready.to_f64(),
                EventKind::Decision(DecisionEvent {
                    tenant,
                    task: j,
                    policy: policy.name(),
                    rule,
                    candidates,
                    tie_cluster,
                    alternatives: alts,
                    restricted,
                    ptype: q,
                    unit,
                    start: placement.start,
                    finish: placement.finish,
                }),
            );
        }
        (
            placement,
            DecisionTrace {
                rule,
                candidates,
                tie_cluster,
            },
        )
    }
}

/// Policies that are only defined on hybrid (CPU+GPU, 2-type) platforms.
pub fn requires_two_types(policy: &OnlinePolicy) -> bool {
    matches!(
        policy,
        OnlinePolicy::ErLs | OnlinePolicy::R1 | OnlinePolicy::R2 | OnlinePolicy::R3
    )
}

/// Run the online engine over `order` (must be a topological order —
/// the precedence-respecting arrival sequence).
pub fn online_schedule(
    g: &TaskGraph,
    plat: &Platform,
    order: &[TaskId],
    policy: &OnlinePolicy,
) -> Schedule {
    online_schedule_traced(g, plat, order, policy, &mut NoopSink)
}

/// [`online_schedule`] with an event sink: every irrevocable decision
/// emits its [`EventKind::Decision`] span.  With a [`NoopSink`] this
/// *is* `online_schedule` (the parity suites pin it).
pub fn online_schedule_traced(
    g: &TaskGraph,
    plat: &Platform,
    order: &[TaskId],
    policy: &OnlinePolicy,
    sink: &mut dyn Sink,
) -> Schedule {
    let n = g.n_tasks();
    assert_eq!(order.len(), n, "arrival order must cover all tasks");
    if requires_two_types(policy) {
        assert!(
            plat.n_types() == 2,
            "{} is defined for hybrid platforms",
            policy.name()
        );
    }

    let mut engine = PolicyEngine::new(plat);
    let mut rng = match policy {
        OnlinePolicy::Random(seed) => Some(Rng::new(*seed)),
        _ => None,
    };
    let mut placements: Vec<Option<Placement>> = vec![None; n];
    let mut seen = vec![false; n];

    for &j in order {
        // arrival must respect precedences; predecessor finishes are
        // tick-canonical, so the fold (and the re-quantize inside the
        // engine) is exact
        let ready = g.preds[j]
            .iter()
            .map(|&p| {
                placements[p]
                    .unwrap_or_else(|| panic!("order not topological: {p} after {j}"))
                    .finish
            })
            .fold(0.0f64, f64::max);
        debug_assert!(!seen[j]);
        seen[j] = true;
        placements[j] = Some(
            engine
                .decide_in_traced(g, plat, j, ready, policy, rng.as_mut(), &[], 0, sink)
                .0,
        );
    }

    Schedule::from_placements(placements.into_iter().map(Option::unwrap).collect())
}

/// Convenience: arrival order = task-id order (our generators emit ids
/// topologically).
pub fn online_by_id(g: &TaskGraph, plat: &Platform, policy: &OnlinePolicy) -> Schedule {
    let order: Vec<TaskId> = (0..g.n_tasks()).collect();
    online_schedule(g, plat, &order, policy)
}

/// A random topological order (for arrival-order robustness tests).
pub fn random_topo_order(g: &TaskGraph, rng: &mut Rng) -> Vec<TaskId> {
    let n = g.n_tasks();
    let mut remaining: Vec<usize> = g.preds.iter().map(|p| p.len()).collect();
    let mut avail: Vec<TaskId> = (0..n).filter(|&j| remaining[j] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while !avail.is_empty() {
        let pick = rng.below(avail.len());
        let j = avail.swap_remove(pick);
        order.push(j);
        for &s in &g.succs[j] {
            remaining[s] -= 1;
            if remaining[s] == 0 {
                avail.push(s);
            }
        }
    }
    assert_eq!(order.len(), n);
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sched::reference;
    use crate::sim::validate;

    fn plat() -> Platform {
        Platform::hybrid(4, 2)
    }

    fn all_policies(seed: u64) -> Vec<OnlinePolicy> {
        vec![
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(seed),
            OnlinePolicy::R1,
            OnlinePolicy::R2,
            OnlinePolicy::R3,
        ]
    }

    #[test]
    fn all_policies_produce_valid_schedules() {
        let mut rng = Rng::new(11);
        let g = gen::hybrid_dag(&mut rng, 60, 0.08);
        for policy in all_policies(3) {
            let s = online_by_id(&g, &plat(), &policy);
            validate(&g, &plat(), &s).unwrap();
        }
    }

    #[test]
    fn erls_step1_sends_long_cpu_tasks_to_gpu() {
        // single task: p̄ = 100 >= 0 + p̠ = 1 -> GPU by Step 1
        let mut b = Builder::new("s1");
        b.add_task("t", vec![100.0, 1.0]);
        let g = b.build();
        let s = online_by_id(&g, &plat(), &OnlinePolicy::ErLs);
        assert_eq!(s.placements[0].ptype, 1);
    }

    #[test]
    fn erls_step2_respects_r2() {
        // p̄ = 1 < p̠ = 0.9 + busy gpus... choose m=16,k=4:
        // Step 1: 1 >= 0 + 0.9? false (0.9+0=0.9 <= 1 -> actually true!)
        // pick p̠ = 2: Step 1 false; R2: 1/4 <= 2/2 -> CPU.
        let mut b = Builder::new("s2");
        b.add_task("t", vec![1.0, 2.0]);
        let g = b.build();
        let plat = Platform::hybrid(16, 4);
        let s = online_by_id(&g, &plat, &OnlinePolicy::ErLs);
        assert_eq!(s.placements[0].ptype, 0);
    }

    #[test]
    fn eft_picks_global_earliest_finish() {
        // 1 CPU busy-free, 1 GPU: task faster on CPU goes CPU
        let mut b = Builder::new("eft");
        b.add_task("t", vec![1.0, 5.0]);
        let g = b.build();
        let s = online_by_id(&g, &Platform::hybrid(1, 1), &OnlinePolicy::Eft);
        assert_eq!(s.placements[0].ptype, 0);
    }

    #[test]
    fn irrevocability_no_backfilling() {
        // Two tasks on one CPU: a long then a short; the short one must
        // queue after the long one even though a gap-free world exists.
        let mut b = Builder::new("irr");
        b.add_task("long", vec![5.0, 100.0]);
        b.add_task("short", vec![1.0, 100.0]);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s = online_schedule(&g, &plat, &[0, 1], &OnlinePolicy::Greedy);
        assert_eq!(s.placements[1].start, 5.0);
    }

    #[test]
    fn arrival_order_changes_schedule() {
        let mut b = Builder::new("ord");
        b.add_task("a", vec![5.0, 5.0]);
        b.add_task("b", vec![1.0, 1.0]);
        let g = b.build();
        let plat = Platform::hybrid(1, 1);
        let s1 = online_schedule(&g, &plat, &[0, 1], &OnlinePolicy::Eft);
        let s2 = online_schedule(&g, &plat, &[1, 0], &OnlinePolicy::Eft);
        // different arrival order, different placements
        assert_ne!(
            (s1.placements[0].ptype, s1.placements[0].start),
            (s2.placements[0].ptype, s2.placements[0].start)
        );
    }

    #[test]
    #[should_panic(expected = "order not topological")]
    fn non_topological_order_rejected() {
        let mut b = Builder::new("bad");
        let a = b.add_task("a", vec![1.0, 1.0]);
        let c = b.add_task("b", vec![1.0, 1.0]);
        b.add_arc(a, c);
        let g = b.build();
        online_schedule(&g, &plat(), &[1, 0], &OnlinePolicy::Greedy);
    }

    #[test]
    fn random_topo_order_is_topological() {
        let mut rng = Rng::new(8);
        let g = gen::hybrid_dag(&mut rng, 40, 0.15);
        for _ in 0..5 {
            let order = random_topo_order(&g, &mut rng);
            let mut pos = vec![0usize; 40];
            for (i, &t) in order.iter().enumerate() {
                pos[t] = i;
            }
            for j in 0..40 {
                for &s in &g.succs[j] {
                    assert!(pos[j] < pos[s]);
                }
            }
            // engine accepts it
            let s = online_schedule(&g, &plat(), &order, &OnlinePolicy::ErLs);
            validate(&g, &plat(), &s).unwrap();
        }
    }

    #[test]
    fn decide_in_banned_type_falls_through_to_the_other_side() {
        // CPU-faster task, CPU banned: every two-sided rule and EFT land
        // on the GPU side instead
        let mut b = Builder::new("ban");
        b.add_task("t", vec![1.0, 50.0]);
        let g = b.build();
        let plat = plat();
        let banned_cpu = [UnitSet::Banned, UnitSet::All];
        for policy in [
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::R1,
            OnlinePolicy::R2,
            OnlinePolicy::R3,
        ] {
            let mut engine = PolicyEngine::new(&plat);
            let p = engine.decide_in(&g, &plat, 0, 0.0, &policy, None, &banned_cpu);
            assert_eq!(p.ptype, 1, "{}", policy.name());
        }
        // Random consumes one draw and walks off the banned type
        let mut engine = PolicyEngine::new(&plat);
        let mut rng = Rng::new(5);
        let p = engine.decide_in(
            &g,
            &plat,
            0,
            0.0,
            &OnlinePolicy::Random(5),
            Some(&mut rng),
            &banned_cpu,
        );
        assert_eq!(p.ptype, 1);
    }

    #[test]
    fn decide_in_restricted_set_stacks_on_held_units() {
        // 4 CPUs, CPU-fast task; the tenant is capped to CPU unit 2 only:
        // Greedy and EFT must queue there even though units 0/1/3 idle
        let mut b = Builder::new("held");
        b.add_task("t", vec![2.0, 50.0]);
        let g = b.build();
        let plat = plat();
        let held = [2usize];
        let only = [UnitSet::Only(&held), UnitSet::All];
        let mut engine = PolicyEngine::new(&plat);
        let p1 = engine.decide_in(&g, &plat, 0, 0.0, &OnlinePolicy::Greedy, None, &only);
        assert_eq!((p1.ptype, p1.unit, p1.start), (0, 2, 0.0));
        let p2 = engine.decide_in(&g, &plat, 0, 0.0, &OnlinePolicy::Greedy, None, &only);
        assert_eq!((p2.ptype, p2.unit, p2.start), (0, 2, 2.0), "stacks behind itself");
        // EFT with the CPU restricted to the busy unit 2 now prefers the
        // idle GPU despite the slower processing time cap
        let mut b = Builder::new("held2");
        b.add_task("t", vec![2.0, 5.0]);
        let g2 = b.build();
        let p3 = engine.decide_in(&g2, &plat, 0, 0.0, &OnlinePolicy::Eft, None, &only);
        assert_eq!(p3.ptype, 1, "restricted CPU EFT 6 loses to GPU EFT 5");
    }

    #[test]
    fn decide_in_unconstrained_slice_matches_decide() {
        let mut rng = Rng::new(99);
        let g = gen::hybrid_dag(&mut rng, 30, 0.1);
        for policy in all_policies(2) {
            let mut a = PolicyEngine::new(&plat());
            let mut b = PolicyEngine::new(&plat());
            let mut ra = match policy {
                OnlinePolicy::Random(s) => Some(Rng::new(s)),
                _ => None,
            };
            let mut rb = ra.clone();
            let all = [UnitSet::All, UnitSet::All];
            for j in 0..g.n_tasks() {
                let ready = j as f64 * 0.5;
                let pa = a.decide(&g, &plat(), j, ready, &policy, ra.as_mut());
                let pb = b.decide_in(&g, &plat(), j, ready, &policy, rb.as_mut(), &all);
                assert_eq!(pa, pb, "{}", policy.name());
            }
        }
    }

    #[test]
    fn traced_decisions_match_untraced_and_name_rules() {
        use crate::obs::{EventKind, RecordingSink};
        let mut rng = Rng::new(123);
        let g = gen::hybrid_dag(&mut rng, 40, 0.1);
        let order: Vec<usize> = (0..40).collect();
        for policy in all_policies(4) {
            let plain = online_schedule(&g, &plat(), &order, &policy);
            let mut sink = RecordingSink::new();
            let traced = online_schedule_traced(&g, &plat(), &order, &policy, &mut sink);
            assert_eq!(plain.placements, traced.placements, "{}", policy.name());
            let events = sink.take();
            assert_eq!(events.len(), 40, "one decision span per task");
            for ev in &events {
                let EventKind::Decision(d) = &ev.kind else {
                    panic!("online stream only emits decisions")
                };
                assert_eq!(d.policy, policy.name());
                assert!(d.candidates >= 1);
                assert!(d.tie_cluster >= 1);
                assert!(d.restricted.is_empty(), "unconstrained path");
            }
        }
    }

    #[test]
    fn online_engine_matches_reference_inline() {
        // quick in-module parity check; the full 50+-instance sweep
        // lives in rust/tests/golden_parity.rs
        let mut rng = Rng::new(77);
        for case in 0..6 {
            let g = gen::hybrid_dag(&mut rng, 50, 0.1);
            let order = random_topo_order(&g, &mut rng);
            for policy in all_policies(case) {
                let a = online_schedule(&g, &plat(), &order, &policy);
                let b = reference::online_schedule(&g, &plat(), &order, &policy);
                assert_eq!(a.placements, b.placements, "{}", policy.name());
            }
        }
    }
}
