//! Deterministic observability: structured tracing, a metrics registry,
//! and the decision-explanation renderer.
//!
//! The layer exists to open the scheduler's black box — *which* rule
//! fired for a task, how contended the ready queues were, what a PDHG
//! chunk converged to — without ever perturbing a decision.  Two design
//! rules make that contract checkable:
//!
//! * **Virtual time only.**  Every event carries the virtual time of the
//!   decision it describes and a monotone sequence number assigned by
//!   the sink.  Nothing in this module (or in any core emit site) reads
//!   the wall clock; hetlint R4 scans `rust/src/obs/` like the rest of
//!   the core, and wall-clock timing stays at the coordinator/daemon
//!   edge where it is allowlisted.  Consequence: a `--trace-out` JSONL
//!   log is byte-identical across two runs of the same workload, and
//!   replaying a WAL re-emits the exact event stream of the original
//!   run.
//! * **Emit sites are passive.**  The [`Sink`] trait has a no-op
//!   implementation used by every untraced entry point; emit sites
//!   check [`Sink::enabled`] before building event payloads, so the
//!   disabled path costs one virtual call per decision.  The
//!   `obs_parity` suite pins recording-sink placements bitwise equal to
//!   no-op-sink placements across the golden-parity and
//!   service-fairness seed matrices.
//!
//! Pieces:
//! * [`sink`] — the [`Sink`] trait, [`NoopSink`], [`RecordingSink`].
//! * [`event`] — the event grammar ([`Event`], [`EventKind`]) and its
//!   deterministic JSONL serialization via `substrate::json`.
//! * [`metrics`] — monotone counters + fixed-bucket histograms
//!   ([`Metrics`]) snapshotted into a [`MetricsReport`], the payload of
//!   the daemon `metrics` request.
//! * [`explain`] — renders *why a task landed where it did* from a
//!   recorded event stream (rule fired, exact-tie alternatives,
//!   restricted-set state); `hetsched explain` drives it over a WAL
//!   replay.

pub mod event;
pub mod explain;
pub mod metrics;
pub mod sink;

pub use event::{Alt, DecisionEvent, Event, EventKind, Restrict};
pub use metrics::{Histogram, Metrics, MetricsReport};
pub use sink::{NoopSink, RecordingSink, Sink};
