//! The event grammar and its deterministic JSONL form.
//!
//! Every event is `{seq, t, ev, ...}`: `seq` the sink-assigned monotone
//! sequence number, `t` the *virtual* time of the decision it describes
//! (never a wall-clock reading), `ev` the kind tag.  Serialization goes
//! through `substrate::json` — object keys live in a `BTreeMap`, the
//! float writer is shortest-round-trip — so the same event stream
//! always yields the same bytes, which is what lets ci.sh assert two
//! `--trace-out` runs `diff` clean.

use crate::substrate::json::Json;

/// One trace event.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    /// Monotone sequence number assigned by the sink.
    pub seq: u64,
    /// Virtual time of the described decision/sample.
    pub vtime: f64,
    pub kind: EventKind,
}

/// A rejected candidate that exactly tied a winning decision's finish.
#[derive(Clone, Debug, PartialEq)]
pub struct Alt {
    pub ptype: usize,
    pub unit: usize,
    pub finish: f64,
}

/// Per-type admission constraint in force at decision time (the
/// service's quota path; `All` everywhere on unconstrained decisions).
#[derive(Clone, Debug, PartialEq)]
pub enum Restrict {
    All,
    Only(Vec<usize>),
    Banned,
}

impl Restrict {
    /// Compact display form: `all`, `only[2,5]`, `banned`.
    pub fn label(&self) -> String {
        match self {
            Restrict::All => "all".to_string(),
            Restrict::Only(units) => {
                let ids: Vec<String> = units.iter().map(|u| u.to_string()).collect();
                format!("only[{}]", ids.join(","))
            }
            Restrict::Banned => "banned".to_string(),
        }
    }
}

/// The span of one irrevocable placement decision: which rule fired,
/// what was considered, what tied the winner exactly, and what
/// admission constraints applied.
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionEvent {
    /// Owning tenant (0 for single-stream schedulers).
    pub tenant: usize,
    pub task: usize,
    /// Policy name (`ER-LS`, `EFT`, ... or `HEFT`/`EST`/`List`).
    pub policy: &'static str,
    /// The rule path taken — e.g. `erls-step1`, `r2-flip`, `eft`.
    pub rule: &'static str,
    /// Candidates examined by the selection scan.
    pub candidates: usize,
    /// Candidates whose finish tick exactly equalled the incumbent's
    /// during the scan (1 = the winner was never challenged).
    pub tie_cluster: usize,
    /// Exactly-tied candidates the winner displaced (populated only
    /// when the sink records).
    pub alternatives: Vec<Alt>,
    /// Per-type restriction state (empty = unconstrained decision path).
    pub restricted: Vec<Restrict>,
    pub ptype: usize,
    pub unit: usize,
    pub start: f64,
    pub finish: f64,
}

/// Event payloads.  `&'static str` labels keep the disabled path
/// allocation-free.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// One placement decision (online engine, EST, HEFT, list, service).
    Decision(DecisionEvent),
    /// Depth of a ready queue / stream heap at a decision point.
    Queue { scope: &'static str, depth: usize },
    /// Gap-index state probed for one HEFT decision.
    GapProbe { task: usize, ptype: usize, gaps: usize },
    /// One PDHG chunk: cumulative iterations + residual sample.
    LpChunk { lp: usize, iters: u64, pres: f64, dres: f64, gap: f64 },
    /// One LP finished (emitted in job-index order by the batch driver).
    LpDone { lp: usize, iters: u64, stop: &'static str },
    /// One WAL write at the daemon edge (virtual payload: byte counts
    /// are deterministic functions of the op stream, not of the clock).
    Wal { op: &'static str, bytes: u64 },
}

impl Event {
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("seq", Json::Num(self.seq as f64)),
            ("t", Json::Num(self.vtime)),
        ];
        match &self.kind {
            EventKind::Decision(d) => {
                fields.push(("ev", Json::Str("decision".to_string())));
                fields.push(("tenant", Json::Num(d.tenant as f64)));
                fields.push(("task", Json::Num(d.task as f64)));
                fields.push(("policy", Json::Str(d.policy.to_string())));
                fields.push(("rule", Json::Str(d.rule.to_string())));
                fields.push(("cands", Json::Num(d.candidates as f64)));
                fields.push(("tie", Json::Num(d.tie_cluster as f64)));
                let alts: Vec<Json> = d
                    .alternatives
                    .iter()
                    .map(|a| {
                        Json::Arr(vec![
                            Json::Num(a.ptype as f64),
                            Json::Num(a.unit as f64),
                            Json::Num(a.finish),
                        ])
                    })
                    .collect();
                fields.push(("alts", Json::Arr(alts)));
                let restrict: Vec<Json> =
                    d.restricted.iter().map(|r| Json::Str(r.label())).collect();
                fields.push(("restrict", Json::Arr(restrict)));
                fields.push(("ptype", Json::Num(d.ptype as f64)));
                fields.push(("unit", Json::Num(d.unit as f64)));
                fields.push(("start", Json::Num(d.start)));
                fields.push(("finish", Json::Num(d.finish)));
            }
            EventKind::Queue { scope, depth } => {
                fields.push(("ev", Json::Str("queue".to_string())));
                fields.push(("scope", Json::Str(scope.to_string())));
                fields.push(("depth", Json::Num(*depth as f64)));
            }
            EventKind::GapProbe { task, ptype, gaps } => {
                fields.push(("ev", Json::Str("gap-probe".to_string())));
                fields.push(("task", Json::Num(*task as f64)));
                fields.push(("ptype", Json::Num(*ptype as f64)));
                fields.push(("gaps", Json::Num(*gaps as f64)));
            }
            EventKind::LpChunk { lp, iters, pres, dres, gap } => {
                fields.push(("ev", Json::Str("lp-chunk".to_string())));
                fields.push(("lp", Json::Num(*lp as f64)));
                fields.push(("iters", Json::Num(*iters as f64)));
                fields.push(("pres", Json::Num(*pres)));
                fields.push(("dres", Json::Num(*dres)));
                fields.push(("gap", Json::Num(*gap)));
            }
            EventKind::LpDone { lp, iters, stop } => {
                fields.push(("ev", Json::Str("lp-done".to_string())));
                fields.push(("lp", Json::Num(*lp as f64)));
                fields.push(("iters", Json::Num(*iters as f64)));
                fields.push(("stop", Json::Str(stop.to_string())));
            }
            EventKind::Wal { op, bytes } => {
                fields.push(("ev", Json::Str("wal".to_string())));
                fields.push(("op", Json::Str(op.to_string())));
                fields.push(("bytes", Json::Num(*bytes as f64)));
            }
        }
        Json::obj(fields)
    }

    /// One JSONL line (no trailing newline).
    pub fn jsonl(&self) -> String {
        self.to_json().to_string()
    }
}

/// Render a drained event batch as JSONL (one line per event, each
/// `\n`-terminated) — the `--trace-out` file format.
pub fn to_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.jsonl());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_serializes_deterministically() {
        let ev = Event {
            seq: 7,
            vtime: 1.5,
            kind: EventKind::Decision(DecisionEvent {
                tenant: 2,
                task: 11,
                policy: "EFT",
                rule: "eft",
                candidates: 2,
                tie_cluster: 2,
                alternatives: vec![Alt { ptype: 0, unit: 1, finish: 3.0 }],
                restricted: vec![Restrict::All, Restrict::Only(vec![2, 5])],
                ptype: 1,
                unit: 0,
                start: 1.5,
                finish: 3.0,
            }),
        };
        let line = ev.jsonl();
        assert_eq!(line, ev.jsonl(), "rendering is a pure function");
        assert!(line.contains("\"ev\":\"decision\""));
        assert!(line.contains("\"rule\":\"eft\""));
        assert!(line.contains("\"restrict\":[\"all\",\"only[2,5]\"]"));
        // keys are BTreeMap-ordered: alts before cands before ev
        let a = line.find("\"alts\"").unwrap();
        let c = line.find("\"cands\"").unwrap();
        assert!(a < c);
    }

    #[test]
    fn jsonl_batch_is_line_per_event() {
        let evs = vec![
            Event { seq: 0, vtime: 0.0, kind: EventKind::Queue { scope: "s", depth: 1 } },
            Event { seq: 1, vtime: 0.5, kind: EventKind::Wal { op: "append", bytes: 64 } },
        ];
        let text = to_jsonl(&evs);
        assert_eq!(text.lines().count(), 2);
        assert!(text.ends_with('\n'));
        assert!(text.lines().nth(1).unwrap().contains("\"bytes\":64"));
    }

    #[test]
    fn restrict_labels() {
        assert_eq!(Restrict::All.label(), "all");
        assert_eq!(Restrict::Only(vec![0, 3]).label(), "only[0,3]");
        assert_eq!(Restrict::Banned.label(), "banned");
    }
}
