//! Event sinks: the trait every traced entry point takes, the no-op
//! default, and the recording implementation.
//!
//! The contract emit sites must follow (and the `obs_parity` suite
//! pins): *nothing observable about a decision may depend on the sink*.
//! Sites may branch on [`Sink::enabled`] only to skip building event
//! payloads — never to skip or reorder scheduling work — so the
//! recording and no-op paths execute the same arithmetic in the same
//! order.

use super::event::{Event, EventKind};

/// Receiver for deterministic trace events.
///
/// `emit` takes the event's virtual time plus its payload; the sink is
/// responsible for sequence numbering (a monotone counter, *not* a
/// clock — hetlint R4 holds in this module).
pub trait Sink {
    /// Whether emitted events are observed.  Emit sites use this to
    /// skip payload construction (candidate vectors, restricted-set
    /// snapshots) on the untraced path.
    fn enabled(&self) -> bool;
    /// Record one event at virtual time `vtime`.
    fn emit(&mut self, vtime: f64, kind: EventKind);
}

/// The default sink: drops everything, reports disabled.
pub struct NoopSink;

impl Sink for NoopSink {
    fn enabled(&self) -> bool {
        false
    }
    fn emit(&mut self, _vtime: f64, _kind: EventKind) {}
}

/// In-memory recorder assigning a monotone sequence number per event.
///
/// [`RecordingSink::take`] drains the buffer without resetting the
/// sequence counter, so a streaming consumer (the daemon's
/// `--trace-out` writer) sees globally monotone `seq` across drains.
#[derive(Default)]
pub struct RecordingSink {
    events: Vec<Event>,
    next_seq: u64,
}

impl RecordingSink {
    pub fn new() -> RecordingSink {
        RecordingSink::default()
    }

    /// Events recorded since construction (or the last [`Self::take`]).
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Drain the buffered events, keeping the sequence counter.
    pub fn take(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// Total events emitted over the sink's lifetime (drained or not).
    pub fn emitted(&self) -> u64 {
        self.next_seq
    }
}

impl Sink for RecordingSink {
    fn enabled(&self) -> bool {
        true
    }
    fn emit(&mut self, vtime: f64, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.events.push(Event { seq, vtime, kind });
    }
}

/// `Option<RecordingSink>` is the natural shape for a struct field
/// (tracing off by default, switched on once): `None` behaves as
/// [`NoopSink`], `Some` records.
impl Sink for Option<RecordingSink> {
    fn enabled(&self) -> bool {
        self.is_some()
    }
    fn emit(&mut self, vtime: f64, kind: EventKind) {
        if let Some(rec) = self {
            rec.emit(vtime, kind);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_reports_disabled_and_drops() {
        let mut s = NoopSink;
        assert!(!s.enabled());
        s.emit(1.0, EventKind::Queue { scope: "x", depth: 3 });
    }

    #[test]
    fn recording_assigns_monotone_seq_across_takes() {
        let mut s = RecordingSink::new();
        assert!(s.enabled());
        s.emit(0.0, EventKind::Queue { scope: "a", depth: 1 });
        s.emit(2.5, EventKind::Queue { scope: "a", depth: 2 });
        let first = s.take();
        assert_eq!(first.len(), 2);
        assert_eq!((first[0].seq, first[1].seq), (0, 1));
        s.emit(3.0, EventKind::Queue { scope: "a", depth: 0 });
        let second = s.take();
        assert_eq!(second[0].seq, 2, "seq survives take()");
        assert_eq!(s.emitted(), 3);
    }

    #[test]
    fn option_sink_forwards_only_when_some() {
        let mut off: Option<RecordingSink> = None;
        assert!(!off.enabled());
        off.emit(0.0, EventKind::Queue { scope: "q", depth: 9 });
        let mut on = Some(RecordingSink::new());
        assert!(on.enabled());
        on.emit(1.0, EventKind::Queue { scope: "q", depth: 9 });
        assert_eq!(on.as_ref().map(|r| r.events().len()), Some(1));
    }
}
