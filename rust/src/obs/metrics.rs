//! Metrics registry: monotone counters + fixed-bucket histograms,
//! snapshotted into a [`MetricsReport`] and serialized through
//! `substrate::json`.
//!
//! The registry is deliberately dumb: `u64` counters that only go up
//! and histograms with bounds fixed at registration.  Keys live in a
//! `BTreeMap`, so a report's JSON is deterministic; values observed at
//! the daemon edge (wall-clock latencies, WAL bytes) stay *out of* the
//! wire `report` payload — the `metrics` request is a separate surface
//! precisely so the replay-stable report never mixes with edge timing.

use std::collections::BTreeMap;

use crate::substrate::json::Json;

/// Fixed-bucket histogram: `counts[i]` is the number of observations
/// `<= bounds[i]` (and above `bounds[i-1]`); the last slot is the
/// overflow bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// `bounds` must be finite and strictly ascending.
    pub fn new(bounds: Vec<f64>) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must ascend"
        );
        assert!(bounds.iter().all(|b| b.is_finite()), "bounds must be finite");
        let n = bounds.len() + 1;
        Histogram {
            bounds,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
        }
    }

    pub fn observe(&mut self, x: f64) {
        let i = self.bounds.partition_point(|&b| b < x);
        self.counts[i] += 1;
        self.total += 1;
        self.sum += x;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "bounds",
                Json::Arr(self.bounds.iter().map(|&b| Json::Num(b)).collect()),
            ),
            (
                "counts",
                Json::Arr(self.counts.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            ("sum", Json::Num(self.sum)),
            ("total", Json::Num(self.total as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Histogram, String> {
        let bounds: Vec<f64> = j
            .get("bounds")
            .and_then(Json::as_arr)
            .ok_or("histogram missing bounds")?
            .iter()
            .map(|b| b.as_f64().ok_or("bad bound".to_string()))
            .collect::<Result<_, _>>()?;
        let counts: Vec<u64> = j
            .get("counts")
            .and_then(Json::as_arr)
            .ok_or("histogram missing counts")?
            .iter()
            .map(|c| c.as_f64().map(|x| x as u64).ok_or("bad count".to_string()))
            .collect::<Result<_, _>>()?;
        if counts.len() != bounds.len() + 1 {
            return Err("histogram counts/bounds length mismatch".to_string());
        }
        let sum = j.get("sum").and_then(Json::as_f64).ok_or("histogram missing sum")?;
        let total = j
            .get("total")
            .and_then(Json::as_f64)
            .ok_or("histogram missing total")? as u64;
        Ok(Histogram { bounds, counts, total, sum })
    }
}

/// The mutable registry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    hists: BTreeMap<String, Histogram>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `delta` to a counter (created at 0 on first touch).
    pub fn add(&mut self, key: &str, delta: u64) {
        *self.counters.entry(key.to_string()).or_insert(0) += delta;
    }

    /// Increment a counter by 1.
    pub fn inc(&mut self, key: &str) {
        self.add(key, 1);
    }

    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Register a histogram with the given bucket bounds (no-op if the
    /// key already exists — bounds are fixed at first registration).
    pub fn register_hist(&mut self, key: &str, bounds: &[f64]) {
        self.hists
            .entry(key.to_string())
            .or_insert_with(|| Histogram::new(bounds.to_vec()));
    }

    /// Observe a value into a previously registered histogram.
    pub fn observe(&mut self, key: &str, x: f64) {
        self.hists
            .get_mut(key)
            .unwrap_or_else(|| panic!("histogram {key} not registered"))
            .observe(x);
    }

    pub fn hist(&self, key: &str) -> Option<&Histogram> {
        self.hists.get(key)
    }

    /// Fold another registry into this one (same-key counters add;
    /// same-key histograms require identical bounds and add bucketwise).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.hists {
            match self.hists.get_mut(k) {
                None => {
                    self.hists.insert(k.clone(), h.clone());
                }
                Some(mine) => {
                    assert_eq!(mine.bounds, h.bounds, "merging {k} with different bounds");
                    for (a, b) in mine.counts.iter_mut().zip(&h.counts) {
                        *a += b;
                    }
                    mine.total += h.total;
                    mine.sum += h.sum;
                }
            }
        }
    }

    /// Fold another registry into this one with every key prefixed.
    /// The sharded service uses this for per-shard attribution
    /// (`svc_shard{i}_…` keys beside the global sums); prefixing keeps
    /// the merged key set disjoint from the global one, so plain
    /// [`Self::merge`] semantics (adding) never apply across shards by
    /// accident.
    pub fn merge_prefixed(&mut self, other: &Metrics, prefix: &str) {
        for (k, v) in &other.counters {
            *self.counters.entry(format!("{prefix}{k}")).or_insert(0) += v;
        }
        for (k, h) in &other.hists {
            let key = format!("{prefix}{k}");
            match self.hists.get_mut(&key) {
                None => {
                    self.hists.insert(key, h.clone());
                }
                Some(mine) => {
                    assert_eq!(mine.bounds, h.bounds, "merging {key} with different bounds");
                    for (a, b) in mine.counts.iter_mut().zip(&h.counts) {
                        *a += b;
                    }
                    mine.total += h.total;
                    mine.sum += h.sum;
                }
            }
        }
    }

    /// Freeze into a report.
    pub fn report(&self) -> MetricsReport {
        MetricsReport {
            counters: self.counters.clone(),
            hists: self.hists.clone(),
        }
    }
}

/// Immutable snapshot of a [`Metrics`] registry — the payload of the
/// daemon `metrics` request and of `hetsched metrics`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsReport {
    pub counters: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, Histogram>,
}

impl MetricsReport {
    pub fn to_json(&self) -> Json {
        let counters: Vec<(&str, Json)> = self
            .counters
            .iter()
            .map(|(k, &v)| (k.as_str(), Json::Num(v as f64)))
            .collect();
        let hists: Vec<(&str, Json)> = self
            .hists
            .iter()
            .map(|(k, h)| (k.as_str(), h.to_json()))
            .collect();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("hists", Json::obj(hists)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MetricsReport, String> {
        let mut counters = BTreeMap::new();
        match j.get("counters") {
            Some(Json::Obj(m)) => {
                for (k, v) in m {
                    let n = v.as_f64().ok_or_else(|| format!("bad counter {k}"))?;
                    counters.insert(k.clone(), n as u64);
                }
            }
            _ => return Err("metrics missing counters".to_string()),
        }
        let mut hists = BTreeMap::new();
        match j.get("hists") {
            Some(Json::Obj(m)) => {
                for (k, v) in m {
                    hists.insert(k.clone(), Histogram::from_json(v)?);
                }
            }
            _ => return Err("metrics missing hists".to_string()),
        }
        Ok(MetricsReport { counters, hists })
    }

    /// Human-readable rendering for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("counters:\n");
        for (k, v) in &self.counters {
            out.push_str(&format!("  {k} = {v}\n"));
        }
        if !self.hists.is_empty() {
            out.push_str("histograms:\n");
            for (k, h) in &self.hists {
                out.push_str(&format!("  {k}: total {} sum {}\n", h.total(), h.sum()));
                let mut lo = f64::NEG_INFINITY;
                for (i, &c) in h.counts().iter().enumerate() {
                    let hi = h.bounds().get(i).copied().unwrap_or(f64::INFINITY);
                    if c > 0 {
                        out.push_str(&format!("    ({lo}, {hi}] = {c}\n"));
                    }
                    lo = hi;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotone_and_defaulted() {
        let mut m = Metrics::new();
        assert_eq!(m.counter("x"), 0);
        m.inc("x");
        m.add("x", 4);
        assert_eq!(m.counter("x"), 5);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(vec![1.0, 10.0]);
        h.observe(0.5); // (-inf, 1]
        h.observe(1.0); // boundary goes to the <= bucket
        h.observe(5.0); // (1, 10]
        h.observe(50.0); // overflow
        assert_eq!(h.counts(), &[2, 1, 1]);
        assert_eq!(h.total(), 4);
        assert!((h.sum() - 56.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "ascend")]
    fn histogram_rejects_unsorted_bounds() {
        Histogram::new(vec![2.0, 1.0]);
    }

    #[test]
    fn report_round_trips_exactly() {
        let mut m = Metrics::new();
        m.add("decisions", 42);
        m.inc("wal_appends");
        m.register_hist("lat", &[0.001, 0.01, 0.1]);
        m.observe("lat", 0.004);
        m.observe("lat", 3.0);
        let rep = m.report();
        let j = rep.to_json();
        let back = MetricsReport::from_json(&j).unwrap();
        assert_eq!(back, rep);
        // and the serialized form itself is stable
        assert_eq!(back.to_json().to_string(), j.to_string());
    }

    #[test]
    fn merge_adds_counters_and_buckets() {
        let mut a = Metrics::new();
        a.add("ops", 2);
        a.register_hist("h", &[1.0]);
        a.observe("h", 0.5);
        let mut b = Metrics::new();
        b.add("ops", 3);
        b.add("only_b", 1);
        b.register_hist("h", &[1.0]);
        b.observe("h", 2.0);
        a.merge(&b);
        assert_eq!(a.counter("ops"), 5);
        assert_eq!(a.counter("only_b"), 1);
        assert_eq!(a.hist("h").unwrap().counts(), &[1, 1]);
    }

    #[test]
    fn render_lists_sorted_keys() {
        let mut m = Metrics::new();
        m.inc("b");
        m.inc("a");
        let text = m.report().render();
        let ia = text.find("  a = ").unwrap();
        let ib = text.find("  b = ").unwrap();
        assert!(ia < ib);
    }
}
