//! Decision explanation: render *why a task landed where it did* from a
//! recorded event stream.
//!
//! `hetsched explain --wal <log> --task <tenant:task>` replays the WAL
//! through a recording sink (replay re-emits the exact event stream of
//! the original run — the daemon's decisions are deterministic
//! functions of the op sequence) and hands the events here.  The
//! renderer is a pure function of the events, so its output is pinned
//! byte-for-byte by the `obs_parity` suite.

use super::event::{DecisionEvent, Event, EventKind};

/// Prose for a rule tag (see `PolicyEngine::decide_in_traced` for the
/// emit sites).
fn rule_prose(rule: &str) -> &'static str {
    match rule {
        "erls-step1" => "ER-LS Step 1: p_cpu >= R_gpu + p_gpu, so the GPU finishes it within its own CPU time",
        "erls-step2-cpu" => "ER-LS Step 2: rule R2 (p_cpu/sqrt(m) <= p_gpu/sqrt(k)) chose the CPU side",
        "erls-step2-gpu" => "ER-LS Step 2: rule R2 (p_cpu/sqrt(m) > p_gpu/sqrt(k)) chose the GPU side",
        "erls-cpu-forced" => "ER-LS: the GPU type is quota-banned, CPU is the only open side",
        "erls-gpu-forced" => "ER-LS: the CPU type is quota-banned, GPU is the only open side",
        "r1" => "rule R1 chose this side by the per-type acceleration threshold",
        "r2" => "rule R2 chose this side by sqrt(m)/sqrt(k)-scaled processing times",
        "r3" => "rule R3 chose the side with the smaller processing time",
        "r1-flip" | "r2-flip" | "r3-flip" => {
            "the rule's preferred side is quota-banned; fell through to the other side"
        }
        "greedy" => "Greedy: fastest open type, then its earliest-idle unit",
        "random" => "Random: uniformly drawn type, then its earliest-idle unit",
        "random-walk" => {
            "Random: the drawn type is quota-banned; walked to the next open type"
        }
        "eft" => "EFT: minimized finish time across every allowed unit (exact ties go to the later type)",
        "est" => "EST: earliest-startable ready task on this type's earliest-idle unit",
        "heft" => "HEFT: rank order, then minimum earliest-finish with gap backfilling",
        "list" => "list scheduling: highest-priority ready task on an idle unit of its allocated type",
        _ => "unknown rule",
    }
}

/// Render the explanation for `tenant:task`.  `Err` when the stream
/// holds no decision for that task (never admitted, cancelled before
/// placement, or the wrong tenant id).
pub fn render(events: &[Event], tenant: usize, task: usize) -> Result<String, String> {
    let hit: Option<(&Event, &DecisionEvent)> = events.iter().find_map(|ev| match &ev.kind {
        EventKind::Decision(d) if d.tenant == tenant && d.task == task => Some((ev, d)),
        _ => None,
    });
    let Some((ev, d)) = hit else {
        return Err(format!("no decision recorded for task {tenant}:{task}"));
    };
    // the queue-depth sample emitted just before this decision, if any
    let queue: Option<(&'static str, usize)> = events[..events
        .iter()
        .position(|e| e.seq == ev.seq)
        .unwrap_or(0)]
        .iter()
        .rev()
        .find_map(|e| match e.kind {
            EventKind::Queue { scope, depth } => Some((scope, depth)),
            _ => None,
        });

    let mut out = String::new();
    out.push_str(&format!(
        "task {}:{} — policy {} (event seq {}, virtual time {})\n",
        d.tenant, d.task, d.policy, ev.seq, ev.vtime
    ));
    out.push_str(&format!(
        "  placed: type {} unit {} start {} finish {}\n",
        d.ptype, d.unit, d.start, d.finish
    ));
    out.push_str(&format!("  rule: {} — {}\n", d.rule, rule_prose(d.rule)));
    out.push_str(&format!(
        "  candidates considered: {}; exact-tie cluster size: {}\n",
        d.candidates, d.tie_cluster
    ));
    if d.alternatives.is_empty() {
        out.push_str("  rejected exact ties: none\n");
    } else {
        out.push_str("  rejected exact ties:\n");
        for a in &d.alternatives {
            out.push_str(&format!(
                "    type {} unit {} (finish {})\n",
                a.ptype, a.unit, a.finish
            ));
        }
    }
    if d.restricted.is_empty() {
        out.push_str("  restricted sets: none (unconstrained decision path)\n");
    } else {
        let labels: Vec<String> = d
            .restricted
            .iter()
            .enumerate()
            .map(|(q, r)| format!("q{}={}", q, r.label()))
            .collect();
        out.push_str(&format!("  restricted sets: {}\n", labels.join(" ")));
    }
    if let Some((scope, depth)) = queue {
        out.push_str(&format!("  {scope} depth at decision: {depth}\n"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::{Alt, Restrict};

    fn decision(tenant: usize, task: usize) -> Event {
        Event {
            seq: 1,
            vtime: 2.0,
            kind: EventKind::Decision(DecisionEvent {
                tenant,
                task,
                policy: "EFT",
                rule: "eft",
                candidates: 2,
                tie_cluster: 2,
                alternatives: vec![Alt { ptype: 0, unit: 1, finish: 4.0 }],
                restricted: vec![Restrict::All, Restrict::Banned],
                ptype: 1,
                unit: 0,
                start: 2.0,
                finish: 4.0,
            }),
        }
    }

    #[test]
    fn renders_rule_and_alternatives() {
        let events = vec![
            Event { seq: 0, vtime: 2.0, kind: EventKind::Queue { scope: "stream-heap", depth: 3 } },
            decision(5, 9),
        ];
        let text = render(&events, 5, 9).unwrap();
        assert!(text.contains("task 5:9 — policy EFT"));
        assert!(text.contains("rule: eft —"));
        assert!(text.contains("type 0 unit 1 (finish 4)"));
        assert!(text.contains("q0=all q1=banned"));
        assert!(text.contains("stream-heap depth at decision: 3"));
    }

    #[test]
    fn missing_task_is_an_error() {
        let events = vec![decision(5, 9)];
        let err = render(&events, 5, 10).unwrap_err();
        assert!(err.contains("no decision recorded for task 5:10"));
    }

    #[test]
    fn rendering_is_stable() {
        let events = vec![decision(0, 0)];
        assert_eq!(render(&events, 0, 0).unwrap(), render(&events, 0, 0).unwrap());
    }
}
