//! Live coordinator runtime: the online scheduler driving a real worker
//! pool, StarPU-style (the system the paper targets for deployment, §7).
//!
//! One OS thread per processor unit (CPU and GPU workers), each with a
//! FIFO work queue.  The scheduler thread receives the task stream in a
//! precedence-respecting arrival order, takes the *irrevocable* policy
//! decision at arrival (ER-LS / EFT / Greedy / ... — the same policies
//! as `sched::online`), and dispatches to the chosen unit's queue.
//! Workers block until a task's predecessors have completed, then
//! "execute" it by sleeping `p · time_scale` (scaled virtual time).
//!
//! The run reports realized makespan (virtual time units), per-type busy
//! time, and decision latency, and is cross-checked against the
//! discrete-event prediction of `sched::online` in tests and in
//! `examples/runtime_serve.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::graph::{TaskGraph, TaskId};
use crate::platform::Platform;
use crate::sched::online::OnlinePolicy;
use crate::sim::{Placement, Schedule};
use crate::substrate::pool::WorkQueue;
use crate::substrate::stats::Summary;

#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// wall-clock seconds per virtual time unit (keep small in tests)
    pub time_scale: f64,
    pub policy: OnlinePolicy,
}

#[derive(Clone, Debug)]
pub struct LiveReport {
    /// realized makespan in virtual time units
    pub realized_makespan: f64,
    /// the engine's predicted schedule (same policy, same order)
    pub predicted_makespan: f64,
    pub wall: Duration,
    pub per_type_busy: Vec<f64>,
    pub decision_latency: Summary,
    pub n_tasks: usize,
}

struct TaskMsg {
    task: TaskId,
    dur: f64,
}

struct Tracker {
    remaining: Vec<AtomicUsize>,
    done_flag: Vec<Mutex<bool>>,
    done_cv: Vec<Condvar>,
}

impl Tracker {
    fn new(g: &TaskGraph) -> Tracker {
        Tracker {
            remaining: g.preds.iter().map(|p| AtomicUsize::new(p.len())).collect(),
            done_flag: (0..g.n_tasks()).map(|_| Mutex::new(false)).collect(),
            done_cv: (0..g.n_tasks()).map(|_| Condvar::new()).collect(),
        }
    }

    fn wait_ready(&self, j: TaskId) {
        // fast path
        if self.remaining[j].load(Ordering::Acquire) == 0 {
            return;
        }
        let mut g = self.done_flag[j].lock().unwrap();
        while self.remaining[j].load(Ordering::Acquire) > 0 {
            g = self.done_cv[j].wait(g).unwrap();
        }
        drop(g);
    }

    fn complete(&self, g: &TaskGraph, j: TaskId) {
        for &s in &g.succs[j] {
            if self.remaining[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                let _g = self.done_flag[s].lock().unwrap();
                self.done_cv[s].notify_all();
            }
        }
    }
}

/// Run the task graph live.  Returns the report and the realized
/// schedule (start/finish in virtual time units, measured on the wall).
pub fn run_live(
    g: &TaskGraph,
    plat: &Platform,
    order: &[TaskId],
    cfg: &LiveConfig,
) -> (LiveReport, Schedule) {
    let n = g.n_tasks();
    assert_eq!(order.len(), n);

    // the engine prediction (identical policy and arrival order)
    let predicted = crate::sched::online::online_schedule(g, plat, order, &cfg.policy);

    // worker pool: one queue + thread per unit
    let n_units = plat.n_units();
    let queues: Vec<Arc<WorkQueue<TaskMsg>>> = (0..n_units).map(|_| WorkQueue::new()).collect();
    let _unit_of = {
        // flatten (type, unit) -> linear id
        let mut map = Vec::new();
        for (q, &c) in plat.counts.iter().enumerate() {
            for u in 0..c {
                map.push((q, u));
            }
        }
        map
    };
    let linear_id = |q: usize, u: usize| -> usize {
        plat.counts[..q].iter().sum::<usize>() + u
    };

    let tracker = Arc::new(Tracker::new(g));
    let t0 = Instant::now();
    let scale = cfg.time_scale.max(1e-9);
    // realized (start, finish) in virtual units, recorded by workers
    let spans: Arc<Vec<Mutex<(f64, f64)>>> =
        Arc::new((0..n).map(|_| Mutex::new((0.0, 0.0))).collect());

    std::thread::scope(|scope| {
        // workers
        for unit in 0..n_units {
            let q = Arc::clone(&queues[unit]);
            let tracker = Arc::clone(&tracker);
            let spans = Arc::clone(&spans);
            scope.spawn(move || {
                while let Some(msg) = q.pop() {
                    tracker.wait_ready(msg.task);
                    let start_v = t0.elapsed().as_secs_f64() / scale;
                    std::thread::sleep(Duration::from_secs_f64(msg.dur * scale));
                    let finish_v = t0.elapsed().as_secs_f64() / scale;
                    *spans[msg.task].lock().unwrap() = (start_v, finish_v);
                    tracker.complete(g, msg.task);
                }
            });
        }

        // scheduler: same decision logic as the engine, driven by the
        // predicted state (irrevocable decisions at arrival time)
        let mut latencies = Vec::with_capacity(n);
        for &j in order {
            let td = Instant::now();
            let p = predicted.placements[j];
            latencies.push(td.elapsed().as_secs_f64() + 1e-9);
            let dur = g.time_on(j, p.ptype);
            queues[linear_id(p.ptype, p.unit)].push(TaskMsg { task: j, dur });
        }
        for q in &queues {
            q.close();
        }
        // scope joins workers here
        LAT.with(|l| *l.borrow_mut() = latencies);
    });

    let wall = t0.elapsed();
    let latencies = LAT.with(|l| l.borrow().clone());

    // assemble the realized schedule with the decided placements
    let placements: Vec<Placement> = (0..n)
        .map(|j| {
            let (s, f) = *spans[j].lock().unwrap();
            Placement {
                ptype: predicted.placements[j].ptype,
                unit: predicted.placements[j].unit,
                start: s,
                finish: f,
            }
        })
        .collect();
    let realized = Schedule::from_placements(placements);

    let report = LiveReport {
        realized_makespan: realized.makespan,
        predicted_makespan: predicted.makespan,
        wall,
        per_type_busy: realized.loads(plat.n_types()),
        decision_latency: Summary::of(&latencies),
        n_tasks: n,
    };
    (report, realized)
}

thread_local! {
    static LAT: std::cell::RefCell<Vec<f64>> = const { std::cell::RefCell::new(Vec::new()) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::substrate::rng::Rng;

    #[test]
    fn live_run_matches_prediction_roughly() {
        let mut rng = Rng::new(17);
        let g = gen::hybrid_dag(&mut rng, 30, 0.12);
        let plat = Platform::hybrid(3, 2);
        let order: Vec<usize> = (0..30).collect();
        let cfg = LiveConfig {
            time_scale: 0.0015, // 1.5 ms per unit: fast but measurable
            policy: OnlinePolicy::ErLs,
        };
        let (report, realized) = run_live(&g, &plat, &order, &cfg);
        assert_eq!(report.n_tasks, 30);
        // realized >= predicted (sleep + wakeup overhead only adds)
        assert!(report.realized_makespan >= report.predicted_makespan * 0.95);
        // and within a generous factor (wakeup overhead bounded)
        assert!(
            report.realized_makespan <= report.predicted_makespan * 1.6 + 20.0,
            "realized {} vs predicted {}",
            report.realized_makespan,
            report.predicted_makespan
        );
        // precedence holds in realized schedule
        for j in 0..g.n_tasks() {
            for &s in &g.succs[j] {
                assert!(
                    realized.placements[s].start >= realized.placements[j].finish - 1e-6,
                    "{j} -> {s}"
                );
            }
        }
    }

    #[test]
    fn live_run_all_policies_complete() {
        let mut rng = Rng::new(23);
        let g = gen::hybrid_dag(&mut rng, 15, 0.2);
        let plat = Platform::hybrid(2, 1);
        let order: Vec<usize> = (0..15).collect();
        for policy in [OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let cfg = LiveConfig {
                time_scale: 0.0005,
                policy,
            };
            let (report, _) = run_live(&g, &plat, &order, &cfg);
            assert!(report.realized_makespan > 0.0);
            assert_eq!(report.decision_latency.n, 15);
        }
    }
}
