// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist:
// coordinator/, service_net/, substrate/bench.rs, main.rs, benches/ — runtime
// edges that measure time but never feed it into a scheduling decision).
#![allow(clippy::disallowed_methods)]
//! Live coordinator runtime: the online scheduler driving a real worker
//! pool, StarPU-style (the system the paper targets for deployment, §7).
//!
//! One OS thread per processor unit (CPU and GPU workers), each with a
//! FIFO work queue.  The scheduler thread dispatches the *service*
//! decision stream — many tenants' task graphs arriving over virtual
//! time, each decision irrevocable ([`sched::service`](crate::sched::service)) —
//! to the chosen unit's queue.  Workers block until a task's
//! predecessors have completed, then "execute" it by sleeping
//! `p · time_scale` (scaled virtual time).
//!
//! Two clocks:
//! * `time_scale > 0` — wall-clock execution: realized start/finish are
//!   measured on the wall (in virtual units), so the realized makespan
//!   carries real dispatch/wakeup overhead.
//! * `time_scale == 0` — deterministic virtual clock: workers replay the
//!   discrete-event arithmetic (no sleeping), so the realized schedule
//!   equals the engine prediction *bit for bit* on every run.  This is
//!   the mocked-clock mode the coordinator↔engine agreement tests pin.
//!
//! [`run_live`] (single DAG, kept API) is now a one-tenant special case
//! of [`run_service_live`], which drives N concurrent DAGs over the
//! shared pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::graph::{TaskGraph, TaskId};
use crate::platform::Platform;
use crate::sched::online::OnlinePolicy;
use crate::sched::service::{run_service, ServiceReport, Submission};
use crate::sim::{Placement, Schedule};
use crate::substrate::pool::WorkQueue;
use crate::substrate::stats::Summary;

#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// wall-clock seconds per virtual time unit (keep small in tests);
    /// 0.0 selects the deterministic virtual clock (no sleeping,
    /// realized == predicted exactly)
    pub time_scale: f64,
    pub policy: OnlinePolicy,
}

#[derive(Clone, Debug)]
pub struct LiveReport {
    /// realized makespan in virtual time units
    pub realized_makespan: f64,
    /// the engine's predicted schedule (same policy, same order)
    pub predicted_makespan: f64,
    pub wall: Duration,
    pub per_type_busy: Vec<f64>,
    /// wall-clock seconds per dispatched decision, measured at this
    /// coordinator edge (never inside the scheduler core)
    pub decision_latency: Summary,
    pub n_tasks: usize,
}

/// Config for the multi-tenant live service run.
#[derive(Clone, Debug)]
pub struct ServiceLiveConfig {
    /// wall-clock seconds per virtual time unit; 0.0 = virtual clock
    pub time_scale: f64,
}

/// Outcome of a multi-tenant live run.
#[derive(Debug)]
pub struct ServiceLiveReport {
    /// the engine's prediction (placements, metrics, decision stream)
    pub predicted: ServiceReport,
    /// realized per-tenant schedules (virtual time units)
    pub realized: Vec<Schedule>,
    /// realized completion − arrival, per tenant
    pub realized_flow: Vec<f64>,
    /// realized horizon across all tenants
    pub realized_makespan: f64,
    /// per-tenant wall-clock dispatch latency, measured here at the
    /// coordinator edge (the scheduler core never reads the clock; the
    /// engine's own `TenantReport::decision_latency` is empty for batch
    /// runs and fed only by a daemon/coordinator edge)
    pub dispatch_latency: Vec<Summary>,
    pub wall: Duration,
}

struct TaskMsg {
    tenant: usize,
    task: TaskId,
    dur: f64,
}

struct Tracker {
    remaining: Vec<AtomicUsize>,
    done_flag: Vec<Mutex<bool>>,
    done_cv: Vec<Condvar>,
}

impl Tracker {
    fn new(g: &TaskGraph) -> Tracker {
        Tracker {
            remaining: g.preds.iter().map(|p| AtomicUsize::new(p.len())).collect(),
            done_flag: (0..g.n_tasks()).map(|_| Mutex::new(false)).collect(),
            done_cv: (0..g.n_tasks()).map(|_| Condvar::new()).collect(),
        }
    }

    fn wait_ready(&self, j: TaskId) {
        // fast path
        if self.remaining[j].load(Ordering::Acquire) == 0 {
            return;
        }
        let mut g = self.done_flag[j].lock().unwrap();
        while self.remaining[j].load(Ordering::Acquire) > 0 {
            g = self.done_cv[j].wait(g).unwrap();
        }
        drop(g);
    }

    fn complete(&self, g: &TaskGraph, j: TaskId) {
        for &s in &g.succs[j] {
            if self.remaining[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                let _g = self.done_flag[s].lock().unwrap();
                self.done_cv[s].notify_all();
            }
        }
    }
}

/// Drive N concurrent task graphs live over the shared worker pool,
/// following the service decision stream.  Returns prediction and
/// realization; with `time_scale == 0` the two agree exactly.
pub fn run_service_live(
    plat: &Platform,
    subs: &[Submission],
    cfg: &ServiceLiveConfig,
) -> ServiceLiveReport {
    // the engine prediction: placements + global decision order
    let predicted = run_service(plat, subs);

    let n_units = plat.n_units();
    let queues: Vec<_> = (0..n_units).map(|_| WorkQueue::<TaskMsg>::new()).collect();
    let linear_id = |q: usize, u: usize| -> usize { plat.counts[..q].iter().sum::<usize>() + u };

    let trackers: Vec<Tracker> = subs.iter().map(|s| Tracker::new(&s.graph)).collect();
    // realized (start, finish) in virtual units, per tenant per task
    let spans: Vec<Vec<Mutex<(f64, f64)>>> = subs
        .iter()
        .map(|s| (0..s.graph.n_tasks()).map(|_| Mutex::new((0.0, 0.0))).collect())
        .collect();

    let virtual_clock = cfg.time_scale <= 0.0;
    let scale = cfg.time_scale;
    let mut dispatch_lat: Vec<Vec<f64>> = vec![Vec::new(); subs.len()];
    let t0 = Instant::now();

    std::thread::scope(|scope| {
        // workers: one thread per unit, FIFO in dispatch (= decision) order
        for unit_queue in queues.iter() {
            let trackers = &trackers;
            let spans = &spans;
            scope.spawn(move || {
                // the unit's own virtual free time (virtual-clock replay)
                let mut unit_free = 0.0f64;
                while let Some(msg) = unit_queue.pop() {
                    let g = &subs[msg.tenant].graph;
                    trackers[msg.tenant].wait_ready(msg.task);
                    if virtual_clock {
                        // deterministic discrete-event replay: identical
                        // arithmetic to the engine's prediction
                        let ready = g.preds[msg.task]
                            .iter()
                            .map(|&p| spans[msg.tenant][p].lock().unwrap().1)
                            .fold(subs[msg.tenant].arrival, f64::max);
                        let start = ready.max(unit_free);
                        let finish = start + msg.dur;
                        unit_free = finish;
                        *spans[msg.tenant][msg.task].lock().unwrap() = (start, finish);
                    } else {
                        let start_v = t0.elapsed().as_secs_f64() / scale;
                        std::thread::sleep(Duration::from_secs_f64(msg.dur * scale));
                        let finish_v = t0.elapsed().as_secs_f64() / scale;
                        *spans[msg.tenant][msg.task].lock().unwrap() = (start_v, finish_v);
                    }
                    trackers[msg.tenant].complete(g, msg.task);
                }
            });
        }

        // dispatcher: release the decision stream in global order,
        // holding each tenant's tasks back until its arrival time.
        // Per-decision dispatch latency is measured here — the
        // coordinator is on the wall-clock allowlist; the scheduler
        // core itself never reads the clock.
        for d in &predicted.decisions {
            if !virtual_clock {
                let target = t0 + Duration::from_secs_f64(subs[d.tenant].arrival * scale);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
            }
            let td = Instant::now();
            let p = predicted.tenants[d.tenant].schedule.placements[d.task];
            let dur = subs[d.tenant].graph.time_on(d.task, p.ptype);
            queues[linear_id(p.ptype, p.unit)].push(TaskMsg {
                tenant: d.tenant,
                task: d.task,
                dur,
            });
            dispatch_lat[d.tenant].push(td.elapsed().as_secs_f64().max(f64::MIN_POSITIVE));
        }
        for q in &queues {
            q.close();
        }
        // scope joins workers here
    });
    let wall = t0.elapsed();

    // assemble the realized schedules with the decided placements
    let realized: Vec<Schedule> = subs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Schedule::from_placements(
                (0..s.graph.n_tasks())
                    .map(|j| {
                        let (start, finish) = *spans[i][j].lock().unwrap();
                        let p = predicted.tenants[i].schedule.placements[j];
                        Placement {
                            ptype: p.ptype,
                            unit: p.unit,
                            start,
                            finish,
                        }
                    })
                    .collect(),
            )
        })
        .collect();
    let realized_flow: Vec<f64> = realized
        .iter()
        .zip(subs)
        .map(|(r, s)| r.makespan - s.arrival)
        .collect();
    let realized_makespan = realized.iter().fold(0.0f64, |a, r| a.max(r.makespan));

    let dispatch_latency: Vec<Summary> = dispatch_lat.iter().map(|v| Summary::of(v)).collect();

    ServiceLiveReport {
        predicted,
        realized,
        realized_flow,
        realized_makespan,
        dispatch_latency,
        wall,
    }
}

/// Run one task graph live (kept API: a single-tenant service run).
/// Returns the report and the realized schedule (start/finish in virtual
/// time units; measured on the wall unless `time_scale == 0`).
pub fn run_live(
    g: &TaskGraph,
    plat: &Platform,
    order: &[TaskId],
    cfg: &LiveConfig,
) -> (LiveReport, Schedule) {
    let n = g.n_tasks();
    assert_eq!(order.len(), n);
    let subs = [Submission::new(g.clone(), 0.0, cfg.policy.clone()).with_order(order.to_vec())];
    let out = run_service_live(
        plat,
        &subs,
        &ServiceLiveConfig {
            time_scale: cfg.time_scale,
        },
    );
    let realized = out.realized.into_iter().next().unwrap();
    let report = LiveReport {
        realized_makespan: realized.makespan,
        predicted_makespan: out.predicted.tenants[0].schedule.makespan,
        wall: out.wall,
        per_type_busy: realized.loads(plat.n_types()),
        // edge-measured dispatch latency; the engine's batch report
        // carries an empty latency summary by design
        decision_latency: out.dispatch_latency.into_iter().next().unwrap(),
        n_tasks: n,
    };
    (report, realized)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::sched::online::online_by_id;
    use crate::substrate::rng::Rng;

    #[test]
    fn live_run_matches_prediction_roughly() {
        let mut rng = Rng::new(17);
        let g = gen::hybrid_dag(&mut rng, 30, 0.12);
        let plat = Platform::hybrid(3, 2);
        let order: Vec<usize> = (0..30).collect();
        let cfg = LiveConfig {
            time_scale: 0.0015, // 1.5 ms per unit: fast but measurable
            policy: OnlinePolicy::ErLs,
        };
        let (report, realized) = run_live(&g, &plat, &order, &cfg);
        assert_eq!(report.n_tasks, 30);
        // realized >= predicted (sleep + wakeup overhead only adds)
        assert!(report.realized_makespan >= report.predicted_makespan * 0.95);
        // and within a generous factor (wakeup overhead bounded)
        assert!(
            report.realized_makespan <= report.predicted_makespan * 1.6 + 20.0,
            "realized {} vs predicted {}",
            report.realized_makespan,
            report.predicted_makespan
        );
        // precedence holds in realized schedule
        for j in 0..g.n_tasks() {
            for &s in &g.succs[j] {
                assert!(
                    realized.placements[s].start >= realized.placements[j].finish - 1e-6,
                    "{j} -> {s}"
                );
            }
        }
    }

    #[test]
    fn live_run_all_policies_complete() {
        let mut rng = Rng::new(23);
        let g = gen::hybrid_dag(&mut rng, 15, 0.2);
        let plat = Platform::hybrid(2, 1);
        let order: Vec<usize> = (0..15).collect();
        for policy in [OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let cfg = LiveConfig {
                time_scale: 0.0005,
                policy,
            };
            let (report, _) = run_live(&g, &plat, &order, &cfg);
            assert!(report.realized_makespan > 0.0);
            assert_eq!(report.decision_latency.n, 15);
        }
    }

    #[test]
    fn virtual_clock_single_tenant_agrees_with_engine_exactly() {
        // coordinator↔engine agreement: with the deterministic virtual
        // clock, the realized makespan equals the engine prediction
        // bit for bit, for every policy
        let mut rng = Rng::new(29);
        let g = gen::hybrid_dag(&mut rng, 40, 0.1);
        let plat = Platform::hybrid(3, 2);
        let order: Vec<usize> = (0..40).collect();
        for policy in [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let engine = online_by_id(&g, &plat, &policy);
            let cfg = LiveConfig {
                time_scale: 0.0,
                policy,
            };
            let (report, realized) = run_live(&g, &plat, &order, &cfg);
            assert_eq!(report.realized_makespan, report.predicted_makespan);
            assert_eq!(report.predicted_makespan, engine.makespan);
            assert_eq!(realized.placements, engine.placements);
        }
    }

    #[test]
    fn virtual_clock_contended_realizes_at_least_single_tenant_prediction() {
        // two identical single-task tenants on one CPU: the realized
        // (contended) flow of the queued tenant strictly exceeds its
        // single-tenant predicted makespan, while matching the service
        // prediction exactly
        let mk = || {
            let mut b = Builder::new("one");
            b.add_task("t", vec![2.0, 50.0]);
            b.build()
        };
        let plat = Platform::hybrid(1, 1);
        let subs = vec![
            Submission::new(mk(), 0.0, OnlinePolicy::Greedy),
            Submission::new(mk(), 0.0, OnlinePolicy::Greedy),
        ];
        let out = run_service_live(&plat, &subs, &ServiceLiveConfig { time_scale: 0.0 });
        for (i, t) in out.predicted.tenants.iter().enumerate() {
            assert_eq!(out.realized[i].placements, t.schedule.placements);
            assert_eq!(out.realized_flow[i], t.flow_time);
            // contended realization never beats the single-tenant ideal here
            assert!(out.realized_flow[i] >= t.ideal_makespan - 1e-12);
        }
        assert_eq!(out.realized_flow[0], 2.0);
        assert_eq!(out.realized_flow[1], 4.0); // queued behind tenant 0
        assert_eq!(out.realized_makespan, 4.0);
    }

    #[test]
    fn virtual_clock_multi_tenant_random_dags_agree_exactly() {
        let mut rng = Rng::new(31);
        let plat = Platform::hybrid(3, 2);
        let subs: Vec<Submission> = (0..4)
            .map(|t| {
                let g = gen::hybrid_dag(&mut rng, 25, 0.12);
                let policy = if t % 2 == 0 {
                    OnlinePolicy::ErLs
                } else {
                    OnlinePolicy::Eft
                };
                Submission::new(g, t as f64 * 2.0, policy)
            })
            .collect();
        let out = run_service_live(&plat, &subs, &ServiceLiveConfig { time_scale: 0.0 });
        for (i, t) in out.predicted.tenants.iter().enumerate() {
            assert_eq!(out.realized[i].placements, t.schedule.placements, "tenant {i}");
        }
        assert_eq!(out.realized_makespan, out.predicted.horizon);
    }

    #[test]
    fn virtual_clock_honors_admission_policies_exactly() {
        // quota-capped and weighted-stretch tenants through the live
        // coordinator: the virtual-clock realization must equal the
        // policy-aware prediction bit for bit, and the quota tenant's
        // realized placements must respect its held-units cap
        use crate::sched::service::TenantPolicy;
        let mut rng = Rng::new(41);
        let plat = Platform::hybrid(4, 2);
        let admissions = [
            TenantPolicy::Quota { cpu_share: 0.25, gpu_share: 0.5 },
            TenantPolicy::WeightedStretch { weight: 2.0 },
            TenantPolicy::Fifo,
            TenantPolicy::WeightedStretch { weight: 0.5 },
        ];
        let subs: Vec<Submission> = (0..4)
            .map(|t| {
                let g = gen::hybrid_dag(&mut rng, 20, 0.12);
                let policy = if t % 2 == 0 {
                    OnlinePolicy::Greedy
                } else {
                    OnlinePolicy::Eft
                };
                Submission::new(g, t as f64 * 1.5, policy).with_admission(admissions[t].clone())
            })
            .collect();
        let out = run_service_live(&plat, &subs, &ServiceLiveConfig { time_scale: 0.0 });
        for (i, t) in out.predicted.tenants.iter().enumerate() {
            assert_eq!(out.realized[i].placements, t.schedule.placements, "tenant {i}");
        }
        assert_eq!(out.realized_makespan, out.predicted.horizon);
        // the quota tenant (caps: 1 CPU, 1 GPU) never holds two units of
        // one type at once: any two time-overlapping same-type tasks of
        // its realized schedule must share their unit
        let ps = &out.realized[0].placements;
        for a in ps.iter() {
            for b in ps.iter() {
                if a.ptype == b.ptype && a.start < b.finish && b.start < a.finish {
                    assert_eq!(a.unit, b.unit, "cap-1 tenant spread across units");
                }
            }
        }
    }

    #[test]
    fn service_live_wall_mode_multi_tenant_completes() {
        let mut rng = Rng::new(37);
        let plat = Platform::hybrid(2, 1);
        let subs: Vec<Submission> = (0..3)
            .map(|t| {
                let g = gen::hybrid_dag(&mut rng, 10, 0.2);
                Submission::new(g, t as f64 * 1.0, OnlinePolicy::Greedy)
            })
            .collect();
        let out = run_service_live(&plat, &subs, &ServiceLiveConfig { time_scale: 0.0005 });
        assert_eq!(out.realized.len(), 3);
        for (i, r) in out.realized.iter().enumerate() {
            // realized respects precedence and the tenant's arrival
            let g = &subs[i].graph;
            for j in 0..g.n_tasks() {
                assert!(r.placements[j].start >= subs[i].arrival - 1e-6);
                for &s in &g.succs[j] {
                    assert!(r.placements[s].start >= r.placements[j].finish - 1e-6);
                }
            }
        }
        assert!(out.realized_makespan >= out.predicted.horizon * 0.9);
    }
}
