//! Schedules and their validation: the discrete outcome of every
//! algorithm in the paper, plus a feasibility checker used by tests and
//! by the property suite (precedences respected, units never overlap,
//! durations match the allocation, makespan consistent).

use crate::graph::{TaskGraph, TaskId};
use crate::platform::Platform;

/// Where and when one task runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Placement {
    /// processor type (0 = CPU, 1.. = GPU types)
    pub ptype: usize,
    /// unit index within the type (0..counts[ptype])
    pub unit: usize,
    pub start: f64,
    pub finish: f64,
}

/// A complete schedule: one placement per task.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub placements: Vec<Placement>,
    pub makespan: f64,
}

impl Schedule {
    pub fn from_placements(placements: Vec<Placement>) -> Schedule {
        let makespan = placements.iter().map(|p| p.finish).fold(0.0, f64::max);
        Schedule { placements, makespan }
    }

    pub fn allocation(&self) -> Vec<usize> {
        self.placements.iter().map(|p| p.ptype).collect()
    }

    /// Total busy time per type ("load" in the paper's analyses).
    pub fn loads(&self, n_types: usize) -> Vec<f64> {
        let mut w = vec![0.0; n_types];
        for p in &self.placements {
            w[p.ptype] += p.finish - p.start;
        }
        w
    }

    /// Average utilization per type over [0, makespan).
    pub fn utilization(&self, plat: &Platform) -> Vec<f64> {
        if self.makespan <= 0.0 {
            return vec![0.0; plat.n_types()];
        }
        self.loads(plat.n_types())
            .iter()
            .zip(&plat.counts)
            .map(|(w, &c)| w / (self.makespan * c as f64))
            .collect()
    }

    /// Gantt-style text rendering (one line per unit), for debugging and
    /// the `hetsched schedule --gantt` CLI.
    pub fn gantt(&self, g: &TaskGraph, plat: &Platform) -> String {
        let mut per_unit: Vec<Vec<(TaskId, &Placement)>> = Vec::new();
        let mut unit_index = std::collections::BTreeMap::new();
        for (q, &cnt) in plat.counts.iter().enumerate() {
            for u in 0..cnt {
                unit_index.insert((q, u), per_unit.len());
                per_unit.push(Vec::new());
            }
        }
        for (j, p) in self.placements.iter().enumerate() {
            per_unit[unit_index[&(p.ptype, p.unit)]].push((j, p));
        }
        let mut out = String::new();
        let mut row = 0;
        for (q, &cnt) in plat.counts.iter().enumerate() {
            for u in 0..cnt {
                let tasks = &mut per_unit[row];
                tasks.sort_by(|a, b| a.1.start.total_cmp(&b.1.start));
                out.push_str(&format!("{}[{}]:", plat.names[q], u));
                for (j, p) in tasks.iter() {
                    out.push_str(&format!(
                        " {}#{}@[{:.2},{:.2})",
                        g.names[*j], j, p.start, p.finish
                    ));
                }
                out.push('\n');
                row += 1;
            }
        }
        out
    }
}

/// Per-tenant checks shared by [`validate_schedule`] and
/// [`validate_service`]: placement count, type/unit ranges, exact
/// durations, starts after `arrival`, finishes within the schedule's
/// makespan, precedences respected.  Unit overlap is checked separately
/// (for a service it must run over the *merged* pool view).
fn check_tenant(
    g: &TaskGraph,
    plat: &Platform,
    s: &Schedule,
    arrival: f64,
    who: &str,
) -> Result<(), String> {
    let n = g.n_tasks();
    if s.placements.len() != n {
        return Err(format!(
            "{who}schedule has {} placements for {} tasks",
            s.placements.len(),
            n
        ));
    }
    for (j, p) in s.placements.iter().enumerate() {
        if p.ptype >= plat.n_types() {
            return Err(format!("{who}task {j}: type {} out of range", p.ptype));
        }
        if p.unit >= plat.counts[p.ptype] {
            return Err(format!("{who}task {j}: unit {} out of range", p.unit));
        }
        if p.start < arrival - 1e-9 {
            return Err(format!(
                "{who}task {j}: start {} before arrival {arrival}",
                p.start
            ));
        }
        let want = g.time_on(j, p.ptype);
        if (p.finish - p.start - want).abs() > 1e-6 * (1.0 + want) {
            return Err(format!(
                "{who}task {j}: duration {} != allocated time {}",
                p.finish - p.start,
                want
            ));
        }
        if p.finish > s.makespan + 1e-6 {
            return Err(format!("{who}task {j} finishes after makespan"));
        }
    }
    for j in 0..n {
        for &succ in &g.succs[j] {
            if s.placements[succ].start < s.placements[j].finish - 1e-6 {
                return Err(format!(
                    "{who}precedence violated: {j} finishes {} but {succ} starts {}",
                    s.placements[j].finish, s.placements[succ].start
                ));
            }
        }
    }
    Ok(())
}

/// No-overlap check over a merged per-unit interval view; `label` names
/// the task (e.g. "3" or "t2/7" for tenant 2's task 7).
fn check_no_overlap(
    per_unit: std::collections::BTreeMap<(usize, usize), Vec<(f64, f64, String)>>,
) -> Result<(), String> {
    for ((q, u), mut iv) in per_unit {
        iv.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in iv.windows(2) {
            if w[1].0 < w[0].1 - 1e-6 {
                return Err(format!(
                    "overlap on {q}/{u}: task {} [{:.4},{:.4}) vs task {} [{:.4},{:.4})",
                    w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                ));
            }
        }
    }
    Ok(())
}

/// Full feasibility validation of a single-application schedule: every
/// task placed exactly once on a valid unit, exact durations, all
/// precedences respected, and no two tasks overlapping on one unit.
/// The canonical checker behind the `schedule_invariants` property suite
/// and (via [`validate_service`]) the multi-tenant service mode.
pub fn validate_schedule(g: &TaskGraph, plat: &Platform, s: &Schedule) -> Result<(), String> {
    check_tenant(g, plat, s, 0.0, "")?;
    let mut per_unit: std::collections::BTreeMap<(usize, usize), Vec<(f64, f64, String)>> =
        std::collections::BTreeMap::new();
    for (j, p) in s.placements.iter().enumerate() {
        per_unit
            .entry((p.ptype, p.unit))
            .or_default()
            .push((p.start, p.finish, j.to_string()));
    }
    check_no_overlap(per_unit)
}

/// Back-compat name for [`validate_schedule`].
pub fn validate(g: &TaskGraph, plat: &Platform, s: &Schedule) -> Result<(), String> {
    validate_schedule(g, plat, s)
}

/// One tenant's run inside a shared-pool service: its graph, its
/// placements (absolute virtual times on the shared pool), and the
/// virtual time it arrived.
#[derive(Clone, Copy)]
pub struct TenantRun<'a> {
    pub graph: &'a TaskGraph,
    pub schedule: &'a Schedule,
    pub arrival: f64,
}

/// Tenant-aware schedule merge + validation: per-tenant feasibility
/// (placements, durations, precedences, starts after the tenant's
/// arrival) plus the pool-wide invariant that no two tasks of *any*
/// tenants overlap on one unit.
pub fn validate_service(plat: &Platform, runs: &[TenantRun]) -> Result<(), String> {
    let mut per_unit: std::collections::BTreeMap<(usize, usize), Vec<(f64, f64, String)>> =
        std::collections::BTreeMap::new();
    for (i, r) in runs.iter().enumerate() {
        check_tenant(r.graph, plat, r.schedule, r.arrival, &format!("tenant {i}: "))?;
        for (j, p) in r.schedule.placements.iter().enumerate() {
            per_unit
                .entry((p.ptype, p.unit))
                .or_default()
                .push((p.start, p.finish, format!("t{i}/{j}")));
        }
    }
    check_no_overlap(per_unit)
}

/// Pool-wide no-overlap check over raw placements from any mix of
/// tenants (labels are ordinals).  Used where per-tenant schedules are
/// not graph-aligned — e.g. the cancellation tests, whose cancelled
/// tenants report only their kept tasks — so [`validate_service`] cannot
/// run on them.
pub fn validate_placements_no_overlap<'a>(
    placements: impl IntoIterator<Item = &'a Placement>,
) -> Result<(), String> {
    let mut per_unit: std::collections::BTreeMap<(usize, usize), Vec<(f64, f64, String)>> =
        std::collections::BTreeMap::new();
    for (idx, p) in placements.into_iter().enumerate() {
        per_unit
            .entry((p.ptype, p.unit))
            .or_default()
            .push((p.start, p.finish, idx.to_string()));
    }
    check_no_overlap(per_unit)
}

/// Validation for *realized* (wall-clock measured) schedules from the
/// live coordinator: precedence + no-overlap + duration ≥ allocated
/// time.  Realized durations legitimately exceed the nominal processing
/// time (sleep/wakeup overhead), so the exact-duration check of
/// [`validate`] does not apply.
pub fn validate_realized(g: &TaskGraph, plat: &Platform, s: &Schedule) -> Result<(), String> {
    let n = g.n_tasks();
    if s.placements.len() != n {
        return Err("placement count mismatch".into());
    }
    for (j, p) in s.placements.iter().enumerate() {
        if p.ptype >= plat.n_types() || p.unit >= plat.counts[p.ptype] {
            return Err(format!("task {j}: unit out of range"));
        }
        let want = g.time_on(j, p.ptype);
        if p.finish - p.start < want - 1e-6 * (1.0 + want) {
            return Err(format!(
                "task {j}: realized duration {} below allocated {}",
                p.finish - p.start,
                want
            ));
        }
    }
    for j in 0..n {
        for &succ in &g.succs[j] {
            if s.placements[succ].start < s.placements[j].finish - 1e-6 {
                return Err(format!("precedence violated: {j} -> {succ}"));
            }
        }
    }
    let mut per_unit: std::collections::BTreeMap<(usize, usize), Vec<(f64, f64)>> =
        std::collections::BTreeMap::new();
    for p in &s.placements {
        per_unit
            .entry((p.ptype, p.unit))
            .or_default()
            .push((p.start, p.finish));
    }
    for ((q, u), mut iv) in per_unit {
        iv.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in iv.windows(2) {
            if w[1].0 < w[0].1 - 1e-6 {
                return Err(format!("overlap on unit {q}/{u}"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;

    fn chain2() -> TaskGraph {
        let mut b = Builder::new("c");
        let a = b.add_task("a", vec![2.0, 1.0]);
        let c = b.add_task("b", vec![3.0, 1.0]);
        b.add_arc(a, c);
        b.build()
    }

    fn plat() -> Platform {
        Platform::hybrid(2, 1)
    }

    #[test]
    fn valid_schedule_passes() {
        let g = chain2();
        let s = Schedule::from_placements(vec![
            Placement { ptype: 0, unit: 0, start: 0.0, finish: 2.0 },
            Placement { ptype: 1, unit: 0, start: 2.0, finish: 3.0 },
        ]);
        validate(&g, &plat(), &s).unwrap();
        assert_eq!(s.makespan, 3.0);
        assert_eq!(s.allocation(), vec![0, 1]);
        assert_eq!(s.loads(2), vec![2.0, 1.0]);
    }

    #[test]
    fn precedence_violation_caught() {
        let g = chain2();
        let s = Schedule::from_placements(vec![
            Placement { ptype: 0, unit: 0, start: 0.0, finish: 2.0 },
            Placement { ptype: 1, unit: 0, start: 1.0, finish: 2.0 },
        ]);
        assert!(validate(&g, &plat(), &s).unwrap_err().contains("precedence"));
    }

    #[test]
    fn overlap_caught() {
        let mut b = Builder::new("i");
        b.add_task("a", vec![2.0, 1.0]);
        b.add_task("b", vec![3.0, 1.0]);
        let g = b.build();
        let s = Schedule::from_placements(vec![
            Placement { ptype: 0, unit: 0, start: 0.0, finish: 2.0 },
            Placement { ptype: 0, unit: 0, start: 1.0, finish: 4.0 },
        ]);
        assert!(validate(&g, &plat(), &s).unwrap_err().contains("overlap"));
    }

    #[test]
    fn wrong_duration_caught() {
        let g = chain2();
        let s = Schedule::from_placements(vec![
            Placement { ptype: 0, unit: 0, start: 0.0, finish: 1.0 },
            Placement { ptype: 1, unit: 0, start: 1.0, finish: 2.0 },
        ]);
        assert!(validate(&g, &plat(), &s).unwrap_err().contains("duration"));
    }

    #[test]
    fn unit_out_of_range_caught() {
        let g = chain2();
        let s = Schedule::from_placements(vec![
            Placement { ptype: 1, unit: 5, start: 0.0, finish: 1.0 },
            Placement { ptype: 1, unit: 0, start: 1.0, finish: 2.0 },
        ]);
        assert!(validate(&g, &plat(), &s).unwrap_err().contains("unit"));
    }

    #[test]
    fn service_cross_tenant_overlap_caught() {
        let mut b = Builder::new("one");
        b.add_task("t", vec![2.0, 1.0]);
        let g = b.build();
        let s0 = Schedule::from_placements(vec![Placement {
            ptype: 0,
            unit: 0,
            start: 0.0,
            finish: 2.0,
        }]);
        let s1 = Schedule::from_placements(vec![Placement {
            ptype: 0,
            unit: 0,
            start: 1.0,
            finish: 3.0,
        }]);
        let runs = [
            TenantRun { graph: &g, schedule: &s0, arrival: 0.0 },
            TenantRun { graph: &g, schedule: &s1, arrival: 1.0 },
        ];
        let err = validate_service(&plat(), &runs).unwrap_err();
        assert!(err.contains("overlap"), "{err}");
        // same placements on distinct units are fine
        let s1b = Schedule::from_placements(vec![Placement {
            ptype: 0,
            unit: 1,
            start: 1.0,
            finish: 3.0,
        }]);
        let runs_ok = [
            TenantRun { graph: &g, schedule: &s0, arrival: 0.0 },
            TenantRun { graph: &g, schedule: &s1b, arrival: 1.0 },
        ];
        validate_service(&plat(), &runs_ok).unwrap();
    }

    #[test]
    fn service_start_before_arrival_caught() {
        let mut b = Builder::new("one");
        b.add_task("t", vec![2.0, 1.0]);
        let g = b.build();
        let s = Schedule::from_placements(vec![Placement {
            ptype: 0,
            unit: 0,
            start: 0.0,
            finish: 2.0,
        }]);
        let runs = [TenantRun { graph: &g, schedule: &s, arrival: 5.0 }];
        let err = validate_service(&plat(), &runs).unwrap_err();
        assert!(err.contains("before arrival"), "{err}");
    }

    #[test]
    fn utilization_and_gantt() {
        let g = chain2();
        let s = Schedule::from_placements(vec![
            Placement { ptype: 0, unit: 0, start: 0.0, finish: 2.0 },
            Placement { ptype: 1, unit: 0, start: 2.0, finish: 3.0 },
        ]);
        let u = s.utilization(&plat());
        assert!((u[0] - 2.0 / 6.0).abs() < 1e-12);
        assert!((u[1] - 1.0 / 3.0).abs() < 1e-12);
        let gantt = s.gantt(&g, &plat());
        assert!(gantt.contains("CPU[0]: a#0@[0.00,2.00)"));
        assert!(gantt.contains("GPU[0]: b#1@[2.00,3.00)"));
    }
}
