//! Per-kernel cost model: the stand-in for the paper's StarPU-measured
//! processing times (DESIGN.md §5 Substitutions).
//!
//! The paper recorded, for every task of every Chameleon application, its
//! running time on each resource type of two real testbeds.  We model the
//! same quantity from first principles:
//!
//!   time_cpu(kernel, b)  = flops(kernel, b) / cpu_rate        * jitter
//!   time_gpu(kernel, b)  = time_cpu / accel(kernel, b)        * jitter
//!   accel(kernel, b)     = peak_accel(kernel) * sat(b) ,
//!   sat(b)               = 1 / (1 + b_half / b)
//!
//! which reproduces the structure the algorithms actually react to:
//! GEMM-like kernels accelerate enormously on GPUs at large tiles, small
//! factorization kernels (POTRF/GETRF/TRTRI) accelerate little — and are
//! *slower* on the GPU at small tile sizes (acceleration < 1), exactly
//! the heterogeneity regime the paper's allocation phase targets.
//! A second GPU type (Section 5's Q=3 experiments) is a scaled variant
//! with its own saturation point, mirroring the paper's GTX-970 vs K5200.

use crate::substrate::rng::Rng;

/// Effective scalar rate of one CPU core (time units are arbitrary but
/// consistent; only ratios matter to every algorithm in the paper).
const CPU_RATE: f64 = 1.0e9;

/// Deterministic multiplicative log-normal jitter (sigma of log).
const JITTER_SIGMA: f64 = 0.08;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kernel {
    Gemm,
    Syrk,
    Trsm,
    Trmm,
    Potrf,
    Getrf,
    Trtri,
    Lauum,
    /// Triangular solve applied to a RHS tile (potrs sweeps).
    SolveTile,
    /// Fork-join phase tasks (times drawn per the paper's recipe instead).
    Generic,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Gemm => "GEMM",
            Kernel::Syrk => "SYRK",
            Kernel::Trsm => "TRSM",
            Kernel::Trmm => "TRMM",
            Kernel::Potrf => "POTRF",
            Kernel::Getrf => "GETRF",
            Kernel::Trtri => "TRTRI",
            Kernel::Lauum => "LAUUM",
            Kernel::SolveTile => "SOLVE",
            Kernel::Generic => "TASK",
        }
    }

    /// Dense-tile flop count at tile size b.
    pub fn flops(&self, b: f64) -> f64 {
        let b3 = b * b * b;
        match self {
            Kernel::Gemm => 2.0 * b3,
            Kernel::Syrk => b3,
            Kernel::Trsm => b3,
            Kernel::Trmm => b3,
            Kernel::Potrf => b3 / 3.0,
            Kernel::Getrf => 2.0 * b3 / 3.0,
            Kernel::Trtri => b3 / 3.0,
            Kernel::Lauum => b3 / 3.0,
            Kernel::SolveTile => b3,
            Kernel::Generic => b3,
        }
    }

    /// Peak GPU acceleration at large tiles.  Calibrated to the regime
    /// of the paper's testbed (K20-class GPU vs Xeon cores running
    /// multithreaded BLAS): GEMM-like kernels gain an order of
    /// magnitude, small factorization kernels only a few x — so the
    /// *allocation* decision genuinely matters (with much larger
    /// factors, "everything on the GPU" is trivially optimal and the
    /// paper's comparisons degenerate; see DESIGN.md §5).
    pub fn peak_accel(&self) -> f64 {
        match self {
            Kernel::Gemm => 15.0,
            Kernel::Syrk => 10.0,
            Kernel::Trsm => 9.0,
            Kernel::Trmm => 9.0,
            Kernel::Potrf => 3.0,
            Kernel::Getrf => 3.5,
            Kernel::Trtri => 2.5,
            Kernel::Lauum => 2.5,
            Kernel::SolveTile => 6.0,
            Kernel::Generic => 8.0,
        }
    }
}

/// One resource type's characteristics.
#[derive(Clone, Debug)]
pub struct GpuModel {
    /// Multiplier on every kernel's peak acceleration (1.0 = reference GPU).
    pub accel_scale: f64,
    /// Tile size at which acceleration reaches half its peak.
    pub b_half: f64,
}

/// The cost model: CPU + a list of GPU types (1 for hybrid, 2 for Q=3).
#[derive(Clone, Debug)]
pub struct CostModel {
    pub gpus: Vec<GpuModel>,
    pub block_size: usize,
    pub jitter: bool,
}

impl CostModel {
    /// Hybrid testbed (paper's 2-type machine: Tesla K20-class GPU).
    pub fn hybrid(block_size: usize) -> CostModel {
        CostModel {
            gpus: vec![GpuModel {
                accel_scale: 1.0,
                b_half: 192.0,
            }],
            block_size,
            jitter: true,
        }
    }

    /// 3-type testbed (paper's GTX-970 + K5200: one faster-saturating,
    /// one higher-peak GPU).
    pub fn three_type(block_size: usize) -> CostModel {
        CostModel {
            gpus: vec![
                GpuModel {
                    accel_scale: 1.15,
                    b_half: 160.0,
                },
                GpuModel {
                    accel_scale: 0.85,
                    b_half: 256.0,
                },
            ],
            block_size,
            jitter: true,
        }
    }

    pub fn n_types(&self) -> usize {
        1 + self.gpus.len()
    }

    /// Times on every type for one kernel instance; `rng` drives the
    /// deterministic measurement jitter.
    pub fn times(&self, kernel: Kernel, rng: &mut Rng) -> Vec<f64> {
        let b = self.block_size as f64;
        let cpu_jit = if self.jitter { rng.jitter(JITTER_SIGMA) } else { 1.0 };
        let cpu = kernel.flops(b) / CPU_RATE * cpu_jit;
        let mut out = Vec::with_capacity(self.n_types());
        out.push(cpu);
        for gpu in &self.gpus {
            let sat = 1.0 / (1.0 + gpu.b_half / b);
            let accel = (kernel.peak_accel() * gpu.accel_scale * sat).max(1e-3);
            let gpu_jit = if self.jitter { rng.jitter(JITTER_SIGMA) } else { 1.0 };
            out.push(cpu / cpu_jit / accel * gpu_jit);
        }
        out
    }
}

/// The paper's block-size grid (§6.1).
pub const PAPER_BLOCK_SIZES: [usize; 6] = [64, 128, 320, 512, 768, 960];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_dominates_flops() {
        assert!(Kernel::Gemm.flops(128.0) > Kernel::Potrf.flops(128.0));
        assert_eq!(Kernel::Potrf.flops(3.0), 9.0);
    }

    #[test]
    fn small_tiles_decelerate_factorizations() {
        let cm = CostModel {
            jitter: false,
            ..CostModel::hybrid(64)
        };
        let mut rng = Rng::new(1);
        let t = cm.times(Kernel::Potrf, &mut rng);
        // at b=64 << b_half=192: sat ~ 0.1 -> POTRF accel ~ 0.6 < 1
        assert!(t[1] > t[0], "POTRF should be slower on GPU at b=64: {t:?}");
        let t = cm.times(Kernel::Gemm, &mut rng);
        assert!(t[1] < t[0], "GEMM still accelerates at b=64: {t:?}");
    }

    #[test]
    fn large_tiles_accelerate_everything() {
        let cm = CostModel {
            jitter: false,
            ..CostModel::hybrid(960)
        };
        let mut rng = Rng::new(1);
        for k in [Kernel::Gemm, Kernel::Potrf, Kernel::Trsm, Kernel::Syrk] {
            let t = cm.times(k, &mut rng);
            assert!(t[1] < t[0], "{k:?} should accelerate at b=960: {t:?}");
        }
        // GEMM acceleration approaches its peak
        let t = cm.times(Kernel::Gemm, &mut rng);
        let accel = t[0] / t[1];
        assert!(accel > 12.0 && accel < 16.0, "accel {accel}");
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let cm = CostModel::hybrid(320);
        let a = cm.times(Kernel::Gemm, &mut Rng::new(7));
        let b = cm.times(Kernel::Gemm, &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn three_type_model_has_three_times() {
        let cm = CostModel::three_type(320);
        let t = cm.times(Kernel::Gemm, &mut Rng::new(1));
        assert_eq!(t.len(), 3);
        assert!(t.iter().all(|&x| x > 0.0));
        // the two GPU types differ
        assert!((t[1] - t[2]).abs() > 1e-12);
    }
}
