//! The fork-join application of §6.1 (generated with GGen in the paper),
//! rebuilt with the paper's exact distributional recipe:
//!
//! * execution starts with one sequential task, then forks to `width`
//!   parallel tasks, joined by one task per phase; `p` phases total
//!   (task count = p·width + p + 1, Table 5);
//! * CPU time of each task ~ Gaussian(center = p, std = p/4);
//! * in each phase, 5% of the parallel tasks (randomly chosen) get a GPU
//!   acceleration factor uniform in [0.1, 0.5] (i.e. *slower* on GPU),
//!   the rest uniform in [0.5, 50];
//! * for 3-type platforms the second GPU's factors are drawn by the same
//!   process (independently), as in the paper.

use crate::graph::{Builder, TaskGraph};
use crate::substrate::rng::Rng;

/// Build a fork-join instance. `n_gpu_types` is 1 (hybrid) or more (§5).
pub fn forkjoin(width: usize, phases: usize, n_gpu_types: usize, seed: u64) -> TaskGraph {
    assert!(width > 0 && phases > 0 && n_gpu_types >= 1);
    let mut rng = Rng::new(seed);
    let mut b = Builder::new("fork-join");
    let center = phases as f64;
    let std = center / 4.0;

    let draw_times = |rng: &mut Rng, slow_on_gpu: bool| -> Vec<f64> {
        let cpu = rng.gaussian_pos(center, std, center / 100.0);
        let mut times = vec![cpu];
        for _ in 0..n_gpu_types {
            let accel = if slow_on_gpu {
                rng.uniform(0.1, 0.5)
            } else {
                rng.uniform(0.5, 50.0)
            };
            times.push(cpu / accel);
        }
        times
    };

    let root = b.add_task("SEQ", draw_times(&mut rng, false));
    let mut prev_join = root;
    for ph in 0..phases {
        // choose which of the `width` parallel tasks are the 5% slow-on-GPU
        let n_slow = ((width as f64) * 0.05).round() as usize;
        let mut idx: Vec<usize> = (0..width).collect();
        rng.shuffle(&mut idx);
        let slow: std::collections::HashSet<usize> =
            idx.into_iter().take(n_slow).collect();

        let mut members = Vec::with_capacity(width);
        for w in 0..width {
            let t = b.add_task(
                &format!("FORK{ph}"),
                draw_times(&mut rng, slow.contains(&w)),
            );
            b.add_arc(prev_join, t);
            members.push(t);
        }
        let join = b.add_task(&format!("JOIN{ph}"), draw_times(&mut rng, false));
        for t in members {
            b.add_arc(t, join);
        }
        prev_join = join;
    }
    b.build()
}

/// Closed-form Table 5 task count.
pub fn table5_count(width: usize, phases: usize) -> usize {
    phases * width + phases + 1
}

pub const PAPER_WIDTHS: [usize; 5] = [100, 200, 300, 400, 500];
pub const PAPER_PHASES: [usize; 3] = [2, 5, 10];

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 5 of the paper, verbatim.
    #[test]
    fn table5_task_counts_exact() {
        let expected: &[(usize, [usize; 5])] = &[
            (2, [203, 403, 603, 803, 1003]),
            (5, [506, 1006, 1506, 2006, 2506]),
            (10, [1011, 2011, 3011, 4011, 5011]),
        ];
        for &(p, row) in expected {
            for (i, &w) in PAPER_WIDTHS.iter().enumerate() {
                let g = forkjoin(w, p, 1, 42);
                assert_eq!(g.n_tasks(), row[i], "width={w} p={p}");
                assert_eq!(table5_count(w, p), row[i]);
            }
        }
    }

    #[test]
    fn structure_is_fork_join() {
        let g = forkjoin(10, 3, 1, 7);
        g.validate().unwrap();
        assert_eq!(g.sources().len(), 1);
        assert_eq!(g.sinks().len(), 1);
        // root forks to width
        let root = g.sources()[0];
        assert_eq!(g.succs[root].len(), 10);
        // joins have width preds
        let sink = g.sinks()[0];
        assert_eq!(g.preds[sink].len(), 10);
    }

    #[test]
    fn five_percent_slow_on_gpu() {
        let g = forkjoin(500, 2, 1, 3);
        let slow = (0..g.n_tasks())
            .filter(|&j| g.names[j].starts_with("FORK"))
            .filter(|&j| g.p_gpu(j) > g.p_cpu(j) * 1.9) // accel < ~0.53
            .count();
        // 5% of 1000 fork tasks = ~50 (accept the [0.5,50] draws near 0.5)
        assert!((40..=80).contains(&slow), "slow count {slow}");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = forkjoin(50, 2, 1, 9);
        let b = forkjoin(50, 2, 1, 9);
        assert_eq!(a.proc_times, b.proc_times);
        let c = forkjoin(50, 2, 1, 10);
        assert_ne!(a.proc_times, c.proc_times);
    }

    #[test]
    fn gaussian_cpu_times_center() {
        let g = forkjoin(500, 10, 1, 5);
        let cpu: Vec<f64> = (0..g.n_tasks()).map(|j| g.p_cpu(j)).collect();
        let mean = cpu.iter().sum::<f64>() / cpu.len() as f64;
        assert!((mean - 10.0).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn multi_gpu_types() {
        let g = forkjoin(20, 2, 2, 1);
        assert_eq!(g.n_types(), 3);
        g.validate().unwrap();
    }
}
