//! Chameleon-style tiled dense linear-algebra DAGs: `potrf`, `getrf`,
//! `posv`, `potri`, `potrs` (§6.1, Table 4).
//!
//! The paper generated these applications with the Chameleon/MORSE
//! library and recorded StarPU's task graph.  The DAG of a tiled
//! algorithm is fully determined by the algorithm itself, so we rebuild
//! it here: each tiled kernel declares its tile accesses (reads + one
//! read-modify-write) and a sequential-consistency engine derives the
//! arcs exactly like a task-based runtime (StarPU) does:
//!   * read  t: arc  last_writer(t) -> task
//!   * write t: arcs last_writer(t) -> task and readers-since -> task
//!
//! Task counts per application equal Table 4 for every `nb_blocks`
//! (asserted in tests):
//!   potrf: N + N(N-1) + N(N-1)(N-2)/6            (35/220/1540)
//!   potrs: 2(N + N(N-1)/2)                       (30/110/420)
//!   posv : potrf + potrs                         (65/330/1960)
//!   getrf: N + N(N-1) + N(N-1)(2N-1)/6           (55/385/2870)
//!   potri: potrf + trtri + lauum = 3x potrf count (105/660/4620)

use std::collections::HashMap;

use crate::graph::{Builder, TaskGraph, TaskId};
use crate::substrate::rng::Rng;

use super::costs::{CostModel, Kernel};

/// Tile coordinate namespace: (matrix, row, col). Matrix 0 = A, 1 = X
/// (RHS tiles of the solve sweeps).
type Tile = (u8, usize, usize);

/// Sequential-consistency dependency tracker over tiles.
struct Access {
    last_writer: HashMap<Tile, TaskId>,
    readers: HashMap<Tile, Vec<TaskId>>,
}

impl Access {
    fn new() -> Access {
        Access {
            last_writer: HashMap::new(),
            readers: HashMap::new(),
        }
    }

    /// Register a task reading `reads` and read-modify-writing `write`.
    fn task(&mut self, b: &mut Builder, id: TaskId, reads: &[Tile], write: Tile) {
        for t in reads {
            if let Some(&w) = self.last_writer.get(t) {
                if w != id {
                    b.add_arc(w, id);
                }
            }
            self.readers.entry(*t).or_default().push(id);
        }
        if let Some(&w) = self.last_writer.get(&write) {
            if w != id {
                b.add_arc(w, id);
            }
        }
        if let Some(rs) = self.readers.remove(&write) {
            for r in rs {
                if r != id {
                    b.add_arc(r, id);
                }
            }
        }
        self.last_writer.insert(write, id);
    }
}

struct Gen<'a> {
    b: Builder,
    acc: Access,
    cm: &'a CostModel,
    rng: Rng,
}

impl<'a> Gen<'a> {
    fn new(app: &str, cm: &'a CostModel, seed: u64) -> Gen<'a> {
        Gen {
            b: Builder::new(app),
            acc: Access::new(),
            cm,
            rng: Rng::new(seed),
        }
    }

    fn kernel(&mut self, k: Kernel, reads: &[Tile], write: Tile) -> TaskId {
        let times = self.cm.times(k, &mut self.rng);
        let id = self.b.add_task(k.name(), times);
        self.acc.task(&mut self.b, id, reads, write);
        id
    }

    fn finish(self) -> TaskGraph {
        self.b.build()
    }
}

const A: u8 = 0;
const X: u8 = 1;

/// Tiled Cholesky factorization (lower), N = nb_blocks.
fn emit_potrf(g: &mut Gen, n: usize) {
    for k in 0..n {
        g.kernel(Kernel::Potrf, &[], (A, k, k));
        for i in (k + 1)..n {
            g.kernel(Kernel::Trsm, &[(A, k, k)], (A, i, k));
        }
        for i in (k + 1)..n {
            g.kernel(Kernel::Syrk, &[(A, i, k)], (A, i, i));
            for j in (k + 1)..i {
                g.kernel(Kernel::Gemm, &[(A, i, k), (A, j, k)], (A, i, j));
            }
        }
    }
}

/// Two triangular sweeps (forward with L, backward with L^T) over one
/// block-column of RHS tiles.
fn emit_potrs(g: &mut Gen, n: usize) {
    // forward substitution
    for k in 0..n {
        g.kernel(Kernel::SolveTile, &[(A, k, k)], (X, k, 0));
        for i in (k + 1)..n {
            g.kernel(Kernel::Gemm, &[(A, i, k), (X, k, 0)], (X, i, 0));
        }
    }
    // backward substitution
    for k in (0..n).rev() {
        g.kernel(Kernel::SolveTile, &[(A, k, k)], (X, k, 0));
        for i in 0..k {
            g.kernel(Kernel::Gemm, &[(A, k, i), (X, k, 0)], (X, i, 0));
        }
    }
}

/// Tiled LU factorization without pivoting.
fn emit_getrf(g: &mut Gen, n: usize) {
    for k in 0..n {
        g.kernel(Kernel::Getrf, &[], (A, k, k));
        for j in (k + 1)..n {
            g.kernel(Kernel::Trsm, &[(A, k, k)], (A, k, j));
        }
        for i in (k + 1)..n {
            g.kernel(Kernel::Trsm, &[(A, k, k)], (A, i, k));
        }
        for i in (k + 1)..n {
            for j in (k + 1)..n {
                g.kernel(Kernel::Gemm, &[(A, i, k), (A, k, j)], (A, i, j));
            }
        }
    }
}

/// Tiled in-place inversion of the triangular factor (Chameleon-like
/// variant; counts match Table 4: N TRTRI + N(N-1) TRSM + C(N,3) GEMM).
fn emit_trtri(g: &mut Gen, n: usize) {
    for k in 0..n {
        for i in (k + 1)..n {
            g.kernel(Kernel::Trsm, &[(A, k, k)], (A, i, k));
        }
        g.kernel(Kernel::Trtri, &[], (A, k, k));
        for i in (k + 1)..n {
            for j in 0..k {
                g.kernel(Kernel::Gemm, &[(A, i, k), (A, k, j)], (A, i, j));
            }
            g.kernel(Kernel::Trsm, &[(A, i, i)], (A, i, k));
        }
    }
}

/// Tiled L^T L product (lower, in place); counts mirror potrf's.
fn emit_lauum(g: &mut Gen, n: usize) {
    for k in 0..n {
        g.kernel(Kernel::Lauum, &[], (A, k, k));
        for i in (k + 1)..n {
            g.kernel(Kernel::Syrk, &[(A, i, k)], (A, k, k));
            for j in 0..k {
                g.kernel(Kernel::Gemm, &[(A, i, k), (A, i, j)], (A, k, j));
            }
            g.kernel(Kernel::Trmm, &[(A, i, i)], (A, i, k));
        }
    }
}

/// Public generators.  `seed` drives only the cost-model jitter; the DAG
/// shape is deterministic in `nb_blocks`.
pub fn potrf(nb_blocks: usize, cm: &CostModel, seed: u64) -> TaskGraph {
    let mut g = Gen::new("potrf", cm, seed);
    emit_potrf(&mut g, nb_blocks);
    g.finish()
}

pub fn potrs(nb_blocks: usize, cm: &CostModel, seed: u64) -> TaskGraph {
    let mut g = Gen::new("potrs", cm, seed);
    // factor tiles pre-exist (no potrf tasks in the potrs app)
    emit_potrs(&mut g, nb_blocks);
    g.finish()
}

pub fn posv(nb_blocks: usize, cm: &CostModel, seed: u64) -> TaskGraph {
    let mut g = Gen::new("posv", cm, seed);
    emit_potrf(&mut g, nb_blocks);
    emit_potrs(&mut g, nb_blocks);
    g.finish()
}

pub fn getrf(nb_blocks: usize, cm: &CostModel, seed: u64) -> TaskGraph {
    let mut g = Gen::new("getrf", cm, seed);
    emit_getrf(&mut g, nb_blocks);
    g.finish()
}

pub fn potri(nb_blocks: usize, cm: &CostModel, seed: u64) -> TaskGraph {
    let mut g = Gen::new("potri", cm, seed);
    emit_potrf(&mut g, nb_blocks);
    emit_trtri(&mut g, nb_blocks);
    emit_lauum(&mut g, nb_blocks);
    g.finish()
}

/// Generate by application name.
pub fn by_name(app: &str, nb_blocks: usize, cm: &CostModel, seed: u64) -> Option<TaskGraph> {
    Some(match app {
        "potrf" => potrf(nb_blocks, cm, seed),
        "potrs" => potrs(nb_blocks, cm, seed),
        "posv" => posv(nb_blocks, cm, seed),
        "getrf" => getrf(nb_blocks, cm, seed),
        "potri" => potri(nb_blocks, cm, seed),
        _ => return None,
    })
}

pub const APPS: [&str; 5] = ["getrf", "posv", "potrf", "potri", "potrs"];

/// Closed-form Table 4 task counts.
pub fn table4_count(app: &str, n: usize) -> Option<usize> {
    let potrf_c = n + n * (n - 1) + n * (n - 1) * (n - 2) / 6;
    let potrs_c = 2 * (n + n * (n - 1) / 2);
    Some(match app {
        "potrf" => potrf_c,
        "potrs" => potrs_c,
        "posv" => potrf_c + potrs_c,
        "getrf" => n + n * (n - 1) + n * (n - 1) * (2 * n - 1) / 6,
        "potri" => 3 * potrf_c,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::hybrid(320)
    }

    /// Table 4 of the paper, verbatim.
    #[test]
    fn table4_task_counts_exact() {
        let expected: &[(&str, [usize; 3])] = &[
            ("getrf", [55, 385, 2870]),
            ("posv", [65, 330, 1960]),
            ("potrf", [35, 220, 1540]),
            ("potri", [105, 660, 4620]),
            ("potrs", [30, 110, 420]),
        ];
        for &(app, counts) in expected {
            for (i, &nb) in [5usize, 10, 20].iter().enumerate() {
                let g = by_name(app, nb, &cm(), 1).unwrap();
                assert_eq!(
                    g.n_tasks(),
                    counts[i],
                    "{app} nb_blocks={nb}: got {} want {}",
                    g.n_tasks(),
                    counts[i]
                );
                assert_eq!(table4_count(app, nb), Some(counts[i]));
            }
        }
    }

    #[test]
    fn all_apps_are_valid_dags() {
        for app in APPS {
            let g = by_name(app, 6, &cm(), 3).unwrap();
            g.validate().unwrap();
            assert!(g.n_arcs() > 0);
        }
    }

    #[test]
    fn potrf_dependency_structure() {
        // nb=2: POTRF(0) -> TRSM(1,0) -> SYRK(0,1) -> POTRF(1)
        let mut model = cm();
        model.jitter = false;
        let g = potrf(2, &model, 1);
        assert_eq!(g.n_tasks(), 4);
        assert_eq!(g.names, vec!["POTRF", "TRSM", "SYRK", "POTRF"]);
        assert_eq!(g.succs[0], vec![1]);
        assert_eq!(g.succs[1], vec![2]);
        assert_eq!(g.succs[2], vec![3]);
    }

    #[test]
    fn potrf_has_single_source_and_gemm_majority_at_scale() {
        let g = potrf(20, &cm(), 1);
        assert_eq!(g.sources().len(), 1); // POTRF(0)
        let h = g.kernel_histogram();
        assert_eq!(h["GEMM"], 1140);
        assert_eq!(h["POTRF"], 20);
        assert_eq!(h["TRSM"], 190);
        assert_eq!(h["SYRK"], 190);
    }

    #[test]
    fn potrs_is_two_serial_sweeps() {
        let mut model = cm();
        model.jitter = false;
        let g = potrs(3, &model, 1);
        // forward SOLVE(0) is a source; total = 2(3+3) = 12
        assert_eq!(g.n_tasks(), 12);
        g.validate().unwrap();
        // backward sweep depends on forward sweep (same X tiles)
        let order = g.topo_order().unwrap();
        assert_eq!(order.len(), 12);
    }

    #[test]
    fn dag_shape_independent_of_seed_and_blocksize() {
        let g1 = potrf(8, &CostModel::hybrid(64), 1);
        let g2 = potrf(8, &CostModel::hybrid(960), 99);
        assert_eq!(g1.succs, g2.succs);
        assert_eq!(g1.names, g2.names);
        assert_ne!(g1.proc_times, g2.proc_times);
    }

    #[test]
    fn three_type_times() {
        let cm3 = CostModel::three_type(320);
        let g = posv(5, &cm3, 2);
        assert_eq!(g.n_types(), 3);
        g.validate().unwrap();
    }
}
