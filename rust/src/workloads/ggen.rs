//! GGen-style random DAG families (Cordeiro et al., SIMUTools 2010 —
//! the generator the paper used for its fork-join application).  Beyond
//! the paper's fork-join (workloads::forkjoin), these families are used
//! by the robustness/ablation experiments:
//!
//! * `erdos_renyi`    — G(n, p) DAG: arc (i, j), i < j, with prob. p
//! * `layer_by_layer` — the classic GGen recipe: tasks split into
//!   layers, arcs only from earlier layers
//! * `out_tree` / `in_tree` — divide-and-conquer shapes
//! * `series_parallel` — recursive series/parallel composition
//!
//! Processing times follow the paper's fork-join recipe: CPU time
//! Gaussian, GPU acceleration in [0.5, 50] except a 5% slow-on-GPU
//! fraction in [0.1, 0.5].

use crate::graph::{Builder, TaskGraph};
use crate::substrate::rng::Rng;

fn draw_times(rng: &mut Rng, n_gpu_types: usize, mean: f64) -> Vec<f64> {
    let cpu = rng.gaussian_pos(mean, mean / 4.0, mean / 100.0);
    let mut t = vec![cpu];
    for _ in 0..n_gpu_types {
        let accel = if rng.chance(0.05) {
            rng.uniform(0.1, 0.5)
        } else {
            rng.uniform(0.5, 50.0)
        };
        t.push(cpu / accel);
    }
    t
}

/// G(n, p) DAG over a fixed topological order.
pub fn erdos_renyi(n: usize, p: f64, n_gpu_types: usize, seed: u64) -> TaskGraph {
    let mut rng = Rng::new(seed);
    let mut b = Builder::new("ggen-erdos");
    for i in 0..n {
        let t = draw_times(&mut rng, n_gpu_types, 10.0);
        b.add_task(&format!("t{i}"), t);
    }
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.chance(p) {
                b.add_arc(i, j);
            }
        }
    }
    b.build()
}

/// Layer-by-layer: `layers` layers of `width` tasks; each task draws
/// its predecessors from the previous layer with probability `p`
/// (at least one, so layers are real synchronization ranks).
pub fn layer_by_layer(
    layers: usize,
    width: usize,
    p: f64,
    n_gpu_types: usize,
    seed: u64,
) -> TaskGraph {
    let mut rng = Rng::new(seed);
    let mut b = Builder::new("ggen-layers");
    let mut prev: Vec<usize> = Vec::new();
    for l in 0..layers {
        let cur: Vec<usize> = (0..width)
            .map(|i| {
                let t = draw_times(&mut rng, n_gpu_types, 10.0);
                b.add_task(&format!("l{l}t{i}"), t)
            })
            .collect();
        if l > 0 {
            for &j in &cur {
                let mut any = false;
                for &i in &prev {
                    if rng.chance(p) {
                        b.add_arc(i, j);
                        any = true;
                    }
                }
                if !any {
                    b.add_arc(prev[rng.below(prev.len())], j);
                }
            }
        }
        prev = cur;
    }
    b.build()
}

/// Campaign-scale layered DAG: a `layer_by_layer` instance sized to at
/// least `n_tasks` tasks (the exact count is the smallest
/// layers × width grid covering it) — the `Scale::Full` workload family
/// behind the 10k/50k/100k-task campaign rows.
///
/// Width saturates at [`BIG_LAYER_WIDTH_MAX`] so very large instances
/// grow in depth (layers) rather than unbounded parallelism, matching
/// how long-running DAG workloads scale in practice; the predecessor
/// probability is normalized to ~4 arcs per task so the arc count stays
/// O(n) and a 100k-task instance streams through generation, LP build
/// and scheduling without quadratic blowup.
pub fn big_layered(n_tasks: usize, n_gpu_types: usize, seed: u64) -> TaskGraph {
    let n = n_tasks.max(4);
    let width = (n / 64).clamp(8, BIG_LAYER_WIDTH_MAX);
    let layers = (n + width - 1) / width;
    let p = (4.0 / width as f64).min(1.0);
    layer_by_layer(layers, width, p, n_gpu_types, seed)
}

/// Widest layer `big_layered` generates.
pub const BIG_LAYER_WIDTH_MAX: usize = 512;

/// Out-tree (fork-only divide): root spawns `fanout` children per node
/// down to `depth` levels.
pub fn out_tree(depth: usize, fanout: usize, n_gpu_types: usize, seed: u64) -> TaskGraph {
    let mut rng = Rng::new(seed);
    let mut b = Builder::new("ggen-outtree");
    let t = draw_times(&mut rng, n_gpu_types, 10.0);
    let root = b.add_task("n0", t);
    let mut frontier = vec![root];
    for _ in 1..depth {
        let mut next = Vec::new();
        for &p in &frontier {
            for _ in 0..fanout {
                let t = draw_times(&mut rng, n_gpu_types, 10.0);
                let name = format!("n{}", b.n_tasks());
                let c = b.add_task(&name, t);
                b.add_arc(p, c);
                next.push(c);
            }
        }
        frontier = next;
    }
    b.build()
}

/// In-tree: mirror of `out_tree` (reduction shape).
pub fn in_tree(depth: usize, fanout: usize, n_gpu_types: usize, seed: u64) -> TaskGraph {
    let out = out_tree(depth, fanout, n_gpu_types, seed);
    // reverse every arc
    let mut b = Builder::new("ggen-intree");
    for j in 0..out.n_tasks() {
        b.add_task(&out.names[j], out.proc_times[j].clone());
    }
    for j in 0..out.n_tasks() {
        for &s in &out.succs[j] {
            b.add_arc(s, j);
        }
    }
    b.build()
}

/// Series-parallel DAG by recursive composition; `size_budget` bounds
/// the task count.
pub fn series_parallel(size_budget: usize, n_gpu_types: usize, seed: u64) -> TaskGraph {
    let mut rng = Rng::new(seed);
    let mut b = Builder::new("ggen-sp");
    let budget = size_budget.max(2);
    // returns (entry, exit)
    fn build(
        b: &mut Builder,
        rng: &mut Rng,
        budget: usize,
        n_gpu_types: usize,
    ) -> (usize, usize) {
        if budget <= 1 {
            let t = draw_times(rng, n_gpu_types, 10.0);
            let name = format!("sp{}", b.n_tasks());
            let v = b.add_task(&name, t);
            return (v, v);
        }
        if rng.chance(0.5) {
            // series
            let (e1, x1) = build(b, rng, budget / 2, n_gpu_types);
            let (e2, x2) = build(b, rng, budget - budget / 2, n_gpu_types);
            b.add_arc(x1, e2);
            (e1, x2)
        } else {
            // parallel between fresh entry/exit
            let te = draw_times(rng, n_gpu_types, 10.0);
            let entry_name = format!("sp{}", b.n_tasks());
            let entry = b.add_task(&entry_name, te);
            let branches = 2 + rng.below(3);
            let inner = (budget.saturating_sub(2)) / branches.max(1);
            let mut exits = Vec::new();
            for _ in 0..branches {
                let (e, x) = build(b, rng, inner.max(1), n_gpu_types);
                b.add_arc(entry, e);
                exits.push(x);
            }
            let tx = draw_times(rng, n_gpu_types, 10.0);
            let exit_name = format!("sp{}", b.n_tasks());
            let exit = b.add_task(&exit_name, tx);
            for x in exits {
                b.add_arc(x, exit);
            }
            (entry, exit)
        }
    }
    let _ = build(&mut b, &mut rng, budget, n_gpu_types);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erdos_renyi_valid_and_sized() {
        let g = erdos_renyi(80, 0.08, 1, 3);
        assert_eq!(g.n_tasks(), 80);
        g.validate().unwrap();
        assert!(g.n_arcs() > 0);
    }

    #[test]
    fn layer_by_layer_every_layer_connected() {
        let g = layer_by_layer(6, 8, 0.3, 1, 5);
        assert_eq!(g.n_tasks(), 48);
        g.validate().unwrap();
        // sources only in the first layer
        for s in g.sources() {
            assert!(g.names[s].starts_with("l0"));
        }
    }

    #[test]
    fn out_tree_counts() {
        let g = out_tree(4, 2, 1, 7);
        assert_eq!(g.n_tasks(), 1 + 2 + 4 + 8);
        g.validate().unwrap();
        assert_eq!(g.sources().len(), 1);
        assert_eq!(g.sinks().len(), 8);
    }

    #[test]
    fn in_tree_is_reversed_out_tree() {
        let g = in_tree(4, 2, 1, 7);
        assert_eq!(g.n_tasks(), 15);
        g.validate().unwrap();
        assert_eq!(g.sources().len(), 8);
        assert_eq!(g.sinks().len(), 1);
    }

    #[test]
    fn series_parallel_single_entry_exit_shape() {
        let g = series_parallel(60, 1, 11);
        g.validate().unwrap();
        assert!(g.n_tasks() >= 10);
        // SP graphs stay connected: exactly one component reachable from
        // sources covers everything (weak check: every non-source has preds)
        for j in 0..g.n_tasks() {
            assert!(g.preds[j].len() + g.succs[j].len() > 0 || g.n_tasks() == 1);
        }
    }

    #[test]
    fn big_layered_sizes_and_streams() {
        let g = big_layered(1000, 1, 7);
        assert!(g.n_tasks() >= 1000, "{} tasks", g.n_tasks());
        // width clamp keeps the grid near-minimal: no more than one
        // extra layer of slack
        assert!(g.n_tasks() < 1000 + 512, "{} tasks", g.n_tasks());
        g.validate().unwrap();
        // O(n) arcs: ~4 preds per task plus the at-least-one fallback
        assert!(g.n_arcs() < 8 * g.n_tasks(), "{} arcs", g.n_arcs());
        // deterministic
        let h = big_layered(1000, 1, 7);
        assert_eq!(g.proc_times, h.proc_times);
        assert_eq!(g.n_arcs(), h.n_arcs());
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = erdos_renyi(30, 0.1, 1, 1);
        let b = erdos_renyi(30, 0.1, 1, 1);
        let c = erdos_renyi(30, 0.1, 1, 2);
        assert_eq!(a.proc_times, b.proc_times);
        assert_ne!(a.proc_times, c.proc_times);
    }

    #[test]
    fn schedulable_by_full_pipeline() {
        use crate::platform::Platform;
        use crate::sched::heft::heft_schedule;
        use crate::sim::validate;
        for g in [
            erdos_renyi(40, 0.1, 1, 9),
            layer_by_layer(4, 6, 0.4, 1, 9),
            out_tree(4, 3, 1, 9),
            in_tree(3, 3, 1, 9),
            series_parallel(40, 1, 9),
        ] {
            let plat = Platform::hybrid(4, 2);
            let s = heft_schedule(&g, &plat);
            validate(&g, &plat, &s).unwrap();
        }
    }
}
