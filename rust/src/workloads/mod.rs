//! The paper's benchmark (§6.1): Chameleon dense linear-algebra DAGs and
//! the GGen fork-join application, plus the cost model standing in for
//! the StarPU time measurements.

pub mod chameleon;
pub mod costs;
pub mod forkjoin;
pub mod ggen;

use crate::graph::TaskGraph;
use crate::substrate::rng::seed_for;

use costs::CostModel;

/// One benchmark instance descriptor (application + parameters).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Instance {
    Chameleon { app: String, nb_blocks: usize, block_size: usize },
    ForkJoin { width: usize, phases: usize },
    /// Campaign-scale layered GGen DAG ([`ggen::big_layered`]) — the
    /// 10k/50k/100k-task `Scale::Full` rows beyond the paper's grid.
    Ggen { n_tasks: usize },
}

impl Instance {
    pub fn label(&self) -> String {
        match self {
            Instance::Chameleon { app, nb_blocks, block_size } => {
                format!("{app}-nb{nb_blocks}-bs{block_size}")
            }
            Instance::ForkJoin { width, phases } => format!("forkjoin-w{width}-p{phases}"),
            Instance::Ggen { n_tasks } => format!("ggen-layers-n{n_tasks}"),
        }
    }

    pub fn app(&self) -> &str {
        match self {
            Instance::Chameleon { app, .. } => app,
            Instance::ForkJoin { .. } => "fork-join",
            Instance::Ggen { .. } => "ggen-layers",
        }
    }

    /// Application key + numeric parameter vector for cross-instance
    /// warm-start chaining ([`crate::lp::warm::grid_distance`] over the
    /// parameters decides whether two same-app instances are "close").
    /// Chaining additionally requires identical LP dimensions — e.g.
    /// two Chameleon instances share a DAG (hence an LP layout) exactly
    /// when `app` and `nb_blocks` match and only `block_size` differs —
    /// which the batch-grid builder verifies structurally; this method
    /// only scores proximity.
    pub fn warm_params(&self) -> (&str, Vec<usize>) {
        match self {
            Instance::Chameleon { app, nb_blocks, block_size } => {
                (app.as_str(), vec![*nb_blocks, *block_size])
            }
            Instance::ForkJoin { width, phases } => ("fork-join", vec![*width, *phases]),
            Instance::Ggen { n_tasks } => ("ggen-layers", vec![*n_tasks]),
        }
    }

    /// Materialize the task graph with `n_types` resource types (2 or 3).
    pub fn generate(&self, n_types: usize) -> TaskGraph {
        assert!(n_types == 2 || n_types == 3);
        let seed = seed_for(&[&self.label(), &n_types.to_string()]);
        match self {
            Instance::Chameleon { app, nb_blocks, block_size } => {
                let cm = if n_types == 2 {
                    CostModel::hybrid(*block_size)
                } else {
                    CostModel::three_type(*block_size)
                };
                chameleon::by_name(app, *nb_blocks, &cm, seed)
                    .unwrap_or_else(|| panic!("unknown app {app}"))
            }
            Instance::ForkJoin { width, phases } => {
                forkjoin::forkjoin(*width, *phases, n_types - 1, seed)
            }
            Instance::Ggen { n_tasks } => ggen::big_layered(*n_tasks, n_types - 1, seed),
        }
    }
}

/// Campaign scale (DESIGN.md §4): `Smoke` for tests/benches, `Default`
/// for the recorded EXPERIMENTS.md runs, `Full` = the paper's full grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Default,
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "default" => Some(Scale::Default),
            "full" => Some(Scale::Full),
            _ => None,
        }
    }
}

/// The `Scale::Full` campaign-scale DAG sizes beyond the paper's grid
/// (ROADMAP "scale the campaign grids"): 10k/50k/100k tasks.
pub const FULL_GGEN_TASKS: [usize; 3] = [10_000, 50_000, 100_000];

/// The benchmark instance grid at a given scale.  `Scale::Full` is the
/// paper's grid *plus* the [`FULL_GGEN_TASKS`] layered instances; the
/// campaign driver generates graphs per slice, so the 100k-task DAGs
/// are never all resident at once.
pub fn instances(scale: Scale) -> Vec<Instance> {
    let (nbs, bss, widths, phases): (&[usize], &[usize], &[usize], &[usize]) = match scale {
        Scale::Smoke => (&[5], &[320], &[100], &[2]),
        Scale::Default => (&[5, 10], &[64, 320, 960], &[100, 300, 500], &[2, 5]),
        Scale::Full => (
            &[5, 10, 20],
            &costs::PAPER_BLOCK_SIZES,
            &forkjoin::PAPER_WIDTHS,
            &forkjoin::PAPER_PHASES,
        ),
    };
    let mut out = Vec::new();
    for app in chameleon::APPS {
        for &nb in nbs {
            for &bs in bss {
                out.push(Instance::Chameleon {
                    app: app.to_string(),
                    nb_blocks: nb,
                    block_size: bs,
                });
            }
        }
    }
    for &w in widths {
        for &p in phases {
            out.push(Instance::ForkJoin { width: w, phases: p });
        }
    }
    if scale == Scale::Full {
        for &n in &FULL_GGEN_TASKS {
            out.push(Instance::Ggen { n_tasks: n });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instance_labels_and_generation() {
        let i = Instance::Chameleon {
            app: "potrf".into(),
            nb_blocks: 5,
            block_size: 320,
        };
        assert_eq!(i.label(), "potrf-nb5-bs320");
        let g = i.generate(2);
        assert_eq!(g.n_tasks(), 35);
        assert_eq!(g.n_types(), 2);
        let g3 = i.generate(3);
        assert_eq!(g3.n_types(), 3);
    }

    #[test]
    fn forkjoin_instance() {
        let i = Instance::ForkJoin { width: 100, phases: 2 };
        let g = i.generate(2);
        assert_eq!(g.n_tasks(), 203);
    }

    #[test]
    fn generation_is_deterministic() {
        let i = Instance::ForkJoin { width: 50, phases: 2 };
        assert_eq!(i.generate(2).proc_times, i.generate(2).proc_times);
    }

    #[test]
    fn grids_have_expected_sizes() {
        assert_eq!(instances(Scale::Smoke).len(), 5 + 1);
        assert_eq!(instances(Scale::Default).len(), 5 * 2 * 3 + 3 * 2);
        // paper grid + the 10k/50k/100k layered campaign instances
        assert_eq!(instances(Scale::Full).len(), 5 * 3 * 6 + 5 * 3 + 3);
    }

    #[test]
    fn ggen_instance_labels_and_generation() {
        let i = Instance::Ggen { n_tasks: 10_000 };
        assert_eq!(i.label(), "ggen-layers-n10000");
        assert_eq!(i.app(), "ggen-layers");
        // generate at a test-friendly size through the same path
        let small = Instance::Ggen { n_tasks: 600 };
        let g = small.generate(2);
        assert!(g.n_tasks() >= 600);
        assert_eq!(g.n_types(), 2);
        g.validate().unwrap();
        assert_eq!(small.generate(2).proc_times, g.proc_times);
    }

    #[test]
    fn warm_params_score_instance_proximity() {
        use crate::lp::warm::{grid_distance, CLOSE_DIST};
        let a = Instance::Chameleon { app: "potrf".into(), nb_blocks: 5, block_size: 320 };
        let b = Instance::Chameleon { app: "potrf".into(), nb_blocks: 5, block_size: 512 };
        let c = Instance::Chameleon { app: "potrf".into(), nb_blocks: 20, block_size: 64 };
        let (app_a, pa) = a.warm_params();
        let (app_b, pb) = b.warm_params();
        let (app_c, pc) = c.warm_params();
        assert_eq!(app_a, app_b);
        assert_eq!(app_a, app_c);
        // neighboring block sizes are close; a 4x nb + 5x bs jump is not
        assert!(grid_distance(&pa, &pb) <= CLOSE_DIST);
        assert!(grid_distance(&pa, &pc) > CLOSE_DIST);
    }
}
