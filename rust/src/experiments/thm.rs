//! The adversarial instances of Theorems 1, 2 and 4 (Tables 1–3) and
//! drivers that measure the achieved ratios against the closed forms —
//! the reproduction of Figures 1 and 2.

use crate::graph::{Builder, TaskGraph};
use crate::platform::Platform;
use crate::sched::heft::heft_schedule;
use crate::sched::online::{online_by_id, OnlinePolicy};
use crate::sim::{validate, Placement, Schedule};

/// Theorem 1 / Table 1: 2m sets of tasks on which HEFT achieves ratio
/// `((m+k)/k²)(1 − e^{−k})`, for k ≤ √m.
///
/// Sets A_i (k tasks each): p̄ = p̠ = (m/(m+k))^i.
/// Sets B_i (m tasks each): p̄ = (m/(m+k))^i, p̠ = (k/m²)(m/(m+k))^m.
pub fn thm1_instance(m: usize, k: usize) -> TaskGraph {
    assert!(k * k <= m, "Theorem 1 needs k <= sqrt(m)");
    let mut b = Builder::new("thm1");
    let (mf, kf) = (m as f64, k as f64);
    let q = mf / (mf + kf);
    let b_gpu = kf / (mf * mf) * q.powi(m as i32);
    for i in 1..=m {
        let p = q.powi(i as i32);
        for _ in 0..k {
            b.add_task(&format!("A{i}"), vec![p, p]);
        }
        for _ in 0..m {
            b.add_task(&format!("B{i}"), vec![p, b_gpu]);
        }
    }
    b.build()
}

/// The near-optimal schedule from the Theorem 1 proof (Fig. 1 right):
/// all A_i tasks of a given i go serially on CPU i−1; the B tasks are
/// round-robined over the k GPUs.
pub fn thm1_good_schedule(g: &TaskGraph, m: usize, k: usize) -> Schedule {
    let mut placements = vec![
        Placement {
            ptype: 0,
            unit: 0,
            start: 0.0,
            finish: 0.0
        };
        g.n_tasks()
    ];
    let mut cpu_free = vec![0.0f64; m];
    let mut gpu_free = vec![0.0f64; k];
    let mut next_gpu = 0usize;
    let mut idx = 0usize;
    for i in 1..=m {
        // k tasks of A_i -> CPU (i-1), serially
        let cpu = i - 1;
        for _ in 0..k {
            let start = cpu_free[cpu];
            let fin = start + g.p_cpu(idx);
            placements[idx] = Placement {
                ptype: 0,
                unit: cpu,
                start,
                finish: fin,
            };
            cpu_free[cpu] = fin;
            idx += 1;
        }
        // m tasks of B_i -> round robin over GPUs
        for _ in 0..m {
            let start = gpu_free[next_gpu];
            let fin = start + g.p_gpu(idx);
            placements[idx] = Placement {
                ptype: 1,
                unit: next_gpu,
                start,
                finish: fin,
            };
            gpu_free[next_gpu] = fin;
            next_gpu = (next_gpu + 1) % k;
            idx += 1;
        }
    }
    Schedule::from_placements(placements)
}

/// Closed-form (asymptotic) lower bound on HEFT's ratio from Theorem 1:
/// `((m+k)/k²)(1 − e^{−k})`.
pub fn thm1_predicted_ratio(m: usize, k: usize) -> f64 {
    let (mf, kf) = (m as f64, k as f64);
    (mf + kf) / (kf * kf) * (1.0 - (-kf).exp())
}

/// Exact finite-m ratio of the construction:
/// HEFT = Σ_{i=1..m} q^i with q = m/(m+k); GOOD = km/(m+k);
/// ratio = ((m+k)/k²)(1 − q^m)  →  the asymptotic form as m → ∞
/// (since q^m = (1+k/m)^{−m} ↓ e^{−k}).
pub fn thm1_exact_ratio(m: usize, k: usize) -> f64 {
    let (mf, kf) = (m as f64, k as f64);
    let q = mf / (mf + kf);
    (mf + kf) / (kf * kf) * (1.0 - q.powi(m as i32))
}

/// Measured Theorem-1 experiment: (heft_makespan, good_makespan, ratio).
pub fn thm1_run(m: usize, k: usize) -> (f64, f64, f64) {
    let g = thm1_instance(m, k);
    let plat = Platform::hybrid(m, k);
    let heft = heft_schedule(&g, &plat);
    validate(&g, &plat, &heft).expect("HEFT schedule invalid");
    let good = thm1_good_schedule(&g, m, k);
    validate(&g, &plat, &good).expect("good schedule invalid");
    (heft.makespan, good.makespan, heft.makespan / good.makespan)
}

/// Theorem 2 / Table 2: the instance on which *any* scheduling policy
/// after HLP rounding achieves ratio 6 − O(1/m).  m = k.
///
/// Task A: p̄ = m(2m+1)/(m−1), p̠ = "∞" (a huge finite surrogate).
/// B1 (2m+1 tasks): p̄ = 2m−1, p̠ = 1.  B2 (2m+1): p̄ = 1, p̠ = 2m−1.
/// Full bipartite precedence B1 → B2.
pub fn thm2_instance(m: usize) -> TaskGraph {
    assert!(m >= 3);
    let mf = m as f64;
    let mut b = Builder::new("thm2");
    let inf = 1e6 * mf; // finite surrogate for p̠_A = ∞
    b.add_task("A", vec![mf * (2.0 * mf + 1.0) / (mf - 1.0), inf]);
    let n_b = 2 * m + 1;
    let mut b1 = Vec::new();
    for _ in 0..n_b {
        b1.push(b.add_task("B1", vec![2.0 * mf - 1.0, 1.0]));
    }
    for _ in 0..n_b {
        let t = b.add_task("B2", vec![1.0, 2.0 * mf - 1.0]);
        for &p in &b1 {
            b.add_arc(p, t);
        }
    }
    b.build()
}

/// LP* of the relaxed HLP on the Theorem-2 instance (Proposition 1).
pub fn thm2_lp_star(m: usize) -> f64 {
    let mf = m as f64;
    mf * (2.0 * mf + 1.0) / (mf - 1.0)
}

/// The worst-case makespan 6(2m−1) from the proof.
pub fn thm2_worst_makespan(m: usize) -> f64 {
    6.0 * (2.0 * m as f64 - 1.0)
}

/// The allocation produced by rounding the Proposition-1 optimal
/// fractional solution: A → CPU, B1 → CPU (x = ½ rounds up),
/// B2 → GPU (x = ½ − ε rounds down).
pub fn thm2_proposition_allocation(m: usize) -> Vec<usize> {
    let n_b = 2 * m + 1;
    let mut alloc = vec![0usize]; // A on CPU
    alloc.extend(std::iter::repeat(0).take(n_b)); // B1 on CPU
    alloc.extend(std::iter::repeat(1).take(n_b)); // B2 on GPU
    alloc
}

/// Run the Theorem-2 experiment: schedule the rounded allocation with
/// EST and OLS and report (lp_star, est_ratio, ols_ratio).  Ratios
/// approach 6 as m grows — for *any* scheduling policy (Corollary 1).
pub fn thm2_run(m: usize) -> (f64, f64, f64) {
    use crate::sched::{est::est_schedule, list::ols_schedule};
    let g = thm2_instance(m);
    let plat = Platform::hybrid(m, m);
    let alloc = thm2_proposition_allocation(m);
    let lp_star = thm2_lp_star(m);
    let est = est_schedule(&g, &plat, &alloc);
    validate(&g, &plat, &est).expect("EST schedule invalid");
    let ols = ols_schedule(&g, &plat, &alloc);
    validate(&g, &plat, &ols).expect("OLS schedule invalid");
    (lp_star, est.makespan / lp_star, ols.makespan / lp_star)
}

/// Theorem 4 / Table 3: ER-LS achieves `√(m/k)` on k independent tasks
/// A (p̄ = p̠ = √m) followed by an m-task chain B (p̄ = √m, p̠ = √k).
pub fn thm4_instance(m: usize, k: usize) -> TaskGraph {
    assert!(k <= m);
    let mut b = Builder::new("thm4");
    let sm = (m as f64).sqrt();
    let sk = (k as f64).sqrt();
    for _ in 0..k {
        b.add_task("A", vec![sm, sm]);
    }
    let mut prev: Option<usize> = None;
    for _ in 0..m {
        let t = b.add_task("B", vec![sm, sk]);
        if let Some(p) = prev {
            b.add_arc(p, t);
        }
        prev = Some(t);
    }
    b.build()
}

/// Run ER-LS on the Theorem-4 instance and construct the optimal-style
/// schedule from the proof: A on distinct CPUs, the B chain on one GPU.
pub fn thm4_run(m: usize, k: usize) -> (f64, f64, f64) {
    let g = thm4_instance(m, k);
    let plat = Platform::hybrid(m, k);
    let erls = online_by_id(&g, &plat, &OnlinePolicy::ErLs);
    validate(&g, &plat, &erls).expect("ER-LS schedule invalid");

    let sm = (m as f64).sqrt();
    let sk = (k as f64).sqrt();
    let mut placements = Vec::new();
    for a in 0..k {
        placements.push(Placement {
            ptype: 0,
            unit: a,
            start: 0.0,
            finish: sm,
        });
    }
    for i in 0..m {
        placements.push(Placement {
            ptype: 1,
            unit: 0,
            start: i as f64 * sk,
            finish: (i + 1) as f64 * sk,
        });
    }
    let opt = Schedule::from_placements(placements);
    validate(&g, &plat, &opt).expect("optimal schedule invalid");
    (erls.makespan, opt.makespan, erls.makespan / opt.makespan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thm1_heft_matches_prediction() {
        for (m, k) in [(9usize, 2usize), (16, 3), (25, 4)] {
            let (heft_ms, good_ms, ratio) = thm1_run(m, k);
            // HEFT fills all units until sum_i (m/(m+k))^i
            let (mf, kf) = (m as f64, k as f64);
            let q = mf / (mf + kf);
            let expected_heft: f64 = (1..=m).map(|i| q.powi(i as i32)).sum();
            assert!(
                (heft_ms - expected_heft).abs() < 1e-6,
                "m={m} k={k}: HEFT {heft_ms} vs predicted {expected_heft}"
            );
            // good schedule's makespan is at most km/(m+k)
            assert!(good_ms <= kf * mf / (mf + kf) + 1e-9);
            // measured ratio matches the exact finite-m expression
            assert!(
                (ratio - thm1_exact_ratio(m, k)).abs() < 1e-6,
                "m={m} k={k}: ratio {ratio} vs exact {}",
                thm1_exact_ratio(m, k)
            );
        }
        // exact expression converges to the theorem's asymptotic bound
        // from below: q^m = (1+k/m)^{-m} >= e^{-k}
        for k in [2usize, 3] {
            let exact_small = thm1_exact_ratio(k * k, k);
            let exact_big = thm1_exact_ratio(4000, k);
            let asym = thm1_predicted_ratio(4000, k);
            assert!(exact_small <= asym * (k * k + k) as f64 / (k * k) as f64);
            assert!((exact_big - asym).abs() / asym < 1e-3);
        }
    }

    #[test]
    fn thm1_requires_k_le_sqrt_m() {
        let r = std::panic::catch_unwind(|| thm1_instance(4, 3));
        assert!(r.is_err());
    }

    #[test]
    fn thm2_instance_shape() {
        let g = thm2_instance(5);
        assert_eq!(g.n_tasks(), 4 * 5 + 3); // 1 + (2m+1) + (2m+1) = 23
        assert_eq!(g.n_arcs(), 11 * 11);
        g.validate().unwrap();
    }

    #[test]
    fn thm2_ratio_approaches_six() {
        let mut prev = 0.0;
        for m in [5usize, 10, 20, 40] {
            let (lp_star, est_ratio, ols_ratio) = thm2_run(m);
            // LP* matches Proposition 1's value by construction
            assert!((lp_star - thm2_lp_star(m)).abs() < 1e-9);
            // both policies land on the 6 − O(1/m) worst case:
            // makespan = 6(2m−1), LP* = m(2m+1)/(m−1)
            let want = thm2_worst_makespan(m) / lp_star;
            assert!(
                (est_ratio - want).abs() < 1e-6,
                "m={m}: EST ratio {est_ratio} want {want}"
            );
            assert!(
                (ols_ratio - want).abs() < 1e-6,
                "m={m}: OLS ratio {ols_ratio} want {want}"
            );
            // monotone towards 6, never exceeding it
            assert!(want > prev && want < 6.0);
            prev = want;
        }
        assert!(prev > 5.6, "m=40 ratio should be close to 6: {prev}");
    }

    #[test]
    fn thm2_lp_solution_value_verified_by_simplex() {
        use crate::lp::model::build_hlp;
        use crate::lp::simplex::solve_simplex;
        let m = 4;
        let g = thm2_instance(m);
        let (lp, _) = build_hlp(&g, &Platform::hybrid(m, m));
        let sol = solve_simplex(&lp).unwrap();
        assert!(
            (sol.obj - thm2_lp_star(m)).abs() < 1e-6,
            "simplex {} vs proposition {}",
            sol.obj,
            thm2_lp_star(m)
        );
    }

    #[test]
    fn thm4_erls_hits_lower_bound() {
        for (m, k) in [(16usize, 4usize), (36, 4), (64, 16)] {
            let (erls_ms, opt_ms, ratio) = thm4_run(m, k);
            let sm = (m as f64).sqrt();
            let sk = (k as f64).sqrt();
            // ER-LS: chain serially on CPUs -> m*sqrt(m)
            assert!(
                (erls_ms - m as f64 * sm).abs() < 1e-6,
                "m={m} k={k}: ER-LS {erls_ms}"
            );
            // OPT-style schedule: max(sqrt(m), m*sqrt(k)) = m*sqrt(k)
            assert!((opt_ms - m as f64 * sk).abs() < 1e-6);
            // ratio = sqrt(m/k)
            let want = (m as f64 / k as f64).sqrt();
            assert!((ratio - want).abs() < 1e-6, "ratio {ratio} want {want}");
        }
    }
}
