//! Online campaign (Figs. 6–7): ER-LS vs the EFT / Greedy / Random
//! baselines on the 2-type configs, normalized by LP* (which also feeds
//! the competitive-ratio-vs-√(m/k) series of Fig. 6-right).

use crate::analysis::Record;
use crate::sched::online::{online_by_id, OnlinePolicy};
use crate::sim::validate;
use crate::substrate::rng::seed_for;

use super::driver::run_campaign;
use super::CampaignOpts;

/// The §6.3 policy set.
pub fn policies(instance_label: &str) -> Vec<OnlinePolicy> {
    vec![
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(seed_for(&["online-random", instance_label])),
    ]
}

/// Run the online campaign (2 types).
pub fn run(opts: &CampaignOpts) -> Vec<Record> {
    run_campaign(2, opts, |inst, cfg, g, alloc_lp| {
        let sqrt_mk = (cfg.m() as f64 / cfg.k() as f64).sqrt();
        policies(&inst.label())
            .iter()
            .map(|policy| {
                let s = online_by_id(g, cfg, policy);
                debug_assert!(validate(g, cfg, &s).is_ok());
                Record {
                    instance: inst.label(),
                    app: inst.app().to_string(),
                    config: cfg.label(),
                    algo: policy.name().to_string(),
                    makespan: s.makespan,
                    lp_star: alloc_lp.sol.obj,
                    sqrt_mk,
                }
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{mean_improvement_pct, ratio_by_sqrt_mk};
    use crate::runtime::LpBackendKind;

    #[test]
    fn smoke_online_campaign() {
        let opts = CampaignOpts {
            backend: LpBackendKind::RustPdhg,
            workers: 4,
            ..CampaignOpts::smoke()
        };
        let records = run(&opts);
        // 6 instances x 4 configs x 4 policies
        assert_eq!(records.len(), 6 * 4 * 4);
        for r in &records {
            assert!(r.ratio() > 0.95, "{:?}", r);
        }
        // ER-LS stays below its theoretical 4*sqrt(m/k) bound vs LP*
        for r in records.iter().filter(|r| r.algo == "ER-LS") {
            assert!(
                r.ratio() <= 4.0 * r.sqrt_mk + 1e-6,
                "ER-LS exceeded 4*sqrt(m/k): {:?}",
                r
            );
        }
        // qualitative ordering that holds on both the paper's measured
        // times and our synthetic matrix: Random is far worse than
        // ER-LS, EFT is the strongest baseline, and ER-LS beats Greedy
        // on the irregular fork-join app (the paper's overall +16% vs
        // Greedy depends on its measured time matrix; see EXPERIMENTS.md)
        let rand_vs_er = mean_improvement_pct(&records, "Random", "ER-LS");
        assert!(rand_vs_er < -20.0, "Random vs ER-LS: {rand_vs_er:.1}%");
        let er_vs_eft = mean_improvement_pct(&records, "ER-LS", "EFT");
        assert!(er_vs_eft < 5.0, "EFT should be competitive: {er_vs_eft:.1}%");
        let fj = crate::analysis::pairwise_by_app(&records, "Greedy", "ER-LS");
        assert!(
            fj["fork-join"].mean > 1.0,
            "ER-LS should beat Greedy on fork-join: {}",
            fj["fork-join"].mean
        );
        // Fig. 6-right series exists with one point per sqrt(m/k) value
        let series = ratio_by_sqrt_mk(&records, "ER-LS");
        assert!(!series.is_empty());
        // mean competitive ratio below sqrt(m/k) (paper's observation)
        for (x, s) in &series {
            assert!(s.mean <= *x + 1.0, "mean {} vs sqrt {}", s.mean, x);
        }
    }
}
