//! Ablations of the design choices DESIGN.md calls out:
//!
//! * **OLS priority** (§4.1): the paper ranks by the *allocated* time
//!   (HLP-rank).  Alternatives: HEFT's average-time rank, submission
//!   order, and a random priority — how much does the rank choice buy?
//! * **Rounding threshold** (§3): `x_j ≥ θ` → CPU with θ = 0.5 in the
//!   paper; sweep θ.
//! * **PDHG solver** (§Perf): warm start / Ruiz / restart-to-average
//!   on-off grid, measured in iterations-to-tolerance.

use crate::alloc::greedy_min_time;
use crate::graph::{paths, TaskGraph};
use crate::lp::model::{build_hlp, hlp_warm_start, tighten_hlp_box};
use crate::lp::pdhg::{drive, ChunkBackend, ChunkResult, DriveOpts, RustChunk};

use crate::platform::Platform;
use crate::runtime::LpBackendKind;
use crate::sched::list::list_schedule;
use crate::substrate::rng::Rng;

use super::driver::run_campaign;
use super::CampaignOpts;

/// Priority rules for the OLS scheduling phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// the paper's rank: bottom level under the HLP allocation
    HlpRank,
    /// HEFT-style rank: bottom level under unit-weighted average times
    AvgRank,
    /// submission order (task id, descending so earlier tasks first)
    IdOrder,
    /// random priorities (seeded)
    Random(u64),
}

impl Priority {
    pub fn name(&self) -> &'static str {
        match self {
            Priority::HlpRank => "hlp-rank",
            Priority::AvgRank => "avg-rank",
            Priority::IdOrder => "id-order",
            Priority::Random(_) => "random",
        }
    }

    pub fn compute(&self, g: &TaskGraph, plat: &Platform, alloc: &[usize]) -> Vec<f64> {
        match self {
            Priority::HlpRank => paths::ols_rank(g, alloc),
            Priority::AvgRank => paths::heft_rank(g, &plat.counts),
            Priority::IdOrder => (0..g.n_tasks()).map(|j| -(j as f64)).collect(),
            Priority::Random(seed) => {
                let mut rng = Rng::new(*seed);
                (0..g.n_tasks()).map(|_| rng.f64()).collect()
            }
        }
    }
}

/// Makespans of list scheduling under each priority rule, same allocation.
pub fn ablate_priority(
    g: &TaskGraph,
    plat: &Platform,
    tol: f64,
) -> Vec<(&'static str, f64)> {
    let hlp = crate::algos::solve_hlp(g, plat, LpBackendKind::RustPdhg, tol);
    [
        Priority::HlpRank,
        Priority::AvgRank,
        Priority::IdOrder,
        Priority::Random(7),
    ]
    .iter()
    .map(|p| {
        let prio = p.compute(g, plat, &hlp.alloc);
        let s = list_schedule(g, plat, &hlp.alloc, &prio);
        (p.name(), s.makespan)
    })
    .collect()
}

/// Makespans of HLP-EST under different rounding thresholds θ.
pub fn ablate_rounding_threshold(
    g: &TaskGraph,
    plat: &Platform,
    thetas: &[f64],
    tol: f64,
) -> Vec<(f64, f64)> {
    let (mut lp, vars) = build_hlp(g, plat);
    let warm = hlp_warm_start(g, plat, &greedy_min_time(g), &vars);
    tighten_hlp_box(&mut lp, &vars, warm[vars.lambda]);
    let sol = crate::runtime::solve_lp(&lp, LpBackendKind::RustPdhg, tol, Some(warm));
    thetas
        .iter()
        .map(|&theta| {
            let alloc: Vec<usize> = (0..vars.n_tasks)
                .map(|j| usize::from(sol.z[vars.x(j)] < theta))
                .collect();
            let s = crate::sched::est::est_schedule(g, plat, &alloc);
            (theta, s.makespan)
        })
        .collect()
}

/// One row of the sharded priority-ablation campaign.
#[derive(Clone, Debug)]
pub struct AblationRecord {
    pub instance: String,
    pub config: String,
    pub priority: &'static str,
    pub makespan: f64,
    pub lp_star: f64,
}

impl AblationRecord {
    pub fn ratio(&self) -> f64 {
        self.makespan / self.lp_star
    }
}

/// The priority rules the campaign sweeps.
pub const PRIORITY_GRID: [Priority; 4] = [
    Priority::HlpRank,
    Priority::AvgRank,
    Priority::IdOrder,
    Priority::Random(7),
];

/// Run the OLS-priority ablation over the full benchmark grid, sharded
/// across the worker pool with per-(instance, config) LP reuse through
/// the campaign cache — the same sharding scheme as the offline/online
/// campaigns, so the expensive HLP solves are paid once and shared with
/// the figure harnesses when they use the same cache path.
pub fn run_priority_campaign(opts: &CampaignOpts) -> Vec<AblationRecord> {
    run_campaign(2, opts, |inst, cfg, g, hlp| {
        PRIORITY_GRID
            .iter()
            .map(|p| {
                let prio = p.compute(g, cfg, &hlp.alloc);
                let s = list_schedule(g, cfg, &hlp.alloc, &prio);
                AblationRecord {
                    instance: inst.label(),
                    config: cfg.label(),
                    priority: p.name(),
                    makespan: s.makespan,
                    lp_star: hlp.sol.obj,
                }
            })
            .collect()
    })
}

/// A chunk backend wrapper that disables restart-to-average by reporting
/// an infinitely bad average (the driver then never adopts it).
struct NoRestart(RustChunk);

impl ChunkBackend for NoRestart {
    fn run_chunk(&mut self, z: &mut [f64], y: &mut [f64], tau: f64, sigma: f64) -> ChunkResult {
        let mut res = self.0.run_chunk(z, y, tau, sigma);
        res.avg.pres = f64::INFINITY;
        res
    }
    fn load_avg(&self, z: &mut [f64], y: &mut [f64]) {
        self.0.load_avg(z, y);
    }
    fn iters_per_chunk(&self) -> usize {
        self.0.iters_per_chunk()
    }
    fn name(&self) -> &'static str {
        "pdhg-rust-norestart"
    }
}

/// PDHG solver ablation: iterations to tolerance for each on/off combo.
/// Returns (label, iterations, achieved_gap).
pub fn ablate_pdhg(g: &TaskGraph, plat: &Platform, tol: f64) -> Vec<(String, usize, f64)> {
    let (mut lp, vars) = build_hlp(g, plat);
    let warm = hlp_warm_start(g, plat, &greedy_min_time(g), &vars);
    tighten_hlp_box(&mut lp, &vars, warm[vars.lambda]);
    let mut out = Vec::new();
    for (warm_on, ruiz_on, restart_on) in [
        (true, true, true),
        (false, true, true),
        (true, false, true),
        (true, true, false),
        (false, false, false),
    ] {
        let opts = DriveOpts {
            tol,
            max_iters: 150_000,
            ruiz_iters: if ruiz_on { 8 } else { 0 },
            warm_start: warm_on.then(|| warm.clone()),
            ..Default::default()
        };
        let sol = if restart_on {
            drive(&lp, &opts, |scaled| RustChunk::new(scaled, 250))
        } else {
            drive(&lp, &opts, |scaled| NoRestart(RustChunk::new(scaled, 250)))
        };
        let label = format!(
            "warm={} ruiz={} restart={}",
            u8::from(warm_on),
            u8::from(ruiz_on),
            u8::from(restart_on)
        );
        out.push((label, sol.iters, sol.gap));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{chameleon, costs::CostModel};

    #[test]
    fn priority_ablation_hlp_rank_not_worse_than_random() {
        let g = chameleon::posv(8, &CostModel::hybrid(320), 5);
        let plat = Platform::hybrid(8, 2);
        let results = ablate_priority(&g, &plat, 1e-4);
        assert_eq!(results.len(), 4);
        let get = |n: &str| results.iter().find(|(a, _)| *a == n).unwrap().1;
        // the paper's rank should not lose to random priorities here
        assert!(get("hlp-rank") <= get("random") * 1.05);
    }

    #[test]
    fn threshold_half_is_reasonable() {
        let g = chameleon::potrf(8, &CostModel::hybrid(320), 5);
        let plat = Platform::hybrid(8, 2);
        let sweep = ablate_rounding_threshold(&g, &plat, &[0.25, 0.5, 0.75], 1e-4);
        assert_eq!(sweep.len(), 3);
        for (_, ms) in &sweep {
            assert!(*ms > 0.0);
        }
    }

    #[test]
    fn priority_campaign_shards_and_reuses_lps() {
        let opts = CampaignOpts {
            backend: LpBackendKind::RustPdhg,
            workers: 4,
            ..CampaignOpts::smoke()
        };
        let records = run_priority_campaign(&opts);
        // 6 smoke instances x 4 smoke configs x 4 priority rules
        assert_eq!(records.len(), 6 * 4 * 4);
        for r in &records {
            assert!(r.ratio() > 0.95, "{r:?}");
        }
        // the paper's rank never loses badly to submission order overall
        let mean = |name: &str| {
            let xs: Vec<f64> = records
                .iter()
                .filter(|r| r.priority == name)
                .map(|r| r.ratio())
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(mean("hlp-rank") <= mean("id-order") * 1.05);
    }

    #[test]
    fn pdhg_ablation_full_config_converges_fastest_or_close() {
        let g = chameleon::potrf(8, &CostModel::hybrid(320), 5);
        let plat = Platform::hybrid(8, 2);
        let rows = ablate_pdhg(&g, &plat, 1e-4);
        assert_eq!(rows.len(), 5);
        let full = rows[0].1;
        let bare = rows[4].1;
        assert!(
            full <= bare,
            "full config ({full}) should beat bare PDHG ({bare})"
        );
    }
}
