//! Offline campaign (Figs. 3–5): every benchmark instance × machine
//! config × {HLP-EST, HLP-OLS, HEFT} (2 types) or the QHLP versions
//! (3 types), normalized by the LP* of the corresponding relaxation.

use crate::algos::{run_offline, Offline};
use crate::analysis::Record;
use crate::platform::{self, Platform};
use crate::sim::validate;
use crate::workloads::Scale;

use super::driver::run_campaign;
use super::CampaignOpts;

/// Machine-configuration grid for the given type count and scale.
/// `Scale::Full` runs the extended hybrid grid — the paper's 16
/// configurations plus the 256-/320-unit cluster platforms the
/// gap-indexed engine unlocks.
pub fn configs(n_types: usize, scale: Scale) -> Vec<Platform> {
    match (n_types, scale) {
        (2, Scale::Full) => platform::extended_two_type_configs(),
        (2, Scale::Default) => platform::paper_two_type_configs(),
        (2, Scale::Smoke) => platform::reduced_two_type_configs(),
        (3, Scale::Full) => platform::paper_three_type_configs(),
        (3, Scale::Default) => platform::reduced_three_type_configs(),
        (3, Scale::Smoke) => vec![platform::reduced_three_type_configs()[0].clone()],
        _ => panic!("unsupported type count {n_types}"),
    }
}

/// Run the offline campaign for `n_types` ∈ {2, 3}.
/// Returns one record per (instance, config, algorithm).
pub fn run(n_types: usize, opts: &CampaignOpts) -> Vec<Record> {
    run_campaign(n_types, opts, |inst, cfg, g, alloc_lp| {
        let sqrt_mk = if n_types == 2 {
            (cfg.m() as f64 / cfg.k() as f64).sqrt()
        } else {
            0.0
        };
        Offline::ALL
            .iter()
            .map(|&algo| {
                let (s, _) = run_offline(algo, g, cfg, Some(alloc_lp), opts.backend, opts.tol);
                debug_assert!(validate(g, cfg, &s).is_ok());
                let name = if n_types == 2 {
                    algo.name().to_string()
                } else {
                    format!("Q{}", algo.name())
                };
                Record {
                    instance: inst.label(),
                    app: inst.app().to_string(),
                    config: cfg.label(),
                    algo: name,
                    makespan: s.makespan,
                    lp_star: alloc_lp.sol.obj,
                    sqrt_mk,
                }
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{mean_improvement_pct, pairwise_by_app, ratio_by_app};
    use crate::runtime::LpBackendKind;

    fn smoke_opts() -> CampaignOpts {
        CampaignOpts {
            backend: LpBackendKind::RustPdhg,
            workers: 4,
            ..CampaignOpts::smoke()
        }
    }

    #[test]
    fn smoke_campaign_two_types() {
        let records = run(2, &smoke_opts());
        // 6 instances x 4 smoke configs x 3 algos
        assert_eq!(records.len(), 6 * 4 * 3);
        // every ratio >= ~1 (LP* is a lower bound) and <= 6 (approx ratio)
        for r in &records {
            assert!(r.ratio() > 0.95, "{:?}", r);
            assert!(r.ratio() < 6.3, "{:?}", r);
        }
        // the paper's qualitative claim: HLP-OLS beats HLP-EST on average
        let imp = mean_improvement_pct(&records, "HLP-OLS", "HLP-EST");
        assert!(imp > 0.0, "OLS should improve on EST, got {imp:.1}%");
        // grouping covers all 6 apps
        assert_eq!(ratio_by_app(&records, "HEFT").len(), 6);
    }

    #[test]
    fn smoke_campaign_three_types() {
        let records = run(3, &smoke_opts());
        assert_eq!(records.len(), 6 * 1 * 3);
        for r in &records {
            assert!(r.ratio() > 0.95 && r.ratio() < 12.5, "{:?}", r);
        }
        let pair = pairwise_by_app(&records, "QHEFT", "QHLP-OLS");
        assert!(!pair.is_empty());
    }
}
