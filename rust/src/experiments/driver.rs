//! Shared campaign driver: the Mutex<LpCache> + (instance × config)
//! cross-product + solve-or-cache scaffolding that the offline, online
//! and priority-ablation campaigns previously each carried a private
//! copy of (ROADMAP "campaign-scaffolding dedup").
//!
//! One call runs a whole campaign in two sharded phases:
//!
//! 1. **Allocation phase** — every (instance, config) work item's (Q)HLP
//!    relaxation is fetched from the cache or solved.  Cache misses go
//!    through the *batched* multi-LP PDHG driver
//!    ([`crate::algos::solve_alloc_grid`] → [`crate::lp::batch`]): one
//!    shared worker pool advances all missing LPs concurrently, series
//!    chains are contracted out of the models, and each instance's
//!    config grid forms a warm-start chain (primal + dual iterates flow
//!    from one config to the next, under the escalating budget
//!    schedule).  Cache keys are unchanged — instance, config, type
//!    count, tolerance *and* PDHG iteration budget — and a warm-started
//!    solve certifies the same tolerance a cold solve would, so cached
//!    LP* semantics are identical (pinned by `rust/tests/lp_warm_batch.rs`).
//!    Backends that can't run batched (simplex, PJRT artifacts) keep the
//!    per-item `parallel_map` path.  Chain heads additionally (a) seed
//!    from the previous *process run's* persisted final iterates
//!    ([`super::cache::iterate_key`]) when present — so repeated
//!    campaigns warm-start across processes even when their LP* keys
//!    miss — falling back to (b) a cross-instance chain onto a same-app,
//!    nearby-parameter instance in the same slice
//!    ([`Instance::warm_params`] scored by
//!    [`crate::lp::warm::grid_distance`]); and heads' final iterates are
//!    persisted back (size-bounded) for the next run.
//! 2. **Row phase** — the campaign's row closure runs per work item over
//!    the worker pool, with rows kept in grid order.

use std::sync::Mutex;

use crate::algos::{
    solve_alloc_grid_seeded, solve_hlp_capped, solve_qhlp_capped, AllocLp, GridSeed,
};
use crate::graph::TaskGraph;
use crate::lp::warm::{grid_distance, CLOSE_DIST};
use crate::platform::Platform;
use crate::runtime::{self, LpBackendKind};
use crate::substrate::pool::parallel_map;
use crate::workloads::{instances, Instance};

use super::cache::{cache_key, iterate_key, LpCache};
use super::offline::configs;
use super::CampaignOpts;

/// Run one campaign over the (instance × config) grid for `n_types` ∈
/// {2, 3}.  `row_fn` receives the instance, the machine config, the
/// generated graph and the solved (or cached) relaxation, and returns
/// the campaign's rows for that work item; rows keep grid order.
pub fn run_campaign<R, F>(n_types: usize, opts: &CampaignOpts, row_fn: F) -> Vec<R>
where
    R: Send,
    F: Fn(&Instance, &Platform, &TaskGraph, &AllocLp) -> Vec<R> + Sync,
{
    let insts = instances(opts.scale);
    let cfgs = configs(n_types, opts.scale);
    let cache = Mutex::new(
        opts.cache_path
            .as_ref()
            .map(|p| LpCache::load(p))
            .unwrap_or_default(),
    );

    // work items: one per (instance, config), instance-major so each
    // instance's configs are consecutive (the warm-start chain order);
    // graphs are generated per slice below, never all at once — a
    // Scale::Full campaign holds 10k+-task DAGs that must not all be
    // resident simultaneously (generation is deterministic, so
    // regenerating an instance's graph for the row phase is cheap and
    // changes nothing)
    let mut items: Vec<(usize, usize)> = Vec::new();
    for ii in 0..insts.len() {
        for ci in 0..cfgs.len() {
            items.push((ii, ci));
        }
    }
    let keys: Vec<String> = items
        .iter()
        .map(|&(ii, ci)| {
            cache_key(
                &insts[ii].label(),
                &cfgs[ci].label(),
                n_types,
                opts.tol,
                opts.max_iters,
            )
        })
        .collect();

    // allocation phase: cache hits first, then solve the misses in
    // instance-grouped slices (bounds resident graphs AND built LPs —
    // the batch driver keeps every job's SparseLp alive for the batch's
    // lifetime; slices still span several instances so the batch pool
    // has independent warm chains to run in parallel)
    let mut solved: Vec<Option<AllocLp>> = {
        let cache = cache.lock().unwrap();
        keys.iter().map(|k| cache.get(k)).collect()
    };
    let misses: Vec<usize> = (0..items.len()).filter(|&ix| solved[ix].is_none()).collect();
    if !misses.is_empty() {
        let batched = match opts.backend {
            LpBackendKind::RustPdhg => true,
            LpBackendKind::Auto => !runtime::pjrt_available(),
            LpBackendKind::Pjrt | LpBackendKind::Simplex => false,
        };
        let min_insts = opts.workers.max(2);
        let max_items = (8 * opts.workers.max(1)).max(cfgs.len());
        let mut slice: Vec<usize> = Vec::new(); // miss ixs of whole instances
        let mut slice_insts = 0usize;
        let flush = |slice: &mut Vec<usize>, solved: &mut Vec<Option<AllocLp>>| {
            if slice.is_empty() {
                return;
            }
            // materialize this slice's graphs (one per distinct instance)
            let mut local: Vec<(usize, TaskGraph)> = Vec::new();
            for &ix in slice.iter() {
                let ii = items[ix].0;
                if local.last().map(|(i, _)| *i) != Some(ii) {
                    local.push((ii, insts[ii].generate(n_types)));
                }
            }
            fn graph_of<'a>(local: &'a [(usize, TaskGraph)], ii: usize) -> &'a TaskGraph {
                &local.iter().find(|(i, _)| *i == ii).expect("slice graph").1
            }
            let fresh: Vec<AllocLp> = if batched {
                let grid: Vec<(&TaskGraph, &Platform)> = slice
                    .iter()
                    .map(|&ix| (graph_of(&local, items[ix].0), &cfgs[items[ix].1]))
                    .collect();
                // seed the chain heads: a previous *process run* may have
                // persisted final iterates for exactly this (instance,
                // config) — if so, warm-start from them; otherwise chain
                // the head onto a same-app, nearby-parameter instance
                // already in this slice (cross-instance warm start).
                // Heads keep their final iterates so the next run can do
                // the same; the cache bounds entry sizes.
                let mut seeds: Vec<GridSeed> = Vec::with_capacity(slice.len());
                {
                    let cache = cache.lock().unwrap();
                    for (pos, &ix) in slice.iter().enumerate() {
                        let (ii, ci) = items[ix];
                        let head = pos == 0 || items[slice[pos - 1]].0 != ii;
                        let mut seed = GridSeed {
                            keep_iterates: head,
                            ..Default::default()
                        };
                        if head {
                            let ikey =
                                iterate_key(&insts[ii].label(), &cfgs[ci].label(), n_types);
                            if let Some(it) = cache.get_iterates(&ikey) {
                                seed.iterates = Some(it);
                            } else {
                                let (app, params) = insts[ii].warm_params();
                                let mut best: Option<(usize, f64)> = None;
                                for (ppos, &pix) in slice[..pos].iter().enumerate() {
                                    let (pii, pci) = items[pix];
                                    if pii == ii || pci != ci {
                                        continue;
                                    }
                                    let (papp, pparams) = insts[pii].warm_params();
                                    if papp != app || pparams.len() != params.len() {
                                        continue;
                                    }
                                    let d = grid_distance(&pparams, &params);
                                    if d <= CLOSE_DIST
                                        && best.map_or(true, |(_, bd)| d < bd)
                                    {
                                        best = Some((ppos, d));
                                    }
                                }
                                if let Some((ppos, _)) = best {
                                    seed.chain_from = Some((ppos, true));
                                }
                            }
                        }
                        seeds.push(seed);
                    }
                }
                let full =
                    solve_alloc_grid_seeded(&grid, seeds, opts.tol, opts.max_iters, opts.workers);
                let mut cache = cache.lock().unwrap();
                full.into_iter()
                    .zip(slice.iter())
                    .map(|((lp, kept), &ix)| {
                        if let Some((z, y)) = kept {
                            let (ii, ci) = items[ix];
                            let ikey =
                                iterate_key(&insts[ii].label(), &cfgs[ci].label(), n_types);
                            cache.put_iterates(&ikey, &z, &y);
                        }
                        lp
                    })
                    .collect()
            } else {
                parallel_map(slice.clone(), opts.workers, |ix| {
                    let (ii, ci) = items[ix];
                    let g = graph_of(&local, ii);
                    if n_types == 2 {
                        solve_hlp_capped(g, &cfgs[ci], opts.backend, opts.tol, opts.max_iters)
                    } else {
                        solve_qhlp_capped(g, &cfgs[ci], opts.backend, opts.tol, opts.max_iters)
                    }
                })
            };
            let mut cache = cache.lock().unwrap();
            for (&ix, lp) in slice.iter().zip(fresh) {
                cache.put(&keys[ix], &lp);
                solved[ix] = Some(lp);
            }
            slice.clear();
        };
        let mut prev_inst: Option<usize> = None;
        for &ix in &misses {
            let ii = items[ix].0;
            if prev_inst != Some(ii) {
                // instance boundary: flush once the slice is big enough
                if slice_insts >= min_insts || slice.len() >= max_items {
                    flush(&mut slice, &mut solved);
                    slice_insts = 0;
                }
                slice_insts += 1;
                prev_inst = Some(ii);
            }
            slice.push(ix);
        }
        flush(&mut slice, &mut solved);
    }
    if let Some(path) = &opts.cache_path {
        cache.lock().unwrap().save(path).ok();
    }

    // row phase: one instance at a time (its graph resident only here),
    // the instance's items sharded over the pool, rows kept in grid order
    let mut solved_iter = solved.into_iter().map(Option::unwrap);
    let mut records: Vec<R> = Vec::new();
    for inst in &insts {
        let g = inst.generate(n_types);
        let work: Vec<(usize, AllocLp)> = (0..cfgs.len())
            .map(|ci| (ci, solved_iter.next().expect("one solution per item")))
            .collect();
        let rows: Vec<Vec<R>> = parallel_map(work, opts.workers, |(ci, alloc_lp)| {
            row_fn(inst, &cfgs[ci], &g, &alloc_lp)
        });
        records.extend(rows.into_iter().flatten());
    }
    records
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{run_offline, Offline};
    use crate::experiments::{ablation, offline, online};
    use crate::workloads::Scale;

    fn opts_with_cache(path: std::path::PathBuf) -> CampaignOpts {
        CampaignOpts {
            backend: LpBackendKind::RustPdhg,
            workers: 4,
            cache_path: Some(path),
            ..CampaignOpts::smoke()
        }
    }

    /// The three campaigns run through the shared driver must produce
    /// exactly the records their private scaffolding produced before:
    /// same grid, same LP* (reused across campaigns via the cache), and
    /// per-row values identical to a by-hand replication of the old
    /// per-item loop.
    #[test]
    fn driver_reproduces_all_three_campaigns() {
        let dir =
            std::env::temp_dir().join(format!("hetsched-driver-{}", std::process::id()));
        let path = dir.join("lp_cache.json");
        let opts = opts_with_cache(path.clone());

        let off = offline::run(2, &opts);
        let onl = online::run(&opts);
        let abl = ablation::run_priority_campaign(&opts);
        assert_eq!(off.len(), 6 * 4 * 3);
        assert_eq!(onl.len(), 6 * 4 * 4);
        assert_eq!(abl.len(), 6 * 4 * 4);

        // LP reuse across campaigns: matching (instance, config) rows
        // report the same LP*
        for r in &onl {
            let twin = off
                .iter()
                .find(|o| o.instance == r.instance && o.config == r.config)
                .unwrap();
            assert_eq!(r.lp_star, twin.lp_star, "{}/{}", r.instance, r.config);
        }
        for r in &abl {
            let twin = off
                .iter()
                .find(|o| o.instance == r.instance && o.config == r.config)
                .unwrap();
            assert_eq!(r.lp_star, twin.lp_star);
        }

        // by-hand replication of the pre-driver per-item loop for one
        // work item, through the cache the driver just populated
        let insts = instances(Scale::Smoke);
        let cfgs = configs(2, Scale::Smoke);
        let (inst, cfg) = (&insts[0], &cfgs[0]);
        let g = inst.generate(2);
        let cache = LpCache::load(&path);
        let key = cache_key(&inst.label(), &cfg.label(), 2, opts.tol, opts.max_iters);
        let alloc_lp = cache.get(&key).expect("driver populated the cache");
        for algo in Offline::ALL {
            let (s, _) = run_offline(algo, &g, cfg, Some(&alloc_lp), opts.backend, opts.tol);
            let row = off
                .iter()
                .find(|r| {
                    r.instance == inst.label()
                        && r.config == cfg.label()
                        && r.algo == algo.name()
                })
                .unwrap();
            assert_eq!(row.makespan, s.makespan, "{}", algo.name());
            assert_eq!(row.lp_star, alloc_lp.sol.obj);
        }

        // determinism: a second driver run (cache warm) is identical
        let off2 = offline::run(2, &opts);
        assert_eq!(off.len(), off2.len());
        for (a, b) in off.iter().zip(&off2) {
            assert_eq!(a.instance, b.instance);
            assert_eq!(a.config, b.config);
            assert_eq!(a.algo, b.algo);
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.lp_star, b.lp_star);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Cross-run warm starts (ROADMAP "next lever"): the first campaign
    /// run persists its chain heads' final PDHG iterates; a later run at
    /// a *different budget* — whose LP* keys therefore all miss — seeds
    /// from them and lands on the same LP* (iterates are advisory, the
    /// tolerance certificate is the solve's own).
    #[test]
    fn iterates_persist_across_process_runs() {
        let dir =
            std::env::temp_dir().join(format!("hetsched-xrun-{}", std::process::id()));
        let path = dir.join("lp_cache.json");
        let mk = |max_iters: usize| CampaignOpts {
            backend: LpBackendKind::RustPdhg,
            workers: 4,
            cache_path: Some(path.clone()),
            max_iters,
            ..CampaignOpts::smoke()
        };

        let off_a = offline::run(2, &mk(80_000));
        let cache = LpCache::load(&path);
        assert!(
            cache.n_iterate_entries() > 0,
            "chain heads must persist iterates"
        );

        // different budget => every cache_key misses, iterate keys hit
        let off_b = offline::run(2, &mk(100_000));
        assert_eq!(off_a.len(), off_b.len());
        for (a, b) in off_a.iter().zip(&off_b) {
            assert_eq!(a.instance, b.instance);
            assert_eq!(a.config, b.config);
            let scale = 1.0 + a.lp_star.abs();
            assert!(
                (a.lp_star - b.lp_star).abs() < 2e-3 * scale,
                "{}/{}: {} vs {}",
                a.instance,
                a.config,
                a.lp_star,
                b.lp_star
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The batched allocation phase must agree with the per-item
    /// (simplex-free) solve path on LP* within solver tolerance — the
    /// cache-key-unchanged contract: entries written by either path are
    /// interchangeable.
    #[test]
    fn batched_phase_matches_per_item_solves() {
        let opts = CampaignOpts {
            backend: LpBackendKind::RustPdhg,
            workers: 4,
            ..CampaignOpts::smoke()
        };
        let records = offline::run(2, &opts);
        let insts = instances(Scale::Smoke);
        let cfgs = configs(2, Scale::Smoke);
        // spot-check two work items against solve_hlp_capped
        for (ii, ci) in [(0usize, 0usize), (2, 3)] {
            let g = insts[ii].generate(2);
            let solo = solve_hlp_capped(&g, &cfgs[ci], opts.backend, opts.tol, opts.max_iters);
            let row = records
                .iter()
                .find(|r| r.instance == insts[ii].label() && r.config == cfgs[ci].label())
                .unwrap();
            let scale = 1.0 + solo.sol.obj.abs();
            assert!(
                (row.lp_star - solo.sol.obj).abs() < 1e-3 * scale,
                "{}/{}: {} vs {}",
                row.instance,
                row.config,
                row.lp_star,
                solo.sol.obj
            );
        }
    }
}
