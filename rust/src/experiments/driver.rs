//! Shared campaign driver: the Mutex<LpCache> + (instance × config)
//! cross-product + `parallel_map` + solve-or-cache scaffolding that the
//! offline, online and priority-ablation campaigns previously each
//! carried a private copy of (ROADMAP "campaign-scaffolding dedup").
//!
//! One call runs a whole campaign: for every (instance, machine config)
//! work item, generate the task graph, fetch or solve the (Q)HLP
//! relaxation — keyed by instance, config, type count, tolerance *and*
//! PDHG iteration budget — and hand the solved allocation to the
//! campaign's row closure, sharded across the worker pool with LP reuse
//! through the shared cache file.

use std::sync::Mutex;

use crate::algos::{solve_hlp_capped, solve_qhlp_capped, AllocLp};
use crate::graph::TaskGraph;
use crate::platform::Platform;
use crate::substrate::pool::parallel_map;
use crate::workloads::{instances, Instance};

use super::cache::{cache_key, LpCache};
use super::offline::configs;
use super::CampaignOpts;

/// Run one campaign over the (instance × config) grid for `n_types` ∈
/// {2, 3}.  `row_fn` receives the instance, the machine config, the
/// generated graph and the solved (or cached) relaxation, and returns
/// the campaign's rows for that work item; rows keep grid order.
pub fn run_campaign<R, F>(n_types: usize, opts: &CampaignOpts, row_fn: F) -> Vec<R>
where
    R: Send,
    F: Fn(&Instance, &Platform, &TaskGraph, &AllocLp) -> Vec<R> + Sync,
{
    let insts = instances(opts.scale);
    let cfgs = configs(n_types, opts.scale);
    let cache = Mutex::new(
        opts.cache_path
            .as_ref()
            .map(|p| LpCache::load(p))
            .unwrap_or_default(),
    );

    // work items: one per (instance, config)
    let mut items = Vec::new();
    for inst in &insts {
        for cfg in &cfgs {
            items.push((inst.clone(), cfg.clone()));
        }
    }

    let records: Vec<Vec<R>> = parallel_map(items, opts.workers, |(inst, cfg)| {
        let g = inst.generate(n_types);
        let key = cache_key(&inst.label(), &cfg.label(), n_types, opts.tol, opts.max_iters);
        let cached: Option<AllocLp> = cache.lock().unwrap().get(&key);
        let alloc_lp = cached.unwrap_or_else(|| {
            let solved = if n_types == 2 {
                solve_hlp_capped(&g, &cfg, opts.backend, opts.tol, opts.max_iters)
            } else {
                solve_qhlp_capped(&g, &cfg, opts.backend, opts.tol, opts.max_iters)
            };
            cache.lock().unwrap().put(&key, &solved);
            solved
        });
        row_fn(&inst, &cfg, &g, &alloc_lp)
    });

    if let Some(path) = &opts.cache_path {
        cache.lock().unwrap().save(path).ok();
    }
    records.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{run_offline, Offline};
    use crate::experiments::{ablation, offline, online};
    use crate::runtime::LpBackendKind;
    use crate::workloads::Scale;

    fn opts_with_cache(path: std::path::PathBuf) -> CampaignOpts {
        CampaignOpts {
            backend: LpBackendKind::RustPdhg,
            workers: 4,
            cache_path: Some(path),
            ..CampaignOpts::smoke()
        }
    }

    /// The three campaigns run through the shared driver must produce
    /// exactly the records their private scaffolding produced before:
    /// same grid, same LP* (reused across campaigns via the cache), and
    /// per-row values identical to a by-hand replication of the old
    /// per-item loop.
    #[test]
    fn driver_reproduces_all_three_campaigns() {
        let dir =
            std::env::temp_dir().join(format!("hetsched-driver-{}", std::process::id()));
        let path = dir.join("lp_cache.json");
        let opts = opts_with_cache(path.clone());

        let off = offline::run(2, &opts);
        let onl = online::run(&opts);
        let abl = ablation::run_priority_campaign(&opts);
        assert_eq!(off.len(), 6 * 4 * 3);
        assert_eq!(onl.len(), 6 * 4 * 4);
        assert_eq!(abl.len(), 6 * 4 * 4);

        // LP reuse across campaigns: matching (instance, config) rows
        // report the same LP*
        for r in &onl {
            let twin = off
                .iter()
                .find(|o| o.instance == r.instance && o.config == r.config)
                .unwrap();
            assert_eq!(r.lp_star, twin.lp_star, "{}/{}", r.instance, r.config);
        }
        for r in &abl {
            let twin = off
                .iter()
                .find(|o| o.instance == r.instance && o.config == r.config)
                .unwrap();
            assert_eq!(r.lp_star, twin.lp_star);
        }

        // by-hand replication of the pre-driver per-item loop for one
        // work item, through the cache the driver just populated
        let insts = instances(Scale::Smoke);
        let cfgs = configs(2, Scale::Smoke);
        let (inst, cfg) = (&insts[0], &cfgs[0]);
        let g = inst.generate(2);
        let cache = LpCache::load(&path);
        let key = cache_key(&inst.label(), &cfg.label(), 2, opts.tol, opts.max_iters);
        let alloc_lp = cache.get(&key).expect("driver populated the cache");
        for algo in Offline::ALL {
            let (s, _) = run_offline(algo, &g, cfg, Some(&alloc_lp), opts.backend, opts.tol);
            let row = off
                .iter()
                .find(|r| {
                    r.instance == inst.label()
                        && r.config == cfg.label()
                        && r.algo == algo.name()
                })
                .unwrap();
            assert_eq!(row.makespan, s.makespan, "{}", algo.name());
            assert_eq!(row.lp_star, alloc_lp.sol.obj);
        }

        // determinism: a second driver run (cache warm) is identical
        let off2 = offline::run(2, &opts);
        assert_eq!(off.len(), off2.len());
        for (a, b) in off.iter().zip(&off2) {
            assert_eq!(a.instance, b.instance);
            assert_eq!(a.config, b.config);
            assert_eq!(a.algo, b.algo);
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.lp_star, b.lp_star);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
