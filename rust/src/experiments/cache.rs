//! LP* cache: solving the (Q)HLP relaxation is the expensive step of the
//! campaign (the paper: ~100 s for the biggest instance), and Figs. 3/4
//! and 6/7 share the same (instance, config) LPs — so solved relaxations
//! (objective + rounded allocation) are persisted as JSON.
//!
//! Since the batched warm-start driver landed, cache misses are solved
//! by [`crate::lp::batch`] with warm-start chaining across the config
//! grid.  The key stays exactly (instance, config, type count,
//! tolerance, iteration budget): a warm-started solve certifies the same
//! tolerance as a cold one (`rust/tests/lp_warm_batch.rs` pins LP*
//! agreement), so entries written by cold, warm or batched solves are
//! interchangeable and nothing about warm-starting may leak into the key.

use std::collections::BTreeMap;
use std::path::Path;

use crate::algos::AllocLp;
use crate::lp::LpSolution;
use crate::substrate::json::{parse, Json};

#[derive(Default)]
pub struct LpCache {
    entries: BTreeMap<String, (f64, f64, Vec<usize>)>, // obj, lower_bound, alloc
    dirty: bool,
}

impl LpCache {
    pub fn load(path: &Path) -> LpCache {
        let mut cache = LpCache::default();
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(Json::Obj(map)) = parse(&text) {
                for (k, v) in map {
                    let (Some(obj), Some(lb), Some(alloc)) = (
                        v.get("obj").and_then(Json::as_f64),
                        v.get("lb").and_then(Json::as_f64),
                        v.get("alloc").and_then(Json::as_arr),
                    ) else {
                        continue;
                    };
                    let alloc: Option<Vec<usize>> =
                        alloc.iter().map(|x| x.as_usize()).collect();
                    if let Some(alloc) = alloc {
                        cache.entries.insert(k, (obj, lb, alloc));
                    }
                }
            }
        }
        cache
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, key: &str) -> Option<AllocLp> {
        self.entries.get(key).map(|(obj, lb, alloc)| AllocLp {
            sol: LpSolution {
                z: Vec::new(),
                obj: *obj,
                lower_bound: *lb,
                gap: 0.0,
                iters: 0,
                backend: "cache",
            },
            alloc: alloc.clone(),
        })
    }

    pub fn put(&mut self, key: &str, value: &AllocLp) {
        self.entries.insert(
            key.to_string(),
            (value.sol.obj, value.sol.lower_bound, value.alloc.clone()),
        );
        self.dirty = true;
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if !self.dirty {
            return Ok(());
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let obj: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(k, (obj, lb, alloc))| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("obj", Json::Num(*obj)),
                        ("lb", Json::Num(*lb)),
                        (
                            "alloc",
                            Json::Arr(alloc.iter().map(|&a| Json::Num(a as f64)).collect()),
                        ),
                    ]),
                )
            })
            .collect();
        std::fs::write(path, Json::Obj(obj).to_string())
    }
}

/// Cache key for an (instance, platform, formulation, tolerance,
/// iteration budget) solve.  `max_iters` is part of the key: a capped
/// solve that stopped at its budget is *not* the same LP* as a longer
/// one, so caches keyed without it could serve under-converged solutions
/// across campaigns run at different budgets.
pub fn cache_key(
    instance: &str,
    config: &str,
    n_types: usize,
    tol: f64,
    max_iters: usize,
) -> String {
    format!("{instance}|{config}|q{n_types}|tol{tol:.0e}|it{max_iters}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AllocLp {
        AllocLp {
            sol: LpSolution {
                z: vec![],
                obj: 3.25,
                lower_bound: 3.2,
                gap: 0.0,
                iters: 10,
                backend: "test",
            },
            alloc: vec![0, 1, 1, 0],
        }
    }

    #[test]
    fn roundtrip_via_disk() {
        let dir = std::env::temp_dir().join(format!("hetsched-cache-{}", std::process::id()));
        let path = dir.join("cache.json");
        let mut c = LpCache::default();
        let key = cache_key("potrf-nb5-bs320", "16x2", 2, 1e-4, 80_000);
        assert!(c.get(&key).is_none());
        c.put(&key, &sample());
        c.save(&path).unwrap();
        let c2 = LpCache::load(&path);
        let got = c2.get(&key).unwrap();
        assert_eq!(got.sol.obj, 3.25);
        assert_eq!(got.alloc, vec![0, 1, 1, 0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_empty() {
        let c = LpCache::load(Path::new("/nonexistent/c.json"));
        assert!(c.is_empty());
    }

    #[test]
    fn keys_distinguish_dimensions() {
        assert_ne!(
            cache_key("a", "16x2", 2, 1e-4, 80_000),
            cache_key("a", "16x2", 3, 1e-4, 80_000)
        );
        assert_ne!(
            cache_key("a", "16x2", 2, 1e-4, 80_000),
            cache_key("a", "16x2", 2, 1e-3, 80_000)
        );
    }

    #[test]
    fn keys_distinguish_iteration_budget() {
        // regression (ROADMAP debt): campaigns run at different PDHG
        // budgets must not share LP* entries — a capped solve that hit
        // its budget is a different (possibly under-converged) solution
        assert_ne!(
            cache_key("a", "16x2", 2, 1e-4, 80_000),
            cache_key("a", "16x2", 2, 1e-4, 150_000)
        );
        assert_eq!(
            cache_key("a", "16x2", 2, 1e-4, 80_000),
            cache_key("a", "16x2", 2, 1e-4, 80_000)
        );
    }
}
