//! LP* cache: solving the (Q)HLP relaxation is the expensive step of the
//! campaign (the paper: ~100 s for the biggest instance), and Figs. 3/4
//! and 6/7 share the same (instance, config) LPs — so solved relaxations
//! (objective + rounded allocation) are persisted as JSON.
//!
//! Since the batched warm-start driver landed, cache misses are solved
//! by [`crate::lp::batch`] with warm-start chaining across the config
//! grid.  The key stays exactly (instance, config, type count,
//! tolerance, iteration budget): a warm-started solve certifies the same
//! tolerance as a cold one (`rust/tests/lp_warm_batch.rs` pins LP*
//! agreement), so entries written by cold, warm or batched solves are
//! interchangeable and nothing about warm-starting may leak into the key.
//!
//! The cache additionally persists **final PDHG iterates** (primal z +
//! dual y, in the contracted model's original coordinates) under a
//! separate `iter|…` keyspace ([`iterate_key`]): a later campaign run in
//! a *different process* — typically at a different tolerance or budget,
//! so its LP* keys all miss — warm-starts its chain heads from the
//! previous run's iterates instead of the greedy point.  Iterate entries
//! are advisory (a seed, never a solution): they are keyed without
//! tolerance/budget, bounded per entry by [`MAX_ITERATE_FLOATS`], and
//! their presence or absence never changes what an LP* lookup returns —
//! `cache_key` semantics are untouched.

use std::collections::BTreeMap;
use std::path::Path;

use crate::algos::AllocLp;
use crate::lp::LpSolution;
use crate::substrate::json::{parse, Json};

/// Upper bound on `z.len() + y.len()` for a persisted iterate entry
/// (~200k floats ≈ a 10k-task HLP; the 50k/100k-task instances skip
/// persistence rather than ballooning the cache file).
pub const MAX_ITERATE_FLOATS: usize = 200_000;

/// Prefix separating iterate entries from LP* entries in the JSON file.
const ITER_PREFIX: &str = "iter|";

#[derive(Default)]
pub struct LpCache {
    entries: BTreeMap<String, (f64, f64, Vec<usize>)>, // obj, lower_bound, alloc
    iterates: BTreeMap<String, (Vec<f64>, Vec<f64>)>,  // final (z, y)
    dirty: bool,
}

impl LpCache {
    pub fn load(path: &Path) -> LpCache {
        let mut cache = LpCache::default();
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(Json::Obj(map)) = parse(&text) {
                for (k, v) in map {
                    if k.starts_with(ITER_PREFIX) {
                        let (Some(z), Some(y)) = (
                            v.get("z").and_then(Json::as_arr).and_then(floats),
                            v.get("y").and_then(Json::as_arr).and_then(floats),
                        ) else {
                            continue;
                        };
                        cache.iterates.insert(k, (z, y));
                        continue;
                    }
                    let (Some(obj), Some(lb), Some(alloc)) = (
                        v.get("obj").and_then(Json::as_f64),
                        v.get("lb").and_then(Json::as_f64),
                        v.get("alloc").and_then(Json::as_arr),
                    ) else {
                        continue;
                    };
                    let alloc: Option<Vec<usize>> =
                        alloc.iter().map(|x| x.as_usize()).collect();
                    if let Some(alloc) = alloc {
                        cache.entries.insert(k, (obj, lb, alloc));
                    }
                }
            }
        }
        cache
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, key: &str) -> Option<AllocLp> {
        self.entries.get(key).map(|(obj, lb, alloc)| AllocLp {
            sol: LpSolution {
                z: Vec::new(),
                obj: *obj,
                lower_bound: *lb,
                gap: 0.0,
                iters: 0,
                backend: "cache",
            },
            alloc: alloc.clone(),
        })
    }

    pub fn put(&mut self, key: &str, value: &AllocLp) {
        self.entries.insert(
            key.to_string(),
            (value.sol.obj, value.sol.lower_bound, value.alloc.clone()),
        );
        self.dirty = true;
    }

    /// Persisted final iterates for a cross-run warm start, if a
    /// previous run stored them (and they fit the size bound).
    pub fn get_iterates(&self, key: &str) -> Option<(Vec<f64>, Vec<f64>)> {
        self.iterates.get(key).cloned()
    }

    /// Store final iterates; entries beyond [`MAX_ITERATE_FLOATS`] are
    /// silently skipped (a bound on cache-file growth, not an error —
    /// oversized instances just cold-start next run).
    pub fn put_iterates(&mut self, key: &str, z: &[f64], y: &[f64]) {
        if z.len() + y.len() > MAX_ITERATE_FLOATS {
            return;
        }
        self.iterates
            .insert(key.to_string(), (z.to_vec(), y.to_vec()));
        self.dirty = true;
    }

    pub fn n_iterate_entries(&self) -> usize {
        self.iterates.len()
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if !self.dirty {
            return Ok(());
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut obj: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(k, (obj, lb, alloc))| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("obj", Json::Num(*obj)),
                        ("lb", Json::Num(*lb)),
                        (
                            "alloc",
                            Json::Arr(alloc.iter().map(|&a| Json::Num(a as f64)).collect()),
                        ),
                    ]),
                )
            })
            .collect();
        for (k, (z, y)) in &self.iterates {
            obj.insert(
                k.clone(),
                Json::obj(vec![
                    ("z", Json::Arr(z.iter().map(|&v| Json::Num(v)).collect())),
                    ("y", Json::Arr(y.iter().map(|&v| Json::Num(v)).collect())),
                ]),
            );
        }
        std::fs::write(path, Json::Obj(obj).to_string())
    }
}

fn floats(arr: &[Json]) -> Option<Vec<f64>> {
    arr.iter().map(Json::as_f64).collect()
}

/// Cache key for an (instance, platform, formulation, tolerance,
/// iteration budget) solve.  `max_iters` is part of the key: a capped
/// solve that stopped at its budget is *not* the same LP* as a longer
/// one, so caches keyed without it could serve under-converged solutions
/// across campaigns run at different budgets.
pub fn cache_key(
    instance: &str,
    config: &str,
    n_types: usize,
    tol: f64,
    max_iters: usize,
) -> String {
    format!("{instance}|{config}|q{n_types}|tol{tol:.0e}|it{max_iters}")
}

/// Key for a persisted-iterate entry.  Deliberately *without* tolerance
/// or budget: iterates are a warm-start seed, useful across any solve of
/// the same (instance, config, formulation) — the solve itself still
/// certifies whatever tolerance its caller asked for.
pub fn iterate_key(instance: &str, config: &str, n_types: usize) -> String {
    format!("{ITER_PREFIX}{instance}|{config}|q{n_types}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AllocLp {
        AllocLp {
            sol: LpSolution {
                z: vec![],
                obj: 3.25,
                lower_bound: 3.2,
                gap: 0.0,
                iters: 10,
                backend: "test",
            },
            alloc: vec![0, 1, 1, 0],
        }
    }

    #[test]
    fn roundtrip_via_disk() {
        let dir = std::env::temp_dir().join(format!("hetsched-cache-{}", std::process::id()));
        let path = dir.join("cache.json");
        let mut c = LpCache::default();
        let key = cache_key("potrf-nb5-bs320", "16x2", 2, 1e-4, 80_000);
        assert!(c.get(&key).is_none());
        c.put(&key, &sample());
        c.save(&path).unwrap();
        let c2 = LpCache::load(&path);
        let got = c2.get(&key).unwrap();
        assert_eq!(got.sol.obj, 3.25);
        assert_eq!(got.alloc, vec![0, 1, 1, 0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_empty() {
        let c = LpCache::load(Path::new("/nonexistent/c.json"));
        assert!(c.is_empty());
    }

    #[test]
    fn keys_distinguish_dimensions() {
        assert_ne!(
            cache_key("a", "16x2", 2, 1e-4, 80_000),
            cache_key("a", "16x2", 3, 1e-4, 80_000)
        );
        assert_ne!(
            cache_key("a", "16x2", 2, 1e-4, 80_000),
            cache_key("a", "16x2", 2, 1e-3, 80_000)
        );
    }

    #[test]
    fn keys_distinguish_iteration_budget() {
        // regression (ROADMAP debt): campaigns run at different PDHG
        // budgets must not share LP* entries — a capped solve that hit
        // its budget is a different (possibly under-converged) solution
        assert_ne!(
            cache_key("a", "16x2", 2, 1e-4, 80_000),
            cache_key("a", "16x2", 2, 1e-4, 150_000)
        );
        assert_eq!(
            cache_key("a", "16x2", 2, 1e-4, 80_000),
            cache_key("a", "16x2", 2, 1e-4, 80_000)
        );
    }

    #[test]
    fn iterates_roundtrip_and_leave_lp_star_alone() {
        let dir = std::env::temp_dir()
            .join(format!("hetsched-cache-it-{}", std::process::id()));
        let path = dir.join("cache.json");
        let mut c = LpCache::default();
        let lk = cache_key("potrf-nb5-bs320", "16x2", 2, 1e-4, 80_000);
        let ik = iterate_key("potrf-nb5-bs320", "16x2", 2);
        c.put(&lk, &sample());
        c.put_iterates(&ik, &[0.5, 1.25, -3.0e-7], &[2.0, 0.0]);
        c.save(&path).unwrap();

        let c2 = LpCache::load(&path);
        // LP* lookups are untouched by the iterate keyspace
        assert_eq!(c2.len(), 1);
        assert_eq!(c2.get(&lk).unwrap().sol.obj, 3.25);
        assert!(c2.get(&ik).is_none(), "iterate keys never serve LP*");
        // iterates round-trip losslessly (shortest-repr float printing)
        let (z, y) = c2.get_iterates(&ik).unwrap();
        assert_eq!(z, vec![0.5, 1.25, -3.0e-7]);
        assert_eq!(y, vec![2.0, 0.0]);
        assert!(c2.get_iterates(&lk).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_iterates_are_not_persisted() {
        let mut c = LpCache::default();
        let big = vec![1.0; MAX_ITERATE_FLOATS];
        c.put_iterates("iter|big|16x2|q2", &big, &[1.0]);
        assert_eq!(c.n_iterate_entries(), 0, "beyond the size bound");
        c.put_iterates("iter|ok|16x2|q2", &[1.0; 10], &[1.0; 5]);
        assert_eq!(c.n_iterate_entries(), 1);
    }

    #[test]
    fn iterate_keys_ignore_tolerance_and_budget() {
        // the whole point of the iterate keyspace: a run at a new
        // tolerance/budget (whose LP* keys all miss) still finds seeds
        assert_eq!(iterate_key("a", "16x2", 2), iterate_key("a", "16x2", 2));
        assert!(iterate_key("a", "16x2", 2).starts_with("iter|"));
        assert_ne!(iterate_key("a", "16x2", 2), iterate_key("a", "16x2", 3));
        assert_ne!(iterate_key("a", "16x2", 2), iterate_key("a", "32x2", 2));
    }
}
