//! Batched multi-LP PDHG driver: solve many (Q)HLP instances over one
//! shared worker pool, with per-LP state structs instead of per-LP
//! thread spawns, and warm-start chaining across the campaign grid.
//!
//! A campaign's allocation phase is hundreds of independent LPs whose
//! solve times differ by orders of magnitude.  Parking one pool thread
//! per LP (the old `parallel_map` scheme) serializes stragglers behind
//! whatever shard they landed in; here every solve is a [`PdhgState`]
//! advanced a few chunks at a time through a shared [`WorkQueue`], so
//! the pool drains breadth-first and a straggler only ever occupies one
//! worker-quantum at a time.  Jobs may declare a `seed_from` dependency:
//! the job starts once its seed finishes and warm-starts primal *and*
//! dual from the seed's final iterates ([`PdhgState::iterates`]), with
//! the escalating [`BudgetSchedule`] bounding expected work.
//!
//! # Complexity
//!
//! With J jobs, worker count W, and per-LP dimensions (n vars, m rows,
//! nnz nonzeros):
//!
//! | phase                  | cost                                        |
//! |------------------------|---------------------------------------------|
//! | state construction     | O(ruiz · nnz) once per job (lazy, admitted) |
//! | one scheduling quantum | O(chunk · nnz) = 1000 PDHG iters            |
//! | queue traffic          | O(1) push/pop per quantum                   |
//! | memory                 | O(nnz + n + m) per *admitted* job's solver  |
//! |                        | state, at most `2W + 4` resident at once;   |
//! |                        | every job's input `SparseLp` stays resident |
//! |                        | for the batch's lifetime, so callers bound  |
//! |                        | the batch size (the campaign driver slices  |
//! |                        | its miss list at instance boundaries); seed |
//! |                        | iterates are freed at their last consumer   |
//! | determinism            | per-LP trajectories are scheduling-         |
//! |                        | independent: results are bit-identical to   |
//! |                        | running each state's step loop alone        |
//!
//! Dependency chains (`seed_from`) are restricted to earlier job
//! indices, so the dependency graph is acyclic by construction and a
//! finished seed always precedes its dependents in the queue.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::obs::{EventKind, Sink};
use crate::substrate::pool::WorkQueue;

use super::pdhg::{DriveOpts, PdhgState, RustChunk, StopReason};
use super::warm::BudgetSchedule;
use super::{LpSolution, SparseLp};

/// One LP in a batch.
pub struct BatchJob {
    pub lp: SparseLp,
    /// Solve options; `opts.max_iters` is the *cap* of the budget
    /// schedule.  `opts.warm_start`/`warm_start_dual` are used as given
    /// unless `seed_from` overrides them.
    pub opts: DriveOpts,
    /// Warm-start from the final iterates of an earlier job in this
    /// batch (must hold `seed_from < index`); the job is held back until
    /// the seed completes.
    pub seed_from: Option<usize>,
    /// Seed is a close grid neighbor: grant a shrunken first allotment
    /// (escalating back up to `opts.max_iters` if it fails to converge).
    pub warm_close: bool,
    /// Return this job's final (z, y) iterates from
    /// [`solve_batch_full`] — the campaign driver persists them for
    /// cross-run warm starts.  Off by default: iterates of unmarked
    /// jobs are freed as soon as their last dependent consumes them.
    pub keep_iterates: bool,
}

impl BatchJob {
    /// A plain cold job.
    pub fn cold(lp: SparseLp, opts: DriveOpts) -> BatchJob {
        BatchJob {
            lp,
            opts,
            seed_from: None,
            warm_close: false,
            keep_iterates: false,
        }
    }
}

/// Chunks each job advances per queue pop: enough to amortize the queue
/// round-trip, small enough to keep the pool breadth-first.
const CHUNKS_PER_QUANTUM: usize = 4;

struct Slot {
    job: BatchJob,
    state: Option<PdhgState<RustChunk>>,
    schedule: BudgetSchedule,
    /// final iterates (original coordinates), kept only until the last
    /// dependent has consumed them
    iterates: Option<(Vec<f64>, Vec<f64>)>,
    /// dependents that still need `iterates`
    seed_consumers: usize,
    done: Option<LpSolution>,
    /// why the solve stopped (for post-join trace emission)
    stopped_for: Option<StopReason>,
}

/// Closes the queue if a worker panics, so its siblings blocked in
/// `pop()` drain out and the panic can propagate through the scope.
struct CloseOnPanic<'a>(&'a WorkQueue<usize>);

impl Drop for CloseOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.close();
        }
    }
}

/// Solve every job, sharing `workers` OS threads across all of them;
/// results keep job order.  Deterministic: each LP's trajectory depends
/// only on its own options and (for seeded jobs) its seed's final
/// iterates, never on worker interleaving.
pub fn solve_batch(jobs: Vec<BatchJob>, workers: usize) -> Vec<LpSolution> {
    solve_batch_full(jobs, workers)
        .into_iter()
        .map(|(sol, _)| sol)
        .collect()
}

/// [`solve_batch`], additionally returning the final (z, y) iterates —
/// in *original* (pre-scaling) coordinates — of every job that set
/// [`BatchJob::keep_iterates`] (`None` for the rest).
pub fn solve_batch_full(
    jobs: Vec<BatchJob>,
    workers: usize,
) -> Vec<(LpSolution, Option<(Vec<f64>, Vec<f64>)>)> {
    solve_batch_inner(jobs, workers)
        .into_iter()
        .map(|(sol, kept, _)| (sol, kept))
        .collect()
}

/// [`solve_batch`] with an event sink.  Worker interleaving is
/// nondeterministic, so no per-chunk events cross the pool; instead one
/// `lp-done` span per job (iteration count, stop reason) is emitted
/// *after* the join, in job-index order — the same events on every run
/// because per-LP trajectories are scheduling-independent.  Virtual
/// time is the job's own iteration count; no wall clock is read.
pub fn solve_batch_traced(
    jobs: Vec<BatchJob>,
    workers: usize,
    sink: &mut dyn Sink,
) -> Vec<LpSolution> {
    let full = solve_batch_inner(jobs, workers);
    let mut sols = Vec::with_capacity(full.len());
    for (i, (sol, _, stop)) in full.into_iter().enumerate() {
        if sink.enabled() {
            sink.emit(
                sol.iters as f64,
                EventKind::LpDone {
                    lp: i,
                    iters: sol.iters as u64,
                    stop: stop.label(),
                },
            );
        }
        sols.push(sol);
    }
    sols
}

fn solve_batch_inner(
    jobs: Vec<BatchJob>,
    workers: usize,
) -> Vec<(LpSolution, Option<(Vec<f64>, Vec<f64>)>, StopReason)> {
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut roots: Vec<usize> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        match job.seed_from {
            Some(s) => {
                assert!(s < i, "seed_from must reference an earlier job ({s} >= {i})");
                dependents[s].push(i);
            }
            None => roots.push(i),
        }
    }
    let slots: Vec<Mutex<Slot>> = jobs
        .into_iter()
        .enumerate()
        .map(|(i, job)| {
            let cap = job.opts.max_iters;
            let schedule = if job.warm_close {
                BudgetSchedule::warm(cap)
            } else {
                BudgetSchedule::cold(cap)
            };
            Mutex::new(Slot {
                job,
                state: None,
                schedule,
                iterates: None,
                seed_consumers: dependents[i].len(),
                done: None,
                stopped_for: None,
            })
        })
        .collect();

    let workers = workers.max(1).min(n);
    let queue = WorkQueue::new();
    for i in roots {
        queue.push(i);
    }
    let remaining = AtomicUsize::new(n);
    // cap on simultaneously materialized states (CSR + scratch is the
    // dominant memory): beyond it, fresh jobs defer in the queue
    let admit_cap = 2 * workers + 4;
    let admitted = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let _guard = CloseOnPanic(&queue);
                while let Some(i) = queue.pop() {
                    let mut guard = slots[i].lock().unwrap();
                    let slot = &mut *guard;
                    if slot.state.is_none() {
                        // admission: don't materialize more states than
                        // the pool can actively advance (atomic reserve —
                        // a plain load+add could overshoot the cap when
                        // several workers admit at once)
                        let reserved = admitted
                            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                                (v < admit_cap).then_some(v + 1)
                            })
                            .is_ok();
                        if !reserved {
                            drop(guard);
                            queue.push(i);
                            std::thread::yield_now();
                            continue;
                        }
                        let mut opts = slot.job.opts.clone();
                        if let Some(s) = slot.job.seed_from {
                            // lock order is safe: a worker only ever
                            // holds slot i and then its seed s < i, and
                            // seeds are done (never re-queued)
                            let mut seed = slots[s].lock().unwrap();
                            let (z, y) = seed
                                .iterates
                                .clone()
                                .expect("seed finished before dependents are queued");
                            seed.seed_consumers -= 1;
                            if seed.seed_consumers == 0 && !seed.job.keep_iterates {
                                seed.iterates = None; // last consumer
                            }
                            opts.warm_start = Some(z);
                            opts.warm_start_dual = Some(y);
                        }
                        opts.max_iters = slot.schedule.granted();
                        slot.state = Some(PdhgState::new(&slot.job.lp, &opts, |scaled| {
                            RustChunk::new(scaled, 250)
                        }));
                    }

                    let state = slot.state.as_mut().unwrap();
                    let mut stopped = false;
                    for _ in 0..CHUNKS_PER_QUANTUM {
                        if state.step() {
                            stopped = true;
                            break;
                        }
                    }
                    if stopped
                        && state.stop_reason() == Some(StopReason::Budget)
                        && slot.schedule.escalate()
                    {
                        state.extend_budget(slot.schedule.granted());
                        stopped = false;
                    }
                    if stopped {
                        let state = slot.state.take().unwrap();
                        slot.stopped_for = state.stop_reason();
                        // materialize final iterates only for consumers:
                        // dependents still to seed, or a caller keep flag
                        if slot.seed_consumers > 0 || slot.job.keep_iterates {
                            slot.iterates = Some(state.iterates());
                        }
                        slot.done = Some(state.into_solution(&slot.job.lp));
                        drop(guard);
                        admitted.fetch_sub(1, Ordering::SeqCst);
                        for &d in &dependents[i] {
                            queue.push(d);
                        }
                        if remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
                            queue.close();
                        }
                    } else {
                        drop(guard);
                        queue.push(i);
                    }
                }
            });
        }
    });

    slots
        .into_iter()
        .map(|s| {
            let slot = s.into_inner().unwrap();
            let sol = slot.done.expect("batch drained with unfinished job");
            let stop = slot.stopped_for.expect("finished job has a stop reason");
            let kept = if slot.job.keep_iterates {
                slot.iterates
            } else {
                None
            };
            (sol, kept, stop)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::pdhg::solve_rust;

    fn knapsack(b: f64) -> SparseLp {
        // min -x1-x2 : x1+x2 <= b, x in [0,1]^2  ->  -min(b, 2)
        let mut lp = SparseLp {
            n: 2,
            m: 1,
            b: vec![b],
            c: vec![-1.0, -1.0],
            lo: vec![0.0; 2],
            hi: vec![1.0; 2],
            ..Default::default()
        };
        lp.push(0, 0, 1.0);
        lp.push(0, 1, 1.0);
        lp
    }

    #[test]
    fn batch_matches_individual_drives_exactly() {
        // independent jobs through the pool must reproduce drive()
        // bit-for-bit (scheduling cannot touch a state's trajectory)
        let bs = [0.5, 0.9, 1.3, 1.7];
        let jobs: Vec<BatchJob> = bs
            .iter()
            .map(|&b| BatchJob::cold(knapsack(b), DriveOpts::default()))
            .collect();
        let sols = solve_batch(jobs, 3);
        assert_eq!(sols.len(), bs.len());
        for (&b, sol) in bs.iter().zip(&sols) {
            let alone = solve_rust(&knapsack(b), &DriveOpts::default());
            assert_eq!(sol.obj, alone.obj, "b={b}");
            assert_eq!(sol.iters, alone.iters, "b={b}");
            assert_eq!(sol.z, alone.z, "b={b}");
        }
    }

    #[test]
    fn seeded_job_waits_for_its_seed_and_converges() {
        // job 1 warm-starts from job 0's optimum of a nearby LP
        let jobs = vec![
            BatchJob::cold(knapsack(1.5), DriveOpts::default()),
            BatchJob {
                lp: knapsack(1.4),
                opts: DriveOpts::default(),
                seed_from: Some(0),
                warm_close: true,
                keep_iterates: false,
            },
        ];
        let sols = solve_batch(jobs, 2);
        assert!((sols[0].obj + 1.5).abs() < 2e-3, "obj {}", sols[0].obj);
        assert!((sols[1].obj + 1.4).abs() < 2e-3, "obj {}", sols[1].obj);
        // the warm-started neighbor should need no more iterations than a
        // cold solve of the same LP (one-chunk slack: a seed from a
        // *different* LP's optimum is helpful, not guaranteed-optimal)
        let cold = solve_rust(&knapsack(1.4), &DriveOpts::default());
        assert!(
            sols[1].iters <= cold.iters + 250,
            "warm {} way beyond cold {}",
            sols[1].iters,
            cold.iters
        );
    }

    #[test]
    fn warm_close_budget_still_reaches_cold_quality() {
        // a deliberately terrible seed with a shrunken first allotment:
        // escalation must carry the solve to the same tolerance anyway
        let lp = knapsack(1.5);
        let bad_seed = BatchJob::cold(knapsack(0.1), DriveOpts::default());
        let jobs = vec![
            bad_seed,
            BatchJob {
                lp: lp.clone(),
                opts: DriveOpts::default(),
                seed_from: Some(0),
                warm_close: true,
                keep_iterates: false,
            },
        ];
        let sols = solve_batch(jobs, 2);
        let cold = solve_rust(&lp, &DriveOpts::default());
        let scale = 1.0 + cold.obj.abs();
        assert!(
            (sols[1].obj - cold.obj).abs() < 5e-3 * scale,
            "warm {} vs cold {}",
            sols[1].obj,
            cold.obj
        );
    }

    #[test]
    fn keep_iterates_returns_final_points() {
        // marked jobs hand back their final (z, y); unmarked jobs don't,
        // and a kept seed still feeds its dependents
        let jobs = vec![
            BatchJob {
                keep_iterates: true,
                ..BatchJob::cold(knapsack(1.5), DriveOpts::default())
            },
            BatchJob {
                lp: knapsack(1.4),
                opts: DriveOpts::default(),
                seed_from: Some(0),
                warm_close: true,
                keep_iterates: false,
            },
        ];
        let full = solve_batch_full(jobs, 2);
        let (z, y) = full[0].1.as_ref().expect("kept iterates");
        assert_eq!(z.len(), 2);
        assert_eq!(y.len(), 1);
        // the kept primal is the solution's primal (original coordinates)
        assert_eq!(z, &full[0].0.z);
        assert!(full[1].1.is_none(), "unmarked job keeps nothing");
        assert!((full[1].0.obj + 1.4).abs() < 2e-3);
        // a restarted solve seeded from the kept iterates converges
        // immediately-ish (certificate in the first chunks)
        let warm = solve_rust(
            &knapsack(1.5),
            &DriveOpts {
                warm_start: Some(z.clone()),
                warm_start_dual: Some(y.clone()),
                ..Default::default()
            },
        );
        assert!((warm.obj + 1.5).abs() < 2e-3);
        assert!(warm.iters <= full[0].0.iters + 250);
    }

    #[test]
    fn traced_batch_matches_untraced_and_orders_done_spans() {
        use crate::obs::{EventKind, RecordingSink};
        let bs = [0.5, 0.9, 1.3, 1.7];
        let mk_jobs = || -> Vec<BatchJob> {
            bs.iter()
                .map(|&b| BatchJob::cold(knapsack(b), DriveOpts::default()))
                .collect()
        };
        let plain = solve_batch(mk_jobs(), 3);
        let mut sink = RecordingSink::new();
        let traced = solve_batch_traced(mk_jobs(), 3, &mut sink);
        assert_eq!(plain.len(), traced.len());
        for (a, b) in plain.iter().zip(&traced) {
            assert_eq!(a.obj, b.obj);
            assert_eq!(a.iters, b.iters);
            assert_eq!(a.z, b.z);
        }
        // one lp-done span per job, in job-index order, despite the
        // nondeterministic worker interleaving inside the pool
        let events = sink.take();
        assert_eq!(events.len(), bs.len());
        for (i, (e, sol)) in events.iter().zip(&traced).enumerate() {
            match &e.kind {
                EventKind::LpDone { lp, iters, stop } => {
                    assert_eq!(*lp, i, "done spans must keep job order");
                    assert_eq!(*iters as usize, sol.iters);
                    assert_eq!(*stop, "converged");
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
    }

    #[test]
    fn single_worker_and_empty_batch() {
        assert!(solve_batch(Vec::new(), 4).is_empty());
        let sols = solve_batch(
            vec![BatchJob::cold(knapsack(1.5), DriveOpts::default())],
            1,
        );
        assert!((sols[0].obj + 1.5).abs() < 2e-3);
    }

    #[test]
    #[should_panic(expected = "seed_from must reference an earlier job")]
    fn forward_seed_rejected() {
        let jobs = vec![BatchJob {
            lp: knapsack(1.5),
            opts: DriveOpts::default(),
            seed_from: Some(0), // self-reference: 0 >= 0
            warm_close: false,
            keep_iterates: false,
        }];
        solve_batch(jobs, 1);
    }
}
