//! Series-chain contraction for the (Q)HLP arc rows.
//!
//! Linear-algebra DAGs and fork-join graphs are full of *series chains*:
//! paths v₀ → v₁ → … → v_k whose interior vertices have in-degree 1 and
//! out-degree 1.  Each chain arc contributes one precedence row to the
//! LP ((1) for HLP, (9) for QHLP), but the interior completion variables
//! C_{v₁}, …, C_{v_{k-1}} appear in *only* those two adjacent rows — so
//! summing a chain's k rows telescopes them away and leaves one
//! aggregate row
//!
//!   C_{v₀} + Σ_{i=1..k} [p̄_{v_i} x_{v_i} + p̠_{v_i}(1 − x_{v_i})] ≤ C_{v_k}
//!
//! (QHLP analogously with Σ_q p_{v_i,q} x_{v_i,q}).
//!
//! # Equivalence for the fractional relaxation
//!
//! * Any point feasible for the k original rows satisfies their sum.
//! * Conversely, given a point satisfying the aggregate row, setting
//!   C_{v_i} := C_{v₀} + Σ_{j≤i} (chain increments) satisfies every
//!   original row with equality; the interior values stay inside their
//!   box because each increment is positive (processing times are > 0
//!   and x ∈ [0,1], Σ_q x = 1) so C_{v₀} ≤ C_{v_i} ≤ C_{v_k} ≤ hi.
//!   Interior vertices are never sources (in-degree 1) nor sinks
//!   (out-degree 1), so with sink-only cap rows no other row mentions
//!   their C; with `CapRows::All` their cap row `C ≤ λ` is satisfiable
//!   by the same construction (C_{v_i} ≤ C_{v_k} ≤ λ).
//!
//! Hence the (x, λ) projection of the feasible set — all that rounding
//! and the objective see — is unchanged, while the model loses one row
//! per interior vertex.  Fewer rows means a smaller operator norm and a
//! cheaper matvec, both of which PDHG pays for on every iteration.
//! Equivalence is pinned against the exact simplex oracle in tests and
//! in `rust/tests/lp_warm_batch.rs`.
//!
//! Implementation note: the aggregate row is *literally the sum* of the
//! chain's arc rows, so contraction is a generic row-merge transform on
//! the built COO ([`contract`]) driven by a graph-side plan
//! ([`plan_chains`]).  It therefore applies unchanged to HLP and QHLP,
//! whose builders both emit one row per arc, in the same (task-major)
//! arc order, as rows `0..n_arcs`.

use crate::graph::TaskGraph;

use super::SparseLp;

/// Maximal series chains of a task graph, as groups of arc indices in
/// the LP builders' arc emission order (arc i is row i of a built
/// (Q)HLP).  Every group has ≥ 2 arcs; arcs outside any group are left
/// untouched by [`contract`].
#[derive(Clone, Debug, Default)]
pub struct ChainPlan {
    pub groups: Vec<Vec<usize>>,
    pub n_arcs: usize,
}

impl ChainPlan {
    /// Rows removed by contracting this plan.
    pub fn rows_dropped(&self) -> usize {
        self.groups.iter().map(|g| g.len() - 1).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }
}

/// Find the maximal series chains of `g`.  O(n + |E|).
pub fn plan_chains(g: &TaskGraph) -> ChainPlan {
    let n = g.n_tasks();
    // arc index = position in the builders' (i, succs[i]) emission order
    let mut arc_base = vec![0usize; n + 1];
    for j in 0..n {
        arc_base[j + 1] = arc_base[j] + g.succs[j].len();
    }
    let interior: Vec<bool> = (0..n)
        .map(|j| g.preds[j].len() == 1 && g.succs[j].len() == 1)
        .collect();
    let mut groups = Vec::new();
    for u in 0..n {
        if interior[u] {
            continue; // mid-chain: collected from the chain's start
        }
        for (pos, &v) in g.succs[u].iter().enumerate() {
            if !interior[v] {
                continue;
            }
            // maximal chain u -> v -> ... through interior vertices;
            // the start arc's source is never interior, and a DAG has
            // no interior cycles, so every chain is found exactly once
            let mut group = vec![arc_base[u] + pos];
            let mut w = v;
            while interior[w] {
                group.push(arc_base[w]); // out-degree 1: its only arc
                w = g.succs[w][0];
            }
            groups.push(group);
        }
    }
    ChainPlan {
        groups,
        n_arcs: arc_base[n],
    }
}

/// Merge each planned chain's arc rows (rows `0..plan.n_arcs` of `lp`)
/// into their sum; all other rows are kept verbatim.  Row order is
/// preserved, with each aggregate row sitting where the chain's first
/// arc row was.  The variable space is untouched: interior completion
/// columns simply end up unreferenced (their ±1 coefficients cancel
/// exactly), so warm starts, rounding and variable indices all carry
/// over unchanged.
pub fn contract(lp: &SparseLp, plan: &ChainPlan) -> SparseLp {
    if plan.groups.is_empty() {
        return lp.clone();
    }
    assert!(plan.n_arcs <= lp.m, "plan does not match LP");
    let mut group_of_row = vec![usize::MAX; lp.m];
    for (gi, grp) in plan.groups.iter().enumerate() {
        for &a in grp {
            assert!(a < plan.n_arcs, "chain arc {a} beyond arc rows");
            assert!(group_of_row[a] == usize::MAX, "arc {a} in two chains");
            group_of_row[a] = gi;
        }
    }
    // new row index per old row; a group collapses onto its first row
    let mut new_index = vec![usize::MAX; lp.m];
    let mut group_new = vec![usize::MAX; plan.groups.len()];
    let mut nm = 0usize;
    for r in 0..lp.m {
        let gi = group_of_row[r];
        if gi == usize::MAX {
            new_index[r] = nm;
            nm += 1;
        } else if group_new[gi] == usize::MAX {
            group_new[gi] = nm;
            new_index[r] = nm;
            nm += 1;
        } else {
            new_index[r] = group_new[gi];
        }
    }
    let mut b = vec![0.0f64; nm];
    for r in 0..lp.m {
        b[new_index[r]] += lp.b[r];
    }
    let mut out = SparseLp {
        n: lp.n,
        m: nm,
        b,
        c: lp.c.clone(),
        lo: lp.lo.clone(),
        hi: lp.hi.clone(),
        ..Default::default()
    };
    // merged rows accumulate coefficients per column (the interior C
    // columns get +1 and -1, cancelling to an exact 0.0 that push drops)
    let mut acc: Vec<std::collections::BTreeMap<u32, f64>> =
        vec![Default::default(); plan.groups.len()];
    for i in 0..lp.vals.len() {
        let r = lp.rows[i] as usize;
        let gi = group_of_row[r];
        if gi == usize::MAX {
            out.push(new_index[r], lp.cols[i] as usize, lp.vals[i]);
        } else {
            *acc[gi].entry(lp.cols[i]).or_insert(0.0) += lp.vals[i];
        }
    }
    for (gi, cols) in acc.iter().enumerate() {
        for (&col, &val) in cols {
            out.push(group_new[gi], col as usize, val);
        }
    }
    debug_assert!(out.validate().is_ok());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, Builder};
    use crate::lp::model::{build_hlp, build_qhlp};
    use crate::lp::simplex::solve_simplex;
    use crate::platform::Platform;
    use crate::substrate::rng::Rng;

    /// a -> b -> c -> d plus a side arc a -> d: one 3-arc chain
    /// (b, c interior), the side arc untouched.
    fn chainy() -> TaskGraph {
        let mut bl = Builder::new("chainy");
        let a = bl.add_task("a", vec![3.0, 1.0]);
        let b = bl.add_task("b", vec![2.0, 4.0]);
        let c = bl.add_task("c", vec![5.0, 2.0]);
        let d = bl.add_task("d", vec![1.0, 1.0]);
        bl.add_arc(a, b);
        bl.add_arc(b, c);
        bl.add_arc(c, d);
        bl.add_arc(a, d);
        bl.build()
    }

    #[test]
    fn plan_finds_maximal_chain() {
        let g = chainy();
        let plan = plan_chains(&g);
        assert_eq!(plan.n_arcs, 4);
        assert_eq!(plan.groups.len(), 1);
        // arc order: (a,b)=0, (a,d)=1, (b,c)=2, (c,d)=3
        assert_eq!(plan.groups[0], vec![0, 2, 3]);
        assert_eq!(plan.rows_dropped(), 2);
    }

    #[test]
    fn pure_chain_contracts_to_one_row() {
        let mut bl = Builder::new("path");
        let mut prev = bl.add_task("t", vec![1.0, 2.0]);
        for _ in 0..5 {
            let t = bl.add_task("t", vec![1.0, 2.0]);
            bl.add_arc(prev, t);
            prev = t;
        }
        let g = bl.build();
        let plan = plan_chains(&g);
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.groups[0].len(), 5);
        let (lp, _) = build_hlp(&g, &Platform::hybrid(2, 1));
        let slim = contract(&lp, &plan);
        assert_eq!(slim.m, lp.m - 4);
        assert_eq!(slim.n, lp.n);
    }

    #[test]
    fn no_chains_is_identity() {
        // diamond: every interior vertex has 2 preds or 2 succs
        let mut bl = Builder::new("diamond");
        let a = bl.add_task("a", vec![1.0, 1.0]);
        let b = bl.add_task("b", vec![1.0, 1.0]);
        let c = bl.add_task("c", vec![1.0, 1.0]);
        let d = bl.add_task("d", vec![1.0, 1.0]);
        bl.add_arc(a, b);
        bl.add_arc(a, c);
        bl.add_arc(b, d);
        bl.add_arc(c, d);
        let g = bl.build();
        let plan = plan_chains(&g);
        assert!(plan.is_empty());
        let (lp, _) = build_hlp(&g, &Platform::hybrid(2, 1));
        let same = contract(&lp, &plan);
        assert_eq!(same.m, lp.m);
        assert_eq!(same.nnz(), lp.nnz());
    }

    #[test]
    fn contracted_hlp_same_optimum_as_full() {
        let mut rng = Rng::new(0xC0A1);
        for case in 0..10 {
            let g = gen::hybrid_dag(&mut rng, 14, 0.18);
            let plan = plan_chains(&g);
            let plat = Platform::hybrid(3, 2);
            let (full, _) = build_hlp(&g, &plat);
            let slim = contract(&full, &plan);
            assert_eq!(slim.m, full.m - plan.rows_dropped());
            let a = solve_simplex(&full).unwrap().obj;
            let b = solve_simplex(&slim).unwrap().obj;
            assert!(
                (a - b).abs() < 1e-7 * (1.0 + a.abs()),
                "case {case}: {a} vs {b} ({} chains)",
                plan.groups.len()
            );
        }
    }

    #[test]
    fn contracted_qhlp_same_optimum_as_full() {
        let mut rng = Rng::new(0xC0A2);
        for _ in 0..6 {
            let g = gen::random_dag(&mut rng, 10, 0.2, 3);
            let plan = plan_chains(&g);
            let plat = Platform::new(vec![2, 2, 1]);
            let (full, _) = build_qhlp(&g, &plat);
            let slim = contract(&full, &plan);
            let a = solve_simplex(&full).unwrap().obj;
            let b = solve_simplex(&slim).unwrap().obj;
            assert!((a - b).abs() < 1e-7 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn contraction_on_chainy_graph_explicit() {
        let g = chainy();
        let plat = Platform::hybrid(2, 1);
        let plan = plan_chains(&g);
        let (full, _) = build_hlp(&g, &plat);
        let slim = contract(&full, &plan);
        assert_eq!(slim.m, full.m - 2);
        let a = solve_simplex(&full).unwrap().obj;
        let b = solve_simplex(&slim).unwrap().obj;
        assert!((a - b).abs() < 1e-9 * (1.0 + a.abs()));
    }
}
