//! Restarted PDHG (the PDLP scheme) for the box LP — the same algorithm
//! that is AOT-compiled from JAX/Pallas (python/compile/model.py).
//!
//! Split in two pieces:
//! * [`ChunkBackend`] — "advance N iterations from (z, y) with steps
//!   (τ, σ), return the KKT diagnostics".  Implemented here in pure Rust
//!   ([`RustChunk`]: f64, cache-blocked [`BlockedCsr`] with fused
//!   matvec+prox passes, an autotuned block width, explicit 4-lane
//!   elementwise kernels and range-threaded passes on large LPs;
//!   [`ScalarChunk`]: the retained row-by-row CSR oracle) and by
//!   `runtime::PjrtChunk` (the compiled HLO artifact, f32).  All see
//!   the *scaled* LP.
//! * [`drive`] — the backend-agnostic outer loop: Ruiz-scale, pick
//!   initial steps from the operator-norm bound, run chunks, rebalance
//!   the primal/dual step ratio (PDLP's primal-weight update), stop on a
//!   certified relative duality gap.

use crate::obs::{EventKind, NoopSink, Sink};
use crate::substrate::pool;

use super::scale::ruiz;
use super::{LpSolution, SparseLp};

/// KKT diagnostics returned by a chunk (order matches the artifact's
/// diag output: [pobj, dobj, pres, dres]).
#[derive(Clone, Copy, Debug, Default)]
pub struct Diag {
    pub pobj: f64,
    pub dobj: f64,
    pub pres: f64,
    pub dres: f64,
}

impl Diag {
    pub fn scale(&self) -> f64 {
        1.0 + self.pobj.abs() + self.dobj.abs()
    }
    pub fn gap(&self) -> f64 {
        (self.pobj - self.dobj).abs() / self.scale()
    }
    pub fn converged(&self, tol: f64) -> bool {
        let s = self.scale();
        self.gap() < tol && self.pres / s < tol && self.dres / s < tol
    }
}

/// KKT diagnostics for the last iterate and the in-chunk ergodic average
/// (the restart-to-average candidate, PDLP's accelerator).
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkResult {
    pub last: Diag,
    pub avg: Diag,
}

impl Diag {
    /// Scalar progress metric used to choose the restart candidate.
    pub fn score(&self) -> f64 {
        (self.pres + self.dres + (self.pobj - self.dobj).abs()) / self.scale()
    }
}

/// One PDHG chunk executor over a fixed (already scaled) LP.
pub trait ChunkBackend {
    /// Advance `iters_per_chunk()` iterations in place; also compute the
    /// in-chunk average iterate (kept inside the backend) and return
    /// diagnostics for both points.
    fn run_chunk(&mut self, z: &mut [f64], y: &mut [f64], tau: f64, sigma: f64) -> ChunkResult;
    /// Overwrite (z, y) with the average iterate of the last chunk
    /// (the driver calls this to restart-to-average).
    fn load_avg(&self, z: &mut [f64], y: &mut [f64]);
    fn iters_per_chunk(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// CSR matrix for fast row-major matvec.
#[derive(Clone, Debug)]
pub struct Csr {
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub data: Vec<f64>,
    pub n_rows: usize,
    pub n_cols: usize,
}

impl Csr {
    pub fn from_coo(
        n_rows: usize,
        n_cols: usize,
        rows: &[u32],
        cols: &[u32],
        vals: &[f64],
    ) -> Csr {
        let mut counts = vec![0u32; n_rows + 1];
        for &r in rows {
            counts[r as usize + 1] += 1;
        }
        for i in 0..n_rows {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let nnz = vals.len();
        let mut indices = vec![0u32; nnz];
        let mut data = vec![0.0f64; nnz];
        for i in 0..nnz {
            let r = rows[i] as usize;
            let at = cursor[r] as usize;
            indices[at] = cols[i];
            data[at] = vals[i];
            cursor[r] += 1;
        }
        Csr {
            indptr,
            indices,
            data,
            n_rows,
            n_cols,
        }
    }

    /// Transpose (for Aᵀ matvec as a second CSR).
    pub fn transpose(&self) -> Csr {
        let nnz = self.data.len();
        let mut rows_t = Vec::with_capacity(nnz);
        let mut cols_t = Vec::with_capacity(nnz);
        let mut vals_t = Vec::with_capacity(nnz);
        for r in 0..self.n_rows {
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                rows_t.push(self.indices[i]);
                cols_t.push(r as u32);
                vals_t.push(self.data[i]);
            }
        }
        Csr::from_coo(self.n_cols, self.n_rows, &rows_t, &cols_t, &vals_t)
    }

    /// out = A x
    pub fn matvec(&self, x: &[f64], out: &mut [f64]) {
        for r in 0..self.n_rows {
            let mut acc = 0.0;
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                acc += self.data[i] * x[self.indices[i] as usize];
            }
            out[r] = acc;
        }
    }
}

/// Narrow block width of a [`BlockedCsr`] — rows per cache block for
/// long-row matrices, and the SIMD lane count of the fused elementwise
/// kernels (power of two: the row-within-block index is masked, which
/// lets the compiler drop the bounds check on the accumulator array in
/// the hot loops).
pub const BLOCK: usize = 4;

/// Wide block width, picked by the [`BlockedCsr::from_csr`] autotune
/// for short-row matrices: merging eight rows per column sweep
/// amortizes the `x` gathers that short rows can't amortize alone.
pub const BLOCK_WIDE: usize = 8;

/// Elementwise lane width of the fused kernels: the prox, reflection
/// and running-average updates run in explicit 4-lane `[f64; 4]`
/// groups over exact-width chunks, which the autovectorizer maps onto
/// 256-bit SIMD on stable Rust — no intrinsics, no feature gates.
const LANES: usize = 4;

/// Fused passes fan out across [`pool::parallel_map`] workers only at
/// or above this many rows; below it thread-spawn latency beats the
/// bandwidth win.  Threading never changes results: ranges are whole
/// blocks, each row's (column-ordered) sum is computed entirely inside
/// one range, and every write is to a disjoint sub-slice — bitwise
/// identical output for any worker count, which is what lets the
/// `state_stepping_matches_drive_exactly` bitwise pins hold on any
/// machine.
const PAR_MIN_ROWS: usize = 4096;

/// Worker count for one fused pass (1 = stay on the caller's thread).
fn par_workers(n_rows: usize) -> usize {
    if n_rows < PAR_MIN_ROWS {
        1
    } else {
        std::thread::available_parallelism().map_or(1, |n| n.get()).min(8)
    }
}

/// Cache-blocked sparse layout for the PDHG hot loop: rows are grouped
/// into fixed-width blocks of [`BLOCK`] or [`BLOCK_WIDE`] rows (width
/// chosen once per matrix by the `from_csr` shape autotune), and within
/// a block every entry is stored column-sorted as
/// `(col, row-within-block, val)` triples.
///
/// Why this beats row-by-row CSR inside the iteration:
/// * the block accumulators live in registers across a whole block's
///   entries, so each output value is written once instead of the
///   load/add/store churn of short scalar rows;
/// * column-sorting makes the gathers from `x` sweep forward through
///   memory once per block instead of restarting per row (the (Q)HLP
///   models' precedence rows hit overlapping column ranges);
/// * the inner loop is a flat zip over three equal-length slices with a
///   masked accumulator index — no per-entry bounds checks, friendly to
///   auto-vectorization.
///
/// The block width never changes numbers: entries sort by
/// `(col, row)`, so the entries of any single row stay in column order
/// whatever the width, and each row's sum is accumulated in exactly
/// that order — width 4 and width 8 agree bitwise (pinned by tests).
/// Against [`Csr::matvec`] the per-row sums ARE re-associated by the
/// column sort, so agreement there is to rounding (ε), not bitwise;
/// the scalar kernel ([`ScalarChunk`]) is retained as the oracle and
/// the equivalence is pinned by tests at certificate tolerance.
#[derive(Clone, Debug)]
pub struct BlockedCsr {
    pub n_rows: usize,
    pub n_cols: usize,
    /// rows per block: [`BLOCK`] or [`BLOCK_WIDE`]
    block: usize,
    /// entry offsets per block; `block_ptr.len() == ceil(n_rows/block)+1`
    block_ptr: Vec<u32>,
    cols: Vec<u32>,
    /// row within the block, `< block`
    rowi: Vec<u8>,
    vals: Vec<f64>,
}

impl BlockedCsr {
    /// Build with the block width chosen by a deterministic *shape*
    /// heuristic — never a wall-clock probe, so the same matrix always
    /// gets the same layout on every machine: short rows
    /// (avg nnz/row <= [`BLOCK_WIDE`]) on a non-trivial matrix take the
    /// wide width, long rows keep the narrow one (wider blocks stop
    /// paying for the extra accumulators once single rows already
    /// amortize their column sweep).
    pub fn from_csr(a: &Csr) -> BlockedCsr {
        let nnz = a.data.len();
        let wide = a.n_rows >= 64 && nnz <= a.n_rows * BLOCK_WIDE;
        Self::from_csr_with_block(a, if wide { BLOCK_WIDE } else { BLOCK })
    }

    /// Build with an explicit block width (`BLOCK` or `BLOCK_WIDE`).
    /// Tests use this to pin that both widths agree bitwise; production
    /// code goes through the autotuned [`Self::from_csr`].
    pub fn from_csr_with_block(a: &Csr, w: usize) -> BlockedCsr {
        assert!(w == BLOCK || w == BLOCK_WIDE, "unsupported block width {w}");
        let nb = (a.n_rows + w - 1) / w;
        let nnz = a.data.len();
        let mut block_ptr = Vec::with_capacity(nb + 1);
        block_ptr.push(0u32);
        let mut cols = Vec::with_capacity(nnz);
        let mut rowi = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        let mut entries: Vec<(u32, u8, f64)> = Vec::new();
        for b in 0..nb {
            entries.clear();
            for t in 0..w.min(a.n_rows - b * w) {
                let r = b * w + t;
                for i in a.indptr[r] as usize..a.indptr[r + 1] as usize {
                    entries.push((a.indices[i], t as u8, a.data[i]));
                }
            }
            entries.sort_unstable_by_key(|&(c, r, _)| (c, r));
            for &(c, r, v) in &entries {
                cols.push(c);
                rowi.push(r);
                vals.push(v);
            }
            block_ptr.push(cols.len() as u32);
        }
        BlockedCsr {
            n_rows: a.n_rows,
            n_cols: a.n_cols,
            block: w,
            block_ptr,
            cols,
            rowi,
            vals,
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Rows per block the autotune picked ([`BLOCK`] or [`BLOCK_WIDE`]).
    pub fn block_rows(&self) -> usize {
        self.block
    }

    /// Gather one block's accumulators: `acc[r] += val * x[col]` over
    /// the block's column-sorted entries.  `W` must equal the built
    /// block width; the mask keeps the accumulator index in-bounds
    /// without a branch.
    #[inline(always)]
    fn block_acc<const W: usize>(&self, b: usize, x: &[f64]) -> [f64; W] {
        let lo = self.block_ptr[b] as usize;
        let hi = self.block_ptr[b + 1] as usize;
        let mut acc = [0.0f64; W];
        for ((&c, &r), &v) in self.cols[lo..hi]
            .iter()
            .zip(&self.rowi[lo..hi])
            .zip(&self.vals[lo..hi])
        {
            acc[r as usize & (W - 1)] += v * x[c as usize];
        }
        acc
    }

    /// out = A x (blocked; per-row sums are column-ordered).
    pub fn matvec(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.n_rows);
        match self.block {
            BLOCK_WIDE => self.matvec_w::<BLOCK_WIDE>(x, out),
            _ => self.matvec_w::<BLOCK>(x, out),
        }
    }

    fn matvec_w<const W: usize>(&self, x: &[f64], out: &mut [f64]) {
        for (b, out_b) in out.chunks_mut(W).enumerate() {
            let acc = self.block_acc::<W>(b, x);
            out_b.copy_from_slice(&acc[..out_b.len()]);
        }
    }

    /// Fused primal half-step over this matrix's rows (call on Aᵀ, whose
    /// rows are the primal variables): per block, compute `g = Aᵀy`,
    /// then immediately apply the box prox, the reflection and the
    /// running-average accumulation for those variables.  `z`, `zbar`,
    /// `c`, the box and `z_avg` are each traversed exactly once and the
    /// `g` vector never materializes.  Above [`PAR_MIN_ROWS`] rows the
    /// pass fans out over disjoint block ranges (bitwise identical to
    /// the serial pass for any worker count).
    #[allow(clippy::too_many_arguments)]
    pub fn fused_primal(
        &self,
        y: &[f64],
        z: &mut [f64],
        zbar: &mut [f64],
        c: &[f64],
        lo: &[f64],
        hi: &[f64],
        tau: f64,
        z_avg: &mut [f64],
    ) {
        debug_assert_eq!(z.len(), self.n_rows);
        match self.block {
            BLOCK_WIDE => self.fused_primal_par::<BLOCK_WIDE>(y, z, zbar, c, lo, hi, tau, z_avg),
            _ => self.fused_primal_par::<BLOCK>(y, z, zbar, c, lo, hi, tau, z_avg),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn fused_primal_par<const W: usize>(
        &self,
        y: &[f64],
        z: &mut [f64],
        zbar: &mut [f64],
        c: &[f64],
        lo: &[f64],
        hi: &[f64],
        tau: f64,
        z_avg: &mut [f64],
    ) {
        let workers = par_workers(self.n_rows);
        if workers <= 1 {
            self.fused_primal_rows::<W>(0, y, z, zbar, c, lo, hi, tau, z_avg);
            return;
        }
        let nb = self.block_ptr.len() - 1;
        let per = (nb + workers - 1) / workers;
        let mut items: Vec<(usize, &mut [f64], &mut [f64], &[f64], &[f64], &[f64], &mut [f64])> =
            Vec::with_capacity(workers);
        let (mut z_r, mut zb_r, mut av_r) = (z, zbar, z_avg);
        let (mut c_r, mut lo_r, mut hi_r) = (c, lo, hi);
        let mut fb = 0usize;
        while fb < nb {
            let blocks = per.min(nb - fb);
            let rows = (blocks * W).min(z_r.len());
            let (z_s, z_t) = z_r.split_at_mut(rows);
            let (zb_s, zb_t) = zb_r.split_at_mut(rows);
            let (av_s, av_t) = av_r.split_at_mut(rows);
            let (c_s, c_t) = c_r.split_at(rows);
            let (lo_s, lo_t) = lo_r.split_at(rows);
            let (hi_s, hi_t) = hi_r.split_at(rows);
            z_r = z_t;
            zb_r = zb_t;
            av_r = av_t;
            c_r = c_t;
            lo_r = lo_t;
            hi_r = hi_t;
            items.push((fb, z_s, zb_s, c_s, lo_s, hi_s, av_s));
            fb += blocks;
        }
        pool::parallel_map(items, workers, |(first, z_s, zb_s, c_s, lo_s, hi_s, av_s)| {
            self.fused_primal_rows::<W>(first, y, z_s, zb_s, c_s, lo_s, hi_s, tau, av_s)
        });
    }

    /// Serial fused primal pass over one contiguous range of blocks:
    /// `first_block` is the global index of the range's first block and
    /// the slices hold exactly the range's rows.  Full blocks run the
    /// explicit 4-lane kernel; the matrix's ragged tail block (rows not
    /// a multiple of `W`, always globally last) finishes row-by-row.
    #[allow(clippy::too_many_arguments)]
    fn fused_primal_rows<const W: usize>(
        &self,
        first_block: usize,
        y: &[f64],
        z: &mut [f64],
        zbar: &mut [f64],
        c: &[f64],
        lo: &[f64],
        hi: &[f64],
        tau: f64,
        z_avg: &mut [f64],
    ) {
        let n = z.len();
        let nfull = n / W;
        let blocks = z
            .chunks_exact_mut(W)
            .zip(zbar.chunks_exact_mut(W))
            .zip(c.chunks_exact(W))
            .zip(lo.chunks_exact(W))
            .zip(hi.chunks_exact(W))
            .zip(z_avg.chunks_exact_mut(W));
        for (k, (((((z_b, zb_b), c_b), lo_b), hi_b), av_b)) in blocks.enumerate() {
            let acc = self.block_acc::<W>(first_block + k, y);
            for g in 0..W / LANES {
                let o = g * LANES;
                let mut znew = [0.0f64; LANES];
                for l in 0..LANES {
                    znew[l] =
                        (z_b[o + l] - tau * (c_b[o + l] + acc[o + l])).clamp(lo_b[o + l], hi_b[o + l]);
                }
                for l in 0..LANES {
                    zb_b[o + l] = 2.0 * znew[l] - z_b[o + l];
                }
                for l in 0..LANES {
                    av_b[o + l] += znew[l];
                }
                for l in 0..LANES {
                    z_b[o + l] = znew[l];
                }
            }
        }
        let tail = n % W;
        if tail > 0 {
            let base = nfull * W;
            let acc = self.block_acc::<W>(first_block + nfull, y);
            for t in 0..tail {
                let j = base + t;
                let znew = (z[j] - tau * (c[j] + acc[t])).clamp(lo[j], hi[j]);
                zbar[j] = 2.0 * znew - z[j];
                z[j] = znew;
                z_avg[j] += znew;
            }
        }
    }

    /// Fused dual half-step over this matrix's rows (call on A): per
    /// block, compute `A z̄`, then immediately apply the projected dual
    /// ascent and the running-average accumulation — the `az` vector
    /// never materializes and `y`/`b`/`y_avg` are traversed once.
    /// Threads and lanes exactly as [`Self::fused_primal`].
    pub fn fused_dual(
        &self,
        zbar: &[f64],
        y: &mut [f64],
        b_vec: &[f64],
        sigma: f64,
        y_avg: &mut [f64],
    ) {
        debug_assert_eq!(y.len(), self.n_rows);
        match self.block {
            BLOCK_WIDE => self.fused_dual_par::<BLOCK_WIDE>(zbar, y, b_vec, sigma, y_avg),
            _ => self.fused_dual_par::<BLOCK>(zbar, y, b_vec, sigma, y_avg),
        }
    }

    fn fused_dual_par<const W: usize>(
        &self,
        zbar: &[f64],
        y: &mut [f64],
        b_vec: &[f64],
        sigma: f64,
        y_avg: &mut [f64],
    ) {
        let workers = par_workers(self.n_rows);
        if workers <= 1 {
            self.fused_dual_rows::<W>(0, zbar, y, b_vec, sigma, y_avg);
            return;
        }
        let nb = self.block_ptr.len() - 1;
        let per = (nb + workers - 1) / workers;
        let mut items: Vec<(usize, &mut [f64], &[f64], &mut [f64])> = Vec::with_capacity(workers);
        let (mut y_r, mut av_r) = (y, y_avg);
        let mut b_r = b_vec;
        let mut fb = 0usize;
        while fb < nb {
            let blocks = per.min(nb - fb);
            let rows = (blocks * W).min(y_r.len());
            let (y_s, y_t) = y_r.split_at_mut(rows);
            let (av_s, av_t) = av_r.split_at_mut(rows);
            let (b_s, b_t) = b_r.split_at(rows);
            y_r = y_t;
            av_r = av_t;
            b_r = b_t;
            items.push((fb, y_s, b_s, av_s));
            fb += blocks;
        }
        pool::parallel_map(items, workers, |(first, y_s, b_s, av_s)| {
            self.fused_dual_rows::<W>(first, zbar, y_s, b_s, sigma, av_s)
        });
    }

    /// Serial fused dual pass over one contiguous range of blocks (see
    /// [`Self::fused_primal_rows`] for the range/tail contract).
    fn fused_dual_rows<const W: usize>(
        &self,
        first_block: usize,
        zbar: &[f64],
        y: &mut [f64],
        b_vec: &[f64],
        sigma: f64,
        y_avg: &mut [f64],
    ) {
        let n = y.len();
        let nfull = n / W;
        let blocks = y
            .chunks_exact_mut(W)
            .zip(b_vec.chunks_exact(W))
            .zip(y_avg.chunks_exact_mut(W));
        for (k, ((y_b, b_b), av_b)) in blocks.enumerate() {
            let acc = self.block_acc::<W>(first_block + k, zbar);
            for g in 0..W / LANES {
                let o = g * LANES;
                let mut ynew = [0.0f64; LANES];
                for l in 0..LANES {
                    ynew[l] = (y_b[o + l] + sigma * (acc[o + l] - b_b[o + l])).max(0.0);
                }
                for l in 0..LANES {
                    av_b[o + l] += ynew[l];
                }
                for l in 0..LANES {
                    y_b[o + l] = ynew[l];
                }
            }
        }
        let tail = n % W;
        if tail > 0 {
            let base = nfull * W;
            let acc = self.block_acc::<W>(first_block + nfull, zbar);
            for t in 0..tail {
                let i = base + t;
                let ynew = (y[i] + sigma * (acc[t] - b_vec[i])).max(0.0);
                y[i] = ynew;
                y_avg[i] += ynew;
            }
        }
    }
}

/// Pure-Rust chunk backend (f64); the algorithmic mirror of the JAX
/// artifact — one iteration is:
///   z⁺ = clip(z − τ(c + Aᵀy), lo, hi);  z̄ = 2z⁺ − z;
///   y⁺ = max(0, y + σ(Az̄ − b))
///
/// The hot loop runs on the cache-blocked layout ([`BlockedCsr`]) with
/// both halves of the iteration *fused*: the Aᵀy gather feeds the box
/// prox block-by-block and the Az̄ gather feeds the dual ascent
/// block-by-block, so neither `g` nor `az` is materialized or
/// re-traversed.  [`ScalarChunk`] keeps the original row-by-row CSR
/// kernel as the oracle; the two agree to rounding (per-row sums are
/// column-reordered), pinned at certificate tolerance by tests.
pub struct RustChunk {
    a: BlockedCsr,
    at: BlockedCsr,
    lp: SparseLp,
    iters: usize,
    // scratch (diagnostics only — the iteration itself fuses these away)
    g: Vec<f64>,
    az: Vec<f64>,
    zbar: Vec<f64>,
    // in-chunk ergodic averages (restart candidates)
    z_avg: Vec<f64>,
    y_avg: Vec<f64>,
}

impl RustChunk {
    pub fn new(lp: &SparseLp, iters: usize) -> RustChunk {
        let a = Csr::from_coo(lp.m, lp.n, &lp.rows, &lp.cols, &lp.vals);
        let at = a.transpose();
        RustChunk {
            a: BlockedCsr::from_csr(&a),
            at: BlockedCsr::from_csr(&at),
            lp: lp.clone(),
            iters,
            g: vec![0.0; lp.n],
            az: vec![0.0; lp.m],
            zbar: vec![0.0; lp.n],
            z_avg: vec![0.0; lp.n],
            y_avg: vec![0.0; lp.m],
        }
    }

    fn diagnostics(&mut self, z: &[f64], y: &[f64]) -> Diag {
        let lp = &self.lp;
        self.a.matvec(z, &mut self.az);
        self.at.matvec(y, &mut self.g);
        diag_from(lp, z, y, &self.az, &self.g)
    }
}

/// KKT diagnostics at (z, y) given precomputed `az = Az`, `g = Aᵀy`
/// (shared by the blocked and scalar backends).
fn diag_from(lp: &SparseLp, z: &[f64], y: &[f64], az: &[f64], g: &[f64]) -> Diag {
    let mut pres = 0.0;
    for i in 0..lp.m {
        let v = (az[i] - lp.b[i]).max(0.0);
        pres += v * v;
    }
    let mut dres = 0.0;
    let mut pobj = 0.0;
    let mut dobj = 0.0;
    for j in 0..lp.n {
        let rc = lp.c[j] + g[j];
        let proj = (z[j] - rc).clamp(lp.lo[j], lp.hi[j]);
        let d = z[j] - proj;
        dres += d * d;
        pobj += lp.c[j] * z[j];
        dobj += (rc * lp.lo[j]).min(rc * lp.hi[j]);
    }
    for i in 0..lp.m {
        dobj -= lp.b[i] * y[i];
    }
    Diag {
        pobj,
        dobj,
        pres: pres.sqrt(),
        dres: dres.sqrt(),
    }
}

impl ChunkBackend for RustChunk {
    fn run_chunk(&mut self, z: &mut [f64], y: &mut [f64], tau: f64, sigma: f64) -> ChunkResult {
        self.z_avg.iter_mut().for_each(|x| *x = 0.0);
        self.y_avg.iter_mut().for_each(|x| *x = 0.0);
        for _ in 0..self.iters {
            self.at.fused_primal(
                y,
                z,
                &mut self.zbar,
                &self.lp.c,
                &self.lp.lo,
                &self.lp.hi,
                tau,
                &mut self.z_avg,
            );
            self.a
                .fused_dual(&self.zbar, y, &self.lp.b, sigma, &mut self.y_avg);
        }
        let inv = 1.0 / self.iters as f64;
        self.z_avg.iter_mut().for_each(|x| *x *= inv);
        self.y_avg.iter_mut().for_each(|x| *x *= inv);
        let last = self.diagnostics(z, y);
        let za = std::mem::take(&mut self.z_avg);
        let ya = std::mem::take(&mut self.y_avg);
        let avg = self.diagnostics(&za, &ya);
        self.z_avg = za;
        self.y_avg = ya;
        ChunkResult { last, avg }
    }

    fn load_avg(&self, z: &mut [f64], y: &mut [f64]) {
        z.copy_from_slice(&self.z_avg);
        y.copy_from_slice(&self.y_avg);
    }

    fn iters_per_chunk(&self) -> usize {
        self.iters
    }

    fn name(&self) -> &'static str {
        "pdhg-rust"
    }
}

/// The original row-by-row CSR kernel, retained verbatim as the oracle
/// for the blocked [`RustChunk`]: per-row summation order is exactly
/// the COO build order, and every vector (`g`, `az`, the averages) is
/// materialized and traversed separately per iteration.  Tests pin
/// blocked-vs-scalar agreement; do NOT "optimize" this — its value is
/// being the old behavior.
pub struct ScalarChunk {
    a: Csr,
    at: Csr,
    lp: SparseLp,
    iters: usize,
    g: Vec<f64>,
    az: Vec<f64>,
    zbar: Vec<f64>,
    z_avg: Vec<f64>,
    y_avg: Vec<f64>,
}

impl ScalarChunk {
    pub fn new(lp: &SparseLp, iters: usize) -> ScalarChunk {
        let a = Csr::from_coo(lp.m, lp.n, &lp.rows, &lp.cols, &lp.vals);
        let at = a.transpose();
        ScalarChunk {
            a,
            at,
            lp: lp.clone(),
            iters,
            g: vec![0.0; lp.n],
            az: vec![0.0; lp.m],
            zbar: vec![0.0; lp.n],
            z_avg: vec![0.0; lp.n],
            y_avg: vec![0.0; lp.m],
        }
    }

    fn diagnostics(&mut self, z: &[f64], y: &[f64]) -> Diag {
        self.a.matvec(z, &mut self.az);
        self.at.matvec(y, &mut self.g);
        diag_from(&self.lp, z, y, &self.az, &self.g)
    }
}

impl ChunkBackend for ScalarChunk {
    fn run_chunk(&mut self, z: &mut [f64], y: &mut [f64], tau: f64, sigma: f64) -> ChunkResult {
        let n = self.lp.n;
        self.z_avg.iter_mut().for_each(|x| *x = 0.0);
        self.y_avg.iter_mut().for_each(|x| *x = 0.0);
        for _ in 0..self.iters {
            // g = c + A'y
            self.at.matvec(y, &mut self.g);
            for j in 0..n {
                let znew = (z[j] - tau * (self.lp.c[j] + self.g[j]))
                    .clamp(self.lp.lo[j], self.lp.hi[j]);
                self.zbar[j] = 2.0 * znew - z[j];
                z[j] = znew;
            }
            self.a.matvec(&self.zbar, &mut self.az);
            for i in 0..self.lp.m {
                y[i] = (y[i] + sigma * (self.az[i] - self.lp.b[i])).max(0.0);
            }
            for j in 0..n {
                self.z_avg[j] += z[j];
            }
            for i in 0..self.lp.m {
                self.y_avg[i] += y[i];
            }
        }
        let inv = 1.0 / self.iters as f64;
        self.z_avg.iter_mut().for_each(|x| *x *= inv);
        self.y_avg.iter_mut().for_each(|x| *x *= inv);
        let last = self.diagnostics(z, y);
        let za = std::mem::take(&mut self.z_avg);
        let ya = std::mem::take(&mut self.y_avg);
        let avg = self.diagnostics(&za, &ya);
        self.z_avg = za;
        self.y_avg = ya;
        ChunkResult { last, avg }
    }

    fn load_avg(&self, z: &mut [f64], y: &mut [f64]) {
        z.copy_from_slice(&self.z_avg);
        y.copy_from_slice(&self.y_avg);
    }

    fn iters_per_chunk(&self) -> usize {
        self.iters
    }

    fn name(&self) -> &'static str {
        "pdhg-rust-scalar"
    }
}

/// Options for the outer drive loop.
#[derive(Clone, Debug)]
pub struct DriveOpts {
    pub tol: f64,
    pub max_iters: usize,
    /// Ruiz preconditioning rounds (0 disables).
    pub ruiz_iters: usize,
    /// Feasible primal warm start in *original* coordinates.
    pub warm_start: Option<Vec<f64>>,
    /// Dual warm start (y ≥ 0) in *original* coordinates — the previous
    /// optimum's multipliers when re-solving the same instance at a
    /// nearby machine config (`lp::warm`).  Negative entries are clipped.
    pub warm_start_dual: Option<Vec<f64>>,
}

impl Default for DriveOpts {
    fn default() -> Self {
        DriveOpts {
            tol: 1e-4,
            max_iters: 400_000,
            ruiz_iters: 8,
            warm_start: None,
            warm_start_dual: None,
        }
    }
}

/// Why a [`PdhgState`] stopped stepping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The best iterate is certified within tolerance.
    Converged,
    /// The best KKT score stopped improving (precision floor).
    Stalled,
    /// The iteration budget ran out (extendable via
    /// [`PdhgState::extend_budget`]).
    Budget,
}

impl StopReason {
    /// Stable tag used in `lp-done` trace events.
    pub fn label(self) -> &'static str {
        match self {
            StopReason::Converged => "converged",
            StopReason::Stalled => "stalled",
            StopReason::Budget => "budget",
        }
    }
}

/// The reified outer PDHG loop: everything [`drive`] used to keep on its
/// stack, packaged so a solve can be advanced one chunk at a time.  This
/// is what lets the batched driver ([`super::batch`]) interleave many
/// LPs over one worker pool instead of parking one thread per solve.
pub struct PdhgState<B: ChunkBackend> {
    backend: B,
    scaling: super::scale::Scaling,
    tol: f64,
    max_iters: usize,
    eta: f64,
    // primal weight ω: τ = η/ω, σ = η·ω (τσ = η² ≤ (0.9/||A||)²)
    omega: f64,
    z: Vec<f64>,
    y: Vec<f64>,
    iters: usize,
    best_dobj: f64,
    // best-scoring iterate seen so far (returned at the end — PDHG with
    // restarts oscillates, so "last" is not necessarily the best)
    best: Diag,
    best_score: f64,
    best_z: Vec<f64>,
    // stall detection: an f32 backend can bottom out above a tight
    // tolerance; stop once the best KKT score stops improving and
    // return the best point with its honestly-certified gap.
    chunks_since_improvement: usize,
    score_at_last_check: f64,
    stop: Option<StopReason>,
}

impl<B: ChunkBackend> PdhgState<B> {
    /// Ruiz-scale `lp`, pick step sizes from the operator-norm bound and
    /// set up the (possibly warm-started) iterates.  `make_backend`
    /// receives the scaled LP.
    pub fn new(
        lp: &SparseLp,
        opts: &DriveOpts,
        make_backend: impl FnOnce(&SparseLp) -> B,
    ) -> PdhgState<B> {
        let (scaled, scaling) = ruiz(lp, opts.ruiz_iters);
        let norm = super::scale::opnorm_power(&scaled, 24);
        let eta = 0.9 / norm;
        let backend = make_backend(&scaled);
        // start from the warm start (scaled into z' = z / dc) or from
        // the box projection of 0
        let z: Vec<f64> = match &opts.warm_start {
            Some(w) => {
                assert_eq!(w.len(), lp.n, "warm start dimension");
                w.iter()
                    .enumerate()
                    .map(|(j, &v)| (v / scaling.dc[j]).clamp(scaled.lo[j], scaled.hi[j]))
                    .collect()
            }
            None => (0..scaled.n)
                .map(|j| 0.0f64.clamp(scaled.lo[j], scaled.hi[j]))
                .collect(),
        };
        // dual warm start scaled as y' = y / dr (y = Dr y', see scale.rs)
        let y: Vec<f64> = match &opts.warm_start_dual {
            Some(w) => {
                assert_eq!(w.len(), lp.m, "dual warm start dimension");
                w.iter()
                    .enumerate()
                    .map(|(i, &v)| (v / scaling.dr[i]).max(0.0))
                    .collect()
            }
            None => vec![0.0; scaled.m],
        };
        let best_z = z.clone();
        PdhgState {
            backend,
            scaling,
            tol: opts.tol,
            max_iters: opts.max_iters,
            eta,
            omega: 1.0,
            z,
            y,
            iters: 0,
            best_dobj: f64::NEG_INFINITY,
            best: Diag::default(),
            best_score: f64::INFINITY,
            best_z,
            chunks_since_improvement: 0,
            score_at_last_check: f64::INFINITY,
            stop: None,
        }
    }

    /// Advance one chunk; returns `true` once the solve has stopped
    /// (see [`Self::stop_reason`]).  Stepping a stopped state is a no-op.
    pub fn step(&mut self) -> bool {
        self.step_traced(0, &mut NoopSink)
    }

    /// [`Self::step`] with an event sink: per chunk, an `lp-chunk`
    /// residual sample (iteration count as the virtual clock — the LP
    /// loop, like the scheduler core, never reads the wall clock) and,
    /// when the solve stops, one `lp-done` span naming the stop reason.
    /// `lp_id` labels this solve in a batched stream.  With a
    /// [`NoopSink`] this *is* `step` — pinned bitwise by
    /// `state_stepping_matches_drive_exactly` and the obs parity suite.
    pub fn step_traced(&mut self, lp_id: usize, sink: &mut dyn Sink) -> bool {
        if self.stop.is_some() {
            return true;
        }
        if self.iters >= self.max_iters {
            self.stop = Some(StopReason::Budget);
            self.emit_done(lp_id, sink);
            return true;
        }
        let tau = self.eta / self.omega;
        let sigma = self.eta * self.omega;
        let res = self.backend.run_chunk(&mut self.z, &mut self.y, tau, sigma);
        self.iters += self.backend.iters_per_chunk();
        // restart-to-average (PDLP): adopt the ergodic average whenever
        // its KKT score beats the last iterate's.
        let diag = if res.avg.score() < res.last.score() {
            self.backend.load_avg(&mut self.z, &mut self.y);
            res.avg
        } else {
            res.last
        };
        if sink.enabled() {
            sink.emit(
                self.iters as f64,
                EventKind::LpChunk {
                    lp: lp_id,
                    iters: self.iters as u64,
                    pres: diag.pres,
                    dres: diag.dres,
                    gap: diag.gap(),
                },
            );
        }
        self.best_dobj = self.best_dobj.max(res.last.dobj.max(res.avg.dobj));
        if diag.score() < self.best_score {
            self.best_score = diag.score();
            self.best = diag;
            self.best_z.copy_from_slice(&self.z);
        }
        if self.best.converged(self.tol) {
            self.stop = Some(StopReason::Converged);
            self.emit_done(lp_id, sink);
            return true;
        }
        if self.best_score < self.score_at_last_check * 0.98 {
            self.score_at_last_check = self.best_score;
            self.chunks_since_improvement = 0;
        } else {
            self.chunks_since_improvement += 1;
            if self.chunks_since_improvement >= 40 {
                // practical floor for this backend/precision
                self.stop = Some(StopReason::Stalled);
                self.emit_done(lp_id, sink);
                return true;
            }
        }
        // Smoothed primal-weight rebalancing (PDLP's log-space update,
        // capped per chunk — aggressive jumps destabilize the iteration).
        // Residuals are floored at a fraction of the convergence target
        // so a residual that is already "good enough" exerts no pull.
        // pres high -> grow σ (ω up); dres high -> grow τ (ω down).
        let floor = 0.1 * self.tol * diag.scale();
        let (p, d) = (diag.pres.max(floor), diag.dres.max(floor));
        let target = self.omega * (p / d).sqrt().sqrt();
        self.omega = (target.clamp(self.omega / 1.3, self.omega * 1.3)).clamp(1e-3, 1e3);
        if self.iters >= self.max_iters {
            self.stop = Some(StopReason::Budget);
            self.emit_done(lp_id, sink);
            return true;
        }
        false
    }

    /// One `lp-done` span for the just-set stop reason (no-op when the
    /// sink is disabled).
    fn emit_done(&self, lp_id: usize, sink: &mut dyn Sink) {
        if sink.enabled() {
            let stop = self.stop.map_or("budget", StopReason::label);
            sink.emit(
                self.iters as f64,
                EventKind::LpDone { lp: lp_id, iters: self.iters as u64, stop },
            );
        }
    }

    pub fn stop_reason(&self) -> Option<StopReason> {
        self.stop
    }

    pub fn iters(&self) -> usize {
        self.iters
    }

    /// Raise the iteration budget (the warm-start escalation schedule of
    /// [`super::warm::BudgetSchedule`]); clears a `Budget` stop so
    /// stepping can resume.  Converged/stalled states stay stopped.
    pub fn extend_budget(&mut self, new_max: usize) {
        if new_max > self.max_iters {
            self.max_iters = new_max;
            if self.stop == Some(StopReason::Budget) {
                self.stop = None;
            }
        }
    }

    /// Final (best-primal, current-dual) iterates in *original*
    /// coordinates — the seed for warm-starting a grid neighbor.
    pub fn iterates(&self) -> (Vec<f64>, Vec<f64>) {
        (
            self.scaling.unscale_z(&self.best_z),
            self.scaling.unscale_y(&self.y),
        )
    }

    /// Package the best iterate as an [`LpSolution`] in original
    /// coordinates (`lp` must be the LP this state was built from).
    pub fn into_solution(self, lp: &SparseLp) -> LpSolution {
        let z_orig = self.scaling.unscale_z(&self.best_z);
        LpSolution {
            obj: lp.objective(&z_orig),
            lower_bound: self.best_dobj,
            gap: self.best.gap(),
            z: z_orig,
            iters: self.iters,
            backend: self.backend.name(),
        }
    }
}

/// Drive a chunk backend built by `make_backend` on the Ruiz-scaled LP.
///
/// `make_backend` receives the scaled LP; the returned solution is in
/// *original* coordinates, with `lower_bound` the dual bound (valid for
/// the original LP since scaling preserves objective values).
pub fn drive<B: ChunkBackend>(
    lp: &SparseLp,
    opts: &DriveOpts,
    make_backend: impl FnOnce(&SparseLp) -> B,
) -> LpSolution {
    let mut state = PdhgState::new(lp, opts, make_backend);
    while !state.step() {}
    state.into_solution(lp)
}

/// Solve with the in-tree Rust backend (blocked kernel).
pub fn solve_rust(lp: &SparseLp, opts: &DriveOpts) -> LpSolution {
    drive(lp, opts, |scaled| RustChunk::new(scaled, 250))
}

/// Solve with the retained scalar oracle kernel (tests/benches only —
/// the blocked kernel is the production path).
pub fn solve_rust_scalar(lp: &SparseLp, opts: &DriveOpts) -> LpSolution {
    drive(lp, opts, |scaled| ScalarChunk::new(scaled, 250))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knapsack() -> SparseLp {
        // min -x1-x2 : x1+x2 <= 1.5, x in [0,1]^2  -> -1.5
        let mut lp = SparseLp {
            n: 2,
            m: 1,
            b: vec![1.5],
            c: vec![-1.0, -1.0],
            lo: vec![0.0; 2],
            hi: vec![1.0; 2],
            ..Default::default()
        };
        lp.push(0, 0, 1.0);
        lp.push(0, 1, 1.0);
        lp
    }

    #[test]
    fn csr_roundtrip_and_matvec() {
        let rows = vec![0u32, 0, 1, 2];
        let cols = vec![0u32, 2, 1, 0];
        let vals = vec![1.0, 2.0, 3.0, 4.0];
        let a = Csr::from_coo(3, 3, &rows, &cols, &vals);
        let mut out = vec![0.0; 3];
        a.matvec(&[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, vec![3.0, 3.0, 4.0]);
        let at = a.transpose();
        let mut out_t = vec![0.0; 3];
        at.matvec(&[1.0, 1.0, 1.0], &mut out_t);
        assert_eq!(out_t, vec![5.0, 3.0, 2.0]);
    }

    fn random_csr(rng: &mut crate::substrate::rng::Rng, m: usize, n: usize) -> Csr {
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for r in 0..m {
            for c in 0..n {
                if rng.chance(0.3) {
                    rows.push(r as u32);
                    cols.push(c as u32);
                    vals.push(rng.uniform(-2.0, 2.0));
                }
            }
        }
        Csr::from_coo(m, n, &rows, &cols, &vals)
    }

    #[test]
    fn blocked_matvec_matches_scalar_within_eps() {
        // per-row sums are column-reordered in the blocked layout, so
        // agreement is to rounding, not bitwise
        let mut rng = crate::substrate::rng::Rng::new(41);
        for (m, n) in [(1usize, 1usize), (3, 5), (4, 4), (7, 9), (16, 3), (33, 17)] {
            let a = random_csr(&mut rng, m, n);
            let blocked = BlockedCsr::from_csr(&a);
            assert_eq!(blocked.nnz(), a.data.len());
            let x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut want = vec![0.0; m];
            let mut got = vec![1.0; m]; // non-zero: matvec must overwrite
            a.matvec(&x, &mut want);
            blocked.matvec(&x, &mut got);
            for (w, g) in want.iter().zip(&got) {
                assert!((w - g).abs() < 1e-12 * (1.0 + w.abs()), "{w} vs {g}");
            }
        }
    }

    #[test]
    fn autotune_picks_block_width_by_shape() {
        let mut rng = crate::substrate::rng::Rng::new(7);
        // short rows (1 nnz/row) on a non-trivial matrix -> wide blocks
        let rows: Vec<u32> = (0..256u32).collect();
        let cols: Vec<u32> = (0..256u32).map(|c| c % 16).collect();
        let vals: Vec<f64> = (0..256).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let short = Csr::from_coo(256, 16, &rows, &cols, &vals);
        assert_eq!(BlockedCsr::from_csr(&short).block_rows(), BLOCK_WIDE);
        // long rows (16 nnz/row) -> narrow blocks
        let mut r2 = Vec::new();
        let mut c2 = Vec::new();
        let mut v2 = Vec::new();
        for r in 0..256u32 {
            for k in 0..16u32 {
                r2.push(r);
                c2.push(k);
                v2.push(rng.uniform(-1.0, 1.0));
            }
        }
        let long = Csr::from_coo(256, 16, &r2, &c2, &v2);
        assert_eq!(BlockedCsr::from_csr(&long).block_rows(), BLOCK);
        // tiny matrices never take the wide path
        let tiny = Csr::from_coo(3, 3, &[0, 1, 2], &[0, 1, 2], &[1.0, 1.0, 1.0]);
        assert_eq!(BlockedCsr::from_csr(&tiny).block_rows(), BLOCK);
    }

    #[test]
    fn block_widths_agree_bitwise() {
        // entries sort by (col, row) inside a block, so any single
        // row's sum is accumulated in column order at EITHER width:
        // 4 vs 8 must agree bit-for-bit, which is what makes the
        // autotune decision numerically free
        let mut rng = crate::substrate::rng::Rng::new(43);
        for (m, n) in [(5usize, 4usize), (13, 9), (64, 64), (131, 17)] {
            let a = random_csr(&mut rng, m, n);
            let b4 = BlockedCsr::from_csr_with_block(&a, BLOCK);
            let b8 = BlockedCsr::from_csr_with_block(&a, BLOCK_WIDE);
            let x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut o4 = vec![0.0; m];
            let mut o8 = vec![0.0; m];
            b4.matvec(&x, &mut o4);
            b8.matvec(&x, &mut o8);
            for (p, q) in o4.iter().zip(&o8) {
                assert_eq!(p.to_bits(), q.to_bits(), "{p} vs {q}");
            }
        }
    }

    #[test]
    fn threaded_fused_passes_match_serial_bitwise() {
        // above PAR_MIN_ROWS the fused passes fan out; ranges are whole
        // blocks with disjoint writes, so any worker count must
        // reproduce the serial single-range pass bit-for-bit (ragged
        // tail included: 5003 % 4 == 5003 % 8 == 3)
        let m = PAR_MIN_ROWS + 907;
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        let mut rng = crate::substrate::rng::Rng::new(11);
        for r in 0..m {
            for d in [0usize, 1, 2] {
                rows.push(r as u32);
                cols.push(((r + d * 17) % m) as u32);
                vals.push(rng.uniform(-1.0, 1.0));
            }
        }
        let a = Csr::from_coo(m, m, &rows, &cols, &vals);
        let x: Vec<f64> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let cvec: Vec<f64> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let lo = vec![-1.0; m];
        let hi = vec![1.0; m];
        for w in [BLOCK, BLOCK_WIDE] {
            let blocked = BlockedCsr::from_csr_with_block(&a, w);
            let mut z_t = x.clone();
            let mut zb_t = vec![0.0; m];
            let mut av_t = vec![0.0; m];
            blocked.fused_primal(&x, &mut z_t, &mut zb_t, &cvec, &lo, &hi, 0.2, &mut av_t);
            let mut z_s = x.clone();
            let mut zb_s = vec![0.0; m];
            let mut av_s = vec![0.0; m];
            if w == BLOCK {
                blocked.fused_primal_rows::<BLOCK>(
                    0, &x, &mut z_s, &mut zb_s, &cvec, &lo, &hi, 0.2, &mut av_s,
                );
            } else {
                blocked.fused_primal_rows::<BLOCK_WIDE>(
                    0, &x, &mut z_s, &mut zb_s, &cvec, &lo, &hi, 0.2, &mut av_s,
                );
            }
            let pairs = z_t
                .iter()
                .zip(&z_s)
                .chain(zb_t.iter().zip(&zb_s))
                .chain(av_t.iter().zip(&av_s));
            for (p, q) in pairs {
                assert_eq!(p.to_bits(), q.to_bits(), "primal w={w}: {p} vs {q}");
            }
            let mut y_t = x.clone();
            let mut ya_t = vec![0.0; m];
            blocked.fused_dual(&x, &mut y_t, &cvec, 0.3, &mut ya_t);
            let mut y_s = x.clone();
            let mut ya_s = vec![0.0; m];
            if w == BLOCK {
                blocked.fused_dual_rows::<BLOCK>(0, &x, &mut y_s, &cvec, 0.3, &mut ya_s);
            } else {
                blocked.fused_dual_rows::<BLOCK_WIDE>(0, &x, &mut y_s, &cvec, 0.3, &mut ya_s);
            }
            for (p, q) in y_t.iter().zip(&y_s).chain(ya_t.iter().zip(&ya_s)) {
                assert_eq!(p.to_bits(), q.to_bits(), "dual w={w}: {p} vs {q}");
            }
        }
    }

    #[test]
    fn blocked_chunk_matches_scalar_oracle() {
        // one chunk from the same start: iterates and diagnostics agree
        // to rounding; a full solve agrees at certificate tolerance
        let lp = knapsack();
        let mut blocked = RustChunk::new(&lp, 50);
        let mut scalar = ScalarChunk::new(&lp, 50);
        let (mut zb, mut yb) = (vec![0.0; lp.n], vec![0.0; lp.m]);
        let (mut zs, mut ys) = (vec![0.0; lp.n], vec![0.0; lp.m]);
        let rb = blocked.run_chunk(&mut zb, &mut yb, 0.3, 0.3);
        let rs = scalar.run_chunk(&mut zs, &mut ys, 0.3, 0.3);
        for (a, b) in zb.iter().zip(&zs) {
            assert!((a - b).abs() < 1e-9, "z {a} vs {b}");
        }
        for (a, b) in yb.iter().zip(&ys) {
            assert!((a - b).abs() < 1e-9, "y {a} vs {b}");
        }
        assert!((rb.last.pobj - rs.last.pobj).abs() < 1e-9);
        assert!((rb.avg.dobj - rs.avg.dobj).abs() < 1e-9);

        let a = solve_rust(&lp, &DriveOpts::default());
        let b = solve_rust_scalar(&lp, &DriveOpts::default());
        assert!((a.obj - b.obj).abs() < 2e-3, "{} vs {}", a.obj, b.obj);
    }

    #[test]
    fn solves_knapsack() {
        let lp = knapsack();
        let sol = solve_rust(&lp, &DriveOpts::default());
        assert!((sol.obj + 1.5).abs() < 1e-3, "obj {}", sol.obj);
        assert!(sol.gap < 1e-3);
        assert!(sol.lower_bound <= sol.obj + 1e-6);
    }

    #[test]
    fn solves_lower_bounded_var() {
        // min x : -x <= -3, x in [0,10] -> 3
        let mut lp = SparseLp {
            n: 1,
            m: 1,
            b: vec![-3.0],
            c: vec![1.0],
            lo: vec![0.0],
            hi: vec![10.0],
            ..Default::default()
        };
        lp.push(0, 0, -1.0);
        let sol = solve_rust(&lp, &DriveOpts::default());
        assert!((sol.obj - 3.0).abs() < 1e-3, "obj {}", sol.obj);
    }

    #[test]
    fn dual_bound_is_valid() {
        let lp = knapsack();
        let sol = solve_rust(&lp, &DriveOpts::default());
        // optimum is exactly -1.5; lower bound must not exceed it
        assert!(sol.lower_bound <= -1.5 + 1e-6, "lb {}", sol.lower_bound);
        assert!(sol.lower_bound > -1.6);
    }

    #[test]
    fn state_stepping_matches_drive_exactly() {
        // PdhgState is the reified drive() loop: stepping it to the end
        // must reproduce the one-shot solve bit-for-bit
        let lp = knapsack();
        let opts = DriveOpts::default();
        let a = solve_rust(&lp, &opts);
        let mut st = PdhgState::new(&lp, &opts, |scaled| RustChunk::new(scaled, 250));
        let mut steps = 0;
        while !st.step() {
            steps += 1;
            assert!(steps < 10_000, "runaway state");
        }
        assert!(st.stop_reason().is_some());
        let b = st.into_solution(&lp);
        assert_eq!(a.obj, b.obj);
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.gap, b.gap);
        assert_eq!(a.z, b.z);
    }

    #[test]
    fn traced_stepping_matches_untraced_and_emits_residuals() {
        use crate::obs::{EventKind, RecordingSink};
        let lp = knapsack();
        let opts = DriveOpts::default();
        let a = solve_rust(&lp, &opts);
        let mut st = PdhgState::new(&lp, &opts, |scaled| RustChunk::new(scaled, 250));
        let mut sink = RecordingSink::new();
        while !st.step_traced(7, &mut sink) {}
        let b = st.into_solution(&lp);
        assert_eq!(a.obj, b.obj);
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.z, b.z);
        let events = sink.take();
        let chunks = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::LpChunk { lp: 7, .. }))
            .count();
        assert!(chunks >= 1, "at least one residual sample per solve");
        let dones: Vec<_> = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::LpDone { .. }))
            .collect();
        assert_eq!(dones.len(), 1, "exactly one lp-done span");
        if let EventKind::LpDone { lp, stop, iters } = &dones[0].kind {
            assert_eq!(*lp, 7);
            assert_eq!(*stop, "converged");
            assert_eq!(*iters as usize, b.iters);
        }
    }

    #[test]
    fn budget_stop_is_extendable() {
        let lp = knapsack();
        let opts = DriveOpts {
            tol: 1e-9,
            max_iters: 5,
            ..Default::default()
        };
        let mut st = PdhgState::new(&lp, &opts, |scaled| RustChunk::new(scaled, 5));
        while !st.step() {}
        assert_eq!(st.stop_reason(), Some(StopReason::Budget));
        let capped_iters = st.iters();
        st.extend_budget(100_000);
        assert!(st.stop_reason().is_none(), "budget stop must clear");
        while !st.step() {}
        assert!(st.iters() > capped_iters);
        let sol = st.into_solution(&lp);
        assert!((sol.obj + 1.5).abs() < 1e-3, "obj {}", sol.obj);
    }

    #[test]
    fn dual_warm_start_accepted_and_not_slower() {
        let lp = knapsack();
        let opts = DriveOpts::default();
        let mut st = PdhgState::new(&lp, &opts, |scaled| RustChunk::new(scaled, 250));
        while !st.step() {}
        let (z, y) = st.iterates();
        assert_eq!(z.len(), lp.n);
        assert_eq!(y.len(), lp.m);
        let cold = st.into_solution(&lp);
        let warm = solve_rust(
            &lp,
            &DriveOpts {
                warm_start: Some(z),
                warm_start_dual: Some(y),
                ..Default::default()
            },
        );
        assert!((warm.obj - cold.obj).abs() < 2e-3, "{} vs {}", warm.obj, cold.obj);
        // starting from the finished iterates, convergence should not
        // take longer than the cold run (one-chunk slack for the
        // first-chunk certificate)
        assert!(
            warm.iters <= cold.iters + 250,
            "warm {} way beyond cold {}",
            warm.iters,
            cold.iters
        );
    }

    #[test]
    fn unscaled_vs_scaled_same_answer() {
        let lp = knapsack();
        let a = solve_rust(
            &lp,
            &DriveOpts {
                ruiz_iters: 0,
                ..Default::default()
            },
        );
        let b = solve_rust(&lp, &DriveOpts::default());
        assert!((a.obj - b.obj).abs() < 2e-3);
    }
}
