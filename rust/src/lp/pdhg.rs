//! Restarted PDHG (the PDLP scheme) for the box LP — the same algorithm
//! that is AOT-compiled from JAX/Pallas (python/compile/model.py).
//!
//! Split in two pieces:
//! * [`ChunkBackend`] — "advance N iterations from (z, y) with steps
//!   (τ, σ), return the KKT diagnostics".  Implemented here in pure Rust
//!   ([`RustChunk`], f64 CSR) and by `runtime::PjrtChunk` (the compiled
//!   HLO artifact, f32).  Both see the *scaled* LP.
//! * [`drive`] — the backend-agnostic outer loop: Ruiz-scale, pick
//!   initial steps from the operator-norm bound, run chunks, rebalance
//!   the primal/dual step ratio (PDLP's primal-weight update), stop on a
//!   certified relative duality gap.

use super::scale::ruiz;
use super::{LpSolution, SparseLp};

/// KKT diagnostics returned by a chunk (order matches the artifact's
/// diag output: [pobj, dobj, pres, dres]).
#[derive(Clone, Copy, Debug, Default)]
pub struct Diag {
    pub pobj: f64,
    pub dobj: f64,
    pub pres: f64,
    pub dres: f64,
}

impl Diag {
    pub fn scale(&self) -> f64 {
        1.0 + self.pobj.abs() + self.dobj.abs()
    }
    pub fn gap(&self) -> f64 {
        (self.pobj - self.dobj).abs() / self.scale()
    }
    pub fn converged(&self, tol: f64) -> bool {
        let s = self.scale();
        self.gap() < tol && self.pres / s < tol && self.dres / s < tol
    }
}

/// KKT diagnostics for the last iterate and the in-chunk ergodic average
/// (the restart-to-average candidate, PDLP's accelerator).
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkResult {
    pub last: Diag,
    pub avg: Diag,
}

impl Diag {
    /// Scalar progress metric used to choose the restart candidate.
    pub fn score(&self) -> f64 {
        (self.pres + self.dres + (self.pobj - self.dobj).abs()) / self.scale()
    }
}

/// One PDHG chunk executor over a fixed (already scaled) LP.
pub trait ChunkBackend {
    /// Advance `iters_per_chunk()` iterations in place; also compute the
    /// in-chunk average iterate (kept inside the backend) and return
    /// diagnostics for both points.
    fn run_chunk(&mut self, z: &mut [f64], y: &mut [f64], tau: f64, sigma: f64) -> ChunkResult;
    /// Overwrite (z, y) with the average iterate of the last chunk
    /// (the driver calls this to restart-to-average).
    fn load_avg(&self, z: &mut [f64], y: &mut [f64]);
    fn iters_per_chunk(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// CSR matrix for fast row-major matvec.
#[derive(Clone, Debug)]
pub struct Csr {
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub data: Vec<f64>,
    pub n_rows: usize,
    pub n_cols: usize,
}

impl Csr {
    pub fn from_coo(
        n_rows: usize,
        n_cols: usize,
        rows: &[u32],
        cols: &[u32],
        vals: &[f64],
    ) -> Csr {
        let mut counts = vec![0u32; n_rows + 1];
        for &r in rows {
            counts[r as usize + 1] += 1;
        }
        for i in 0..n_rows {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let nnz = vals.len();
        let mut indices = vec![0u32; nnz];
        let mut data = vec![0.0f64; nnz];
        for i in 0..nnz {
            let r = rows[i] as usize;
            let at = cursor[r] as usize;
            indices[at] = cols[i];
            data[at] = vals[i];
            cursor[r] += 1;
        }
        Csr {
            indptr,
            indices,
            data,
            n_rows,
            n_cols,
        }
    }

    /// Transpose (for Aᵀ matvec as a second CSR).
    pub fn transpose(&self) -> Csr {
        let nnz = self.data.len();
        let mut rows_t = Vec::with_capacity(nnz);
        let mut cols_t = Vec::with_capacity(nnz);
        let mut vals_t = Vec::with_capacity(nnz);
        for r in 0..self.n_rows {
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                rows_t.push(self.indices[i]);
                cols_t.push(r as u32);
                vals_t.push(self.data[i]);
            }
        }
        Csr::from_coo(self.n_cols, self.n_rows, &rows_t, &cols_t, &vals_t)
    }

    /// out = A x
    pub fn matvec(&self, x: &[f64], out: &mut [f64]) {
        for r in 0..self.n_rows {
            let mut acc = 0.0;
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                acc += self.data[i] * x[self.indices[i] as usize];
            }
            out[r] = acc;
        }
    }
}

/// Pure-Rust chunk backend (f64); the algorithmic mirror of the JAX
/// artifact — one iteration is:
///   z⁺ = clip(z − τ(c + Aᵀy), lo, hi);  z̄ = 2z⁺ − z;
///   y⁺ = max(0, y + σ(Az̄ − b))
pub struct RustChunk {
    a: Csr,
    at: Csr,
    lp: SparseLp,
    iters: usize,
    // scratch
    g: Vec<f64>,
    az: Vec<f64>,
    zbar: Vec<f64>,
    // in-chunk ergodic averages (restart candidates)
    z_avg: Vec<f64>,
    y_avg: Vec<f64>,
}

impl RustChunk {
    pub fn new(lp: &SparseLp, iters: usize) -> RustChunk {
        let a = Csr::from_coo(lp.m, lp.n, &lp.rows, &lp.cols, &lp.vals);
        let at = a.transpose();
        RustChunk {
            a,
            at,
            lp: lp.clone(),
            iters,
            g: vec![0.0; lp.n],
            az: vec![0.0; lp.m],
            zbar: vec![0.0; lp.n],
            z_avg: vec![0.0; lp.n],
            y_avg: vec![0.0; lp.m],
        }
    }

    fn diagnostics(&mut self, z: &[f64], y: &[f64]) -> Diag {
        let lp = &self.lp;
        self.a.matvec(z, &mut self.az);
        self.at.matvec(y, &mut self.g);
        let mut pres = 0.0;
        for i in 0..lp.m {
            let v = (self.az[i] - lp.b[i]).max(0.0);
            pres += v * v;
        }
        let mut dres = 0.0;
        let mut pobj = 0.0;
        let mut dobj = 0.0;
        for j in 0..lp.n {
            let rc = lp.c[j] + self.g[j];
            let proj = (z[j] - rc).clamp(lp.lo[j], lp.hi[j]);
            let d = z[j] - proj;
            dres += d * d;
            pobj += lp.c[j] * z[j];
            dobj += (rc * lp.lo[j]).min(rc * lp.hi[j]);
        }
        for i in 0..lp.m {
            dobj -= lp.b[i] * y[i];
        }
        Diag {
            pobj,
            dobj,
            pres: pres.sqrt(),
            dres: dres.sqrt(),
        }
    }
}

impl ChunkBackend for RustChunk {
    fn run_chunk(&mut self, z: &mut [f64], y: &mut [f64], tau: f64, sigma: f64) -> ChunkResult {
        let n = self.lp.n;
        self.z_avg.iter_mut().for_each(|x| *x = 0.0);
        self.y_avg.iter_mut().for_each(|x| *x = 0.0);
        for _ in 0..self.iters {
            // g = c + A'y
            self.at.matvec(y, &mut self.g);
            for j in 0..n {
                let znew = (z[j] - tau * (self.lp.c[j] + self.g[j]))
                    .clamp(self.lp.lo[j], self.lp.hi[j]);
                self.zbar[j] = 2.0 * znew - z[j];
                z[j] = znew;
            }
            self.a.matvec(&self.zbar, &mut self.az);
            for i in 0..self.lp.m {
                y[i] = (y[i] + sigma * (self.az[i] - self.lp.b[i])).max(0.0);
            }
            for j in 0..n {
                self.z_avg[j] += z[j];
            }
            for i in 0..self.lp.m {
                self.y_avg[i] += y[i];
            }
        }
        let inv = 1.0 / self.iters as f64;
        self.z_avg.iter_mut().for_each(|x| *x *= inv);
        self.y_avg.iter_mut().for_each(|x| *x *= inv);
        let last = self.diagnostics(z, y);
        let za = std::mem::take(&mut self.z_avg);
        let ya = std::mem::take(&mut self.y_avg);
        let avg = self.diagnostics(&za, &ya);
        self.z_avg = za;
        self.y_avg = ya;
        ChunkResult { last, avg }
    }

    fn load_avg(&self, z: &mut [f64], y: &mut [f64]) {
        z.copy_from_slice(&self.z_avg);
        y.copy_from_slice(&self.y_avg);
    }

    fn iters_per_chunk(&self) -> usize {
        self.iters
    }

    fn name(&self) -> &'static str {
        "pdhg-rust"
    }
}

/// Options for the outer drive loop.
#[derive(Clone, Debug)]
pub struct DriveOpts {
    pub tol: f64,
    pub max_iters: usize,
    /// Ruiz preconditioning rounds (0 disables).
    pub ruiz_iters: usize,
    /// Feasible primal warm start in *original* coordinates.
    pub warm_start: Option<Vec<f64>>,
}

impl Default for DriveOpts {
    fn default() -> Self {
        DriveOpts {
            tol: 1e-4,
            max_iters: 400_000,
            ruiz_iters: 8,
            warm_start: None,
        }
    }
}

/// Drive a chunk backend built by `make_backend` on the Ruiz-scaled LP.
///
/// `make_backend` receives the scaled LP; the returned solution is in
/// *original* coordinates, with `lower_bound` the dual bound (valid for
/// the original LP since scaling preserves objective values).
pub fn drive<B: ChunkBackend>(
    lp: &SparseLp,
    opts: &DriveOpts,
    make_backend: impl FnOnce(&SparseLp) -> B,
) -> LpSolution {
    let (scaled, scaling) = ruiz(lp, opts.ruiz_iters);
    let norm = super::scale::opnorm_power(&scaled, 24);
    let eta = 0.9 / norm;
    // primal weight ω: τ = η/ω, σ = η·ω (τσ = η² ≤ (0.9/||A||)²)
    let mut omega: f64 = 1.0;

    let mut backend = make_backend(&scaled);
    // start from the warm start (scaled into z' = z / dc) or from the
    // box projection of 0
    let mut z: Vec<f64> = match &opts.warm_start {
        Some(w) => {
            assert_eq!(w.len(), lp.n, "warm start dimension");
            w.iter()
                .enumerate()
                .map(|(j, &v)| (v / scaling.dc[j]).clamp(scaled.lo[j], scaled.hi[j]))
                .collect()
        }
        None => (0..scaled.n)
            .map(|j| 0.0f64.clamp(scaled.lo[j], scaled.hi[j]))
            .collect(),
    };
    let mut y = vec![0.0; scaled.m];
    let mut iters = 0;
    let mut best_dobj = f64::NEG_INFINITY;
    // best-scoring iterate seen so far (returned at the end — PDHG with
    // restarts oscillates, so "last" is not necessarily the best)
    let mut best = Diag::default();
    let mut best_score = f64::INFINITY;
    let mut best_z = z.clone();
    // stall detection: an f32 backend can bottom out above a tight
    // tolerance; stop once the best KKT score stops improving and
    // return the best point with its honestly-certified gap.
    let mut chunks_since_improvement = 0usize;
    let mut score_at_last_check = f64::INFINITY;

    while iters < opts.max_iters {
        let tau = eta / omega;
        let sigma = eta * omega;
        let res = backend.run_chunk(&mut z, &mut y, tau, sigma);
        iters += backend.iters_per_chunk();
        // restart-to-average (PDLP): adopt the ergodic average whenever
        // its KKT score beats the last iterate's.
        let diag = if res.avg.score() < res.last.score() {
            backend.load_avg(&mut z, &mut y);
            res.avg
        } else {
            res.last
        };
        best_dobj = best_dobj.max(res.last.dobj.max(res.avg.dobj));
        if diag.score() < best_score {
            best_score = diag.score();
            best = diag;
            best_z.copy_from_slice(&z);
        }
        if best.converged(opts.tol) {
            break;
        }
        if best_score < score_at_last_check * 0.98 {
            score_at_last_check = best_score;
            chunks_since_improvement = 0;
        } else {
            chunks_since_improvement += 1;
            if chunks_since_improvement >= 40 {
                break; // practical floor for this backend/precision
            }
        }
        // Smoothed primal-weight rebalancing (PDLP's log-space update,
        // capped per chunk — aggressive jumps destabilize the iteration).
        // Residuals are floored at a fraction of the convergence target
        // so a residual that is already "good enough" exerts no pull.
        // pres high -> grow σ (ω up); dres high -> grow τ (ω down).
        let floor = 0.1 * opts.tol * diag.scale();
        let (p, d) = (diag.pres.max(floor), diag.dres.max(floor));
        let target = omega * (p / d).sqrt().sqrt();
        omega = (target.clamp(omega / 1.3, omega * 1.3)).clamp(1e-3, 1e3);
    }

    let z_orig = scaling.unscale_z(&best_z);
    LpSolution {
        obj: lp.objective(&z_orig),
        lower_bound: best_dobj,
        gap: best.gap(),
        z: z_orig,
        iters,
        backend: backend.name(),
    }
}

/// Solve with the in-tree Rust backend.
pub fn solve_rust(lp: &SparseLp, opts: &DriveOpts) -> LpSolution {
    drive(lp, opts, |scaled| RustChunk::new(scaled, 250))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knapsack() -> SparseLp {
        // min -x1-x2 : x1+x2 <= 1.5, x in [0,1]^2  -> -1.5
        let mut lp = SparseLp {
            n: 2,
            m: 1,
            b: vec![1.5],
            c: vec![-1.0, -1.0],
            lo: vec![0.0; 2],
            hi: vec![1.0; 2],
            ..Default::default()
        };
        lp.push(0, 0, 1.0);
        lp.push(0, 1, 1.0);
        lp
    }

    #[test]
    fn csr_roundtrip_and_matvec() {
        let rows = vec![0u32, 0, 1, 2];
        let cols = vec![0u32, 2, 1, 0];
        let vals = vec![1.0, 2.0, 3.0, 4.0];
        let a = Csr::from_coo(3, 3, &rows, &cols, &vals);
        let mut out = vec![0.0; 3];
        a.matvec(&[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, vec![3.0, 3.0, 4.0]);
        let at = a.transpose();
        let mut out_t = vec![0.0; 3];
        at.matvec(&[1.0, 1.0, 1.0], &mut out_t);
        assert_eq!(out_t, vec![5.0, 3.0, 2.0]);
    }

    #[test]
    fn solves_knapsack() {
        let lp = knapsack();
        let sol = solve_rust(&lp, &DriveOpts::default());
        assert!((sol.obj + 1.5).abs() < 1e-3, "obj {}", sol.obj);
        assert!(sol.gap < 1e-3);
        assert!(sol.lower_bound <= sol.obj + 1e-6);
    }

    #[test]
    fn solves_lower_bounded_var() {
        // min x : -x <= -3, x in [0,10] -> 3
        let mut lp = SparseLp {
            n: 1,
            m: 1,
            b: vec![-3.0],
            c: vec![1.0],
            lo: vec![0.0],
            hi: vec![10.0],
            ..Default::default()
        };
        lp.push(0, 0, -1.0);
        let sol = solve_rust(&lp, &DriveOpts::default());
        assert!((sol.obj - 3.0).abs() < 1e-3, "obj {}", sol.obj);
    }

    #[test]
    fn dual_bound_is_valid() {
        let lp = knapsack();
        let sol = solve_rust(&lp, &DriveOpts::default());
        // optimum is exactly -1.5; lower bound must not exceed it
        assert!(sol.lower_bound <= -1.5 + 1e-6, "lb {}", sol.lower_bound);
        assert!(sol.lower_bound > -1.6);
    }

    #[test]
    fn unscaled_vs_scaled_same_answer() {
        let lp = knapsack();
        let a = solve_rust(
            &lp,
            &DriveOpts {
                ruiz_iters: 0,
                ..Default::default()
            },
        );
        let b = solve_rust(&lp, &DriveOpts::default());
        assert!((a.obj - b.obj).abs() < 2e-3);
    }
}
