//! HLP / QHLP construction (the paper's allocation LPs).
//!
//! HLP (Section 3, constraints (1)–(6)); variable layout — chosen to
//! match `python/tests/test_pdhg.py::build_hlp` exactly so the two
//! implementations cross-check each other:
//!
//!   z = [ x_0 .. x_{n-1},  C_0 .. C_{n-1},  λ ]
//!   x_j ∈ [0,1];  C_j, λ ∈ [0, U]   (U = Σ_j p̄_j, a trivial upper bound)
//!
//! QHLP (Section 5, constraints (9)–(14)); layout:
//!
//!   z = [ x_{0,0} .. x_{0,Q-1}, x_{1,0} .., ...,  C_0 .. C_{n-1},  λ ]
//!
//! with the assignment equality (13) split into two inequalities.

use crate::graph::TaskGraph;
use crate::platform::Platform;

use super::SparseLp;

/// Which tasks get an explicit `C_j ≤ λ` row.
///
/// The paper writes constraint (3)/(11) for every task, but the arc
/// constraints make `C` non-decreasing along every path, so bounding the
/// *sinks* is equivalent (identical optimal value and x/λ projection of
/// the feasible set) while shrinking the λ column from n rows to
/// #sinks + Q rows — which matters enormously for PDHG, whose step size
/// scales with 1/‖A‖₂ ≈ 1/√(λ-column count).  Equivalence is asserted
/// against the full formulation via simplex in tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapRows {
    /// `C_j ≤ λ` for every task (the paper's literal formulation).
    All,
    /// `C_j ≤ λ` for sink tasks only (equivalent, PDHG-friendly).
    SinksOnly,
}

/// Variable indices of a built HLP.
#[derive(Clone, Copy, Debug)]
pub struct HlpVars {
    pub n_tasks: usize,
    /// x_j = `j`; C_j = `n_tasks + j`; λ = `2 n_tasks`.
    pub lambda: usize,
}

impl HlpVars {
    pub fn x(&self, j: usize) -> usize {
        j
    }
    pub fn completion(&self, j: usize) -> usize {
        self.n_tasks + j
    }
}

/// Build HLP for a hybrid platform (`m` CPUs, `k` GPUs) with sink-only
/// cap rows (see [`CapRows`]).
pub fn build_hlp(g: &TaskGraph, plat: &Platform) -> (SparseLp, HlpVars) {
    build_hlp_opts(g, plat, CapRows::SinksOnly)
}

/// Build HLP with an explicit cap-row policy.
pub fn build_hlp_opts(g: &TaskGraph, plat: &Platform, caps: CapRows) -> (SparseLp, HlpVars) {
    assert_eq!(g.n_types(), 2, "HLP is the 2-type LP; use build_qhlp");
    assert_eq!(plat.n_types(), 2);
    let n = g.n_tasks();
    let (m, k) = (plat.m() as f64, plat.k() as f64);
    let n_arcs = g.n_arcs();
    let n_src = g.sources().len();
    let capped: Vec<usize> = match caps {
        CapRows::All => (0..n).collect(),
        CapRows::SinksOnly => g.sinks(),
    };

    let vars = HlpVars {
        n_tasks: n,
        lambda: 2 * n,
    };
    let n_vars = 2 * n + 1;
    let n_rows = n_arcs + n_src + capped.len() + 2;

    let mut lp = SparseLp {
        n: n_vars,
        m: n_rows,
        b: Vec::with_capacity(n_rows),
        c: vec![0.0; n_vars],
        lo: vec![0.0; n_vars],
        hi: vec![0.0; n_vars],
        ..Default::default()
    };
    lp.c[vars.lambda] = 1.0;
    let u: f64 = (0..n).map(|j| g.p_cpu(j)).sum();
    for j in 0..n {
        lp.hi[vars.x(j)] = 1.0;
        lp.hi[vars.completion(j)] = u;
    }
    lp.hi[vars.lambda] = u;

    let mut row = 0;
    // (1) C_i + p̄_j x_j + p̠_j (1 - x_j) ≤ C_j  for each arc (i, j)
    //  => C_i + (p̄_j - p̠_j) x_j - C_j ≤ -p̠_j
    for i in 0..n {
        for &j in &g.succs[i] {
            lp.push(row, vars.completion(i), 1.0);
            lp.push(row, vars.x(j), g.p_cpu(j) - g.p_gpu(j));
            lp.push(row, vars.completion(j), -1.0);
            lp.b.push(-g.p_gpu(j));
            row += 1;
        }
    }
    // (2) p̄_j x_j + p̠_j (1 - x_j) ≤ C_j  for sources
    for j in 0..n {
        if g.preds[j].is_empty() {
            lp.push(row, vars.x(j), g.p_cpu(j) - g.p_gpu(j));
            lp.push(row, vars.completion(j), -1.0);
            lp.b.push(-g.p_gpu(j));
            row += 1;
        }
    }
    // (3) C_j ≤ λ (sinks suffice; see CapRows)
    for &j in &capped {
        lp.push(row, vars.completion(j), 1.0);
        lp.push(row, vars.lambda, -1.0);
        lp.b.push(0.0);
        row += 1;
    }
    // (4) (1/m) Σ p̄_j x_j ≤ λ
    for j in 0..n {
        lp.push(row, vars.x(j), g.p_cpu(j) / m);
    }
    lp.push(row, vars.lambda, -1.0);
    lp.b.push(0.0);
    row += 1;
    // (5) (1/k) Σ p̠_j (1 - x_j) ≤ λ  =>  -(1/k) Σ p̠_j x_j - λ ≤ -(1/k) Σ p̠_j
    let gpu_total: f64 = (0..n).map(|j| g.p_gpu(j)).sum();
    for j in 0..n {
        lp.push(row, vars.x(j), -g.p_gpu(j) / k);
    }
    lp.push(row, vars.lambda, -1.0);
    lp.b.push(-gpu_total / k);
    row += 1;

    debug_assert_eq!(row, n_rows);
    debug_assert!(lp.validate().is_ok());
    (lp, vars)
}

/// Variable indices of a built QHLP.
#[derive(Clone, Copy, Debug)]
pub struct QhlpVars {
    pub n_tasks: usize,
    pub n_types: usize,
    pub lambda: usize,
}

impl QhlpVars {
    pub fn x(&self, j: usize, q: usize) -> usize {
        j * self.n_types + q
    }
    pub fn completion(&self, j: usize) -> usize {
        self.n_tasks * self.n_types + j
    }
}

/// Build QHLP for a general platform with `Q ≥ 2` types (sink-only caps).
pub fn build_qhlp(g: &TaskGraph, plat: &Platform) -> (SparseLp, QhlpVars) {
    build_qhlp_opts(g, plat, CapRows::SinksOnly)
}

/// Build QHLP with an explicit cap-row policy.
pub fn build_qhlp_opts(g: &TaskGraph, plat: &Platform, caps: CapRows) -> (SparseLp, QhlpVars) {
    let q = plat.n_types();
    assert_eq!(g.n_types(), q);
    assert!(q >= 2);
    let n = g.n_tasks();
    let n_arcs = g.n_arcs();
    let n_src = g.sources().len();
    let capped: Vec<usize> = match caps {
        CapRows::All => (0..n).collect(),
        CapRows::SinksOnly => g.sinks(),
    };

    let vars = QhlpVars {
        n_tasks: n,
        n_types: q,
        lambda: n * q + n,
    };
    let n_vars = n * q + n + 1;
    // rows: arcs + sources + caps + Q loads + 2n assignment inequalities
    let n_rows = n_arcs + n_src + capped.len() + q + 2 * n;

    let mut lp = SparseLp {
        n: n_vars,
        m: n_rows,
        b: Vec::with_capacity(n_rows),
        c: vec![0.0; n_vars],
        lo: vec![0.0; n_vars],
        hi: vec![0.0; n_vars],
        ..Default::default()
    };
    lp.c[vars.lambda] = 1.0;
    let u: f64 = (0..n).map(|j| g.time_on(j, 0)).sum();
    for j in 0..n {
        for t in 0..q {
            lp.hi[vars.x(j, t)] = 1.0;
        }
        lp.hi[vars.completion(j)] = u;
    }
    lp.hi[vars.lambda] = u;

    let mut row = 0;
    // (9) C_i + Σ_q p_{j,q} x_{j,q} ≤ C_j for each arc (i, j)
    for i in 0..n {
        for &j in &g.succs[i] {
            lp.push(row, vars.completion(i), 1.0);
            for t in 0..q {
                lp.push(row, vars.x(j, t), g.time_on(j, t));
            }
            lp.push(row, vars.completion(j), -1.0);
            lp.b.push(0.0);
            row += 1;
        }
    }
    // (10) sources
    for j in 0..n {
        if g.preds[j].is_empty() {
            for t in 0..q {
                lp.push(row, vars.x(j, t), g.time_on(j, t));
            }
            lp.push(row, vars.completion(j), -1.0);
            lp.b.push(0.0);
            row += 1;
        }
    }
    // (11) C_j ≤ λ (sinks suffice; see CapRows)
    for &j in &capped {
        lp.push(row, vars.completion(j), 1.0);
        lp.push(row, vars.lambda, -1.0);
        lp.b.push(0.0);
        row += 1;
    }
    // (12) per-type load
    for t in 0..q {
        let mq = plat.counts[t] as f64;
        for j in 0..n {
            lp.push(row, vars.x(j, t), g.time_on(j, t) / mq);
        }
        lp.push(row, vars.lambda, -1.0);
        lp.b.push(0.0);
        row += 1;
    }
    // (13) Σ_q x_{j,q} = 1, as ≤ 1 and ≥ 1
    for j in 0..n {
        for t in 0..q {
            lp.push(row, vars.x(j, t), 1.0);
        }
        lp.b.push(1.0);
        row += 1;
        for t in 0..q {
            lp.push(row, vars.x(j, t), -1.0);
        }
        lp.b.push(-1.0);
        row += 1;
    }

    debug_assert_eq!(row, n_rows);
    debug_assert!(lp.validate().is_ok());
    (lp, vars)
}

/// Tighten the box bounds of a built HLP: any feasible schedule value
/// `lambda_hi` (e.g. the warm start's λ) upper-bounds λ*, and some
/// optimal solution keeps every `C_j ≤ λ*`, so shrinking
/// `hi[C_j] = hi[λ] = lambda_hi` preserves the optimum while improving
/// PDHG's dual bound enormously (the dual objective pays
/// `min(rc·lo, rc·hi)` per variable — a loose `hi = Σp̄` lets slightly
/// negative reduced costs wreck it).
pub fn tighten_hlp_box(lp: &mut SparseLp, vars: &HlpVars, lambda_hi: f64) {
    let hi = lambda_hi * (1.0 + 1e-9);
    for j in 0..vars.n_tasks {
        lp.hi[vars.completion(j)] = lp.hi[vars.completion(j)].min(hi);
    }
    lp.hi[vars.lambda] = lp.hi[vars.lambda].min(hi);
}

/// Same for QHLP.
pub fn tighten_qhlp_box(lp: &mut SparseLp, vars: &QhlpVars, lambda_hi: f64) {
    let hi = lambda_hi * (1.0 + 1e-9);
    for j in 0..vars.n_tasks {
        lp.hi[vars.completion(j)] = lp.hi[vars.completion(j)].min(hi);
    }
    lp.hi[vars.lambda] = lp.hi[vars.lambda].min(hi);
}

/// Feasible warm start for HLP from a concrete allocation: x per
/// `alloc`, C = completion under infinite units (top level + own time),
/// λ = max(critical path, load bounds).  Cuts PDHG iteration counts by a
/// large factor (EXPERIMENTS.md §Perf).
pub fn hlp_warm_start(g: &TaskGraph, plat: &Platform, alloc: &[usize], vars: &HlpVars) -> Vec<f64> {
    let n = g.n_tasks();
    let len = |j: usize| g.time_on(j, alloc[j]);
    let tl = crate::graph::paths::top_level(g, &len);
    let mut z = vec![0.0; 2 * n + 1];
    let mut loads = vec![0.0f64; 2];
    let mut cp: f64 = 0.0;
    for j in 0..n {
        z[vars.x(j)] = if alloc[j] == 0 { 1.0 } else { 0.0 };
        let c = tl[j] + len(j);
        z[vars.completion(j)] = c;
        cp = cp.max(c);
        loads[alloc[j]] += len(j);
    }
    z[vars.lambda] = cp
        .max(loads[0] / plat.m() as f64)
        .max(loads[1] / plat.k() as f64);
    z
}

/// Feasible warm start for QHLP (same construction, Q types).
pub fn qhlp_warm_start(
    g: &TaskGraph,
    plat: &Platform,
    alloc: &[usize],
    vars: &QhlpVars,
) -> Vec<f64> {
    let n = g.n_tasks();
    let q = vars.n_types;
    let len = |j: usize| g.time_on(j, alloc[j]);
    let tl = crate::graph::paths::top_level(g, &len);
    let mut z = vec![0.0; n * q + n + 1];
    let mut loads = vec![0.0f64; q];
    let mut cp: f64 = 0.0;
    for j in 0..n {
        z[vars.x(j, alloc[j])] = 1.0;
        let c = tl[j] + len(j);
        z[vars.completion(j)] = c;
        cp = cp.max(c);
        loads[alloc[j]] += len(j);
    }
    let mut lam = cp;
    for t in 0..q {
        lam = lam.max(loads[t] / plat.counts[t] as f64);
    }
    z[vars.lambda] = lam;
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;
    use crate::platform::Platform;

    fn diamond() -> TaskGraph {
        let mut b = Builder::new("diamond");
        let t0 = b.add_task("a", vec![4.0, 1.0]);
        let t1 = b.add_task("b", vec![2.0, 5.0]);
        let t2 = b.add_task("c", vec![6.0, 1.0]);
        let t3 = b.add_task("d", vec![4.0, 1.0]);
        b.add_arc(t0, t1);
        b.add_arc(t0, t2);
        b.add_arc(t1, t3);
        b.add_arc(t2, t3);
        b.build()
    }

    #[test]
    fn hlp_shape() {
        let g = diamond();
        let (lp, vars) = build_hlp_opts(&g, &Platform::hybrid(2, 1), CapRows::All);
        assert_eq!(lp.n, 9);
        assert_eq!(lp.m, 4 + 1 + 4 + 2);
        assert_eq!(vars.lambda, 8);
        assert_eq!(lp.c[8], 1.0);
        assert_eq!(lp.hi[0], 1.0);
        assert_eq!(lp.hi[4], 16.0); // U = 4+2+6+4
        lp.validate().unwrap();
        // sinks-only drops 3 cap rows (single sink)
        let (lp2, _) = build_hlp(&g, &Platform::hybrid(2, 1));
        assert_eq!(lp2.m, 4 + 1 + 1 + 2);
        lp2.validate().unwrap();
    }

    #[test]
    fn sinks_only_caps_equivalent_to_full() {
        use crate::graph::gen;
        use crate::lp::simplex::solve_simplex;
        use crate::substrate::rng::Rng;
        let mut rng = Rng::new(41);
        for _ in 0..8 {
            let g = gen::hybrid_dag(&mut rng, 12, 0.25);
            let plat = Platform::hybrid(3, 2);
            let (full, _) = build_hlp_opts(&g, &plat, CapRows::All);
            let (slim, _) = build_hlp_opts(&g, &plat, CapRows::SinksOnly);
            let a = solve_simplex(&full).unwrap().obj;
            let b = solve_simplex(&slim).unwrap().obj;
            assert!((a - b).abs() < 1e-7 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn warm_start_is_feasible() {
        use crate::graph::gen;
        use crate::substrate::rng::Rng;
        let mut rng = Rng::new(43);
        for _ in 0..8 {
            let g = gen::hybrid_dag(&mut rng, 25, 0.15);
            let plat = Platform::hybrid(4, 2);
            let alloc: Vec<usize> = (0..25)
                .map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j)))
                .collect();
            let (lp, vars) = build_hlp(&g, &plat);
            let z = hlp_warm_start(&g, &plat, &alloc, &vars);
            assert!(lp.max_violation(&z) < 1e-9, "viol {}", lp.max_violation(&z));
            // and within bounds
            for j in 0..lp.n {
                assert!(z[j] >= lp.lo[j] - 1e-12 && z[j] <= lp.hi[j] + 1e-9);
            }
            // QHLP variant
            let (qlp, qvars) = build_qhlp(&g, &plat);
            let qz = qhlp_warm_start(&g, &plat, &alloc, &qvars);
            assert!(qlp.max_violation(&qz) < 1e-9);
        }
    }

    #[test]
    fn hlp_feasible_point_all_cpu_serial() {
        // all on CPU executed serially: x=1, C_j = cumulative, λ = U
        let g = diamond();
        let (lp, vars) = build_hlp(&g, &Platform::hybrid(2, 1));
        let mut z = vec![0.0; lp.n];
        for j in 0..4 {
            z[vars.x(j)] = 1.0;
        }
        // serial completion in topo order 0,1,2,3
        z[vars.completion(0)] = 4.0;
        z[vars.completion(1)] = 6.0;
        z[vars.completion(2)] = 12.0;
        z[vars.completion(3)] = 16.0;
        z[vars.lambda] = 16.0;
        assert!(lp.max_violation(&z) < 1e-12, "viol {}", lp.max_violation(&z));
    }

    #[test]
    fn hlp_infeasible_if_lambda_below_critical_path() {
        let g = diamond();
        let (lp, vars) = build_hlp(&g, &Platform::hybrid(2, 1));
        // all GPU: CP = 1 + 1 + 1 = 3 via (0,2,3); λ = 2 must violate
        let mut z = vec![0.0; lp.n];
        z[vars.completion(0)] = 1.0;
        z[vars.completion(1)] = 6.0;
        z[vars.completion(2)] = 2.0;
        z[vars.completion(3)] = 3.0;
        z[vars.lambda] = 2.0;
        assert!(lp.max_violation(&z) > 0.5);
    }

    #[test]
    fn qhlp_shape_and_q2_equivalence_dimensions() {
        let g = diamond();
        let (lp, vars) = build_qhlp_opts(&g, &Platform::hybrid(2, 1), CapRows::All);
        assert_eq!(lp.n, 4 * 2 + 4 + 1);
        assert_eq!(lp.m, 4 + 1 + 4 + 2 + 8);
        assert_eq!(vars.x(1, 1), 3);
        assert_eq!(vars.completion(0), 8);
        lp.validate().unwrap();
        let (lp2, _) = build_qhlp(&g, &Platform::hybrid(2, 1));
        assert_eq!(lp2.m, 4 + 1 + 1 + 2 + 8);
    }

    #[test]
    fn qhlp_assignment_equality_enforced() {
        let g = diamond();
        let (lp, vars) = build_qhlp(&g, &Platform::hybrid(2, 1));
        let mut z = vec![0.0; lp.n];
        // x all zero violates Σ x = 1 (the ≥ rows)
        for j in 0..4 {
            z[vars.completion(j)] = 100.0;
        }
        z[vars.lambda] = 1000.0;
        assert!(lp.max_violation(&z) >= 1.0 - 1e-12);
    }

    #[test]
    fn qhlp_three_types() {
        let mut b = Builder::new("t");
        let a = b.add_task("a", vec![3.0, 1.0, 2.0]);
        let c = b.add_task("b", vec![5.0, 4.0, 1.0]);
        b.add_arc(a, c);
        let g = b.build();
        let plat = Platform::new(vec![4, 2, 1]);
        let (lp, vars) = build_qhlp(&g, &plat);
        assert_eq!(lp.n, 2 * 3 + 2 + 1);
        assert_eq!(vars.lambda, 8);
        // feasible: both tasks on type 0, serially
        let mut z = vec![0.0; lp.n];
        z[vars.x(0, 0)] = 1.0;
        z[vars.x(1, 0)] = 1.0;
        z[vars.completion(0)] = 3.0;
        z[vars.completion(1)] = 8.0;
        z[vars.lambda] = 8.0;
        assert!(lp.max_violation(&z) < 1e-12);
    }
}
