//! Ruiz equilibration: iterative row/column scaling that brings every
//! row and column of A to unit max-norm.  PDHG's convergence constant
//! depends on the conditioning of A; the HLP mixes O(1) precedence
//! coefficients with O(p/m) load coefficients, so this matters a lot
//! (see EXPERIMENTS.md §Perf for the measured effect).
//!
//! With diagonal Dr, Dc and  A' = Dr A Dc,  z = Dc z':
//!   A z ≤ b   ⇔  A' z' ≤ Dr b
//!   cᵀ z      =  (Dc c)ᵀ z'        (objective value unchanged)
//!   lo ≤ z ≤ hi ⇔ lo/dc ≤ z' ≤ hi/dc

use super::SparseLp;

#[derive(Clone, Debug)]
pub struct Scaling {
    pub dr: Vec<f64>,
    pub dc: Vec<f64>,
}

impl Scaling {
    /// Map a scaled primal point back to original coordinates.
    pub fn unscale_z(&self, z_scaled: &[f64]) -> Vec<f64> {
        z_scaled.iter().zip(&self.dc).map(|(z, d)| z * d).collect()
    }

    /// Map a scaled dual point back to original coordinates
    /// (y = Dr y' for rows scaled as Dr A).
    pub fn unscale_y(&self, y_scaled: &[f64]) -> Vec<f64> {
        y_scaled.iter().zip(&self.dr).map(|(y, d)| y * d).collect()
    }
}

/// Apply `iters` rounds of Ruiz scaling; returns the scaled LP and the
/// diagonal scalings.  Empty rows/columns keep scale 1.
pub fn ruiz(lp: &SparseLp, iters: usize) -> (SparseLp, Scaling) {
    let mut out = lp.clone();
    let mut dr = vec![1.0f64; lp.m];
    let mut dc = vec![1.0f64; lp.n];

    for _ in 0..iters {
        let mut row_max = vec![0.0f64; lp.m];
        let mut col_max = vec![0.0f64; lp.n];
        for i in 0..out.vals.len() {
            let a = out.vals[i].abs();
            let r = out.rows[i] as usize;
            let c = out.cols[i] as usize;
            row_max[r] = row_max[r].max(a);
            col_max[c] = col_max[c].max(a);
        }
        let sr: Vec<f64> = row_max
            .iter()
            .map(|&x| if x > 0.0 { 1.0 / x.sqrt() } else { 1.0 })
            .collect();
        let sc: Vec<f64> = col_max
            .iter()
            .map(|&x| if x > 0.0 { 1.0 / x.sqrt() } else { 1.0 })
            .collect();
        for i in 0..out.vals.len() {
            out.vals[i] *= sr[out.rows[i] as usize] * sc[out.cols[i] as usize];
        }
        for (d, s) in dr.iter_mut().zip(&sr) {
            *d *= s;
        }
        for (d, s) in dc.iter_mut().zip(&sc) {
            *d *= s;
        }
    }
    // b' = Dr b ; c' = Dc c ; bounds' = bounds / dc
    for (bi, d) in out.b.iter_mut().zip(&dr) {
        *bi *= d;
    }
    for j in 0..out.n {
        out.c[j] *= dc[j];
        out.lo[j] /= dc[j];
        out.hi[j] /= dc[j];
    }
    (out, Scaling { dr, dc })
}

/// Estimate ||A||_2 by power iteration on AᵀA (tight, so PDHG can take
/// the largest stable step).  Falls back to the norm-product bound if
/// the iteration degenerates.  ~1.02 safety factor is applied by callers
/// via the 0.9 step margin.
pub fn opnorm_power(lp: &SparseLp, iters: usize) -> f64 {
    if lp.vals.is_empty() {
        return 1e-12;
    }
    let mut v = vec![1.0f64; lp.n];
    let mut av = vec![0.0f64; lp.m];
    let mut atav = vec![0.0f64; lp.n];
    let mut norm = 0.0f64;
    for _ in 0..iters {
        av.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..lp.vals.len() {
            av[lp.rows[i] as usize] += lp.vals[i] * v[lp.cols[i] as usize];
        }
        atav.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..lp.vals.len() {
            atav[lp.cols[i] as usize] += lp.vals[i] * av[lp.rows[i] as usize];
        }
        let nrm2: f64 = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm2 <= 1e-300 {
            return opnorm_bound(lp);
        }
        norm = nrm2.sqrt(); // ||A||^2 ~ ||AᵀA v||/||v|| with ||v||=1
        for (vi, ai) in v.iter_mut().zip(&atav) {
            *vi = ai / nrm2;
        }
    }
    // power iteration underestimates; blend with the safe upper bound
    (norm * 1.05).min(opnorm_bound(lp)).max(1e-12)
}

/// Cheap upper bound on ||A||_2: sqrt(||A||_1 ||A||_inf).
pub fn opnorm_bound(lp: &SparseLp) -> f64 {
    let mut row_sum = vec![0.0f64; lp.m];
    let mut col_sum = vec![0.0f64; lp.n];
    for i in 0..lp.vals.len() {
        let a = lp.vals[i].abs();
        row_sum[lp.rows[i] as usize] += a;
        col_sum[lp.cols[i] as usize] += a;
    }
    let rmax = row_sum.iter().copied().fold(0.0, f64::max);
    let cmax = col_sum.iter().copied().fold(0.0, f64::max);
    (rmax * cmax).sqrt().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_lp() -> SparseLp {
        let mut lp = SparseLp {
            n: 3,
            m: 2,
            b: vec![4.0, -1.0],
            c: vec![1.0, -2.0, 0.5],
            lo: vec![0.0; 3],
            hi: vec![1.0, 2.0, 10.0],
            ..Default::default()
        };
        lp.push(0, 0, 100.0);
        lp.push(0, 1, 0.01);
        lp.push(1, 1, -5.0);
        lp.push(1, 2, 2.0);
        lp
    }

    #[test]
    fn scaling_equilibrates_magnitudes() {
        let lp = toy_lp();
        let (scaled, _) = ruiz(&lp, 10);
        let mut row_max = vec![0.0f64; scaled.m];
        let mut col_max = vec![0.0f64; scaled.n];
        for i in 0..scaled.vals.len() {
            row_max[scaled.rows[i] as usize] =
                row_max[scaled.rows[i] as usize].max(scaled.vals[i].abs());
            col_max[scaled.cols[i] as usize] =
                col_max[scaled.cols[i] as usize].max(scaled.vals[i].abs());
        }
        for &x in row_max.iter().chain(col_max.iter()) {
            assert!((x - 1.0).abs() < 0.05, "max {x}");
        }
    }

    #[test]
    fn feasibility_preserved_under_scaling() {
        let lp = toy_lp();
        let (scaled, s) = ruiz(&lp, 6);
        // a feasible original point
        let z = vec![0.02, 0.5, 0.0];
        assert!(lp.max_violation(&z) < 1e-12);
        // its scaled image z' = z / dc
        let z_scaled: Vec<f64> = z.iter().zip(&s.dc).map(|(z, d)| z / d).collect();
        assert!(scaled.max_violation(&z_scaled) < 1e-9);
        // objective value identical
        assert!((lp.objective(&z) - scaled.objective(&z_scaled)).abs() < 1e-9);
        // unscale round-trips
        let back = s.unscale_z(&z_scaled);
        for (a, b) in back.iter().zip(&z) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn opnorm_bound_dominates_true_norm() {
        let lp = toy_lp();
        // crude power iteration on dense A to compare
        let a = [[100.0, 0.01, 0.0], [0.0, -5.0, 2.0]];
        let mut v = [1.0f64, 1.0, 1.0];
        let mut norm = 0.0;
        for _ in 0..100 {
            let av = [
                a[0][0] * v[0] + a[0][1] * v[1] + a[0][2] * v[2],
                a[1][0] * v[0] + a[1][1] * v[1] + a[1][2] * v[2],
            ];
            let atav = [
                a[0][0] * av[0] + a[1][0] * av[1],
                a[0][1] * av[0] + a[1][1] * av[1],
                a[0][2] * av[0] + a[1][2] * av[1],
            ];
            norm = (atav.iter().map(|x| x * x).sum::<f64>()).sqrt().sqrt();
            let nv = atav.map(|x| x / (norm * norm));
            v = nv;
        }
        assert!(opnorm_bound(&lp) >= norm * 0.99);
    }
}
