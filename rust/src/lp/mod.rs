//! The allocation-phase linear programs of the paper and their solvers.
//!
//! * [`model`] — build HLP (2 types, constraints (1)–(6)) and QHLP
//!   (Q types, constraints (9)–(14)) from a task graph + platform, in the
//!   generic box form `min cᵀz : Az ≤ b, lo ≤ z ≤ hi` (COO).
//! * [`scale`] — Ruiz equilibration (preconditioning for PDHG).
//! * [`pdhg`] — restarted PDHG: the backend-generic chunk driver (used by
//!   both the in-tree Rust mirror and the AOT JAX/Pallas artifact run via
//!   PJRT), the reified per-solve [`pdhg::PdhgState`], and the Rust chunk
//!   backend itself.
//! * [`chain`] — series-chain contraction: merge the arc rows of linear
//!   chains into single aggregate rows (provably equivalent for the
//!   fractional relaxation) before solving.
//! * [`warm`] — grid warm-starting policy: config-grid distance and the
//!   escalating convergence-budget schedule (the iterate chaining itself
//!   lives in [`batch`]).
//! * [`batch`] — the batched multi-LP PDHG driver: many solves advanced
//!   chunk-by-chunk over one shared worker pool, with warm-start
//!   chaining across the campaign grid.
//! * [`simplex`] — exact dense two-phase simplex (test oracle + small
//!   instances).
//! * [`rounding`] — the paper's rounding rules (`x_j ≥ ½` for HLP,
//!   argmax with min-time tie-break for QHLP).

pub mod batch;
pub mod chain;
pub mod model;
pub mod pdhg;
pub mod rounding;
pub mod scale;
pub mod simplex;
pub mod warm;

/// A linear program `min cᵀz  s.t.  Az ≤ b,  lo ≤ z ≤ hi` with sparse A.
#[derive(Clone, Debug, Default)]
pub struct SparseLp {
    /// number of variables
    pub n: usize,
    /// number of rows
    pub m: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
    pub b: Vec<f64>,
    pub c: Vec<f64>,
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl SparseLp {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn push(&mut self, row: usize, col: usize, val: f64) {
        debug_assert!(row < self.m && col < self.n);
        // hetlint: allow(no-raw-float-eq) -- structural sparsity: exact zeros are dropped from the triplet store, not a tolerance test
        if val != 0.0 {
            self.rows.push(row as u32);
            self.cols.push(col as u32);
            self.vals.push(val);
        }
    }

    /// Objective value of a point.
    pub fn objective(&self, z: &[f64]) -> f64 {
        self.c.iter().zip(z).map(|(c, z)| c * z).sum()
    }

    /// Max violation of `Az ≤ b` at `z` (0 if feasible).
    pub fn max_violation(&self, z: &[f64]) -> f64 {
        let mut az = vec![0.0; self.m];
        for i in 0..self.vals.len() {
            az[self.rows[i] as usize] += self.vals[i] * z[self.cols[i] as usize];
        }
        az.iter()
            .zip(&self.b)
            .map(|(a, b)| (a - b).max(0.0))
            .fold(0.0, f64::max)
    }

    /// Structural sanity checks (indices in range, bounds ordered).
    pub fn validate(&self) -> Result<(), String> {
        if self.b.len() != self.m || self.c.len() != self.n {
            return Err("b/c length mismatch".into());
        }
        if self.lo.len() != self.n || self.hi.len() != self.n {
            return Err("bounds length mismatch".into());
        }
        for j in 0..self.n {
            if self.lo[j] > self.hi[j] {
                return Err(format!("lo > hi at var {j}"));
            }
        }
        for i in 0..self.vals.len() {
            if self.rows[i] as usize >= self.m || self.cols[i] as usize >= self.n {
                return Err("COO index out of range".into());
            }
            if !self.vals[i].is_finite() {
                return Err("non-finite coefficient".into());
            }
        }
        Ok(())
    }
}

/// Result of an LP solve (any backend).
#[derive(Clone, Debug)]
pub struct LpSolution {
    pub z: Vec<f64>,
    /// primal objective at `z`
    pub obj: f64,
    /// best dual lower bound on the optimum (= obj for exact backends)
    pub lower_bound: f64,
    /// relative duality gap achieved
    pub gap: f64,
    /// total PDHG iterations (0 for simplex)
    pub iters: usize,
    pub backend: &'static str,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_lp_helpers() {
        let mut lp = SparseLp {
            n: 2,
            m: 1,
            b: vec![1.5],
            c: vec![-1.0, -1.0],
            lo: vec![0.0, 0.0],
            hi: vec![1.0, 1.0],
            ..Default::default()
        };
        lp.push(0, 0, 1.0);
        lp.push(0, 1, 1.0);
        lp.push(0, 1, 0.0); // dropped
        assert_eq!(lp.nnz(), 2);
        assert!(lp.validate().is_ok());
        assert_eq!(lp.objective(&[1.0, 0.5]), -1.5);
        assert_eq!(lp.max_violation(&[1.0, 0.5]), 0.0);
        assert!(lp.max_violation(&[1.0, 1.0]) > 0.49);
    }

    #[test]
    fn validate_catches_bad_bounds() {
        let lp = SparseLp {
            n: 1,
            m: 0,
            c: vec![0.0],
            lo: vec![1.0],
            hi: vec![0.0],
            ..Default::default()
        };
        assert!(lp.validate().is_err());
    }
}
