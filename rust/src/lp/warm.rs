//! Warm-starting (Q)HLP solves across the campaign configuration grid.
//!
//! The campaigns solve the *same instance* at many machine configs
//! (16×2 … 128×16).  Only the load rows ((4)/(5), (12)) and one b entry
//! depend on (m, k), so the LPs of one instance share a variable/row
//! layout and their optima move continuously with the config — the
//! previous optimum's (z, y) is an excellent starting point for the
//! neighbor's solve.  The chaining itself lives in the batch driver
//! (`BatchJob::seed_from`, wired by `algos::solve_alloc_grid`); this
//! module provides the policy pieces:
//!
//! * [`grid_distance`] / [`CLOSE_DIST`] — log-scale parameter distance
//!   and the "close neighbor" threshold deciding which chains run
//!   shrunken.  The distance is generic over any positive integer
//!   parameter vector: machine configs (`Platform::counts`) for
//!   within-instance chains, and *instance* parameters
//!   (`Instance::warm_params` — e.g. a Chameleon `(nb, bs)`) for
//!   cross-instance chains between same-app jobs, which the campaign
//!   driver links when two instances share an LP layout and sit within
//!   [`CLOSE_DIST`] of each other.
//! * [`BudgetSchedule`] — the convergence-budget schedule: a solve whose
//!   warm start is close (a neighbor within [`CLOSE_DIST`]) gets a
//!   quarter of the campaign's PDHG budget first and escalates (×2 per
//!   exhaustion) back to the full budget only if it fails to converge.
//!   The *cap* is the campaign budget either way, so a warm-started
//!   solve can always reach exactly the tolerance a cold solve reaches —
//!   the schedule bounds expected work, never convergence quality
//!   (pinned by `rust/tests/lp_warm_batch.rs`).
//!
//! (A persistent cross-run iterate store is a ROADMAP "next lever", not
//! part of this module yet — the LP* cache only persists objectives.)

/// Log-scale distance between two parameter vectors (machine configs or
/// same-app instance parameters): Σ_q |ln m_q − ln m'_q|.  Adjacent
/// configs of the paper grids (counts doubling per step) are exactly
/// `ln 2` apart per differing coordinate; neighboring Chameleon block
/// sizes (64…960) are ≤ ln 2 apart in their coordinate too, which is
/// what makes the same threshold meaningful for cross-instance chains.
pub fn grid_distance(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len(), "parameter vector lengths differ");
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x as f64).ln() - (y as f64).ln()).abs())
        .sum()
}

/// A neighbor within about two doubling steps counts as "close" for the
/// budget schedule.
pub const CLOSE_DIST: f64 = 2.1 * std::f64::consts::LN_2;

/// Escalating iteration-budget schedule (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct BudgetSchedule {
    granted: usize,
    cap: usize,
}

/// Smallest first allotment a warm-started solve is granted.
const MIN_WARM_GRANT: usize = 2_000;

impl BudgetSchedule {
    /// Cold solve: the full campaign budget up front.
    pub fn cold(cap: usize) -> BudgetSchedule {
        BudgetSchedule { granted: cap, cap }
    }

    /// Warm-started solve with a close seed: a quarter of the budget
    /// first, escalation available up to `cap`.
    pub fn warm(cap: usize) -> BudgetSchedule {
        BudgetSchedule {
            granted: (cap / 4).max(MIN_WARM_GRANT).min(cap),
            cap,
        }
    }

    /// Iterations currently granted.
    pub fn granted(&self) -> usize {
        self.granted
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Double the grant (up to the cap) after an allotment exhausted
    /// without convergence.  `false` once the cap is reached — the solve
    /// then stops exactly where a cold solve at the campaign budget
    /// would.
    pub fn escalate(&mut self) -> bool {
        if self.granted >= self.cap {
            return false;
        }
        self.granted = self.granted.saturating_mul(2).min(self.cap);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_distance_is_log_scale() {
        assert_eq!(grid_distance(&[16, 2], &[16, 2]), 0.0);
        let one_step = grid_distance(&[16, 2], &[16, 4]);
        assert!((one_step - std::f64::consts::LN_2).abs() < 1e-12);
        // symmetric, additive over coordinates
        assert_eq!(one_step, grid_distance(&[16, 4], &[16, 2]));
        let two = grid_distance(&[16, 2], &[32, 4]);
        assert!((two - 2.0 * std::f64::consts::LN_2).abs() < 1e-12);
        assert!(two <= CLOSE_DIST);
        assert!(grid_distance(&[16, 2], &[128, 16]) > CLOSE_DIST);
    }

    #[test]
    fn budget_schedule_escalates_to_cap() {
        let mut s = BudgetSchedule::warm(80_000);
        assert_eq!(s.granted(), 20_000);
        assert!(s.escalate());
        assert_eq!(s.granted(), 40_000);
        assert!(s.escalate());
        assert_eq!(s.granted(), 80_000);
        assert!(!s.escalate(), "cap reached");
        assert_eq!(s.granted(), s.cap());

        let mut c = BudgetSchedule::cold(80_000);
        assert_eq!(c.granted(), 80_000);
        assert!(!c.escalate());
    }

    #[test]
    fn tiny_budgets_stay_within_cap() {
        let s = BudgetSchedule::warm(500);
        assert_eq!(s.granted(), 500); // MIN_WARM_GRANT clamped to cap
        let mut s = BudgetSchedule::warm(10_000);
        assert_eq!(s.granted(), 2_500);
        while s.escalate() {}
        assert_eq!(s.granted(), 10_000);
    }
}
