//! Rounding the fractional HLP/QHLP solution into an allocation — the
//! paper's rules:
//!
//! * HLP (§3): `x_j ≥ ½` → CPU side, else GPU side.
//! * QHLP (§5): `q' = argmax_q x_{j,q}`; ties broken towards the type
//!   with the smallest processing time.

use crate::graph::TaskGraph;

use super::model::{HlpVars, QhlpVars};

/// Allocation: processor type per task (0 = CPU, 1.. = GPU types).
pub type Allocation = Vec<usize>;

/// Round a fractional HLP solution.
pub fn round_hlp(z: &[f64], vars: &HlpVars) -> Allocation {
    (0..vars.n_tasks)
        .map(|j| if z[vars.x(j)] >= 0.5 { 0 } else { 1 })
        .collect()
}

/// Round a fractional QHLP solution.
pub fn round_qhlp(z: &[f64], vars: &QhlpVars, g: &TaskGraph) -> Allocation {
    (0..vars.n_tasks)
        .map(|j| {
            let mut best_q = 0usize;
            let mut best_x = f64::NEG_INFINITY;
            for q in 0..vars.n_types {
                let x = z[vars.x(j, q)];
                let better = x > best_x + 1e-12
                    || ((x - best_x).abs() <= 1e-12 && g.time_on(j, q) < g.time_on(j, best_q));
                if better {
                    best_x = x.max(best_x);
                    best_q = q;
                }
            }
            best_q
        })
        .collect()
}

/// Property of the rounding used in the Q(Q+1) proof: the chosen type's
/// fractional value is at least 1/Q (Equation (17)).  Returns the worst
/// (task, value) pair for diagnostics.
pub fn min_selected_fraction(z: &[f64], vars: &QhlpVars, alloc: &Allocation) -> (usize, f64) {
    let mut worst = (0usize, f64::INFINITY);
    for j in 0..vars.n_tasks {
        let x = z[vars.x(j, alloc[j])];
        if x < worst.1 {
            worst = (j, x);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;
    use crate::lp::model::{build_hlp, build_qhlp};
    use crate::lp::pdhg::{solve_rust, DriveOpts};
    use crate::platform::Platform;

    fn two_task_graph() -> crate::graph::TaskGraph {
        let mut b = Builder::new("t");
        b.add_task("a", vec![10.0, 1.0]); // strongly GPU
        b.add_task("b", vec![1.0, 10.0]); // strongly CPU
        b.build()
    }

    #[test]
    fn hlp_round_threshold() {
        let vars = HlpVars {
            n_tasks: 2,
            lambda: 4,
        };
        let z = vec![0.5, 0.49, 0.0, 0.0, 0.0];
        assert_eq!(round_hlp(&z, &vars), vec![0, 1]);
    }

    #[test]
    fn hlp_round_on_solved_lp_follows_speed() {
        let g = two_task_graph();
        let (lp, vars) = build_hlp(&g, &Platform::hybrid(2, 1));
        let sol = solve_rust(&lp, &DriveOpts::default());
        let alloc = round_hlp(&sol.z, &vars);
        assert_eq!(alloc, vec![1, 0], "z = {:?}", &sol.z[..2]);
    }

    #[test]
    fn qhlp_round_argmax_and_tiebreak() {
        let g = two_task_graph();
        let vars = QhlpVars {
            n_tasks: 2,
            n_types: 2,
            lambda: 6,
        };
        // task 0: clear argmax type 1; task 1: tie -> faster type (0)
        let z = vec![0.2, 0.8, 0.5, 0.5, 0.0, 0.0, 0.0];
        let alloc = round_qhlp(&z, &vars, &g);
        assert_eq!(alloc, vec![1, 0]);
    }

    #[test]
    fn qhlp_selected_fraction_at_least_inverse_q() {
        let g = two_task_graph();
        let plat = Platform::hybrid(2, 1);
        let (lp, vars) = build_qhlp(&g, &plat);
        let sol = solve_rust(&lp, &DriveOpts::default());
        let alloc = round_qhlp(&sol.z, &vars, &g);
        let (_, frac) = min_selected_fraction(&sol.z, &vars, &alloc);
        // Σ_q x = 1 and argmax => x >= 1/Q (allow PDHG tolerance)
        assert!(frac >= 1.0 / 2.0 - 1e-2, "frac {frac}");
    }
}
