//! Rounding the fractional HLP/QHLP solution into an allocation — the
//! paper's rules:
//!
//! * HLP (§3): `x_j ≥ ½` → CPU side, else GPU side.
//! * QHLP (§5): `q' = argmax_q x_{j,q}`; ties broken towards the type
//!   with the smallest processing time.

use crate::graph::TaskGraph;

use super::model::{HlpVars, QhlpVars};

/// Allocation: processor type per task (0 = CPU, 1.. = GPU types).
pub type Allocation = Vec<usize>;

/// Round a fractional HLP solution.
pub fn round_hlp(z: &[f64], vars: &HlpVars) -> Allocation {
    (0..vars.n_tasks)
        .map(|j| if z[vars.x(j)] >= 0.5 { 0 } else { 1 })
        .collect()
}

/// Round a fractional QHLP solution.
///
/// Two passes per task so the result is independent of the type order:
/// first the exact argmax of the fractional assignment, then — among the
/// types within the 1e-12 tie band of that maximum — the fastest type
/// (ties on speed towards the lowest type index).  The previous
/// single-pass fold kept a running `best_x = x.max(best_x)` while
/// switching `best_q` on tie-breaks, which made three-way near-ties
/// order-dependent (a later type could beat the band anchor without
/// beating the fastest in-band type).
pub fn round_qhlp(z: &[f64], vars: &QhlpVars, g: &TaskGraph) -> Allocation {
    (0..vars.n_tasks)
        .map(|j| {
            let max_x = (0..vars.n_types)
                .map(|q| z[vars.x(j, q)])
                .fold(f64::NEG_INFINITY, f64::max);
            (0..vars.n_types)
                .filter(|&q| z[vars.x(j, q)] >= max_x - 1e-12)
                .min_by(|&a, &b| {
                    g.time_on(j, a).total_cmp(&g.time_on(j, b)).then(a.cmp(&b))
                })
                .expect("at least the argmax type is within its own band")
        })
        .collect()
}

/// Property of the rounding used in the Q(Q+1) proof: the chosen type's
/// fractional value is at least 1/Q (Equation (17)).  Returns the worst
/// (task, value) pair for diagnostics.
pub fn min_selected_fraction(z: &[f64], vars: &QhlpVars, alloc: &Allocation) -> (usize, f64) {
    let mut worst = (0usize, f64::INFINITY);
    for j in 0..vars.n_tasks {
        let x = z[vars.x(j, alloc[j])];
        if x < worst.1 {
            worst = (j, x);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Builder;
    use crate::lp::model::{build_hlp, build_qhlp};
    use crate::lp::pdhg::{solve_rust, DriveOpts};
    use crate::platform::Platform;

    fn two_task_graph() -> crate::graph::TaskGraph {
        let mut b = Builder::new("t");
        b.add_task("a", vec![10.0, 1.0]); // strongly GPU
        b.add_task("b", vec![1.0, 10.0]); // strongly CPU
        b.build()
    }

    #[test]
    fn hlp_round_threshold() {
        let vars = HlpVars {
            n_tasks: 2,
            lambda: 4,
        };
        let z = vec![0.5, 0.49, 0.0, 0.0, 0.0];
        assert_eq!(round_hlp(&z, &vars), vec![0, 1]);
    }

    #[test]
    fn hlp_round_on_solved_lp_follows_speed() {
        let g = two_task_graph();
        let (lp, vars) = build_hlp(&g, &Platform::hybrid(2, 1));
        let sol = solve_rust(&lp, &DriveOpts::default());
        let alloc = round_hlp(&sol.z, &vars);
        assert_eq!(alloc, vec![1, 0], "z = {:?}", &sol.z[..2]);
    }

    #[test]
    fn qhlp_round_argmax_and_tiebreak() {
        let g = two_task_graph();
        let vars = QhlpVars {
            n_tasks: 2,
            n_types: 2,
            lambda: 6,
        };
        // task 0: clear argmax type 1; task 1: tie -> faster type (0)
        let z = vec![0.2, 0.8, 0.5, 0.5, 0.0, 0.0, 0.0];
        let alloc = round_qhlp(&z, &vars, &g);
        assert_eq!(alloc, vec![1, 0]);
    }

    #[test]
    fn qhlp_round_three_way_near_tie_is_order_independent() {
        // Three types whose fractional values straddle the 1e-12 band:
        // x = [0.5 - 1.8e-12, 0.5 - 9e-13, 0.5].  The argmax is type 2;
        // its band contains type 1 (9e-13 below) but NOT type 0
        // (1.8e-12 below).  The fastest in-band type is 1.  The old
        // running-anchor fold picked type 2: type 0 (out of the true
        // band, but the fastest overall) anchored the scan, type 1
        // could not beat that anchor on time, and type 2 then beat the
        // stale anchor "strictly" — an order-dependent outcome.
        let mut b = Builder::new("band");
        b.add_task("t", vec![1.0, 5.0, 9.0]);
        let g = b.build();
        let vars = QhlpVars {
            n_tasks: 1,
            n_types: 3,
            lambda: 4,
        };
        let z = vec![0.5 - 1.8e-12, 0.5 - 9e-13, 0.5, 0.0, 0.0];
        assert_eq!(round_qhlp(&z, &vars, &g), vec![1]);
    }

    #[test]
    fn qhlp_round_all_three_in_band_picks_fastest() {
        let mut b = Builder::new("band3");
        b.add_task("t", vec![3.0, 1.0, 2.0]);
        let g = b.build();
        let vars = QhlpVars {
            n_tasks: 1,
            n_types: 3,
            lambda: 4,
        };
        let z = vec![0.5, 0.5 - 4e-13, 0.5 + 4e-13, 0.0, 0.0];
        assert_eq!(round_qhlp(&z, &vars, &g), vec![1]);
    }

    #[test]
    fn qhlp_selected_fraction_at_least_inverse_q() {
        let g = two_task_graph();
        let plat = Platform::hybrid(2, 1);
        let (lp, vars) = build_qhlp(&g, &plat);
        let sol = solve_rust(&lp, &DriveOpts::default());
        let alloc = round_qhlp(&sol.z, &vars, &g);
        let (_, frac) = min_selected_fraction(&sol.z, &vars, &alloc);
        // Σ_q x = 1 and argmax => x >= 1/Q (allow PDHG tolerance)
        assert!(frac >= 1.0 / 2.0 - 1e-2, "frac {frac}");
    }
}
