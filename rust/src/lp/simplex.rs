//! Exact LP solver: dense two-phase primal simplex.
//!
//! The role of this module is the one GLPK played in the paper (§6.2):
//! an exact optimum `LP*` for the HLP/QHLP relaxations.  It is the
//! correctness oracle for the PDHG path (both backends must agree with
//! it on every test LP) and the exact backend for small instances; big
//! campaign instances use PDHG with its duality-gap certificate instead.
//!
//! Handles the general box form by shifting to `x̃ = z − lo ≥ 0` and
//! materializing finite upper bounds as extra rows.  Dantzig pricing
//! with an automatic switch to Bland's rule to guarantee termination.

use super::{LpSolution, SparseLp};

const EPS: f64 = 1e-9;
/// Upper bounds at or above this are treated as +inf (no row emitted).
const BIG: f64 = 1e17;

#[derive(Debug, Clone, PartialEq)]
pub enum SimplexError {
    Infeasible,
    Unbounded,
    IterationLimit,
}

struct Tableau {
    /// rows x cols, last column = rhs
    t: Vec<Vec<f64>>,
    n_rows: usize,
    n_cols: usize, // variables incl. slacks/artificials (excl. rhs)
    basis: Vec<usize>,
}

impl Tableau {
    fn pivot(&mut self, r: usize, c: usize) {
        let piv = self.t[r][c];
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for x in self.t[r].iter_mut() {
            *x *= inv;
        }
        let prow = self.t[r].clone();
        for (i, row) in self.t.iter_mut().enumerate() {
            if i != r {
                let f = row[c];
                // hetlint: allow(no-raw-float-eq) -- exact-zero skip: eliminating with f == 0 is a no-op, not a tolerance test
                if f != 0.0 {
                    for (x, p) in row.iter_mut().zip(&prow) {
                        *x -= f * p;
                    }
                }
            }
        }
        self.basis[r] = c;
    }

    /// Run simplex on the cost row (last row), minimizing.
    /// `allowed` marks columns that may enter the basis.
    fn optimize(&mut self, allowed: &[bool], max_iters: usize) -> Result<(), SimplexError> {
        let cost_row = self.n_rows;
        let mut iters = 0usize;
        // switch to Bland when past this many iterations (anti-cycling)
        let bland_after = max_iters / 2;
        loop {
            iters += 1;
            if iters > max_iters {
                return Err(SimplexError::IterationLimit);
            }
            // entering column
            let mut enter: Option<usize> = None;
            if iters <= bland_after {
                let mut best = -EPS;
                for c in 0..self.n_cols {
                    if allowed[c] && self.t[cost_row][c] < best {
                        best = self.t[cost_row][c];
                        enter = Some(c);
                    }
                }
            } else {
                for c in 0..self.n_cols {
                    if allowed[c] && self.t[cost_row][c] < -EPS {
                        enter = Some(c);
                        break;
                    }
                }
            }
            let Some(c) = enter else {
                return Ok(());
            };
            // leaving row: min ratio, Bland tie-break on basis index
            let mut leave: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for r in 0..self.n_rows {
                let a = self.t[r][c];
                if a > EPS {
                    let ratio = self.t[r][self.n_cols] / a;
                    let better = ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && leave.map(|l| self.basis[r] < self.basis[l]).unwrap_or(true));
                    if better {
                        best_ratio = ratio;
                        leave = Some(r);
                    }
                }
            }
            let Some(r) = leave else {
                return Err(SimplexError::Unbounded);
            };
            self.pivot(r, c);
        }
    }
}

/// Solve `min cᵀz : Az ≤ b, lo ≤ z ≤ hi` exactly.
pub fn solve_simplex(lp: &SparseLp) -> Result<LpSolution, SimplexError> {
    let n = lp.n;
    // shift: x̃ = z - lo; extra rows for finite hi
    let shift: Vec<f64> = lp.lo.clone();
    let ubs: Vec<(usize, f64)> = (0..n)
        .filter(|&j| lp.hi[j] < BIG)
        .map(|j| (j, lp.hi[j] - lp.lo[j]))
        .collect();
    let m = lp.m + ubs.len();

    // dense A-tilde and b-tilde
    let mut a = vec![vec![0.0f64; n]; m];
    let mut b = vec![0.0f64; m];
    for i in 0..lp.vals.len() {
        a[lp.rows[i] as usize][lp.cols[i] as usize] += lp.vals[i];
    }
    for i in 0..lp.m {
        let alo: f64 = a[i].iter().zip(&shift).map(|(x, l)| x * l).sum();
        b[i] = lp.b[i] - alo;
    }
    for (r, &(j, ub)) in ubs.iter().enumerate() {
        a[lp.m + r][j] = 1.0;
        b[lp.m + r] = ub;
    }

    // columns: structural n | slacks m | artificials (rows with b<0)
    let neg_rows: Vec<usize> = (0..m).filter(|&i| b[i] < -EPS).collect();
    let n_art = neg_rows.len();
    let n_cols = n + m + n_art;
    let mut t = vec![vec![0.0f64; n_cols + 1]; m + 1];
    let mut basis = vec![0usize; m];
    {
        let mut art = 0;
        for i in 0..m {
            let negate = b[i] < -EPS;
            let s = if negate { -1.0 } else { 1.0 };
            for j in 0..n {
                t[i][j] = s * a[i][j];
            }
            t[i][n + i] = s; // slack
            t[i][n_cols] = s * b[i];
            if negate {
                t[i][n + m + art] = 1.0;
                basis[i] = n + m + art;
                art += 1;
            } else {
                basis[i] = n + i;
            }
        }
    }

    let max_iters = 200 * (m + n) + 2000;

    // Phase 1: minimize sum of artificials.
    if n_art > 0 {
        // phase-1 cost: +1 on artificial columns; reduce against the
        // basic artificial rows so basic reduced costs are zero.
        for c in 0..=n_cols {
            t[m][c] = 0.0;
        }
        for c in n + m..n_cols {
            t[m][c] = 1.0;
        }
        for i in 0..m {
            if basis[i] >= n + m {
                for c in 0..=n_cols {
                    t[m][c] -= t[i][c];
                }
            }
        }
        let allowed: Vec<bool> = (0..n_cols).map(|_| true).collect();
        let mut tab = Tableau {
            t,
            n_rows: m,
            n_cols,
            basis,
        };
        tab.optimize(&allowed, max_iters)?;
        // objective = -t[m][rhs] (we built the negated cost row)
        let phase1_obj = -tab.t[m][n_cols];
        if phase1_obj > 1e-6 {
            return Err(SimplexError::Infeasible);
        }
        // pivot any artificial still basic (degenerate) out of the basis
        for r in 0..m {
            if tab.basis[r] >= n + m {
                if let Some(c) = (0..n + m).find(|&c| tab.t[r][c].abs() > EPS) {
                    tab.pivot(r, c);
                }
            }
        }
        t = tab.t;
        basis = tab.basis;
    }

    // Phase 2: minimize c̃ᵀ x̃ (c̃ = c on structural, 0 on slacks).
    for c in 0..=n_cols {
        t[m][c] = 0.0;
    }
    for j in 0..n {
        t[m][j] = lp.c[j];
    }
    // subtract basic rows to zero reduced costs of the basis
    for i in 0..m {
        let f = t[m][basis[i]];
        // hetlint: allow(no-raw-float-eq) -- exact-zero skip: a zero reduced cost needs no row update, not a tolerance test
        if f != 0.0 {
            let row = t[i].clone();
            for (x, p) in t[m].iter_mut().zip(&row) {
                *x -= f * p;
            }
        }
    }
    let allowed: Vec<bool> = (0..n_cols).map(|c| c < n + m).collect();
    let mut tab = Tableau {
        t,
        n_rows: m,
        n_cols,
        basis,
    };
    tab.optimize(&allowed, max_iters)?;

    // extract
    let mut z = shift;
    for r in 0..m {
        if tab.basis[r] < n {
            z[tab.basis[r]] += tab.t[r][n_cols];
        }
    }
    let obj = lp.objective(&z);
    Ok(LpSolution {
        z,
        obj,
        lower_bound: obj,
        gap: 0.0,
        iters: 0,
        backend: "simplex",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::pdhg::{solve_rust, DriveOpts};
    use crate::substrate::rng::Rng;

    fn knapsack() -> SparseLp {
        let mut lp = SparseLp {
            n: 2,
            m: 1,
            b: vec![1.5],
            c: vec![-1.0, -1.0],
            lo: vec![0.0; 2],
            hi: vec![1.0; 2],
            ..Default::default()
        };
        lp.push(0, 0, 1.0);
        lp.push(0, 1, 1.0);
        lp
    }

    #[test]
    fn textbook_lp() {
        // max 3x+2y : x+y<=4, x+3y<=6, x,y>=0 -> (4,0), obj 12
        let mut lp = SparseLp {
            n: 2,
            m: 2,
            b: vec![4.0, 6.0],
            c: vec![-3.0, -2.0],
            lo: vec![0.0; 2],
            hi: vec![f64::INFINITY; 2],
            ..Default::default()
        };
        lp.push(0, 0, 1.0);
        lp.push(0, 1, 1.0);
        lp.push(1, 0, 1.0);
        lp.push(1, 1, 3.0);
        let sol = solve_simplex(&lp).unwrap();
        assert!((sol.obj + 12.0).abs() < 1e-9, "obj {}", sol.obj);
        assert!((sol.z[0] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn knapsack_with_box() {
        let sol = solve_simplex(&knapsack()).unwrap();
        assert!((sol.obj + 1.5).abs() < 1e-9);
    }

    #[test]
    fn phase1_negative_rhs() {
        // min x : x >= 3 (as -x <= -3), x in [0,10] -> 3
        let mut lp = SparseLp {
            n: 1,
            m: 1,
            b: vec![-3.0],
            c: vec![1.0],
            lo: vec![0.0],
            hi: vec![10.0],
            ..Default::default()
        };
        lp.push(0, 0, -1.0);
        let sol = solve_simplex(&lp).unwrap();
        assert!((sol.obj - 3.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1 and x >= 2
        let mut lp = SparseLp {
            n: 1,
            m: 2,
            b: vec![1.0, -2.0],
            c: vec![0.0],
            lo: vec![0.0],
            hi: vec![f64::INFINITY],
            ..Default::default()
        };
        lp.push(0, 0, 1.0);
        lp.push(1, 0, -1.0);
        assert!(matches!(solve_simplex(&lp), Err(SimplexError::Infeasible)));
    }

    #[test]
    fn unbounded_detected() {
        // min -x : x >= 0 unbounded
        let lp = SparseLp {
            n: 1,
            m: 0,
            c: vec![-1.0],
            lo: vec![0.0],
            hi: vec![f64::INFINITY],
            ..Default::default()
        };
        assert!(matches!(solve_simplex(&lp), Err(SimplexError::Unbounded)));
    }

    #[test]
    fn nonzero_lower_bounds() {
        // min x+y : x+y >= 5, x in [1,3], y in [2, 10] -> 5 at (1,4)? x+y>=5
        // feasible min is max(5, 1+2)=5
        let mut lp = SparseLp {
            n: 2,
            m: 1,
            b: vec![-5.0],
            c: vec![1.0, 1.0],
            lo: vec![1.0, 2.0],
            hi: vec![3.0, 10.0],
            ..Default::default()
        };
        lp.push(0, 0, -1.0);
        lp.push(0, 1, -1.0);
        let sol = solve_simplex(&lp).unwrap();
        assert!((sol.obj - 5.0).abs() < 1e-9, "obj {}", sol.obj);
        assert!(sol.z[0] >= 1.0 - 1e-9 && sol.z[1] >= 2.0 - 1e-9);
    }

    #[test]
    fn agrees_with_pdhg_on_random_lps() {
        let mut rng = Rng::new(77);
        for case in 0..20 {
            let n = 2 + rng.below(8);
            let m = 1 + rng.below(6);
            let mut lp = SparseLp {
                n,
                m,
                b: (0..m).map(|_| rng.uniform(0.5, 5.0)).collect(),
                c: (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect(),
                lo: vec![0.0; n],
                hi: (0..n).map(|_| rng.uniform(0.5, 3.0)).collect(),
                ..Default::default()
            };
            for i in 0..m {
                for j in 0..n {
                    if rng.chance(0.5) {
                        lp.push(i, j, rng.uniform(-2.0, 2.0));
                    }
                }
            }
            let exact = solve_simplex(&lp).unwrap();
            let approx = solve_rust(
                &lp,
                &DriveOpts {
                    tol: 1e-6,
                    ..Default::default()
                },
            );
            let scale = 1.0 + exact.obj.abs();
            assert!(
                (exact.obj - approx.obj).abs() / scale < 5e-3,
                "case {case}: simplex {} vs pdhg {}",
                exact.obj,
                approx.obj
            );
            // duality sandwich: pdhg lower bound <= exact optimum
            assert!(approx.lower_bound <= exact.obj + 1e-6 * scale);
        }
    }
}
