//! End-to-end algorithm pipelines — the exact algorithm set the paper
//! evaluates (§6):
//!
//! offline, 2 types:  HLP-EST, HLP-OLS, HEFT
//! offline, Q types:  QHLP-EST, QHLP-OLS, QHEFT
//! online,  2 types:  ER-LS, EFT, Greedy, Random (+ R1/R2/R3 rules)
//!
//! Each offline pipeline = allocation phase (LP relax + round) followed
//! by the scheduling phase (EST or OLS); HEFT is the single-phase
//! baseline.  `LpBackendKind` picks where the relaxation is solved
//! (PJRT artifact / Rust PDHG / simplex).

use crate::alloc::{greedy_min_time, Allocation};
use crate::graph::TaskGraph;
use crate::lp::model::{
    build_hlp, build_qhlp, hlp_warm_start, qhlp_warm_start, tighten_hlp_box,
    tighten_qhlp_box, HlpVars, QhlpVars,
};

use crate::lp::rounding::{round_hlp, round_qhlp};
use crate::lp::LpSolution;
use crate::platform::Platform;
use crate::runtime::{self, LpBackendKind};
use crate::sched::est::est_schedule;
use crate::sched::heft::heft_schedule;
use crate::sched::list::ols_schedule;
use crate::sim::Schedule;

/// Offline algorithm identifiers (2-type names; the same code handles
/// the Q-type generalizations of §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Offline {
    HlpEst,
    HlpOls,
    Heft,
}

impl Offline {
    pub fn name(&self) -> &'static str {
        match self {
            Offline::HlpEst => "HLP-EST",
            Offline::HlpOls => "HLP-OLS",
            Offline::Heft => "HEFT",
        }
    }

    pub const ALL: [Offline; 3] = [Offline::HlpEst, Offline::HlpOls, Offline::Heft];
}

/// The solved allocation LP for an instance (shared by EST/OLS and by
/// the figure harnesses as the `LP*` normalizer).
#[derive(Clone, Debug)]
pub struct AllocLp {
    pub sol: LpSolution,
    pub alloc: Allocation,
}

/// Solve + round HLP (2 types).  The greedy warm start both seeds PDHG
/// and tightens the C/λ box to its (feasible) makespan bound.
pub fn solve_hlp(g: &TaskGraph, plat: &Platform, backend: LpBackendKind, tol: f64) -> AllocLp {
    solve_hlp_capped(g, plat, backend, tol, crate::lp::pdhg::DriveOpts::default().max_iters)
}

/// `solve_hlp` with an explicit PDHG iteration budget.
pub fn solve_hlp_capped(
    g: &TaskGraph,
    plat: &Platform,
    backend: LpBackendKind,
    tol: f64,
    max_iters: usize,
) -> AllocLp {
    let (mut lp, vars) = build_hlp(g, plat);
    let warm = hlp_warm_start(g, plat, &greedy_min_time(g), &vars);
    tighten_hlp_box(&mut lp, &vars, warm[vars.lambda]);
    let sol = runtime::solve_lp_capped(&lp, backend, tol, Some(warm), max_iters);
    let alloc = round_hlp(&sol.z, &vars);
    AllocLp { sol, alloc }
}

/// Solve + round QHLP (Q ≥ 2 types).
pub fn solve_qhlp(g: &TaskGraph, plat: &Platform, backend: LpBackendKind, tol: f64) -> AllocLp {
    solve_qhlp_capped(g, plat, backend, tol, crate::lp::pdhg::DriveOpts::default().max_iters)
}

/// `solve_qhlp` with an explicit PDHG iteration budget.
pub fn solve_qhlp_capped(
    g: &TaskGraph,
    plat: &Platform,
    backend: LpBackendKind,
    tol: f64,
    max_iters: usize,
) -> AllocLp {
    let (mut lp, vars) = build_qhlp(g, plat);
    let warm = qhlp_warm_start(g, plat, &greedy_min_time(g), &vars);
    tighten_qhlp_box(&mut lp, &vars, warm[vars.lambda]);
    let sol = runtime::solve_lp_capped(&lp, backend, tol, Some(warm), max_iters);
    let alloc = round_qhlp(&sol.z, &vars, g);
    AllocLp { sol, alloc }
}

/// Run one offline algorithm; returns the schedule and (for the LP-based
/// ones) the allocation LP solution, reusing `lp` if provided.
pub fn run_offline(
    algo: Offline,
    g: &TaskGraph,
    plat: &Platform,
    lp: Option<&AllocLp>,
    backend: LpBackendKind,
    tol: f64,
) -> (Schedule, Option<AllocLp>) {
    match algo {
        Offline::Heft => (heft_schedule(g, plat), None),
        Offline::HlpEst | Offline::HlpOls => {
            let owned;
            let alloc_lp = match lp {
                Some(l) => l,
                None => {
                    owned = if plat.n_types() == 2 && g.n_types() == 2 {
                        solve_hlp(g, plat, backend, tol)
                    } else {
                        solve_qhlp(g, plat, backend, tol)
                    };
                    &owned
                }
            };
            let s = match algo {
                Offline::HlpEst => est_schedule(g, plat, &alloc_lp.alloc),
                Offline::HlpOls => ols_schedule(g, plat, &alloc_lp.alloc),
                Offline::Heft => unreachable!(),
            };
            (s, Some(alloc_lp.clone()))
        }
    }
}

/// Expose the LP-facade with explicit warm start (used by runtime).
pub fn lp_vars_hlp(g: &TaskGraph, plat: &Platform) -> HlpVars {
    build_hlp(g, plat).1
}

pub fn lp_vars_qhlp(g: &TaskGraph, plat: &Platform) -> QhlpVars {
    build_qhlp(g, plat).1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::validate;
    use crate::workloads::{chameleon, costs::CostModel};

    #[test]
    fn all_offline_algorithms_on_potrf() {
        let g = chameleon::potrf(5, &CostModel::hybrid(320), 3);
        let plat = Platform::hybrid(4, 2);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        assert!(hlp.sol.obj > 0.0);
        for algo in Offline::ALL {
            let (s, _) = run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::RustPdhg, 1e-4);
            validate(&g, &plat, &s).unwrap();
            // the 6-approximation certificate, with LP tolerance slack
            assert!(
                s.makespan <= 6.0 * hlp.sol.obj * 1.05 + 1e-9,
                "{}: {} > 6 x {}",
                algo.name(),
                s.makespan,
                hlp.sol.obj
            );
        }
    }

    #[test]
    fn qhlp_three_types_pipeline() {
        let g = chameleon::posv(5, &CostModel::three_type(320), 3);
        let plat = Platform::new(vec![4, 2, 1]);
        let qhlp = solve_qhlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        for algo in [Offline::HlpEst, Offline::HlpOls, Offline::Heft] {
            let (s, _) =
                run_offline(algo, &g, &plat, Some(&qhlp), LpBackendKind::RustPdhg, 1e-4);
            validate(&g, &plat, &s).unwrap();
            // Q(Q+1) = 12 certificate
            assert!(s.makespan <= 12.0 * qhlp.sol.obj * 1.05);
        }
    }

    #[test]
    fn lp_star_is_lower_bound_for_makespan() {
        let g = chameleon::getrf(5, &CostModel::hybrid(128), 5);
        let plat = Platform::hybrid(16, 2);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-5);
        for algo in Offline::ALL {
            let (s, _) = run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::RustPdhg, 1e-5);
            // LP* (within tolerance) lower-bounds any feasible makespan
            assert!(s.makespan >= hlp.sol.obj * 0.99, "{}", algo.name());
        }
    }
}
