//! End-to-end algorithm pipelines — the exact algorithm set the paper
//! evaluates (§6):
//!
//! offline, 2 types:  HLP-EST, HLP-OLS, HEFT
//! offline, Q types:  QHLP-EST, QHLP-OLS, QHEFT
//! online,  2 types:  ER-LS, EFT, Greedy, Random (+ R1/R2/R3 rules)
//!
//! Each offline pipeline = allocation phase (LP relax + round) followed
//! by the scheduling phase (EST or OLS); HEFT is the single-phase
//! baseline.  `LpBackendKind` picks where the relaxation is solved
//! (PJRT artifact / Rust PDHG / simplex).

use crate::alloc::{greedy_min_time, Allocation};
use crate::graph::TaskGraph;
use crate::lp::chain;
use crate::lp::model::{
    build_hlp, build_qhlp, hlp_warm_start, qhlp_warm_start, tighten_hlp_box,
    tighten_qhlp_box, HlpVars, QhlpVars,
};

use crate::lp::rounding::{round_hlp, round_qhlp};
use crate::lp::{LpSolution, SparseLp};
use crate::platform::Platform;
use crate::runtime::{self, LpBackendKind};
use crate::sched::est::est_schedule;
use crate::sched::heft::heft_schedule;
use crate::sched::list::ols_schedule;
use crate::sim::Schedule;

/// Offline algorithm identifiers (2-type names; the same code handles
/// the Q-type generalizations of §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Offline {
    HlpEst,
    HlpOls,
    Heft,
}

impl Offline {
    pub fn name(&self) -> &'static str {
        match self {
            Offline::HlpEst => "HLP-EST",
            Offline::HlpOls => "HLP-OLS",
            Offline::Heft => "HEFT",
        }
    }

    pub const ALL: [Offline; 3] = [Offline::HlpEst, Offline::HlpOls, Offline::Heft];
}

/// The solved allocation LP for an instance (shared by EST/OLS and by
/// the figure harnesses as the `LP*` normalizer).
#[derive(Clone, Debug)]
pub struct AllocLp {
    pub sol: LpSolution,
    pub alloc: Allocation,
}

/// Shared prelude of every HLP solve path — build the model, compute
/// the greedy warm start, tighten the C/λ box to its feasible bound,
/// contract series chains per `plan`.  The batched and per-item paths
/// (and the lp_batch bench) all go through here, which is what the
/// cache-interchangeability contract rests on: every path must solve
/// the identical model from the identical start.  An empty `plan`
/// (`ChainPlan::default()`) builds the uncontracted model.
pub fn build_hlp_job(
    g: &TaskGraph,
    plat: &Platform,
    greedy: &[usize],
    plan: &chain::ChainPlan,
) -> (SparseLp, Vec<f64>, HlpVars) {
    let (mut lp, vars) = build_hlp(g, plat);
    let warm = hlp_warm_start(g, plat, greedy, &vars);
    tighten_hlp_box(&mut lp, &vars, warm[vars.lambda]);
    (chain::contract(&lp, plan), warm, vars)
}

/// QHLP version of [`build_hlp_job`].
pub fn build_qhlp_job(
    g: &TaskGraph,
    plat: &Platform,
    greedy: &[usize],
    plan: &chain::ChainPlan,
) -> (SparseLp, Vec<f64>, QhlpVars) {
    let (mut lp, vars) = build_qhlp(g, plat);
    let warm = qhlp_warm_start(g, plat, greedy, &vars);
    tighten_qhlp_box(&mut lp, &vars, warm[vars.lambda]);
    (chain::contract(&lp, plan), warm, vars)
}

/// Solve + round HLP (2 types).  The greedy warm start both seeds PDHG
/// and tightens the C/λ box to its (feasible) makespan bound; series
/// chains are contracted away before solving ([`crate::lp::chain`]).
pub fn solve_hlp(g: &TaskGraph, plat: &Platform, backend: LpBackendKind, tol: f64) -> AllocLp {
    solve_hlp_capped(g, plat, backend, tol, crate::lp::pdhg::DriveOpts::default().max_iters)
}

/// `solve_hlp` with an explicit PDHG iteration budget.
pub fn solve_hlp_capped(
    g: &TaskGraph,
    plat: &Platform,
    backend: LpBackendKind,
    tol: f64,
    max_iters: usize,
) -> AllocLp {
    let (lp, warm, vars) =
        build_hlp_job(g, plat, &greedy_min_time(g), &chain::plan_chains(g));
    let sol = runtime::solve_lp_capped(&lp, backend, tol, Some(warm), max_iters);
    let alloc = round_hlp(&sol.z, &vars);
    AllocLp { sol, alloc }
}

/// Solve + round QHLP (Q ≥ 2 types).
pub fn solve_qhlp(g: &TaskGraph, plat: &Platform, backend: LpBackendKind, tol: f64) -> AllocLp {
    solve_qhlp_capped(g, plat, backend, tol, crate::lp::pdhg::DriveOpts::default().max_iters)
}

/// `solve_qhlp` with an explicit PDHG iteration budget.
pub fn solve_qhlp_capped(
    g: &TaskGraph,
    plat: &Platform,
    backend: LpBackendKind,
    tol: f64,
    max_iters: usize,
) -> AllocLp {
    let (lp, warm, vars) =
        build_qhlp_job(g, plat, &greedy_min_time(g), &chain::plan_chains(g));
    let sol = runtime::solve_lp_capped(&lp, backend, tol, Some(warm), max_iters);
    let alloc = round_qhlp(&sol.z, &vars, g);
    AllocLp { sol, alloc }
}

/// Batched allocation solves over a slice of the campaign grid: one
/// (graph, platform) pair per entry, all solved concurrently by the
/// batched PDHG driver ([`crate::lp::batch`]) over one worker pool.
///
/// Consecutive entries referring to the *same* graph (pointer equality —
/// the campaign driver materializes each instance's graph once) form a
/// warm-start chain: entry i seeds from entry i−1's final primal/dual
/// iterates, and close grid neighbors ([`crate::lp::warm::CLOSE_DIST`])
/// run under the shrunken escalating budget schedule.  Chain plans are
/// computed once per graph.  Each LP still gets its own greedy warm
/// start and box tightening (λ bounds must be feasible for *its*
/// config), so the head of every chain behaves exactly like
/// [`solve_hlp_capped`] / [`solve_qhlp_capped`] on the Rust backend.
pub fn solve_alloc_grid(
    items: &[(&TaskGraph, &Platform)],
    tol: f64,
    max_iters: usize,
    workers: usize,
) -> Vec<AllocLp> {
    solve_alloc_grid_seeded(items, Vec::new(), tol, max_iters, workers)
        .into_iter()
        .map(|(a, _)| a)
        .collect()
}

/// External seeding options for one item of [`solve_alloc_grid_seeded`]
/// (all off by default — a default seed vector reproduces
/// [`solve_alloc_grid`] exactly).
#[derive(Default)]
pub struct GridSeed {
    /// Cross-run warm start: final (z, y) iterates — in the contracted
    /// model's original coordinates — persisted by a previous campaign
    /// run ([`crate::experiments::cache`]).  Applied only to chain heads
    /// and only if the dimensions still match the freshly built LP
    /// (model construction is deterministic, so a mismatch means the
    /// entry is from an older model layout and is silently dropped).
    pub iterates: Option<(Vec<f64>, Vec<f64>)>,
    /// Cross-*instance* chain: seed from the given earlier item index (a
    /// same-app instance with nearby parameters at the same config —
    /// the caller scores proximity via
    /// [`crate::lp::warm::grid_distance`] over
    /// [`crate::workloads::Instance::warm_params`]).  The bool is the
    /// "close" flag for the shrunken budget schedule.  Ignored when the
    /// item already chains within its own instance, or when the LP
    /// dimensions differ (different DAG structure).
    pub chain_from: Option<(usize, bool)>,
    /// Return this item's final iterates for persistence.
    pub keep_iterates: bool,
}

/// [`solve_alloc_grid`] with external seeding: per-item cross-run warm
/// starts, cross-instance chains and iterate keep flags ([`GridSeed`]).
/// Warm starts and chains only change where PDHG *starts* — every solve
/// still certifies `tol`, so LP* cache semantics are untouched.
pub fn solve_alloc_grid_seeded(
    items: &[(&TaskGraph, &Platform)],
    mut seeds: Vec<GridSeed>,
    tol: f64,
    max_iters: usize,
    workers: usize,
) -> Vec<(AllocLp, Option<(Vec<f64>, Vec<f64>)>)> {
    use crate::lp::batch::{solve_batch_full, BatchJob};
    use crate::lp::pdhg::DriveOpts;
    use crate::lp::warm::{grid_distance, CLOSE_DIST};

    enum Vars {
        Two(HlpVars),
        Q(QhlpVars),
    }

    seeds.resize_with(items.len(), GridSeed::default);
    let mut jobs: Vec<BatchJob> = Vec::with_capacity(items.len());
    let mut vars_of = Vec::with_capacity(items.len());
    // chain plan and greedy allocation depend only on the graph: hoist
    // them across each graph's run of consecutive configs
    let mut per_graph: Option<(crate::lp::chain::ChainPlan, Allocation)> = None;
    for (idx, &(g, plat)) in items.iter().enumerate() {
        assert_eq!(g.n_types(), plat.n_types(), "graph/platform type mismatch");
        let same_graph_as_prev = idx > 0 && std::ptr::eq(items[idx - 1].0, g);
        if !same_graph_as_prev {
            per_graph = Some((chain::plan_chains(g), greedy_min_time(g)));
        }
        let (plan, greedy) = per_graph.as_ref().unwrap();
        let (lp, warm, vars) = if g.n_types() == 2 {
            let (lp, warm, v) = build_hlp_job(g, plat, greedy, plan);
            (lp, warm, Vars::Two(v))
        } else {
            let (lp, warm, v) = build_qhlp_job(g, plat, greedy, plan);
            (lp, warm, Vars::Q(v))
        };
        let seed = &mut seeds[idx];
        let (seed_from, mut warm_close) = if same_graph_as_prev {
            let close =
                grid_distance(&items[idx - 1].1.counts, &plat.counts) <= CLOSE_DIST;
            (Some(idx - 1), close)
        } else if let Some((x, close)) = seed.chain_from {
            // cross-instance chain: only sound when the LP layout is
            // identical (same DAG structure, e.g. Chameleon instances
            // differing only in block size)
            if x < idx && jobs[x].lp.n == lp.n && jobs[x].lp.m == lp.m {
                (Some(x), close)
            } else {
                (None, false)
            }
        } else {
            (None, false)
        };
        let mut opts = DriveOpts {
            tol,
            max_iters,
            warm_start: Some(warm),
            ..Default::default()
        };
        if seed_from.is_none() {
            // chain heads may warm-start from a previous run's persisted
            // iterates (primal + dual) instead of the greedy point
            if let Some((z, y)) = seed.iterates.take() {
                if z.len() == lp.n && y.len() == lp.m {
                    opts.warm_start = Some(z);
                    opts.warm_start_dual = Some(y);
                    warm_close = true;
                }
            }
        }
        jobs.push(BatchJob {
            lp,
            opts,
            seed_from,
            warm_close,
            keep_iterates: seed.keep_iterates,
        });
        vars_of.push(vars);
    }

    let sols = solve_batch_full(jobs, workers);
    items
        .iter()
        .zip(sols)
        .zip(vars_of)
        .map(|((&(g, _), (sol, kept)), vars)| {
            let alloc = match vars {
                Vars::Two(v) => round_hlp(&sol.z, &v),
                Vars::Q(v) => round_qhlp(&sol.z, &v, g),
            };
            (AllocLp { sol, alloc }, kept)
        })
        .collect()
}

/// Run one offline algorithm; returns the schedule and (for the LP-based
/// ones) the allocation LP solution, reusing `lp` if provided.
pub fn run_offline(
    algo: Offline,
    g: &TaskGraph,
    plat: &Platform,
    lp: Option<&AllocLp>,
    backend: LpBackendKind,
    tol: f64,
) -> (Schedule, Option<AllocLp>) {
    match algo {
        Offline::Heft => (heft_schedule(g, plat), None),
        Offline::HlpEst | Offline::HlpOls => {
            let owned;
            let alloc_lp = match lp {
                Some(l) => l,
                None => {
                    owned = if plat.n_types() == 2 && g.n_types() == 2 {
                        solve_hlp(g, plat, backend, tol)
                    } else {
                        solve_qhlp(g, plat, backend, tol)
                    };
                    &owned
                }
            };
            let s = match algo {
                Offline::HlpEst => est_schedule(g, plat, &alloc_lp.alloc),
                Offline::HlpOls => ols_schedule(g, plat, &alloc_lp.alloc),
                Offline::Heft => unreachable!(),
            };
            (s, Some(alloc_lp.clone()))
        }
    }
}

/// Expose the LP-facade with explicit warm start (used by runtime).
pub fn lp_vars_hlp(g: &TaskGraph, plat: &Platform) -> HlpVars {
    build_hlp(g, plat).1
}

pub fn lp_vars_qhlp(g: &TaskGraph, plat: &Platform) -> QhlpVars {
    build_qhlp(g, plat).1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::validate;
    use crate::workloads::{chameleon, costs::CostModel};

    #[test]
    fn all_offline_algorithms_on_potrf() {
        let g = chameleon::potrf(5, &CostModel::hybrid(320), 3);
        let plat = Platform::hybrid(4, 2);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        assert!(hlp.sol.obj > 0.0);
        for algo in Offline::ALL {
            let (s, _) = run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::RustPdhg, 1e-4);
            validate(&g, &plat, &s).unwrap();
            // the 6-approximation certificate, with LP tolerance slack
            assert!(
                s.makespan <= 6.0 * hlp.sol.obj * 1.05 + 1e-9,
                "{}: {} > 6 x {}",
                algo.name(),
                s.makespan,
                hlp.sol.obj
            );
        }
    }

    #[test]
    fn qhlp_three_types_pipeline() {
        let g = chameleon::posv(5, &CostModel::three_type(320), 3);
        let plat = Platform::new(vec![4, 2, 1]);
        let qhlp = solve_qhlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        for algo in [Offline::HlpEst, Offline::HlpOls, Offline::Heft] {
            let (s, _) =
                run_offline(algo, &g, &plat, Some(&qhlp), LpBackendKind::RustPdhg, 1e-4);
            validate(&g, &plat, &s).unwrap();
            // Q(Q+1) = 12 certificate
            assert!(s.makespan <= 12.0 * qhlp.sol.obj * 1.05);
        }
    }

    #[test]
    fn alloc_grid_matches_per_item_solves() {
        // the batched grid path (chain contraction + warm chaining) must
        // land on the same LP* as per-item solves, within solver tolerance
        let g = chameleon::potrf(5, &CostModel::hybrid(320), 3);
        let g2 = chameleon::getrf(5, &CostModel::hybrid(128), 5);
        let plats = [
            Platform::hybrid(4, 2),
            Platform::hybrid(8, 2),
            Platform::hybrid(8, 4),
        ];
        let mut items: Vec<(&TaskGraph, &Platform)> = Vec::new();
        for p in &plats {
            items.push((&g, p));
        }
        for p in &plats {
            items.push((&g2, p));
        }
        let grid = solve_alloc_grid(&items, 1e-4, 80_000, 3);
        assert_eq!(grid.len(), 6);
        for (i, &(gr, p)) in items.iter().enumerate() {
            let solo = solve_hlp_capped(gr, p, LpBackendKind::RustPdhg, 1e-4, 80_000);
            let scale = 1.0 + solo.sol.obj.abs();
            assert!(
                (grid[i].sol.obj - solo.sol.obj).abs() < 1e-3 * scale,
                "item {i}: grid {} vs solo {}",
                grid[i].sol.obj,
                solo.sol.obj
            );
            assert_eq!(grid[i].alloc.len(), gr.n_tasks());
        }
    }

    #[test]
    fn cross_instance_chain_matches_solo_solves() {
        // same app, same nb, different block size: identical DAG
        // structure (hence LP layout), different costs — the
        // cross-instance chain regime.  LP* must match per-item solves.
        let g320 = chameleon::potrf(5, &CostModel::hybrid(320), 3);
        let g512 = chameleon::potrf(5, &CostModel::hybrid(512), 3);
        let plat = Platform::hybrid(8, 2);
        let items: Vec<(&TaskGraph, &Platform)> = vec![(&g320, &plat), (&g512, &plat)];
        let seeds = vec![
            GridSeed { keep_iterates: true, ..Default::default() },
            GridSeed { chain_from: Some((0, true)), ..Default::default() },
        ];
        let out = solve_alloc_grid_seeded(&items, seeds, 1e-4, 80_000, 2);
        assert!(out[0].1.is_some(), "kept iterates");
        for (i, &(gr, p)) in items.iter().enumerate() {
            let solo = solve_hlp_capped(gr, p, LpBackendKind::RustPdhg, 1e-4, 80_000);
            let scale = 1.0 + solo.sol.obj.abs();
            assert!(
                (out[i].0.sol.obj - solo.sol.obj).abs() < 1e-3 * scale,
                "item {i}: chained {} vs solo {}",
                out[i].0.sol.obj,
                solo.sol.obj
            );
        }
        // a dimension-mismatched chain (different nb => different DAG)
        // is dropped silently, not an error
        let g10 = chameleon::potrf(10, &CostModel::hybrid(320), 3);
        let items2: Vec<(&TaskGraph, &Platform)> = vec![(&g320, &plat), (&g10, &plat)];
        let seeds2 = vec![
            GridSeed::default(),
            GridSeed { chain_from: Some((0, true)), ..Default::default() },
        ];
        let out2 = solve_alloc_grid_seeded(&items2, seeds2, 1e-4, 80_000, 2);
        let solo10 = solve_hlp_capped(&g10, &plat, LpBackendKind::RustPdhg, 1e-4, 80_000);
        let scale = 1.0 + solo10.sol.obj.abs();
        assert!(
            (out2[1].0.sol.obj - solo10.sol.obj).abs() < 1e-3 * scale,
            "dropped chain must fall back to the plain solve"
        );
    }

    #[test]
    fn cross_run_iterate_seed_accepted_and_dimension_checked() {
        let g = chameleon::potrf(5, &CostModel::hybrid(320), 3);
        let plat = Platform::hybrid(8, 2);
        let items: Vec<(&TaskGraph, &Platform)> = vec![(&g, &plat)];
        let keep = vec![GridSeed { keep_iterates: true, ..Default::default() }];
        let run1 = solve_alloc_grid_seeded(&items, keep, 1e-4, 80_000, 1);
        let (z, y) = run1[0].1.clone().expect("kept iterates");

        // "next process": seed from the persisted iterates — same LP*,
        // and convergence from the finished point is not slower than
        // the cold run (one-chunk certificate slack)
        let seeded = vec![GridSeed { iterates: Some((z, y)), ..Default::default() }];
        let run2 = solve_alloc_grid_seeded(&items, seeded, 1e-4, 80_000, 1);
        let scale = 1.0 + run1[0].0.sol.obj.abs();
        assert!(
            (run2[0].0.sol.obj - run1[0].0.sol.obj).abs() < 1e-3 * scale,
            "warm {} vs cold {}",
            run2[0].0.sol.obj,
            run1[0].0.sol.obj
        );
        assert!(run2[0].0.sol.iters <= run1[0].0.sol.iters + 250);

        // stale iterates with wrong dimensions are dropped silently
        let bad = vec![GridSeed {
            iterates: Some((vec![0.0; 3], vec![0.0; 2])),
            ..Default::default()
        }];
        let run3 = solve_alloc_grid_seeded(&items, bad, 1e-4, 80_000, 1);
        assert!((run3[0].0.sol.obj - run1[0].0.sol.obj).abs() < 1e-3 * scale);
    }

    #[test]
    fn lp_star_is_lower_bound_for_makespan() {
        let g = chameleon::getrf(5, &CostModel::hybrid(128), 5);
        let plat = Platform::hybrid(16, 2);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-5);
        for algo in Offline::ALL {
            let (s, _) = run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::RustPdhg, 1e-5);
            // LP* (within tolerance) lower-bounds any feasible makespan
            assert!(s.makespan >= hlp.sol.obj * 0.99, "{}", algo.name());
        }
    }
}
