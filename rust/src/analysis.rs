//! Campaign result records and the aggregations behind Figures 3–7:
//! per-app ratio-to-LP* distributions, pairwise algorithm ratios, and
//! competitive-ratio-vs-√(m/k) series.

use std::collections::BTreeMap;

use crate::substrate::stats::{render_csv, render_table, Summary};

/// One (instance, machine config, algorithm) measurement.
#[derive(Clone, Debug)]
pub struct Record {
    /// instance label, e.g. "potrf-nb10-bs320"
    pub instance: String,
    /// application name, e.g. "potrf" (figure grouping key)
    pub app: String,
    /// machine config label, e.g. "64x8"
    pub config: String,
    pub algo: String,
    pub makespan: f64,
    /// optimal value of the (Q)HLP relaxation for this (instance, config)
    pub lp_star: f64,
    /// √(m/k) of the config (Fig. 6-right x-axis; 0 for Q≠2)
    pub sqrt_mk: f64,
}

impl Record {
    /// makespan / LP* — the y-axis of Figs. 3, 5, 6.
    pub fn ratio(&self) -> f64 {
        self.makespan / self.lp_star
    }
}

/// Per-app summaries of makespan/LP* for one algorithm (Fig. 3/5/6-left).
pub fn ratio_by_app(records: &[Record], algo: &str) -> BTreeMap<String, Summary> {
    let mut groups: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for r in records.iter().filter(|r| r.algo == algo) {
        groups.entry(r.app.clone()).or_default().push(r.ratio());
    }
    groups
        .into_iter()
        .map(|(k, v)| (k, Summary::of(&v)))
        .collect()
}

/// Per-app summaries of makespan(A)/makespan(B) over matched
/// (instance, config) pairs (Fig. 4/5-right/7).
pub fn pairwise_by_app(records: &[Record], a: &str, b: &str) -> BTreeMap<String, Summary> {
    let mut index: BTreeMap<(&str, &str), f64> = BTreeMap::new();
    for r in records.iter().filter(|r| r.algo == b) {
        index.insert((r.instance.as_str(), r.config.as_str()), r.makespan);
    }
    let mut groups: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for r in records.iter().filter(|r| r.algo == a) {
        if let Some(mb) = index.get(&(r.instance.as_str(), r.config.as_str())) {
            groups
                .entry(r.app.clone())
                .or_default()
                .push(r.makespan / mb);
        }
    }
    groups
        .into_iter()
        .map(|(k, v)| (k, Summary::of(&v)))
        .collect()
}

/// Mean competitive ratio per machine config, keyed by √(m/k)
/// (Fig. 6-right series; one entry per config value).
pub fn ratio_by_sqrt_mk(records: &[Record], algo: &str) -> Vec<(f64, Summary)> {
    let mut groups: BTreeMap<u64, (f64, Vec<f64>)> = BTreeMap::new();
    for r in records.iter().filter(|r| r.algo == algo) {
        let key = (r.sqrt_mk * 1e6) as u64;
        groups
            .entry(key)
            .or_insert((r.sqrt_mk, Vec::new()))
            .1
            .push(r.ratio());
    }
    groups
        .into_values()
        .map(|(x, v)| (x, Summary::of(&v)))
        .collect()
}

/// Overall mean improvement of algo `a` over algo `b` in percent
/// (positive = a is better/lower makespan), as the paper quotes.
pub fn mean_improvement_pct(records: &[Record], a: &str, b: &str) -> f64 {
    let per_app = pairwise_by_app(records, a, b);
    let means: Vec<f64> = per_app.values().map(|s| s.mean).collect();
    if means.is_empty() {
        return 0.0;
    }
    let overall = means.iter().sum::<f64>() / means.len() as f64;
    (1.0 - overall) * 100.0
}

/// Render a per-app summary map as a table.
pub fn render_summary_table(title: &str, groups: &BTreeMap<String, Summary>) -> String {
    let rows: Vec<Vec<String>> = groups
        .iter()
        .map(|(app, s)| {
            vec![
                app.clone(),
                format!("{}", s.n),
                format!("{:.4}", s.mean),
                format!("{:.4}", s.stderr),
                format!("{:.4}", s.min),
                format!("{:.4}", s.p50),
                format!("{:.4}", s.max),
            ]
        })
        .collect();
    format!(
        "## {title}\n{}",
        render_table(&["app", "n", "mean", "stderr", "min", "p50", "max"], &rows)
    )
}

/// CSV dump of raw records (one row per measurement).
pub fn records_csv(records: &[Record]) -> String {
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            vec![
                r.instance.clone(),
                r.app.clone(),
                r.config.clone(),
                r.algo.clone(),
                format!("{:.6}", r.makespan),
                format!("{:.6}", r.lp_star),
                format!("{:.6}", r.ratio()),
                format!("{:.4}", r.sqrt_mk),
            ]
        })
        .collect();
    render_csv(
        &["instance", "app", "config", "algo", "makespan", "lp_star", "ratio", "sqrt_mk"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(instance: &str, app: &str, config: &str, algo: &str, ms: f64, lp: f64) -> Record {
        Record {
            instance: instance.into(),
            app: app.into(),
            config: config.into(),
            algo: algo.into(),
            makespan: ms,
            lp_star: lp,
            sqrt_mk: 2.0,
        }
    }

    #[test]
    fn ratio_by_app_groups() {
        let records = vec![
            rec("i1", "potrf", "16x2", "HEFT", 2.0, 1.0),
            rec("i2", "potrf", "16x2", "HEFT", 4.0, 2.0),
            rec("i3", "posv", "16x2", "HEFT", 3.0, 1.0),
            rec("i1", "potrf", "16x2", "HLP-OLS", 1.5, 1.0),
        ];
        let g = ratio_by_app(&records, "HEFT");
        assert_eq!(g.len(), 2);
        assert_eq!(g["potrf"].n, 2);
        assert!((g["potrf"].mean - 2.0).abs() < 1e-12);
        assert!((g["posv"].mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pairwise_matches_instances() {
        let records = vec![
            rec("i1", "potrf", "16x2", "HLP-EST", 2.0, 1.0),
            rec("i1", "potrf", "16x2", "HLP-OLS", 1.6, 1.0),
            rec("i1", "potrf", "32x4", "HLP-EST", 3.0, 1.0),
            rec("i1", "potrf", "32x4", "HLP-OLS", 1.5, 1.0),
            // unmatched record ignored
            rec("i9", "potrf", "16x2", "HLP-EST", 9.0, 1.0),
        ];
        let g = pairwise_by_app(&records, "HLP-EST", "HLP-OLS");
        assert_eq!(g["potrf"].n, 2);
        assert!((g["potrf"].mean - (2.0 / 1.6 + 2.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn improvement_percentage_sign() {
        let records = vec![
            rec("i1", "a", "c", "X", 0.9, 1.0),
            rec("i1", "a", "c", "Y", 1.0, 1.0),
        ];
        // X beats Y by 10%
        assert!((mean_improvement_pct(&records, "X", "Y") - 10.0).abs() < 1e-9);
        assert!(mean_improvement_pct(&records, "Y", "X") < 0.0);
    }

    #[test]
    fn sqrt_mk_series() {
        let mut records = vec![rec("i1", "a", "16x4", "ER-LS", 2.0, 1.0)];
        records[0].sqrt_mk = 2.0;
        let mut r2 = rec("i2", "a", "64x4", "ER-LS", 8.0, 2.0);
        r2.sqrt_mk = 4.0;
        records.push(r2);
        let series = ratio_by_sqrt_mk(&records, "ER-LS");
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].0, 2.0);
        assert_eq!(series[1].1.mean, 4.0);
    }

    #[test]
    fn renders() {
        let records = vec![rec("i1", "a", "c", "X", 2.0, 1.0)];
        let t = render_summary_table("T", &ratio_by_app(&records, "X"));
        assert!(t.contains("## T") && t.contains("| a"));
        let csv = records_csv(&records);
        assert!(csv.lines().count() == 2);
    }
}
