//! Parse artifacts/manifest.json (written by python/compile/aot.py):
//! the bucket ladder of compiled PDHG chunk executables.

use std::path::{Path, PathBuf};

use crate::substrate::json::{parse, Json};

#[derive(Clone, Debug, PartialEq)]
pub struct BucketSpec {
    pub name: String,
    pub file: String,
    /// padded variable count (multiple of `block`)
    pub n: usize,
    /// padded row count
    pub r: usize,
    /// padded nonzero count
    pub nz: usize,
    /// PDHG iterations per executable call
    pub iters: usize,
    pub block: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub pad_b: f64,
    pub buckets: Vec<BucketSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let dir = path
            .parent()
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| PathBuf::from("."));
        Self::parse_with_dir(&text, dir)
    }

    pub fn parse_with_dir(text: &str, dir: PathBuf) -> Result<Manifest, String> {
        let v = parse(text)?;
        if v.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err("manifest: unsupported format".into());
        }
        let pad_b = v
            .get("pad_b")
            .and_then(Json::as_f64)
            .ok_or("manifest: missing pad_b")?;
        let mut buckets = Vec::new();
        for b in v
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or("manifest: missing buckets")?
        {
            let field = |k: &str| -> Result<usize, String> {
                b.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("manifest bucket: missing {k}"))
            };
            buckets.push(BucketSpec {
                name: b
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or("bucket name")?
                    .to_string(),
                file: b
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or("bucket file")?
                    .to_string(),
                n: field("n")?,
                r: field("r")?,
                nz: field("nz")?,
                iters: field("iters")?,
                block: field("block")?,
            });
        }
        if buckets.is_empty() {
            return Err("manifest: no buckets".into());
        }
        // keep sorted by capacity so pick() returns the smallest fit
        buckets.sort_by_key(|b| (b.n, b.r, b.nz));
        Ok(Manifest { dir, pad_b, buckets })
    }

    /// Smallest bucket that fits an LP of the given dimensions.
    pub fn pick(&self, n_vars: usize, n_rows: usize, nnz: usize) -> Option<&BucketSpec> {
        self.buckets
            .iter()
            .find(|b| n_vars <= b.n && n_rows <= b.r && nnz <= b.nz)
    }

    pub fn hlo_path(&self, bucket: &BucketSpec) -> PathBuf {
        self.dir.join(&bucket.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "pad_b": 1e9,
      "buckets": [
        {"name": "b1", "file": "pdhg_b1.hlo.txt", "n": 8192, "r": 16384,
         "nz": 65536, "iters": 250, "block": 4096},
        {"name": "b0", "file": "pdhg_b0.hlo.txt", "n": 4096, "r": 8192,
         "nz": 32768, "iters": 250, "block": 4096}
      ]
    }"#;

    #[test]
    fn parses_and_sorts() {
        let m = Manifest::parse_with_dir(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.pad_b, 1e9);
        assert_eq!(m.buckets.len(), 2);
        assert_eq!(m.buckets[0].name, "b0"); // sorted by size
        assert_eq!(m.hlo_path(&m.buckets[1]).to_str().unwrap(), "/a/pdhg_b1.hlo.txt");
    }

    #[test]
    fn pick_smallest_fit() {
        let m = Manifest::parse_with_dir(SAMPLE, PathBuf::from(".")).unwrap();
        assert_eq!(m.pick(100, 100, 100).unwrap().name, "b0");
        assert_eq!(m.pick(5000, 100, 100).unwrap().name, "b1");
        assert!(m.pick(100_000, 1, 1).is_none());
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Manifest::parse_with_dir("{}", PathBuf::from(".")).is_err());
        assert!(Manifest::parse_with_dir(
            r#"{"format":"protobuf","pad_b":1,"buckets":[]}"#,
            PathBuf::from(".")
        )
        .is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration: if `make artifacts` has run, the real manifest parses
        let path = crate::runtime::artifacts_dir().join("manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(m.pick(4 * 4620 + 1, 30_000, 140_000).is_some(),
                "ladder must cover the largest campaign LP");
        }
    }
}
