//! The PJRT chunk backend: executes the AOT-compiled PDHG chunk (JAX +
//! Pallas, lowered to HLO text) on the CPU PJRT client, implementing the
//! same [`ChunkBackend`] contract as the Rust mirror so `lp::pdhg::drive`
//! can drive either interchangeably.
//!
//! Padding contract (must match python/compile/model.py):
//!   * padded columns: c = 0, lo = hi = 0
//!   * padded rows:    b = PAD_B (manifest.pad_b)
//!   * padded nnz:     val = 0, row = 0, col = 0

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::lp::pdhg::{drive, ChunkBackend, ChunkResult, Diag};
use crate::lp::{LpSolution, SparseLp};

use super::manifest::{BucketSpec, Manifest};

/// Loaded artifacts + compiled executables (one per bucket, compiled
/// lazily on first use and cached for the process lifetime).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative PDHG iterations executed through PJRT (perf telemetry)
    pub total_iters: usize,
    /// cumulative chunk calls
    pub total_chunks: usize,
}

impl PjrtRuntime {
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(PjrtRuntime {
            client,
            manifest,
            executables: HashMap::new(),
            total_iters: 0,
            total_chunks: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&mut self, bucket: &BucketSpec) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(&bucket.name) {
            let path = self.manifest.hlo_path(bucket);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).context("PJRT compile")?;
            self.executables.insert(bucket.name.clone(), exe);
        }
        Ok(&self.executables[&bucket.name])
    }

    /// Solve an LP end-to-end via the artifact (scaling + chunk driving
    /// handled by `lp::pdhg::drive`, exactly like the Rust backend).
    pub fn solve(&mut self, lp: &SparseLp, opts: &crate::lp::pdhg::DriveOpts) -> Result<LpSolution> {
        let bucket = self
            .manifest
            .pick(lp.n, lp.m, lp.nnz())
            .ok_or_else(|| anyhow!("LP ({} vars, {} rows, {} nnz) exceeds bucket ladder",
                lp.n, lp.m, lp.nnz()))?
            .clone();
        let pad_b = self.manifest.pad_b;
        // compile (cached) before borrowing immutably for the chunks
        self.executable(&bucket)?;
        let exe = &self.executables[&bucket.name];
        let sol = drive(lp, opts, |scaled| {
            // fit was validated by pick(); scaling never grows dimensions
            PjrtChunk::new(exe, &bucket, pad_b, scaled).expect("chunk init")
        });
        self.total_iters += sol.iters;
        self.total_chunks += sol.iters / bucket.iters.max(1);
        Ok(sol)
    }
}

/// One in-flight LP solve on a fixed bucket: the padded static inputs
/// are kept as host literals and marshalled per chunk.
///
/// §Perf note: device-resident `PjRtBuffer` reuse via `execute_b` was
/// tried and reverted — the xla-rs C wrapper's `Execute` *consumes*
/// input buffers (the literal path deliberately `release()`s ownership
/// into it), so reusing a buffer across chunks is a use-after-free.
/// The literal path re-uploads ~0.5 MB per 250-iteration chunk, which
/// profiling shows is < 3% of chunk time on this CPU target.
pub struct PjrtChunk<'a> {
    exe: &'a xla::PjRtLoadedExecutable,
    bucket: BucketSpec,
    // static inputs (host literals, uploaded by execute() each chunk)
    nz_val: xla::Literal,
    nz_row: xla::Literal,
    nz_col: xla::Literal,
    b: xla::Literal,
    c: xla::Literal,
    lo: xla::Literal,
    hi: xla::Literal,
    // scratch for f32 conversion
    zbuf: Vec<f32>,
    ybuf: Vec<f32>,
    // ergodic averages of the last chunk (restart candidates)
    z_avg: Vec<f32>,
    y_avg: Vec<f32>,
}

fn lit_f32(values: &[f32]) -> xla::Literal {
    xla::Literal::vec1(values)
}

fn lit_i32(values: &[i32]) -> xla::Literal {
    xla::Literal::vec1(values)
}

impl<'a> PjrtChunk<'a> {
    pub fn new(
        exe: &'a xla::PjRtLoadedExecutable,
        bucket: &BucketSpec,
        pad_b: f64,
        lp: &SparseLp,
    ) -> Result<PjrtChunk<'a>> {
        if lp.n > bucket.n || lp.m > bucket.r || lp.nnz() > bucket.nz {
            return Err(anyhow!("LP does not fit bucket {}", bucket.name));
        }
        let mut nz_val = vec![0.0f32; bucket.nz];
        let mut nz_row = vec![0i32; bucket.nz];
        let mut nz_col = vec![0i32; bucket.nz];
        for i in 0..lp.nnz() {
            nz_val[i] = lp.vals[i] as f32;
            nz_row[i] = lp.rows[i] as i32;
            nz_col[i] = lp.cols[i] as i32;
        }
        let mut b = vec![pad_b as f32; bucket.r];
        for (dst, src) in b.iter_mut().zip(&lp.b) {
            *dst = *src as f32;
        }
        let mut c = vec![0.0f32; bucket.n];
        let mut lo = vec![0.0f32; bucket.n];
        let mut hi = vec![0.0f32; bucket.n];
        for j in 0..lp.n {
            c[j] = lp.c[j] as f32;
            lo[j] = lp.lo[j] as f32;
            hi[j] = lp.hi[j] as f32;
        }
        Ok(PjrtChunk {
            exe,
            bucket: bucket.clone(),
            nz_val: lit_f32(&nz_val),
            nz_row: lit_i32(&nz_row),
            nz_col: lit_i32(&nz_col),
            b: lit_f32(&b),
            c: lit_f32(&c),
            lo: lit_f32(&lo),
            hi: lit_f32(&hi),
            zbuf: vec![0.0f32; bucket.n],
            ybuf: vec![0.0f32; bucket.r],
            z_avg: vec![0.0f32; bucket.n],
            y_avg: vec![0.0f32; bucket.r],
        })
    }

    /// Execute one chunk; returns (z, y, z_avg, y_avg, diag8).
    fn execute(
        &mut self,
        tau: f64,
        sigma: f64,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let z0 = lit_f32(&self.zbuf);
        let y0 = lit_f32(&self.ybuf);
        let tau_l = lit_f32(&[tau as f32]);
        let sigma_l = lit_f32(&[sigma as f32]);
        let args: Vec<&xla::Literal> = vec![
            &self.nz_val, &self.nz_row, &self.nz_col, &self.b, &self.c, &self.lo, &self.hi,
            &z0, &y0, &tau_l, &sigma_l,
        ];
        let result = self.exe.execute::<&xla::Literal>(&args).context("execute")?;
        let mut out = result[0][0].to_literal_sync().context("to_literal")?;
        // jax lowered with return_tuple=True: (z, y, z_avg, y_avg, diag)
        let parts = out.decompose_tuple().context("decompose")?;
        if parts.len() != 5 {
            anyhow::bail!("expected 5 outputs, got {}", parts.len());
        }
        let mut it = parts.into_iter();
        let z = it.next().unwrap().to_vec::<f32>().context("z")?;
        let y = it.next().unwrap().to_vec::<f32>().context("y")?;
        let za = it.next().unwrap().to_vec::<f32>().context("z_avg")?;
        let ya = it.next().unwrap().to_vec::<f32>().context("y_avg")?;
        let diag = it.next().unwrap().to_vec::<f32>().context("diag")?;
        Ok((z, y, za, ya, diag))
    }
}

impl ChunkBackend for PjrtChunk<'_> {
    fn run_chunk(&mut self, z: &mut [f64], y: &mut [f64], tau: f64, sigma: f64) -> ChunkResult {
        for (dst, src) in self.zbuf.iter_mut().zip(z.iter()) {
            *dst = *src as f32;
        }
        for (dst, src) in self.ybuf.iter_mut().zip(y.iter()) {
            *dst = *src as f32;
        }
        let (znew, ynew, za, ya, diag) = self
            .execute(tau, sigma)
            .expect("PJRT chunk execution failed");
        for (dst, src) in z.iter_mut().zip(znew.iter()) {
            *dst = *src as f64;
        }
        for (dst, src) in y.iter_mut().zip(ynew.iter()) {
            *dst = *src as f64;
        }
        self.z_avg = za;
        self.y_avg = ya;
        let d = |o: usize| Diag {
            pobj: diag[o] as f64,
            dobj: diag[o + 1] as f64,
            pres: diag[o + 2] as f64,
            dres: diag[o + 3] as f64,
        };
        ChunkResult {
            last: d(0),
            avg: d(4),
        }
    }

    fn load_avg(&self, z: &mut [f64], y: &mut [f64]) {
        for (dst, src) in z.iter_mut().zip(self.z_avg.iter()) {
            *dst = *src as f64;
        }
        for (dst, src) in y.iter_mut().zip(self.y_avg.iter()) {
            *dst = *src as f64;
        }
    }

    fn iters_per_chunk(&self) -> usize {
        self.bucket.iters
    }

    fn name(&self) -> &'static str {
        "pdhg-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::pdhg::DriveOpts;

    fn artifacts_present() -> bool {
        crate::runtime::artifacts_dir().join("manifest.json").exists()
    }

    fn knapsack() -> SparseLp {
        let mut lp = SparseLp {
            n: 2,
            m: 1,
            b: vec![1.5],
            c: vec![-1.0, -1.0],
            lo: vec![0.0; 2],
            hi: vec![1.0; 2],
            ..Default::default()
        };
        lp.push(0, 0, 1.0);
        lp.push(0, 1, 1.0);
        lp
    }

    #[test]
    fn pjrt_solves_knapsack() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = PjrtRuntime::load(&crate::runtime::artifacts_dir()).unwrap();
        let sol = rt
            .solve(&knapsack(), &DriveOpts { tol: 1e-4, ..Default::default() })
            .unwrap();
        assert_eq!(sol.backend, "pdhg-pjrt");
        assert!((sol.obj + 1.5).abs() < 5e-3, "obj {}", sol.obj);
        assert!(rt.total_iters > 0);
    }

    #[test]
    fn pjrt_agrees_with_rust_backend_on_hlp() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        use crate::lp::model::build_hlp;
        use crate::platform::Platform;
        use crate::workloads::{chameleon, costs::CostModel};
        let g = chameleon::potrf(5, &CostModel::hybrid(320), 7);
        let (lp, _) = build_hlp(&g, &Platform::hybrid(4, 2));
        let mut rt = PjrtRuntime::load(&crate::runtime::artifacts_dir()).unwrap();
        let opts = DriveOpts { tol: 1e-4, ..Default::default() };
        let a = rt.solve(&lp, &opts).unwrap();
        let b = crate::lp::pdhg::solve_rust(&lp, &opts);
        let scale = 1.0 + a.obj.abs().max(b.obj.abs());
        assert!(
            (a.obj - b.obj).abs() / scale < 5e-3,
            "pjrt {} vs rust {}",
            a.obj,
            b.obj
        );
    }
}
