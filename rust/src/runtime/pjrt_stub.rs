//! Stub PJRT runtime, compiled when the `pjrt` feature is off (the
//! default for the offline build, where the `xla` runtime crate is not
//! vendored).  Mirrors the API surface of the real
//! [`pjrt`](crate::runtime) module: `load` always fails, so
//! [`with_runtime`](crate::runtime::with_runtime) reports the artifacts
//! as absent and `LpBackendKind::Auto` silently falls back to the
//! in-tree Rust PDHG backend.

use std::path::Path;

use crate::lp::pdhg::DriveOpts;
use crate::lp::{LpSolution, SparseLp};

/// Placeholder for the loaded-artifact runtime of the real backend.
pub struct PjrtRuntime {
    /// cumulative PDHG iterations executed through PJRT (always 0 here)
    pub total_iters: usize,
    /// cumulative chunk calls (always 0 here)
    pub total_chunks: usize,
}

impl PjrtRuntime {
    pub fn load(_dir: &Path) -> Result<PjrtRuntime, String> {
        Err("hetsched was built without the `pjrt` feature (the `xla` \
             runtime crate is not vendored in this build); use the \
             rust/simplex LP backends"
            .to_string())
    }

    pub fn solve(&mut self, _lp: &SparseLp, _opts: &DriveOpts) -> Result<LpSolution, String> {
        Err("PJRT backend unavailable: built without the `pjrt` feature".to_string())
    }
}
