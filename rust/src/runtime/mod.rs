//! PJRT runtime: load the AOT-compiled JAX/Pallas PDHG artifacts (HLO
//! text, see python/compile/aot.py) and drive them from the Rust side.
//!
//! This is the Layer-3 ↔ Layer-2/1 bridge.  `make artifacts` produces
//! `artifacts/pdhg_<bucket>.hlo.txt` + `manifest.json`; at startup we
//! parse the manifest, compile each needed bucket once on the PJRT CPU
//! client (compilation is cached per process), and then every HLP/QHLP
//! solve pads its scaled LP into the smallest fitting bucket and runs
//! 250-iteration chunks until the duality-gap certificate closes.

pub mod manifest;

// The real PJRT client needs the vendored `xla` tree (not shipped in the
// offline build); without the `pjrt` feature a stub with the same API
// surface always fails to load, so `with_runtime` returns `None` and
// `LpBackendKind::Auto` falls back to the Rust PDHG mirror.
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

use crate::lp::pdhg::{self, DriveOpts};
use crate::lp::{LpSolution, SparseLp};

use manifest::Manifest;
use pjrt::PjrtRuntime;

/// Which LP backend to use for the allocation phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LpBackendKind {
    /// AOT JAX/Pallas artifact via PJRT if available, else Rust PDHG.
    Auto,
    /// Force the in-tree Rust PDHG mirror.
    RustPdhg,
    /// Force the PJRT artifact (error if artifacts are missing).
    Pjrt,
    /// Exact dense simplex (small instances only).
    Simplex,
}

impl LpBackendKind {
    pub fn parse(s: &str) -> Option<LpBackendKind> {
        match s {
            "auto" => Some(LpBackendKind::Auto),
            "rust" | "pdhg-rust" => Some(LpBackendKind::RustPdhg),
            "pjrt" => Some(LpBackendKind::Pjrt),
            "simplex" => Some(LpBackendKind::Simplex),
            _ => None,
        }
    }
}

// The PJRT client is Rc-based (not Send), so the cached runtime is
// per-thread: each campaign worker compiles its own executables once
// (compilation of the ~40 kB chunk HLOs is cheap next to the solves).
thread_local! {
    static TLS_RT: std::cell::RefCell<Option<Result<PjrtRuntime, String>>> =
        const { std::cell::RefCell::new(None) };
}

/// Default artifacts directory: $HETSCHED_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("HETSCHED_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Run `f` with this thread's PJRT runtime (initialized on first use).
/// Returns `None` if the artifacts are absent or fail to load.
pub fn with_runtime<R>(f: impl FnOnce(&mut PjrtRuntime) -> R) -> Option<R> {
    TLS_RT.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(
                PjrtRuntime::load(&artifacts_dir()).map_err(|e| e.to_string()),
            );
        }
        match slot.as_mut().unwrap() {
            Ok(rt) => Some(f(rt)),
            Err(_) => None,
        }
    })
}

/// Whether this thread can solve through the PJRT artifact backend
/// (loads the runtime on first call; cheap afterwards).  The campaign
/// driver uses this to decide between the batched Rust-PDHG path and the
/// per-item artifact path under `LpBackendKind::Auto`.
pub fn pjrt_available() -> bool {
    with_runtime(|_| ()).is_some()
}

/// Solve an LP with the selected backend (the campaign entry point).
/// `warm` is a feasible primal point in original coordinates, if known.
pub fn solve_lp(
    lp: &SparseLp,
    kind: LpBackendKind,
    tol: f64,
    warm: Option<Vec<f64>>,
) -> LpSolution {
    solve_lp_capped(lp, kind, tol, warm, DriveOpts::default().max_iters)
}

/// `solve_lp` with an explicit PDHG iteration budget (campaign knob:
/// stragglers return with a certified-but-looser gap instead of
/// burning minutes).
pub fn solve_lp_capped(
    lp: &SparseLp,
    kind: LpBackendKind,
    tol: f64,
    warm: Option<Vec<f64>>,
    max_iters: usize,
) -> LpSolution {
    let opts = DriveOpts {
        tol,
        warm_start: warm,
        max_iters,
        ..Default::default()
    };
    match kind {
        LpBackendKind::Simplex => crate::lp::simplex::solve_simplex(lp)
            .expect("simplex failed on allocation LP (feasible by construction)"),
        LpBackendKind::RustPdhg => pdhg::solve_rust(lp, &opts),
        LpBackendKind::Pjrt => with_runtime(|rt| rt.solve(lp, &opts))
            .expect("PJRT artifacts not found (run `make artifacts`)")
            .expect("PJRT solve failed"),
        LpBackendKind::Auto => {
            match with_runtime(|rt| rt.solve(lp, &opts)) {
                Some(Ok(sol)) => sol,
                _ => pdhg::solve_rust(lp, &opts),
            }
        }
    }
}

/// Load just the manifest (used by CLI info commands and tests).
pub fn load_manifest() -> Result<Manifest, String> {
    Manifest::load(&artifacts_dir().join("manifest.json"))
}
