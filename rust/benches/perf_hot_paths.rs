// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: hot-path performance (EXPERIMENTS.md §Perf).
//!
//! Sections:
//!   0. engine vs seed schedulers on a 5000-task, 32+8-unit instance —
//!      the event-driven-core acceptance gate — plus gap-indexed HEFT vs
//!      the reference timeline scan on a 10k-task, 256-unit (192+64)
//!      instance, plus the Tick-vs-f64 decision-comparator row (the
//!      integer clock must not lose to the banded float compare it
//!      replaced).  Results (and speedups) are written to
//!      BENCH_sched.json so the perf trajectory is tracked PR over PR;
//!      gates: EST >= 5x seed, HEFT >= 1x the linear scan, clock
//!      tick_ms <= 1.05x f64_ms.
//!   L3: LP build, Ruiz scaling, list/EST/HEFT schedulers, ranks,
//!       validator, and the end-to-end offline pipeline.
//!   L1+L2: PDHG chunk execution through PJRT (skipped without
//!       artifacts), plus the paper's ~100 s GLPK anchor re-timed.
//!
//! Set HETSCHED_BENCH_QUICK=1 to stop after the JSON is written.

use hetsched::algos::solve_hlp_capped;
use hetsched::graph::{gen, paths};
use hetsched::lp::model::{build_hlp, hlp_warm_start, tighten_hlp_box};
use hetsched::lp::pdhg::{solve_rust, ChunkBackend, DriveOpts, RustChunk};
use hetsched::lp::scale::ruiz;
use hetsched::platform::Platform;
use hetsched::runtime::{with_runtime, LpBackendKind};
use hetsched::sched::engine::Tick;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sched::{est::est_schedule, heft::heft_schedule, list::ols_schedule, reference};
use hetsched::sim::validate;
use hetsched::substrate::bench::{bench, bench_with, black_box, BenchOpts, BenchResult};
use hetsched::substrate::json::Json;
use hetsched::substrate::rng::Rng;
use hetsched::workloads::{chameleon, costs::CostModel};
use std::time::Duration;

fn sched_pair(
    name: &str,
    opts: &BenchOpts,
    mut engine: impl FnMut() -> f64,
    mut seedf: impl FnMut() -> f64,
) -> (BenchResult, BenchResult, f64) {
    // parity sanity before timing anything
    let (me, ms) = (engine(), seedf());
    assert_eq!(me, ms, "{name}: engine and seed makespans diverged");
    let e = bench_with(&format!("{name} (engine)"), opts, || {
        black_box(engine());
    });
    println!("{}", e.report());
    let s = bench_with(&format!("{name} (seed)"), opts, || {
        black_box(seedf());
    });
    println!("{}", s.report());
    let speedup = s.mean.as_secs_f64() / e.mean.as_secs_f64();
    println!("    -> speedup {speedup:.1}x");
    (e, s, speedup)
}

fn main() {
    // ---- 0. acceptance gate: 5000 tasks, 32 CPUs + 8 GPUs ----------
    println!("== engine vs seed schedulers (5000-task hybrid DAG, 32x8) ==");
    let mut rng = Rng::new(2026);
    let big = gen::hybrid_dag(&mut rng, 5000, 0.002);
    let bigplat = Platform::hybrid(32, 8);
    let bigalloc: Vec<usize> = (0..big.n_tasks())
        .map(|j| usize::from(big.p_gpu(j) < big.p_cpu(j)))
        .collect();
    println!(
        "instance: {} tasks, {} arcs, platform {}",
        big.n_tasks(),
        big.n_arcs(),
        bigplat.label()
    );
    let opts = BenchOpts {
        warmup: Duration::from_millis(200),
        measure: Duration::from_millis(2000),
        min_iters: 3,
        max_iters: 100_000,
    };
    let (est_e, est_s, est_speedup) = sched_pair(
        "EST 5000",
        &opts,
        || est_schedule(&big, &bigplat, &bigalloc).makespan,
        || reference::est_schedule(&big, &bigplat, &bigalloc).makespan,
    );
    let (ols_e, ols_s, ols_speedup) = sched_pair(
        "OLS 5000",
        &opts,
        || ols_schedule(&big, &bigplat, &bigalloc).makespan,
        || reference::ols_schedule(&big, &bigplat, &bigalloc).makespan,
    );
    let (onl_e, onl_s, onl_speedup) = sched_pair(
        "online ER-LS 5000",
        &opts,
        || online_by_id(&big, &bigplat, &OnlinePolicy::ErLs).makespan,
        || reference::online_by_id(&big, &bigplat, &OnlinePolicy::ErLs).makespan,
    );

    // ---- gap-indexed HEFT: 10k tasks on a 256-unit (192+64) platform —
    // the cluster-scale regime the gap index unlocks.  The reference is
    // the per-task scan over every unit's timeline.
    println!("\n== gap-index HEFT vs reference scan (10k-task DAG, 192x64) ==");
    let huge = gen::hybrid_dag(&mut rng, 10_000, 0.001);
    let hugeplat = Platform::hybrid(192, 64);
    println!(
        "instance: {} tasks, {} arcs, platform {}",
        huge.n_tasks(),
        huge.n_arcs(),
        hugeplat.label()
    );
    let (heft_e, heft_s, heft_speedup) = sched_pair(
        "HEFT 10k/256u",
        &opts,
        || heft_schedule(&huge, &hugeplat).makespan,
        || reference::heft_schedule(&huge, &hugeplat).makespan,
    );

    // ---- tick vs f64 clock: decision-comparator throughput ---------
    // Every heap pop, gap probe and tie-break in the engine compares
    // event times.  Before the Tick migration each comparison was a
    // banded float compare (subtract, abs, branch against the 1e-9
    // band, then order); now it is one integer compare.  Time both
    // over the same decision stream of quantized event times.
    println!("\n== event-clock comparator: Tick(u64) vs banded f64 ==");
    let mut trng = Rng::new(777);
    let times: Vec<f64> = (0..(1 << 20) + 1).map(|_| trng.uniform(0.0, 1e6)).collect();
    let ticks: Vec<Tick> = times.iter().map(|&t| Tick::quantize(t)).collect();
    let seed_band = 1e-9; // the comparator band the seed schedulers used
    let band_before = |a: f64, b: f64| (a - b).abs() > seed_band && a < b;
    let clock_f64 = bench_with("decision stream (banded f64)", &opts, || {
        let ts = black_box(&times);
        let n = ts.windows(2).filter(|w| band_before(w[0], w[1])).count();
        black_box(n);
    });
    println!("{}", clock_f64.report());
    let clock_tick = bench_with("decision stream (Tick)", &opts, || {
        let ts = black_box(&ticks);
        let n = ts.windows(2).filter(|w| w[0] < w[1]).count();
        black_box(n);
    });
    println!("{}", clock_tick.report());
    let clock_speedup = clock_f64.mean.as_secs_f64() / clock_tick.mean.as_secs_f64();
    println!("    -> tick comparator {clock_speedup:.2}x the banded-float baseline");

    let ms = |r: &BenchResult| Json::Num(r.mean.as_secs_f64() * 1e3);
    let section = |e: &BenchResult, s: &BenchResult, speedup: f64| {
        Json::obj(vec![
            ("engine_ms", ms(e)),
            ("seed_ms", ms(s)),
            ("speedup", Json::Num(speedup)),
        ])
    };
    let report = Json::obj(vec![
        ("bench", Json::Str("perf_hot_paths".into())),
        (
            "instance",
            Json::obj(vec![
                ("tasks", Json::Num(big.n_tasks() as f64)),
                ("arcs", Json::Num(big.n_arcs() as f64)),
                ("platform", Json::Str(bigplat.label())),
            ]),
        ),
        ("est", section(&est_e, &est_s, est_speedup)),
        ("ols", section(&ols_e, &ols_s, ols_speedup)),
        ("online_erls", section(&onl_e, &onl_s, onl_speedup)),
        (
            "heft_instance",
            Json::obj(vec![
                ("tasks", Json::Num(huge.n_tasks() as f64)),
                ("arcs", Json::Num(huge.n_arcs() as f64)),
                ("platform", Json::Str(hugeplat.label())),
            ]),
        ),
        ("heft", section(&heft_e, &heft_s, heft_speedup)),
        (
            "clock",
            Json::obj(vec![
                ("tick_ms", ms(&clock_tick)),
                ("f64_ms", ms(&clock_f64)),
                ("speedup", Json::Num(clock_speedup)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_sched.json", report.to_string()).expect("write BENCH_sched.json");
    println!("wrote BENCH_sched.json\n");
    assert!(
        est_speedup >= 5.0,
        "acceptance: EST engine must be >= 5x the seed (got {est_speedup:.1}x)"
    );
    assert!(
        heft_speedup >= 1.0,
        "acceptance: gap-index HEFT must beat the 256-unit linear scan (got {heft_speedup:.2}x)"
    );
    // 5% noise slack, same as the kernel gate in lp_batch: both loops
    // stream 8 bytes/decision, so the win is compute-side and small
    // enough for scheduler jitter to matter on a loaded box
    assert!(
        clock_tick.mean.as_secs_f64() <= clock_f64.mean.as_secs_f64() * 1.05,
        "acceptance: Tick comparator must not lose to the banded f64 baseline (got {clock_speedup:.2}x)"
    );

    if std::env::var("HETSCHED_BENCH_QUICK").is_ok() {
        return;
    }

    // ---- L3 hot paths ----------------------------------------------
    let plat = Platform::hybrid(16, 4);
    let g = chameleon::posv(10, &CostModel::hybrid(320), 3); // 330 tasks
    let alloc: Vec<usize> = (0..g.n_tasks())
        .map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j)))
        .collect();

    println!("== L3 hot paths (posv nb=10, 330 tasks, 16x4) ==");
    bench("build_hlp", || {
        black_box(build_hlp(&g, &plat));
    });
    let (lp, vars) = build_hlp(&g, &plat);
    bench("ruiz scaling (8 rounds)", || {
        black_box(ruiz(&lp, 8));
    });
    bench("ols_rank (bottom levels)", || {
        black_box(paths::ols_rank(&g, &alloc));
    });
    bench("list scheduler (OLS)", || {
        black_box(ols_schedule(&g, &plat, &alloc));
    });
    bench("EST scheduler", || {
        black_box(est_schedule(&g, &plat, &alloc));
    });
    bench("HEFT scheduler (insertion)", || {
        black_box(heft_schedule(&g, &plat));
    });
    let s = ols_schedule(&g, &plat, &alloc);
    bench("schedule validator", || {
        validate(&g, &plat, &s).unwrap();
    });

    println!("\n== L1+L2: PDHG chunks (scaled LP, 250 iters/chunk) ==");
    let mut scaled_lp = lp.clone();
    let warm = hlp_warm_start(&g, &plat, &alloc, &vars);
    tighten_hlp_box(&mut scaled_lp, &vars, warm[vars.lambda]);
    let (scaled, _) = ruiz(&scaled_lp, 8);
    let mut rust_chunk = RustChunk::new(&scaled, 250);
    let mut z = vec![0.0; scaled.n];
    let mut y = vec![0.0; scaled.m];
    let slow = BenchOpts {
        warmup: Duration::from_millis(300),
        measure: Duration::from_secs(3),
        ..Default::default()
    };
    let r = bench_with("rust chunk: 250 PDHG iters", &slow, || {
        black_box(rust_chunk.run_chunk(&mut z, &mut y, 1e-3, 1e-3));
    });
    println!("{}", r.report());
    println!("    -> {:.0} PDHG iters/s (rust, f64)", r.throughput(250.0));

    let pjrt_ok = with_runtime(|rt| {
        let opts = DriveOpts {
            tol: 1e-4,
            warm_start: Some(warm.clone()),
            ..Default::default()
        };
        // end-to-end solves through the artifact
        let t = std::time::Instant::now();
        let sol = rt.solve(&scaled_lp, &opts).expect("pjrt solve");
        println!(
            "pjrt end-to-end solve: obj {:.4}, {} iters in {:?} ({:.0} iters/s)",
            sol.obj,
            sol.iters,
            t.elapsed(),
            sol.iters as f64 / t.elapsed().as_secs_f64()
        );
    })
    .is_some();
    if !pjrt_ok {
        println!("(PJRT artifacts not present; run `make artifacts`)");
    }

    println!("\n== paper anchor: full HLP of potri nb=20 (4620 tasks, 64x8) ==");
    let anchor = chameleon::potri(20, &CostModel::hybrid(320), 7);
    let anchorplat = Platform::hybrid(64, 8);
    let t = std::time::Instant::now();
    let sol = solve_hlp_capped(&anchor, &anchorplat, LpBackendKind::RustPdhg, 1e-3, 120_000);
    println!(
        "rust-pdhg: LP* = {:.4} (gap {:.1e}, {} iters) in {:?}  [paper/GLPK: ~100 s]",
        sol.sol.obj,
        sol.sol.gap,
        sol.sol.iters,
        t.elapsed()
    );

    // LP solve comparison across backends on a mid instance
    println!("\n== backend comparison (potrf nb=10, 220 tasks, 16x4) ==");
    let mid = chameleon::potrf(10, &CostModel::hybrid(320), 3);
    let (midlp, _) = build_hlp(&mid, &plat);
    let r = bench_with("rust-pdhg", &slow, || {
        black_box(solve_rust(
            &midlp,
            &DriveOpts {
                tol: 1e-4,
                ..Default::default()
            },
        ));
    });
    println!("{}", r.report());
}
