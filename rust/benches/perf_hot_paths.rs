//! Bench: hot-path performance (EXPERIMENTS.md §Perf).
//!
//! L1+L2: PDHG chunk execution through PJRT (per-bucket iterations/sec,
//!        and the padding waste vs the Rust mirror on the same LP);
//! L3:    LP build, Ruiz scaling, list/EST/HEFT schedulers, ranks,
//!        validator, and the end-to-end offline pipeline.
//!
//! The paper's anchor (§6.2): "the linear program resolution took about
//! 100 seconds" on the biggest instance (potri nb=20, 4620 tasks) with
//! GLPK; the same relaxation is timed below end-to-end.

use hetsched::algos::solve_hlp_capped;
use hetsched::graph::paths;
use hetsched::lp::model::{build_hlp, hlp_warm_start, tighten_hlp_box};
use hetsched::lp::pdhg::{solve_rust, ChunkBackend, DriveOpts, RustChunk};
use hetsched::lp::scale::ruiz;
use hetsched::platform::Platform;
use hetsched::runtime::{with_runtime, LpBackendKind};
use hetsched::sched::{est::est_schedule, heft::heft_schedule, list::ols_schedule};
use hetsched::sim::validate;
use hetsched::substrate::bench::{bench, bench_with, black_box, BenchOpts};
use hetsched::workloads::{chameleon, costs::CostModel};
use std::time::Duration;

fn main() {
    let plat = Platform::hybrid(16, 4);
    let g = chameleon::posv(10, &CostModel::hybrid(320), 3); // 330 tasks
    let alloc: Vec<usize> = (0..g.n_tasks())
        .map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j)))
        .collect();

    println!("== L3 hot paths (posv nb=10, 330 tasks, 16x4) ==");
    bench("build_hlp", || {
        black_box(build_hlp(&g, &plat));
    });
    let (lp, vars) = build_hlp(&g, &plat);
    bench("ruiz scaling (8 rounds)", || {
        black_box(ruiz(&lp, 8));
    });
    bench("ols_rank (bottom levels)", || {
        black_box(paths::ols_rank(&g, &alloc));
    });
    bench("list scheduler (OLS)", || {
        black_box(ols_schedule(&g, &plat, &alloc));
    });
    bench("EST scheduler", || {
        black_box(est_schedule(&g, &plat, &alloc));
    });
    bench("HEFT scheduler (insertion)", || {
        black_box(heft_schedule(&g, &plat));
    });
    let s = ols_schedule(&g, &plat, &alloc);
    bench("schedule validator", || {
        validate(&g, &plat, &s).unwrap();
    });

    println!("\n== L1+L2: PDHG chunks (scaled LP, 250 iters/chunk) ==");
    let mut scaled_lp = lp.clone();
    let warm = hlp_warm_start(&g, &plat, &alloc, &vars);
    tighten_hlp_box(&mut scaled_lp, &vars, warm[vars.lambda]);
    let (scaled, _) = ruiz(&scaled_lp, 8);
    let mut rust_chunk = RustChunk::new(&scaled, 250);
    let mut z = vec![0.0; scaled.n];
    let mut y = vec![0.0; scaled.m];
    let slow = BenchOpts {
        warmup: Duration::from_millis(300),
        measure: Duration::from_secs(3),
        ..Default::default()
    };
    let r = bench_with("rust chunk: 250 PDHG iters", &slow, || {
        black_box(rust_chunk.run_chunk(&mut z, &mut y, 1e-3, 1e-3));
    });
    println!("{}", r.report());
    println!("    -> {:.0} PDHG iters/s (rust, f64)", r.throughput(250.0));

    let pjrt_ok = with_runtime(|rt| {
        let opts = DriveOpts {
            tol: 1e-4,
            warm_start: Some(warm.clone()),
            ..Default::default()
        };
        // end-to-end solves through the artifact
        let t = std::time::Instant::now();
        let sol = rt.solve(&scaled_lp, &opts).expect("pjrt solve");
        println!(
            "pjrt end-to-end solve: obj {:.4}, {} iters in {:?} ({:.0} iters/s)",
            sol.obj,
            sol.iters,
            t.elapsed(),
            sol.iters as f64 / t.elapsed().as_secs_f64()
        );
    })
    .is_some();
    if !pjrt_ok {
        println!("(PJRT artifacts not present; run `make artifacts`)");
    }

    println!("\n== paper anchor: full HLP of potri nb=20 (4620 tasks, 64x8) ==");
    let big = chameleon::potri(20, &CostModel::hybrid(320), 7);
    let bigplat = Platform::hybrid(64, 8);
    let t = std::time::Instant::now();
    let sol = solve_hlp_capped(&big, &bigplat, LpBackendKind::RustPdhg, 1e-3, 120_000);
    println!(
        "rust-pdhg: LP* = {:.4} (gap {:.1e}, {} iters) in {:?}  [paper/GLPK: ~100 s]",
        sol.sol.obj,
        sol.sol.gap,
        sol.sol.iters,
        t.elapsed()
    );

    // LP solve comparison across backends on a mid instance
    println!("\n== backend comparison (potrf nb=10, 220 tasks, 16x4) ==");
    let mid = chameleon::potrf(10, &CostModel::hybrid(320), 3);
    let (midlp, _) = build_hlp(&mid, &plat);
    for (name, f) in [
        ("rust-pdhg", Box::new(|| {
            black_box(solve_rust(&midlp, &DriveOpts { tol: 1e-4, ..Default::default() }));
        }) as Box<dyn FnMut()>),
    ] {
        let mut f = f;
        let r = bench_with(name, &slow, &mut *f);
        println!("{}", r.report());
    }
}
