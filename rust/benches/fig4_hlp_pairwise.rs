// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: regenerate Figure 4 — pairwise makespan ratios
//! HLP-EST/HLP-OLS (left) and HEFT/HLP-OLS (right), grouped by app.

use hetsched::analysis::{mean_improvement_pct, pairwise_by_app, render_summary_table};
use hetsched::experiments::{offline, CampaignOpts};
use hetsched::workloads::Scale;

fn main() {
    let scale = std::env::var("HETSCHED_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Smoke);
    let opts = CampaignOpts {
        scale,
        ..CampaignOpts::smoke()
    };
    let t = std::time::Instant::now();
    let records = offline::run(2, &opts);
    println!("Fig.4 campaign: {} records in {:?}\n", records.len(), t.elapsed());
    println!(
        "{}",
        render_summary_table(
            "Fig.4-left HLP-EST / HLP-OLS (paper: OLS ~8% better on average)",
            &pairwise_by_app(&records, "HLP-EST", "HLP-OLS")
        )
    );
    println!(
        "{}",
        render_summary_table(
            "Fig.4-right HEFT / HLP-OLS (paper: OLS ~2% better on average)",
            &pairwise_by_app(&records, "HEFT", "HLP-OLS")
        )
    );
    println!(
        "HLP-OLS vs HLP-EST: {:+.1}% | HLP-OLS vs HEFT: {:+.1}%",
        mean_improvement_pct(&records, "HLP-OLS", "HLP-EST"),
        mean_improvement_pct(&records, "HLP-OLS", "HEFT"),
    );
}
