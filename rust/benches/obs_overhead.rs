//! Bench: observability overhead on the contended service workload.
//!
//! Runs the same 50 DAGs × 1000 tasks × 32-CPU + 8-GPU stream (the
//! `service_throughput` instance, FIFO admission) twice: once with the
//! production no-op sink path (tracing off — the default for every
//! caller) and once with a recording sink draining after the run, and
//! writes BENCH_obs.json so the overhead trajectory is tracked PR over
//! PR.  Two acceptances:
//!
//! * the no-op path must hold the service-mode throughput floor
//!   (10k scheduled tasks/s) — the enforceable form of "instrumentation
//!   with tracing off costs nothing a gate can see";
//! * full recording must stay within 2x of the no-op path (events are
//!   heap-allocated payloads; the contract is cheap-when-off, bounded
//!   -when-on).
//!
//! The `ci.sh --perf` gate re-checks both rows from the JSON.

use std::time::Duration;

use hetsched::graph::gen;
use hetsched::platform::Platform;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sched::service::{run_service_with_ideals, Service, Submission};
use hetsched::substrate::bench::{bench_with, black_box, BenchOpts};
use hetsched::substrate::json::Json;
use hetsched::substrate::rng::Rng;

fn main() {
    let plat = Platform::hybrid(32, 8);
    let policies = [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy];
    let mut rng = Rng::new(2027);
    let subs: Vec<Submission> = (0..50)
        .map(|t| {
            let g = gen::hybrid_dag(&mut rng, 1000, 0.004);
            Submission::new(g, t as f64 * 40.0, policies[t % policies.len()].clone())
        })
        .collect();
    let total_tasks: usize = subs.iter().map(|s| s.graph.n_tasks()).sum();
    println!(
        "== obs overhead: {} tenants x 1000 tasks on {} ==",
        subs.len(),
        plat.label()
    );

    // time the streaming engine only (ideals precomputed, as in the
    // throughput bench)
    let ideals: Vec<f64> = subs
        .iter()
        .map(|s| online_by_id(&s.graph, &plat, &s.policy).makespan)
        .collect();
    let opts = BenchOpts {
        warmup: Duration::from_millis(300),
        measure: Duration::from_millis(2000),
        min_iters: 3,
        max_iters: 100_000,
    };

    // non-perturbation sanity before timing: tracing on and off place
    // identically (the obs_parity suite pins this bitwise; here it
    // guards the bench itself against comparing different schedules)
    let plain = run_service_with_ideals(&plat, &subs, Some(&ideals));
    let mut traced_svc = Service::new_with_ideals(&plat, &subs, Some(&ideals));
    traced_svc.enable_trace();
    traced_svc.run();
    let n_events = traced_svc.take_trace().len();
    let traced = traced_svc.report(None);
    assert_eq!(plain.decisions.len(), traced.decisions.len());
    assert_eq!(plain.horizon.to_bits(), traced.horizon.to_bits());

    let noop = bench_with("service 50x1000 (noop sink)", &opts, || {
        black_box(run_service_with_ideals(&plat, &subs, Some(&ideals)).horizon);
    });
    println!("{}", noop.report());
    let rec = bench_with("service 50x1000 (recording sink)", &opts, || {
        let mut svc = Service::new_with_ideals(&plat, &subs, Some(&ideals));
        svc.enable_trace();
        svc.run();
        black_box(svc.take_trace().len());
        black_box(svc.report(None).horizon);
    });
    println!("{}", rec.report());

    let noop_tps = noop.throughput(total_tasks as f64);
    let rec_tps = rec.throughput(total_tasks as f64);
    let overhead_pct =
        (rec.mean.as_secs_f64() / noop.mean.as_secs_f64() - 1.0) * 100.0;
    println!(
        "    -> noop {noop_tps:.0} tasks/s | recording {rec_tps:.0} tasks/s \
         ({overhead_pct:+.1}% , {n_events} events, {:.2} events/decision)",
        n_events as f64 / plain.decisions.len() as f64
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("obs_overhead".into())),
        ("tenants", Json::Num(subs.len() as f64)),
        ("tasks_total", Json::Num(total_tasks as f64)),
        ("platform", Json::Str(plat.label())),
        (
            "noop",
            Json::obj(vec![
                ("mean_ms", Json::Num(noop.mean.as_secs_f64() * 1e3)),
                ("p95_ms", Json::Num(noop.p95.as_secs_f64() * 1e3)),
                ("tasks_per_sec", Json::Num(noop_tps)),
            ]),
        ),
        (
            "recording",
            Json::obj(vec![
                ("mean_ms", Json::Num(rec.mean.as_secs_f64() * 1e3)),
                ("p95_ms", Json::Num(rec.p95.as_secs_f64() * 1e3)),
                ("tasks_per_sec", Json::Num(rec_tps)),
                ("events", Json::Num(n_events as f64)),
                (
                    "events_per_decision",
                    Json::Num(n_events as f64 / plain.decisions.len() as f64),
                ),
            ]),
        ),
        ("recording_overhead_pct", Json::Num(overhead_pct)),
    ]);
    std::fs::write("BENCH_obs.json", out.to_string()).expect("write BENCH_obs.json");
    println!("wrote BENCH_obs.json");

    assert!(
        noop_tps >= 10_000.0,
        "no-op-sink service throughput regressed: {noop_tps:.0} tasks/s"
    );
    assert!(
        rec.mean.as_secs_f64() <= noop.mean.as_secs_f64() * 2.0,
        "recording-sink overhead {overhead_pct:.1}% exceeds the 2x bound"
    );
}
