// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: regenerate Figure 7 — pairwise Greedy/ER-LS (left) and
//! EFT/ER-LS (right) makespan ratios per application.

use hetsched::analysis::{mean_improvement_pct, pairwise_by_app, render_summary_table};
use hetsched::experiments::{online, CampaignOpts};
use hetsched::workloads::Scale;

fn main() {
    let scale = std::env::var("HETSCHED_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Smoke);
    let opts = CampaignOpts {
        scale,
        ..CampaignOpts::smoke()
    };
    let t = std::time::Instant::now();
    let records = online::run(&opts);
    println!("Fig.7 campaign: {} records in {:?}\n", records.len(), t.elapsed());
    println!(
        "{}",
        render_summary_table(
            "Fig.7-left Greedy / ER-LS (paper: ER-LS ~16% better on average)",
            &pairwise_by_app(&records, "Greedy", "ER-LS")
        )
    );
    println!(
        "{}",
        render_summary_table(
            "Fig.7-right EFT / ER-LS (paper: EFT ~10% better on average)",
            &pairwise_by_app(&records, "EFT", "ER-LS")
        )
    );
    println!(
        "ER-LS vs Greedy: {:+.1}% | ER-LS vs EFT: {:+.1}%",
        mean_improvement_pct(&records, "ER-LS", "Greedy"),
        mean_improvement_pct(&records, "ER-LS", "EFT"),
    );
}
