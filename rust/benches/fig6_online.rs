// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: regenerate Figure 6 — online algorithms over LP* (left) and
//! the mean competitive ratio as a function of √(m/k) (right) — plus
//! decision-throughput micro-benches of the online engine.

use hetsched::analysis::{ratio_by_app, ratio_by_sqrt_mk, render_summary_table};
use hetsched::experiments::{online, CampaignOpts};
use hetsched::platform::Platform;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::substrate::bench::{bench, black_box};
use hetsched::workloads::{forkjoin, Scale};

fn main() {
    let scale = std::env::var("HETSCHED_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Smoke);
    let opts = CampaignOpts {
        scale,
        ..CampaignOpts::smoke()
    };
    let t = std::time::Instant::now();
    let records = online::run(&opts);
    println!("Fig.6 campaign: {} records in {:?}\n", records.len(), t.elapsed());
    for algo in ["ER-LS", "EFT", "Greedy", "Random"] {
        println!(
            "{}",
            render_summary_table(
                &format!("Fig.6-left makespan/LP* — {algo}"),
                &ratio_by_app(&records, algo)
            )
        );
    }
    println!("Fig.6-right mean competitive ratio (±stderr) vs sqrt(m/k):");
    for algo in ["ER-LS", "EFT", "Greedy"] {
        let series = ratio_by_sqrt_mk(&records, algo);
        let pts: Vec<String> = series
            .iter()
            .map(|(x, s)| format!("({x:.2}, {:.3}±{:.3})", s.mean, s.stderr))
            .collect();
        println!("  {algo:>7}: {}", pts.join(" "));
    }
    println!();

    // decision throughput: tasks/second through the online engine
    let g = forkjoin::forkjoin(500, 10, 1, 5); // 5011 tasks
    let plat = Platform::hybrid(64, 8);
    for policy in [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy] {
        let name = policy.name();
        let r = bench(&format!("online engine {name} (5011 tasks, 64x8)"), || {
            black_box(online_by_id(&g, &plat, &policy));
        });
        println!(
            "    -> {:.0} scheduling decisions/s",
            r.throughput(g.n_tasks() as f64)
        );
    }
}
