//! Bench: ablations of the design choices (DESIGN.md §Perf / §4.1):
//! OLS priority rule, HLP rounding threshold, and the PDHG solver's
//! warm-start / Ruiz / restart components.

use hetsched::experiments::ablation;
use hetsched::platform::Platform;
use hetsched::workloads::{chameleon, costs::CostModel, forkjoin, ggen};

fn main() {
    let plat = Platform::hybrid(16, 4);
    let cases: Vec<(&str, hetsched::graph::TaskGraph)> = vec![
        ("posv-nb10", chameleon::posv(10, &CostModel::hybrid(320), 5)),
        ("potri-nb10", chameleon::potri(10, &CostModel::hybrid(320), 5)),
        ("forkjoin-100x5", forkjoin::forkjoin(100, 5, 1, 5)),
        ("ggen-layers-8x20", ggen::layer_by_layer(8, 20, 0.3, 1, 5)),
        ("ggen-sp-150", ggen::series_parallel(150, 1, 5)),
    ];

    println!("== OLS priority rule (makespan; same HLP allocation) ==");
    for (name, g) in &cases {
        let rows = ablation::ablate_priority(g, &plat, 1e-4);
        let base = rows
            .iter()
            .find(|(n, _)| *n == "hlp-rank")
            .map(|(_, m)| *m)
            .unwrap();
        let cells: Vec<String> = rows
            .iter()
            .map(|(n, m)| format!("{n} {:.4} ({:+.1}%)", m, (m / base - 1.0) * 100.0))
            .collect();
        println!("{name:>18}: {}", cells.join(" | "));
    }

    println!("\n== HLP rounding threshold θ (x >= θ -> CPU; makespan) ==");
    for (name, g) in &cases {
        let sweep =
            ablation::ablate_rounding_threshold(g, &plat, &[0.1, 0.3, 0.5, 0.7, 0.9], 1e-4);
        let cells: Vec<String> = sweep
            .iter()
            .map(|(t, m)| format!("θ={t}: {m:.4}"))
            .collect();
        println!("{name:>18}: {}", cells.join(" | "));
    }

    println!("\n== PDHG components (iterations to tol=1e-4, cap 150k) ==");
    for (name, g) in &cases {
        println!("{name}:");
        for (label, iters, gap) in ablation::ablate_pdhg(g, &plat, 1e-4) {
            println!("    {label:>28}: {iters:>7} iters (gap {gap:.1e})");
        }
    }
}
