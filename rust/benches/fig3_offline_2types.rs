// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: regenerate Figure 3 — makespan/LP* per application for
//! HLP-EST / HLP-OLS / HEFT on 2 resource types — and time the offline
//! pipeline stages on a representative instance.
//!
//!     cargo bench --bench fig3_offline_2types
//!     HETSCHED_BENCH_SCALE=default cargo bench ...   (bigger grid)

use hetsched::algos::{run_offline, solve_hlp, Offline};
use hetsched::analysis::{ratio_by_app, render_summary_table};
use hetsched::experiments::{offline, CampaignOpts};
use hetsched::platform::Platform;
use hetsched::runtime::LpBackendKind;
use hetsched::substrate::bench::bench;
use hetsched::workloads::{chameleon, costs::CostModel, Scale};

fn scale() -> Scale {
    std::env::var("HETSCHED_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Smoke)
}

fn main() {
    // ---- the figure ----
    let opts = CampaignOpts {
        scale: scale(),
        ..CampaignOpts::smoke()
    };
    let t = std::time::Instant::now();
    let records = offline::run(2, &opts);
    println!(
        "Fig.3 campaign: {} records in {:?} (scale {:?})\n",
        records.len(),
        t.elapsed(),
        opts.scale
    );
    for algo in ["HLP-EST", "HLP-OLS", "HEFT"] {
        println!(
            "{}",
            render_summary_table(
                &format!("Fig.3 makespan/LP* — {algo}"),
                &ratio_by_app(&records, algo)
            )
        );
    }

    // ---- stage micro-benches on posv nb=10 (330 tasks), 16x4 ----
    let g = chameleon::posv(10, &CostModel::hybrid(320), 3);
    let plat = Platform::hybrid(16, 4);
    bench("hlp-solve+round (rust-pdhg, posv nb=10)", || {
        let _ = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
    });
    let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
    for algo in Offline::ALL {
        bench(&format!("{} schedule phase (posv nb=10)", algo.name()), || {
            let _ = run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::RustPdhg, 1e-4);
        });
    }
}
