// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: regenerate Figure 5 — the 3-resource-type experiment:
//! QHLP-EST / QHLP-OLS / QHEFT over LP* (left) and QHEFT/QHLP-OLS
//! pairwise (right).

use hetsched::analysis::{
    mean_improvement_pct, pairwise_by_app, ratio_by_app, render_summary_table,
};
use hetsched::experiments::{offline, CampaignOpts};
use hetsched::workloads::Scale;

fn main() {
    let scale = std::env::var("HETSCHED_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Smoke);
    let opts = CampaignOpts {
        scale,
        ..CampaignOpts::smoke()
    };
    let t = std::time::Instant::now();
    let records = offline::run(3, &opts);
    println!("Fig.5 campaign: {} records in {:?}\n", records.len(), t.elapsed());
    for algo in ["QHLP-EST", "QHLP-OLS", "QHEFT"] {
        println!(
            "{}",
            render_summary_table(
                &format!("Fig.5-left makespan/LP* — {algo}"),
                &ratio_by_app(&records, algo)
            )
        );
    }
    println!(
        "{}",
        render_summary_table(
            "Fig.5-right QHEFT / QHLP-OLS (paper: QHEFT ~5% better on average)",
            &pairwise_by_app(&records, "QHEFT", "QHLP-OLS")
        )
    );
    println!(
        "QHEFT vs QHLP-OLS: {:+.1}%",
        mean_improvement_pct(&records, "QHEFT", "QHLP-OLS")
    );
}
