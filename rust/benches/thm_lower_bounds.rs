// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: the adversarial instances of Theorems 1, 2 and 4
//! (Tables 1–3, Figures 1–2): measured ratios vs closed forms.

use hetsched::experiments::thm;

fn main() {
    println!("Theorem 1 — HEFT worst case (Table 1, Fig. 1):");
    println!(
        "{:>5} {:>3} {:>12} {:>12} {:>9} {:>9} {:>9}",
        "m", "k", "HEFT", "GOOD", "ratio", "exact", "asympt"
    );
    // note: beyond m ~ 150 the geometric processing times (m/(m+k))^i
    // collapse below f64 resolution of the HEFT rank comparisons and the
    // adversarial ordering degrades — same limit the paper's Python
    // implementation would hit.
    for (m, k) in [
        (9usize, 2usize),
        (16, 2),
        (16, 4),
        (36, 4),
        (64, 8),
        (100, 10),
        (128, 8),
    ] {
        if k * k > m {
            continue;
        }
        let t = std::time::Instant::now();
        let (heft_ms, good_ms, ratio) = thm::thm1_run(m, k);
        println!(
            "{m:>5} {k:>3} {heft_ms:>12.4} {good_ms:>12.4} {ratio:>9.4} {:>9.4} {:>9.4}   [{:?}]",
            thm::thm1_exact_ratio(m, k),
            thm::thm1_predicted_ratio(m, k),
            t.elapsed()
        );
    }

    println!("\nTheorem 2 — HLP-EST tightness (Table 2, Fig. 2):");
    println!(
        "{:>5} {:>12} {:>10} {:>10} {:>10}",
        "m", "LP*", "EST", "OLS", "6-O(1/m)"
    );
    for m in [5usize, 10, 20, 40, 80, 160] {
        let (lp_star, est_ratio, ols_ratio) = thm::thm2_run(m);
        println!(
            "{m:>5} {lp_star:>12.4} {est_ratio:>10.4} {ols_ratio:>10.4} {:>10.4}",
            thm::thm2_worst_makespan(m) / lp_star
        );
    }

    println!("\nTheorem 4 — ER-LS lower bound (Table 3):");
    println!(
        "{:>5} {:>3} {:>12} {:>12} {:>9} {:>9}",
        "m", "k", "ER-LS", "OPT", "ratio", "sqrt(m/k)"
    );
    for (m, k) in [
        (16usize, 4usize),
        (36, 4),
        (64, 4),
        (64, 16),
        (128, 8),
        (256, 4),
    ] {
        let (erls_ms, opt_ms, ratio) = thm::thm4_run(m, k);
        println!(
            "{m:>5} {k:>3} {erls_ms:>12.4} {opt_ms:>12.4} {ratio:>9.4} {:>9.4}",
            (m as f64 / k as f64).sqrt()
        );
    }
}
