// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: the batched warm-start LP subsystem on the paper grid
//! (EXPERIMENTS.md §LP).  Writes BENCH_lp.json; `ci.sh --perf` requires
//! the file to parse and the batched+warm grid total to be no slower
//! than the cold per-solve baseline.
//!
//! Five ways to solve the same (instance × machine-config) HLP grid:
//!   cold            — per-item sequential solves, uncontracted models
//!                     (the per-solve baseline of the acceptance gate)
//!   cold_parallel   — per-item solves over `parallel_map`, uncontracted:
//!                     the *pre-subsystem campaign path*, i.e. the fair
//!                     wall-clock baseline at equal worker count
//!   cold_contracted — per-item sequential, series chains contracted
//!                     (isolates the chain-dropping win)
//!   batched         — all LPs through the shared-pool batch driver,
//!                     no warm chaining
//!   warm            — the full subsystem: batched + chain contraction +
//!                     per-instance primal/dual warm chains + escalating
//!                     budgets (exactly what `experiments::driver` runs)
//!
//! Gates: warm wall < cold wall (per-solve baseline), and warm total
//! iterations ≤ cold_contracted total iterations (the work win, which
//! unlike wall clock cannot be bought with thread count; chain heads
//! are identical solves, warm seeding only removes iterations).
//!
//! Also timed and gated: the SIMD (blocked + 4-lane fused, autotuned
//! block width, range-threaded above 4096 rows) `RustChunk` kernel vs
//! the retained `ScalarChunk` oracle on a ~1000-task HLP — SIMD must
//! not lose (the `kernel` row of BENCH_lp.json, which also records the
//! block widths the autotune picked for A and Aᵀ).
//!
//! Set HETSCHED_BENCH_QUICK=1 for a reduced grid (4 configs, 1 app);
//! set HETSCHED_BENCH_FULL=1 to add the Scale::Full rows: the 10k-task
//! fork-join chain plus the 10k/50k/100k-task `ggen-layers` instances
//! on the 256-unit (192+64) platform.

use hetsched::algos::{build_hlp_job, solve_alloc_grid};
use hetsched::alloc::greedy_min_time;
use hetsched::graph::TaskGraph;
use hetsched::lp::batch::{solve_batch, BatchJob};
use hetsched::lp::chain::{plan_chains, ChainPlan};
use hetsched::lp::pdhg::{
    solve_rust, BlockedCsr, ChunkBackend, Csr, DriveOpts, RustChunk, ScalarChunk,
};
use hetsched::platform::{self, Platform};
use hetsched::substrate::json::Json;
use hetsched::substrate::pool::parallel_map;
use hetsched::workloads::{chameleon, costs::CostModel, forkjoin, Instance};
use std::time::Instant;

const TOL: f64 = 1e-4;
const MAX_ITERS: usize = 60_000;

struct GridRun {
    wall_s: f64,
    total_iters: usize,
    objs: Vec<f64>,
}

fn section(r: &GridRun) -> Json {
    Json::obj(vec![
        ("wall_s", Json::Num(r.wall_s)),
        ("iters", Json::Num(r.total_iters as f64)),
    ])
}

fn solve_one(g: &TaskGraph, plat: &Platform, contracted: bool) -> hetsched::lp::LpSolution {
    let plan = if contracted {
        plan_chains(g)
    } else {
        ChainPlan::default() // identity contraction: the uncontracted model
    };
    let (lp, warm, _) = build_hlp_job(g, plat, &greedy_min_time(g), &plan);
    solve_rust(
        &lp,
        &DriveOpts {
            tol: TOL,
            max_iters: MAX_ITERS,
            warm_start: Some(warm),
            ..Default::default()
        },
    )
}

/// cold per-solve baseline: sequential, one LP at a time.
fn run_cold(items: &[(&TaskGraph, &Platform)], contracted: bool) -> GridRun {
    let t = Instant::now();
    let mut total_iters = 0;
    let mut objs = Vec::with_capacity(items.len());
    for &(g, plat) in items {
        let sol = solve_one(g, plat, contracted);
        total_iters += sol.iters;
        objs.push(sol.obj);
    }
    GridRun {
        wall_s: t.elapsed().as_secs_f64(),
        total_iters,
        objs,
    }
}

/// the pre-subsystem campaign path: per-item solves over the worker
/// pool (fair wall-clock baseline at equal worker count).
fn run_cold_parallel(items: &[(&TaskGraph, &Platform)], workers: usize) -> GridRun {
    let t = Instant::now();
    let sols = parallel_map(items.to_vec(), workers, |(g, plat)| {
        solve_one(g, plat, false)
    });
    GridRun {
        wall_s: t.elapsed().as_secs_f64(),
        total_iters: sols.iter().map(|s| s.iters).sum(),
        objs: sols.iter().map(|s| s.obj).collect(),
    }
}

/// batch driver without warm chaining (independent jobs, shared pool).
fn run_batched(items: &[(&TaskGraph, &Platform)], workers: usize) -> GridRun {
    let t = Instant::now();
    let jobs: Vec<BatchJob> = items
        .iter()
        .map(|&(g, plat)| {
            let (lp, warm, _) = build_hlp_job(g, plat, &greedy_min_time(g), &plan_chains(g));
            BatchJob::cold(
                lp,
                DriveOpts {
                    tol: TOL,
                    max_iters: MAX_ITERS,
                    warm_start: Some(warm),
                    ..Default::default()
                },
            )
        })
        .collect();
    let sols = solve_batch(jobs, workers);
    GridRun {
        wall_s: t.elapsed().as_secs_f64(),
        total_iters: sols.iter().map(|s| s.iters).sum(),
        objs: sols.iter().map(|s| s.obj).collect(),
    }
}

/// the full subsystem, exactly as the campaign driver calls it.
fn run_warm(items: &[(&TaskGraph, &Platform)], workers: usize) -> GridRun {
    let t = Instant::now();
    let sols = solve_alloc_grid(items, TOL, MAX_ITERS, workers);
    GridRun {
        wall_s: t.elapsed().as_secs_f64(),
        total_iters: sols.iter().map(|s| s.sol.iters).sum(),
        objs: sols.iter().map(|s| s.sol.obj).collect(),
    }
}

fn main() {
    let quick = std::env::var("HETSCHED_BENCH_QUICK").is_ok();
    let cm = CostModel::hybrid(320);
    let apps: Vec<(&str, TaskGraph)> = if quick {
        vec![("potrf-nb5", chameleon::potrf(5, &cm, 3))]
    } else {
        vec![
            ("potrf-nb5", chameleon::potrf(5, &cm, 3)),
            ("posv-nb5", chameleon::posv(5, &cm, 3)),
            ("forkjoin-w100-p2", forkjoin::forkjoin(100, 2, 1, 2026)),
        ]
    };
    let configs: Vec<Platform> = if quick {
        platform::reduced_two_type_configs()
    } else {
        platform::paper_two_type_configs()
    };
    // instance-major grid order: each app's configs are consecutive, so
    // solve_alloc_grid chains warm starts along the config axis
    let mut items: Vec<(&TaskGraph, &Platform)> = Vec::new();
    for (_, g) in &apps {
        for cfg in &configs {
            items.push((g, cfg));
        }
    }
    let rows_dropped: usize = apps
        .iter()
        .map(|(_, g)| plan_chains(g).rows_dropped())
        .sum();
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    println!(
        "== lp_batch: {} apps x {} configs = {} HLPs, tol {TOL}, {} workers ==",
        apps.len(),
        configs.len(),
        items.len(),
        workers
    );

    let cold = run_cold(&items, false);
    println!(
        "cold (per-solve, uncontracted):  {:>8.3} s  {:>9} iters",
        cold.wall_s, cold.total_iters
    );
    let cold_p = run_cold_parallel(&items, workers);
    println!(
        "cold_parallel (pre-subsystem):   {:>8.3} s  {:>9} iters",
        cold_p.wall_s, cold_p.total_iters
    );
    let cold_c = run_cold(&items, true);
    println!(
        "cold (per-solve, contracted):    {:>8.3} s  {:>9} iters",
        cold_c.wall_s, cold_c.total_iters
    );
    let batched = run_batched(&items, workers);
    println!(
        "batched (shared pool):           {:>8.3} s  {:>9} iters",
        batched.wall_s, batched.total_iters
    );
    let warm = run_warm(&items, workers);
    println!(
        "batched+warm (grid chains):      {:>8.3} s  {:>9} iters",
        warm.wall_s, warm.total_iters
    );

    // every variant must land on the same LP*s within tolerance
    for (i, a) in cold.objs.iter().enumerate() {
        let scale = 1.0 + a.abs();
        for (label, run) in [
            ("cold_parallel", &cold_p),
            ("contracted", &cold_c),
            ("batched", &batched),
            ("warm", &warm),
        ] {
            let v = run.objs[i];
            assert!(
                (a - v).abs() < 5.0 * TOL * scale,
                "LP {i}: {label} obj {v} vs cold {a}"
            );
        }
    }

    // ---- SIMD vs scalar PDHG kernel ----------------------------------
    // same LP, same iterate stream, pure chunk wall clock: the SIMD
    // (fused matvec+prox, explicit 4-lane, autotuned block width)
    // RustChunk must not lose to the retained scalar oracle.  A
    // ~1000-task fork-join HLP keeps the matrix big enough to measure
    // and small enough to run in the quick gate.
    let kernel_g = forkjoin::forkjoin(499, 2, 1, 9);
    let kernel_plat = Platform::hybrid(64, 16);
    let (kernel_lp, _, _) = build_hlp_job(
        &kernel_g,
        &kernel_plat,
        &greedy_min_time(&kernel_g),
        &plan_chains(&kernel_g),
    );
    const KERNEL_CHUNKS: usize = 16; // x250 iters each
    let time_kernel = |backend: &mut dyn ChunkBackend| {
        let mut z = vec![0.0; kernel_lp.n];
        let mut y = vec![0.0; kernel_lp.m];
        backend.run_chunk(&mut z, &mut y, 1e-3, 1e-3); // warmup
        let t = Instant::now();
        for _ in 0..KERNEL_CHUNKS {
            backend.run_chunk(&mut z, &mut y, 1e-3, 1e-3);
        }
        (t.elapsed().as_secs_f64(), z[0] + y[0]) // sink defeats DCE
    };
    // record which widths the shape autotune picks for A and Aᵀ (the
    // fused passes use the same BlockedCsr layouts RustChunk builds)
    let kernel_a = Csr::from_coo(
        kernel_lp.m,
        kernel_lp.n,
        &kernel_lp.rows,
        &kernel_lp.cols,
        &kernel_lp.vals,
    );
    let kernel_block = BlockedCsr::from_csr(&kernel_a).block_rows();
    let kernel_block_t = BlockedCsr::from_csr(&kernel_a.transpose()).block_rows();
    let (blocked_s, sink_b) = time_kernel(&mut RustChunk::new(&kernel_lp, 250));
    let (scalar_s, sink_s) = time_kernel(&mut ScalarChunk::new(&kernel_lp, 250));
    // sanity, not the equivalence test (that lives in tier-1): the two
    // kernels' trajectories agree to accumulated rounding
    assert!(
        (sink_b - sink_s).abs() < 1e-3 * (1.0 + sink_s.abs()),
        "blocked and scalar kernels diverged: {sink_b} vs {sink_s}"
    );
    let kernel_speedup = scalar_s / blocked_s;
    println!(
        "kernel ({} vars x {} rows, {} chunks, blocks {}x/{}x): simd {:.4} s, scalar {:.4} s -> {:.2}x",
        kernel_lp.n, kernel_lp.m, KERNEL_CHUNKS, kernel_block, kernel_block_t,
        blocked_s, scalar_s, kernel_speedup
    );

    let speedup = cold.wall_s / warm.wall_s;
    println!("-> batched+warm vs cold per-solve baseline: {speedup:.2}x");
    println!(
        "-> batched+warm vs cold_parallel (fair wall): {:.2}x; work: {} vs {} contracted iters",
        cold_p.wall_s / warm.wall_s,
        warm.total_iters,
        cold_c.total_iters
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("lp_batch".into())),
        (
            "grid",
            Json::obj(vec![
                (
                    "instances",
                    Json::Arr(
                        apps.iter().map(|(n, _)| Json::Str(n.to_string())).collect(),
                    ),
                ),
                ("configs", Json::Num(configs.len() as f64)),
                ("lps", Json::Num(items.len() as f64)),
                ("tol", Json::Num(TOL)),
                ("workers", Json::Num(workers as f64)),
                ("chain_rows_dropped", Json::Num(rows_dropped as f64)),
            ]),
        ),
        ("cold", section(&cold)),
        ("cold_parallel", section(&cold_p)),
        ("cold_contracted", section(&cold_c)),
        ("batched", section(&batched)),
        ("warm", section(&warm)),
        ("speedup_warm_vs_cold", Json::Num(speedup)),
        (
            "speedup_warm_vs_cold_parallel",
            Json::Num(cold_p.wall_s / warm.wall_s),
        ),
        (
            "kernel",
            Json::obj(vec![
                ("blocked_s", Json::Num(blocked_s)),
                ("scalar_s", Json::Num(scalar_s)),
                ("speedup", Json::Num(kernel_speedup)),
                ("block", Json::Num(kernel_block as f64)),
                ("block_t", Json::Num(kernel_block_t as f64)),
                ("lanes", Json::Num(4.0)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_lp.json", report.to_string()).expect("write BENCH_lp.json");
    println!("wrote BENCH_lp.json");

    // acceptance: the full subsystem beats the cold per-solve baseline on
    // wall clock, and — the thread-count-independent claim — does less
    // PDHG work than per-item contracted solves of the same grid
    assert!(
        warm.wall_s < cold.wall_s,
        "acceptance: batched+warm ({:.3} s) must beat the cold per-solve baseline ({:.3} s)",
        warm.wall_s,
        cold.wall_s
    );
    // 5% slack: a warm seed is not *guaranteed* to help on every single
    // LP (a misleading neighbor optimum can converge slower than the
    // cold box projection); the gate catches systematic regressions, not
    // the occasional bad seed
    assert!(
        warm.total_iters as f64 <= cold_c.total_iters as f64 * 1.05,
        "acceptance: warm grid iterations ({}) must not exceed per-item contracted solves ({}) by >5%",
        warm.total_iters,
        cold_c.total_iters
    );
    // the SIMD kernel must not lose to the scalar oracle (5% noise
    // slack; the same gate runs off BENCH_lp.json in ci.sh --perf)
    assert!(
        blocked_s <= scalar_s * 1.05,
        "acceptance: SIMD kernel ({blocked_s:.4} s) must not lose to scalar ({scalar_s:.4} s)"
    );

    if std::env::var("HETSCHED_BENCH_FULL").is_ok() {
        // Scale::Full-sized row for EXPERIMENTS.md: a 10k-task fork-join
        // on 128x16, cold vs warm-from-64x16
        println!("\n== Scale::Full row: forkjoin w=1999 p=5 (10001 tasks) ==");
        let big = forkjoin::forkjoin(1999, 5, 1, 2026);
        let near = Platform::hybrid(64, 16);
        let far = Platform::hybrid(128, 16);
        let t = Instant::now();
        let cold_big = run_cold(&[(&big, &far)], true);
        println!(
            "cold 128x16: obj {:.4}, {} iters in {:.3} s",
            cold_big.objs[0], cold_big.total_iters, cold_big.wall_s
        );
        let items_big: Vec<(&TaskGraph, &Platform)> = vec![(&big, &near), (&big, &far)];
        let warm_big = run_warm(&items_big, 2);
        println!(
            "warm chain 64x16 -> 128x16: objs {:.4}/{:.4}, {} iters in {:.3} s (total incl. cold head; wall {:.3} s)",
            warm_big.objs[0],
            warm_big.objs[1],
            warm_big.total_iters,
            warm_big.wall_s,
            t.elapsed().as_secs_f64()
        );

        // the lifted Scale::Full grid (EXPERIMENTS.md §Scale::Full):
        // 10k/50k/100k-task layered DAGs on the 256-unit platform,
        // cold-contracted at 192x64 vs a warm chain from the paper
        // grid's biggest config.  The 100k row is minutes of PDHG —
        // that is the point of running it behind the FULL flag.
        for n in hetsched::workloads::FULL_GGEN_TASKS {
            let inst = Instance::Ggen { n_tasks: n };
            let g = inst.generate(2);
            println!(
                "\n== Scale::Full row: {} ({} tasks, {} arcs) ==",
                inst.label(),
                g.n_tasks(),
                g.n_arcs()
            );
            let far = Platform::hybrid(192, 64);
            let near = Platform::hybrid(128, 16);
            let cold_row = run_cold(&[(&g, &far)], true);
            println!(
                "cold 192x64: obj {:.4}, {} iters in {:.3} s",
                cold_row.objs[0], cold_row.total_iters, cold_row.wall_s
            );
            let chain: Vec<(&TaskGraph, &Platform)> = vec![(&g, &near), (&g, &far)];
            let warm_row = run_warm(&chain, 2);
            println!(
                "warm chain 128x16 -> 192x64: objs {:.4}/{:.4}, {} iters, wall {:.3} s",
                warm_row.objs[0], warm_row.objs[1], warm_row.total_iters, warm_row.wall_s
            );
        }
    }
}
