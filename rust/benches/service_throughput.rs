//! Bench: multi-tenant service-mode throughput gate.
//!
//! Schedules 50 DAGs × 1000 tasks on a 32-CPU + 8-GPU shared pool
//! through the streaming service engine, reports decision throughput and
//! stretch statistics, and writes BENCH_service.json so the service-mode
//! perf trajectory is tracked PR over PR (the optional `ci.sh --perf`
//! gate checks the file exists and parses).

use std::time::Duration;

use hetsched::graph::gen;
use hetsched::platform::Platform;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sched::service::{run_service, run_service_with_ideals, Submission};
use hetsched::sim::validate_service;
use hetsched::substrate::bench::{bench_with, black_box, BenchOpts};
use hetsched::substrate::json::Json;
use hetsched::substrate::rng::Rng;

fn main() {
    let plat = Platform::hybrid(32, 8);
    let policies = [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy];
    let mut rng = Rng::new(2027);
    let subs: Vec<Submission> = (0..50)
        .map(|t| {
            let g = gen::hybrid_dag(&mut rng, 1000, 0.004);
            Submission::new(g, t as f64 * 40.0, policies[t % policies.len()].clone())
        })
        .collect();
    let total_tasks: usize = subs.iter().map(|s| s.graph.n_tasks()).sum();
    println!(
        "== service mode: {} tenants x 1000 tasks on {} ==",
        subs.len(),
        plat.label()
    );

    // feasibility before timing anything
    let report = run_service(&plat, &subs);
    validate_service(&plat, &report.tenant_runs(&subs)).expect("service schedule feasible");

    // precompute the per-tenant ideal makespans so the timed region
    // measures the streaming engine only (not the metrics reruns)
    let ideals: Vec<f64> = subs
        .iter()
        .map(|s| online_by_id(&s.graph, &plat, &s.policy).makespan)
        .collect();
    let opts = BenchOpts {
        warmup: Duration::from_millis(300),
        measure: Duration::from_millis(2000),
        min_iters: 3,
        max_iters: 100_000,
    };
    let r = bench_with("service 50x1000 (32x8 pool)", &opts, || {
        black_box(run_service_with_ideals(&plat, &subs, Some(&ideals)).horizon);
    });
    println!("{}", r.report());
    let tasks_per_sec = r.throughput(total_tasks as f64);
    println!("    -> {tasks_per_sec:.0} scheduled tasks/s");

    let out = Json::obj(vec![
        ("bench", Json::Str("service_throughput".into())),
        ("tenants", Json::Num(subs.len() as f64)),
        ("tasks_total", Json::Num(total_tasks as f64)),
        ("platform", Json::Str(plat.label())),
        ("mean_ms", Json::Num(r.mean.as_secs_f64() * 1e3)),
        ("p95_ms", Json::Num(r.p95.as_secs_f64() * 1e3)),
        ("tasks_per_sec", Json::Num(tasks_per_sec)),
        ("horizon", Json::Num(report.horizon)),
        ("mean_stretch", Json::Num(report.mean_stretch)),
        ("max_stretch", Json::Num(report.max_stretch)),
        (
            "utilization",
            Json::Arr(report.utilization.iter().map(|&u| Json::Num(u)).collect()),
        ),
    ]);
    std::fs::write("BENCH_service.json", out.to_string()).expect("write BENCH_service.json");
    println!("wrote BENCH_service.json");

    // acceptance: the streaming engine must stay comfortably in the
    // tens-of-thousands-of-decisions-per-second range even on modest
    // hardware (50k decisions well under 5 s)
    assert!(
        tasks_per_sec >= 10_000.0,
        "service throughput regressed: {tasks_per_sec:.0} tasks/s"
    );
}
