// Wall-clock reads are legitimate in benches (hetlint/clippy allowlist).
#![allow(clippy::disallowed_methods)]
//! Bench: multi-tenant service-mode throughput + fairness-policy gate.
//!
//! Schedules 50 DAGs × 1000 tasks on a 32-CPU + 8-GPU shared pool
//! through the streaming service engine under each admission policy
//! (FIFO / Quota / WeightedStretch), reports decision throughput and
//! stretch statistics per policy, and writes BENCH_service.json so the
//! service-mode perf + fairness trajectory is tracked PR over PR.  The
//! `ci.sh --perf` gate parses the policy rows and requires
//! WeightedStretch's max stretch at or below FIFO's on this contended
//! instance (the fairness acceptance), on top of the throughput floor.

use std::time::Duration;

use hetsched::graph::gen;
use hetsched::platform::Platform;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sched::service::{
    run_service_with_ideals, ServiceReport, ShardedService, Submission, TenantPolicy,
};
use hetsched::sim::validate_service;
use hetsched::substrate::bench::{bench_with, black_box, BenchOpts};
use hetsched::substrate::json::Json;
use hetsched::substrate::rng::Rng;

fn main() {
    let plat = Platform::hybrid(32, 8);
    let policies = [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy];
    let mut rng = Rng::new(2027);
    let base: Vec<Submission> = (0..50)
        .map(|t| {
            let g = gen::hybrid_dag(&mut rng, 1000, 0.004);
            Submission::new(g, t as f64 * 40.0, policies[t % policies.len()].clone())
        })
        .collect();
    let total_tasks: usize = base.iter().map(|s| s.graph.n_tasks()).sum();
    println!(
        "== service mode: {} tenants x 1000 tasks on {} ==",
        base.len(),
        plat.label()
    );

    // precompute the per-tenant ideal makespans so the timed region
    // measures the streaming engine only (not the metrics reruns); the
    // ideal depends on (graph, order, policy), not on the admission
    // layer, so one set serves all three variants
    let ideals: Vec<f64> = base
        .iter()
        .map(|s| online_by_id(&s.graph, &plat, &s.policy).makespan)
        .collect();
    let opts = BenchOpts {
        warmup: Duration::from_millis(300),
        measure: Duration::from_millis(2000),
        min_iters: 3,
        max_iters: 100_000,
    };

    let admissions: [(&str, TenantPolicy); 3] = [
        ("fifo", TenantPolicy::Fifo),
        ("quota", TenantPolicy::Quota { cpu_share: 0.25, gpu_share: 0.25 }),
        ("stretch", TenantPolicy::WeightedStretch { weight: 1.0 }),
    ];

    let mut rows: Vec<(&str, Json)> = vec![
        ("bench", Json::Str("service_throughput".into())),
        ("tenants", Json::Num(base.len() as f64)),
        ("tasks_total", Json::Num(total_tasks as f64)),
        ("platform", Json::Str(plat.label())),
    ];
    let mut min_tps = f64::INFINITY;
    for (key, admission) in &admissions {
        let subs: Vec<Submission> = base
            .iter()
            .map(|s| s.clone().with_admission(admission.clone()))
            .collect();
        // feasibility before timing anything
        let report = run_service_with_ideals(&plat, &subs, Some(&ideals));
        validate_service(&plat, &report.tenant_runs(&subs))
            .unwrap_or_else(|e| panic!("{key}: infeasible service schedule: {e}"));

        let r = bench_with(&format!("service 50x1000 (32x8 pool, {key})"), &opts, || {
            black_box(run_service_with_ideals(&plat, &subs, Some(&ideals)).horizon);
        });
        println!("{}", r.report());
        let tasks_per_sec = r.throughput(total_tasks as f64);
        println!(
            "    -> {tasks_per_sec:.0} scheduled tasks/s | max stretch {:.2} | p99 {:.2} | Jain {:.3}",
            report.max_stretch, report.stretch_p99, report.jain_index
        );
        min_tps = min_tps.min(tasks_per_sec);
        rows.push((
            *key,
            Json::obj(vec![
                ("mean_ms", Json::Num(r.mean.as_secs_f64() * 1e3)),
                ("p95_ms", Json::Num(r.p95.as_secs_f64() * 1e3)),
                ("tasks_per_sec", Json::Num(tasks_per_sec)),
                ("horizon", Json::Num(report.horizon)),
                ("mean_stretch", Json::Num(report.mean_stretch)),
                ("max_stretch", Json::Num(report.max_stretch)),
                ("p99_stretch", Json::Num(report.stretch_p99)),
                ("jain_index", Json::Num(report.jain_index)),
                (
                    "utilization",
                    Json::Arr(report.utilization.iter().map(|&u| Json::Num(u)).collect()),
                ),
            ]),
        ));
    }

    // sharded two-level scheduler on the same contended instance: 4
    // disjoint slices (8 CPUs + 2 GPUs each), FIFO admission — the row
    // the ci.sh --perf gate compares against the single-loop fifo row
    // (per-shard heaps and unit trees are a quarter the size, so the
    // sharded layer must not be slower on this instance)
    let run_sharded = |shards: usize| -> ServiceReport {
        let mut svc = ShardedService::new(&plat, shards).expect("valid shard count");
        for sub in &base {
            svc.admit(sub.clone()).expect("valid submission");
        }
        svc.run();
        svc.report(Some(&ideals))
    };
    let report = run_sharded(4);
    {
        let svc = {
            let mut svc = ShardedService::new(&plat, 4).unwrap();
            for sub in &base {
                svc.admit(sub.clone()).unwrap();
            }
            svc.run();
            svc
        };
        validate_service(&plat, &report.tenant_runs(svc.submissions()))
            .unwrap_or_else(|e| panic!("sharded: infeasible merged schedule: {e}"));
    }
    let r = bench_with("service 50x1000 (32x8 pool, 4 shards)", &opts, || {
        black_box(run_sharded(4).horizon);
    });
    println!("{}", r.report());
    let sharded_tps = r.throughput(total_tasks as f64);
    println!(
        "    -> {sharded_tps:.0} scheduled tasks/s | max stretch {:.2} | p99 {:.2} | Jain {:.3}",
        report.max_stretch, report.stretch_p99, report.jain_index
    );
    rows.push((
        "sharded",
        Json::obj(vec![
            ("shards", Json::Num(4.0)),
            ("mean_ms", Json::Num(r.mean.as_secs_f64() * 1e3)),
            ("p95_ms", Json::Num(r.p95.as_secs_f64() * 1e3)),
            ("tasks_per_sec", Json::Num(sharded_tps)),
            ("horizon", Json::Num(report.horizon)),
            ("mean_stretch", Json::Num(report.mean_stretch)),
            ("max_stretch", Json::Num(report.max_stretch)),
            ("p99_stretch", Json::Num(report.stretch_p99)),
            ("jain_index", Json::Num(report.jain_index)),
            (
                "utilization",
                Json::Arr(report.utilization.iter().map(|&u| Json::Num(u)).collect()),
            ),
        ]),
    ));

    // the 1M-task cluster campaign (HETSCHED_BENCH_FULL=1): 500 tenants
    // x 2000 tasks on a 1024-unit platform, 8 shards — the scale the
    // two-level design exists for.  One timed pass (the instance is too
    // big for the sampling loop), wall clock at the bench edge only.
    if std::env::var("HETSCHED_BENCH_FULL").is_ok() {
        let big_plat = Platform::hybrid(768, 256);
        let mut rng = Rng::new(9001);
        let big: Vec<Submission> = (0..500)
            .map(|t| {
                let g = gen::hybrid_dag(&mut rng, 2000, 0.002);
                Submission::new(g, t as f64 * 5.0, policies[t % policies.len()].clone())
            })
            .collect();
        let big_tasks: usize = big.iter().map(|s| s.graph.n_tasks()).sum();
        println!(
            "== full campaign: {} tenants x 2000 tasks on {} ==",
            big.len(),
            big_plat.label()
        );
        let t0 = std::time::Instant::now();
        let mut svc = ShardedService::new(&big_plat, 8).expect("valid shard count");
        for sub in &big {
            svc.admit(sub.clone()).expect("valid submission");
        }
        svc.run();
        let elapsed = t0.elapsed().as_secs_f64();
        let m = svc.metrics();
        let tps = big_tasks as f64 / elapsed;
        println!(
            "    -> {big_tasks} tasks in {elapsed:.2}s = {tps:.0} tasks/s | \
             {} migrations across 8 shards",
            m.counter("svc_migrations")
        );
        rows.push((
            "campaign_1m",
            Json::obj(vec![
                ("shards", Json::Num(8.0)),
                ("tenants", Json::Num(big.len() as f64)),
                ("tasks_total", Json::Num(big_tasks as f64)),
                ("platform", Json::Str(big_plat.label())),
                ("wall_s", Json::Num(elapsed)),
                ("tasks_per_sec", Json::Num(tps)),
                ("migrations", Json::Num(m.counter("svc_migrations") as f64)),
            ]),
        ));
    }

    let out = Json::obj(rows);
    std::fs::write("BENCH_service.json", out.to_string()).expect("write BENCH_service.json");
    println!("wrote BENCH_service.json");

    // acceptance: the streaming engine must stay comfortably in the
    // tens-of-thousands-of-decisions-per-second range even on modest
    // hardware (50k decisions well under 5 s) — under EVERY admission
    // policy, so a pathological quota/reordering path cannot hide; the
    // fairness gate (stretch max_stretch strictly below fifo's) is
    // re-checked from the JSON by ci.sh --perf
    assert!(
        min_tps >= 10_000.0,
        "service throughput regressed: {min_tps:.0} tasks/s on the slowest policy"
    );
}
